"""Production decoupled LayUp lane (DESIGN.md §9): double-buffered params,
D-deep gradient FIFO, per-layer-group version clocks — and its parity with
the sim trainer's fb_ratio/update_delay semantics.

Fast tests run in-process on one device (M=1 prod backend) or lower-only in
a subprocess; the compile-and-execute mesh tests are marked ``slow`` and run
in the nightly job."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _fixtures import mlp_batch as _batch, mlp_problem as _mlp_problem
from _subproc import run_sub as _run
from repro.core import TrainerBackend, make_backend
from repro.optim import constant, momentum


class TestProdBackend:
    def test_satisfies_protocol(self):
        loss_fn, _ = _mlp_problem()
        be = make_backend("prod", "layup", M=1, loss_fn=loss_fn,
                          optimizer=momentum(0.9), schedule=constant(0.05))
        assert isinstance(be, TrainerBackend)
        assert be.kind == "prod" and be.name == "prod:layup"

    def test_rejects_non_layup_algorithms(self):
        loss_fn, _ = _mlp_problem()
        with pytest.raises(ValueError, match="layup family"):
            make_backend("prod", "ddp", M=1, loss_fn=loss_fn,
                         optimizer=momentum(0.9), schedule=constant(0.05))

    def test_requires_numeric_pieces(self):
        with pytest.raises(ValueError, match="prod backend needs"):
            make_backend("prod", "layup", M=1)

    def test_requires_enough_devices(self):
        loss_fn, _ = _mlp_problem()
        with pytest.raises(ValueError, match="devices"):
            make_backend("prod", "layup", M=1 + len(jax.devices()),
                         loss_fn=loss_fn, optimizer=momentum(0.9),
                         schedule=constant(0.05))

    @pytest.mark.parametrize("R,D", [(1, 0), (1, 1), (2, 1)])
    def test_sim_prod_parity(self, R, D):
        """Acceptance: prod == sim trainer at R=1/D=0 AND through the
        decoupled operating points (the tentpole's R/D parity) — exact
        staleness accounting, loss within 1e-5 (here: exactly equal),
        step by step. D>0 cross-checks the two gradient-FIFO
        implementations (api.make_sim_trainer vs backward_update_lane)."""
        loss_fn, params = _mlp_problem()
        kw = dict(M=1, loss_fn=loss_fn, optimizer=momentum(0.9),
                  schedule=constant(0.05), fb_ratio=R, update_delay=D)
        prod = make_backend("prod", "layup", **kw)
        sim = make_backend("sim", "layup-hypercube", **kw)
        ps = prod.init(jax.random.PRNGKey(0), params)
        ss = sim.init(jax.random.PRNGKey(0), params)
        rng = jax.random.PRNGKey(3)
        for t in range(5):
            b = _batch(t)
            rng, r = jax.random.split(rng)
            ps, pm = prod.step(ps, b, r)
            ss, sm = sim.step(ss, b, r)
            assert abs(float(pm["loss"]) - float(sm["loss"])) < 1e-5
            np.testing.assert_array_equal(
                np.asarray(pm["layer_staleness"]),
                np.asarray(sm["layer_staleness"]))
            assert float(pm["update_staleness"]) == float(
                sm["update_staleness"])
            assert float(pm["weight_sum"]) == pytest.approx(1.0)
        assert prod.summary()["steps"] == sim.summary()["steps"] == 5.0

    def test_fifo_depth_and_warmup(self):
        """State carries a D-deep gradient FIFO; the first D updates are
        warm-up no-ops and update_staleness == D afterwards."""
        loss_fn, params = _mlp_problem()
        D = 2
        be = make_backend("prod", "layup", M=1, loss_fn=loss_fn,
                          optimizer=momentum(0.9), schedule=constant(0.05),
                          fb_ratio=2, update_delay=D)
        st = be.init(jax.random.PRNGKey(0), params)
        assert st["fifo"]["stamp"].shape == (D,)
        assert jax.tree.leaves(st["fifo"]["g"])[0].shape[1] == D
        p0 = jax.tree.map(np.asarray, st["read"])
        rng = jax.random.PRNGKey(3)
        for t in range(D + 2):
            rng, r = jax.random.split(rng)
            st, m = be.step(st, _batch(t), r)
            if t < D:
                # zero-gradient pops: params must not move during warm-up
                err = max(float(np.abs(np.asarray(a) - b).max())
                          for a, b in zip(jax.tree.leaves(st["read"]),
                                          jax.tree.leaves(p0)))
                assert err == 0.0, (t, err)
                assert float(m["update_staleness"]) == 0.0
            else:
                assert float(m["update_staleness"]) == float(D)
        moved = max(float(np.abs(np.asarray(a) - b).max())
                    for a, b in zip(jax.tree.leaves(st["read"]),
                                    jax.tree.leaves(p0)))
        assert moved > 0.0

    def test_fifo_buffers_match_param_dtype(self):
        """Satellite: the gradient FIFO allocates in the params' dtypes
        (bf16 params get a bf16 FIFO — half the memory of the old f32
        buffers), in BOTH FIFO implementations (prod fifo_init and the sim
        trainer's delay state), and a step preserves the dtype."""
        from repro.core import get_algorithm, make_sim_trainer
        loss_fn, params = _mlp_problem()
        params16 = jax.tree.map(lambda p: p.astype(jnp.bfloat16), params)
        be = make_backend("prod", "layup", M=1, loss_fn=loss_fn,
                          optimizer=momentum(0.9), schedule=constant(0.05),
                          update_delay=2)
        st = be.init(jax.random.PRNGKey(0), params16)
        for buf, p in zip(jax.tree.leaves(st["fifo"]["g"]),
                          jax.tree.leaves(params16)):
            assert buf.dtype == p.dtype, (buf.dtype, p.dtype)
        st, _ = be.step(st, _batch(0), jax.random.PRNGKey(1))
        for buf, p in zip(jax.tree.leaves(st["fifo"]["g"]),
                          jax.tree.leaves(params16)):
            assert buf.dtype == p.dtype, (buf.dtype, p.dtype)
        assert st["fifo"]["stamp"].dtype == jnp.float32
        init_fn, step_fn = make_sim_trainer(
            get_algorithm("layup-hypercube"), loss_fn, momentum(0.9),
            constant(0.05), 1, update_delay=2)
        sst = init_fn(jax.random.PRNGKey(0), params16)
        for buf, p in zip(jax.tree.leaves(sst.delay["g"]),
                          jax.tree.leaves(params16)):
            assert buf.dtype == p.dtype, (buf.dtype, p.dtype)
        sst, _ = step_fn(sst, _batch(0), jax.random.PRNGKey(1))
        for buf, p in zip(jax.tree.leaves(sst.delay["g"]),
                          jax.tree.leaves(params16)):
            assert buf.dtype == p.dtype, (buf.dtype, p.dtype)

    def test_version_clock_monotone_and_buffers_consistent(self):
        loss_fn, params = _mlp_problem()
        be = make_backend("prod", "layup", M=1, loss_fn=loss_fn,
                          optimizer=momentum(0.9), schedule=constant(0.05),
                          fb_ratio=2, update_delay=1)
        st = be.init(jax.random.PRNGKey(0), params)
        prev = np.asarray(st["versions"])
        rng = jax.random.PRNGKey(3)
        for t in range(4):
            rng, r = jax.random.split(rng)
            st, _ = be.step(st, _batch(t), r)
            v = np.asarray(st["versions"])
            assert (v >= prev).all(), "version clock moved backward"
            prev = v
            # read adopts write at every buffer swap
            err = max(float(jnp.abs(a - b).max())
                      for a, b in zip(jax.tree.leaves(st["read"]),
                                      jax.tree.leaves(st["write"])))
            assert err == 0.0

    def test_fb_ratio_requires_divisible_batch(self):
        loss_fn, params = _mlp_problem()
        be = make_backend("prod", "layup", M=1, loss_fn=loss_fn,
                          optimizer=momentum(0.9), schedule=constant(0.05),
                          fb_ratio=3)
        st = be.init(jax.random.PRNGKey(0), params)
        with pytest.raises(ValueError, match="fb_ratio=3"):
            be.step(st, _batch(0, b=8), jax.random.PRNGKey(1))

    def test_straggler_mask_freezes_updates_not_gossip(self):
        """straggler_delays[i]=d: worker i applies its update every d+1
        steps only; with M=1 and d=1 the odd steps are exact no-ops."""
        loss_fn, params = _mlp_problem()
        be = make_backend("prod", "layup", M=1, loss_fn=loss_fn,
                          optimizer=momentum(0.9), schedule=constant(0.05),
                          straggler_delays=np.array([1]))
        st = be.init(jax.random.PRNGKey(0), params)
        rng = jax.random.PRNGKey(3)
        st, _ = be.step(st, _batch(0), rng)  # t=0: active
        p_after0 = jax.tree.map(np.asarray, st["read"])
        st, _ = be.step(st, _batch(1), rng)  # t=1: frozen
        err = max(float(np.abs(np.asarray(a) - b).max())
                  for a, b in zip(jax.tree.leaves(st["read"]),
                                  jax.tree.leaves(p_after0)))
        assert err == 0.0
        st, _ = be.step(st, _batch(2), rng)  # t=2: active again
        moved = max(float(np.abs(np.asarray(a) - b).max())
                    for a, b in zip(jax.tree.leaves(st["read"]),
                                    jax.tree.leaves(p_after0)))
        assert moved > 0.0


class TestMakeStepRouting:
    def test_decoupled_rejects_ddp_and_accum(self):
        from repro.configs import get_config, reduced, ShapeConfig
        from repro.launch.train import make_step
        from repro.models import build_model
        m = build_model(reduced(get_config("stablelm-1.6b")))
        shape = ShapeConfig("t", 16, 4, "train")
        with pytest.raises(ValueError, match="decoupled"):
            make_step(m, None, shape, algo="ddp", fb_ratio=2)
        with pytest.raises(ValueError, match="accum_steps"):
            make_step(m, None, shape, algo="layup", fb_ratio=2,
                      accum_steps=2)


def test_decoupled_step_lowers_on_dryrun_mesh():
    """Acceptance: make_step(algo="layup", fb_ratio=2, update_delay=1)
    lowers on the host-device dry-run mesh — tier-1, so the CI matrix
    exercises BOTH branches of the shard_map import shim on every PR
    (lower-only: no XLA compile, seconds not minutes)."""
    out = _run("""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from repro.configs import get_config, reduced, ShapeConfig
from repro.launch.mesh import make_test_mesh
from repro.launch.train import make_step
from repro.models import build_model
from repro.optim import momentum, constant
cfg = reduced(get_config("stablelm-1.6b"))
m = build_model(cfg)
shape = ShapeConfig("t", 16, 4, "train")
for mesh_shape, axes in (((1, 1, 2), ("pod", "data", "model")),
                         ((2, 2), ("data", "model"))):
    mesh = make_test_mesh(mesh_shape, axes)
    step = make_step(m, mesh, shape, algo="layup", optimizer=momentum(0.9),
                     schedule=constant(0.05), shifts=(1,), fb_ratio=2,
                     update_delay=1)
    step.lower()
    print("LOWERED", step.describe)
""", timeout=900)
    assert out.count("LOWERED") == 2
    assert "R=2, D=1" in out


@pytest.mark.slow
def test_decoupled_prod_r2d1_runs_on_dryrun_mesh():
    """Satellite: R=2/D=1 prod step compiles AND RUNS on the 1×1×2 dry-run
    mesh — gradient FIFO depth, per-group version-clock monotonicity, and
    parity with make_sim_trainer at R=1/D=0 (loss within 1e-5, staleness
    accounting exact)."""
    out = _run("""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config, reduced, ShapeConfig
from repro.core import get_algorithm, make_sim_trainer
from repro.launch.mesh import make_test_mesh
from repro.launch.train import make_step, make_decoupled_state
from repro.models import build_model
from repro.optim import momentum, constant
from repro.data.synthetic import lm_batch_for

mesh = make_test_mesh((1, 1, 2), ("pod", "data", "model"))
cfg = reduced(get_config("stablelm-1.6b"))
m = build_model(cfg)
shape = ShapeConfig("t", 16, 4, "train")
opt = momentum(0.9)

# --- R=2/D=1: FIFO depth + version-clock monotonicity ---------------------
step = make_step(m, mesh, shape, algo="layup", optimizer=opt,
                 schedule=constant(0.05), shifts=(1,), fb_ratio=2,
                 update_delay=1)
c = step.lower().compile()
params = m.init(jax.random.PRNGKey(0))
sp = jax.tree.map(lambda p: jnp.broadcast_to(p[None], (1,) + p.shape) + 0,
                  params)
state = make_decoupled_state(sp, opt, update_delay=1)
assert state["fifo"]["stamp"].shape == (1,)
assert jax.tree.leaves(state["fifo"]["g"])[0].shape[1] == 1
batch = lm_batch_for(cfg, 4, 16)
prev = np.asarray(state["versions"])
for t in range(3):
    state, metrics = c(state, batch, jnp.asarray(t, jnp.int32),
                       jnp.zeros((), jnp.int32))
    v = np.asarray(state["versions"])
    assert (v >= prev).all()
    prev = v
    assert np.isfinite(float(metrics["loss"]))
print("R2D1 OK", float(metrics["loss"]),
      float(metrics["update_staleness"]))
assert float(metrics["update_staleness"]) == 1.0

# --- R=1/D=0 parity with make_sim_trainer ---------------------------------
# (make_step routes R=1/D=0 to the lockstep builder, so build the
# decoupled lane directly — parity proves the lanes add nothing at the
# trivial operating point)
from repro.launch.train import make_layup_decoupled_train_step
stepQ = make_layup_decoupled_train_step(
    m, mesh, opt, constant(0.05), shape, shifts=(1,), fb_ratio=1,
    update_delay=0)
cQ = stepQ.lower().compile()
state = make_decoupled_state(sp, opt, update_delay=0)
init_fn, sim_step = make_sim_trainer(
    get_algorithm("layup-hypercube"), m.loss_fn, opt, constant(0.05), 1)
sim_state = init_fn(jax.random.PRNGKey(0), params)
rng = jax.random.PRNGKey(7)
for t in range(4):
    batch = lm_batch_for(cfg, 4, 16, seed=t)
    sim_batch = jax.tree.map(lambda x: x[None], batch)
    state, pm = cQ(state, batch, jnp.asarray(t, jnp.int32),
                   jnp.zeros((), jnp.int32))
    rng, r = jax.random.split(rng)
    sim_state, sm = sim_step(sim_state, sim_batch, r)
    dl = abs(float(pm["loss"]) - float(sm["loss"]))
    ds = np.abs(np.asarray(pm["layer_staleness"])
                - np.asarray(sm["layer_staleness"])).max()
    print("t", t, "dloss", dl, "dstale", ds)
    assert dl < 1e-5, (t, dl)
    assert ds == 0.0, (t, ds)
print("PARITY OK")
""")
    assert "R2D1 OK" in out and "PARITY OK" in out


@pytest.mark.slow
def test_decoupled_m2_staleness_matches_sim_hypercube():
    """M=2 on the (2,2) mesh: the ring's version stamping equals the sim
    hypercube schedule's stamping step for step (params diverge — the prod
    mix order differs from the sim's mixed-version update — but the
    staleness *accounting* is the same machinery), and the first update's
    loss matches to float tolerance. M=2 ring-1 gossip also keeps the two
    replicas in exact consensus, like the lockstep step."""
    out = _run("""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config, reduced, ShapeConfig
from repro.core import get_algorithm, make_sim_trainer
from repro.launch.mesh import make_test_mesh
from repro.launch.train import make_layup_decoupled_train_step, make_decoupled_state
from repro.models import build_model
from repro.optim import momentum, constant
from repro.data.synthetic import lm_batch_for

mesh = make_test_mesh((2, 2), ("data", "model"))
cfg = reduced(get_config("stablelm-1.6b"))
m = build_model(cfg)
shape = ShapeConfig("t", 16, 8, "train")
opt = momentum(0.9)
M = 2
step = make_layup_decoupled_train_step(
    m, mesh, opt, constant(0.05), shape, shifts=(1,), fb_ratio=1,
    update_delay=0)
c = step.lower().compile()
params = m.init(jax.random.PRNGKey(0))
sp = jax.tree.map(lambda p: jnp.broadcast_to(p[None], (M,) + p.shape) + 0,
                  params)
state = make_decoupled_state(sp, opt, update_delay=0)
init_fn, sim_step = make_sim_trainer(
    get_algorithm("layup-hypercube"), m.loss_fn, opt, constant(0.05), M)
sim_state = init_fn(jax.random.PRNGKey(0), params)
rng = jax.random.PRNGKey(7)
for t in range(3):
    batch = lm_batch_for(cfg, 8, 16, seed=t)
    sim_batch = jax.tree.map(
        lambda x: x.reshape((M, x.shape[0] // M) + x.shape[1:]), batch)
    state, pm = c(state, batch, jnp.asarray(t, jnp.int32),
                  jnp.zeros((), jnp.int32))
    rng, r = jax.random.split(rng)
    sim_state, sm = sim_step(sim_state, sim_batch, r)
    ds = np.abs(np.asarray(pm["layer_staleness"])
                - np.asarray(sm["layer_staleness"])).max()
    if t == 0:
        dl = abs(float(pm["loss"]) - float(sm["loss"]))
        assert dl < 1e-5, dl
    assert ds == 0.0, (t, ds)
    # shift-1 exchange at M=2 brings both replicas to full consensus
    diff = max(float(jnp.abs(x[0] - x[1]).max())
               for x in jax.tree.leaves(state["read"]))
    assert diff < 1e-5, diff
print("M2 OK")
""")
    assert "M2 OK" in out
