"""System behaviour of LayUp + baselines on the sim backend."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (consensus, disagreement, get_algorithm,
                        list_algorithms, make_backend, make_sim_trainer)
from repro.core.api import choose_peers, pushsum_weight_update
from repro.core.layerview import LayerPartition, send_fractions
from repro.core.drift import (elastic_constant, estimate_lipschitz,
                              gradient_bias, lemma61_bound)
from repro.data.synthetic import SyntheticVision, make_worker_batches
from repro.optim import constant, momentum, sgd

M = 8


def _mlp_problem():
    ds = SyntheticVision(num_classes=10, dim=32, snr=1.5, seed=0)

    def init(rng):
        k1, k2 = jax.random.split(rng)
        return {"l1": jax.random.normal(k1, (32, 64)) * 0.2,
                "l2": jax.random.normal(k2, (64, 10)) * 0.2}

    def loss_fn(p, batch):
        h = jnp.tanh(batch["x"] @ p["l1"])
        logits = h @ p["l2"]
        ce = -jnp.mean(jax.nn.log_softmax(logits)[
            jnp.arange(logits.shape[0]), batch["labels"]])
        return ce, {}

    return ds, init, loss_fn


def _run(algo_name, steps=200, delays=None, lr=0.05, seed=0, workers=M,
         **trainer_kw):
    ds, init, loss_fn = _mlp_problem()
    algo = get_algorithm(algo_name)
    init_fn, step_fn = make_sim_trainer(algo, loss_fn, momentum(0.9),
                                        constant(lr), workers,
                                        straggler_delays=delays, **trainer_kw)
    st = init_fn(jax.random.PRNGKey(seed), init(jax.random.PRNGKey(seed + 1)))
    rng = jax.random.PRNGKey(seed + 2)
    losses, dis, stale = [], [], []
    for t in range(steps):
        batch = jax.tree.map(jnp.asarray,
                             make_worker_batches(ds, workers, 32, t))
        rng, r = jax.random.split(rng)
        st, metrics = step_fn(st, batch, r)
        losses.append(float(metrics["loss"]))
        dis.append(float(metrics["disagreement"]))
        stale.append(np.asarray(metrics["layer_staleness"]))
    return st, np.array(losses), np.array(dis), np.array(stale)


class TestConvergence:
    @pytest.mark.parametrize("algo", ["ddp", "layup", "gosgd", "adpsgd",
                                      "localsgd", "slowmo", "co2"])
    def test_all_algorithms_converge(self, algo):
        _, losses, _, _ = _run(algo)
        assert np.mean(losses[-20:]) < 0.6 * losses[0], algo

    def test_layup_matches_ddp_quality(self):
        """Paper C1: LayUp reaches DDP-level loss (±10%)."""
        _, l_ddp, _, _ = _run("ddp")
        _, l_layup, _, _ = _run("layup")
        assert np.mean(l_layup[-20:]) < 1.1 * np.mean(l_ddp[-20:])


class TestLayUpMechanics:
    def test_ddp_replicas_identical(self):
        st, _, dis, stale = _run("ddp", steps=20)
        assert dis[-1] < 1e-5

    def test_layup_weights_conserved(self):
        st, _, _, _ = _run("layup", steps=50)
        assert float(jnp.sum(st.weights)) == pytest.approx(1.0, abs=1e-5)

    def test_gosgd_mass_includes_in_flight(self):
        st, _, _, _ = _run("gosgd", steps=50)
        total = (float(jnp.sum(st.weights))
                 + float(jnp.sum(st.extras["q0"]["w"]))
                 + float(jnp.sum(st.extras["q1"]["w"])))
        assert total == pytest.approx(1.0, abs=1e-5)

    def test_layerwise_staleness_below_block_per_layer(self):
        """Paper §3.2/C5, at layer granularity: layer-wise (zero-delay)
        updates are strictly fresher than end-of-iteration block updates at
        EVERY layer group (block messages ride a 2-slot queue → staleness 2;
        layer-wise messages land mid-backward → staleness < 1)."""
        _, _, _, s_layer = _run("layup", steps=80)
        _, _, _, s_block = _run("layup-block", steps=80)
        mean_layer = s_layer[40:].mean(axis=0)
        mean_block = s_block[40:].mean(axis=0)
        assert mean_layer.shape == mean_block.shape == (2,)
        assert np.all(mean_layer < mean_block), (mean_layer, mean_block)

    def test_straggler_robust_accuracy(self):
        """Paper Fig 3A: a delayed worker does not break convergence."""
        delays = np.zeros(M, int)
        delays[0] = 4
        _, losses, _, _ = _run("layup", steps=200, delays=delays)
        assert np.mean(losses[-20:]) < 0.6 * losses[0]

    def test_disagreement_bounded(self):
        """Paper Fig A1/C7: disagreement stays bounded during training."""
        _, _, dis, _ = _run("layup", steps=200)
        assert np.max(dis[20:]) < 10 * (np.mean(dis[20:]) + 1e-9)


class TestHypercubeGossip:
    def test_converges_and_conserves_mass(self):
        st, losses, _, _ = _run("layup-hypercube", steps=150)
        assert np.mean(losses[-20:]) < 0.6 * losses[0]
        assert float(jnp.sum(st.weights)) == pytest.approx(1.0, abs=1e-5)

    def test_lower_drift_than_random_gossip(self):
        """Beyond-paper claim: deterministic hypercube schedule mixes faster
        than uniform random gossip at the same message volume."""
        means = {algo: np.mean([
            np.mean(_run(algo, steps=150, seed=s)[2][50:]) for s in (0, 1)])
            for algo in ("layup", "layup-hypercube")}
        assert means["layup-hypercube"] < 0.75 * means["layup"], means

    def test_xor_partner_is_involution(self):
        from repro.core import get_algorithm
        algo = get_algorithm("layup-hypercube")
        for step in range(4):
            send_ok, has_recv, sender_idx = algo._peers(
                jax.random.PRNGKey(0), 8, jnp.ones(8, bool), step)
            s = np.asarray(sender_idx)
            np.testing.assert_array_equal(s[s], np.arange(8))


class TestGradAccumulation:
    def test_sim_vs_accum_equivalence_concept(self):
        """Averaging grads over microbatches == full-batch grads (linearity),
        checked on the MLP problem."""
        ds, init, loss_fn = _mlp_problem()
        p = init(jax.random.PRNGKey(0))
        batch = jax.tree.map(jnp.asarray, make_worker_batches(ds, 1, 64, 0))
        b = jax.tree.map(lambda x: x[0], batch)
        g_full = jax.grad(lambda p: loss_fn(p, b)[0])(p)
        halves = [jax.tree.map(lambda x: x[:32], b),
                  jax.tree.map(lambda x: x[32:], b)]
        g_acc = jax.tree.map(
            lambda a, c: (a + c) / 2,
            jax.grad(lambda p: loss_fn(p, halves[0])[0])(p),
            jax.grad(lambda p: loss_fn(p, halves[1])[0])(p))
        for a, c in zip(jax.tree.leaves(g_full), jax.tree.leaves(g_acc)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                                       rtol=1e-4, atol=1e-5)


class TestPeerSelection:
    def test_no_self_sends_and_unique_receivers(self, rng):
        active = jnp.ones(M, bool)
        for i in range(20):
            r = jax.random.fold_in(rng, i)
            send_ok, has_recv, sender_idx = choose_peers(r, M, active)
            # winners are unique per receiver by construction
            senders = np.asarray(sender_idx)[np.asarray(has_recv)]
            assert len(senders) == len(set(senders.tolist()))
            assert int(send_ok.sum()) == int(has_recv.sum())
            # no sender sends to itself
            assert not np.any(senders == np.arange(M)[np.asarray(has_recv)])

    def test_inactive_workers_never_send(self, rng):
        active = jnp.zeros(M, bool).at[0].set(True)
        send_ok, has_recv, _ = choose_peers(rng, M, active)
        assert int(send_ok.sum()) <= 1
        assert not bool(send_ok[1:].any())

    def test_pushsum_conservation(self, rng):
        w = jax.random.uniform(rng, (M,)) + 0.1
        w = w / w.sum()
        active = jnp.ones(M, bool)
        for i in range(10):
            r = jax.random.fold_in(rng, 100 + i)
            send_ok, has_recv, sender_idx = choose_peers(r, M, active)
            w = pushsum_weight_update(w, send_ok, has_recv, sender_idx)
            assert float(w.sum()) == pytest.approx(1.0, abs=1e-6)
            assert float(w.min()) > 0


class TestTheory:
    def test_lemma61_bias_bound(self, rng):
        """Empirical check of Lemma 6.1: ‖b‖² ≤ 4·K̂²·η²·B̂²."""
        ds, init, loss_fn = _mlp_problem()
        st, _, _, _ = _run("layup", steps=100, lr=0.05)
        batch = jax.tree.map(jnp.asarray, make_worker_batches(ds, M, 32, 999))
        b0 = jax.tree.map(lambda x: x[0], batch)
        params0 = jax.tree.map(lambda x: x[0], st.params)
        params1 = jax.tree.map(lambda x: x[1], st.params)
        # x̃ = x̂ mixed once with a peer (the lemma's mixed version)
        w0, w1 = float(st.weights[0]), float(st.weights[1]) / 2
        a, b = w0 / (w0 + w1), w1 / (w0 + w1)
        p_tilde = jax.tree.map(lambda x, y: a * x + b * y, params0, params1)

        k_hat = estimate_lipschitz(loss_fn, params0, b0, rng, n_probes=8)
        b_hat = elastic_constant(st.params, st.weights, 0.05)
        bias = gradient_bias(loss_fn, params0, p_tilde, b0)
        bound = lemma61_bound(k_hat, 0.05, b_hat)
        assert float(bias) ** 2 <= float(bound) * 1.5  # slack for estimation

    def test_consensus_weighted_mean(self, rng):
        params = {"w": jax.random.normal(rng, (4, 3))}
        weights = jnp.array([0.4, 0.3, 0.2, 0.1])
        c = consensus(params, weights)
        expect = np.average(np.asarray(params["w"]), axis=0,
                            weights=np.asarray(weights))
        np.testing.assert_allclose(np.asarray(c["w"]), expect, rtol=1e-5)

    def test_mass_conservation_zero_grads(self, rng):
        """With zero updates, Σ wᵢxᵢ is exactly conserved by LayUp mixing."""
        algo = get_algorithm("layup")
        params = {"w": jax.random.normal(rng, (M, 5))}
        weights = jnp.full((M,), 1.0 / M)
        updates = {"w": jnp.zeros((M, 5))}
        active = jnp.ones(M, bool)
        mass0 = consensus(params, weights)["w"]
        part = LayerPartition(params)
        v, w, _, _ = algo.post(part.view(params, M=M), weights, (),
                               part.split(updates), active,
                               jax.random.fold_in(rng, 5), 0)
        mass1 = consensus(part.join(v.groups), w)["w"]
        np.testing.assert_allclose(np.asarray(mass0), np.asarray(mass1),
                                   rtol=1e-5, atol=1e-6)


def test_registry_complete():
    algos = list_algorithms()
    for a in ("layup", "layup-block", "ddp", "gosgd", "adpsgd", "localsgd",
              "slowmo", "co2"):
        assert a in algos


class TestLayerGranularHooks:
    def test_sigma_w_conserved_direct_hooks(self, rng):
        """Σw is conserved by the v2 grouped post() for every gossip mode."""
        for name in ("layup", "layup-hypercube", "adpsgd"):
            algo = get_algorithm(name)
            params = {"l1": jax.random.normal(rng, (M, 4, 3)),
                      "l2": jax.random.normal(jax.random.fold_in(rng, 1),
                                              (M, 3))}
            part = LayerPartition(params)
            w = jax.random.uniform(jax.random.fold_in(rng, 2), (M,)) + 0.1
            w = w / w.sum()
            updates = jax.tree.map(jnp.zeros_like, params)
            view = part.view(params, M=M)
            extras = algo.init_extras(view, M)
            for step in range(5):
                view, w, extras, _ = algo.post(
                    view, w, extras, part.split(updates),
                    jnp.ones(M, bool), jax.random.fold_in(rng, 10 + step),
                    jnp.int32(step))
            assert float(w.sum()) == pytest.approx(1.0, abs=1e-5), name

    def test_versions_monotone_and_grouped(self):
        """Version clocks expose one column per layer group and never move
        backwards."""
        st, _, _, stale = _run("layup", steps=30)
        assert st.versions.shape == (M, 2)
        assert stale.shape == (30, 2)
        assert np.all(np.asarray(st.versions) >= 0.0)

    def test_send_fractions_depth_ordering(self):
        """Output-most groups are generated earliest in the backward."""
        phi = send_fractions(4)
        assert phi.shape == (4,)
        assert np.all(np.diff(phi) < 0)  # deeper group => earlier send
        assert 0.0 < phi[-1] <= phi[0] <= 1.0


class TestHypercubeNonPowerOfTwo:
    def test_unpaired_workers_idle(self):
        """M=6: XOR partners ≥ M leave workers unpaired — they must not send
        or receive, and valid pairs must stay involutions."""
        algo = get_algorithm("layup-hypercube")
        M6 = 6
        for step in range(6):
            send_ok, has_recv, sender_idx = algo._peers(
                jax.random.PRNGKey(0), M6, jnp.ones(M6, bool), step)
            send_ok = np.asarray(send_ok)
            has_recv = np.asarray(has_recv)
            sender_idx = np.asarray(sender_idx)
            bits = 3  # ceil(log2(6))
            stride = 1 << (step % bits)
            partners = np.arange(M6) ^ stride
            # anyone whose partner is out of range is fully idle
            out = partners >= M6
            assert not send_ok[out].any(), (step, send_ok)
            assert not has_recv[out].any(), (step, has_recv)
            # receivers hear from exactly their XOR partner, which echoes back
            s = sender_idx[has_recv]
            np.testing.assert_array_equal(
                partners[has_recv], s)
            assert int(send_ok.sum()) == int(has_recv.sum())

    def test_converges_and_conserves_mass_m6(self):
        st, losses, _, _ = _run("layup-hypercube", steps=120, workers=6)
        assert np.mean(losses[-20:]) < 0.7 * losses[0]
        assert float(jnp.sum(st.weights)) == pytest.approx(1.0, abs=1e-5)


class TestDecoupledExecution:
    """The paper's PD-ASGD mechanism: fb_ratio=R forward passes per backward,
    update_delay=D iterations between a gradient's forward and its landing."""

    def test_all_algorithms_run_decoupled_under_backend(self):
        """Acceptance: make_sim_trainer(..., fb_ratio=R, update_delay=D) runs
        all seven algorithms behind the TrainerBackend protocol with
        per-layer staleness metrics exposed."""
        ds, init, loss_fn = _mlp_problem()
        for name in ("ddp", "layup", "gosgd", "adpsgd", "localsgd",
                     "slowmo", "co2"):
            be = make_backend("sim", name, M=4, loss_fn=loss_fn,
                              optimizer=momentum(0.9), schedule=constant(0.05),
                              fb_ratio=2, update_delay=1)
            st = be.init(jax.random.PRNGKey(0), init(jax.random.PRNGKey(1)))
            rng = jax.random.PRNGKey(2)
            for t in range(4):
                batch = jax.tree.map(jnp.asarray,
                                     make_worker_batches(ds, 4, 32, t))
                rng, r = jax.random.split(rng)
                st, m = be.step(st, batch, r)
            assert np.asarray(m["layer_staleness"]).shape == (2,), name
            assert np.isfinite(float(m["loss"])), name
            # after warm-up the applied gradient is exactly D=1 steps old
            assert float(m["update_staleness"]) == pytest.approx(1.0), name

    def test_decoupled_layup_converges_on_synthetic_lm(self):
        """Acceptance regression: layup with R=2, D=1 converges on the
        synthetic LM, and its measured per-layer staleness is strictly lower
        than layup-block's at every layer group.

        The convergence check SEED-AVERAGES over 3 inits: XLA CPU can
        compile numerically different (reassociated) binaries across
        processes and a single 80-step trajectory amplifies that past any
        single-seed threshold (the PR-2-widened 0.95 still flaked; ROADMAP
        names seed-averaging, not threshold tuning, as the fix). Averaging
        washes out per-trajectory amplification, so the original 0.92
        threshold holds."""
        from repro.configs.base import ModelConfig
        from repro.data.synthetic import SyntheticLM
        from repro.models import build_model
        from repro.optim import linear_warmup_cosine

        cfg = ModelConfig(name="tiny-lm", family="dense", num_layers=2,
                          d_model=64, num_heads=2, num_kv_heads=1, d_ff=128,
                          vocab_size=32)
        model = build_model(cfg)
        # temperature 2.5 → strongly structured Markov chain: plenty of
        # learnable headroom above the entropy floor (≈1.9 vs ln32≈3.5)
        ds = SyntheticLM(vocab=cfg.vocab_size, seq_len=16, temperature=2.5)
        Mw = 4

        def run(algo_name, steps, seed=0):
            be = make_backend(
                "sim", algo_name, M=Mw,
                loss_fn=lambda p, b: model.loss_fn(p, b, block_k=16),
                optimizer=momentum(0.9),
                schedule=linear_warmup_cosine(0.1, 10, steps),
                fb_ratio=2, update_delay=1)
            st = be.init(jax.random.PRNGKey(seed),
                         model.init(jax.random.PRNGKey(seed + 1)))
            rng = jax.random.PRNGKey(seed + 2)
            losses, stale = [], []
            for t in range(steps):
                batch = jax.tree.map(jnp.asarray,
                                     make_worker_batches(ds, Mw, 16, t))
                rng, r = jax.random.split(rng)
                st, m = be.step(st, batch, r)
                losses.append(float(m["loss"]))
                stale.append(np.asarray(m["layer_staleness"]))
            return np.array(losses), np.array(stale)

        runs = [run("layup", steps=80, seed=100 * s) for s in range(3)]
        losses = np.mean([r[0] for r in runs], axis=0)
        stale = runs[0][1]
        assert np.mean(losses[-10:]) < 0.92 * np.mean(losses[:5]), losses[-10:]
        # staleness is structural, not convergence-dependent — one seed and
        # a shorter block run suffice for the per-layer comparison
        _, stale_block = run("layup-block", steps=40)
        mean_layer = stale[40:].mean(axis=0)
        mean_block = stale_block[20:].mean(axis=0)
        assert mean_layer.shape == mean_block.shape
        assert np.all(mean_layer < mean_block), (mean_layer, mean_block)
