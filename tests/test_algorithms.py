"""System behaviour of LayUp + baselines on the sim backend."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (consensus, disagreement, get_algorithm,
                        list_algorithms, make_sim_trainer)
from repro.core.api import choose_peers, pushsum_weight_update
from repro.core.drift import (elastic_constant, estimate_lipschitz,
                              gradient_bias, lemma61_bound)
from repro.data.synthetic import SyntheticVision, make_worker_batches
from repro.optim import constant, momentum, sgd

M = 8


def _mlp_problem():
    ds = SyntheticVision(num_classes=10, dim=32, snr=1.5, seed=0)

    def init(rng):
        k1, k2 = jax.random.split(rng)
        return {"l1": jax.random.normal(k1, (32, 64)) * 0.2,
                "l2": jax.random.normal(k2, (64, 10)) * 0.2}

    def loss_fn(p, batch):
        h = jnp.tanh(batch["x"] @ p["l1"])
        logits = h @ p["l2"]
        ce = -jnp.mean(jax.nn.log_softmax(logits)[
            jnp.arange(logits.shape[0]), batch["labels"]])
        return ce, {}

    return ds, init, loss_fn


def _run(algo_name, steps=200, delays=None, lr=0.05, seed=0):
    ds, init, loss_fn = _mlp_problem()
    algo = get_algorithm(algo_name)
    init_fn, step_fn = make_sim_trainer(algo, loss_fn, momentum(0.9),
                                        constant(lr), M,
                                        straggler_delays=delays)
    st = init_fn(jax.random.PRNGKey(seed), init(jax.random.PRNGKey(seed + 1)))
    rng = jax.random.PRNGKey(seed + 2)
    losses, dis = [], []
    for t in range(steps):
        batch = jax.tree.map(jnp.asarray, make_worker_batches(ds, M, 32, t))
        rng, r = jax.random.split(rng)
        st, metrics = step_fn(st, batch, r)
        losses.append(float(metrics["loss"]))
        dis.append(float(metrics["disagreement"]))
    return st, np.array(losses), np.array(dis)


class TestConvergence:
    @pytest.mark.parametrize("algo", ["ddp", "layup", "gosgd", "adpsgd",
                                      "localsgd", "slowmo", "co2"])
    def test_all_algorithms_converge(self, algo):
        _, losses, _ = _run(algo)
        assert np.mean(losses[-20:]) < 0.6 * losses[0], algo

    def test_layup_matches_ddp_quality(self):
        """Paper C1: LayUp reaches DDP-level loss (±10%)."""
        _, l_ddp, _ = _run("ddp")
        _, l_layup, _ = _run("layup")
        assert np.mean(l_layup[-20:]) < 1.1 * np.mean(l_ddp[-20:])


class TestLayUpMechanics:
    def test_ddp_replicas_identical(self):
        st, _, dis = _run("ddp", steps=20)
        assert dis[-1] < 1e-5

    def test_layup_weights_conserved(self):
        st, _, _ = _run("layup", steps=50)
        assert float(jnp.sum(st.weights)) == pytest.approx(1.0, abs=1e-5)

    def test_gosgd_mass_includes_in_flight(self):
        st, _, _ = _run("gosgd", steps=50)
        total = (float(jnp.sum(st.weights))
                 + float(jnp.sum(st.extras["q0"]["w"]))
                 + float(jnp.sum(st.extras["q1"]["w"])))
        assert total == pytest.approx(1.0, abs=1e-5)

    def test_layerwise_reduces_drift_vs_block(self):
        """Paper §3.2/C5: layer-wise (zero-delay) updates drift less than
        end-of-iteration block updates."""
        _, _, d_layer = _run("layup", steps=150)
        _, _, d_block = _run("layup-block", steps=150)
        assert np.mean(d_layer[50:]) < np.mean(d_block[50:])

    def test_straggler_robust_accuracy(self):
        """Paper Fig 3A: a delayed worker does not break convergence."""
        delays = np.zeros(M, int)
        delays[0] = 4
        _, losses, _ = _run("layup", steps=200, delays=delays)
        assert np.mean(losses[-20:]) < 0.6 * losses[0]

    def test_disagreement_bounded(self):
        """Paper Fig A1/C7: disagreement stays bounded during training."""
        _, _, dis = _run("layup", steps=200)
        assert np.max(dis[20:]) < 10 * (np.mean(dis[20:]) + 1e-9)


class TestHypercubeGossip:
    def test_converges_and_conserves_mass(self):
        st, losses, _ = _run("layup-hypercube", steps=150)
        assert np.mean(losses[-20:]) < 0.6 * losses[0]
        assert float(jnp.sum(st.weights)) == pytest.approx(1.0, abs=1e-5)

    def test_lower_drift_than_random_gossip(self):
        """Beyond-paper claim: deterministic hypercube schedule mixes faster
        than uniform random gossip at the same message volume."""
        means = {algo: np.mean([
            np.mean(_run(algo, steps=150, seed=s)[2][50:]) for s in (0, 1)])
            for algo in ("layup", "layup-hypercube")}
        assert means["layup-hypercube"] < 0.75 * means["layup"], means

    def test_xor_partner_is_involution(self):
        from repro.core import get_algorithm
        algo = get_algorithm("layup-hypercube")
        for step in range(4):
            send_ok, has_recv, sender_idx = algo._peers(
                jax.random.PRNGKey(0), 8, jnp.ones(8, bool), step)
            s = np.asarray(sender_idx)
            np.testing.assert_array_equal(s[s], np.arange(8))


class TestGradAccumulation:
    def test_sim_vs_accum_equivalence_concept(self):
        """Averaging grads over microbatches == full-batch grads (linearity),
        checked on the MLP problem."""
        ds, init, loss_fn = _mlp_problem()
        p = init(jax.random.PRNGKey(0))
        batch = jax.tree.map(jnp.asarray, make_worker_batches(ds, 1, 64, 0))
        b = jax.tree.map(lambda x: x[0], batch)
        g_full = jax.grad(lambda p: loss_fn(p, b)[0])(p)
        halves = [jax.tree.map(lambda x: x[:32], b),
                  jax.tree.map(lambda x: x[32:], b)]
        g_acc = jax.tree.map(
            lambda a, c: (a + c) / 2,
            jax.grad(lambda p: loss_fn(p, halves[0])[0])(p),
            jax.grad(lambda p: loss_fn(p, halves[1])[0])(p))
        for a, c in zip(jax.tree.leaves(g_full), jax.tree.leaves(g_acc)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                                       rtol=1e-4, atol=1e-5)


class TestPeerSelection:
    def test_no_self_sends_and_unique_receivers(self, rng):
        active = jnp.ones(M, bool)
        for i in range(20):
            r = jax.random.fold_in(rng, i)
            send_ok, has_recv, sender_idx = choose_peers(r, M, active)
            # winners are unique per receiver by construction
            senders = np.asarray(sender_idx)[np.asarray(has_recv)]
            assert len(senders) == len(set(senders.tolist()))
            assert int(send_ok.sum()) == int(has_recv.sum())
            # no sender sends to itself
            assert not np.any(senders == np.arange(M)[np.asarray(has_recv)])

    def test_inactive_workers_never_send(self, rng):
        active = jnp.zeros(M, bool).at[0].set(True)
        send_ok, has_recv, _ = choose_peers(rng, M, active)
        assert int(send_ok.sum()) <= 1
        assert not bool(send_ok[1:].any())

    def test_pushsum_conservation(self, rng):
        w = jax.random.uniform(rng, (M,)) + 0.1
        w = w / w.sum()
        active = jnp.ones(M, bool)
        for i in range(10):
            r = jax.random.fold_in(rng, 100 + i)
            send_ok, has_recv, sender_idx = choose_peers(r, M, active)
            w = pushsum_weight_update(w, send_ok, has_recv, sender_idx)
            assert float(w.sum()) == pytest.approx(1.0, abs=1e-6)
            assert float(w.min()) > 0


class TestTheory:
    def test_lemma61_bias_bound(self, rng):
        """Empirical check of Lemma 6.1: ‖b‖² ≤ 4·K̂²·η²·B̂²."""
        ds, init, loss_fn = _mlp_problem()
        st, _, _ = _run("layup", steps=100, lr=0.05)
        batch = jax.tree.map(jnp.asarray, make_worker_batches(ds, M, 32, 999))
        b0 = jax.tree.map(lambda x: x[0], batch)
        params0 = jax.tree.map(lambda x: x[0], st.params)
        params1 = jax.tree.map(lambda x: x[1], st.params)
        # x̃ = x̂ mixed once with a peer (the lemma's mixed version)
        w0, w1 = float(st.weights[0]), float(st.weights[1]) / 2
        a, b = w0 / (w0 + w1), w1 / (w0 + w1)
        p_tilde = jax.tree.map(lambda x, y: a * x + b * y, params0, params1)

        k_hat = estimate_lipschitz(loss_fn, params0, b0, rng, n_probes=8)
        b_hat = elastic_constant(st.params, st.weights, 0.05)
        bias = gradient_bias(loss_fn, params0, p_tilde, b0)
        bound = lemma61_bound(k_hat, 0.05, b_hat)
        assert float(bias) ** 2 <= float(bound) * 1.5  # slack for estimation

    def test_consensus_weighted_mean(self, rng):
        params = {"w": jax.random.normal(rng, (4, 3))}
        weights = jnp.array([0.4, 0.3, 0.2, 0.1])
        c = consensus(params, weights)
        expect = np.average(np.asarray(params["w"]), axis=0,
                            weights=np.asarray(weights))
        np.testing.assert_allclose(np.asarray(c["w"]), expect, rtol=1e-5)

    def test_mass_conservation_zero_grads(self, rng):
        """With zero updates, Σ wᵢxᵢ is exactly conserved by LayUp mixing."""
        algo = get_algorithm("layup")
        params = {"w": jax.random.normal(rng, (M, 5))}
        weights = jnp.full((M,), 1.0 / M)
        updates = {"w": jnp.zeros((M, 5))}
        active = jnp.ones(M, bool)
        mass0 = consensus(params, weights)["w"]
        p, w, _, _ = algo.post(params, weights, (), updates, active,
                               jax.random.fold_in(rng, 5), 0)
        mass1 = consensus(p, w)["w"]
        np.testing.assert_allclose(np.asarray(mass0), np.asarray(mass1),
                                   rtol=1e-5, atol=1e-6)


def test_registry_complete():
    algos = list_algorithms()
    for a in ("layup", "layup-block", "ddp", "gosgd", "adpsgd", "localsgd",
              "slowmo", "co2"):
        assert a in algos
