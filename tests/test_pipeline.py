"""Stage-graph pipeline engine (DESIGN.md §10): loss/staleness parity with
the monolithic decoupled step, buffer-lifetime management, and the measured
per-stage timeline.

The parity class is the tentpole acceptance: ``overlap=True`` must
reproduce the monolithic ``make_layup_decoupled_train_step`` numerics
EXACTLY at (R, D) ∈ {(1,0), (1,1), (2,1)} — the monolithic path is the
numerics oracle, the engine only changes the dispatch schedule. In-process
tests run the M=1 prod backend; the mesh tests run in subprocesses (the CI
matrix covers both shard_map shim paths via its two jax versions)."""
import itertools
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _fixtures import mlp_batch as _batch, mlp_problem as _mlp_problem
from _subproc import run_sub as _run
from repro.core import make_backend
from repro.launch.pipeline import StageTimeline
from repro.optim import constant, momentum


class TestEngineParity:
    """Acceptance: the overlap engine is loss- and staleness-exact vs. the
    monolithic decoupled step at every required operating point."""

    @pytest.mark.parametrize("R,D", [(1, 0), (1, 1), (2, 1)])
    def test_exact_vs_monolithic(self, R, D):
        loss_fn, params = _mlp_problem()
        kw = dict(M=1, loss_fn=loss_fn, optimizer=momentum(0.9),
                  schedule=constant(0.05), fb_ratio=R, update_delay=D)
        mono = make_backend("prod", "layup", **kw)
        pipe = make_backend("prod", "layup", overlap=True, **kw)
        ms = mono.init(jax.random.PRNGKey(0), params)
        ps = pipe.init(jax.random.PRNGKey(0), params)
        rng = jax.random.PRNGKey(3)
        for t in range(6):
            b = _batch(t)
            rng, r = jax.random.split(rng)
            ms, mm = mono.step(ms, b, r)
            ps, pm = pipe.step(ps, b, r)
            assert float(mm["loss"]) == float(pm["loss"]), (R, D, t)
            np.testing.assert_array_equal(
                np.asarray(mm["layer_staleness"]),
                np.asarray(pm["layer_staleness"]))
            assert float(mm["update_staleness"]) == float(
                pm["update_staleness"]), (R, D, t)
            assert float(mm["disagreement"]) == float(pm["disagreement"])
        # the engine-managed buffers end bit-identical to the step state
        for a, b in zip(jax.tree.leaves(ps["read"]),
                        jax.tree.leaves(ms["read"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_exact_with_straggler_mask(self):
        """The update stage's active-mask path matches the monolithic
        lane's straggler emulation step by step."""
        loss_fn, params = _mlp_problem()
        kw = dict(M=1, loss_fn=loss_fn, optimizer=momentum(0.9),
                  schedule=constant(0.05), fb_ratio=2, update_delay=1,
                  straggler_delays=np.array([1]))
        mono = make_backend("prod", "layup", **kw)
        pipe = make_backend("prod", "layup", overlap=True, **kw)
        ms = mono.init(jax.random.PRNGKey(0), params)
        ps = pipe.init(jax.random.PRNGKey(0), params)
        rng = jax.random.PRNGKey(3)
        for t in range(4):
            rng, r = jax.random.split(rng)
            ms, mm = mono.step(ms, _batch(t), r)
            ps, pm = pipe.step(ps, _batch(t), r)
            assert float(mm["loss"]) == float(pm["loss"]), t

    def test_sim_trainer_parity(self):
        """Transitively: engine == monolithic == sim trainer, so the
        engine inherits the PR-2 sim-vs-prod contract."""
        loss_fn, params = _mlp_problem()
        kw = dict(M=1, loss_fn=loss_fn, optimizer=momentum(0.9),
                  schedule=constant(0.05), fb_ratio=2, update_delay=1)
        sim = make_backend("sim", "layup-hypercube", **kw)
        pipe = make_backend("prod", "layup", overlap=True, **kw)
        ss = sim.init(jax.random.PRNGKey(0), params)
        ps = pipe.init(jax.random.PRNGKey(0), params)
        rng = jax.random.PRNGKey(3)
        for t in range(5):
            rng, r = jax.random.split(rng)
            ss, sm = sim.step(ss, _batch(t), r)
            ps, pm = pipe.step(ps, _batch(t), r)
            assert abs(float(sm["loss"]) - float(pm["loss"])) < 1e-5, t
            np.testing.assert_array_equal(
                np.asarray(sm["layer_staleness"]),
                np.asarray(pm["layer_staleness"]))


class TestEngineMechanics:
    def test_timeline_records_all_stages(self):
        loss_fn, params = _mlp_problem()
        be = make_backend("prod", "layup", M=1, loss_fn=loss_fn,
                          optimizer=momentum(0.9), schedule=constant(0.05),
                          fb_ratio=2, update_delay=1, overlap=True)
        st = be.init(jax.random.PRNGKey(0), params)
        rng = jax.random.PRNGKey(3)
        for t in range(3):
            rng, r = jax.random.split(rng)
            st, _ = be.step(st, _batch(t), r)
        be.timeline.finalize()
        stages = {e["stage"] for e in be.timeline.events}
        assert stages == {"fwd", "update", "gossip"}
        # R=2: two fwd slices per step
        assert sum(1 for e in be.timeline.events
                   if e["stage"] == "fwd" and e["step"] == 1) == 2
        for e in be.timeline.events:
            assert e["complete"] is not None
            assert e["complete"] >= e["dispatch"]
        s = be.timeline.summary()
        for k in ("wall_s", "overlap_events", "overlap_s",
                  "fwd_gossip_overlap_s", "stage_s", "steps"):
            assert k in s
        assert s["steps"] == 3

    def test_summary_includes_overlap_fields(self):
        loss_fn, params = _mlp_problem()
        be = make_backend("prod", "layup", M=1, loss_fn=loss_fn,
                          optimizer=momentum(0.9), schedule=constant(0.05),
                          overlap=True)
        st = be.init(jax.random.PRNGKey(0), params)
        st, _ = be.step(st, _batch(0), jax.random.PRNGKey(1))
        s = be.summary()
        for k in ("pipeline_wall_s", "overlap_events", "overlap_s",
                  "fwd_gossip_overlap_s"):
            assert k in s

    def test_graveyard_bounded_by_backpressure(self):
        loss_fn, params = _mlp_problem()
        be = make_backend("prod", "layup", M=1, loss_fn=loss_fn,
                          optimizer=momentum(0.9), schedule=constant(0.05),
                          fb_ratio=2, update_delay=1, overlap=True)
        st = be.init(jax.random.PRNGKey(0), params)
        rng = jax.random.PRNGKey(3)
        for t in range(8):
            rng, r = jax.random.split(rng)
            st, _ = be.step(st, _batch(t), r)
            assert len(be.engine._graveyard) <= be.engine.max_inflight_steps
        # held handles are released once their fences retire
        jax.block_until_ready(st)
        st, _ = be.step(st, _batch(9), rng)
        assert len(be.engine._graveyard) <= 1 + 1

    def test_timeline_dump_is_json(self, tmp_path):
        loss_fn, params = _mlp_problem()
        be = make_backend("prod", "layup", M=1, loss_fn=loss_fn,
                          optimizer=momentum(0.9), schedule=constant(0.05),
                          overlap=True)
        st = be.init(jax.random.PRNGKey(0), params)
        st, _ = be.step(st, _batch(0), jax.random.PRNGKey(1))
        be.timeline.finalize()
        path = be.timeline.dump(str(tmp_path / "stages.json"))
        with open(path) as f:
            doc = json.load(f)
        assert "summary" in doc and "events" in doc
        assert doc["events"][0]["dispatch"] >= 0.0


class TestTimelineAccounting:
    """The overlap arithmetic, pinned with a synthetic clock and fences —
    no jax, no timing flakes."""

    class Fence:
        def __init__(self):
            self.ready = False

        def is_ready(self):
            return self.ready

    def test_fwd_gossip_overlap_adjacent_and_counted_once(self):
        clk = itertools.count()
        tl = StageTimeline(clock=lambda: float(next(clk)))
        g0 = self.Fence()
        ev = tl.begin("gossip", 0)          # t=0 (poll at t=0)
        tl.commit(ev, g0)                   # poll at t=1
        f1a, f1b = self.Fence(), self.Fence()
        ev = tl.begin("fwd", 1, slice_idx=0)   # t=2: gossip 0 in flight
        assert ("gossip", 0, None) in ev["concurrent"]
        tl.commit(ev, f1a)
        ev = tl.begin("fwd", 1, slice_idx=1)   # t=4: still in flight
        assert ("gossip", 0, None) in ev["concurrent"]
        tl.commit(ev, f1b)
        g0.ready = True
        tl.poll()                           # gossip 0 completes at t=6
        f1a.ready = f1b.ready = True
        tl.finalize()
        s = tl.summary()
        # one window only (earliest fwd, dispatch t=2 → gossip complete
        # t=6), even though both slices saw the gossip in flight
        assert s["fwd_gossip_overlap_s"] == pytest.approx(4.0)
        assert s["overlap_events"] == 2

    def test_no_overlap_when_fences_ready(self):
        clk = itertools.count()
        tl = StageTimeline(clock=lambda: float(next(clk)))
        g = self.Fence()
        ev = tl.begin("gossip", 0)
        tl.commit(ev, g)
        g.ready = True
        ev = tl.begin("fwd", 1, slice_idx=0)
        assert ev["concurrent"] == []
        f = self.Fence()
        f.ready = True
        tl.commit(ev, f)
        tl.finalize()
        assert tl.summary()["fwd_gossip_overlap_s"] == 0.0

    def test_non_adjacent_gossip_not_counted(self):
        clk = itertools.count()
        tl = StageTimeline(clock=lambda: float(next(clk)))
        g = self.Fence()
        ev = tl.begin("gossip", 0)
        tl.commit(ev, g)
        ev = tl.begin("fwd", 5, slice_idx=0)  # step jump: not adjacent
        assert ("gossip", 0, None) in ev["concurrent"]
        f = self.Fence()
        tl.commit(ev, f)
        g.ready = f.ready = True
        tl.finalize()
        s = tl.summary()
        assert s["fwd_gossip_overlap_s"] == 0.0
        assert s["overlap_s"] > 0.0  # still counted as generic overlap


class TestRouting:
    def test_make_step_overlap_rejects_ddp(self):
        from repro.configs import get_config, reduced, ShapeConfig
        from repro.launch.train import make_step
        from repro.models import build_model
        m = build_model(reduced(get_config("stablelm-1.6b")))
        shape = ShapeConfig("t", 16, 4, "train")
        with pytest.raises(ValueError, match="decoupled"):
            make_step(m, None, shape, algo="ddp", overlap=True)

    def test_make_step_overlap_rejects_accum(self):
        from repro.configs import get_config, reduced, ShapeConfig
        from repro.launch.train import make_step
        from repro.models import build_model
        m = build_model(reduced(get_config("stablelm-1.6b")))
        shape = ShapeConfig("t", 16, 4, "train")
        with pytest.raises(ValueError, match="accum_steps"):
            make_step(m, None, shape, algo="layup", overlap=True,
                      accum_steps=2)

    def test_forward_slice_lane_bounds(self):
        from repro.launch.train import forward_slice_lane
        loss_fn, _ = _mlp_problem()
        with pytest.raises(ValueError, match="slice_idx"):
            forward_slice_lane(loss_fn, fb_ratio=2, slice_idx=2)


def test_pipeline_lowers_on_dryrun_mesh():
    """make_step(..., overlap=True) lowers every stage executable on the
    host-device dry-run meshes — tier-1, so the CI matrix exercises BOTH
    shard_map shim paths on every PR (lower-only: no XLA compile)."""
    out = _run("""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
from repro.configs import get_config, reduced, ShapeConfig
from repro.launch.mesh import make_test_mesh
from repro.launch.train import make_step
from repro.models import build_model
from repro.optim import momentum, constant
cfg = reduced(get_config("stablelm-1.6b"))
m = build_model(cfg)
shape = ShapeConfig("t", 16, 4, "train")
for mesh_shape, axes in (((1, 1, 2), ("pod", "data", "model")),
                         ((2, 2), ("data", "model"))):
    mesh = make_test_mesh(mesh_shape, axes)
    step = make_step(m, mesh, shape, algo="layup", optimizer=momentum(0.9),
                     schedule=constant(0.05), shifts=(1,), fb_ratio=2,
                     update_delay=1, overlap=True)
    lowered = step.lower()
    assert sorted(lowered) == ["fwd0", "fwd1", "gossip", "update"], lowered
    print("LOWERED", step.describe)
""", timeout=900)
    assert out.count("LOWERED") == 2
    assert "R=2, D=1" in out


@pytest.mark.slow
def test_pipeline_m2_mesh_parity_with_monolithic():
    """Acceptance (mesh form): the engine compiles AND RUNS on the dry-run
    meshes, matching the monolithic step's losses and staleness exactly at
    (R,D)=(2,1) with real gossip (M=2) and at (1,0)."""
    out = _run("""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config, reduced, ShapeConfig
from repro.launch.mesh import make_test_mesh
from repro.launch.train import (make_layup_decoupled_train_step,
                                make_decoupled_state, make_step)
from repro.models import build_model
from repro.optim import momentum, constant
from repro.data.synthetic import lm_batch_for

cfg = reduced(get_config("stablelm-1.6b"))
m = build_model(cfg)
opt = momentum(0.9)
for (mesh_shape, axes, M, bsz, R, D) in (
        ((2, 2), ("data", "model"), 2, 8, 2, 1),
        ((1, 1, 2), ("pod", "data", "model"), 1, 4, 1, 0)):
    mesh = make_test_mesh(mesh_shape, axes)
    shape = ShapeConfig("t", 16, bsz, "train")
    mono = make_layup_decoupled_train_step(
        m, mesh, opt, constant(0.05), shape, shifts=(1,), fb_ratio=R,
        update_delay=D)
    c = mono.lower().compile()
    pipe = make_step(m, mesh, shape, algo="layup", optimizer=opt,
                     schedule=constant(0.05), shifts=(1,), fb_ratio=R,
                     update_delay=D, overlap=True)
    params = m.init(jax.random.PRNGKey(0))
    sp = jax.tree.map(
        lambda p: jnp.broadcast_to(p[None], (M,) + p.shape) + 0, params)
    ms = make_decoupled_state(sp, opt, update_delay=D)
    ps = pipe.init_state(jax.tree.map(jnp.copy, sp))
    batch = lm_batch_for(cfg, bsz, 16)
    for t in range(3):
        ms, mm = c(ms, batch, jnp.asarray(t, jnp.int32),
                   jnp.zeros((), jnp.int32))
        ps, pm = pipe.fn(ps, batch, t, 0)
        dl = abs(float(mm["loss"]) - float(pm["loss"]))
        ds = np.abs(np.asarray(mm["layer_staleness"])
                    - np.asarray(pm["layer_staleness"])).max()
        assert dl < 1e-6, (M, R, D, t, dl)
        assert ds == 0.0, (M, R, D, t, ds)
    pipe.timeline.finalize()
    assert len(pipe.timeline.events) == 3 * (R + 2)
    print(f"MESH PARITY OK M={M} R={R} D={D}")
""")
    assert out.count("MESH PARITY OK") == 2
