import numpy as np
import pytest

from repro.core.simulator import HardwareModel, SimResult, simulate, straggler_sweep

HW = HardwareModel(fwd_time=1.0, bwd_ratio=2.0, num_layers=24,
                   model_bytes=1.6e9, bandwidth=25e9,
                   allreduce_bandwidth=100e9)
ALGOS = ["ddp", "localsgd", "slowmo", "co2", "gosgd", "adpsgd", "layup"]


class TestSimulator:
    @pytest.mark.parametrize("algo", ALGOS)
    def test_runs_and_positive(self, algo):
        r = simulate(algo, M=8, iters=50, hw=HW)
        assert r.total_time > 0
        assert 0 < r.utilization <= 1.0 + 1e-9
        assert 0 < r.mfu <= HW.kernel_mfu + 1e-9

    def test_ddp_pays_allreduce(self):
        r_ddp = simulate("ddp", M=8, iters=50, hw=HW)
        r_layup = simulate("layup", M=8, iters=50, hw=HW)
        assert r_ddp.total_time > r_layup.total_time

    def test_layup_mfu_at_least_ddp(self):
        """Paper Table 4: LayUp ≥ DDP utilization."""
        assert (simulate("layup", M=8, iters=50, hw=HW).mfu
                >= simulate("ddp", M=8, iters=50, hw=HW).mfu)

    def test_straggler_ordering(self):
        """Paper Fig 3B: sync methods degrade ~linearly; gossip flat."""
        sweep = straggler_sweep(ALGOS, M=8, iters=50, hw=HW, delays=(0, 4))
        for a in ("ddp", "localsgd", "slowmo", "co2"):
            assert sweep[a][1] > 3 * sweep[a][0], a
        for a in ("layup", "gosgd"):
            assert sweep[a][1] < 1.5 * sweep[a][0], a
        # adpsgd degrades through rendezvous with the straggler
        assert sweep["adpsgd"][1] > 1.2 * sweep["adpsgd"][0]

    def test_layup_hides_comm_better_than_gosgd_when_bw_limited(self):
        """Layer-wise sends start earlier → less stall at low bandwidth."""
        hw = HardwareModel(fwd_time=1.0, bwd_ratio=2.0, num_layers=24,
                           model_bytes=1.6e9, bandwidth=0.45e9)
        r_layup = simulate("layup", M=8, iters=50, hw=hw)
        r_gosgd = simulate("gosgd", M=8, iters=50, hw=hw)
        assert r_layup.total_time <= r_gosgd.total_time

    def test_localsgd_cheaper_comm_than_ddp(self):
        hw = HardwareModel(allreduce_bandwidth=5e9)
        assert (simulate("localsgd", M=8, iters=64, hw=hw, sync_every=8).total_time
                < simulate("ddp", M=8, iters=64, hw=hw).total_time)
