"""Unit tests for the roofline-analysis machinery (HLO parsing + analytic
cost model) — these guard the §Roofline numbers."""
import numpy as np
import pytest

from repro.configs import INPUT_SHAPES, get_config
from repro.launch import analysis as AN

HLO = """\
HloModule jit_step

%region_body (arg: (s32[], bf16[8,128])) -> (s32[], bf16[8,128]) {
  %ar = bf16[8,128]{1,0} all-reduce(%x), replica_groups=[16,16]<=[256]
  %cp = bf16[4,128]{1,0} collective-permute(%y), source_target_pairs={{0,1}}
}

%helper (p: bf16[2,2]) -> bf16[2,2] {
  %ag = bf16[32,128]{1,0} all-gather(%z), replica_groups=[16,16]<=[256]
}

ENTRY %main (a: bf16[8,128]) -> bf16[8,128] {
  %w = (s32[], bf16[8,128]) while(%init), condition=%cond, body=%region_body
  %top = f32[100]{0} all-reduce(%q), replica_groups=[1,256]<=[256]
  %call = bf16[2,2] fusion(%a), kind=kLoop, calls=%helper
}
"""


class TestCollectiveParsing:
    def test_loop_multiplication(self):
        colls = AN.parse_collectives(HLO, loop_trip=10)
        # in-body all-reduce counted 10x, entry all-reduce once
        assert colls["all-reduce"].count == 11
        ar_body = 8 * 128 * 2  # bf16
        ar_top = 100 * 4
        expect = 10 * ar_body * 2 * 15 / 16 + ar_top * 2 * 255 / 256
        assert colls["all-reduce"].wire_bytes == pytest.approx(expect, rel=1e-6)

    def test_permute_wire_equals_bytes(self):
        colls = AN.parse_collectives(HLO, loop_trip=3)
        assert colls["collective-permute"].count == 3
        assert colls["collective-permute"].wire_bytes == 3 * 4 * 128 * 2

    def test_helper_not_in_loop(self):
        # %helper is called from ENTRY, not the while body → counted once
        colls = AN.parse_collectives(HLO, loop_trip=10)
        assert colls["all-gather"].count == 1

    def test_group_size_parsing(self):
        assert AN._group_size("replica_groups=[16,16]<=[256]", 1) == 16
        assert AN._group_size("replica_groups={{0,1,2,3}}", 1) == 4
        assert AN._group_size("no groups here", 7) == 7

    def test_wire_factors(self):
        assert AN._wire_factor("all-reduce", 16) == pytest.approx(2 * 15 / 16)
        assert AN._wire_factor("all-gather", 16) == pytest.approx(15 / 16)
        assert AN._wire_factor("reduce-scatter", 16) == 15
        assert AN._wire_factor("collective-permute", 16) == 1.0


class TestAnalyticCosts:
    @pytest.mark.parametrize("arch", ["granite-8b", "mixtral-8x7b",
                                      "mamba2-780m", "whisper-large-v3",
                                      "jamba-v0.1-52b"])
    @pytest.mark.parametrize("shape", ["train_4k", "decode_32k"])
    def test_positive_and_finite(self, arch, shape):
        cfg = get_config(arch)
        ac = AN.analytic_costs(cfg, INPUT_SHAPES[shape], n_model=16,
                               n_workers=16)
        assert ac["flops_per_device"] > 0
        assert ac["bytes_per_device"] > 0
        assert np.isfinite(ac["flops_per_device"])

    def test_train_flops_close_to_6nd(self):
        """Dense train analytic flops ≈ (4/3)·6·N·D/devices (remat factor),
        within the attention/vocab corrections."""
        cfg = get_config("granite-8b")
        shape = INPUT_SHAPES["train_4k"]
        ac = AN.analytic_costs(cfg, shape, n_model=16, n_workers=16)
        model = AN.model_flops(cfg, shape) / 256
        ratio = ac["flops_per_device"] / model
        assert 1.1 < ratio < 2.2, ratio  # 4/3 remat + attention overhead

    def test_decode_memory_bound(self):
        cfg = get_config("yi-34b")
        ac = AN.analytic_costs(cfg, INPUT_SHAPES["decode_32k"], n_model=16,
                               n_workers=16)
        t_comp = ac["flops_per_device"] / AN.PEAK_FLOPS
        t_mem = ac["bytes_per_device"] / AN.HBM_BW
        assert t_mem > 10 * t_comp  # decode must be memory-dominant

    def test_moe_sharding_divides_expert_flops(self):
        cfg = get_config("qwen3-moe-30b-a3b")  # 128 experts % 16 == 0
        shape = INPUT_SHAPES["train_4k"]
        a16 = AN.analytic_costs(cfg, shape, n_model=16, n_workers=16)
        a1 = AN.analytic_costs(cfg, shape, n_model=1, n_workers=16)
        assert a1["flops_per_device"] > 4 * a16["flops_per_device"]

    def test_cpu_artifact_detector(self):
        txt = "x = f32[24,16,4096,2048]{3,2,1,0} convert(%p)\n" \
              "y = f32[24,16,4096,2048]{3,2,1,0} parameter(0)\n" \
              "z = f32[24,8]{1,0} convert(%q)\n"
        b = AN.cpu_residual_artifact_bytes(txt, n_super=24)
        assert b == 24 * 16 * 4096 * 2048 * 4  # counted once; small ignored
        assert AN.cpu_residual_artifact_bytes(txt, n_super=1) == 0.0
