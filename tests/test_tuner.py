"""Roofline-driven stage autotuner (DESIGN.md §16): cutout extraction,
the deterministic harness, grid enumeration, scoring, TuningRecord
round-trip/versioning, and the make_step/ProdTrainerBackend load path.

Everything in the unit classes is DETERMINISTIC: the harness runs with a
scripted clock and a fake-executable runner (no real timing, no sleeps),
extraction runs against fake engines with identity stages, and scoring is
pure arithmetic pinned to exact values. Only TestRealCutouts touches a
real engine (M=1, tiny MLP) and the slow mesh test does real timing."""
import itertools
import json
import os
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _fixtures import mlp_batch as _batch, mlp_problem as _mlp_problem
from _subproc import run_sub as _run
from repro.core import make_backend
from repro.launch.analysis import stage_floors
from repro.launch.pipeline import PipelineEngine
from repro.launch.streams import StreamEngine
from repro.launch.tuner import (
    DEFAULT_CANDIDATE, TUNING_SCHEMA_VERSION, Candidate, CutoutHarness,
    StageCutout, TuningRecord, apply_tuning, build_record, enumerate_grid,
    extract_cutouts, load_tuning, make_key, overlap_efficiency,
    problem_descriptor, resolve_tuning, score_candidate,
    stage_times_from_cutouts, synthesize_args)
from repro.optim import constant, momentum

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_sds = jax.ShapeDtypeStruct


def _fake_abstract_args(R=2, with_groups=False):
    plane = {"l1": _sds((1, 8), jnp.float32), "l2": _sds((1, 4), jnp.float32)}
    batch = {"x": _sds((1, 4, 2), jnp.float32)}
    i32 = _sds((), jnp.int32)
    out = {"fwd": (plane, batch),
           "update": (plane, plane, plane, i32),
           "gossip": (plane, _sds((1,), jnp.float32), i32)}
    if with_groups:
        for g in ("l1", "l2"):
            out[f"mix:{g}"] = (plane[g], _sds((1,), jnp.float32), i32)
        out["clock"] = (_sds((1,), jnp.float32), i32)
    return out


class TestSynthesize:
    def test_materializes_ones_with_shapes_and_dtypes(self):
        args = (_sds((3, 4), jnp.bfloat16),
                {"a": _sds((), jnp.int32)},
                (_sds((2,), jnp.float32), _sds((2,), jnp.float32)))
        got = synthesize_args(args)
        assert got[0].shape == (3, 4)
        assert got[0].dtype == jnp.bfloat16
        assert got[1]["a"].shape == () and got[1]["a"].dtype == np.int32
        assert isinstance(got[2], tuple) and len(got[2]) == 2
        assert np.all(np.asarray(got[0], np.float32) == 1.0)
        assert int(got[1]["a"]) == 1

    def test_fresh_buffers_per_call(self):
        args = (_sds((4,), jnp.float32),)
        a, b = synthesize_args(args), synthesize_args(args)
        assert a[0] is not b[0]  # donation safety: never reuse a buffer


class TestCutoutExtraction:
    def test_pipeline_engine_cutouts(self):
        fns = [lambda *a: ("fwd0", a), lambda *a: ("fwd1", a)]
        upd, gos = (lambda *a: ("upd", a)), (lambda *a: ("gos", a))
        eng = PipelineEngine(
            R=2, D=1, M=1, stages={"fwd": fns, "update": upd, "gossip": gos},
            abstract_args=_fake_abstract_args())
        cuts = extract_cutouts(eng)
        assert set(cuts) == {"fwd0", "fwd1", "update", "gossip"}
        assert cuts["fwd0"].fn is fns[0] and cuts["fwd1"].fn is fns[1]
        assert cuts["update"].fn is upd and cuts["gossip"].fn is gos
        assert cuts["fwd0"].abstract_args == eng.abstract_args["fwd"]
        # every cutout is independently runnable on synthetic buffers
        tag, args = cuts["update"].fn(*synthesize_args(
            cuts["update"].abstract_args))
        assert tag == "upd" and len(args) == 4

    def test_engine_without_abstract_args_raises(self):
        eng = PipelineEngine(R=1, D=0, M=1, stages={
            "fwd": [lambda *a: a], "update": lambda *a: a,
            "gossip": lambda *a: a})
        with pytest.raises(ValueError, match="abstract args"):
            eng.stage_cutouts()

    def test_batch_placeholder_raises_until_filled(self):
        absargs = _fake_abstract_args()
        absargs["fwd"] = (absargs["fwd"][0], None)  # backend-path state
        eng = PipelineEngine(R=1, D=0, M=1, stages={
            "fwd": [lambda *a: a], "update": lambda *a: a,
            "gossip": lambda *a: a}, abstract_args=absargs)
        with pytest.raises(ValueError, match="batch"):
            eng.stage_cutouts()

    def test_stream_engine_cutouts(self):
        fns = [lambda *a: a, lambda *a: a]
        mixes = {"l1": lambda *a: a, "l2": lambda *a: a}
        eng = StreamEngine(
            R=2, D=0, M=1, group_names=["l1", "l2"],
            stages={"fwd": fns, "update": lambda *a: a,
                    "gossip": lambda *a: a},
            group_stages={"mix": mixes, "clock": lambda *a: a},
            n_streams=2, abstract_args=_fake_abstract_args(with_groups=True))
        try:
            cuts = extract_cutouts(eng)
            assert set(cuts) == {"fwd0", "fwd1", "update",
                                 "mix:l1", "mix:l2", "clock"}
            assert cuts["mix:l1"].fn is mixes["l1"]
        finally:
            eng.close()


class TestHarness:
    def _cutout(self):
        return StageCutout("update", lambda *a: ("out", a),
                           (_sds((4,), jnp.float32), _sds((), jnp.int32)))

    def test_scripted_clock_exact_arithmetic(self):
        clk = itertools.count()
        calls = []
        h = CutoutHarness(clock=lambda: float(next(clk)),
                          runner=lambda fn, args: calls.append(args),
                          warmup=1, reps=3)
        t = h.time_cutout(self._cutout())
        # the clock ticks ONLY around measured reps (0,1),(2,3),(4,5):
        # every rep measures exactly 1.0 — warmup never touches the clock
        assert t == {"mean_s": 1.0, "best_s": 1.0, "reps": 3.0}
        assert len(calls) == 4  # warmup + 3 measured reps

    def test_synthesizes_fresh_args_per_invocation(self):
        seen = []
        h = CutoutHarness(clock=lambda: 0.0,
                          runner=lambda fn, args: seen.append(args),
                          warmup=0, reps=2)
        h.time_cutout(self._cutout())
        assert len(seen) == 2
        assert seen[0][0] is not seen[1][0]
        assert seen[0][0].shape == (4,) and seen[0][1].shape == ()

    def test_variable_clock_mean_and_best(self):
        ticks = iter([0.0, 3.0, 10.0, 11.0])  # reps: 3.0 then 1.0
        h = CutoutHarness(clock=lambda: next(ticks),
                          runner=lambda fn, args: None, warmup=0, reps=2)
        t = h.time_cutout(self._cutout())
        assert t["mean_s"] == pytest.approx(2.0)
        assert t["best_s"] == pytest.approx(1.0)

    def test_time_engine_covers_every_cutout(self):
        eng = PipelineEngine(
            R=2, D=0, M=1,
            stages={"fwd": [lambda *a: a, lambda *a: a],
                    "update": lambda *a: a, "gossip": lambda *a: a},
            abstract_args=_fake_abstract_args())
        clk = itertools.count()
        h = CutoutHarness(clock=lambda: float(next(clk)),
                          runner=lambda fn, args: None, warmup=0, reps=1)
        timings = h.time_engine(eng)
        assert set(timings) == {"fwd0", "fwd1", "update", "gossip"}

    def test_reps_must_be_positive(self):
        with pytest.raises(ValueError, match="rep"):
            CutoutHarness(reps=0)


class TestStageTimes:
    def test_pipeline_names_collapse(self):
        t = stage_times_from_cutouts({
            "fwd0": {"mean_s": 1.0}, "fwd1": {"mean_s": 3.0},
            "update": {"mean_s": 4.0}, "gossip": {"mean_s": 5.0}})
        assert t == {"fwd": 2.0, "update": 4.0, "gossip": 5.0}

    def test_stream_names_sum_mixes_plus_clock(self):
        t = stage_times_from_cutouts({
            "fwd0": {"mean_s": 1.0}, "update": {"mean_s": 2.0},
            "mix:l1": {"mean_s": 0.5}, "mix:l2": {"mean_s": 0.25},
            "clock": {"mean_s": 0.25}})
        assert t["gossip"] == pytest.approx(1.0)


class TestGrid:
    def test_default_grid_shape_and_determinism(self):
        g = enumerate_grid()
        assert len(g) == 3 * 3 * 1 * 3 * 1
        assert g == enumerate_grid()
        assert DEFAULT_CANDIDATE in g
        assert len(set(g)) == len(g)

    def test_custom_values(self):
        g = enumerate_grid(R_values=(1, 2), D_values=(0,),
                           groupings=("layer", "legacy"),
                           max_inflight=(3,), tiles=(64, 128))
        assert len(g) == 8
        assert g[0] == Candidate(R=1, D=0, grouping="layer",
                                 max_inflight_steps=3, tile=64)

    def test_label_round_trips_the_knobs(self):
        c = Candidate(R=4, D=2, grouping="layer", max_inflight_steps=2,
                      tile=256)
        assert c.label() == "R4_D2_layer_q2_t256"


class TestScoring:
    TIMES = {"fwd": 1.0, "update": 2.0, "gossip": 2.0}

    def test_exact_value_default_candidate(self):
        s = score_candidate(Candidate(R=2, D=1, max_inflight_steps=3),
                            self.TIMES)
        # serial = 2*1+2+2 = 6; critical = max(2, 4) = 4; eff = 1 (no
        # timeline); depth = 1-2^-(3+1) = 0.9375 → step = 6-0.9375*2
        assert s["serial_s"] == pytest.approx(6.0)
        assert s["critical_s"] == pytest.approx(4.0)
        assert s["step_time_s"] == pytest.approx(4.125)
        assert s["staleness"] == pytest.approx(1.5)
        assert s["score"] == pytest.approx(2.0 / 4.125 / 1.15)

    def test_paper_trade_R2_beats_R1_when_tail_dominates(self):
        # gossip+update dominate → a second fwd slice is (nearly) free
        s1 = score_candidate(Candidate(R=1, D=0), self.TIMES)
        s2 = score_candidate(Candidate(R=2, D=1), self.TIMES)
        assert s2["score"] > s1["score"]

    def test_staleness_penalty_caps_deep_schedules(self):
        t = {"fwd": 1.0, "update": 0.1, "gossip": 0.1}  # fwd-bound
        s1 = score_candidate(Candidate(R=1, D=0), t)
        s4 = score_candidate(Candidate(R=4, D=2), t, staleness_penalty=1.0)
        assert s1["score"] > s4["score"]

    def test_roofline_floors_clamp_measured_times(self):
        floors = {"fwd": 1.0, "update": 1.0, "gossip": 10.0}
        fast = {"fwd": 0.001, "update": 0.001, "gossip": 0.001}
        s = score_candidate(Candidate(R=1, D=0), fast, floors=floors)
        assert s["serial_s"] == pytest.approx(12.0)

    def test_measured_timeline_modulates_overlap(self):
        full = {"wall_s": 10.0, "exec_overlap_s": 10.0}
        none = {"wall_s": 10.0, "exec_overlap_s": 0.0}
        c = Candidate(R=2, D=1)
        s_full = score_candidate(c, self.TIMES, timeline=full)
        s_none = score_candidate(c, self.TIMES, timeline=none)
        assert s_full["overlap_eff"] == 1.0 and s_none["overlap_eff"] == 0.0
        assert s_none["step_time_s"] == pytest.approx(s_none["serial_s"])
        assert s_full["score"] > s_none["score"]

    def test_empty_timeline_is_zero_eff_not_crash(self):
        assert overlap_efficiency({"wall_s": 0.0}) == 0.0
        assert overlap_efficiency({}) == 0.0
        assert overlap_efficiency(None) == 1.0

    def test_legacy_grouping_pays_the_repack_wire(self):
        layer = score_candidate(Candidate(grouping="layer"), self.TIMES)
        legacy = score_candidate(Candidate(grouping="legacy"), self.TIMES)
        assert legacy["score"] < layer["score"]

    def test_off_128_tiles_pay_a_penalty(self):
        base = score_candidate(Candidate(tile=128), self.TIMES)
        small = score_candidate(Candidate(tile=32), self.TIMES)
        big = score_candidate(Candidate(tile=512), self.TIMES)
        assert small["score"] < base["score"]
        assert big["score"] < base["score"]

    def test_stage_floors_from_report_dict_and_dataclass(self):
        from repro.launch.analysis import RooflineReport
        rep = RooflineReport(t_compute=4.0, t_memory=2.0, t_collective=1.0)
        f = stage_floors(rep, R=2)
        assert f == {"fwd": 0.5, "update": 3.0, "gossip": 1.0}
        assert stage_floors(rep.to_dict(), R=2) == f


class TestRecord:
    def _entries(self):
        times = {"fwd": 1.0, "update": 2.0, "gossip": 2.0}
        cands = [DEFAULT_CANDIDATE, Candidate(R=1, D=0),
                 Candidate(R=4, D=2, max_inflight_steps=4)]
        return [(c, times, None) for c in cands]

    def test_build_record_picks_max_score_and_keeps_table(self):
        rec = build_record(self._entries(), key="k")
        assert rec.version == TUNING_SCHEMA_VERSION and rec.key == "k"
        scores = [row["score"] for row in rec.table]
        assert scores == sorted(scores, reverse=True)
        assert rec.score == pytest.approx(scores[0])
        assert rec.best["label"] == rec.table[0]["label"]
        # the default is always in the table → tuned never below default
        default_row = [r for r in rec.table
                       if r["label"] == DEFAULT_CANDIDATE.label()]
        assert default_row and rec.score >= default_row[0]["score"]

    def test_ties_break_toward_the_earliest_entry(self):
        times = {"fwd": 1.0, "update": 1.0, "gossip": 1.0}
        a = Candidate(R=2, D=1, max_inflight_steps=3)
        b = Candidate(R=2, D=1, max_inflight_steps=3, tile=128)
        rec = build_record([(a, times, None), (b, times, None)], key="k")
        assert rec.best_candidate() == a

    def test_empty_entries_raise(self):
        with pytest.raises(ValueError, match="at least one"):
            build_record([], key="k")

    def test_callable_floors_are_per_candidate(self):
        # the roofline fwd floor divides by R (analysis.stage_floors):
        # passing a callable lets each candidate get its own clamp
        seen = []
        def floors(c):
            seen.append(c.R)
            return {"fwd": 5.0 / c.R, "update": 0.0, "gossip": 0.0}
        times = {"fwd": 0.001, "update": 0.001, "gossip": 0.001}
        rec = build_record([(Candidate(R=1, D=0), times, None),
                            (Candidate(R=2, D=0), times, None)],
                           key="k", floors=floors)
        assert sorted(seen) == [1, 2]
        # R·(5/R) = 5.0 for both: the clamp applied per candidate (the
        # unclamped serial would be 0.003)
        by_r = {row["R"]: row for row in rec.table}
        assert by_r[1]["serial_s"] == pytest.approx(5.002)
        assert by_r[2]["serial_s"] == pytest.approx(5.002)

    def test_round_trip(self, tmp_path):
        rec = build_record(self._entries(), key="plane[x:8]|data1|wire=param",
                           meta={"steps": 4})
        path = rec.save(str(tmp_path / "rec.json"))
        got = load_tuning(path, key=rec.key)
        assert got is not None
        assert got.to_dict() == rec.to_dict()
        assert got.best_candidate() == rec.best_candidate()

    def test_missing_file_warns_and_falls_back(self, tmp_path):
        with pytest.warns(UserWarning, match="tuning record"):
            assert load_tuning(str(tmp_path / "nope.json")) is None

    def test_corrupted_json_warns_and_falls_back(self, tmp_path):
        p = tmp_path / "bad.json"
        p.write_text("{not json!!")
        with pytest.warns(UserWarning, match="unreadable"):
            assert load_tuning(str(p)) is None

    def test_stale_version_warns_and_falls_back(self, tmp_path):
        rec = build_record(self._entries(), key="k")
        doc = rec.to_dict()
        doc["version"] = TUNING_SCHEMA_VERSION + 99
        p = tmp_path / "stale.json"
        p.write_text(json.dumps(doc))
        with pytest.warns(UserWarning, match="stale"):
            assert load_tuning(str(p)) is None

    def test_key_mismatch_warns_and_falls_back(self, tmp_path):
        rec = build_record(self._entries(), key="mesh-a")
        p = rec.save(str(tmp_path / "rec.json"))
        with pytest.warns(UserWarning, match="keyed"):
            assert load_tuning(p, key="mesh-b") is None
        # and without an expected key the same record loads fine
        assert load_tuning(p) is not None

    def test_malformed_body_warns_and_falls_back(self, tmp_path):
        p = tmp_path / "hollow.json"
        p.write_text(json.dumps({"version": TUNING_SCHEMA_VERSION,
                                 "key": "k", "score": 1.0}))  # no "best"
        with pytest.warns(UserWarning, match="tuning record"):
            assert load_tuning(str(p)) is None
        p.write_text(json.dumps({"version": TUNING_SCHEMA_VERSION,
                                 "key": "k", "score": 1.0,
                                 "best": {"R": 2}}))  # best missing D
        with pytest.warns(UserWarning, match="tuning record"):
            assert load_tuning(str(p)) is None

    def test_make_key_composition(self):
        k = make_key("plane[l1:128]", "data4xmodel1", "int8")
        assert k == "plane[l1:128]|data4xmodel1|wire=int8"


class TestApply:
    def _record(self, **best):
        b = {"R": 4, "D": 2, "grouping": "layer", "max_inflight_steps": 4,
             "tile": 128}
        b.update(best)
        return TuningRecord(version=TUNING_SCHEMA_VERSION, key="k",
                            best=b, score=1.0)

    def test_record_fills_untouched_defaults(self):
        got = apply_tuning(self._record())
        assert got == {"fb_ratio": 4, "update_delay": 2, "flat": True,
                       "max_inflight_steps": 4}

    def test_explicit_kwargs_always_win(self):
        got = apply_tuning(self._record(), fb_ratio=2, update_delay=1,
                           max_inflight_steps=8)
        assert got == {"fb_ratio": 2, "update_delay": 1, "flat": True,
                       "max_inflight_steps": 8}

    def test_legacy_grouping_flips_flat_only_from_default(self):
        assert apply_tuning(self._record(grouping="legacy"))["flat"] is False

    def test_none_record_is_identity(self):
        assert apply_tuning(None, fb_ratio=3) == {
            "fb_ratio": 3, "update_delay": 0, "flat": True,
            "max_inflight_steps": None}

    def test_resolve_passthrough_and_path(self, tmp_path):
        rec = self._record()
        assert resolve_tuning(None) is None
        assert resolve_tuning(rec) is rec
        p = rec.save(str(tmp_path / "r.json"))
        got = resolve_tuning(p)
        assert got is not None and got.best_candidate().R == 4
        with pytest.warns(UserWarning, match="keyed"):
            assert resolve_tuning(rec, key="other") is None


class TestBackendIntegration:
    def _record(self, R=2, D=1, q=4):
        return TuningRecord(
            version=TUNING_SCHEMA_VERSION, key="unit",
            best={"R": R, "D": D, "grouping": "layer",
                  "max_inflight_steps": q, "tile": 128}, score=1.0)

    def _kw(self):
        loss_fn, params = _mlp_problem()
        return params, dict(M=1, loss_fn=loss_fn, optimizer=momentum(0.9),
                            schedule=constant(0.05), measure_drift=False)

    def test_record_configures_engine_and_implies_overlap(self):
        params, kw = self._kw()
        be = make_backend("prod", "layup", tuning=self._record(), **kw)
        assert be.overlap and be.tuning is not None
        st = be.init(jax.random.PRNGKey(0), params)
        assert be.engine.R == 2 and be.engine.D == 1
        assert be.engine.max_inflight_steps == 4
        for t in range(3):
            st, m = be.step(st, _batch(t), None)
        assert np.isfinite(float(m["loss"]))

    def test_explicit_kwargs_beat_the_record(self):
        params, kw = self._kw()
        be = make_backend("prod", "layup", tuning=self._record(R=4, D=2),
                          fb_ratio=2, update_delay=1, **kw)
        be.init(jax.random.PRNGKey(0), params)
        assert be.engine.R == 2 and be.engine.D == 1
        assert be.engine.max_inflight_steps == 4  # untouched default: tuned

    def test_bad_record_path_warns_and_keeps_defaults(self, tmp_path):
        params, kw = self._kw()
        with pytest.warns(UserWarning, match="tuning record"):
            be = make_backend("prod", "layup",
                              tuning=str(tmp_path / "missing.json"), **kw)
        assert not be.overlap and be.tuning is None
        st = be.init(jax.random.PRNGKey(0), params)
        st, m = be.step(st, _batch(0), None)
        assert np.isfinite(float(m["loss"]))

    def test_max_inflight_steps_kwarg_threads_through(self):
        params, kw = self._kw()
        be = make_backend("prod", "layup", overlap=True, fb_ratio=2,
                          update_delay=1, max_inflight_steps=2, **kw)
        be.init(jax.random.PRNGKey(0), params)
        assert be.engine.max_inflight_steps == 2


class TestRealCutouts:
    """The only unit class touching a real engine: cutouts extracted from
    the M=1 backend engine are runnable executables (compile-cache hits —
    same shapes the engine jitted), timed here with reps=1."""

    def test_cutouts_from_live_backend_engine_run(self):
        loss_fn, params = _mlp_problem()
        be = make_backend("prod", "layup", M=1, loss_fn=loss_fn,
                          optimizer=momentum(0.9), schedule=constant(0.05),
                          overlap=True, fb_ratio=2, update_delay=1,
                          measure_drift=False)
        st = be.init(jax.random.PRNGKey(0), params)
        eng = be.engine
        # backend path: the fwd batch signature is unknown until step one
        with pytest.raises(ValueError, match="batch"):
            eng.stage_cutouts()
        for t in range(2):
            st, m = be.step(st, _batch(t), None)
        float(m["loss"])
        cuts = extract_cutouts(eng)
        assert set(cuts) == {"fwd0", "fwd1", "update", "gossip"}
        h = CutoutHarness(warmup=1, reps=1)
        timings = {n: h.time_cutout(c) for n, c in cuts.items()}
        times = stage_times_from_cutouts(timings)
        assert all(v > 0.0 for v in times.values())
        rec = build_record(
            [(Candidate(R=2, D=1), times, be.timeline.summary())],
            key=make_key(problem_descriptor(be.part), "host1",
                         be.wire))
        assert rec.score > 0.0


@pytest.mark.slow
def test_autotune_on_mesh_tuned_never_below_default():
    """Acceptance (slow tier, 4 host devices): a real cutout-timed grid on
    the M=4 backend scores the tuned candidate >= the hand-picked default
    on the same measured StageTimeline, and the emitted record loads
    through ProdTrainerBackend (inside run_autotune's gates) AND through
    make_step on the Model path."""
    out = _run(f"""
import os, sys
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
sys.path.insert(0, {_REPO!r})
import jax
from benchmarks.autotune import run_autotune
rec, default_score = run_autotune(quick=True, steps=4, out_dir=None)
assert rec.score >= default_score, (rec.score, default_score)
print("TUNED", rec.best["label"], "score", rec.score)
path = rec.save("/tmp/tuning_mesh_test.json")

# the same record drives the Model-path factory through make_step
from repro.configs import get_config, reduced, ShapeConfig
from repro.launch.mesh import make_test_mesh
from repro.launch.train import make_step
from repro.models import build_model
from repro.optim import momentum, constant
cfg = reduced(get_config("stablelm-1.6b"))
m = build_model(cfg)
mesh = make_test_mesh((2, 2), ("data", "model"))
shape = ShapeConfig("t", 16, 4, "train")
step = make_step(m, mesh, shape, algo="layup", optimizer=momentum(0.9),
                 schedule=constant(0.05), shifts=(1,), tuning=path)
print("MAKESTEP", step.engine.R, step.engine.D,
      step.engine.max_inflight_steps)
""", timeout=1800)
    assert "TUNED" in out
    # "TUNED R{r}_D{d}_{grouping}_q{q}_t{tile} score {s}" must agree with
    # what make_step actually built from the record
    label = out.split("TUNED ", 1)[1].split()[0]
    parts = label.split("_")
    want = (int(parts[0][1:]), int(parts[1][1:]), int(parts[3][1:]))
    got = tuple(int(v) for v in out.split("MAKESTEP", 1)[1].split()[:3])
    assert got == want, (got, want)
