"""repro.serving tests: publisher handoff, swap gating, admission control,
and live (checkpoint-free) weight swaps into the serve loop.

The unit tests drive the subsystem with hand-built planes and a minimal
fake loop so the swap invariants (atomicity, gating) are asserted exactly;
the integration test runs the real trainer-with-publisher → LiveServer
path end to end on one CPU device.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.layerview import FlatPartition
from repro.serving import (AdmissionQueue, LiveServer, PlanePublisher,
                           PlaneSnapshot, SwapPolicy)


# ---------------------------------------------------------------------------
# publisher
# ---------------------------------------------------------------------------

def _tiny_tree(fill):
    return {"blocks": {"w": jnp.full((2, 3, 4), fill, jnp.float32)},
            "embed": {"table": jnp.full((8, 4), fill, jnp.float32)}}


def _publish(pub, part, fill, step, *, drift=None, M=1):
    """Pack a constant-filled tree and publish it as an (M, size) plane."""
    flat = part.pack(_tiny_tree(fill))
    plane = {g: jnp.stack([b] * M) for g, b in flat.items()}
    versions = jnp.full((M, part.num_groups), float(step + 1), jnp.float32)
    return pub.publish(plane, versions, jnp.ones(M), step, drift=drift)


def test_publisher_cadence_seq_and_latest():
    part = FlatPartition(_tiny_tree(0.0))
    pub = PlanePublisher(every=2)
    snaps = [_publish(pub, part, float(i), i) for i in range(5)]
    # calls 1, 3, 5 kept; calls 2, 4 skipped
    assert [s is not None for s in snaps] == [True, False, True, False, True]
    assert pub.stats.published == 3 and pub.stats.skipped == 2
    assert [s.seq for s in snaps if s] == [1, 2, 3]  # seq counts publishes
    latest = pub.latest()
    assert latest.seq == 3 and latest.step == 4
    assert pub.latest(after_seq=3) is None           # nothing newer
    assert pub.latest(after_seq=2).seq == 3
    assert pub.wait_for(after_seq=2, timeout=0.01).seq == 3
    assert pub.wait_for(after_seq=3, timeout=0.01) is None  # times out


def test_publisher_stable_flag_controls_copy():
    part = FlatPartition(_tiny_tree(0.0))
    pub = PlanePublisher()
    flat = part.pack(_tiny_tree(1.0))
    plane = {g: b[None] for g, b in flat.items()}
    v, w = jnp.ones((1, part.num_groups)), jnp.ones(1)
    s1 = pub.publish(plane, v, w, 0, stable=True)
    for g in plane:
        assert s1.plane[g] is plane[g]               # zero-copy handles
    s2 = pub.publish(plane, v, w, 1, stable=False)
    for g in plane:
        assert s2.plane[g] is not plane[g]           # stabilized copies
        np.testing.assert_array_equal(np.asarray(s2.plane[g]),
                                      np.asarray(plane[g]))
    assert pub.stats.copied_planes == 1
    # version clocks are defensively copied on BOTH paths
    assert s1.versions is not v and s2.versions is not v


def test_publisher_rejects_bad_cadence():
    with pytest.raises(ValueError):
        PlanePublisher(every=0)


# ---------------------------------------------------------------------------
# swap policy
# ---------------------------------------------------------------------------

def _snap(seq, step, *, versions=None, drift=None, G=3):
    if versions is None:
        versions = np.full((1, G), float(step + 1), np.float32)  # staleness 0
    return PlaneSnapshot(seq=seq, step=step, plane={},
                         versions=np.asarray(versions, np.float32),
                         w=np.ones(1), drift=drift)


def test_policy_staleness_gate():
    pol = SwapPolicy(max_staleness=2.0)
    # versions = step+1 - stale → per-group staleness == stale
    ok = pol.evaluate(_snap(1, 10, versions=np.full((1, 3), 9.0)))   # 2.0
    assert ok.accepted and ok.reason == "fresh" and ok.staleness_max == 2.0
    bad = pol.evaluate(_snap(2, 10, versions=np.full((1, 3), 8.0)))  # 3.0
    assert not bad.accepted and bad.reason == "staleness"
    # the max over groups gates, not the mean
    mixed = np.asarray([[11.0, 11.0, 7.0]])                          # max 4.0
    assert not pol.evaluate(_snap(3, 10, versions=mixed)).accepted
    assert pol.gated_rejections == 2 and pol.accepted == 1


def test_policy_drift_gate():
    pol = SwapPolicy(max_drift=0.5)
    assert pol.evaluate(_snap(1, 0, drift=0.4)).accepted
    d = pol.evaluate(_snap(2, 0, drift=0.9))
    assert not d.accepted and d.reason == "drift" and d.drift == 0.9
    # unmeasured drift (None) passes the gate rather than rejecting
    assert pol.evaluate(_snap(3, 0, drift=None)).accepted
    assert pol.gated_rejections == 1


def test_policy_swap_cadence():
    pol = SwapPolicy(min_interval_steps=5, max_interval_steps=20,
                     max_staleness=0.0)
    first = pol.evaluate(_snap(1, 10), last_swap_step=None)
    assert first.accepted                       # no prior swap: no interval
    too_soon = pol.evaluate(_snap(2, 12), last_swap_step=10)
    assert not too_soon.accepted and too_soon.reason == "min-interval"
    # past max_interval, freshness wins even over a failing staleness gate
    stale = np.zeros((1, 3), np.float32)        # staleness = step+1, huge
    forced = pol.evaluate(_snap(3, 31, versions=stale), last_swap_step=10)
    assert forced.accepted and forced.reason == "forced-max-interval"
    # inside the window the staleness gate still applies
    gated = pol.evaluate(_snap(4, 25, versions=stale), last_swap_step=10)
    assert not gated.accepted and gated.reason == "staleness"
    assert pol.rejected == 2 and pol.gated_rejections == 1
    assert pol.counts == {"fresh": 1, "min-interval": 1,
                          "forced-max-interval": 1, "staleness": 1}


# ---------------------------------------------------------------------------
# admission queue
# ---------------------------------------------------------------------------

def test_admission_bounded_depth_rejects_with_retry_hint():
    q = AdmissionQueue(max_depth=2)
    assert q.submit("a").accepted and q.submit("b").accepted
    t = q.submit("c")
    assert not t.accepted and t.reason == "queue-full"
    assert t.retry_after_s > 0.0
    assert q.depth == 2 and q.stats()["rejected"] == 1
    assert q.take(10) == ["a", "b"]             # FIFO order
    assert q.submit("c").accepted               # space freed


def test_admission_deadline_drop():
    q = AdmissionQueue(max_depth=8)
    now = time.monotonic()
    q.submit("late", deadline_s=now - 1.0, now=now)     # already expired
    q.submit("ok", deadline_s=now + 60.0, now=now)
    q.submit("nolimit", now=now)
    got = q.take(10, now=now)
    assert got == ["ok", "nolimit"]
    s = q.stats()
    assert s["deadline_dropped"] == 1
    assert s["admitted"] == 2 and s["submitted"] == 3 and s["depth"] == 0


def test_admission_drain_ema_updates():
    q = AdmissionQueue(max_depth=8)
    now = time.monotonic()
    for i in range(4):
        q.submit(i, now=now)
    q.take(2, now=now)
    before = q.stats()["drain_ema_s"]
    q.take(2, now=now + 1.0)                    # 0.5 s/request measured
    assert q.stats()["drain_ema_s"] > before


# ---------------------------------------------------------------------------
# live swaps (fake loop: exact invariants)
# ---------------------------------------------------------------------------

class _FakeLoop:
    """Just enough ServeLoop surface for LiveServer.poll()."""

    def __init__(self):
        self.params = None
        self.params_version = None
        self.steps_run = 0

    def set_params(self, params, version=None):
        self.params = params
        self.params_version = version


def test_swap_is_atomic_across_groups():
    """Served params always come from exactly ONE published plane: after
    any sequence of swaps, every group decodes to the same plane version
    (constant-fill probe), and the version clocks travel with the plane
    they describe."""
    part = FlatPartition(_tiny_tree(0.0))
    assert part.num_groups >= 2                 # multi-group or no test
    pub = PlanePublisher()
    loop = _FakeLoop()
    srv = LiveServer(loop, part, pub)
    for step, fill in [(0, 1.0), (1, 2.0), (5, 7.0)]:
        _publish(pub, part, fill, step)
        d = srv.poll()
        assert d.accepted
        leaves = jax.tree.leaves(loop.params)
        assert len(leaves) == len(jax.tree.leaves(_tiny_tree(0.0)))
        for leaf in leaves:                     # every group, one version
            np.testing.assert_array_equal(np.asarray(leaf),
                                          np.full(leaf.shape, fill))
        assert loop.params_version == (d.seq, step)
        # version clocks advance together with the plane: the swap records
        # the clocks of the SAME snapshot that produced the params
        np.testing.assert_array_equal(
            srv.swaps[-1].versions, np.full((1, part.num_groups), step + 1.0))
    assert srv.swap_count == 3
    # two publishes between polls: only the newest is evaluated — a decode
    # can never observe the intermediate plane, let alone a mix
    _publish(pub, part, 8.0, 6)
    _publish(pub, part, 9.0, 7)
    srv.poll()
    for leaf in jax.tree.leaves(loop.params):
        np.testing.assert_array_equal(np.asarray(leaf),
                                      np.full(leaf.shape, 9.0))
    assert srv.poll() is None                   # nothing unseen left


def test_rejected_plane_skipped_serving_continues():
    part = FlatPartition(_tiny_tree(0.0))
    pub = PlanePublisher()
    loop = _FakeLoop()
    srv = LiveServer(loop, part, pub, policy=SwapPolicy(max_drift=0.5))
    _publish(pub, part, 1.0, 0, drift=0.0)
    assert srv.poll().accepted
    good = loop.params
    _publish(pub, part, 2.0, 1, drift=9.0)      # diverging: must be gated
    d = srv.poll()
    assert not d.accepted and d.reason == "drift"
    assert loop.params is good                  # still serving the old tree
    assert loop.params_version == (1, 0)
    _publish(pub, part, 3.0, 2, drift=0.1)      # recovered: swaps again
    assert srv.poll().accepted
    assert loop.params_version == (3, 2)
    assert srv.swap_count == 2
    assert srv.policy.gated_rejections == 1


def test_live_server_serves_selected_worker():
    part = FlatPartition(_tiny_tree(0.0))
    pub = PlanePublisher()
    flat1, flat2 = part.pack(_tiny_tree(1.0)), part.pack(_tiny_tree(2.0))
    plane = {g: jnp.stack([flat1[g], flat2[g]]) for g in flat1}  # M=2
    versions = jnp.ones((2, part.num_groups))
    loop = _FakeLoop()
    srv = LiveServer(loop, part, pub, worker=1)
    pub.publish(plane, versions, jnp.ones(2), 0)
    assert srv.poll().accepted
    for leaf in jax.tree.leaves(loop.params):
        np.testing.assert_array_equal(np.asarray(leaf),
                                      np.full(leaf.shape, 2.0))


# ---------------------------------------------------------------------------
# end-to-end: trainer publishes, LiveServer swaps, no checkpoint anywhere
# ---------------------------------------------------------------------------

def _tiny_backend(pub, **kw):
    from repro.configs.base import ModelConfig
    from repro.core import make_backend
    from repro.models import build_model
    from repro.optim import constant, momentum

    cfg = ModelConfig(name="tiny-lm", family="dense", num_layers=2,
                      d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                      vocab_size=64)
    model = build_model(cfg)
    be = make_backend("prod", "layup", M=1,
                      loss_fn=lambda p, b: model.loss_fn(p, b, block_k=32),
                      optimizer=momentum(0.9), schedule=constant(0.05),
                      fb_ratio=2, update_delay=1, measure_drift=True,
                      publisher=pub, **kw)
    return cfg, model, be


def test_live_swap_end_to_end_monolithic(rng, tmp_path, monkeypatch):
    """Full path on one CPU device: decoupled trainer publishes each
    gossip round, the LiveServer swaps the read plane into a real
    ServeLoop mid-serving — and nothing ever touches the filesystem."""
    from repro.data.synthetic import SyntheticLM, make_worker_batches
    from repro.launch.serve import Request, ServeLoop

    monkeypatch.chdir(tmp_path)                 # catch any stray file I/O
    pub = PlanePublisher()
    cfg, model, be = _tiny_backend(pub)
    params = model.init(rng)
    st = be.init(jax.random.PRNGKey(0), params)
    ds = SyntheticLM(vocab=cfg.vocab_size, seq_len=16, temperature=1.2)
    for t in range(2):
        st, _ = be.step(st, jax.tree.map(jnp.asarray,
                                         make_worker_batches(ds, 1, 4, t)), None)
    assert pub.stats.published == 2
    assert pub.stats.copied_planes == 2         # monolithic lane stabilizes

    loop = ServeLoop(model, params, num_slots=2, max_len=16)
    adm = AdmissionQueue(max_depth=8)
    # M=1 never stamps version clocks, so leave the staleness gate off here
    srv = LiveServer(loop, be.part, pub, policy=SwapPolicy(),
                     admission=adm)
    assert adm.submit(Request(uid=0, prompt=np.asarray([1, 2], np.int32),
                              max_new_tokens=3)).accepted
    srv.run_until_idle()
    st, _ = be.step(st, jax.tree.map(jnp.asarray,
                                     make_worker_batches(ds, 1, 4, 2)), None)
    assert adm.submit(Request(uid=1, prompt=np.asarray([3], np.int32),
                              max_new_tokens=2)).accepted
    srv.run_until_idle()

    s = srv.stats()
    assert s["tokens_emitted"] == 5 and s["requests_completed"] == 2
    assert s["swaps"] >= 2                      # swapped mid-serving, twice
    assert s["params_version"] is not None      # serving published weights
    assert s["admission"]["admitted"] == 2
    # swapped params == the trainer's read plane, unpacked — no checkpoint
    expect = srv._unpack(pub.latest().plane)
    for a, b in zip(jax.tree.leaves(loop.params), jax.tree.leaves(expect)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert list(tmp_path.iterdir()) == []       # zero files written


@pytest.mark.slow
def test_live_swap_end_to_end_pipeline(rng):
    """Same path through the overlapped stage-graph engine: publishes are
    zero-copy (the engine never donates the read plane)."""
    from repro.data.synthetic import SyntheticLM, make_worker_batches
    from repro.launch.serve import Request, ServeLoop

    pub = PlanePublisher()
    cfg, model, be = _tiny_backend(pub, overlap=True)
    params = model.init(rng)
    st = be.init(jax.random.PRNGKey(0), params)
    ds = SyntheticLM(vocab=cfg.vocab_size, seq_len=16, temperature=1.2)
    for t in range(3):
        st, _ = be.step(st, jax.tree.map(jnp.asarray,
                                         make_worker_batches(ds, 1, 4, t)), None)
    assert pub.stats.published == 3
    assert pub.stats.copied_planes == 0         # true zero-copy handoff

    loop = ServeLoop(model, params, num_slots=1, max_len=16)
    srv = LiveServer(loop, be.part, pub)
    loop.submit(Request(uid=0, prompt=np.asarray([1], np.int32),
                        max_new_tokens=2))
    srv.run_until_idle()
    assert srv.swap_count == 1 and loop.tokens_emitted == 2
    assert loop.params_version == (3, 2)
