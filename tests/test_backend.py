"""TrainerBackend protocol: one entry point over the numeric sim trainer
and the event-driven simulator, plus the decoupled fwd/bwd thread lanes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import TrainerBackend, make_backend
from repro.core.simulator import EventSimulator, HardwareModel, simulate
from repro.data.synthetic import SyntheticVision, make_worker_batches
from repro.optim import constant, momentum

M = 4
HW = HardwareModel(fwd_time=1.0, bwd_ratio=2.0, num_layers=24,
                   model_bytes=1.6e9, bandwidth=25e9,
                   allreduce_bandwidth=100e9)


def _problem():
    ds = SyntheticVision(num_classes=10, dim=16, snr=1.5, seed=0)

    def init(rng):
        k1, k2 = jax.random.split(rng)
        return {"l1": jax.random.normal(k1, (16, 32)) * 0.2,
                "l2": jax.random.normal(k2, (32, 10)) * 0.2}

    def loss_fn(p, batch):
        h = jnp.tanh(batch["x"] @ p["l1"])
        logits = h @ p["l2"]
        ce = -jnp.mean(jax.nn.log_softmax(logits)[
            jnp.arange(logits.shape[0]), batch["labels"]])
        return ce, {}

    return ds, init, loss_fn


class TestProtocol:
    def test_both_kinds_satisfy_protocol(self):
        ds, init, loss_fn = _problem()
        sim = make_backend("sim", "layup", M=M, loss_fn=loss_fn,
                           optimizer=momentum(0.9), schedule=constant(0.05))
        ev = make_backend("event", "layup", M=M, hw=HW)
        assert isinstance(sim, TrainerBackend)
        assert isinstance(ev, TrainerBackend)
        assert sim.kind == "sim" and ev.kind == "event"

    def test_lockstep_drive(self):
        """Both backends step once per update iteration and aggregate."""
        ds, init, loss_fn = _problem()
        sim = make_backend("sim", "layup", M=M, loss_fn=loss_fn,
                           optimizer=momentum(0.9), schedule=constant(0.05))
        ev = make_backend("event", "layup", M=M, hw=HW)
        st = sim.init(jax.random.PRNGKey(0), init(jax.random.PRNGKey(1)))
        es = ev.init(jax.random.PRNGKey(0))
        rng = jax.random.PRNGKey(2)
        for t in range(5):
            batch = jax.tree.map(jnp.asarray, make_worker_batches(ds, M, 8, t))
            rng, r = jax.random.split(rng)
            st, m_num = sim.step(st, batch, r)
            es, m_ev = ev.step(es, None, None)
        assert np.isfinite(float(m_num["loss"]))
        assert m_ev["iter_time"] > 0
        assert sim.summary()["steps"] == ev.summary()["steps"] == 5.0
        assert ev.summary()["total_time"] == pytest.approx(
            ev.result().total_time)

    def test_event_alias_for_block_and_hypercube(self):
        for name, expect in (("layup-block", "gosgd"),
                             ("layup-hypercube", "layup")):
            ev = make_backend("event", name, M=M, hw=HW)
            assert ev._event_algo == expect

    def test_drive_helper_collects_history(self):
        from repro.core import drive
        ds, init, loss_fn = _problem()
        sim = make_backend("sim", "layup", M=M, loss_fn=loss_fn,
                           optimizer=momentum(0.9), schedule=constant(0.05))
        batches = [jax.tree.map(jnp.asarray, make_worker_batches(ds, M, 8, t))
                   for t in range(4)]
        out = drive(sim, batches, jax.random.PRNGKey(0),
                    params_single=init(jax.random.PRNGKey(1)),
                    history_keys=("loss", "layer_staleness"))
        assert out["history"]["loss"].shape == (4,)
        assert out["history"]["layer_staleness"].shape == (4, 2)
        assert out["steps"] == 4.0

    def test_unknown_kind_raises(self):
        with pytest.raises(ValueError, match="unknown backend kind"):
            make_backend("mesh", "layup", M=M)

    def test_sim_requires_numeric_pieces(self):
        with pytest.raises(ValueError, match="sim backend needs"):
            make_backend("sim", "layup", M=M)


class TestDecoupledLanes:
    def test_sync_algos_reject_decoupled(self):
        for algo in ("ddp", "localsgd", "slowmo", "co2"):
            with pytest.raises(ValueError, match="decoupled execution"):
                simulate(algo, M=M, iters=4, hw=HW, fb_ratio=2)
        with pytest.raises(ValueError, match="rendezvous"):
            simulate("adpsgd", M=M, iters=4, hw=HW, update_delay=1)

    def test_decoupled_never_slower_than_coupled_when_bw_limited(self):
        """Compute never stalls on the NIC in decoupled mode — the paper's
        core speed argument."""
        hw = HardwareModel(fwd_time=1.0, bwd_ratio=2.0, num_layers=24,
                           model_bytes=1.6e9, bandwidth=0.45e9)
        cpl = simulate("layup", M=8, iters=50, hw=hw)
        dec = simulate("layup", M=8, iters=50, hw=hw, update_delay=1)
        assert dec.total_time <= cpl.total_time + 1e-9
        assert dec.utilization == pytest.approx(1.0)
        assert dec.mfu == pytest.approx(hw.kernel_mfu)

    def test_fb_ratio_scales_forward_throughput(self):
        r1 = simulate("layup", M=8, iters=50, hw=HW, fb_ratio=1,
                      update_delay=1)
        r2 = simulate("layup", M=8, iters=50, hw=HW, fb_ratio=2,
                      update_delay=1)
        # forward lane serves 2 passes per update; updates are slower but
        # forward throughput is higher
        assert r2.fwd_passes_per_s > r1.fwd_passes_per_s
        assert r2.updates_per_s < r1.updates_per_s
        assert r2.fwd_passes_per_s == pytest.approx(2 * r2.updates_per_s)

    def test_grad_staleness_grows_with_delay(self):
        r1 = simulate("layup", M=8, iters=60, hw=HW, update_delay=1)
        r3 = simulate("layup", M=8, iters=60, hw=HW, update_delay=3)
        assert 0.0 < r1.mean_grad_staleness < r3.mean_grad_staleness

    def test_incremental_matches_batch(self):
        """EventSimulator.step() composed == simulate() wrapper."""
        sim = EventSimulator("gosgd", M=8, hw=HW)
        for _ in range(30):
            sim.step()
        a = sim.result()
        b = simulate("gosgd", M=8, iters=30, hw=HW)
        assert a.total_time == pytest.approx(b.total_time)
        assert a.mfu == pytest.approx(b.mfu)
