"""Quantized gossip wire (DESIGN.md §14): int8 + per-row-scale round trip,
error-feedback residual invariants, delay compensation, and bit-exactness
of the default wire across the three execution engines.

Kernel-vs-ref comparisons use tight-but-nonzero tolerances: interpret-mode
Pallas and XLA-compiled jnp contract FMAs (and fold divisions) differently,
so scales can differ by ~1 ulp and an int8 level can flip where v/scale
sits within ~1e-5 of a rounding boundary. What must agree tightly is the
DEQUANTIZED value q·s (and the residual, which carries the complement).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.backend import make_backend
from repro.kernels import ops
from repro.kernels.quantize import quant_layout, quant_wire_nbytes
from repro.kernels.ref import dequant_mix_ref, quantize_plane_ref
from repro.optim.optimizers import sgd

from _fixtures import mlp_batch, mlp_problem
from _subproc import run_sub

# odd sizes straddle the 128-lane row and the 32-row sublane padding
SIZES = [1, 127, 129, 1023, 8 * 128 + 5]


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=1e-5, atol=1e-6)


def _res_tol(dtype):
    # one bf16 ULP of slack: an f32 intermediate that straddles a rounding
    # boundary can cast to adjacent bf16 values under different FMA
    # contraction
    return dict(rtol=2e-2, atol=1e-4) if dtype == jnp.bfloat16 else \
        dict(rtol=1e-5, atol=1e-6)


class TestQuantLayout:
    def test_rows_padding_and_bytes(self):
        for n in SIZES:
            rows, tile, ntiles = quant_layout(n)
            assert rows * 128 >= n
            assert rows % 32 == 0 and rows == tile * ntiles
            assert quant_wire_nbytes(n) == n + 4 * rows

    def test_wire_under_055_of_bf16_at_scale(self):
        n = 1 << 20
        assert quant_wire_nbytes(n) <= 0.55 * (2 * n)


class TestQuantizeRoundTrip:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("n", SIZES)
    def test_kernel_matches_ref(self, rng, dtype, n):
        x = (jax.random.normal(rng, (n,)) * 3.0).astype(dtype)
        r = (jax.random.normal(jax.random.fold_in(rng, 1), (n,))
             * 0.01).astype(dtype)
        qk, sk, resk = ops.quantize_plane(x, r, interpret=True)
        qr, sr, resr = quantize_plane_ref(x, r)
        rows, _, _ = quant_layout(n)
        np.testing.assert_allclose(np.asarray(sk), np.asarray(sr),
                                   rtol=1e-6, atol=0)
        assert qk.dtype == jnp.int8 and qk.shape == x.shape
        assert sk.shape == (rows,) and sk.dtype == jnp.float32
        # the EF identity q·s + res == x + r_in must hold for BOTH
        # implementations (this is what makes the wire non-lossy in sum)
        v = (np.asarray(x, np.float32) + np.asarray(r, np.float32))
        eps = (np.float32(np.finfo(np.float16).eps)
               if dtype == jnp.bfloat16 else 1e-6)
        for q, s, res in ((qk, sk, resk), (qr, sr, resr)):
            deq = (np.asarray(q, np.float32)
                   * np.repeat(np.asarray(s), 128)[:n])
            np.testing.assert_allclose(
                deq + np.asarray(res, np.float32), v,
                rtol=0, atol=float(np.abs(v).max() + 1) * eps * 4)
        # and the two residuals agree up to a single quantization level
        # (a borderline int8 level can flip under different div folding)
        lvl = float(np.asarray(sr).max())
        np.testing.assert_allclose(
            np.asarray(resk, np.float32), np.asarray(resr, np.float32),
            rtol=0, atol=lvl * 1.01)

    @pytest.mark.parametrize("n", SIZES)
    def test_round_trip_error_bounded(self, rng, n):
        x = jax.random.normal(rng, (n,)) * 2.0
        q, s, res = quantize_plane_ref(x)
        rows, _, _ = quant_layout(n)
        deq = np.zeros(rows * 128, np.float32)
        deq[:n] = np.asarray(q, np.float32) * np.repeat(np.asarray(s),
                                                        128)[:rows * 128][:n]
        err = np.abs(np.asarray(x) - deq[:n])
        # per-row bound: |x - q*s| <= absmax_row / 254 (round-to-nearest
        # over 127 levels), and the EF residual IS that error
        xp = np.zeros(rows * 128, np.float32)
        xp[:n] = np.asarray(x)
        absmax = np.abs(xp.reshape(rows, 128)).max(axis=1)
        bound = np.repeat(absmax / 254.0 + 1e-7, 128)[:n]
        assert (err <= bound).all()
        np.testing.assert_allclose(np.asarray(res), np.asarray(x) - deq[:n],
                                   rtol=1e-5, atol=1e-6)

    def test_zero_plane_zero_scale_guard(self):
        x = jnp.zeros((256,), jnp.float32)
        q, s, res = quantize_plane_ref(x)
        assert (np.asarray(q) == 0).all()
        assert (np.asarray(s) == 1.0).all()  # guarded, not 0/0
        assert (np.asarray(res) == 0.0).all()


class TestDequantMix:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("n", SIZES)
    @pytest.mark.parametrize("with_upd", [False, True])
    def test_kernel_matches_ref(self, rng, dtype, n, with_upd):
        x = (jax.random.normal(rng, (n,)) * 2.0).astype(dtype)
        peer = (jax.random.normal(jax.random.fold_in(rng, 1), (n,))
                * 2.0).astype(dtype)
        upd = ((jax.random.normal(jax.random.fold_in(rng, 2), (n,))
                * 0.01).astype(dtype) if with_upd else None)
        q, s, _ = quantize_plane_ref(peer)
        # traced alpha/beta, like the lane's push-sum weights
        a, b = jnp.float32(0.6), jnp.float32(0.4)
        out_k = ops.dequant_mix(x, q, s, upd, a, b, interpret=True)
        out_r = dequant_mix_ref(x, q, s, upd, a, b)
        np.testing.assert_allclose(
            np.asarray(out_k, np.float32), np.asarray(out_r, np.float32),
            **_res_tol(dtype))
        assert out_k.dtype == x.dtype and out_k.shape == x.shape

    def test_scales_shape_validated(self, rng):
        x = jax.random.normal(rng, (256,))
        q, s, _ = quantize_plane_ref(x)
        with pytest.raises(ValueError):
            ops.dequant_mix(x, q, s[:-1], None, 0.5, 0.5, interpret=True)


class TestErrorFeedback:
    @pytest.mark.parametrize("n", [257, 1023])
    def test_residual_bounded_over_rounds(self, rng, n):
        """EF invariant: carrying resid forward keeps it bounded by the
        one-round quantization error (it never accumulates drift)."""
        x = jax.random.normal(rng, (n,)) * 2.0
        res = jnp.zeros_like(x)
        scale_bound = float(jnp.max(jnp.abs(x))) / 100.0
        for step in range(5):
            xt = x * (1.0 + 0.1 * step)  # a slowly moving plane
            q, s, res = quantize_plane_ref(xt, res)
            assert float(jnp.max(jnp.abs(res))) <= scale_bound, step

    def test_error_feedback_recovers_lost_mass(self, rng):
        """What quantization drops in round t is re-injected in round
        t+1: v_t = x_t + res_{t-1} and res_t = v_t - q_t*s_t exactly."""
        n = 640
        x = jax.random.normal(rng, (n,)) * 2.0
        res = jnp.zeros_like(x)
        total_sent = np.zeros(n, np.float64)
        total_in = np.zeros(n, np.float64)
        for step in range(3):
            total_in += np.asarray(x, np.float64)
            q, s, res = quantize_plane_ref(x, res)
            rows, _, _ = quant_layout(n)
            deq = (np.asarray(q, np.float64)
                   * np.repeat(np.asarray(s, np.float64), 128)[:n])
            total_sent += deq
        # everything not yet shipped sits in the residual
        np.testing.assert_allclose(total_in - total_sent,
                                   np.asarray(res, np.float64),
                                   rtol=1e-4, atol=1e-5)


def _drive(be, params, steps=4):
    st = be.init(jax.random.PRNGKey(0), params)
    losses = []
    for t in range(steps):
        st, m = be.step(st, mlp_batch(t), jax.random.PRNGKey(t))
        losses.append(float(m["loss"]))
    return losses, be


ENGINES = [dict(), dict(overlap=True), dict(overlap=True, streams=2)]


class TestWireThreading:
    """M=1: the int8 mix is the identity (no peer), so the quantized wire
    must be BIT-EXACT vs the param wire while still exercising the resid/
    theta threading through all three engines."""

    @pytest.mark.parametrize("eng", ENGINES,
                             ids=["monolithic", "overlap", "streams"])
    def test_int8_identity_at_m1(self, eng):
        loss_fn, params = mlp_problem()
        ref, _ = _drive(make_backend(
            "prod", "layup", M=1, loss_fn=loss_fn, optimizer=sgd(),
            schedule=lambda t: 0.05, fb_ratio=2, update_delay=1,
            measure_drift=False, **eng), params)
        got, be = _drive(make_backend(
            "prod", "layup", M=1, loss_fn=loss_fn, optimizer=sgd(),
            schedule=lambda t: 0.05, fb_ratio=2, update_delay=1,
            measure_drift=False, wire="int8", **eng), params)
        assert got == ref
        s = be.summary()
        assert s["wire_dtype"] == "int8"
        assert s["wire_bytes_per_round"] < be.part.plane_nbytes()

    @pytest.mark.parametrize("eng", ENGINES,
                             ids=["monolithic", "overlap", "streams"])
    def test_compensate_runs_and_d0_noop(self, eng):
        loss_fn, params = mlp_problem()
        # D=0: staleness is 0 every step, the correction self-gates to a
        # no-op — bit-exact vs the uncompensated lane
        ref, _ = _drive(make_backend(
            "prod", "layup", M=1, loss_fn=loss_fn, optimizer=sgd(),
            schedule=lambda t: 0.05, fb_ratio=1, update_delay=0,
            measure_drift=False, **eng), params)
        got, _ = _drive(make_backend(
            "prod", "layup", M=1, loss_fn=loss_fn, optimizer=sgd(),
            schedule=lambda t: 0.05, fb_ratio=1, update_delay=0,
            measure_drift=False, compensate=0.5, **eng), params)
        assert got == ref
        # D=1: the correction must engage (losses shift once staleness>0)
        raw, _ = _drive(make_backend(
            "prod", "layup", M=1, loss_fn=loss_fn, optimizer=sgd(),
            schedule=lambda t: 0.05, fb_ratio=1, update_delay=1,
            measure_drift=False, **eng), params)
        comp, _ = _drive(make_backend(
            "prod", "layup", M=1, loss_fn=loss_fn, optimizer=sgd(),
            schedule=lambda t: 0.05, fb_ratio=1, update_delay=1,
            measure_drift=False, compensate=0.5, **eng), params)
        assert raw != comp
        assert raw[:2] == comp[:2]  # warmup steps: FIFO not yet stale

    def test_wire_validation(self):
        loss_fn, params = mlp_problem()
        with pytest.raises(ValueError, match="wire"):
            make_backend("prod", "layup", M=1, loss_fn=loss_fn,
                         optimizer=sgd(), schedule=lambda t: 0.05,
                         wire="fp4")
        with pytest.raises(ValueError, match="flat"):
            make_backend("prod", "layup", M=1, loss_fn=loss_fn,
                         optimizer=sgd(), schedule=lambda t: 0.05,
                         flat=False, wire="int8")
        with pytest.raises(ValueError):
            make_backend("prod", "layup", M=1, loss_fn=loss_fn,
                         optimizer=sgd(), schedule=lambda t: 0.05,
                         compensate=-1.0)


class TestParamWireBitExact:
    """wire="param" explicitly must be bit-identical to the default at
    (R, D) ∈ {(1, 0), (1, 1), (2, 1)} — the quantization plumbing must
    not perturb the exact wire."""

    @pytest.mark.parametrize("R,D", [(1, 0), (1, 1), (2, 1)])
    def test_explicit_param_wire_matches_default(self, R, D):
        loss_fn, params = mlp_problem()
        ref, _ = _drive(make_backend(
            "prod", "layup", M=1, loss_fn=loss_fn, optimizer=sgd(),
            schedule=lambda t: 0.05, fb_ratio=R, update_delay=D,
            measure_drift=False), params)
        got, _ = _drive(make_backend(
            "prod", "layup", M=1, loss_fn=loss_fn, optimizer=sgd(),
            schedule=lambda t: 0.05, fb_ratio=R, update_delay=D,
            measure_drift=False, wire="param"), params)
        assert got == ref


class TestCompensationFormula:
    def test_lane_formula_matches_manual(self):
        """D=1 decoupled lane with λ>0: the applied update must equal the
        optimizer run on hand-compensated grads g + λ·g⊙g⊙(θ_now−θ_stale)
        with the FIFO's staleness as the drift factor."""
        from repro.core.layerview import FlatPartition
        from repro.launch.train import (backward_update_lane,
                                        make_decoupled_state)
        lam = 0.7
        params = {"w": jnp.arange(6.0).reshape(2, 3) * 0.1}
        part = FlatPartition(params)
        opt = sgd()
        upd = backward_update_lane(opt, lambda t: 0.1, update_delay=1,
                                   compensate=lam)
        plane = part.pack(params)
        opt_state = opt.init(plane)
        g0 = {k: jnp.ones_like(v) * 0.3 for k, v in plane.items()}
        g1 = {k: jnp.ones_like(v) * 0.5 for k, v in plane.items()}
        fifo = {"g": jax.tree.map(lambda x: x[None], g0),
                "stamp": jnp.zeros((1,), jnp.float32)}
        theta_stale = jax.tree.map(lambda x: x - 0.01, plane)
        out, _, _, stale, _, theta_new = upd(plane, opt_state, g1, fifo,
                                             jnp.int32(1), theta=theta_stale)
        drift = float(stale)  # staleness popped from the FIFO stamp
        assert drift == 1.0
        g_comp = jax.tree.map(
            lambda g, p, tp: g + lam * g * g * (drift * (p - tp)),
            g0, plane, theta_stale)
        updates, _ = opt.update(g_comp, opt.init(plane), plane, 0.1)
        expected = jax.tree.map(lambda p, u: p + u, plane, updates)
        for k in plane:
            np.testing.assert_allclose(np.asarray(out[k]),
                                       np.asarray(expected[k]),
                                       rtol=1e-6, atol=1e-7)
        # θ_new is this step's pre-update params (next step's θ_stale)
        for k in plane:
            np.testing.assert_array_equal(np.asarray(theta_new[k]),
                                          np.asarray(plane[k]))


@pytest.mark.slow
class TestMultiWorkerParity:
    def test_m2_int8_tracks_param_wire(self):
        """M=2 ring: the quantized wire's loss trajectory must track the
        exact wire within tolerance (EF keeps the error non-drifting)."""
        out = run_sub("""
            import os
            os.environ["XLA_FLAGS"] = (
                "--xla_force_host_platform_device_count=2")
            import jax, jax.numpy as jnp, numpy as np
            from repro.core.backend import make_backend
            from repro.optim.optimizers import sgd

            def loss_fn(p, b):
                h = jnp.tanh(b["x"] @ p["l1"])
                logits = h @ p["l2"]
                ce = -jnp.mean(jax.nn.log_softmax(logits)[
                    jnp.arange(logits.shape[0]), b["labels"]])
                return ce, {}

            params = {
                "l1": jax.random.normal(jax.random.PRNGKey(1), (16, 32)) * .2,
                "l2": jax.random.normal(jax.random.PRNGKey(2), (32, 10)) * .2}

            def batch(t, M=2, b=8):
                return {"x": jax.random.normal(
                            jax.random.PRNGKey(10 + t), (M, b, 16)),
                        "labels": jax.random.randint(
                            jax.random.PRNGKey(90 + t), (M, b), 0, 10)}

            losses = {}
            for wire in ("param", "int8"):
                be = make_backend("prod", "layup", M=2, loss_fn=loss_fn,
                                  optimizer=sgd(), schedule=lambda t: 0.05,
                                  fb_ratio=1, update_delay=1,
                                  measure_drift=False, wire=wire)
                st = be.init(jax.random.PRNGKey(0), params)
                ls = []
                for t in range(12):
                    st, m = be.step(st, batch(t), jax.random.PRNGKey(t))
                    ls.append(float(m["loss"]))
                losses[wire] = ls
            d = max(abs(a - b) for a, b in
                    zip(losses["param"], losses["int8"]))
            rel = d / max(abs(x) for x in losses["param"])
            assert rel < 0.02, (rel, losses)
            print("PARITY_OK", rel)
        """)
        assert "PARITY_OK" in out
