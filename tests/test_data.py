import numpy as np
import pytest

from repro.data.synthetic import (SyntheticLM, SyntheticVision,
                                  make_worker_batches)
from repro.data.pipeline import ShardedIterator


class TestSyntheticLM:
    def test_markov_structure(self):
        ds = SyntheticLM(vocab=16, seq_len=32, seed=0)
        rng = np.random.default_rng(0)
        batch = ds.sample(rng, 64)
        assert batch["tokens"].shape == (64, 32)
        # labels are next tokens
        np.testing.assert_array_equal(batch["tokens"][:, 1:],
                                      batch["labels"][:, :-1])
        # entropy floor is below log(V) (the chain is learnable)
        assert 0 < ds.entropy < np.log(16)

    def test_deterministic_worker_sharding(self):
        ds = SyntheticLM(vocab=16, seq_len=8)
        b1 = make_worker_batches(ds, 4, 2, step=3)
        b2 = make_worker_batches(ds, 4, 2, step=3)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        assert b1["tokens"].shape == (4, 2, 8)
        # different workers see different data at the same step
        assert not np.array_equal(b1["tokens"][0], b1["tokens"][1])
        # different steps differ
        b3 = make_worker_batches(ds, 4, 2, step=4)
        assert not np.array_equal(b1["tokens"], b3["tokens"])


class TestSyntheticVision:
    def test_class_structure(self):
        ds = SyntheticVision(num_classes=4, dim=32, snr=10.0)
        rng = np.random.default_rng(0)
        b = ds.sample(rng, 256)
        assert b["x"].shape == (256, 32)
        # at high SNR nearest-prototype classification is near perfect
        sims = b["x"] @ ds.prototypes.T
        acc = (sims.argmax(-1) == b["labels"]).mean()
        assert acc > 0.95


class TestPipeline:
    def test_prefetch_iterator(self):
        ds = SyntheticLM(vocab=16, seq_len=8)
        it = ShardedIterator(ds, num_workers=2, batch_per_worker=4, prefetch=2)
        try:
            b1 = next(it)
            b2 = next(it)
            assert b1["tokens"].shape == (2, 4, 8)
            assert not np.array_equal(np.asarray(b1["tokens"]),
                                      np.asarray(b2["tokens"]))
        finally:
            it.close()
