"""Flat layer-group parameter plane (DESIGN.md §11): pack-once layout,
zero-repack gossip, param-dtype wire, fused Pallas mix.

The parity class is the tentpole acceptance: with the flat plane enabled
(the default), the monolithic decoupled step AND the pipeline engine must
reproduce the legacy (tree-state, f32-ravel-wire) oracle's loss/staleness/
params EXACTLY for f32 params at (R, D) ∈ {(1,0), (1,1), (2,1)} — the flat
path only changes the memory layout and the wire dtype, never the math
order. bf16 params additionally halve the bytes-on-wire while holding loss
parity (the mix arithmetic stays f32 on exact bf16-representable values).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _fixtures import mlp_batch as _batch, mlp_problem as _mlp_problem
from _subproc import run_sub as _run
from repro.core import FlatPartition, make_backend
from repro.optim import constant, momentum


class TestFlatPartition:
    def _tree(self, dtype=jnp.float32):
        return {"blocks": [{"w": jnp.arange(12, dtype=dtype).reshape(3, 4),
                            "b": jnp.ones((4,), dtype)},
                           {"w": jnp.arange(12, dtype=dtype).reshape(3, 4)
                            * 2, "b": jnp.zeros((4,), dtype)}],
                "embed": jnp.arange(6, dtype=dtype).reshape(2, 3),
                "scale": jnp.asarray(3.0, dtype)}

    def test_roundtrip_exact(self):
        tree = self._tree()
        part = FlatPartition(tree)
        plane = part.pack(tree)
        assert set(plane) == set(part.names)
        for n in part.names:
            assert plane[n].shape == (part.group_sizes[n],)
        back = part.unpack(plane)
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
            assert a.dtype == b.dtype
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    @pytest.mark.parametrize("lead", [(2,), (2, 3)])
    def test_roundtrip_with_leading_axes(self, lead):
        """Worker-stacked (M, ...) and FIFO-stacked (M, D, ...) trees pack
        into (M, n) / (M, D, n) buffers and round-trip exactly."""
        tree = self._tree()
        part = FlatPartition(tree)
        st = jax.tree.map(lambda x: jnp.broadcast_to(x, lead + x.shape) + 0,
                          tree)
        plane = part.pack(st)
        for n in part.names:
            assert plane[n].shape == lead + (part.group_sizes[n],)
        back = part.unpack(plane)
        for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(back)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_mixed_dtype_group_splits_per_dtype(self):
        """A group mixing bf16 and f32 leaves packs into one buffer PER
        dtype — every leaf is stored at its own dtype (no silent f32
        master copies; the persistent plane stays numerically identical
        to the per-leaf tree state) and round-trips exactly."""
        tree = {"g": {"a": jnp.arange(4, dtype=jnp.bfloat16),
                      "b": jnp.arange(4, dtype=jnp.float32) * 0.5},
                "h": jnp.ones((3,), jnp.bfloat16)}
        part = FlatPartition(tree)
        plane = part.pack(tree)
        assert set(plane) == {"g:bfloat16", "g:float32", "h"}
        assert plane["g:bfloat16"].dtype == jnp.bfloat16
        assert plane["g:float32"].dtype == jnp.float32
        assert part.plane_nbytes() == 4 * 2 + 4 * 4 + 3 * 2
        # version clocks stay per GROUP, not per dtype bucket
        assert part.names == ("g", "h")
        back = part.unpack(plane)
        assert back["g"]["a"].dtype == jnp.bfloat16
        assert back["g"]["b"].dtype == jnp.float32
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
            np.testing.assert_array_equal(
                np.asarray(a, np.float32), np.asarray(b, np.float32))

    def test_pack_rejects_wrong_structure(self):
        tree = self._tree()
        part = FlatPartition(tree)
        with pytest.raises(ValueError, match="leaves"):
            part.pack({"blocks": tree["blocks"]})

    def test_plane_nbytes_halves_for_bf16(self):
        """Satellite regression: wire dtype follows param dtype, so a bf16
        model's plane — the bytes one gossip collective ships per peer —
        is exactly half the f32 plane."""
        b32 = FlatPartition(self._tree(jnp.float32)).plane_nbytes()
        b16 = FlatPartition(self._tree(jnp.bfloat16)).plane_nbytes()
        assert b16 * 2 == b32

    def test_partition_is_layerpartition(self):
        """FlatPartition is a drop-in LayerPartition: split/join/versions
        keep working (the v2 hooks and version clocks are unchanged)."""
        tree = self._tree()
        part = FlatPartition(tree)
        joined = part.join(part.split(tree))
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(joined)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert part.init_versions(3).shape == (3, part.num_groups)


class TestFlatLaneParity:
    """Tentpole acceptance: flat plane == legacy oracle, exactly (f32)."""

    @pytest.mark.parametrize("R,D", [(1, 0), (1, 1), (2, 1)])
    @pytest.mark.parametrize("overlap", [False, True])
    def test_exact_vs_legacy_oracle(self, R, D, overlap):
        loss_fn, params = _mlp_problem()
        kw = dict(M=1, loss_fn=loss_fn, optimizer=momentum(0.9),
                  schedule=constant(0.05), fb_ratio=R, update_delay=D)
        legacy = make_backend("prod", "layup", flat=False, **kw)
        flat = make_backend("prod", "layup", flat=True, overlap=overlap,
                            **kw)
        ls = legacy.init(jax.random.PRNGKey(0), params)
        fs = flat.init(jax.random.PRNGKey(0), params)
        part = FlatPartition(params)
        rng = jax.random.PRNGKey(3)
        for t in range(6):
            b = _batch(t)
            rng, r = jax.random.split(rng)
            ls, lm = legacy.step(ls, b, r)
            fs, fm = flat.step(fs, b, r)
            assert float(lm["loss"]) == float(fm["loss"]), (R, D, overlap, t)
            np.testing.assert_array_equal(
                np.asarray(lm["layer_staleness"]),
                np.asarray(fm["layer_staleness"]))
            assert float(lm["update_staleness"]) == float(
                fm["update_staleness"])
        # params: the unpacked flat plane is bit-identical to the legacy
        # tree state
        unpacked = part.unpack(fs["read"])
        for a, b in zip(jax.tree.leaves(unpacked),
                        jax.tree.leaves(ls["read"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_state_is_flat_plane(self):
        """The packed representation is the PERSISTENT one: state buffers
        are per-group planes, not parameter trees, including the FIFO."""
        loss_fn, params = _mlp_problem()
        part = FlatPartition(params)
        be = make_backend("prod", "layup", M=1, loss_fn=loss_fn,
                          optimizer=momentum(0.9), schedule=constant(0.05),
                          fb_ratio=2, update_delay=2)
        st = be.init(jax.random.PRNGKey(0), params)
        for key in ("read", "write"):
            assert set(st[key]) == set(part.names)
            for n in part.names:
                assert st[key][n].shape == (1, part.group_sizes[n])
        for n in part.names:
            assert st["fifo"]["g"][n].shape == (1, 2, part.group_sizes[n])
        st, _ = be.step(st, _batch(0), jax.random.PRNGKey(1))
        for n in part.names:  # a step preserves the plane layout + dtype
            assert st["read"][n].shape == (1, part.group_sizes[n])
            assert st["read"][n].dtype == part.group_dtypes[n]

    def test_bf16_wire_halves_with_loss_parity(self):
        """Satellite: bf16 params move HALF the bytes per collective on
        the flat wire (the state plane's nbytes are the wire payload) and
        the loss trajectory matches the legacy f32-wire path to bf16
        tolerance — the mix still runs in f32, on values that are exactly
        bf16-representable on both wires."""
        loss_fn, params = _mlp_problem()
        p16 = jax.tree.map(lambda p: p.astype(jnp.bfloat16), params)
        kw = dict(M=1, loss_fn=loss_fn, optimizer=momentum(0.9),
                  schedule=constant(0.05), fb_ratio=1, update_delay=1)
        legacy = make_backend("prod", "layup", flat=False, **kw)
        flat = make_backend("prod", "layup", flat=True, **kw)
        ls = legacy.init(jax.random.PRNGKey(0), p16)
        fs = flat.init(jax.random.PRNGKey(0), p16)
        # bytes-on-wire regression: the packed bf16 buffers are half the
        # f32 plane the legacy path would have shipped
        wire16 = sum(int(np.asarray(v).nbytes) for v in fs["read"].values())
        wire32 = sum(int(np.prod(l.shape)) * 4
                     for l in jax.tree.leaves(p16))
        assert wire16 * 2 == wire32
        assert FlatPartition(p16).plane_nbytes() * 2 \
            == FlatPartition(params).plane_nbytes()
        rng = jax.random.PRNGKey(3)
        for t in range(5):
            b = _batch(t)
            rng, r = jax.random.split(rng)
            ls, lm = legacy.step(ls, b, r)
            fs, fm = flat.step(fs, b, r)
            assert abs(float(lm["loss"]) - float(fm["loss"])) < 2e-2, t

    def test_mixed_dtype_params_match_legacy_oracle(self):
        """bf16 weights + f32 biases in the SAME layer group (the common
        mixed-precision layout): the per-dtype plane buckets keep every
        leaf at its own dtype, so the trajectory still matches the legacy
        tree-state oracle — bf16 rounding happens at the same points."""
        def loss_fn(p, b):
            h = jnp.tanh(b["x"] @ p["layer"]["w"].astype(jnp.float32)
                         + p["layer"]["b"])
            logits = h @ p["head"]["w"]
            ce = -jnp.mean(jax.nn.log_softmax(logits)[
                jnp.arange(logits.shape[0]), b["labels"]])
            return ce, {}

        k1, k2 = jax.random.PRNGKey(1), jax.random.PRNGKey(2)
        # "layer" is ONE group holding a bf16 weight and an f32 bias
        pmix = {"layer": {"w": (jax.random.normal(k1, (16, 32)) * 0.2
                               ).astype(jnp.bfloat16),
                          "b": jnp.zeros((32,), jnp.float32)},
                "head": {"w": jax.random.normal(k2, (32, 10)) * 0.2}}
        assert FlatPartition(pmix).names == ("head", "layer")
        kw = dict(M=1, loss_fn=loss_fn, optimizer=momentum(0.9),
                  schedule=constant(0.05), fb_ratio=1, update_delay=1)
        legacy = make_backend("prod", "layup", flat=False, **kw)
        flat = make_backend("prod", "layup", **kw)
        ls = legacy.init(jax.random.PRNGKey(0), pmix)
        fs = flat.init(jax.random.PRNGKey(0), pmix)
        rng = jax.random.PRNGKey(3)
        for t in range(5):
            b = _batch(t)
            rng, r = jax.random.split(rng)
            ls, lm = legacy.step(ls, b, r)
            fs, fm = flat.step(fs, b, r)
            assert float(lm["loss"]) == float(fm["loss"]), t
        unpacked = flat.export_params(fs)
        for a, b in zip(jax.tree.leaves(unpacked),
                        jax.tree.leaves(ls["read"])):
            assert a.dtype == b.dtype
            np.testing.assert_array_equal(
                np.asarray(a, np.float32), np.asarray(b, np.float32))

    def test_checkpoint_roundtrip_through_unpacked_view(self, tmp_path):
        """Satellite: checkpoint export goes through the unpacked view —
        save the tree view of a trained flat state, restore, repack, and
        land bit-identical to the live plane (and to a legacy-state
        checkpoint of the same run)."""
        from repro.checkpoint import restore_checkpoint, save_checkpoint
        loss_fn, params = _mlp_problem()
        part = FlatPartition(params)
        be = make_backend("prod", "layup", M=1, loss_fn=loss_fn,
                          optimizer=momentum(0.9), schedule=constant(0.05),
                          fb_ratio=2, update_delay=1)
        st = be.init(jax.random.PRNGKey(0), params)
        rng = jax.random.PRNGKey(3)
        for t in range(3):
            rng, r = jax.random.split(rng)
            st, _ = be.step(st, _batch(t), r)
        export = part.unpack(st["read"])  # (M, ...) tree view
        save_checkpoint(str(tmp_path), 3, export)
        restored = restore_checkpoint(str(tmp_path), 3, like=export)
        replane = part.pack(restored)
        for n in part.names:
            np.testing.assert_array_equal(np.asarray(replane[n]),
                                          np.asarray(st["read"][n]))


class TestExportParams:
    def test_export_matches_legacy_tree(self):
        """``ProdTrainerBackend.export_params`` unpacks the live plane to
        the stacked tree — bit-identical to the legacy backend's read
        state after the same trajectory."""
        loss_fn, params = _mlp_problem()
        kw = dict(M=1, loss_fn=loss_fn, optimizer=momentum(0.9),
                  schedule=constant(0.05), fb_ratio=2, update_delay=1)
        legacy = make_backend("prod", "layup", flat=False, **kw)
        flat = make_backend("prod", "layup", **kw)
        ls = legacy.init(jax.random.PRNGKey(0), params)
        fs = flat.init(jax.random.PRNGKey(0), params)
        rng = jax.random.PRNGKey(3)
        for t in range(3):
            rng, r = jax.random.split(rng)
            ls, _ = legacy.step(ls, _batch(t), r)
            fs, _ = flat.step(fs, _batch(t), r)
        exported = flat.export_params(fs)
        assert legacy.export_params(ls) is ls["read"]  # identity on trees
        for a, b in zip(jax.tree.leaves(exported),
                        jax.tree.leaves(ls["read"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_algo_runner_prod_eval_unpacks_flat_plane(self):
        """Regression: run_algorithm(backend="prod") evaluates a consensus
        snapshot of the read buffer — with the flat plane it must go
        through export_params, or eval_fn receives 1-D group buffers."""
        from benchmarks.algo_runner import run_algorithm
        from repro.core.simulator import HardwareModel
        loss_fn, params = _mlp_problem()

        class DS:
            def sample(self, rng, b):
                return {"x": rng.standard_normal((b, 16)).astype(np.float32),
                        "labels": rng.integers(0, 10, b)}

        hold = DS().sample(np.random.default_rng(123), 16)
        hold = jax.tree.map(jnp.asarray, hold)
        r = run_algorithm(
            "layup", ds=DS(), init_params_fn=lambda k: params,
            loss_fn=loss_fn, eval_fn=lambda p: loss_fn(p, hold)[0],
            M=1, steps=4, batch_per_worker=8, lr=0.05, hw=HardwareModel(),
            eval_every=2, warmup=2, backend="prod")
        assert r.eval_metric.size >= 2 and np.isfinite(r.eval_metric).all()


class TestPallasGossipPath:
    """Satellite: the fused gossip_mix kernel wired into the gossip path
    (interpret mode on CPU)."""

    def test_fused_monolithic_matches_default_at_m1(self):
        """At M=1 the fused lane degenerates to a kernel-applied
        ``x + upd`` — bitwise-equal to the default apply for f32, so the
        whole trajectory must match exactly."""
        loss_fn, params = _mlp_problem()
        kw = dict(M=1, loss_fn=loss_fn, optimizer=momentum(0.9),
                  schedule=constant(0.05), fb_ratio=2, update_delay=1)
        base = make_backend("prod", "layup", **kw)
        pal = make_backend("prod", "layup", use_pallas=True, **kw)
        bs = base.init(jax.random.PRNGKey(0), params)
        zs = pal.init(jax.random.PRNGKey(0), params)
        rng = jax.random.PRNGKey(3)
        for t in range(4):
            b = _batch(t)
            rng, r = jax.random.split(rng)
            bs, bm = base.step(bs, b, r)
            zs, zm = pal.step(zs, b, r)
            assert float(bm["loss"]) == float(zm["loss"]), t

    def test_fused_pipeline_matches_fused_monolithic(self):
        """The pipeline engine's fused gossip stage (which donates the
        deltas, not the live plane) is exact vs the fused monolithic
        step."""
        loss_fn, params = _mlp_problem()
        kw = dict(M=1, loss_fn=loss_fn, optimizer=momentum(0.9),
                  schedule=constant(0.05), fb_ratio=2, update_delay=1,
                  use_pallas=True)
        mono = make_backend("prod", "layup", **kw)
        pipe = make_backend("prod", "layup", overlap=True, **kw)
        ms = mono.init(jax.random.PRNGKey(0), params)
        ps = pipe.init(jax.random.PRNGKey(0), params)
        rng = jax.random.PRNGKey(3)
        for t in range(4):
            b = _batch(t)
            rng, r = jax.random.split(rng)
            ms, mm = mono.step(ms, b, r)
            ps, pm = pipe.step(ps, b, r)
            assert float(mm["loss"]) == float(pm["loss"]), t
            np.testing.assert_array_equal(
                np.asarray(mm["layer_staleness"]),
                np.asarray(pm["layer_staleness"]))

    def test_use_pallas_requires_flat(self):
        loss_fn, _ = _mlp_problem()
        with pytest.raises(ValueError, match="flat"):
            make_backend("prod", "layup", M=1, loss_fn=loss_fn,
                         optimizer=momentum(0.9), schedule=constant(0.05),
                         flat=False, use_pallas=True)


def test_flat_and_pallas_lower_on_dryrun_mesh():
    """Acceptance (both shard_map shim paths, via the CI matrix): the flat
    monolithic step, the flat pipeline stages AND the fused-pallas variant
    all lower on the host-device dry-run meshes — tier-1, lower-only."""
    out = _run("""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
from repro.configs import get_config, reduced, ShapeConfig
from repro.launch.mesh import make_test_mesh
from repro.launch.train import make_step
from repro.models import build_model
from repro.optim import momentum, constant
cfg = reduced(get_config("stablelm-1.6b"))
m = build_model(cfg)
shape = ShapeConfig("t", 16, 4, "train")
for mesh_shape, axes in (((1, 1, 2), ("pod", "data", "model")),
                         ((2, 2), ("data", "model"))):
    mesh = make_test_mesh(mesh_shape, axes)
    for kw in (dict(), dict(use_pallas=True), dict(overlap=True)):
        step = make_step(m, mesh, shape, algo="layup",
                         optimizer=momentum(0.9), schedule=constant(0.05),
                         shifts=(1,), fb_ratio=2, update_delay=1, **kw)
        step.lower()
        print("LOWERED", step.describe)
""", timeout=900)
    assert out.count("LOWERED") == 6
    assert out.count("flat=True") == 6
    assert out.count("pallas") == 2


@pytest.mark.slow
def test_flat_m2_mesh_exact_vs_legacy_oracle():
    """Acceptance (mesh form): with real ring gossip (M=2) on the dry-run
    mesh, the flat monolithic step and the flat pipeline engine match the
    LEGACY oracle's losses exactly — the param-dtype wire and the plane
    layout change nothing for f32 params."""
    out = _run("""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config, reduced, ShapeConfig
from repro.launch.mesh import make_test_mesh
from repro.launch.train import (make_layup_decoupled_train_step,
                                make_decoupled_state, make_step)
from repro.models import build_model
from repro.optim import momentum, constant
from repro.data.synthetic import lm_batch_for

cfg = reduced(get_config("stablelm-1.6b"))
m = build_model(cfg)
opt = momentum(0.9)
mesh = make_test_mesh((2, 2), ("data", "model"))
M, bsz, R, D = 2, 8, 2, 1
shape = ShapeConfig("t", 16, bsz, "train")
params = m.init(jax.random.PRNGKey(0))
sp = jax.tree.map(lambda p: jnp.broadcast_to(p[None], (M,) + p.shape) + 0,
                  params)
batch = lm_batch_for(cfg, bsz, 16)
leg = make_layup_decoupled_train_step(
    m, mesh, opt, constant(0.05), shape, shifts=(1,), fb_ratio=R,
    update_delay=D, flat=False).lower().compile()
fl = make_layup_decoupled_train_step(
    m, mesh, opt, constant(0.05), shape, shifts=(1,), fb_ratio=R,
    update_delay=D).lower().compile()
pipe = make_step(m, mesh, shape, algo="layup", optimizer=opt,
                 schedule=constant(0.05), shifts=(1,), fb_ratio=R,
                 update_delay=D, overlap=True)
ls = make_decoupled_state(sp, opt, update_delay=D, flat=False)
fs = make_decoupled_state(sp, opt, update_delay=D)
ps = pipe.init_state(jax.tree.map(jnp.copy, sp))
for t in range(3):
    ls, lm = leg(ls, batch, jnp.asarray(t, jnp.int32),
                 jnp.zeros((), jnp.int32))
    fs, fm = fl(fs, batch, jnp.asarray(t, jnp.int32),
                jnp.zeros((), jnp.int32))
    ps, pm = pipe.fn(ps, batch, t, 0)
    assert float(lm["loss"]) == float(fm["loss"]), (t, "mono")
    dl = abs(float(lm["loss"]) - float(pm["loss"]))
    assert dl < 1e-6, (t, "pipe", dl)
    ds = np.abs(np.asarray(lm["layer_staleness"])
                - np.asarray(fm["layer_staleness"])).max()
    assert ds == 0.0, (t, ds)
print("FLAT MESH ORACLE OK")
""")
    assert "FLAT MESH ORACLE OK" in out


@pytest.mark.slow
@pytest.mark.xfail(
    strict=True,
    reason="known limitation (ROADMAP): per-leaf TP sharding requires "
           "flat=False — the flat plane shards every group buffer P(data) "
           "and REPLICATES it over 'model'; a future per-shard plane PR "
           "flips this to passing")
def test_flat_plane_carries_tp_sharding_on_model_axis():
    """Pins the flat-plane/TP trade: on a (2,2) data x model mesh the
    tensor-parallel axis should eventually appear in the read plane's
    sharding specs. Today it does not (the plane is replicated over
    'model' — pipeline.py's ``p_sh = tree.map(lambda _: w_sh, ...)``);
    the subprocess just reports the observed specs, the xfail'd assert
    below states the DESIRED behavior."""
    out = _run("""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from repro.configs import get_config, reduced, ShapeConfig
from repro.launch.mesh import make_test_mesh
from repro.launch.train import make_step
from repro.models import build_model
from repro.optim import momentum, constant
from repro.data.synthetic import lm_batch_for

cfg = reduced(get_config("stablelm-1.6b"))
m = build_model(cfg)
mesh = make_test_mesh((2, 2), ("data", "model"))
M, bsz = 2, 8
shape = ShapeConfig("t", 16, bsz, "train")
params = m.init(jax.random.PRNGKey(0))
sp = jax.tree.map(lambda p: jnp.broadcast_to(p[None], (M,) + p.shape) + 0,
                  params)
step = make_step(m, mesh, shape, algo="layup", optimizer=momentum(0.9),
                 schedule=constant(0.05), shifts=(1,), fb_ratio=2,
                 update_delay=1, overlap=True)
st = step.init_state(sp)
# one real step: the gossip stage's pinned out_shardings land on the
# read plane, so the observed specs ARE the engine's sharding contract
st, mtr = step.fn(st, lm_batch_for(cfg, bsz, 16), 0, 0)
float(mtr["loss"])
specs = sorted(str(buf.sharding.spec) for buf in st["read"].values())
print("READ_SPECS", "; ".join(specs))
print("SPECS_OK")
""")
    assert "SPECS_OK" in out
    assert "model" in out.split("READ_SPECS", 1)[1].splitlines()[0]
