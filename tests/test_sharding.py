import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch import sharding as SH


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)

    @property
    def size(self):
        n = 1
        for v in self.shape.values():
            n *= v
        return n


MESH = FakeMesh({"data": 16, "model": 16})
MESH3 = FakeMesh({"pod": 2, "data": 16, "model": 16})


class TestRules:
    def test_single_pod_drops_pod_axis(self):
        rules = SH.rules_for(MESH)
        assert rules["worker"] == ("data",)
        assert rules["batch"] == ("data",)

    def test_multi_pod_keeps_both(self):
        rules = SH.rules_for(MESH3)
        assert rules["worker"] == ("pod", "data")

    def test_overrides(self):
        rules = SH.rules_for(MESH, overrides={"heads": None})
        assert rules["heads"] is None


class TestSpecForAxes:
    def test_basic_mapping(self):
        rules = SH.rules_for(MESH)
        spec = SH.spec_for_axes(("embed", "heads", "hd"), rules, MESH,
                                (1024, 32, 128))
        assert spec == P(None, "model", None)

    def test_non_divisible_falls_back(self):
        rules = SH.rules_for(MESH)
        # whisper: 20 heads on a 16-way axis → replicate
        spec = SH.spec_for_axes(("embed", "heads", "hd"), rules, MESH,
                                (1280, 20, 64))
        assert spec == P(None, None, None)

    def test_duplicate_axis_first_wins(self):
        rules = SH.rules_for(MESH)
        # MoE: experts and ffn both map to model; experts (divisible) wins
        spec = SH.spec_for_axes(("experts", "embed", "ffn"), rules, MESH,
                                (128, 2048, 768))
        assert spec == P("model", None, None)

    def test_duplicate_axis_falls_through_when_first_not_divisible(self):
        rules = SH.rules_for(MESH)
        # mixtral: 8 experts (not divisible by 16) → dff gets the axis
        spec = SH.spec_for_axes(("experts", "embed", "ffn"), rules, MESH,
                                (8, 4096, 14336))
        assert spec == P(None, None, "model")

    def test_worker_stacking(self):
        rules = SH.rules_for(MESH3)
        spec = SH.spec_for_axes(("worker", "embed", "ffn"), rules, MESH3,
                                (32, 4096, 14336))
        assert spec == P(("pod", "data"), None, "model")


class TestOptShardings:
    """Optimizer-state shardings are keyed by tree path, not leaf shape:
    two params with identical shapes but different shardings must not
    collide (the old shape-keyed dict was last-wins)."""

    def _mesh(self):
        return jax.make_mesh((1, 1), ("data", "model"))

    def test_same_shape_params_keep_distinct_shardings(self):
        import jax.numpy as jnp
        from jax.sharding import NamedSharding
        from repro.launch.train import _opt_shardings
        from repro.optim import adamw, momentum
        mesh = self._mesh()
        abstract = {"a": jax.ShapeDtypeStruct((4, 8), jnp.float32),
                    "b": jax.ShapeDtypeStruct((4, 8), jnp.float32)}
        p_sh = {"a": NamedSharding(mesh, P(None, "model")),
                "b": NamedSharding(mesh, P("model", None))}
        opt_sh = _opt_shardings(momentum(0.9), abstract, p_sh, mesh)
        assert opt_sh["a"].spec == P(None, "model")
        assert opt_sh["b"].spec == P("model", None)
        # adamw nests the param tree under mu/nu and adds a scalar count:
        # suffix matching strips the wrapper key; count is replicated
        opt_sh = _opt_shardings(adamw(), abstract, p_sh, mesh)
        assert opt_sh["mu"]["a"].spec == P(None, "model")
        assert opt_sh["mu"]["b"].spec == P("model", None)
        assert opt_sh["nu"]["a"].spec == P(None, "model")
        assert opt_sh["count"].spec == P()

    def test_stacked_variant_keys_by_path(self):
        import jax.numpy as jnp
        from jax.sharding import NamedSharding
        from repro.launch.train import _opt_shardings_stacked
        from repro.optim import adamw
        mesh = self._mesh()
        abstract = {"a": jax.ShapeDtypeStruct((4, 8), jnp.float32),
                    "b": jax.ShapeDtypeStruct((4, 8), jnp.float32)}
        p_sh = {"a": NamedSharding(mesh, P("data", None, "model")),
                "b": NamedSharding(mesh, P("data", "model", None))}
        opt_single = jax.eval_shape(adamw().init, abstract)
        opt_sh = _opt_shardings_stacked(opt_single, abstract, p_sh, mesh, 1)
        assert opt_sh["mu"]["a"].spec == P("data", None, "model")
        assert opt_sh["mu"]["b"].spec == P("data", "model", None)
        assert opt_sh["nu"]["b"].spec == P("data", "model", None)
        # unmatched leaves (count) fall back to worker-stacked replication
        assert opt_sh["count"].spec == P("data")
