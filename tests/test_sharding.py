import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch import sharding as SH


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)

    @property
    def size(self):
        n = 1
        for v in self.shape.values():
            n *= v
        return n


MESH = FakeMesh({"data": 16, "model": 16})
MESH3 = FakeMesh({"pod": 2, "data": 16, "model": 16})


class TestRules:
    def test_single_pod_drops_pod_axis(self):
        rules = SH.rules_for(MESH)
        assert rules["worker"] == ("data",)
        assert rules["batch"] == ("data",)

    def test_multi_pod_keeps_both(self):
        rules = SH.rules_for(MESH3)
        assert rules["worker"] == ("pod", "data")

    def test_overrides(self):
        rules = SH.rules_for(MESH, overrides={"heads": None})
        assert rules["heads"] is None


class TestSpecForAxes:
    def test_basic_mapping(self):
        rules = SH.rules_for(MESH)
        spec = SH.spec_for_axes(("embed", "heads", "hd"), rules, MESH,
                                (1024, 32, 128))
        assert spec == P(None, "model", None)

    def test_non_divisible_falls_back(self):
        rules = SH.rules_for(MESH)
        # whisper: 20 heads on a 16-way axis → replicate
        spec = SH.spec_for_axes(("embed", "heads", "hd"), rules, MESH,
                                (1280, 20, 64))
        assert spec == P(None, None, None)

    def test_duplicate_axis_first_wins(self):
        rules = SH.rules_for(MESH)
        # MoE: experts and ffn both map to model; experts (divisible) wins
        spec = SH.spec_for_axes(("experts", "embed", "ffn"), rules, MESH,
                                (128, 2048, 768))
        assert spec == P("model", None, None)

    def test_duplicate_axis_falls_through_when_first_not_divisible(self):
        rules = SH.rules_for(MESH)
        # mixtral: 8 experts (not divisible by 16) → dff gets the axis
        spec = SH.spec_for_axes(("experts", "embed", "ffn"), rules, MESH,
                                (8, 4096, 14336))
        assert spec == P(None, None, "model")

    def test_worker_stacking(self):
        rules = SH.rules_for(MESH3)
        spec = SH.spec_for_axes(("worker", "embed", "ffn"), rules, MESH3,
                                (32, 4096, 14336))
        assert spec == P(("pod", "data"), None, "model")
