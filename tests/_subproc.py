"""Shared subprocess runner for the mesh tests (XLA device-count flags must
be set before jax init, so these run out-of-process)."""
import os
import subprocess
import sys
import textwrap

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(code: str, timeout=1800):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    # pin the CPU platform: with libtpu present, an unset JAX_PLATFORMS
    # makes each subprocess spend ~7 min probing a TPU backend before
    # falling back to CPU (the host-device-count flag applies to the CPU
    # platform anyway) — most of what made these tests "slow"
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    return r.stdout
