"""Hypothesis property-based tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the optional hypothesis dep")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.api import choose_peers, consensus, pushsum_weight_update
from repro.core.layerview import LayerPartition
from repro.core.adpsgd import random_matching
from repro.kernels import ref as KREF
from repro.models import layers as L
from repro.models import ssm as S

SETTINGS = dict(max_examples=20, deadline=None)


class TestPushSumProperties:
    @given(m=st.integers(2, 24), seed=st.integers(0, 2**30),
           steps=st.integers(1, 8))
    @settings(**SETTINGS)
    def test_weight_sum_invariant(self, m, seed, steps):
        rng = jax.random.PRNGKey(seed)
        w = jax.random.uniform(jax.random.fold_in(rng, 1), (m,)) + 0.05
        w = w / w.sum()
        active = jax.random.bernoulli(jax.random.fold_in(rng, 2), 0.7, (m,))
        for i in range(steps):
            r = jax.random.fold_in(rng, 10 + i)
            send_ok, has_recv, sender_idx = choose_peers(r, m, active)
            w = pushsum_weight_update(w, send_ok, has_recv, sender_idx)
        assert float(w.sum()) == pytest.approx(1.0, abs=1e-5)
        assert float(w.min()) > 0.0

    @given(m=st.integers(2, 24), seed=st.integers(0, 2**30))
    @settings(**SETTINGS)
    def test_winner_targets_unique(self, m, seed):
        rng = jax.random.PRNGKey(seed)
        active = jnp.ones(m, bool)
        send_ok, has_recv, sender_idx = choose_peers(rng, m, active)
        senders = np.asarray(sender_idx)[np.asarray(has_recv)]
        assert len(senders) == len(set(senders.tolist()))
        # every active worker either wins its send or was skipped; winners
        # count equals receivers count
        assert int(send_ok.sum()) == int(has_recv.sum()) > 0

    @given(m=st.integers(2, 16), seed=st.integers(0, 2**30))
    @settings(**SETTINGS)
    def test_adpsgd_matching_is_involution(self, m, seed):
        partner = random_matching(jax.random.PRNGKey(seed), m)
        p = np.asarray(partner)
        np.testing.assert_array_equal(p[p], np.arange(m))


class TestGossipMassConservation:
    @given(m=st.integers(2, 12), n=st.integers(1, 20),
           seed=st.integers(0, 2**30))
    @settings(**SETTINGS)
    def test_layup_mix_preserves_weighted_mean(self, m, n, seed):
        from repro.core import get_algorithm
        rng = jax.random.PRNGKey(seed)
        algo = get_algorithm("layup")
        params = {"w": jax.random.normal(jax.random.fold_in(rng, 1), (m, n))}
        w = jax.random.uniform(jax.random.fold_in(rng, 2), (m,)) + 0.05
        w = w / w.sum()
        updates = {"w": jnp.zeros((m, n))}
        active = jnp.ones(m, bool)
        before = consensus(params, w)["w"]
        part = LayerPartition(params)
        v2, w2, _, _ = algo.post(part.view(params, M=m), w, (),
                                 part.split(updates), active,
                                 jax.random.fold_in(rng, 3), 0)
        after = consensus(part.join(v2.groups), w2)["w"]
        np.testing.assert_allclose(np.asarray(before), np.asarray(after),
                                   rtol=1e-4, atol=1e-5)


class TestAttentionProperties:
    @given(s=st.sampled_from([8, 16, 32]),
           hq=st.sampled_from([1, 2, 4]),
           g=st.sampled_from([1, 2]),
           window=st.sampled_from([0, 8]),
           seed=st.integers(0, 2**30))
    @settings(**SETTINGS)
    def test_flash_equals_naive(self, s, hq, g, window, seed):
        rng = jax.random.PRNGKey(seed)
        hkv = max(hq // g, 1)
        hq = hkv * g
        d = 8
        q = jax.random.normal(jax.random.fold_in(rng, 1), (1, s, hq, d))
        k = jax.random.normal(jax.random.fold_in(rng, 2), (1, s, hkv, d))
        v = jax.random.normal(jax.random.fold_in(rng, 3), (1, s, hkv, d))
        pos = jnp.arange(s)[None]
        out = L.flash_attention_jnp(q, k, v, q_positions=pos, k_positions=pos,
                                    causal=True, window=window, block_k=8)
        ref = KREF.attention_ref(q.transpose(0, 2, 1, 3),
                                 k.transpose(0, 2, 1, 3),
                                 v.transpose(0, 2, 1, 3),
                                 causal=True, window=window)
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(ref.transpose(0, 2, 1, 3)),
                                   rtol=2e-3, atol=2e-4)

    @given(seed=st.integers(0, 2**30))
    @settings(**SETTINGS)
    def test_attention_is_convex_combination(self, seed):
        """Each output row lies in the convex hull of V rows: max|out| ≤ max|V|."""
        rng = jax.random.PRNGKey(seed)
        q = jax.random.normal(jax.random.fold_in(rng, 1), (1, 16, 2, 8))
        k = jax.random.normal(jax.random.fold_in(rng, 2), (1, 16, 2, 8))
        v = jax.random.normal(jax.random.fold_in(rng, 3), (1, 16, 2, 8))
        pos = jnp.arange(16)[None]
        out = L.flash_attention_jnp(q, k, v, q_positions=pos, k_positions=pos,
                                    block_k=8)
        assert float(jnp.abs(out).max()) <= float(jnp.abs(v).max()) + 1e-5


class TestSSDProperties:
    @given(l=st.sampled_from([8, 16, 32]), chunk=st.sampled_from([4, 8, 16]),
           h=st.integers(1, 3), seed=st.integers(0, 2**30))
    @settings(**SETTINGS)
    def test_chunked_equals_recurrence(self, l, chunk, h, seed):
        if chunk > l:
            chunk = l
        if l % chunk:
            return
        rng = jax.random.PRNGKey(seed)
        b, p, n = 1, 4, 4
        x = jax.random.normal(jax.random.fold_in(rng, 1), (b, l, h, p)) * 0.5
        dt = jax.nn.softplus(
            jax.random.normal(jax.random.fold_in(rng, 2), (b, l, h)))
        A = -jnp.exp(jax.random.normal(jax.random.fold_in(rng, 3), (h,)) * 0.3)
        Bm = jax.random.normal(jax.random.fold_in(rng, 4), (b, l, n)) * 0.5
        Cm = jax.random.normal(jax.random.fold_in(rng, 5), (b, l, n)) * 0.5
        y1, s1 = S.ssd_chunked(x, dt, A, Bm, Cm, chunk=chunk)
        y2, s2 = S.ssd_reference(x, dt, A, Bm, Cm)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   rtol=1e-3, atol=1e-4)


class TestOptimizerProperties:
    @given(seed=st.integers(0, 2**30), lr=st.floats(1e-4, 0.5))
    @settings(**SETTINGS)
    def test_sgd_descent_direction(self, seed, lr):
        from repro.optim import sgd
        from repro.optim.optimizers import apply_updates
        rng = jax.random.PRNGKey(seed)
        g = jax.random.normal(rng, (16,))
        opt = sgd()
        u, _ = opt.update(g, opt.init(g), jnp.zeros(16), lr)
        assert float(jnp.dot(u, g)) <= 0.0  # descent

    @given(seed=st.integers(0, 2**30))
    @settings(**SETTINGS)
    def test_cross_entropy_nonneg(self, seed):
        rng = jax.random.PRNGKey(seed)
        logits = jax.random.normal(rng, (4, 8, 16)) * 3
        labels = jax.random.randint(jax.random.fold_in(rng, 1), (4, 8), 0, 16)
        assert float(L.cross_entropy(logits, labels)) >= 0.0
