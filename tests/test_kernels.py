"""Pallas-kernel validation: shape/dtype sweeps vs the pure-jnp oracles
(interpret mode — kernel bodies execute in Python on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels import ref as KREF


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-4, atol=2e-5)


class TestFlashKernel:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("B,Hq,Hkv,S,D,bq,bk", [
        (1, 2, 2, 64, 16, 16, 16),
        (2, 4, 2, 128, 32, 32, 64),   # GQA, rectangular blocks
        (1, 8, 1, 64, 8, 64, 16),     # MQA
    ])
    def test_sweep(self, rng, dtype, B, Hq, Hkv, S, D, bq, bk):
        q = jax.random.normal(rng, (B, Hq, S, D)).astype(dtype)
        k = jax.random.normal(jax.random.fold_in(rng, 1),
                              (B, Hkv, S, D)).astype(dtype)
        v = jax.random.normal(jax.random.fold_in(rng, 2),
                              (B, Hkv, S, D)).astype(dtype)
        out = ops.flash_attention(q, k, v, block_q=bq, block_k=bk,
                                  interpret=True)
        ref = KREF.attention_ref(q, k, v)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32),
            **_tol(dtype))

    @pytest.mark.parametrize("causal,window", [(True, 16), (False, 0)])
    def test_masking_variants(self, rng, causal, window):
        B, Hq, Hkv, S, D = 1, 2, 1, 64, 16
        q = jax.random.normal(rng, (B, Hq, S, D))
        k = jax.random.normal(jax.random.fold_in(rng, 1), (B, Hkv, S, D))
        v = jax.random.normal(jax.random.fold_in(rng, 2), (B, Hkv, S, D))
        out = ops.flash_attention(q, k, v, causal=causal, window=window,
                                  block_q=16, block_k=16, interpret=True)
        ref = KREF.attention_ref(q, k, v, causal=causal, window=window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-4, atol=2e-5)


class TestSSDKernel:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("B,H,S,P,N,chunk", [
        (1, 2, 32, 8, 4, 8),
        (2, 3, 64, 16, 8, 16),
        (1, 1, 64, 32, 16, 64),
    ])
    def test_sweep(self, rng, dtype, B, H, S, P, N, chunk):
        x = (jax.random.normal(rng, (B, H, S, P)) * 0.5).astype(dtype)
        dt = jax.nn.softplus(
            jax.random.normal(jax.random.fold_in(rng, 1), (B, H, S))
        ).astype(dtype)
        A = -jnp.exp(jax.random.normal(jax.random.fold_in(rng, 2), (H,)) * 0.3)
        Bm = (jax.random.normal(jax.random.fold_in(rng, 3), (B, S, N)) * 0.5
              ).astype(dtype)
        Cm = (jax.random.normal(jax.random.fold_in(rng, 4), (B, S, N)) * 0.5
              ).astype(dtype)
        y = ops.ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, interpret=True)
        ref = KREF.ssd_ref(x, dt, A, Bm, Cm)
        np.testing.assert_allclose(
            np.asarray(y, np.float32), np.asarray(ref, np.float32),
            **_tol(dtype))


class TestGossipMixKernel:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("shape", [(128,), (7, 33, 5), (1024, 128),
                                       (3, 3)])
    def test_sweep(self, rng, dtype, shape):
        x = jax.random.normal(rng, shape).astype(dtype)
        r = jax.random.normal(jax.random.fold_in(rng, 1), shape).astype(dtype)
        u = (jax.random.normal(jax.random.fold_in(rng, 2), shape) * 0.01
             ).astype(dtype)
        out = ops.gossip_mix(x, r, u, 0.6, 0.4, interpret=True)
        ref = KREF.gossip_mix_ref(x, r, u, 0.6, 0.4)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32),
            **_tol(dtype))

    def test_pure_mix_convexity(self, rng):
        """With upd = 0, output lies between x and x_recv elementwise."""
        x = jnp.ones((64,)) * 2.0
        r = jnp.ones((64,)) * -1.0
        out = ops.gossip_mix(x, r, jnp.zeros(64), 0.75, 0.25, interpret=True)
        np.testing.assert_allclose(np.asarray(out), 0.75 * 2.0 - 0.25,
                                   rtol=1e-6)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("n", [1, 127, 129, 1023, 8 * 128 + 5])
    def test_odd_sizes_exercise_padding(self, rng, dtype, n):
        """Satellite: fused kernel vs the reference mix at sizes that are
        NOT multiples of the (8, 128) tile — the pad/unpad path must be
        exact (padding contributes zeros that are sliced away)."""
        x = jax.random.normal(rng, (n,)).astype(dtype)
        r = jax.random.normal(jax.random.fold_in(rng, 1), (n,)).astype(dtype)
        u = (jax.random.normal(jax.random.fold_in(rng, 2), (n,)) * 0.01
             ).astype(dtype)
        out = ops.gossip_mix(x, r, u, 0.7, 0.3, interpret=True)
        ref = KREF.gossip_mix_ref(x, r, u, 0.7, 0.3)
        assert out.shape == (n,) and out.dtype == dtype
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32), **_tol(dtype))

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("n", [127, 1024])
    def test_pure_mix_variant_matches_ref(self, rng, dtype, n):
        """upd=None selects the 2-read pure-mix kernel (the lockstep
        gossip path); it must equal the reference with a zero update."""
        x = jax.random.normal(rng, (n,)).astype(dtype)
        r = jax.random.normal(jax.random.fold_in(rng, 1), (n,)).astype(dtype)
        out = ops.gossip_mix(x, r, None, 0.6, 0.4, interpret=True)
        ref = KREF.gossip_mix_ref(x, r, jnp.zeros_like(x), 0.6, 0.4)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32), **_tol(dtype))

    def test_traced_alpha_beta(self, rng):
        """α/β arrive as traced scalars from the push-sum weights inside
        the jitted gossip stage — the SMEM prefetch path must accept
        them."""
        x = jax.random.normal(rng, (300,))
        r = jax.random.normal(jax.random.fold_in(rng, 1), (300,))

        @jax.jit
        def f(w, rw):
            new_w = w + rw
            return ops.gossip_mix(x, r, None, w / new_w, rw / new_w,
                                  interpret=True)

        out = f(jnp.float32(0.5), jnp.float32(0.25))
        ref = KREF.gossip_mix_ref(x, r, jnp.zeros_like(x),
                                  2.0 / 3.0, 1.0 / 3.0)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-6, atol=1e-6)


class TestRMSNormKernel:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("shape,tile", [((4, 64), 2), ((2, 7, 128), 8),
                                            ((300, 32), 256)])
    def test_sweep(self, rng, dtype, shape, tile):
        x = (jax.random.normal(rng, shape) * 3).astype(dtype)
        g = (1 + 0.1 * jax.random.normal(jax.random.fold_in(rng, 1),
                                         shape[-1:])).astype(dtype)
        out = ops.rmsnorm(x, g, tile_rows=tile, interpret=True)
        ref = KREF.rmsnorm_ref(x, g)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32),
            **_tol(dtype))

    def test_matches_model_rmsnorm(self, rng):
        from repro.models.layers import rmsnorm as model_rmsnorm
        x = jax.random.normal(rng, (8, 64))
        g = jnp.ones(64)
        np.testing.assert_allclose(
            np.asarray(ops.rmsnorm(x, g, interpret=True)),
            np.asarray(model_rmsnorm(x, g)), rtol=1e-5, atol=1e-6)


class TestFlashBackwardKernels:
    """Pallas dq + dk/dv backward passes vs naive autodiff grads."""

    @pytest.mark.parametrize("Hq,Hkv,causal,window,bq,bk", [
        (2, 2, True, 0, 16, 16),
        (4, 2, True, 16, 32, 16),   # GQA + sliding window
        (4, 1, False, 0, 16, 32),   # MQA bidirectional
    ])
    def test_grads_match_naive(self, rng, Hq, Hkv, causal, window, bq, bk):
        from repro.kernels.flash_attention import flash_attention_trainable
        B, S, D = 1, 64, 16
        q = jax.random.normal(rng, (B, Hq, S, D))
        k = jax.random.normal(jax.random.fold_in(rng, 1), (B, Hkv, S, D))
        v = jax.random.normal(jax.random.fold_in(rng, 2), (B, Hkv, S, D))

        def f(q, k, v):
            return flash_attention_trainable(
                q, k, v, causal=causal, window=window, block_q=bq,
                block_k=bk, interpret=True).sum()

        def g(q, k, v):
            return KREF.attention_ref(q, k, v, causal=causal,
                                      window=window).sum()

        g1 = jax.grad(f, (0, 1, 2))(q, k, v)
        g2 = jax.grad(g, (0, 1, 2))(q, k, v)
        for a, b, n in zip(g1, g2, "qkv"):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-3, atol=2e-5, err_msg=n)

    def test_fwd_lse_output(self, rng):
        from repro.kernels.flash_attention import flash_attention
        B, H, S, D = 1, 2, 32, 8
        q = jax.random.normal(rng, (B, H, S, D))
        k = jax.random.normal(jax.random.fold_in(rng, 1), (B, H, S, D))
        o, lse = flash_attention(q, k, k, block_q=8, block_k=8,
                                 return_lse=True, interpret=True)
        assert lse.shape == (B, H, S)
        assert np.all(np.isfinite(np.asarray(lse)))
