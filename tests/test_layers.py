import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref as KREF
from repro.models import layers as L


def _qkv(rng, B, Sq, Sk, Hq, Hkv, D, dtype=jnp.float32):
    q = jax.random.normal(rng, (B, Sq, Hq, D), dtype)
    k = jax.random.normal(jax.random.fold_in(rng, 1), (B, Sk, Hkv, D), dtype)
    v = jax.random.normal(jax.random.fold_in(rng, 2), (B, Sk, Hkv, D), dtype)
    return q, k, v


class TestRMSNorm:
    def test_unit_variance(self, rng):
        x = jax.random.normal(rng, (4, 64)) * 5.0
        y = L.rmsnorm(x, jnp.ones(64))
        rms = jnp.sqrt(jnp.mean(y * y, -1))
        np.testing.assert_allclose(np.asarray(rms), 1.0, rtol=1e-3)

    def test_gamma_scales(self, rng):
        x = jax.random.normal(rng, (4, 64))
        y2 = L.rmsnorm(x, 2 * jnp.ones(64))
        y1 = L.rmsnorm(x, jnp.ones(64))
        np.testing.assert_allclose(np.asarray(y2), np.asarray(2 * y1), rtol=1e-5)


class TestRoPE:
    def test_norm_preserved(self, rng):
        x = jax.random.normal(rng, (2, 16, 4, 32))
        pos = jnp.broadcast_to(jnp.arange(16)[None], (2, 16))
        y = L.apply_rope(x, pos, theta=1e4, fraction=1.0)
        np.testing.assert_allclose(np.linalg.norm(np.asarray(x), axis=-1),
                                   np.linalg.norm(np.asarray(y), axis=-1),
                                   rtol=1e-5)

    def test_relative_property(self, rng):
        """<rope(q,i), rope(k,j)> depends only on i - j."""
        q = jax.random.normal(rng, (1, 1, 1, 32))
        k = jax.random.normal(jax.random.fold_in(rng, 1), (1, 1, 1, 32))

        def dot_at(i, j):
            qi = L.apply_rope(q, jnp.array([[i]]), theta=1e4)
            kj = L.apply_rope(k, jnp.array([[j]]), theta=1e4)
            return float(jnp.sum(qi * kj))

        assert abs(dot_at(5, 3) - dot_at(12, 10)) < 1e-4
        assert abs(dot_at(7, 7) - dot_at(0, 0)) < 1e-4

    def test_partial_fraction_passthrough(self, rng):
        x = jax.random.normal(rng, (1, 8, 2, 32))
        pos = jnp.broadcast_to(jnp.arange(8)[None], (1, 8))
        y = L.apply_rope(x, pos, theta=1e4, fraction=0.25)
        # last 75% of dims untouched
        np.testing.assert_array_equal(np.asarray(x[..., 8:]),
                                      np.asarray(y[..., 8:]))

    def test_theta_zero_identity(self, rng):
        x = jax.random.normal(rng, (1, 8, 2, 32))
        pos = jnp.broadcast_to(jnp.arange(8)[None], (1, 8))
        np.testing.assert_array_equal(
            np.asarray(L.apply_rope(x, pos, theta=0.0)), np.asarray(x))

    def test_mrope_matches_rope_for_equal_axes(self, rng):
        """When t==h==w, M-RoPE must behave like a rotation by that pos."""
        x = jax.random.normal(rng, (2, 8, 2, 32))
        pos = jnp.broadcast_to(jnp.arange(8)[None], (2, 8))
        pos3 = jnp.stack([pos, pos, pos])
        y = L.apply_mrope(x, pos3, theta=1e4)
        # norm preservation is the key invariant
        np.testing.assert_allclose(np.linalg.norm(np.asarray(x), axis=-1),
                                   np.linalg.norm(np.asarray(y), axis=-1),
                                   rtol=1e-5)


class TestFlashAttention:
    @pytest.mark.parametrize("causal,window", [(True, 0), (True, 16),
                                               (False, 0)])
    @pytest.mark.parametrize("Hq,Hkv", [(4, 4), (4, 2), (8, 1)])
    def test_matches_naive(self, rng, causal, window, Hq, Hkv):
        B, S, D = 2, 64, 16
        q, k, v = _qkv(rng, B, S, S, Hq, Hkv, D)
        pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        out = L.flash_attention_jnp(q, k, v, q_positions=pos, k_positions=pos,
                                    causal=causal, window=window, block_k=16)
        # ref uses (B, H, S, D) layout
        ref = KREF.attention_ref(q.transpose(0, 2, 1, 3),
                                 k.transpose(0, 2, 1, 3),
                                 v.transpose(0, 2, 1, 3),
                                 causal=causal, window=window)
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(ref.transpose(0, 2, 1, 3)),
                                   rtol=2e-4, atol=2e-5)

    def test_grads_match_naive(self, rng):
        B, S, Hq, Hkv, D = 1, 32, 2, 1, 8
        q, k, v = _qkv(rng, B, S, S, Hq, Hkv, D)
        pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

        def f_flash(q, k, v):
            return L.flash_attention_jnp(q, k, v, q_positions=pos,
                                         k_positions=pos, block_k=8).sum()

        def f_ref(q, k, v):
            return KREF.attention_ref(q.transpose(0, 2, 1, 3),
                                      k.transpose(0, 2, 1, 3),
                                      v.transpose(0, 2, 1, 3)).sum()

        g1 = jax.grad(f_flash, (0, 1, 2))(q, k, v)
        g2 = jax.grad(f_ref, (0, 1, 2))(q, k, v)
        for a, b in zip(g1, g2):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-3, atol=1e-4)

    def test_decode_matches_full(self, rng):
        """Decode attention at position t == row t of the full pass."""
        B, S, Hq, Hkv, D = 2, 16, 4, 2, 8
        q, k, v = _qkv(rng, B, S, S, Hq, Hkv, D)
        pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        full = L.flash_attention_jnp(q, k, v, q_positions=pos,
                                     k_positions=pos, block_k=8)
        t = S - 1
        out = L.decode_attention_jnp(
            q[:, t:t + 1], k, v, q_position=jnp.full((B,), t),
            k_positions=pos)
        np.testing.assert_allclose(np.asarray(out), np.asarray(full[:, t:t + 1]),
                                   rtol=2e-4, atol=2e-5)


class TestCrossEntropy:
    def test_uniform_is_logV(self):
        V = 64
        logits = jnp.zeros((4, 8, V))
        labels = jnp.zeros((4, 8), jnp.int32)
        np.testing.assert_allclose(float(L.cross_entropy(logits, labels)),
                                   np.log(V), rtol=1e-5)

    def test_perfect_prediction(self):
        labels = jnp.arange(8)[None]
        logits = jax.nn.one_hot(labels, 8) * 100.0
        assert float(L.cross_entropy(logits, labels)) < 1e-3


class TestParamSpecs:
    def test_init_respects_shape_dtype(self, rng):
        from repro.models.layers import ParamSpec, init_params, logical_axes
        specs = {"a": ParamSpec((4, 8), ("embed", "ffn")),
                 "b": ParamSpec((8,), ("ffn",), init="zeros")}
        p = init_params(rng, specs, jnp.bfloat16)
        assert p["a"].shape == (4, 8) and p["a"].dtype == jnp.bfloat16
        assert float(jnp.abs(p["b"]).max()) == 0.0
        assert logical_axes(specs)["a"] == ("embed", "ffn")

    def test_init_deterministic(self, rng):
        from repro.models.layers import ParamSpec, init_params
        specs = {"a": ParamSpec((4, 8), (None, None))}
        p1 = init_params(rng, specs)
        p2 = init_params(rng, specs)
        np.testing.assert_array_equal(np.asarray(p1["a"]), np.asarray(p2["a"]))
