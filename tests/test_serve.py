"""Serving-loop tests: continuous batching over the decode step."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.launch.serve import Request, ServeLoop
from repro.models import build_model
from repro.models import layers as L
from repro.models import transformer as T


@pytest.mark.parametrize("arch", ["granite-8b", "mamba2-780m"])
def test_serve_completes_all_requests(arch, rng):
    cfg = reduced(get_config(arch))
    m = build_model(cfg)
    params = m.init(rng)
    loop = ServeLoop(m, params, num_slots=2, max_len=32)
    reqs = [Request(uid=i,
                    prompt=np.arange(3 + i, dtype=np.int32) % cfg.vocab_size,
                    max_new_tokens=4)
            for i in range(5)]  # 5 requests > 2 slots → queuing + reuse
    out = loop.serve(reqs)
    assert set(out) == {0, 1, 2, 3, 4}
    for uid, toks in out.items():
        assert len(toks) == 4
        assert all(0 <= t < cfg.vocab_size for t in toks)
    # continuous batching actually batched: fewer steps than sequential sum
    sequential = sum(len(r.prompt) + r.max_new_tokens for r in reqs)
    assert loop.steps_run < sequential


def test_serve_matches_teacher_forced_argmax(rng):
    """The loop's greedy outputs == argmax of the full forward pass."""
    cfg = reduced(get_config("granite-8b"))
    m = build_model(cfg)
    params = m.init(rng)
    prompt = np.asarray([5, 17, 3], np.int32)
    loop = ServeLoop(m, params, num_slots=1, max_len=16)
    out = loop.serve([Request(uid=0, prompt=prompt, max_new_tokens=3)])[0]

    # reference: greedily extend with the full forward pass
    toks = list(prompt)
    for _ in range(3):
        b = {"tokens": jnp.asarray([toks], jnp.int32)}
        h = L.embed_apply(params["embed"], b["tokens"])
        pos = jnp.arange(len(toks))[None]
        h, _, _ = T.decoder_forward(params, h, cfg, positions=pos, block_k=8)
        h = L.rmsnorm(h, params["final_norm"], cfg.norm_eps)
        logits = L.unembed_apply(params["embed"], h, cfg.tie_embeddings)
        toks.append(int(jnp.argmax(logits[0, -1])))
    assert out == toks[len(prompt):], (out, toks[len(prompt):])


def test_eos_frees_slot_early(rng):
    cfg = reduced(get_config("granite-8b"))
    m = build_model(cfg)
    params = m.init(rng)
    loop = ServeLoop(m, params, num_slots=1, max_len=64)
    r = Request(uid=0, prompt=np.asarray([1], np.int32), max_new_tokens=50)
    # force EOS on whatever the first generated token is
    loop.serve([r], max_steps=2)
    if r.output:
        eos = r.output[0]
        loop2 = ServeLoop(m, params, num_slots=1, max_len=64)
        r2 = Request(uid=0, prompt=np.asarray([1], np.int32),
                     max_new_tokens=50, eos_id=eos)
        out = loop2.serve([r2])
        assert len(out[0]) == 1  # stopped at EOS immediately
