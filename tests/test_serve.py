"""Serving-loop tests: continuous batching over the decode step."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.launch.serve import Request, ServeLoop
from repro.models import build_model
from repro.models import layers as L
from repro.models import transformer as T


@pytest.mark.parametrize("arch", ["granite-8b", "mamba2-780m"])
def test_serve_completes_all_requests(arch, rng):
    cfg = reduced(get_config(arch))
    m = build_model(cfg)
    params = m.init(rng)
    loop = ServeLoop(m, params, num_slots=2, max_len=32)
    reqs = [Request(uid=i,
                    prompt=np.arange(3 + i, dtype=np.int32) % cfg.vocab_size,
                    max_new_tokens=4)
            for i in range(5)]  # 5 requests > 2 slots → queuing + reuse
    out = loop.serve(reqs)
    assert set(out) == {0, 1, 2, 3, 4}
    for uid, toks in out.items():
        assert len(toks) == 4
        assert all(0 <= t < cfg.vocab_size for t in toks)
    # continuous batching actually batched: fewer steps than sequential sum
    sequential = sum(len(r.prompt) + r.max_new_tokens for r in reqs)
    assert loop.steps_run < sequential


def test_serve_matches_teacher_forced_argmax(rng):
    """The loop's greedy outputs == argmax of the full forward pass."""
    cfg = reduced(get_config("granite-8b"))
    m = build_model(cfg)
    params = m.init(rng)
    prompt = np.asarray([5, 17, 3], np.int32)
    loop = ServeLoop(m, params, num_slots=1, max_len=16)
    out = loop.serve([Request(uid=0, prompt=prompt, max_new_tokens=3)])[0]

    # reference: greedily extend with the full forward pass
    toks = list(prompt)
    for _ in range(3):
        b = {"tokens": jnp.asarray([toks], jnp.int32)}
        h = L.embed_apply(params["embed"], b["tokens"])
        pos = jnp.arange(len(toks))[None]
        h, _, _ = T.decoder_forward(params, h, cfg, positions=pos, block_k=8)
        h = L.rmsnorm(h, params["final_norm"], cfg.norm_eps)
        logits = L.unembed_apply(params["embed"], h, cfg.tie_embeddings)
        toks.append(int(jnp.argmax(logits[0, -1])))
    assert out == toks[len(prompt):], (out, toks[len(prompt):])


def test_eos_frees_slot_early(rng):
    cfg = reduced(get_config("granite-8b"))
    m = build_model(cfg)
    params = m.init(rng)
    loop = ServeLoop(m, params, num_slots=1, max_len=64)
    r = Request(uid=0, prompt=np.asarray([1], np.int32), max_new_tokens=50)
    # force EOS on whatever the first generated token is
    loop.serve([r], max_steps=2)
    if r.output:
        eos = r.output[0]
        loop2 = ServeLoop(m, params, num_slots=1, max_len=64)
        r2 = Request(uid=0, prompt=np.asarray([1], np.int32),
                     max_new_tokens=50, eos_id=eos)
        out = loop2.serve([r2])
        assert len(out[0]) == 1  # stopped at EOS immediately


def test_max_tokens_frees_slot_and_queued_request_fills_it(rng):
    """A slot freed by max-tokens is reused by the next queued request on
    the immediately following step — no idle decode step in between."""
    cfg = reduced(get_config("granite-8b"))
    m = build_model(cfg)
    params = m.init(rng)
    loop = ServeLoop(m, params, num_slots=1, max_len=32)
    r1 = Request(uid=0, prompt=np.asarray([1, 2], np.int32), max_new_tokens=2)
    r2 = Request(uid=1, prompt=np.asarray([3], np.int32), max_new_tokens=2)
    loop.submit(r1)
    loop.submit(r2)
    # r1 needs len(prompt) + max_new - 1 = 3 steps (the last prompt feed
    # already emits a token)
    for _ in range(3):
        assert loop.step_once()
    assert r1.done and len(r1.output) == 2
    assert loop.slots[0].req is None          # slot freed the step it finished
    assert loop.step_once()                   # very next step decodes r2
    assert loop.slots[0].req is r2            # admitted into the freed slot
    loop.run()
    assert r2.done and len(r2.output) == 2
    assert loop.steps_run == 5                # 3 (r1) + 2 (r2), zero idle steps


def test_eos_frees_slot_and_queued_request_fills_it(rng):
    """Same same-step handoff when the slot frees via EOS instead of the
    max-tokens bound."""
    cfg = reduced(get_config("granite-8b"))
    m = build_model(cfg)
    params = m.init(rng)
    probe = ServeLoop(m, params, num_slots=1, max_len=32)
    rp = Request(uid=0, prompt=np.asarray([1], np.int32), max_new_tokens=1)
    probe.serve([rp])
    eos = rp.output[0]

    loop = ServeLoop(m, params, num_slots=1, max_len=32)
    r1 = Request(uid=0, prompt=np.asarray([1], np.int32),
                 max_new_tokens=50, eos_id=eos)
    r2 = Request(uid=1, prompt=np.asarray([2], np.int32), max_new_tokens=1)
    loop.submit(r1)
    loop.submit(r2)
    assert loop.step_once()                   # r1 hits EOS on its first token
    assert r1.done and len(r1.output) == 1
    assert loop.slots[0].req is None
    assert loop.step_once()                   # next step serves r2, no idle gap
    assert r2.done and len(r2.output) == 1    # admitted AND served that step
    assert loop.steps_run == 2


def test_prefill_by_decode_matches_one_shot_prefill(rng):
    """Feeding the prompt token-by-token through the decode step yields
    the same next-token distribution as the one-shot prefill pass."""
    cfg = reduced(get_config("granite-8b"))
    m = build_model(cfg)
    params = m.init(rng)
    prompt = np.asarray([5, 17, 3, 8], np.int32)

    loop = ServeLoop(m, params, num_slots=1, max_len=16)
    out = loop.serve([Request(uid=0, prompt=prompt, max_new_tokens=1)])[0]

    cache, logits = m.prefill_fn(params, {"tokens": jnp.asarray([prompt])},
                                 block_k=8)
    assert out[0] == int(jnp.argmax(logits[0, -1]))


def test_serve_stats_accounting(rng):
    cfg = reduced(get_config("granite-8b"))
    m = build_model(cfg)
    params = m.init(rng)
    loop = ServeLoop(m, params, num_slots=2, max_len=32)
    assert loop.stats()["slot_occupancy"] == 0.0  # no steps yet
    reqs = [Request(uid=i, prompt=np.asarray([i + 1], np.int32),
                    max_new_tokens=3) for i in range(3)]
    loop.serve(reqs)
    s = loop.stats()
    assert s["requests_completed"] == 3
    assert s["tokens_emitted"] == sum(len(r.output) for r in reqs) == 9
    assert s["queue_depth"] == 0 and s["slots_busy"] == 0
    assert s["steps_run"] == loop.steps_run > 0
    # 3 single-token prompts × 3 tokens = 9 busy slot-steps over the run
    assert 0.0 < s["slot_occupancy"] <= 1.0
    assert s["slot_occupancy"] == 9 / (s["steps_run"] * 2)
    assert s["params_version"] is None        # static params, never swapped
