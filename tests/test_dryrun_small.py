"""Integration: the production train/serve steps lower, compile AND RUN on a
small (2,2)/(2,2,2) host-device mesh in a subprocess (XLA device-count flags
must be set before jax init, so these run out-of-process)."""
import pytest

from _subproc import run_sub as _run


PRELUDE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config, reduced, ShapeConfig
from repro.launch.mesh import make_test_mesh
from repro.launch.train import make_step
from repro.models import build_model
from repro.optim import momentum, constant
from repro.data.synthetic import lm_batch_for
"""


@pytest.mark.slow
def test_layup_train_step_runs_on_mesh():
    out = _run(PRELUDE + """
mesh = make_test_mesh((2, 2), ("data", "model"))
cfg = reduced(get_config("granite-8b"))
m = build_model(cfg)
shape = ShapeConfig("t", 32, 8, "train")
step = make_step(m, mesh, shape, algo="layup", optimizer=momentum(0.9),
                 schedule=constant(0.05), shifts=(1,))
compiled = step.lower().compile()
# actually execute with real values
M = 2
params = m.init(jax.random.PRNGKey(0))
sp = jax.tree.map(lambda p: jnp.broadcast_to(p[None], (M,) + p.shape), params)
opt = momentum(0.9)
os_ = jax.vmap(opt.init)(sp)
w = jnp.full((M,), 0.5)
batch = lm_batch_for(cfg, 8, 32)
tok0 = np.asarray(sp["embed"]["tok"][0])  # copy before donation
p2, o2, w2, loss = compiled(sp, os_, w, batch, jnp.zeros((), jnp.int32),
                            jnp.zeros((), jnp.int32))
assert np.isfinite(float(loss)), loss
assert float(jnp.sum(w2)) == 1.0
# params changed from init, and with M=2 the symmetric shift-1 exchange
# brings both replicas to the same mixed value (full consensus)
diff = float(jnp.abs(p2["embed"]["tok"][0] - p2["embed"]["tok"][1]).max())
moved = float(np.abs(np.asarray(p2["embed"]["tok"][0]) - tok0).max())
print("LOSS", float(loss), "DIFF", diff, "MOVED", moved)
assert moved > 0
assert diff < 1e-5
""")
    assert "LOSS" in out


@pytest.mark.slow
def test_ddp_train_step_runs_on_mesh():
    _run(PRELUDE + """
mesh = make_test_mesh((2, 2), ("data", "model"))
cfg = reduced(get_config("stablelm-1.6b"))
m = build_model(cfg)
shape = ShapeConfig("t", 32, 8, "train")
step = make_step(m, mesh, shape, algo="ddp", optimizer=momentum(0.9),
                 schedule=constant(0.05))
compiled = step.lower().compile()
params = m.init(jax.random.PRNGKey(0))
opt = momentum(0.9)
batch = lm_batch_for(cfg, 8, 32)
p2, o2, loss = compiled(params, opt.init(params), batch,
                        jnp.zeros((), jnp.int32))
assert np.isfinite(float(loss))
print("OK", float(loss))
""")


@pytest.mark.slow
def test_serve_steps_compile_on_multipod_mesh():
    _run(PRELUDE + """
mesh = make_test_mesh((2, 2, 2), ("pod", "data", "model"))
for name in ("mixtral-8x7b", "mamba2-780m"):
    cfg = reduced(get_config(name))
    m = build_model(cfg)
    step = make_step(m, mesh, ShapeConfig("d", 64, 8, "decode"))
    step.lower().compile()
    step = make_step(m, mesh, ShapeConfig("p", 64, 8, "prefill"))
    step.lower().compile()
print("OK")
""")


@pytest.mark.slow
def test_layup_gossip_shift_switch_compiles():
    """The runtime-randomized (lax.switch) gossip variant also lowers."""
    _run(PRELUDE + """
mesh = make_test_mesh((4, 2), ("data", "model"))
cfg = reduced(get_config("granite-8b"))
m = build_model(cfg)
step = make_step(m, mesh, ShapeConfig("t", 32, 8, "train"),
                 algo="layup", shifts=(1, 2, 3))
step.lower().compile()
print("OK")
""")


@pytest.mark.slow
def test_fsdp_preset_runs_and_matches_megatron():
    """§Perf FSDP preset: same numerics as the baseline sharding."""
    _run(PRELUDE + """
import repro.models.transformer as T
from jax.sharding import PartitionSpec as P
mesh = make_test_mesh((2, 2), ("data", "model"))
cfg = reduced(get_config("granite-8b"))
m = build_model(cfg)
shape = ShapeConfig("t", 32, 8, "train")
opt = momentum(0.9)
M = 2
params = m.init(jax.random.PRNGKey(0))
sp = jax.tree.map(lambda p: jnp.broadcast_to(p[None], (M,) + p.shape), params)
os_ = jax.vmap(opt.init)(sp)
w = jnp.full((M,), 0.5)
batch = lm_batch_for(cfg, 8, 32)
outs = {}
for preset in (None, "fsdp"):
    if preset == "fsdp":
        T.ACTIVATION_PSPEC = P("model", None, None)
    try:
        step = make_step(m, mesh, shape, algo="layup", optimizer=opt,
                         schedule=constant(0.05), shifts=(1,), preset=preset)
        c = step.lower().compile()
        p2, _, _, loss = c(jax.tree.map(jnp.array, sp),
                           jax.tree.map(jnp.array, os_), jnp.array(w), batch,
                           jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32))
        outs[preset] = (jax.tree.map(np.asarray, p2), float(loss))
    finally:
        T.ACTIVATION_PSPEC = None
a, b = outs[None], outs["fsdp"]
assert abs(a[1] - b[1]) < 1e-3, (a[1], b[1])
err = max(float(np.abs(np.asarray(x, np.float32) - np.asarray(y, np.float32)).max())
          for x, y in zip(jax.tree.leaves(a[0]), jax.tree.leaves(b[0])))
print("ERR", err)
assert err < 5e-2, err
""")


@pytest.mark.slow
def test_ep_mesh_layout_compiles():
    """§Perf EP mesh (data, expert, tp) with grouped MoE dispatch."""
    _run(PRELUDE + """
import repro.models.moe as MOE
from jax.sharding import NamedSharding, PartitionSpec as P
mesh = make_test_mesh((2, 2, 2), ("data", "expert", "tp"))
cfg = reduced(get_config("mixtral-8x7b"))
m = build_model(cfg)
MOE.GROUPS = 2
MOE.GROUP_PSPEC = NamedSharding(mesh, P("expert", None, None))
MOE.EXPERT_PSPEC = NamedSharding(mesh, P("expert", None, None))
try:
    step = make_step(m, mesh, ShapeConfig("p", 32, 8, "prefill"))
    step.lower().compile()
finally:
    MOE.GROUPS = 1
    MOE.GROUP_PSPEC = MOE.EXPERT_PSPEC = None
print("OK")
""")


@pytest.mark.slow
def test_accum_steps_matches_full_batch():
    """Gradient accumulation in the prod step == full-batch step."""
    _run(PRELUDE + """
mesh = make_test_mesh((2, 2), ("data", "model"))
cfg = reduced(get_config("stablelm-1.6b"))
m = build_model(cfg)
shape = ShapeConfig("t", 32, 8, "train")
opt = momentum(0.9)
M = 2
params = m.init(jax.random.PRNGKey(0))
sp = jax.tree.map(lambda p: jnp.broadcast_to(p[None], (M,) + p.shape), params)
os_ = jax.vmap(opt.init)(sp)
w = jnp.full((M,), 0.5)
batch = lm_batch_for(cfg, 8, 32)
res = {}
for acc in (1, 4):
    step = make_step(m, mesh, shape, algo="layup", optimizer=opt,
                     schedule=constant(0.05), shifts=(1,), accum_steps=acc)
    c = step.lower().compile()
    p2, _, _, loss = c(jax.tree.map(jnp.array, sp),
                       jax.tree.map(jnp.array, os_), jnp.array(w), batch,
                       jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32))
    res[acc] = (jax.tree.map(np.asarray, p2), float(loss))
assert abs(res[1][1] - res[4][1]) < 2e-3, (res[1][1], res[4][1])
err = max(float(np.abs(np.asarray(x, np.float32) - np.asarray(y, np.float32)).max())
          for x, y in zip(jax.tree.leaves(res[1][0]), jax.tree.leaves(res[4][0])))
print("ERR", err)
assert err < 5e-2, err
""")


@pytest.mark.slow
def test_layup_sim_equals_prod_single_shift():
    """Sim backend with a fixed ring shift == prod shard_map step
    (same math, two execution paths)."""
    _run(PRELUDE + """
import functools
from repro.core.layup import LayUp
mesh = make_test_mesh((2, 2), ("data", "model"))
cfg = reduced(get_config("stablelm-1.6b"))
m = build_model(cfg)
shape = ShapeConfig("t", 16, 4, "train")
opt = momentum(0.9)
step = make_step(m, mesh, shape, algo="layup", optimizer=opt,
                 schedule=constant(0.05), shifts=(1,))
compiled = step.lower().compile()
M = 2
params = m.init(jax.random.PRNGKey(0))
sp = jax.tree.map(lambda p: jnp.broadcast_to(p[None], (M,) + p.shape), params)
os_ = jax.vmap(opt.init)(sp)
w = jnp.full((M,), 0.5)
batch = lm_batch_for(cfg, 4, 16)
p_prod, _, w_prod, _ = compiled(sp, os_, w, batch, jnp.zeros((), jnp.int32),
                                jnp.zeros((), jnp.int32))

# manual reference: per-worker grads, update, then ring-shift push-sum mix
def worker(p, b):
    g = jax.grad(lambda p: m.loss_fn(p, b)[0])(p)
    u, _ = opt.update(g, opt.init(p), p, jnp.float32(0.05))
    return jax.tree.map(lambda x, uu: x + uu.astype(x.dtype), p, u)

b0 = jax.tree.map(lambda x: x[:2], batch)
b1 = jax.tree.map(lambda x: x[2:], batch)
u0 = worker(params, b0)
u1 = worker(params, b1)
# both weights 0.5 → plain average after shift-1 exchange
mixed0 = jax.tree.map(lambda a, b: 0.5 * a + 0.5 * b, u0, u1)
err = max(float(jnp.abs(a - b).max()) for a, b in
          zip(jax.tree.leaves(mixed0),
              jax.tree.leaves(jax.tree.map(lambda x: x[0], p_prod))))
print("ERR", err)
assert err < 5e-3, err
""")
