"""Shared problem fixtures for the decoupled-lane and pipeline-engine
tests: one small MLP classification problem + sim-layout batches. The
engine-vs-monolithic parity suites in test_pipeline.py and the lane tests
in test_decoupled_lane.py must exercise the SAME problem, so it lives in
one place."""
import jax
import jax.numpy as jnp


def mlp_problem():
    def loss_fn(p, b):
        h = jnp.tanh(b["x"] @ p["l1"])
        logits = h @ p["l2"]
        ce = -jnp.mean(jax.nn.log_softmax(logits)[
            jnp.arange(logits.shape[0]), b["labels"]])
        return ce, {}

    params = {"l1": jax.random.normal(jax.random.PRNGKey(1), (16, 32)) * 0.2,
              "l2": jax.random.normal(jax.random.PRNGKey(2), (32, 10)) * 0.2}
    return loss_fn, params


def mlp_batch(t, M=1, b=8):
    return {"x": jax.random.normal(jax.random.PRNGKey(10 + t), (M, b, 16)),
            "labels": jax.random.randint(jax.random.PRNGKey(90 + t),
                                         (M, b), 0, 10)}
