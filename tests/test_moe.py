import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import moe as M


def _cfg(**kw):
    base = reduced(get_config("mixtral-8x7b"))
    return base.with_(**kw) if kw else base


def _params(rng, cfg):
    from repro.models.layers import init_params
    return init_params(rng, M.moe_specs(cfg))


class TestMoE:
    def test_matches_dense_dispatch_with_ample_capacity(self, rng):
        """With capacity ≥ tokens, scatter dispatch == dense-dispatch oracle."""
        cfg = _cfg(capacity_factor=8.0)  # no drops possible
        p = _params(rng, cfg)
        x = jax.random.normal(jax.random.fold_in(rng, 7),
                              (2, 16, cfg.d_model)) * 0.5
        y1, _ = M.moe_apply(p, x, cfg)
        y2 = M.moe_apply_dense(p, x, cfg)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   rtol=2e-3, atol=2e-4)

    def test_capacity_drops_reduce_output(self, rng):
        """With capacity 0 < c << 1 some tokens are dropped, not corrupted."""
        cfg = _cfg(capacity_factor=0.25)
        p = _params(rng, cfg)
        x = jax.random.normal(jax.random.fold_in(rng, 8),
                              (2, 16, cfg.d_model)) * 0.5
        y, _ = M.moe_apply(p, x, cfg)
        assert np.all(np.isfinite(np.asarray(y)))
        # dropped tokens produce strictly smaller magnitude than full capacity
        yf, _ = M.moe_apply(p, x, cfg.with_(capacity_factor=8.0))
        assert float(jnp.sum(jnp.abs(y))) <= float(jnp.sum(jnp.abs(yf))) + 1e-3

    def test_aux_loss_uniform_router_is_one(self, rng):
        """Balanced routing gives aux ≈ 1 (E · Σ f_e·P_e with f=P=1/E)."""
        cfg = _cfg(capacity_factor=8.0)
        p = _params(rng, cfg)
        p = dict(p)
        p["router"] = jnp.zeros_like(p["router"])  # uniform probs → balanced-ish
        x = jax.random.normal(jax.random.fold_in(rng, 9),
                              (4, 64, cfg.d_model))
        _, aux = M.moe_apply(p, x, cfg)
        # ties in top-k make f slightly lumpy; generous bounds
        assert 0.8 < float(aux) < 1.5

    def test_gates_renormalized(self, rng):
        """Outputs scale-invariant to uniform router logits offset."""
        cfg = _cfg(capacity_factor=8.0)
        p = _params(rng, cfg)
        x = jax.random.normal(jax.random.fold_in(rng, 10),
                              (1, 8, cfg.d_model))
        y1, _ = M.moe_apply(p, x, cfg)
        p2 = dict(p)
        p2["router"] = p["router"]  # same; offset applied via logits bias:
        y2, _ = M.moe_apply(p2, x, cfg)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-6)

    def test_grads_flow_to_router_and_experts(self, rng):
        cfg = _cfg(capacity_factor=4.0)
        p = _params(rng, cfg)
        x = jax.random.normal(jax.random.fold_in(rng, 11),
                              (1, 8, cfg.d_model))

        def loss(p):
            y, aux = M.moe_apply(p, x, cfg)
            return jnp.sum(y ** 2) + 0.01 * aux

        g = jax.grad(loss)(p)
        for k in ("router", "wi_gate", "wi_up", "wo"):
            assert float(jnp.abs(g[k]).max()) > 0.0, k

    def test_grouped_dispatch_matches_ungrouped(self, rng):
        """§Perf grouped expert-parallel dispatch == flat dispatch when no
        tokens are dropped (per-group capacity makes drop patterns differ
        otherwise — documented)."""
        cfg = _cfg(capacity_factor=8.0)
        p = _params(rng, cfg)
        x = jax.random.normal(jax.random.fold_in(rng, 12),
                              (2, 32, cfg.d_model)) * 0.5
        y_flat, aux_flat = M.moe_apply(p, x, cfg)
        try:
            M.GROUPS = 4
            y_grp, aux_grp = M.moe_apply(p, x, cfg)
        finally:
            M.GROUPS = 1
        np.testing.assert_allclose(np.asarray(y_grp), np.asarray(y_flat),
                                   rtol=2e-3, atol=2e-4)
        np.testing.assert_allclose(float(aux_grp), float(aux_flat),
                                   rtol=1e-3)

    def test_grouped_dispatch_grads(self, rng):
        cfg = _cfg(capacity_factor=4.0)
        p = _params(rng, cfg)
        x = jax.random.normal(jax.random.fold_in(rng, 13),
                              (1, 16, cfg.d_model))
        try:
            M.GROUPS = 4
            g = jax.grad(lambda p: jnp.sum(M.moe_apply(p, x, cfg)[0] ** 2))(p)
        finally:
            M.GROUPS = 1
        for k, v in g.items():
            assert np.all(np.isfinite(np.asarray(v, np.float32))), k

    def test_capacity_function(self):
        assert M.capacity(64, 4, 2, 1.0) == 32
        assert M.capacity(64, 4, 2, 1.25) == 40
        assert M.capacity(2, 64, 2, 1.0) == 2  # floor at k
