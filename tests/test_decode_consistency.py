"""Strong correctness: prefill+decode must reproduce the teacher-forced
forward pass — next-token logits from the incremental path match the full
pass at every position (per family)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import build_model
from repro.models import layers as L
from repro.models import transformer as T

FAMILIES = ["granite-8b", "mixtral-8x7b", "mamba2-780m", "jamba-v0.1-52b",
            "stablelm-1.6b"]


@pytest.mark.parametrize("name", FAMILIES)
def test_incremental_decode_matches_full_forward(name, rng):
    cfg = reduced(get_config(name))
    if cfg.num_experts:
        # capacity-overflow drops depend on the token-batch size, so the
        # batch and incremental paths only agree when nothing is dropped
        cfg = cfg.with_(capacity_factor=8.0)
    m = build_model(cfg)
    params = m.init(rng)
    B, S = 2, 16
    tokens = jax.random.randint(jax.random.fold_in(rng, 1), (B, S), 0,
                                cfg.vocab_size)

    # full forward logits at each position
    h = L.embed_apply(params["embed"], tokens)
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    h, _, _ = T.decoder_forward(params, h, cfg, positions=positions,
                                block_k=8)
    h = L.rmsnorm(h, params["final_norm"], cfg.norm_eps)
    full_logits = L.unembed_apply(params["embed"], h, cfg.tie_embeddings)

    # incremental: feed tokens one at a time through decode_fn
    cache = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                         m.cache_specs(B, S))
    step = jax.jit(m.decode_fn)
    for t in range(S):
        logits, cache = step(params, cache, tokens[:, t:t + 1],
                             jnp.full((B,), t, jnp.int32))
        np.testing.assert_allclose(
            np.asarray(logits[:, 0], np.float32),
            np.asarray(full_logits[:, t], np.float32),
            rtol=5e-3, atol=5e-3,
            err_msg=f"{name} diverges at position {t}")


def test_sliding_window_ring_buffer_decode(rng):
    """With window W < S the ring-buffer decode matches full SWA forward."""
    cfg = reduced(get_config("mixtral-8x7b")).with_(sliding_window=8,
                                                    num_experts=0, d_ff=128)
    m = build_model(cfg)
    params = m.init(rng)
    B, S = 1, 24
    tokens = jax.random.randint(jax.random.fold_in(rng, 2), (B, S), 0,
                                cfg.vocab_size)
    h = L.embed_apply(params["embed"], tokens)
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    h, _, _ = T.decoder_forward(params, h, cfg, positions=positions,
                                block_k=8)
    h = L.rmsnorm(h, params["final_norm"], cfg.norm_eps)
    full_logits = L.unembed_apply(params["embed"], h, cfg.tie_embeddings)

    cache = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                         m.cache_specs(B, S))
    # ring buffer capacity = window
    assert cache["sub0"]["k"].shape[2] == 8
    step = jax.jit(m.decode_fn)
    for t in range(S):
        logits, cache = step(params, cache, tokens[:, t:t + 1],
                             jnp.full((B,), t, jnp.int32))
        np.testing.assert_allclose(
            np.asarray(logits[:, 0], np.float32),
            np.asarray(full_logits[:, t], np.float32),
            rtol=5e-3, atol=5e-3, err_msg=f"pos {t}")


def test_whisper_decode_consistency(rng):
    cfg = reduced(get_config("whisper-large-v3"))
    m = build_model(cfg)
    params = m.init(rng)
    B, S = 1, 8
    from repro.models import encdec as ED
    audio = jax.random.normal(jax.random.fold_in(rng, 3),
                              (B, cfg.enc_seq, cfg.d_model)) * 0.1
    tokens = jax.random.randint(jax.random.fold_in(rng, 4), (B, S), 0,
                                cfg.vocab_size)
    enc_h = ED.encode(params, audio, cfg, block_k=8)
    full_logits = ED.decode_train(params, enc_h, tokens, cfg, block_k=8)

    # build cache: cross K/V from encoder + empty self cache
    xk = jnp.einsum("bsd,ldhk->lbshk", enc_h,
                    params["dec_blocks"]["cross"]["wk"])
    xv = jnp.einsum("bsd,ldhk->lbshk", enc_h,
                    params["dec_blocks"]["cross"]["wv"])
    self_specs = T.attn_cache_specs(cfg, B, S, 0, (cfg.num_layers,),
                                    cfg.dtype)
    cache = {"self": jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                                  self_specs),
             "cross": {"k": xk, "v": xv}}
    for t in range(S):
        logits, cache = ED.decode_step(params, cache, tokens[:, t:t + 1],
                                       jnp.full((B,), t, jnp.int32), cfg)
        np.testing.assert_allclose(
            np.asarray(logits[:, 0], np.float32),
            np.asarray(full_logits[:, t], np.float32),
            rtol=5e-3, atol=5e-3, err_msg=f"pos {t}")
