"""Per-group execution streams + one-sided signal gossip (DESIGN.md §13).

Three layers, mirroring how the subsystem can fail:

* ``TestSignalBoard`` — the one-sided protocol primitive: version-exact
  payload delivery under ``signal >= v`` waits, monotone signals, bounded
  retention.
* ``TestExecAccounting`` — the timeline arithmetic with a synthetic
  clock: ``exec_overlap_s`` is positive IFF busy spans from *different*
  streams interleave; same-stream pipelining never counts.
* ``TestStreamParity`` / ``TestStreamMechanics`` — the engine itself:
  ``streams > 1`` must be loss/staleness/param-EXACT vs the single-stream
  pipeline engine (which is itself exact vs the monolithic oracle, so the
  stream engine transitively inherits the PR-3 parity contract), plus the
  plumbing guards.
"""
import itertools
import json

import jax
import numpy as np
import pytest

from _fixtures import mlp_batch as _batch, mlp_problem as _mlp_problem
from repro.core import make_backend
from repro.launch.pipeline import StageTimeline
from repro.launch.streams import SignalBoard
from repro.optim import constant, momentum


class TestSignalBoard:
    def test_wait_returns_version_exact_payload(self):
        b = SignalBoard()
        b.put_signal("plane:g", 3, "v3")
        b.put_signal("plane:g", 4, "v4")
        # a consumer of version 3 that wakes up after 4 landed must still
        # read 3's buffer — the lagging-fwd-slice race the board exists for
        assert b.wait_until("plane:g", 3) == "v3"
        assert b.wait_until("plane:g", 4) == "v4"
        assert b.read("plane:g") == 4

    def test_signals_are_monotone(self):
        b = SignalBoard()
        b.put_signal("s", 5)
        with pytest.raises(ValueError, match="monotone"):
            b.put_signal("s", 4)

    def test_wait_timeout_raises_not_hangs(self):
        b = SignalBoard()
        with pytest.raises(TimeoutError, match="signal_wait_until"):
            b.wait_until("never", 1, timeout=0.05)

    def test_retention_window_eviction(self):
        b = SignalBoard(keep=2)
        for v in range(5):
            b.put_signal("s", v, f"v{v}")
        assert b.wait_until("s", 4) == "v4"
        assert b.wait_until("s", 3) == "v3"
        with pytest.raises(KeyError, match="evicted"):
            b.wait_until("s", 1)

    def test_reset_clears_slots(self):
        b = SignalBoard()
        b.put_signal("s", 9, "x")
        b.reset()
        assert b.read("s") is None
        b.put_signal("s", 0, "fresh")  # monotonicity restarts
        assert b.wait_until("s", 0) == "fresh"


class TestExecAccounting:
    """The per-stream overlap arithmetic, pinned with a synthetic clock —
    no threads, no jax, no timing flakes."""

    @staticmethod
    def _tl():
        clk = itertools.count()
        return StageTimeline(clock=lambda: float(next(clk)))

    def test_overlap_iff_spans_interleave_across_streams(self):
        tl = self._tl()
        # fwd busy [0, 10]; gossip busy [4, 8] — 4s of true concurrency
        tl.record_exec("fwd", 0, stream="fwd", enqueue=0.0,
                       exec_start=0.0, complete=10.0)
        tl.record_exec("gossip", 0, stream="gossip", enqueue=1.0,
                       exec_start=4.0, complete=8.0, group="l1")
        s = tl.summary()
        assert s["streams"] == 2
        assert s["exec_overlap_s"] == pytest.approx(4.0)
        assert s["stream_busy_s"] == {"fwd": pytest.approx(10.0),
                                      "gossip": pytest.approx(4.0)}

    def test_no_overlap_when_spans_disjoint(self):
        tl = self._tl()
        tl.record_exec("fwd", 0, stream="fwd", enqueue=0.0,
                       exec_start=0.0, complete=5.0)
        tl.record_exec("gossip", 0, stream="gossip", enqueue=0.0,
                       exec_start=5.0, complete=9.0)
        s = tl.summary()
        assert s["streams"] == 2
        assert s["exec_overlap_s"] == 0.0

    def test_same_stream_spans_never_count(self):
        tl = self._tl()
        # two overlapping records on ONE stream (merged busy interval):
        # pipelining inside a stream is not execution concurrency
        tl.record_exec("gossip", 0, stream="gossip", enqueue=0.0,
                       exec_start=0.0, complete=6.0, group="l1")
        tl.record_exec("gossip", 0, stream="gossip", enqueue=0.0,
                       exec_start=3.0, complete=9.0, group="l2")
        s = tl.summary()
        assert s["streams"] == 1
        assert s["exec_overlap_s"] == 0.0
        assert s["stream_busy_s"]["gossip"] == pytest.approx(9.0)

    def test_three_streams_integrate_busy_minus_one(self):
        tl = self._tl()
        # a [0,6], b [2,6], c [4,6]: ∫(k−1) = 0*2 + 1*2 + 2*2 = 6
        tl.record_exec("fwd", 0, stream="a", enqueue=0.0,
                       exec_start=0.0, complete=6.0)
        tl.record_exec("update", 0, stream="b", enqueue=0.0,
                       exec_start=2.0, complete=6.0)
        tl.record_exec("gossip", 0, stream="c", enqueue=0.0,
                       exec_start=4.0, complete=6.0)
        assert tl.summary()["exec_overlap_s"] == pytest.approx(6.0)

    def test_signal_wait_time_sums(self):
        tl = self._tl()
        tl.record_exec("fwd", 0, stream="fwd", enqueue=0.0,
                       exec_start=1.0, complete=2.0, wait_s=1.0)
        tl.record_exec("gossip", 0, stream="gossip", enqueue=0.0,
                       exec_start=2.5, complete=3.0, wait_s=2.5)
        assert tl.summary()["signal_wait_s"] == pytest.approx(3.5)

    def test_single_stream_engine_reports_streams_1(self):
        # dispatch-only events (the PipelineEngine path) must keep the
        # stream fields at their single-stream defaults
        tl = self._tl()
        ev = tl.begin("fwd", 0)
        class F:
            def is_ready(self):
                return True
        tl.commit(ev, F())
        tl.finalize()
        s = tl.summary()
        assert s["streams"] == 1
        assert s["exec_overlap_s"] == 0.0
        assert s["stream_busy_s"] == {}

    def test_dump_normalizes_stream_timestamps(self, tmp_path):
        tl = self._tl()
        tl.record_exec("fwd", 0, stream="fwd", enqueue=100.0,
                       exec_start=101.0, complete=103.0, wait_s=1.0)
        tl.record_exec("gossip", 0, stream="gossip", enqueue=100.5,
                       exec_start=102.0, complete=104.0, group="l1")
        path = tl.dump(str(tmp_path / "streams.json"))
        with open(path) as f:
            doc = json.load(f)
        ev = doc["events"][0]
        assert ev["stream"] == "fwd"
        assert ev["dispatch"] == pytest.approx(0.0)
        assert ev["enqueue"] == pytest.approx(-1.0)
        assert ev["exec_start"] == pytest.approx(0.0)
        assert doc["summary"]["streams"] == 2


class TestSummaryEdgeCases:
    """Degenerate timelines must aggregate to clean zeros — never divide
    by zero, never KeyError (ISSUE 10 hardening; the autotuner feeds
    these summaries straight into its overlap-efficiency term)."""

    @staticmethod
    def _tl():
        clk = itertools.count()
        return StageTimeline(clock=lambda: float(next(clk)))

    def test_zero_recorded_steps_full_default_summary(self):
        s = self._tl().summary()
        assert s == {"events": 0, "steps": 0, "wall_s": 0.0,
                     "overlap_events": 0, "overlap_s": 0.0,
                     "fwd_gossip_overlap_s": 0.0, "stage_s": {},
                     "streams": 1, "exec_overlap_s": 0.0,
                     "stream_busy_s": {}, "signal_wait_s": 0.0}

    def test_open_events_only_count_but_aggregate_to_zero(self):
        # a dispatch whose fence never retired: the event is counted but
        # no closed span exists — every aggregate stays at its default
        tl = self._tl()
        class Never:
            def is_ready(self):
                return False
        ev = tl.begin("fwd", 0)
        tl.commit(ev, Never())
        s = tl.summary()
        assert s["events"] == 1 and s["steps"] == 0
        assert s["wall_s"] == 0.0 and s["exec_overlap_s"] == 0.0

    def test_single_stream_single_event(self):
        tl = self._tl()
        tl.record_exec("fwd", 0, stream="fwd", enqueue=0.0,
                       exec_start=0.0, complete=3.0)
        s = tl.summary()
        assert s["streams"] == 1
        assert s["exec_overlap_s"] == 0.0
        assert s["stream_busy_s"] == {"fwd": pytest.approx(3.0)}

    def test_many_streams_never_interleaving_is_exactly_zero(self):
        # back-to-back spans across three streams sharing endpoints:
        # touching at a point is not overlap, and the sweep must not
        # accumulate rounding residue
        tl = self._tl()
        for i, name in enumerate(("a", "b", "c")):
            tl.record_exec("fwd", 0, stream=name, enqueue=0.0,
                           exec_start=float(2 * i),
                           complete=float(2 * i + 2))
        s = tl.summary()
        assert s["streams"] == 3
        assert s["exec_overlap_s"] == 0.0

    def test_zero_width_spans_no_division_by_zero(self):
        # two streams, both with instantaneous spans at the same tick:
        # wall_s == 0.0 and the sweep integral must still be exactly 0.0
        tl = self._tl()
        tl.record_exec("update", 0, stream="a", enqueue=5.0,
                       exec_start=5.0, complete=5.0)
        tl.record_exec("gossip", 0, stream="b", enqueue=5.0,
                       exec_start=5.0, complete=5.0)
        s = tl.summary()
        assert s["wall_s"] == 0.0
        assert s["exec_overlap_s"] == 0.0
        assert s["stream_busy_s"] == {"a": 0.0, "b": 0.0}
        # and the tuner's consumer of this summary stays finite on it
        from repro.launch.tuner import overlap_efficiency
        assert overlap_efficiency(s) == 0.0


def _run_backend(R, D, streams, steps=5):
    loss_fn, params = _mlp_problem()
    be = make_backend("prod", "layup", M=1, loss_fn=loss_fn,
                      optimizer=momentum(0.9), schedule=constant(0.05),
                      fb_ratio=R, update_delay=D, overlap=True,
                      streams=streams, measure_drift=True)
    st = be.init(jax.random.PRNGKey(0), params)
    hist = []
    for t in range(steps):
        st, m = be.step(st, _batch(t, 1, 4 * R), None)
        hist.append((float(m["loss"]), float(m["update_staleness"]),
                     np.asarray(m["layer_staleness"]).copy(),
                     float(m["disagreement"])))
    tree = jax.tree.map(np.asarray, be.export_params(st))
    summary = be.summary()
    if hasattr(be.engine, "close"):
        be.engine.close()
    return hist, tree, summary


class TestStreamParity:
    """streams > 1 is loss/staleness/param-EXACT vs the single-stream
    engine at the required operating points — the acceptance criterion.
    (The single-stream engine is exact vs the monolithic oracle, so the
    stream engine transitively matches the monolithic step too.)"""

    @pytest.mark.parametrize("R,D", [(1, 1), (2, 1)])
    def test_exact_vs_single_stream(self, R, D):
        base_hist, base_tree, _ = _run_backend(R, D, streams=1)
        got_hist, got_tree, summary = _run_backend(R, D, streams=3)
        for i, (a, b) in enumerate(zip(base_hist, got_hist)):
            assert a[0] == b[0], f"loss diverged at step {i}"
            assert a[1] == b[1], f"update_staleness diverged at step {i}"
            assert np.array_equal(a[2], b[2]), \
                f"layer_staleness diverged at step {i}"
            assert a[3] == b[3], f"disagreement diverged at step {i}"
        for la, lb in zip(jax.tree.leaves(base_tree),
                          jax.tree.leaves(got_tree)):
            assert np.array_equal(la, lb), "final params diverged"
        # R+2 capped: (1,1) → 3 streams; (2,1) → 3 streams
        assert summary["streams"] >= 2


class TestStreamMechanics:
    def test_streams_require_overlap(self):
        loss_fn, params = _mlp_problem()
        with pytest.raises(ValueError, match="overlap=True"):
            make_backend("prod", "layup", M=1, loss_fn=loss_fn,
                         optimizer=momentum(0.9), schedule=constant(0.05),
                         streams=2)

    def test_streams_require_flat_plane(self):
        loss_fn, params = _mlp_problem()
        with pytest.raises(ValueError, match="flat"):
            make_backend("prod", "layup", M=1, loss_fn=loss_fn,
                         optimizer=momentum(0.9), schedule=constant(0.05),
                         overlap=True, streams=2, flat=False)

    def test_timeline_records_execution_events(self):
        loss_fn, params = _mlp_problem()
        be = make_backend("prod", "layup", M=1, loss_fn=loss_fn,
                          optimizer=momentum(0.9), schedule=constant(0.05),
                          fb_ratio=2, update_delay=1, overlap=True,
                          streams=3, measure_drift=False)
        st = be.init(jax.random.PRNGKey(0), params)
        for t in range(3):
            st, _ = be.step(st, _batch(t, 1, 8), None)
        s = be.summary()  # finalizes the engine + timeline
        evs = be.timeline.events
        stages = {e["stage"] for e in evs}
        assert {"fwd", "update", "gossip", "clock"} <= stages
        streams_seen = {e["stream"] for e in evs}
        assert {"fwd", "update", "gossip"} <= streams_seen
        # one gossip (mix) event per plane group per step
        groups = {e.get("group") for e in evs if e["stage"] == "gossip"}
        assert groups == set(be.part.group_sizes)
        for e in evs:
            assert e["complete"] >= e["exec_start"] >= 0
            assert e["wait_s"] >= 0.0
        assert s["streams"] == 3
        assert s["signal_wait_s"] >= 0.0
        be.engine.close()

    def test_export_params_materializes_futures(self):
        loss_fn, params = _mlp_problem()
        be = make_backend("prod", "layup", M=1, loss_fn=loss_fn,
                          optimizer=momentum(0.9), schedule=constant(0.05),
                          overlap=True, streams=2, measure_drift=False)
        st = be.init(jax.random.PRNGKey(0), params)
        st, _ = be.step(st, _batch(0, 1, 4), None)
        tree = be.export_params(st)
        for leaf, ref in zip(jax.tree.leaves(tree), jax.tree.leaves(params)):
            assert np.asarray(leaf).shape[1:] == np.asarray(ref).shape
        be.engine.close()

    def test_reinit_resets_board_and_timeline(self):
        loss_fn, params = _mlp_problem()
        be = make_backend("prod", "layup", M=1, loss_fn=loss_fn,
                          optimizer=momentum(0.9), schedule=constant(0.05),
                          overlap=True, streams=2, measure_drift=False)
        st = be.init(jax.random.PRNGKey(0), params)
        st, m1 = be.step(st, _batch(0, 1, 4), None)
        first = float(m1["loss"])
        st = be.init(jax.random.PRNGKey(0), params)  # fresh measured run
        assert be.timeline.events == []
        st, m2 = be.step(st, _batch(0, 1, 4), None)
        assert float(m2["loss"]) == first
        be.engine.close()
