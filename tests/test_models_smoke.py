"""Per-architecture smoke tests (deliverable f): every assigned arch as a
REDUCED same-family variant runs one forward/train step and one decode step
on CPU, asserting output shapes and finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced, list_configs
from repro.data.synthetic import lm_batch_for
from repro.models import build_model

ASSIGNED = [
    "jamba-v0.1-52b", "qwen2-vl-2b", "mamba2-780m", "mixtral-8x7b",
    "granite-8b", "qwen3-moe-30b-a3b", "yi-34b", "stablelm-1.6b",
    "moonshot-v1-16b-a3b", "whisper-large-v3",
]

B, S = 2, 32


@pytest.fixture(scope="module")
def models():
    return {}


def _build(models, name):
    if name not in models:
        cfg = reduced(get_config(name))
        m = build_model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        models[name] = (cfg, m, params)
    return models[name]


@pytest.mark.parametrize("name", ASSIGNED)
def test_reduced_config_limits(name):
    cfg = reduced(get_config(name))
    assert cfg.num_layers <= 2
    assert cfg.d_model <= 512
    assert cfg.num_experts <= 4
    assert cfg.family == get_config(name).family


@pytest.mark.parametrize("name", ASSIGNED)
def test_train_step(models, name):
    cfg, m, params = _build(models, name)
    batch = lm_batch_for(cfg, B, S)
    (loss, metrics), grads = jax.value_and_grad(
        lambda p: m.loss_fn(p, batch), has_aux=True)(params)
    assert np.isfinite(float(loss))
    assert float(loss) > 0
    gsq = sum(float(jnp.sum(g.astype(jnp.float32) ** 2))
              for g in jax.tree.leaves(grads))
    assert np.isfinite(gsq) and gsq > 0


@pytest.mark.parametrize("name", ASSIGNED)
def test_decode_step(models, name):
    cfg, m, params = _build(models, name)
    cache = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                         m.cache_specs(B, S))
    logits, cache2 = m.decode_fn(params, cache,
                                 jnp.zeros((B, 1), jnp.int32),
                                 jnp.zeros((B,), jnp.int32))
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("name", ASSIGNED)
def test_one_sgd_step_reduces_loss_on_repeated_batch(models, name):
    """Overfit sanity: a few SGD steps on one batch reduce its loss."""
    cfg, m, params = _build(models, name)
    batch = lm_batch_for(cfg, B, S, seed=3)

    loss0 = float(m.loss_fn(params, batch)[0])
    p = params
    for _ in range(8):
        g = jax.grad(lambda p: m.loss_fn(p, batch)[0])(p)
        p = jax.tree.map(lambda x, gg: x - 0.1 * gg, p, g)
    loss1 = float(m.loss_fn(p, batch)[0])
    assert loss1 < loss0, (loss0, loss1)


def test_all_assigned_configs_registered():
    for name in ASSIGNED:
        cfg = get_config(name)
        assert cfg.name == name
        assert cfg.source
    assert len(ASSIGNED) == 10
    assert len({get_config(n).family for n in ASSIGNED}) == 6


def test_full_config_specs_match_assignment():
    c = get_config("jamba-v0.1-52b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads,
            c.d_ff, c.vocab_size, c.num_experts, c.experts_per_token) == \
        (32, 4096, 32, 8, 14336, 65536, 16, 2)
    c = get_config("yi-34b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff,
            c.vocab_size) == (60, 7168, 56, 8, 20480, 64000)
    c = get_config("qwen3-moe-30b-a3b")
    assert (c.num_experts, c.experts_per_token, c.moe_d_ff,
            c.vocab_size) == (128, 8, 768, 151936)
    c = get_config("mamba2-780m")
    assert (c.num_layers, c.d_model, c.ssm_state) == (48, 1536, 128)
    c = get_config("whisper-large-v3")
    assert c.enc_dec and c.enc_layers == 32 and c.num_heads == 20
    c = get_config("mixtral-8x7b")
    assert c.sliding_window == 4096 and c.num_experts == 8
    c = get_config("qwen2-vl-2b")
    assert c.mrope and c.frontend == "vision" and c.num_heads == 12
    c = get_config("stablelm-1.6b")
    assert c.num_kv_heads == 32 and c.rope_fraction == 0.25
    c = get_config("moonshot-v1-16b-a3b")
    assert c.num_experts == 64 and c.experts_per_token == 6
    c = get_config("granite-8b")
    assert (c.num_layers, c.d_model) == (36, 4096)


def test_param_counts_orders_of_magnitude():
    """Sanity: parameter counts land near the advertised sizes."""
    expect = {
        "yi-34b": 34e9, "granite-8b": 8e9, "mixtral-8x7b": 47e9,
        "mamba2-780m": 0.78e9, "stablelm-1.6b": 1.6e9,
        "qwen2-vl-2b": 1.5e9, "jamba-v0.1-52b": 52e9,
        "qwen3-moe-30b-a3b": 30e9, "moonshot-v1-16b-a3b": 16e9,
    }
    for name, n in expect.items():
        got = get_config(name).param_counts()["total"]
        assert 0.5 * n < got < 1.8 * n, (name, got, n)


def test_use_pallas_attention_path_matches_jnp():
    """models with layers.USE_PALLAS=True (kernel attention) match the
    pure-jnp flash path — loss and grads (DESIGN.md §8 selectability).

    Runs in a subprocess: mixing interpret-mode Pallas into a large jit
    program occasionally corrupts the XLA:CPU ORC-JIT state for *later*
    unrelated compiles in the same process ("Failed to materialize
    symbols"), so this test is isolated like the mesh dry-run tests."""
    import os
    import subprocess
    import sys
    import textwrap
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    code = textwrap.dedent("""
        import jax, numpy as np
        from repro.configs import get_config, reduced
        from repro.data.synthetic import lm_batch_for
        from repro.models import build_model
        from repro.models import layers as L

        cfg = reduced(get_config("granite-8b"))
        m = build_model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        batch = lm_batch_for(cfg, 1, 32, seed=5)

        def loss_and_grad():
            (l, _), g = jax.value_and_grad(
                lambda p: m.loss_fn(p, batch, block_k=16), has_aux=True)(params)
            return float(l), g

        l_jnp, g_jnp = loss_and_grad()
        L.USE_PALLAS = True
        l_pal, g_pal = loss_and_grad()
        assert abs(l_jnp - l_pal) < 1e-4, (l_jnp, l_pal)
        for a, b in zip(jax.tree.leaves(g_jnp), jax.tree.leaves(g_pal)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=1e-3, atol=1e-4)
        print("OK")
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=600, env=env)
    assert r.returncode == 0 and "OK" in r.stdout, r.stdout + r.stderr
