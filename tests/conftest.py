import os

# Tests run on the single CPU device (the dry-run subprocess tests set
# their own XLA_FLAGS). Keep x64 off and make hypothesis deterministic.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def tree_allclose(a, b, rtol=1e-4, atol=1e-4):
    import jax
    leaves_a = jax.tree.leaves(a)
    leaves_b = jax.tree.leaves(b)
    assert len(leaves_a) == len(leaves_b)
    return all(np.allclose(np.asarray(x, np.float32), np.asarray(y, np.float32),
                           rtol=rtol, atol=atol)
               for x, y in zip(leaves_a, leaves_b))
