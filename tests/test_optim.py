import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (adamw, constant, cosine, linear_decay,
                         linear_warmup_cosine, momentum, sgd)
from repro.optim.optimizers import apply_updates, clip_by_global_norm, global_norm


def _quad_problem():
    """min ||x - t||^2 with known optimum."""
    t = jnp.array([1.0, -2.0, 3.0])

    def grad(x):
        return 2 * (x - t)

    return t, grad


class TestOptimizers:
    @pytest.mark.parametrize("opt", [sgd(), momentum(0.9), adamw()])
    def test_converges_on_quadratic(self, opt):
        t, grad_fn = _quad_problem()
        x = jnp.zeros(3)
        state = opt.init(x)
        for i in range(300):
            u, state = opt.update(grad_fn(x), state, x, 0.05)
            x = apply_updates(x, u)
        np.testing.assert_allclose(np.asarray(x), np.asarray(t), atol=1e-2)

    def test_sgd_matches_manual(self):
        opt = sgd()
        x = jnp.array([1.0, 2.0])
        g = jnp.array([0.5, -0.5])
        u, _ = opt.update(g, opt.init(x), x, 0.1)
        np.testing.assert_allclose(np.asarray(u), [-0.05, 0.05], rtol=1e-6)

    def test_momentum_accumulates(self):
        opt = momentum(0.9)
        x = jnp.zeros(1)
        g = jnp.ones(1)
        s = opt.init(x)
        u1, s = opt.update(g, s, x, 1.0)
        u2, s = opt.update(g, s, x, 1.0)
        np.testing.assert_allclose(np.asarray(u1), [-1.0])
        np.testing.assert_allclose(np.asarray(u2), [-1.9])

    def test_weight_decay_pulls_to_zero(self):
        opt = sgd(weight_decay=0.1)
        x = jnp.array([10.0])
        u, _ = opt.update(jnp.zeros(1), opt.init(x), x, 0.5)
        assert float(u[0]) == pytest.approx(-0.5, rel=1e-5)

    def test_adamw_bias_correction_first_step(self):
        opt = adamw(b1=0.9, b2=0.999, eps=0.0)
        x = jnp.array([0.0])
        g = jnp.array([0.3])
        u, _ = opt.update(g, opt.init(x), x, 1.0)
        # after bias correction the first step is -lr * sign-ish step
        np.testing.assert_allclose(np.asarray(u), [-1.0], rtol=1e-4)

    def test_state_dtype(self):
        opt = momentum(0.9, state_dtype=jnp.bfloat16)
        s = opt.init({"w": jnp.zeros((2, 2), jnp.bfloat16)})
        assert s["w"].dtype == jnp.bfloat16


class TestSchedules:
    def test_cosine_endpoints(self):
        f = cosine(1.0, 100)
        assert float(f(0)) == pytest.approx(1.0)
        assert float(f(100)) == pytest.approx(0.0, abs=1e-6)

    def test_warmup(self):
        f = linear_warmup_cosine(1.0, warmup=10, t_max=110, warmup_lr=0.0)
        assert float(f(0)) == pytest.approx(0.0)
        assert float(f(5)) == pytest.approx(0.5)
        assert float(f(10)) == pytest.approx(1.0, rel=1e-3)

    def test_linear_decay(self):
        f = linear_decay(1.0, warmup=0, t_max=100)
        assert float(f(50)) == pytest.approx(0.5)
        assert float(f(100)) == pytest.approx(0.0)

    def test_constant(self):
        assert float(constant(0.3)(12345)) == pytest.approx(0.3)


class TestGradUtils:
    def test_global_norm(self):
        tree = {"a": jnp.array([3.0]), "b": jnp.array([4.0])}
        assert float(global_norm(tree)) == pytest.approx(5.0)

    def test_clip(self):
        tree = {"a": jnp.array([30.0]), "b": jnp.array([40.0])}
        clipped, n = clip_by_global_norm(tree, 5.0)
        assert float(n) == pytest.approx(50.0)
        assert float(global_norm(clipped)) == pytest.approx(5.0, rel=1e-5)
