import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import ssm as S
from repro.models.layers import init_params


def _inputs(rng, b=2, l=32, h=3, p=8, n=4):
    x = jax.random.normal(rng, (b, l, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(rng, 1), (b, l, h)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(rng, 2), (h,)) * 0.3)
    Bm = jax.random.normal(jax.random.fold_in(rng, 3), (b, l, n)) * 0.5
    Cm = jax.random.normal(jax.random.fold_in(rng, 4), (b, l, n)) * 0.5
    return x, dt, A, Bm, Cm


class TestSSD:
    @pytest.mark.parametrize("chunk", [4, 8, 16, 32])
    def test_chunked_matches_sequential(self, rng, chunk):
        x, dt, A, Bm, Cm = _inputs(rng)
        y1, s1 = S.ssd_chunked(x, dt, A, Bm, Cm, chunk=chunk)
        y2, s2 = S.ssd_reference(x, dt, A, Bm, Cm)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                                   rtol=1e-4, atol=1e-5)

    def test_init_state_continuation(self, rng):
        """Running [0:L/2) then [L/2:L) with the carried state == full run."""
        x, dt, A, Bm, Cm = _inputs(rng, l=32)
        half = 16
        y_full, s_full = S.ssd_chunked(x, dt, A, Bm, Cm, chunk=8)
        y1, s1 = S.ssd_chunked(x[:, :half], dt[:, :half], A,
                               Bm[:, :half], Cm[:, :half], chunk=8)
        y2, s2 = S.ssd_chunked(x[:, half:], dt[:, half:], A,
                               Bm[:, half:], Cm[:, half:], chunk=8,
                               init_state=s1)
        np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                                   np.asarray(y_full), rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full),
                                   rtol=1e-4, atol=1e-5)

    def test_recurrent_step_matches_chunked_tail(self, rng):
        x, dt, A, Bm, Cm = _inputs(rng, l=16)
        y_full, _ = S.ssd_chunked(x, dt, A, Bm, Cm, chunk=4)
        # state after L-1 steps, then one recurrent step
        _, s_prev = S.ssd_chunked(x[:, :-1], dt[:, :-1], A,
                                  Bm[:, :-1], Cm[:, :-1], chunk=5)
        y_t, _ = S.ssd_recurrent_step(
            s_prev.astype(jnp.float32), x[:, -1], dt[:, -1], A,
            Bm[:, -1], Cm[:, -1])
        np.testing.assert_allclose(np.asarray(y_t), np.asarray(y_full[:, -1]),
                                   rtol=1e-3, atol=1e-4)


class TestSSMBlock:
    def _block(self, rng):
        cfg = reduced(get_config("mamba2-780m"))
        params = init_params(rng, S.ssm_specs(cfg))
        x = jax.random.normal(jax.random.fold_in(rng, 5),
                              (2, 16, cfg.d_model)) * 0.1
        return cfg, params, x

    def test_forward_shapes_finite(self, rng):
        cfg, p, x = self._block(rng)
        y = S.ssm_block_apply(p, x, cfg)
        assert y.shape == x.shape
        assert np.all(np.isfinite(np.asarray(y)))

    def test_prefill_then_decode_matches_full(self, rng):
        """Block-level: prefill S-1 tokens + 1 decode step == full forward."""
        cfg, p, x = self._block(rng)
        y_full, (state, tail) = S.ssm_block_apply(p, x, cfg, return_state=True,
                                                  chunk=4)
        y_pre, (s1, t1) = S.ssm_block_apply(p, x[:, :-1], cfg,
                                            return_state=True, chunk=5)
        y_t, _ = S.ssm_block_decode(p, x[:, -1:], cfg, s1, t1)
        np.testing.assert_allclose(np.asarray(y_t), np.asarray(y_full[:, -1:]),
                                   rtol=2e-3, atol=2e-4)

    def test_grads_finite(self, rng):
        cfg, p, x = self._block(rng)
        g = jax.grad(lambda p: jnp.sum(S.ssm_block_apply(p, x, cfg) ** 2))(p)
        for k, v in g.items():
            assert np.all(np.isfinite(np.asarray(v, np.float32))), k
