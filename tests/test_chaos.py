"""Fault-tolerance chaos matrix (DESIGN.md §15): fault plans, membership,
wire guards, nonfinite skips, fail-fast streams, and the end-to-end
killed-peer runs.

The fast tests run on the single pinned CPU device; everything that needs
M > 1 host devices runs out-of-process via ``run_sub`` and is marked
``slow`` (same split as the dry-run mesh tests). The pinned invariants:

* an **empty** FaultPlan turns the membership lane on without touching
  device state — bit-exact with the fault-free lane across all three
  engines at (R, D) ∈ {(1,0), (1,1), (2,1)};
* Σw (the push-sum ``weight_sum`` metric) stays 1.0 through crash,
  death renormalization and recovery — conservation over the live set;
* a peer killed mid-run never raises ``TimeoutError`` and the run
  completes with finite loss;
* a flipped int8 payload is rejected by checksum and repaired bit-exact;
* a NaN delayed gradient is skipped (that group's params untouched) and
  counted in ``nonfinite_skips``.
"""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.chaos import (ALIVE, DEAD, SUSPECT, ChaosController, Fault,
                         FaultPlan, PeerHealth, WireGuard, buffer_checksum,
                         plane_checksum)
from repro.core.backend import make_backend
from repro.launch.streams import SignalBoard, Stream, StreamTask
from repro.optim.optimizers import sgd

from _fixtures import mlp_batch, mlp_problem
from _subproc import run_sub


# ---------------------------------------------------------------------------
# FaultPlan
# ---------------------------------------------------------------------------
class TestFaultPlan:
    def test_parse_roundtrip_deterministic(self):
        spec = "crash:peer=1,step=5;nan:step=3,peer=0,group=1;hang:step=2,seconds=0.1"
        a, b = FaultPlan.parse(spec), FaultPlan.parse(spec)
        assert a == b  # same spec -> same plan, always
        # stable step order regardless of how the spec was written
        assert [f.step for f in a.faults] == [2, 3, 5]
        assert a.at(5) == (Fault(kind="crash", step=5, peer=1),)
        assert a.at(4) == ()
        assert a.last_step == 5

    def test_recover_sugar(self):
        p = FaultPlan.parse("crash:peer=2,step=3,recover=7")
        kinds = [(f.kind, f.step, f.peer) for f in p.faults]
        assert kinds == [("crash", 3, 2), ("recover", 7, 2)]

    def test_empty_plan_is_valid_noop(self):
        p = FaultPlan.parse("")
        assert p.empty and p.at(0) == () and p.last_step == -1
        assert "no faults" in p.describe()

    @pytest.mark.parametrize("bad", [
        "explode:step=1",          # unknown kind
        "crash:peer=1",            # missing step
        "crash:peer=1,step=-2",    # negative step
        "nan:step=1,recover=3",    # recover sugar is crash-only
        "hang:step=1,seconds=99",  # hang bound
        "crash:step=1,frobs=2",    # unknown field
    ])
    def test_rejects_malformed(self, bad):
        with pytest.raises(ValueError):
            FaultPlan.parse(bad)


# ---------------------------------------------------------------------------
# PeerHealth membership machine
# ---------------------------------------------------------------------------
class TestPeerHealth:
    def test_escalation_ladder(self):
        h = PeerHealth(3, suspect_after=1, dead_after=2)
        for t in range(2):
            for p in range(3):
                h.beat(p, t)
            h.observe(t)
        assert all(h.status(p) == ALIVE for p in range(3))
        # peer 1 stops beating: 1 missed epoch -> SUSPECT, 2 -> DEAD
        h.beat(0, 2), h.beat(2, 2)
        h.observe(2)
        assert h.status(1) == SUSPECT and h.is_live(1)
        assert not h.serving_ok(1)  # suspect mixes but never serves
        h.beat(0, 3), h.beat(2, 3)
        h.observe(3)
        assert h.status(1) == DEAD and not h.is_live(1)
        assert h.peers_dead == 1
        np.testing.assert_array_equal(h.alive_mask(), [1.0, 0.0, 1.0])
        # a dead peer's beats are ignored until readmission (the live
        # peers keep beating so they don't escalate themselves)
        h.beat(0, 4), h.beat(2, 4)
        h.beat(1, 4)
        h.observe(4)
        assert h.status(1) == DEAD
        h.readmit(1, 5)
        assert h.status(1) == ALIVE and h.serving_ok(1)
        # the timeline carries every transition
        transitions = [(p, new) for _, p, _, new in h.events]
        assert transitions == [(1, SUSPECT), (1, DEAD), (1, ALIVE)]

    def test_suspect_recovers_on_beat(self):
        h = PeerHealth(2, suspect_after=1, dead_after=3)
        h.beat(0, 0), h.beat(1, 0)
        h.observe(0)
        h.beat(0, 1)
        h.observe(1)
        assert h.status(1) == SUSPECT
        h.beat(0, 2), h.beat(1, 2)  # it was a transient, not a crash
        h.observe(2)
        assert h.status(1) == ALIVE

    def test_wait_guarded_success_path(self):
        h = PeerHealth(2)
        board = SignalBoard()
        board.put_signal("x", 3, "payload")
        out = h.wait_guarded(board, "x", 3, peer=1, deadline=0.05)
        assert out == "payload" and h.status(1) == ALIVE

    def test_wait_guarded_escalates_to_dead(self):
        h = PeerHealth(2)
        board = SignalBoard()  # slot never signalled
        t0 = time.monotonic()
        out = h.wait_guarded(board, "never", 1, peer=1, epoch=7,
                             deadline=0.01, retries=2, backoff=2.0)
        assert out is None
        assert h.status(1) == DEAD
        # retries with backoff + grace wait, not one long deadline:
        # 0.01 + 0.02 + 0.04 plus scheduling slack
        assert time.monotonic() - t0 < 2.0
        assert (7, 1, SUSPECT, DEAD) in h.events

    def test_wait_guarded_late_signal_while_suspect(self):
        h = PeerHealth(2)
        board = SignalBoard()

        def late_put():
            time.sleep(0.1)
            board.put_signal("late", 1, "made-it")

        thr = threading.Thread(target=late_put)
        thr.start()
        # retry ladder 0.02 + 0.04 + 0.08 (+0.16 grace) comfortably spans
        # the 0.1 s late signal even under CI scheduling slack
        out = h.wait_guarded(board, "late", 1, peer=0,
                             deadline=0.02, retries=3)
        thr.join()
        assert out == "made-it"
        assert h.status(0) in (ALIVE, SUSPECT)  # never escalated to DEAD
        assert h.peers_dead == 0


# ---------------------------------------------------------------------------
# WireGuard: per-round plane checksum, reject-and-resend
# ---------------------------------------------------------------------------
class TestWireGuard:
    def _plane(self):
        rng = np.random.default_rng(0)
        return {"l1": jnp.asarray(rng.normal(size=(2, 16)), jnp.float32),
                "l2": jnp.asarray(rng.normal(size=(2, 8)), jnp.float32)}

    def test_checksum_detects_single_bit_flip(self):
        plane = self._plane()
        seals = plane_checksum(plane)
        damaged = np.array(np.asarray(plane["l1"]))
        damaged.view(np.uint8).reshape(-1)[0] ^= 0x01
        assert buffer_checksum(damaged) != seals["l1"]
        assert buffer_checksum(plane["l1"]) == seals["l1"]

    def test_corrupt_rejected_and_repaired_bit_exact(self):
        g = WireGuard()
        plane = self._plane()
        delivered, events = g.round_trip(plane, corrupt_group="l1")
        assert events == {"l1": "checksum-reject", "l2": "ok"}
        for name in plane:  # repair == resend of the sealed original
            np.testing.assert_array_equal(np.asarray(delivered[name]),
                                          np.asarray(plane[name]))
        c = g.counters()
        assert c["checksum_rejects"] == 1 and c["resends"] == 1
        assert c["drops_detected"] == 0

    def test_drop_detected_and_resent(self):
        g = WireGuard()
        plane = self._plane()
        delivered, events = g.round_trip(plane, drop_group="l2")
        assert events == {"l1": "ok", "l2": "drop"}
        np.testing.assert_array_equal(np.asarray(delivered["l2"]),
                                      np.asarray(plane["l2"]))
        assert g.counters()["drops_detected"] == 1

    def test_clean_round_is_pass_through(self):
        g = WireGuard()
        plane = self._plane()
        delivered, events = g.round_trip(plane)
        assert set(events.values()) == {"ok"}
        assert delivered["l1"] is plane["l1"]  # verified: same handle
        assert g.counters()["resends"] == 0


# ---------------------------------------------------------------------------
# Nonfinite-gradient guard in the update lane
# ---------------------------------------------------------------------------
class TestNonfiniteSkip:
    def test_nan_group_skipped_params_untouched(self):
        from repro.core.layerview import FlatPartition
        from repro.launch.train import backward_update_lane
        params = {"l1": jnp.ones((4, 4)), "l2": jnp.ones((4, 2))}
        part = FlatPartition(params)
        plane = part.pack(params)
        opt = sgd(0.1)
        upd = backward_update_lane(opt, lambda t: 0.1, update_delay=0)
        grads = {k: jnp.ones_like(v) * 0.5 for k, v in plane.items()}
        bad = dict(grads)
        bad_name = sorted(plane)[0]  # flat plane: leaves are 1-D buffers
        bad[bad_name] = bad[bad_name].at[0].set(jnp.nan)
        out, _, _, _, skips = upd(plane, opt.init(plane), bad, None,
                                  jnp.int32(0))
        assert float(skips) == 1.0
        # the NaN group is untouched; the clean group still stepped
        np.testing.assert_array_equal(np.asarray(out[bad_name]),
                                      np.asarray(plane[bad_name]))
        clean = [n for n in plane if n != bad_name][0]
        assert not np.allclose(np.asarray(out[clean]),
                               np.asarray(plane[clean]))

    def test_finite_grads_skip_nothing(self):
        from repro.core.layerview import FlatPartition
        from repro.launch.train import backward_update_lane
        params = {"l1": jnp.ones((4, 4))}
        part = FlatPartition(params)
        plane = part.pack(params)
        opt = sgd(0.1)
        upd = backward_update_lane(opt, lambda t: 0.1, update_delay=0)
        grads = {k: jnp.ones_like(v) for k, v in plane.items()}
        _, _, _, _, skips = upd(plane, opt.init(plane), grads, None,
                                jnp.int32(0))
        assert float(skips) == 0.0

    def test_end_to_end_nan_fault_counted_and_survived(self):
        """M=1, D=1 lane with a scheduled NaN injection: the poisoned
        group's update is skipped (counted in the step metric), the run
        stays finite, and the lane keeps training afterwards."""
        loss_fn, params = mlp_problem()
        be = make_backend("prod", "layup", M=1, loss_fn=loss_fn,
                          optimizer=sgd(0.1), schedule=lambda t: 0.1,
                          fb_ratio=1, update_delay=1, measure_drift=False,
                          faults="nan:step=3,peer=0,group=0")
        rng = jax.random.PRNGKey(0)
        state = be.init(rng, params)
        skips_seen, losses = [], []
        for t in range(8):
            state, m = be.step(state, mlp_batch(t), rng)
            losses.append(float(m["loss"]))
            skips_seen.append(float(m["nonfinite_skips"]))
        assert all(np.isfinite(losses)), losses
        assert max(skips_seen) >= 1.0, skips_seen
        s = be.summary()
        assert s["nan_injections"] == 1
        assert s["nonfinite_skips"] >= 1.0


# ---------------------------------------------------------------------------
# Fail-fast streams (the TimeoutError-stranding fix)
# ---------------------------------------------------------------------------
class TestStreamFailFast:
    def test_poison_wakes_cross_stream_waiter(self):
        """A task failure on one stream must fail waiters on OTHER streams
        immediately (board poison), not strand them in a long timeout."""
        board = SignalBoard()
        s_a = Stream("chaos-a", None,
                     on_error=lambda task, exc: board.poison(exc))
        s_b = Stream("chaos-b", None,
                     on_error=lambda task, exc: board.poison(exc))
        try:
            def boom():
                raise ValueError("injected stage failure")

            waiter = s_b.submit(StreamTask(
                "mix", 0,
                wait_fn=lambda: (board.wait_until("never", 1, timeout=600.0),),
                run_fn=lambda x: x))
            bad = s_a.submit(StreamTask("update", 0, run_fn=boom))
            t0 = time.monotonic()
            with pytest.raises(RuntimeError, match="poisoned"):
                waiter.result(timeout=30.0)
            assert time.monotonic() - t0 < 10.0  # fail-fast, not 600 s
            with pytest.raises(ValueError, match="injected stage failure"):
                bad.result(timeout=5.0)
        finally:
            s_a.close()
            s_b.close()
        assert not s_a._thread.is_alive() and not s_b._thread.is_alive()

    def test_board_reset_clears_poison(self):
        board = SignalBoard()
        board.poison(ValueError("old failure"))
        with pytest.raises(RuntimeError):
            board.wait_until("x", 1, timeout=0.01)
        board.reset()
        board.put_signal("x", 1, "fresh")
        assert board.wait_until("x", 1, timeout=0.1) == "fresh"

    def test_engine_close_drains_and_joins_after_failure(self):
        """A poisoned StreamEngine run: close() must raise the original
        failure AND leave no live stream threads behind."""
        loss_fn, params = mlp_problem()
        be = make_backend("prod", "layup", M=1, loss_fn=loss_fn,
                          optimizer=sgd(0.1), schedule=lambda t: 0.1,
                          fb_ratio=1, update_delay=1, overlap=True,
                          streams=2, measure_drift=False)
        rng = jax.random.PRNGKey(0)
        state = be.init(rng, params)
        state, _ = be.step(state, mlp_batch(0), rng)
        eng = be.engine

        def boom():
            raise RuntimeError("poisoned task")

        eng._track(eng._gossip.submit(StreamTask("aux", 1, run_fn=boom)))
        with pytest.raises(RuntimeError):
            eng.close()
        leaked = [th for th in threading.enumerate()
                  if th.name.startswith("stream:") and th.is_alive()]
        assert leaked == [], leaked


# ---------------------------------------------------------------------------
# SwapPolicy health gate (serving never trusts a suspect/dead source)
# ---------------------------------------------------------------------------
class TestSwapPolicyHealthGate:
    class _Snap:
        def __init__(self, seq, step):
            self.seq, self.step = seq, step
            self.versions = np.full((1, 2), float(step), np.float32)
            self.drift = None

    def test_unhealthy_source_rejected(self):
        from repro.serving.policy import SwapPolicy
        h = PeerHealth(2)
        h.mark_suspect(1, 0)
        pol = SwapPolicy(health=h)
        ok = pol.evaluate(self._Snap(0, 5), worker=0)
        assert ok.accepted and ok.reason == "fresh"
        bad = pol.evaluate(self._Snap(1, 6), worker=1)
        assert not bad.accepted and bad.reason == "unhealthy-source"
        assert pol.counts["unhealthy-source"] == 1
        assert pol.rejected == 1

    def test_health_gate_beats_forced_accept(self):
        from repro.serving.policy import SwapPolicy
        h = PeerHealth(2)
        h.mark_dead(1, 0)
        pol = SwapPolicy(max_interval_steps=1, health=h)
        # way past the forced-accept bound, but the source is dead:
        # freshness never outranks serving a dead worker's frozen replica
        d = pol.evaluate(self._Snap(0, 100), last_swap_step=0, worker=1)
        assert not d.accepted and d.reason == "unhealthy-source"
        # readmitted -> the same snapshot shape force-accepts again
        h.readmit(1, 1)
        d2 = pol.evaluate(self._Snap(1, 101), last_swap_step=0, worker=1)
        assert d2.accepted and d2.reason == "forced-max-interval"

    def test_no_health_view_ignores_worker(self):
        from repro.serving.policy import SwapPolicy
        pol = SwapPolicy()
        d = pol.evaluate(self._Snap(0, 1), worker=3)
        assert d.accepted


# ---------------------------------------------------------------------------
# Chaos matrix: empty plan is bit-exact, all engines, all (R, D)
# ---------------------------------------------------------------------------
class TestEmptyPlanBitExact:
    @pytest.mark.parametrize("R,D", [(1, 0), (1, 1), (2, 1)])
    @pytest.mark.parametrize("engine", ["monolithic", "overlap", "streams"])
    def test_m1_empty_plan_matches_fault_free(self, R, D, engine):
        loss_fn, params = mlp_problem()
        ekw = {"monolithic": {}, "overlap": {"overlap": True},
               "streams": {"overlap": True, "streams": 2}}[engine]
        kw = dict(loss_fn=loss_fn, optimizer=sgd(0.1),
                  schedule=lambda t: 0.1, fb_ratio=R, update_delay=D,
                  measure_drift=False, **ekw)

        def drive(be):
            rng = jax.random.PRNGKey(0)
            state = be.init(rng, params)
            out = []
            for t in range(5):
                state, m = be.step(state, mlp_batch(t), rng)
                out.append(float(m["loss"]))
            if hasattr(be.engine, "close"):
                be.engine.close()
            return out

        ref = drive(make_backend("prod", "layup", M=1, **kw))
        got = drive(make_backend("prod", "layup", M=1, faults="", **kw))
        assert got == ref  # bit-exact: membership on, nothing injected

    def test_membership_metrics_present(self):
        loss_fn, params = mlp_problem()
        be = make_backend("prod", "layup", M=1, loss_fn=loss_fn,
                          optimizer=sgd(0.1), schedule=lambda t: 0.1,
                          fb_ratio=1, update_delay=1, measure_drift=False,
                          faults="")
        rng = jax.random.PRNGKey(0)
        state = be.init(rng, params)
        state, m = be.step(state, mlp_batch(0), rng)
        assert float(m["nonfinite_skips"]) == 0.0
        assert float(m["peers_live"]) == 1.0
        s = be.summary()
        assert s["faults_injected"] == 0 and s["rounds_degraded"] == 0


# ---------------------------------------------------------------------------
# ChaosController unit behaviour (host protocol, M=1-safe pieces)
# ---------------------------------------------------------------------------
class TestChaosController:
    def test_empty_plan_never_touches_state(self):
        ctl = ChaosController("", M=2, update_delay=1)
        state = {"w": np.ones(2, np.float32) / 2}
        out_state, out_batch = ctl.before_step(state, {"x": 1}, 0)
        assert out_state is state and out_batch == {"x": 1}
        assert ctl.summary()["rounds_degraded"] == 0

    def test_wire_fault_counters_state_bit_exact(self):
        plane = {"l1": jnp.ones((2, 8)), "l2": jnp.ones((2, 4)) * 2}
        ctl = ChaosController("corrupt:step=1,group=0;drop:step=2,group=1",
                              M=2, wire="int8")
        state = {"read": dict(plane)}
        state, _ = ctl.before_step(state, None, 1)
        state, _ = ctl.before_step(state, None, 2)
        for name in plane:  # reject-and-resend repairs bit-exact
            np.testing.assert_array_equal(np.asarray(state["read"][name]),
                                          np.asarray(plane[name]))
        s = ctl.summary()
        assert s["checksum_rejects"] == 1 and s["drops_detected"] == 1
        assert s["resends"] == 2 and s["rounds_degraded"] == 2

    def test_crash_detect_latency_accounting(self):
        ctl = ChaosController("crash:peer=1,step=2", M=4, update_delay=0)
        w = np.ones(4, np.float32) / 4
        alive = np.ones(4, np.float32)
        state = {"w": jnp.asarray(w), "alive": jnp.asarray(alive)}
        for t in range(6):
            state, _ = ctl.before_step(state, None, t)
        assert ctl.health.status(1) == DEAD
        assert ctl.time_to_detect() is not None
        # the one-time renorm conserved total push-sum mass over survivors
        w_after = np.asarray(state["w"])
        assert w_after[1] == 0.0
        np.testing.assert_allclose(w_after.sum(), 1.0, rtol=1e-6)
        np.testing.assert_array_equal(np.asarray(state["alive"]),
                                      [1.0, 0.0, 1.0, 1.0])


# ---------------------------------------------------------------------------
# End-to-end multi-worker chaos (subprocess: needs M host devices)
# ---------------------------------------------------------------------------
@pytest.mark.slow
class TestKilledPeerRuns:
    def test_m4_streams_int8_crash_and_recover(self):
        """The headline acceptance run: M=4, streams=3, int8 wire, R=2,
        D=1; peer 1 crashes at step 3 and re-enters at step 9. The run
        must complete with finite loss, NO TimeoutError, Σw == 1.0 every
        round, and exactly one donor re-sync."""
        out = run_sub("""
            import os
            os.environ["XLA_FLAGS"] = (
                "--xla_force_host_platform_device_count=4")
            import numpy as np, jax, sys
            sys.path.insert(0, "tests")
            from _fixtures import mlp_problem, mlp_batch
            from repro.core.backend import make_backend
            from repro.optim.optimizers import sgd

            M = 4
            loss_fn, params = mlp_problem()
            be = make_backend("prod", "layup", M=M, loss_fn=loss_fn,
                              optimizer=sgd(0.1), schedule=lambda t: 0.1,
                              fb_ratio=2, update_delay=1, overlap=True,
                              streams=3, wire="int8", measure_drift=False,
                              faults="crash:peer=1,step=3,recover=9")
            rng = jax.random.PRNGKey(0)
            state = be.init(rng, params)
            losses, wsums = [], []
            for t in range(14):
                state, m = be.step(state, mlp_batch(t, M=M, b=8), rng)
                losses.append(float(m["loss"]))
                wsums.append(float(m["weight_sum"]))
            be.engine.close()
            s = be.summary()
            assert all(np.isfinite(losses)), losses
            assert all(abs(w - 1.0) < 1e-3 for w in wsums), wsums
            assert s["resyncs"] == 1, s
            assert s["peers_dead"] == 0, s   # recovered
            assert s["peers_live"] == 4.0, s
            assert s["rounds_degraded"] >= 1, s
            print("OK")
        """, timeout=1500)
        assert "OK" in out

    @pytest.mark.parametrize("M", [2, 4])
    def test_killed_peer_completes_finite(self, M):
        """Crash with NO recovery at M∈{2,4}: the survivors renormalize
        (Σw conserved at 1.0) and the run completes with finite loss on
        both the monolithic and the overlap engine."""
        out = run_sub(f"""
            import os
            os.environ["XLA_FLAGS"] = (
                "--xla_force_host_platform_device_count={M}")
            import numpy as np, jax, sys
            sys.path.insert(0, "tests")
            from _fixtures import mlp_problem, mlp_batch
            from repro.core.backend import make_backend
            from repro.optim.optimizers import sgd

            M = {M}
            loss_fn, params = mlp_problem()
            kw = dict(loss_fn=loss_fn, optimizer=sgd(0.1),
                      schedule=lambda t: 0.1, fb_ratio=1, update_delay=1,
                      measure_drift=False, faults="crash:peer=1,step=2")
            for ekw, name in [(dict(), "mono"), (dict(overlap=True), "ovl")]:
                be = make_backend("prod", "layup", M=M, **kw, **ekw)
                rng = jax.random.PRNGKey(0)
                state = be.init(rng, params)
                losses = []
                for t in range(8):
                    state, m = be.step(state, mlp_batch(t, M=M, b=8), rng)
                    losses.append(float(m["loss"]))
                s = be.summary()
                assert all(np.isfinite(losses)), (name, losses)
                assert s["peers_dead"] == 1, (name, s)
                assert s["peers_live"] == float(M - 1), (name, s)
                assert abs(s["weight_sum"] - 1.0) < 1e-3, (name, s)
            print("OK")
        """, timeout=1500)
        assert "OK" in out

    def test_m2_empty_plan_bit_exact_all_engines(self):
        """Membership on + nothing injected is bit-exact with the
        fault-free lane at M=2 across all three engines."""
        out = run_sub("""
            import os
            os.environ["XLA_FLAGS"] = (
                "--xla_force_host_platform_device_count=2")
            import numpy as np, jax, sys
            sys.path.insert(0, "tests")
            from _fixtures import mlp_problem, mlp_batch
            from repro.core.backend import make_backend
            from repro.optim.optimizers import sgd

            loss_fn, params = mlp_problem()
            kw = dict(loss_fn=loss_fn, optimizer=sgd(0.1),
                      schedule=lambda t: 0.1, fb_ratio=1, update_delay=1,
                      measure_drift=False)

            def drive(be):
                rng = jax.random.PRNGKey(0)
                state = be.init(rng, params)
                out = []
                for t in range(6):
                    state, m = be.step(state, mlp_batch(t, M=2, b=8), rng)
                    out.append(float(m["loss"]))
                if hasattr(be.engine, "close"):
                    be.engine.close()
                return out

            for ekw in [dict(), dict(overlap=True),
                        dict(overlap=True, streams=2)]:
                ref = drive(make_backend("prod", "layup", M=2, **kw, **ekw))
                got = drive(make_backend("prod", "layup", M=2, faults="",
                                         **kw, **ekw))
                assert ref == got, (ekw, ref, got)
            print("OK")
        """, timeout=1500)
        assert "OK" in out
