import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint


def _state(rng):
    return {
        "params": {"w": jax.random.normal(rng, (4, 4)),
                   "layers": (jnp.ones((2, 3)), jnp.zeros(5))},
        "weights": jnp.full((8,), 0.125),
        "step": jnp.asarray(17, jnp.int32),
        "opt": {"mu": jnp.ones((4, 4), jnp.bfloat16)},
    }


def test_round_trip(tmp_path, rng):
    st = _state(rng)
    save_checkpoint(str(tmp_path), 17, st)
    like = jax.tree.map(jnp.zeros_like, st)
    restored = restore_checkpoint(str(tmp_path), 17, like)
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_latest_step(tmp_path, rng):
    st = _state(rng)
    assert latest_step(str(tmp_path)) is None
    save_checkpoint(str(tmp_path), 5, st)
    save_checkpoint(str(tmp_path), 50, st)
    assert latest_step(str(tmp_path)) == 50
    restored = restore_checkpoint(str(tmp_path), None,
                                  jax.tree.map(jnp.zeros_like, st))
    assert int(restored["step"]) == 17


def test_missing_leaf_raises(tmp_path, rng):
    st = _state(rng)
    save_checkpoint(str(tmp_path), 1, st)
    bigger = dict(st, extra=jnp.zeros(3))
    with pytest.raises(KeyError):
        restore_checkpoint(str(tmp_path), 1, bigger)


def test_fill_missing_keeps_like_value(tmp_path, rng):
    """Old checkpoints resume into newer TrainState layouts: leaves absent
    from the archive keep the `like` value (e.g. the v2 versions clock)."""
    st = _state(rng)
    save_checkpoint(str(tmp_path), 1, st)
    bigger = dict(st, versions=jnp.full((8, 2), 7.0))
    restored = restore_checkpoint(str(tmp_path), 1, bigger, fill_missing=True)
    np.testing.assert_array_equal(np.asarray(restored["versions"]),
                                  np.full((8, 2), 7.0))
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(st["params"]["w"]))
