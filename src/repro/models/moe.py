"""Mixture-of-Experts layer: top-k router + capacity-based scatter dispatch.

Dispatch avoids the dense one-hot einsum blow-up (O(T·E·C·d)) by computing
per-assignment capacity slots with a cumsum over one-hot expert assignments
and scattering tokens into an (E, C, d) buffer. The expert axis is the
shardable axis for expert parallelism (logical axis "experts" → mesh
'model'); under GSPMD the scatter/gather lower to all-to-all style exchange.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import ParamSpec


def moe_specs(cfg, prefix_layers: Tuple[int, ...] = ()):
    d, E, F = cfg.d_model, cfg.num_experts, cfg.expert_d_ff()
    L = prefix_layers
    La = tuple("layers" for _ in L)
    return {
        "router": ParamSpec(L + (d, E), La + ("embed", None), scale=0.02),
        "wi_gate": ParamSpec(L + (E, d, F), La + ("experts", "embed", "ffn")),
        "wi_up": ParamSpec(L + (E, d, F), La + ("experts", "embed", "ffn")),
        "wo": ParamSpec(L + (E, F, d), La + ("experts", "ffn", "embed"),
                        init="scaled",
                        scale=0.02 / np.sqrt(max(2 * cfg.num_layers, 1))),
    }


def capacity(tokens: int, num_experts: int, k: int, factor: float) -> int:
    c = int(math.ceil(tokens * k / num_experts * factor))
    return max(c, k)  # at least k slots so tiny smoke shapes work


# --- grouped expert-parallel dispatch (§Perf) -------------------------------
# With tokens replicated over the expert-parallel axis, GSPMD lowers the
# capacity-buffer scatter as full-buffer all-reduces (measured: 5.4 GB f32
# per MoE layer on mixtral/prefill_32k). Splitting tokens into GROUPS
# sharded over that axis makes the dispatch local per group; the
# group-sharded → expert-sharded buffer transpose is then a cheap
# all-to-all. Set by the launcher (dryrun --moe-groups); 1 = off.
GROUPS = 1
GROUP_PSPEC = None   # PartitionSpec for (G, ...) group-major tensors
EXPERT_PSPEC = None  # PartitionSpec for (E, ...) expert-major tensors


def _wsc(x, spec):
    if spec is not None:
        x = jax.lax.with_sharding_constraint(x, spec)
    return x


def _dispatch_group(xt, p, cfg, C):
    """Local top-k dispatch of one token group. xt: (Tg, d).
    Returns (buf (E,C,d), combine metadata, router probs)."""
    Tg, d = xt.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    logits = (xt @ p["router"]).astype(jnp.float32)  # (Tg, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)  # (Tg, k)
    # renormalize the chosen gates (mixtral-style)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # capacity slots: rank of each assignment within its expert
    flat_e = gate_idx.reshape(-1)  # (Tg*k,)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
    ranks = jnp.cumsum(onehot, axis=0) - onehot
    slot = jnp.take_along_axis(ranks, flat_e[:, None], axis=1)[:, 0]
    keep = slot < C

    tok_idx = jnp.repeat(jnp.arange(Tg), k)
    safe_e = jnp.where(keep, flat_e, 0)
    safe_s = jnp.where(keep, slot, C - 1)
    buf = jnp.zeros((E, C, d), xt.dtype)
    contrib = jnp.where(keep[:, None], xt[tok_idx], 0)
    buf = buf.at[safe_e, safe_s].add(contrib, mode="drop")
    meta = (tok_idx, safe_e, safe_s, keep, gate_vals, gate_idx)
    return buf, meta, probs


def _combine_group(out_buf, meta, Tg, d, dtype):
    tok_idx, safe_e, safe_s, keep, gate_vals, _ = meta
    gathered = out_buf[safe_e, safe_s]  # (Tg*k, d)
    w = jnp.where(keep, gate_vals.reshape(-1), 0.0).astype(dtype)
    return jnp.zeros((Tg, d), dtype).at[tok_idx].add(gathered * w[:, None])


def _expert_ffn(p, buf):
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["wi_gate"])) * \
        jnp.einsum("ecd,edf->ecf", buf, p["wi_up"])
    return jnp.einsum("ecf,efd->ecd", h, p["wo"])  # (E, C, d)


def moe_apply(p, x, cfg, *, return_aux=True):
    """x: (B, S, d) → (B, S, d), aux load-balance loss.

    Top-k routing with per-expert capacity; overflow drops (switch-style).
    With GROUPS > 1 (expert-parallel §Perf path) tokens are split into
    groups sharded over the expert axis: dispatch is group-local and the
    buffer reshard group↔expert lowers to an all-to-all.
    """
    B, S, d = x.shape
    T = B * S
    E, k = cfg.num_experts, cfg.experts_per_token
    G = GROUPS if T % GROUPS == 0 else 1
    xt = x.reshape(T, d)

    if G == 1:
        C = capacity(T, E, k, cfg.capacity_factor)
        buf, meta, probs = _dispatch_group(xt, p, cfg, C)
        out_buf = _expert_ffn(p, buf)
        y = _combine_group(out_buf, meta, T, d, x.dtype)
        gate_idx = meta[5]
    else:
        Tg = T // G
        Cg = capacity(Tg, E, k, cfg.capacity_factor)
        xg = _wsc(xt.reshape(G, Tg, d), GROUP_PSPEC)
        bufs, metas, probs = jax.vmap(
            lambda xg_: _dispatch_group(xg_, p, cfg, Cg))(xg)
        # (G, E, Cg, d) group-sharded → (E, G·Cg, d) expert-sharded: a2a
        ebuf = _wsc(bufs.transpose(1, 0, 2, 3).reshape(E, G * Cg, d),
                    EXPERT_PSPEC)
        out = _expert_ffn(p, ebuf)
        # back: expert-sharded → group-sharded: second a2a
        og = _wsc(out.reshape(E, G, Cg, d).transpose(1, 0, 2, 3),
                  GROUP_PSPEC)
        y = jax.vmap(lambda ob, m: _combine_group(ob, m, Tg, d, x.dtype)
                     )(og, metas)
        y = y.reshape(T, d)
        probs = probs.reshape(T, E)
        gate_idx = metas[5].reshape(T, k)
    y = y.reshape(B, S, d)

    if not return_aux:
        return y, jnp.zeros((), jnp.float32)
    # Switch/Mixtral load-balance aux: E * sum_e f_e * P_e
    f = jnp.mean(jax.nn.one_hot(gate_idx, E, dtype=jnp.float32).sum(1), axis=0)
    P = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(f / k * P)
    return y, aux


def moe_apply_dense(p, x, cfg):
    """Oracle: dense dispatch (every expert sees every token). O(T·E) compute;
    only for tests on tiny shapes."""
    B, S, d = x.shape
    T = B * S
    E, k = cfg.num_experts, cfg.experts_per_token
    xt = x.reshape(T, d)
    logits = (xt @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)
    # full expert outputs: (E, T, d)
    h = jax.nn.silu(jnp.einsum("td,edf->etf", xt, p["wi_gate"])) * \
        jnp.einsum("td,edf->etf", xt, p["wi_up"])
    full = jnp.einsum("etf,efd->etd", h, p["wo"])
    mask = jax.nn.one_hot(gate_idx, E, dtype=jnp.float32)  # (T, k, E)
    w = jnp.einsum("tke,tk->te", mask, gate_vals).astype(x.dtype)  # (T, E)
    y = jnp.einsum("etd,te->td", full, w)
    return y.reshape(B, S, d)
