"""Shared neural-net building blocks (pure JAX, no flax).

Parameters are declared as ``ParamSpec`` trees (shape + logical axes +
initializer); ``init_params`` instantiates them and ``logical_axes`` extracts
the axis tree for the sharding rules in ``repro.launch.sharding``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ParamSpec:
    """Declarative parameter: shape, logical axes, initializer."""

    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"  # normal | zeros | ones | scaled
    scale: float = 0.02
    dtype: Any = None

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def init_params(rng: jax.Array, specs, dtype=jnp.float32):
    """Instantiate a ParamSpec tree into arrays (rng folded per leaf path)."""

    def make(path, spec: ParamSpec):
        key = jax.random.fold_in(rng, _path_hash(path))
        dt = spec.dtype or dtype
        if spec.init == "zeros":
            return jnp.zeros(spec.shape, dt)
        if spec.init == "ones":
            return jnp.ones(spec.shape, dt)
        scale = spec.scale
        return (jax.random.normal(key, spec.shape, jnp.float32) * scale).astype(dt)

    return jax.tree_util.tree_map_with_path(make, specs, is_leaf=is_spec)


def abstract_params(specs, dtype=jnp.float32):
    """ShapeDtypeStruct tree matching the spec tree (for dry-runs)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype or dtype), specs,
        is_leaf=is_spec)


def logical_axes(specs):
    return jax.tree.map(lambda s: s.axes, specs, is_leaf=is_spec)


def _path_hash(path) -> int:
    s = jax.tree_util.keystr(path)
    return abs(hash(s)) % (2**31)


def param_count(params) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm(x, gamma, eps=1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * gamma.astype(jnp.float32)).astype(x.dtype)


def rmsnorm_spec(d: int, axis: str = "embed") -> ParamSpec:
    return ParamSpec((d,), (axis,), init="ones")


# ---------------------------------------------------------------------------
# Rotary position embeddings (standard, partial, M-RoPE)
# ---------------------------------------------------------------------------


def _rope_freqs(head_dim: int, fraction: float, theta: float):
    rot_dim = int(head_dim * fraction)
    rot_dim -= rot_dim % 2
    inv = 1.0 / (theta ** (np.arange(0, rot_dim, 2, dtype=np.float32) / rot_dim))
    return rot_dim, jnp.asarray(inv)  # (rot_dim//2,)


def apply_rope(x, positions, *, theta=1e4, fraction=1.0):
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    head_dim = x.shape[-1]
    if theta <= 0:
        return x
    rot_dim, inv = _rope_freqs(head_dim, fraction, theta)
    if rot_dim == 0:
        return x
    ang = positions[..., :, None].astype(jnp.float32) * inv  # (..., S, rd/2)
    sin, cos = jnp.sin(ang)[..., None, :], jnp.cos(ang)[..., None, :]
    x_rot, x_pass = x[..., :rot_dim], x[..., rot_dim:]
    x1, x2 = x_rot[..., : rot_dim // 2], x_rot[..., rot_dim // 2:]
    out1 = (x1.astype(jnp.float32) * cos - x2.astype(jnp.float32) * sin)
    out2 = (x2.astype(jnp.float32) * cos + x1.astype(jnp.float32) * sin)
    return jnp.concatenate(
        [out1.astype(x.dtype), out2.astype(x.dtype), x_pass], axis=-1)


# M-RoPE (qwen2-vl): half-dim split into 3 sections fed by (t, h, w) ids.
_MROPE_FRACS = (0.25, 0.375, 0.375)


def apply_mrope(x, positions3, *, theta=1e6):
    """x: (B, S, H, D); positions3: (3, B, S) — temporal/height/width ids."""
    head_dim = x.shape[-1]
    half = head_dim // 2
    secs = [int(half * f) for f in _MROPE_FRACS]
    secs[-1] = half - secs[0] - secs[1]
    inv = 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float32) / head_dim))
    inv = jnp.asarray(inv)  # (half,)
    # build per-frequency position ids by section
    pos = jnp.concatenate(
        [jnp.broadcast_to(positions3[i][..., None], positions3[i].shape + (secs[i],))
         for i in range(3)], axis=-1)  # (B, S, half)
    ang = pos.astype(jnp.float32) * inv  # (B, S, half)
    sin, cos = jnp.sin(ang)[..., None, :], jnp.cos(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out1 = x1.astype(jnp.float32) * cos - x2.astype(jnp.float32) * sin
    out2 = x2.astype(jnp.float32) * cos + x1.astype(jnp.float32) * sin
    return jnp.concatenate([out1.astype(x.dtype), out2.astype(x.dtype)], axis=-1)


def sinusoidal_positions(seq_len: int, d_model: int) -> jnp.ndarray:
    pos = np.arange(seq_len, dtype=np.float32)[:, None]
    dim = np.arange(0, d_model, 2, dtype=np.float32)[None, :]
    ang = pos / np.power(10000.0, dim / d_model)
    out = np.zeros((seq_len, d_model), np.float32)
    out[:, 0::2] = np.sin(ang)
    out[:, 1::2] = np.cos(ang)
    return jnp.asarray(out)


# ---------------------------------------------------------------------------
# Attention (GQA; chunked online-softmax "flash" in pure jnp)
# ---------------------------------------------------------------------------

NEG_INF = -1e30

# When True, full-sequence attention dispatches to the Pallas kernels
# (flash fwd + bwd via custom_vjp) instead of the pure-jnp flash. On this
# CPU container the kernels run in interpret mode (slow — tests only); on
# TPU they are the deployment path. Set via repro.models.layers.USE_PALLAS.
USE_PALLAS = False


def attention_specs(cfg, prefix_layers: Tuple[int, ...] = ()):
    """Projection specs for one attention sub-layer (optionally stacked)."""
    d, hq, hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    L = prefix_layers
    La = tuple("layers" for _ in L)
    sc = 0.02
    out = {
        "wq": ParamSpec(L + (d, hq, hd), La + ("embed", "heads", "hd"), scale=sc),
        "wk": ParamSpec(L + (d, hkv, hd), La + ("embed", "kv", "hd"), scale=sc),
        "wv": ParamSpec(L + (d, hkv, hd), La + ("embed", "kv", "hd"), scale=sc),
        "wo": ParamSpec(L + (hq, hd, d), La + ("heads", "hd", "embed"), init="scaled",
                        scale=sc / np.sqrt(max(2 * cfg.num_layers, 1))),
    }
    if cfg.qk_norm:
        out["q_norm"] = ParamSpec(L + (hd,), La + ("hd",), init="ones")
        out["k_norm"] = ParamSpec(L + (hd,), La + ("hd",), init="ones")
    return out


def _gqa_scores(q, k):
    """q: (B, Hkv, G, Sq, D), k: (B, Hkv, Sk, D) -> (B, Hkv, G, Sq, Sk) f32."""
    return jnp.einsum("bhgqd,bhkd->bhgqk", q, k,
                      preferred_element_type=jnp.float32)


def _block_mask(kp, q_positions, causal, window):
    """kp: (B, bk); q_positions: (B, Sq) → (B,1,1,Sq,bk) bool."""
    mask = (kp[:, None, None, None, :] >= 0)
    mask = jnp.broadcast_to(
        mask, (kp.shape[0], 1, 1, q_positions.shape[1], kp.shape[1]))
    if causal:
        mask = mask & (kp[:, None, None, None, :]
                       <= q_positions[:, None, None, :, None])
    if window > 0:
        mask = mask & ((q_positions[:, None, None, :, None]
                        - kp[:, None, None, None, :]) < window)
    return mask


def _flash_fwd(qh, kb, vb, kpos, q_positions, causal, window):
    """qh: (B,Hkv,G,Sq,D) pre-scaled; kb/vb: (nblk,B,Hkv,bk,D);
    kpos: (nblk,B,bk). Returns (out_unnormalized→normalized, lse)."""
    B, Hkv, G, Sq, D = qh.shape
    acc0 = jnp.zeros((B, Hkv, G, Sq, D), jnp.float32)
    m0 = jnp.full((B, Hkv, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, Sq), jnp.float32)

    def body(carry, blk):
        acc, m, l = carry
        kblk, vblk, kp = blk
        s = _gqa_scores(qh, kblk)  # (B,Hkv,G,Sq,bk) f32
        s = jnp.where(_block_mask(kp, q_positions, causal, window), s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("bhgqk,bhkd->bhgqd", p.astype(vblk.dtype), vblk,
                        preferred_element_type=jnp.float32)
        acc_new = acc * corr[..., None] + pv
        return (acc_new, m_new, l_new), ()

    (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0), (kb, vb, kpos))
    lse = m + jnp.log(jnp.maximum(l, 1e-30))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out, lse


def flash_attention_jnp(q, k, v, *, q_positions, k_positions, causal=True,
                        window=0, block_k=1024):
    """Chunked online-softmax attention with a flash-style custom VJP:
    the backward pass RECOMPUTES per-block scores instead of saving the
    O(Sq·Sk) probability tensor (saves only out + logsumexp). This is the
    pure-jnp reference the Pallas kernel is validated against.

    q: (B, Sq, Hq, D);  k, v: (B, Sk, Hkv, D).
    positions: (B, Sq) / (B, Sk) absolute token indices (negative = invalid).
    """
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    G = Hq // Hkv
    scale = D ** -0.5
    nblk = max(Sk // block_k, 1)
    bk = Sk // nblk
    assert Sk % nblk == 0, (Sk, block_k)

    if USE_PALLAS:
        # Pallas kernels use (B, H, S, D) layout; positions must be the
        # plain arange the kernels derive from block indices
        from repro.kernels.flash_attention import flash_attention_trainable
        out = flash_attention_trainable(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), causal=causal, window=window,
            block_q=min(block_k, Sq), block_k=bk,
            interpret=jax.default_backend() != "tpu")
        return out.transpose(0, 2, 1, 3)

    def prep(q, k, v, k_positions):
        qh = (q * scale).reshape(B, Sq, Hkv, G, D).transpose(0, 2, 3, 1, 4)
        kb = (k.transpose(0, 2, 1, 3)
              .reshape(B, Hkv, nblk, bk, D).transpose(2, 0, 1, 3, 4))
        vb = (v.transpose(0, 2, 1, 3)
              .reshape(B, Hkv, nblk, bk, D).transpose(2, 0, 1, 3, 4))
        kpos = k_positions.reshape(B, nblk, bk).transpose(1, 0, 2)
        return qh, kb, vb, kpos

    @jax.custom_vjp
    def run(q, k, v, q_pos, k_pos):
        qh, kb, vb, kpos = prep(q, k, v, k_pos)
        out, _ = _flash_fwd(qh, kb, vb, kpos, q_pos, causal, window)
        return out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, Hq, D).astype(q.dtype)

    def run_fwd(q, k, v, q_pos, k_pos):
        qh, kb, vb, kpos = prep(q, k, v, k_pos)
        out, lse = _flash_fwd(qh, kb, vb, kpos, q_pos, causal, window)
        o = out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, Hq, D).astype(q.dtype)
        return o, (q, k, v, q_pos, k_pos, out, lse)

    def run_bwd(res, do):
        q, k, v, q_pos, k_pos, out, lse = res
        qh, kb, vb, kpos = prep(q, k, v, k_pos)
        doh = (do.reshape(B, Sq, Hkv, G, D).transpose(0, 2, 3, 1, 4)
               .astype(jnp.float32))
        delta = jnp.sum(doh * out, axis=-1)  # (B,Hkv,G,Sq)

        dq0 = jnp.zeros((B, Hkv, G, Sq, D), jnp.float32)

        def body(dq_acc, blk):
            kblk, vblk, kp = blk
            s = _gqa_scores(qh, kblk)
            s = jnp.where(_block_mask(kp, q_pos, causal, window), s, NEG_INF)
            p = jnp.exp(s - lse[..., None])  # (B,Hkv,G,Sq,bk)
            dv = jnp.einsum("bhgqk,bhgqd->bhkd", p, doh,
                            preferred_element_type=jnp.float32)
            dp = jnp.einsum("bhgqd,bhkd->bhgqk", doh,
                            vblk.astype(jnp.float32),
                            preferred_element_type=jnp.float32)
            ds = p * (dp - delta[..., None])
            dq_acc = dq_acc + jnp.einsum(
                "bhgqk,bhkd->bhgqd", ds, kblk.astype(jnp.float32),
                preferred_element_type=jnp.float32)
            dk = jnp.einsum("bhgqk,bhgqd->bhkd", ds,
                            qh.astype(jnp.float32),
                            preferred_element_type=jnp.float32)
            return dq_acc, (dk, dv)

        dq_acc, (dkb, dvb) = jax.lax.scan(body, dq0, (kb, vb, kpos))
        dq = (dq_acc * scale).transpose(0, 3, 1, 2, 4).reshape(B, Sq, Hq, D)
        dk = (dkb.transpose(1, 0, 3, 2, 4).reshape(B, Sk, Hkv, D))
        dv = (dvb.transpose(1, 0, 3, 2, 4).reshape(B, Sk, Hkv, D))
        f0 = lambda x: np.zeros(x.shape, jax.dtypes.float0)
        return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype),
                f0(q_pos), f0(k_pos))

    run.defvjp(run_fwd, run_bwd)
    return run(q, k, v, q_positions, k_positions)


def decode_attention_jnp(q, k_cache, v_cache, *, q_position, k_positions,
                         window=0, causal=True):
    """Single-token attention over a (possibly ring-buffered) cache.

    q: (B, 1, Hq, D); caches: (B, Sc, Hkv, D); q_position: (B,);
    k_positions: (B, Sc) absolute positions per slot (negative = empty).
    """
    B, _, Hq, D = q.shape
    _, Sc, Hkv, _ = k_cache.shape
    G = Hq // Hkv
    scale = D ** -0.5
    qh = (q * scale).reshape(B, Hkv, G, D)
    s = jnp.einsum("bhgd,bshd->bhgs", qh, k_cache,
                   preferred_element_type=jnp.float32)
    mask = k_positions >= 0
    if causal:
        mask &= k_positions <= q_position[:, None]
    if window > 0:
        mask &= (q_position[:, None] - k_positions) < window
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, Hq, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# MLP (SwiGLU)
# ---------------------------------------------------------------------------


def mlp_specs(cfg, d_ff: int, prefix_layers: Tuple[int, ...] = ()):
    d = cfg.d_model
    L = prefix_layers
    La = tuple("layers" for _ in L)
    return {
        "wi_gate": ParamSpec(L + (d, d_ff), La + ("embed", "ffn")),
        "wi_up": ParamSpec(L + (d, d_ff), La + ("embed", "ffn")),
        "wo": ParamSpec(L + (d_ff, d), La + ("ffn", "embed"), init="scaled",
                        scale=0.02 / np.sqrt(max(2 * cfg.num_layers, 1))),
    }


def mlp_apply(p, x):
    h = jax.nn.silu(x @ p["wi_gate"]) * (x @ p["wi_up"])
    return h @ p["wo"]


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------


def embed_specs(cfg):
    out = {"tok": ParamSpec((cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
                            scale=0.02)}
    if not cfg.tie_embeddings:
        out["unembed"] = ParamSpec((cfg.d_model, cfg.vocab_size),
                                   ("embed", "vocab"), scale=0.02)
    return out


def embed_apply(p, tokens):
    return jnp.take(p["tok"], tokens, axis=0)


def unembed_apply(p, h, tie: bool):
    if tie:
        return jnp.einsum("...d,vd->...v", h, p["tok"],
                          preferred_element_type=jnp.float32)
    return jnp.einsum("...d,dv->...v", h, p["unembed"],
                      preferred_element_type=jnp.float32)


def cross_entropy(logits, labels):
    """logits: (..., V) f32; labels: (...) int32. Mean over all positions."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)
