"""STUB modality frontends (explicit carve-out, see DESIGN.md §6).

The brief specifies that for [audio] and [vlm] architectures the modality
frontend (mel-spectrogram + conv codec; ViT/SigLIP + projector) is a stub:
``input_specs()`` supplies precomputed frame/patch embeddings of the right
shape and the language/decoder transformer consumes them. These helpers
produce *deterministic synthetic* embeddings for smoke tests and examples so
end-to-end drivers run without a real codec.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def synth_patch_embeddings(rng, batch, seq, d_model, dtype=jnp.float32):
    """Stand-in for ViT patch embeddings mixed with text embeddings."""
    return jax.random.normal(rng, (batch, seq, d_model), dtype) * 0.02


def synth_mrope_positions(batch, seq, *, image_span=None):
    """3-axis (t/h/w) M-RoPE ids. Text tokens advance all axes together;
    an optional image span advances h/w over a fake grid."""
    t = jnp.broadcast_to(jnp.arange(seq)[None], (batch, seq))
    h = t
    w = t
    if image_span is not None:
        s, e, grid = image_span  # tokens [s, e) form a grid x grid image
        idx = jnp.arange(seq)
        in_img = (idx >= s) & (idx < e)
        rel = jnp.clip(idx - s, 0, grid * grid - 1)
        h = jnp.where(in_img[None], s + rel[None] // grid, h)
        w = jnp.where(in_img[None], s + rel[None] % grid, w)
        t = jnp.where(in_img[None], s, t)
    return jnp.stack([t, h, w], axis=0).astype(jnp.int32)


def synth_audio_frames(rng, batch, enc_seq, d_model, dtype=jnp.float32):
    """Stand-in for whisper's mel+conv frontend output (B, 1500, d)."""
    return jax.random.normal(rng, (batch, enc_seq, d_model), dtype) * 0.02
