"""Mamba2-style SSD (state-space duality) block — pure JAX.

Chunked "dual form" for train/prefill (matmul-heavy → MXU-friendly on TPU),
exact recurrence for single-token decode (O(1) state). The chunked form is
also the reference for the Pallas `ssd_scan` kernel.

Layout conventions:
  x_ssm : (B, S, H, P)   heads H = d_inner / head_dim P
  dt    : (B, S, H)      post-softplus step sizes
  A     : (H,)           negative decay rates (-exp(A_log))
  Bm/Cm : (B, S, N)      shared across heads (ngroups=1), N = ssm_state
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import ParamSpec, rmsnorm


def ssm_specs(cfg, prefix_layers: Tuple[int, ...] = ()):
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    w = cfg.ssm_conv
    L = prefix_layers
    La = tuple("layers" for _ in L)
    conv_dim = di + 2 * n
    return {
        "in_proj_z": ParamSpec(L + (d, di), La + ("embed", "inner")),
        "in_proj_x": ParamSpec(L + (d, di), La + ("embed", "inner")),
        "in_proj_B": ParamSpec(L + (d, n), La + ("embed", None)),
        "in_proj_C": ParamSpec(L + (d, n), La + ("embed", None)),
        "in_proj_dt": ParamSpec(L + (d, h), La + ("embed", "inner")),
        "dt_bias": ParamSpec(L + (h,), La + ("inner",), init="zeros"),
        "conv_w": ParamSpec(L + (w, conv_dim), La + (None, "inner"),
                            scale=1.0 / np.sqrt(w)),
        "conv_b": ParamSpec(L + (conv_dim,), La + ("inner",), init="zeros"),
        "A_log": ParamSpec(L + (h,), La + ("inner",), init="zeros"),
        "D": ParamSpec(L + (h,), La + ("inner",), init="ones"),
        "gate_norm": ParamSpec(L + (di,), La + ("inner",), init="ones"),
        "out_proj": ParamSpec(L + (di, d), La + ("inner", "embed"),
                              init="scaled",
                              scale=0.02 / np.sqrt(max(2 * cfg.num_layers, 1))),
    }


# ---------------------------------------------------------------------------
# chunked SSD (train / prefill)
# ---------------------------------------------------------------------------


def ssd_chunked(x, dt, A, Bm, Cm, *, chunk=128, init_state=None):
    """Returns (y, final_state). final_state: (B, H, N, P)."""
    b, l, h, p = x.shape
    n = Bm.shape[-1]
    chunk = min(chunk, l)
    assert l % chunk == 0, (l, chunk)
    nc = l // chunk

    xs = x.reshape(b, nc, chunk, h, p)
    dts = dt.reshape(b, nc, chunk, h)
    Bs = Bm.reshape(b, nc, chunk, n)
    Cs = Cm.reshape(b, nc, chunk, n)

    dA = dts * A  # (b, nc, q, h) — negative
    cum = jnp.cumsum(dA, axis=2)  # inclusive within-chunk cumsum

    # ---- intra-chunk (dual / attention-like form) --------------------------
    # decay from step j to step i (i >= j): exp(cum_i - cum_j)
    Lmat = jnp.exp(cum[:, :, :, None, :] - cum[:, :, None, :, :])  # (b,nc,i,j,h)
    Lmat = jnp.where(
        (jnp.arange(chunk)[:, None] >= jnp.arange(chunk)[None, :])[None, None, :, :, None],
        Lmat, 0.0)
    CB = jnp.einsum("bcin,bcjn->bcij", Cs, Bs,
                    preferred_element_type=jnp.float32)  # (b,nc,i,j)
    W = CB[..., None] * Lmat * dts[:, :, None, :, :]  # (b,nc,i,j,h)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", W.astype(x.dtype), xs,
                         preferred_element_type=jnp.float32)

    # ---- chunk states -------------------------------------------------------
    last = cum[:, :, -1:, :]  # (b,nc,1,h)
    decay_to_end = jnp.exp(last - cum)  # (b,nc,q,h)
    # S[b,c,h,n,p] = sum_j decay_j * dt_j * B_j ⊗ x_j
    wts = (decay_to_end * dts).astype(x.dtype)
    S = jnp.einsum("bcqh,bcqn,bcqhp->bchnp", wts, Bs, xs,
                   preferred_element_type=jnp.float32)

    # ---- inter-chunk recurrence over chunk states ---------------------------
    chunk_decay = jnp.exp(last[:, :, 0, :])  # (b,nc,h) total decay per chunk
    h0 = (jnp.zeros((b, h, n, p), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def body(carry, inp):
        S_c, dec = inp  # (b,h,n,p), (b,h)
        new = carry * dec[:, :, None, None] + S_c
        return new, carry  # emit state *entering* this chunk

    S_sw = S.transpose(1, 0, 2, 3, 4)
    dec_sw = chunk_decay.transpose(1, 0, 2)
    final, entering = jax.lax.scan(body, h0, (S_sw, dec_sw))
    entering = entering.transpose(1, 0, 2, 3, 4)  # (b,nc,h,n,p)

    # ---- inter-chunk contribution -------------------------------------------
    decay_from_start = jnp.exp(cum)  # (b,nc,q,h) decay from chunk start to i
    y_inter = jnp.einsum("bcqn,bcqh,bchnp->bcqhp",
                         Cs, decay_from_start.astype(x.dtype),
                         entering.astype(x.dtype),
                         preferred_element_type=jnp.float32)

    y = (y_intra + y_inter).reshape(b, l, h, p).astype(x.dtype)
    return y, final.astype(x.dtype)


def ssd_recurrent_step(state, x_t, dt_t, A, B_t, C_t):
    """One decode step.  state: (B,H,N,P); x_t: (B,H,P); dt_t: (B,H);
    B_t/C_t: (B,N). Returns (y_t, new_state)."""
    dA = jnp.exp(dt_t * A)  # (B,H)
    upd = jnp.einsum("bn,bhp->bhnp", B_t, (dt_t[..., None] * x_t))
    new = state * dA[:, :, None, None] + upd.astype(state.dtype)
    y = jnp.einsum("bn,bhnp->bhp", C_t, new)
    return y.astype(x_t.dtype), new


# ---------------------------------------------------------------------------
# full block
# ---------------------------------------------------------------------------


def _conv_causal(xBC, w, b, tail=None):
    """Depthwise causal conv, width K. xBC: (B, S, C); w: (K, C).
    tail: (B, K-1, C) previous inputs (decode/prefill chaining)."""
    K = w.shape[0]
    if tail is None:
        tail = jnp.zeros(xBC.shape[:1] + (K - 1,) + xBC.shape[2:], xBC.dtype)
    full = jnp.concatenate([tail, xBC], axis=1)  # (B, S+K-1, C)
    out = sum(full[:, i:i + xBC.shape[1]] * w[i] for i in range(K))
    new_tail = full[:, -(K - 1):] if K > 1 else tail
    return out + b, new_tail


def ssm_block_apply(p, x, cfg, *, init_state=None, conv_tail=None,
                    return_state=False, chunk=128):
    """x: (B, S, d_model) → (B, S, d_model) [+ (state, conv_tail)]."""
    B_, S, d = x.shape
    di, n, h, pd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim

    z = x @ p["in_proj_z"]
    xBC = jnp.concatenate(
        [x @ p["in_proj_x"], x @ p["in_proj_B"], x @ p["in_proj_C"]], axis=-1)
    dt_raw = x @ p["in_proj_dt"] + p["dt_bias"]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32))

    xBC, new_tail = _conv_causal(xBC, p["conv_w"], p["conv_b"], conv_tail)
    xBC = jax.nn.silu(xBC)
    x_ssm = xBC[..., :di].reshape(B_, S, h, pd)
    Bm = xBC[..., di:di + n]
    Cm = xBC[..., di + n:]

    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, state = ssd_chunked(x_ssm, dt, A, Bm, Cm, chunk=chunk,
                           init_state=init_state)
    y = y + p["D"][None, None, :, None] * x_ssm
    y = y.reshape(B_, S, di)
    y = rmsnorm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    out = y @ p["out_proj"]
    if return_state:
        return out, (state, new_tail)
    return out


def ssm_block_decode(p, x, cfg, state, conv_tail):
    """Single-token decode. x: (B, 1, d). Returns (out, (state, tail))."""
    B_, _, d = x.shape
    di, n, h, pd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    z = x @ p["in_proj_z"]
    xBC = jnp.concatenate(
        [x @ p["in_proj_x"], x @ p["in_proj_B"], x @ p["in_proj_C"]], axis=-1)
    dt_raw = x @ p["in_proj_dt"] + p["dt_bias"]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32))[:, 0]  # (B, H)

    xBC, new_tail = _conv_causal(xBC, p["conv_w"], p["conv_b"], conv_tail)
    xBC = jax.nn.silu(xBC)[:, 0]  # (B, conv_dim)
    x_t = xBC[..., :di].reshape(B_, h, pd)
    B_t = xBC[..., di:di + n]
    C_t = xBC[..., di + n:]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, new_state = ssd_recurrent_step(state, x_t, dt, A, B_t, C_t)
    y = y + p["D"][None, :, None] * x_t
    y = y.reshape(B_, 1, di)
    y = rmsnorm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    return y @ p["out_proj"], (new_state, new_tail)


def ssd_reference(x, dt, A, Bm, Cm, init_state=None):
    """Oracle: step-by-step recurrence (slow, exact)."""
    b, l, h, p = x.shape
    n = Bm.shape[-1]
    state = (jnp.zeros((b, h, n, p), jnp.float32) if init_state is None
             else init_state.astype(jnp.float32))
    ys = []
    for t in range(l):
        y, state = ssd_recurrent_step(state, x[:, t], dt[:, t], A,
                                      Bm[:, t], Cm[:, t])
        ys.append(y)
    return jnp.stack(ys, axis=1).astype(x.dtype), state.astype(x.dtype)
