"""Decoder-only transformer assembly: dense / MoE / SSM / hybrid / VLM.

Layers are scanned (`jax.lax.scan` over stacked parameter leaves) with remat
on the block body so 60-layer configs keep the HLO small and compile fast.
The hybrid (jamba) family scans over *super-blocks* of ``attn_layer_period``
sub-layers so the 1:7 mamba:attention interleave and the every-2nd-layer MoE
pattern stay homogeneous across scan steps.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S
from repro.models.layers import ParamSpec

REMAT_POLICY = jax.checkpoint_policies.dots_with_no_batch_dims_saveable

# When True, layer scans are fully unrolled. Used by the dry-run's small
# (1- and 2-superblock) cost compiles: XLA cost_analysis counts a while-loop
# body once regardless of trip count, so unrolled lowerings give the true
# per-layer cost for the two-point fit (see launch/dryrun.py).
UNROLL_SCANS = False


def _scan(body, init, xs):
    return jax.lax.scan(body, init, xs, unroll=True if UNROLL_SCANS else 1)


# Optional PartitionSpec for the (B_local, S, d_model) hidden states. Set by
# the launcher for FSDP-style sharding (batch over the model axis → GSPMD
# gathers weights instead of all-reducing activations). None = let GSPMD
# propagate from the parameter shardings (baseline Megatron-TP behavior).
ACTIVATION_PSPEC = None


def constrain_h(h):
    if ACTIVATION_PSPEC is not None:
        try:
            h = jax.lax.with_sharding_constraint(h, ACTIVATION_PSPEC)
        except RuntimeError as e:
            # Raw-PartitionSpec constraints need a mesh context, which the
            # jax 0.4.x fully-manual shard_map body does not provide. The
            # constraint is a no-op under that fallback anyway (the body
            # sees model-axis-replicated shards, DESIGN.md §2), so skip it
            # rather than fail the trace — but only that specific failure.
            if "non-empty mesh" not in str(e):
                raise
    return h


def remat_block(f):
    """Manual checkpointing with explicit residual + cotangent dtypes.

    ``jax.checkpoint`` + scan stacks f32 *copies* of the saved carries and
    emits f32 per-layer parameter cotangents (12.9 GB extra on
    stablelm-1.6b/train_4k). This wrapper pins residuals to exactly the
    block inputs and casts cotangents back to the input dtypes inside the
    loop, so the stacked buffers stay bf16.

    ``f(h, p, dc, ic)``: ``dc`` = differentiable consts (e.g. encoder
    states), ``ic`` = integer consts (positions — cotangent float0).
    Consts must be passed explicitly (custom_vjp cannot close over tracers).
    """

    @jax.custom_vjp
    def wrapped(h, p, dc, ic):
        return f(h, p, dc, ic)

    def fwd(h, p, dc, ic):
        return f(h, p, dc, ic), (h, p, dc, ic)

    def bwd(res, ct):
        h, p, dc, ic = res
        _, vjp = jax.vjp(lambda h_, p_, dc_: f(h_, p_, dc_, ic), h, p, dc)
        dh, dp, ddc = vjp(ct)
        cast = lambda t, like: jax.tree.map(
            lambda x, y: x.astype(y.dtype), t, like)
        ic_zeros = jax.tree.map(
            lambda x: np.zeros(x.shape, jax.dtypes.float0), ic)
        return cast(dh, h), cast(dp, p), cast(ddc, dc), ic_zeros

    wrapped.defvjp(fwd, bwd)
    return wrapped


# ---------------------------------------------------------------------------
# attention sub-layer
# ---------------------------------------------------------------------------


def attn_sublayer_specs(cfg, prefix):
    d = cfg.d_model
    La = tuple("layers" for _ in prefix)
    out = {"norm": ParamSpec(prefix + (d,), La + ("embed",), init="ones")}
    out.update(L.attention_specs(cfg, prefix))
    return out


def _project_qkv(p, x, cfg):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qk_norm:
        q = L.rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = L.rmsnorm(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


def _rope_qk(q, k, cfg, positions, mrope_pos):
    if cfg.mrope and mrope_pos is not None:
        return (L.apply_mrope(q, mrope_pos, theta=cfg.rope_theta),
                L.apply_mrope(k, mrope_pos, theta=cfg.rope_theta))
    return (L.apply_rope(q, positions, theta=cfg.rope_theta,
                         fraction=cfg.rope_fraction),
            L.apply_rope(k, positions, theta=cfg.rope_theta,
                         fraction=cfg.rope_fraction))


def attn_sublayer(p, h, cfg, *, positions, mrope_pos=None, window=0,
                  causal=True, block_k=1024):
    """Full-sequence attention (train / prefill). Returns (h', (k, v))."""
    x = L.rmsnorm(h, p["norm"], cfg.norm_eps)
    q, k, v = _project_qkv(p, x, cfg)
    q, k = _rope_qk(q, k, cfg, positions, mrope_pos)
    out = L.flash_attention_jnp(q, k, v, q_positions=positions,
                                k_positions=positions, causal=causal,
                                window=window, block_k=block_k)
    o = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return h + o, (k, v)


def attn_sublayer_decode(p, h, cfg, cache, *, position, window=0):
    """One-token attention against the KV cache (possibly ring-buffered).

    cache: {"k": (B, Sc, Hkv, hd), "v": ...}; position: (B,).
    """
    B = h.shape[0]
    Sc = cache["k"].shape[1]
    x = L.rmsnorm(h, p["norm"], cfg.norm_eps)
    q, k, v = _project_qkv(p, x, cfg)
    pos_b = position[:, None]  # (B,1)
    if cfg.mrope:
        mp = jnp.broadcast_to(position[None, :, None], (3, B, 1))
        q, k = _rope_qk(q, k, cfg, pos_b, mp)
    else:
        q, k = _rope_qk(q, k, cfg, pos_b, None)
    slot = jnp.where(window > 0, position % Sc, jnp.minimum(position, Sc - 1))
    kc = jax.vmap(lambda c, s, u: jax.lax.dynamic_update_slice(c, u, (s, 0, 0))
                  )(cache["k"], slot, k)
    vc = jax.vmap(lambda c, s, u: jax.lax.dynamic_update_slice(c, u, (s, 0, 0))
                  )(cache["v"], slot, v)
    if window > 0:
        # ring buffer: slot i holds the largest pos' <= pos with pos' ≡ i (mod Sc)
        idx = jnp.arange(Sc)[None, :]
        k_positions = position[:, None] - ((position[:, None] - idx) % Sc)
    else:
        k_positions = jnp.broadcast_to(jnp.arange(Sc)[None, :], (B, Sc))
    out = L.decode_attention_jnp(q, kc, vc, q_position=position,
                                 k_positions=k_positions, window=window)
    o = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return h + o, {"k": kc, "v": vc}


def attn_cache_specs(cfg, B, seq_len, window, prefix=(), dtype=None):
    Sc = min(seq_len, window) if window > 0 else seq_len
    dt = dtype or cfg.dtype
    sh = prefix + (B, Sc, cfg.num_kv_heads, cfg.head_dim)
    return {"k": jax.ShapeDtypeStruct(sh, dt), "v": jax.ShapeDtypeStruct(sh, dt)}


# ---------------------------------------------------------------------------
# mlp / moe sub-layers
# ---------------------------------------------------------------------------


def mlp_sublayer_specs(cfg, prefix, *, use_moe):
    d = cfg.d_model
    La = tuple("layers" for _ in prefix)
    out = {"norm": ParamSpec(prefix + (d,), La + ("embed",), init="ones")}
    if use_moe:
        out.update(M.moe_specs(cfg, prefix))
    else:
        out.update(L.mlp_specs(cfg, cfg.d_ff, prefix))
    return out


def mlp_sublayer(p, h, cfg, *, use_moe):
    x = L.rmsnorm(h, p["norm"], cfg.norm_eps)
    if use_moe:
        y, aux = M.moe_apply(p, x, cfg)
        return h + y, aux
    return h + L.mlp_apply(p, x), jnp.zeros((), jnp.float32)


# ---------------------------------------------------------------------------
# ssm sub-layer
# ---------------------------------------------------------------------------


def ssm_sublayer_specs(cfg, prefix):
    d = cfg.d_model
    La = tuple("layers" for _ in prefix)
    out = {"norm": ParamSpec(prefix + (d,), La + ("embed",), init="ones")}
    out.update(S.ssm_specs(cfg, prefix))
    return out


def ssm_sublayer(p, h, cfg, *, init_state=None, conv_tail=None,
                 return_state=False):
    x = L.rmsnorm(h, p["norm"], cfg.norm_eps)
    if return_state:
        y, st = S.ssm_block_apply(p, x, cfg, init_state=init_state,
                                  conv_tail=conv_tail, return_state=True)
        return h + y, st
    return h + S.ssm_block_apply(p, x, cfg), None


def ssm_sublayer_decode(p, h, cfg, cache):
    x = L.rmsnorm(h, p["norm"], cfg.norm_eps)
    y, (st, tail) = S.ssm_block_decode(p, x, cfg, cache["state"],
                                       cache["conv_tail"])
    return h + y, {"state": st, "conv_tail": tail}


def ssm_cache_specs(cfg, B, prefix=(), dtype=None):
    dt = dtype or cfg.dtype
    conv_dim = cfg.d_inner + 2 * cfg.ssm_state
    return {
        "state": jax.ShapeDtypeStruct(
            prefix + (B, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim), dt),
        "conv_tail": jax.ShapeDtypeStruct(
            prefix + (B, cfg.ssm_conv - 1, conv_dim), dt),
    }


# ---------------------------------------------------------------------------
# layer-type layout
# ---------------------------------------------------------------------------


def layer_kinds(cfg):
    """Per-layer (mixer_kind, use_moe): mixer_kind in {'attn','ssm'}."""
    kinds = []
    for l in range(cfg.num_layers):
        mixer = "attn" if cfg.is_attn_layer(l) else "ssm"
        kinds.append((mixer, cfg.is_moe_layer(l)))
    return kinds


def _superblock_period(cfg) -> int:
    """Scan period: smallest p such that layer kinds repeat with period p."""
    kinds = layer_kinds(cfg)
    n = cfg.num_layers
    for p in range(1, n + 1):
        if n % p:
            continue
        if all(kinds[i] == kinds[i % p] for i in range(n)):
            return p
    return n


# ---------------------------------------------------------------------------
# specs for the whole decoder stack
# ---------------------------------------------------------------------------


def decoder_specs(cfg) -> Dict[str, Any]:
    period = _superblock_period(cfg)
    n_super = cfg.num_layers // period
    prefix = (n_super,)
    kinds = layer_kinds(cfg)[:period]
    blocks: Dict[str, Any] = {}
    for i, (mixer, use_moe) in enumerate(kinds):
        sub: Dict[str, Any] = {}
        if mixer == "attn":
            sub["attn"] = attn_sublayer_specs(cfg, prefix)
        else:
            sub["ssm"] = ssm_sublayer_specs(cfg, prefix)
        if cfg.d_ff or cfg.num_experts:
            sub["mlp"] = mlp_sublayer_specs(cfg, prefix, use_moe=use_moe)
        blocks[f"sub{i}"] = sub
    specs = {
        "embed": L.embed_specs(cfg),
        "blocks": blocks,
        "final_norm": L.rmsnorm_spec(cfg.d_model),
    }
    return specs


# ---------------------------------------------------------------------------
# forward passes
# ---------------------------------------------------------------------------


def _sub_kinds(cfg):
    period = _superblock_period(cfg)
    return layer_kinds(cfg)[:period]


def decoder_forward(params, h, cfg, *, positions, mrope_pos=None,
                    collect_cache=False, block_k=1024):
    """Run the full stack over a sequence of hidden states ``h`` (B,S,d).

    Returns (h, aux_loss, cache|None). cache leaves are stacked (n_super,...).
    """
    kinds = _sub_kinds(cfg)
    window = cfg.sliding_window

    def superblock(h, block_params, dc, ic):
        del dc
        h = constrain_h(h)
        positions = ic["positions"]
        mrope_pos = ic.get("mrope")
        aux_total = jnp.zeros((), jnp.float32)
        caches = {}
        for i, (mixer, use_moe) in enumerate(kinds):
            sub = block_params[f"sub{i}"]
            if mixer == "attn":
                h, (k, v) = attn_sublayer(sub["attn"], h, cfg,
                                          positions=positions,
                                          mrope_pos=mrope_pos, window=window,
                                          block_k=block_k)
                if collect_cache:
                    caches[f"sub{i}"] = {"k": k, "v": v}
            else:
                h, st = ssm_sublayer(sub["ssm"], h, cfg,
                                     return_state=collect_cache)
                if collect_cache:
                    caches[f"sub{i}"] = {"state": st[0], "conv_tail": st[1]}
            if "mlp" in sub:
                h, aux = mlp_sublayer(sub["mlp"], h, cfg, use_moe=use_moe)
                aux_total = aux_total + aux
        return h, (aux_total, caches if collect_cache else None)

    wrapped = remat_block(superblock)
    ic = {"positions": positions}
    if mrope_pos is not None:
        ic["mrope"] = mrope_pos

    def body(h, block_params):
        return wrapped(h, block_params, {}, ic)

    h, (aux, caches) = _scan(body, h, params["blocks"])
    return h, jnp.sum(aux), caches


def decoder_decode_step(params, h, cfg, cache, *, position, window):
    """One-token step through the stack. h: (B,1,d); cache stacked (n_super,…)."""
    kinds = _sub_kinds(cfg)

    def superblock(h, inp):
        block_params, block_cache = inp
        new_cache = {}
        for i, (mixer, _) in enumerate(kinds):
            sub = block_params[f"sub{i}"]
            if mixer == "attn":
                h, c = attn_sublayer_decode(sub["attn"], h, cfg,
                                            block_cache[f"sub{i}"],
                                            position=position, window=window)
            else:
                h, c = ssm_sublayer_decode(sub["ssm"], h, cfg,
                                           block_cache[f"sub{i}"])
            new_cache[f"sub{i}"] = c
            if "mlp" in sub:
                h, _ = mlp_sublayer(sub["mlp"], h, cfg,
                                    use_moe=kinds[i][1])
        return h, new_cache

    h, new_cache = _scan(superblock, h,
                         (params["blocks"], cache))
    return h, new_cache


def decoder_cache_specs(cfg, B, seq_len, window, dtype=None):
    kinds = _sub_kinds(cfg)
    n_super = cfg.num_layers // len(kinds)
    prefix = (n_super,)
    out = {}
    for i, (mixer, _) in enumerate(kinds):
        if mixer == "attn":
            out[f"sub{i}"] = attn_cache_specs(cfg, B, seq_len, window,
                                              prefix, dtype)
        else:
            out[f"sub{i}"] = ssm_cache_specs(cfg, B, prefix, dtype)
    return out
