"""Unified model API: ``build_model(cfg)`` → a ``Model`` bundle of pure fns.

Every architecture family exposes the same surface:
  specs()                  ParamSpec tree (shapes + logical sharding axes)
  init(rng)                materialized params
  loss_fn(params, batch)   (scalar loss, metrics dict) — teacher-forced LM
  prefill_fn(params, batch)→ (cache, last_logits)
  decode_fn(params, cache, token, position) → (logits, new_cache)
  cache_specs(B, seq_len)  ShapeDtypeStruct tree for serve_step dry-runs
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import encdec as ED
from repro.models import layers as L
from repro.models import transformer as T


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    specs: Any
    loss_fn: Callable
    prefill_fn: Callable
    decode_fn: Callable
    cache_specs: Callable

    def init(self, rng, dtype=None):
        return L.init_params(rng, self.specs, dtype or self.cfg.dtype)

    def abstract_params(self, dtype=None):
        return L.abstract_params(self.specs, dtype or self.cfg.dtype)

    def logical_axes(self):
        return L.logical_axes(self.specs)


# ---------------------------------------------------------------------------
# decoder-only families (dense / moe / ssm / hybrid / vlm)
# ---------------------------------------------------------------------------


def _decoder_embed_inputs(params, batch, cfg):
    """Embed tokens or accept stubbed embeddings; produce positions."""
    if cfg.frontend == "vision":
        h = batch["embeds"]
        mrope_pos = batch["positions"]  # (3, B, S)
        B, S = h.shape[0], h.shape[1]
        positions = mrope_pos[0]  # temporal axis doubles as causal order
    else:
        tokens = batch["tokens"]
        B, S = tokens.shape
        h = L.embed_apply(params["embed"], tokens)
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        mrope_pos = None
    return h, positions, mrope_pos


def _build_decoder_model(cfg: ModelConfig) -> Model:
    specs = T.decoder_specs(cfg)

    def loss_fn(params, batch, *, block_k=1024):
        h, positions, mrope_pos = _decoder_embed_inputs(params, batch, cfg)
        h, aux, _ = T.decoder_forward(params, h, cfg, positions=positions,
                                      mrope_pos=mrope_pos, block_k=block_k)
        h = L.rmsnorm(h, params["final_norm"], cfg.norm_eps)
        logits = L.unembed_apply(params["embed"], h, cfg.tie_embeddings)
        ce = L.cross_entropy(logits, batch["labels"])
        loss = ce + cfg.router_aux_weight * aux
        return loss, {"ce": ce, "aux": aux}

    def prefill_fn(params, batch, *, block_k=1024):
        h, positions, mrope_pos = _decoder_embed_inputs(params, batch, cfg)
        h, _, cache = T.decoder_forward(params, h, cfg, positions=positions,
                                        mrope_pos=mrope_pos,
                                        collect_cache=True, block_k=block_k)
        h = L.rmsnorm(h[:, -1:], params["final_norm"], cfg.norm_eps)
        logits = L.unembed_apply(params["embed"], h, cfg.tie_embeddings)
        return cache, logits

    def decode_fn(params, cache, token, position):
        h = L.embed_apply(params["embed"], token)  # (B,1,d)
        h, new_cache = T.decoder_decode_step(params, h, cfg, cache,
                                             position=position,
                                             window=cfg.sliding_window)
        h = L.rmsnorm(h, params["final_norm"], cfg.norm_eps)
        logits = L.unembed_apply(params["embed"], h, cfg.tie_embeddings)
        return logits, new_cache

    def cache_specs(B, seq_len, dtype=None):
        return T.decoder_cache_specs(cfg, B, seq_len, cfg.sliding_window,
                                     dtype)

    return Model(cfg, specs, loss_fn, prefill_fn, decode_fn, cache_specs)


# ---------------------------------------------------------------------------
# encoder-decoder family (whisper)
# ---------------------------------------------------------------------------


def _build_encdec_model(cfg: ModelConfig) -> Model:
    specs = ED.encdec_specs(cfg)

    def loss_fn(params, batch, *, block_k=512):
        enc_h = ED.encode(params, batch["audio_embeds"], cfg, block_k=block_k)
        logits = ED.decode_train(params, enc_h, batch["tokens"], cfg,
                                 block_k=block_k)
        ce = L.cross_entropy(logits, batch["labels"])
        return ce, {"ce": ce, "aux": jnp.zeros((), jnp.float32)}

    def prefill_fn(params, batch, *, block_k=512):
        """Builds the decode cache: encoder pass + cross K/V + empty self kv."""
        enc_h = ED.encode(params, batch["audio_embeds"], cfg, block_k=block_k)
        xk = jnp.einsum("bsd,ldhk->lbshk", enc_h,
                        params["dec_blocks"]["cross"]["wk"])
        xv = jnp.einsum("bsd,ldhk->lbshk", enc_h,
                        params["dec_blocks"]["cross"]["wv"])
        B = enc_h.shape[0]
        S = batch["tokens"].shape[1]
        self_specs = T.attn_cache_specs(cfg, B, S, cfg.sliding_window,
                                        (cfg.num_layers,), cfg.dtype)
        self_cache = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                                  self_specs)
        cache = {"self": self_cache, "cross": {"k": xk, "v": xv}}
        # teacher-forced warm start is up to the caller; return BOS logits
        logits, cache = ED.decode_step(params, cache, batch["tokens"][:, :1],
                                       jnp.zeros((B,), jnp.int32), cfg,
                                       window=cfg.sliding_window)
        return cache, logits

    def decode_fn(params, cache, token, position):
        return ED.decode_step(params, cache, token, position, cfg,
                              window=cfg.sliding_window)

    def cache_specs(B, seq_len, dtype=None):
        return ED.encdec_cache_specs(cfg, B, seq_len, cfg.sliding_window,
                                     dtype)

    return Model(cfg, specs, loss_fn, prefill_fn, decode_fn, cache_specs)


# ---------------------------------------------------------------------------


def build_model(cfg: ModelConfig) -> Model:
    if cfg.enc_dec:
        return _build_encdec_model(cfg)
    return _build_decoder_model(cfg)
