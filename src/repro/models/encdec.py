"""Whisper-style encoder–decoder transformer.

The mel-spectrogram + conv frontend is a STUB per the brief: the encoder
consumes precomputed frame embeddings (B, enc_seq, d_model) supplied by
``input_specs``. Sinusoidal positions are added on both sides (whisper has
no RoPE; ``rope_theta=0`` disables rotation in the shared attention code).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import transformer as T
from repro.models.layers import ParamSpec

REMAT_POLICY = T.REMAT_POLICY


def cross_attn_specs(cfg, prefix):
    d = cfg.d_model
    La = tuple("layers" for _ in prefix)
    out = {"norm": ParamSpec(prefix + (d,), La + ("embed",), init="ones")}
    out.update(L.attention_specs(cfg, prefix))
    return out


def encdec_specs(cfg) -> Dict[str, Any]:
    ne, nd = cfg.enc_layers, cfg.num_layers
    enc_block = {
        "attn": T.attn_sublayer_specs(cfg, (ne,)),
        "mlp": T.mlp_sublayer_specs(cfg, (ne,), use_moe=False),
    }
    dec_block = {
        "attn": T.attn_sublayer_specs(cfg, (nd,)),
        "cross": cross_attn_specs(cfg, (nd,)),
        "mlp": T.mlp_sublayer_specs(cfg, (nd,), use_moe=False),
    }
    return {
        "embed": L.embed_specs(cfg),
        "enc_blocks": enc_block,
        "enc_norm": L.rmsnorm_spec(cfg.d_model),
        "dec_blocks": dec_block,
        "dec_norm": L.rmsnorm_spec(cfg.d_model),
    }


def _cross_attn(p, h, enc_kv, cfg, *, positions, enc_positions, block_k):
    """Full-sequence cross attention. enc_kv: (k, v) from encoder output."""
    x = L.rmsnorm(h, p["norm"], cfg.norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k, v = enc_kv
    out = L.flash_attention_jnp(q, k, v, q_positions=positions,
                                k_positions=enc_positions, causal=False,
                                window=0, block_k=block_k)
    o = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return h + o


def encode(params, audio_embeds, cfg, *, block_k=512):
    """audio_embeds: (B, enc_seq, d) stub-frontend output → encoder states."""
    B, Se, d = audio_embeds.shape
    h = audio_embeds + L.sinusoidal_positions(Se, d).astype(audio_embeds.dtype)
    positions = jnp.broadcast_to(jnp.arange(Se)[None], (B, Se))

    def block(h, bp, dc, ic):
        del dc
        h = T.constrain_h(h)
        h, _ = T.attn_sublayer(bp["attn"], h, cfg, positions=ic["positions"],
                               causal=False, block_k=block_k)
        h, _ = T.mlp_sublayer(bp["mlp"], h, cfg, use_moe=False)
        return h, ()

    wrapped = T.remat_block(block)
    h, _ = T._scan(
        lambda h, bp: wrapped(h, bp, {}, {"positions": positions}),
        h, params["enc_blocks"])
    return L.rmsnorm(h, params["enc_norm"], cfg.norm_eps)


def decode_train(params, enc_h, tokens, cfg, *, block_k=1024):
    """Teacher-forced decoder pass → logits (B, S, V)."""
    B, S = tokens.shape
    d = cfg.d_model
    h = L.embed_apply(params["embed"], tokens)
    h = h + L.sinusoidal_positions(S, d).astype(h.dtype)
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    Se = enc_h.shape[1]
    enc_positions = jnp.broadcast_to(jnp.arange(Se)[None], (B, Se))

    def block(h, bp, dc, ic):
        enc_h = T.constrain_h(dc["enc_h"])
        h = T.constrain_h(h)
        h, _ = T.attn_sublayer(bp["attn"], h, cfg, positions=ic["positions"],
                               causal=True, window=cfg.sliding_window,
                               block_k=block_k)
        xk = jnp.einsum("bsd,dhk->bshk", enc_h, bp["cross"]["wk"])
        xv = jnp.einsum("bsd,dhk->bshk", enc_h, bp["cross"]["wv"])
        h = _cross_attn(bp["cross"], h, (xk, xv), cfg,
                        positions=ic["positions"],
                        enc_positions=ic["enc_positions"], block_k=block_k)
        h, _ = T.mlp_sublayer(bp["mlp"], h, cfg, use_moe=False)
        return h, ()

    wrapped = T.remat_block(block)
    h, _ = T._scan(
        lambda h, bp: wrapped(h, bp, {"enc_h": enc_h},
                              {"positions": positions,
                               "enc_positions": enc_positions}),
        h, params["dec_blocks"])
    h = L.rmsnorm(h, params["dec_norm"], cfg.norm_eps)
    return L.unembed_apply(params["embed"], h, cfg.tie_embeddings)


def decode_step(params, cache, token, position, cfg, *, window=0):
    """One decoder token. cache: {"self": stacked kv, "cross": stacked kv}."""
    B = token.shape[0]
    h = L.embed_apply(params["embed"], token)  # (B, 1, d)
    # sinusoidal position for the current index
    d = cfg.d_model
    pe = _sinusoid_at(position, d).astype(h.dtype)  # (B, d)
    h = h + pe[:, None, :]

    Se = cache["cross"]["k"].shape[2]
    enc_positions = jnp.broadcast_to(jnp.arange(Se)[None], (B, Se))

    def block(h, inp):
        bp, self_cache, cross_kv = inp
        h, new_self = T.attn_sublayer_decode(bp["attn"], h, cfg, self_cache,
                                             position=position, window=window)
        x = L.rmsnorm(h, bp["cross"]["norm"], cfg.norm_eps)
        q = jnp.einsum("bsd,dhk->bshk", x, bp["cross"]["wq"])
        out = L.decode_attention_jnp(q, cross_kv["k"], cross_kv["v"],
                                     q_position=position,
                                     k_positions=enc_positions,
                                     causal=False)
        h = h + jnp.einsum("bshk,hkd->bsd", out, bp["cross"]["wo"])
        h, _ = T.mlp_sublayer(bp["mlp"], h, cfg, use_moe=False)
        return h, new_self

    h, new_self = T._scan(block, h,
                          (params["dec_blocks"], cache["self"],
                           cache["cross"]))
    h = L.rmsnorm(h, params["dec_norm"], cfg.norm_eps)
    logits = L.unembed_apply(params["embed"], h, cfg.tie_embeddings)
    return logits, {"self": new_self, "cross": cache["cross"]}


def _sinusoid_at(position, d_model):
    """position: (B,) → (B, d_model) sinusoidal embedding."""
    import numpy as np
    half = d_model // 2
    freqs = jnp.asarray(
        1.0 / np.power(10000.0, np.arange(half, dtype=np.float32) * 2 / d_model))
    ang = position[:, None].astype(jnp.float32) * freqs[None, :]
    out = jnp.zeros((position.shape[0], d_model), jnp.float32)
    out = out.at[:, 0::2].set(jnp.sin(ang))
    out = out.at[:, 1::2].set(jnp.cos(ang))
    return out


def encdec_cache_specs(cfg, B, seq_len, window, dtype=None):
    dt = dtype or cfg.dtype
    nd = cfg.num_layers
    self_specs = T.attn_cache_specs(cfg, B, seq_len, window, (nd,), dt)
    sh = (nd, B, cfg.enc_seq, cfg.num_kv_heads, cfg.head_dim)
    cross = {"k": jax.ShapeDtypeStruct(sh, dt),
             "v": jax.ShapeDtypeStruct(sh, dt)}
    return {"self": self_specs, "cross": cross}
