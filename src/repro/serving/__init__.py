"""Train-and-serve subsystem: live inference from the training read plane.

The decoupled lane's double-buffered flat parameter plane (DESIGN.md
§9/§11) always holds one consistent, fully-materialized buffer that
training is not writing — exactly what a live serving path needs. This
package turns it into a weight feed (DESIGN.md §12):

* :class:`PlanePublisher` / :class:`PlaneSnapshot` — once per gossip
  round the trainer publishes an atomic handle to the read plane plus its
  version clocks and drift metric (zero-copy on the pipeline engine);
* :class:`SwapPolicy` / :class:`SwapDecision` — staleness/drift-gated
  acceptance with min/max swap cadence;
* :class:`AdmissionQueue` / :class:`Ticket` — bounded-depth admission
  control with reject-with-retry-after and per-request deadline drop;
* :class:`LiveServer` — gates snapshots, unpacks accepted planes through
  the training ``FlatPartition`` into a ``ServeLoop`` between decode
  steps, and drives admission → decode → swap-poll.
"""
from repro.serving.live import LiveServer, SwapRecord
from repro.serving.policy import SwapDecision, SwapPolicy
from repro.serving.publisher import PlanePublisher, PlaneSnapshot
from repro.serving.queue import AdmissionQueue, Ticket

__all__ = [
    "AdmissionQueue", "LiveServer", "PlanePublisher", "PlaneSnapshot",
    "SwapDecision", "SwapPolicy", "SwapRecord", "Ticket",
]
