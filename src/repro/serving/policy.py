"""SwapPolicy — staleness/drift-gated acceptance of published planes.

Delay-aware ASGD variants (DaSGD's delayed averaging, Zheng et al.'s delay
compensation — PAPERS.md) all trade parameter freshness against stability.
The serving side faces the same trade at swap time: a freshly gossiped
plane is *usually* the best thing to serve, but mid-divergence (high
disagreement) or deep-staleness planes can be worse than the params
already serving. The policy makes that trade explicit, using exactly the
accounting the training side already produces:

* **per-group staleness** — the ``(M, G)`` version clocks stamped by the
  gossip stage (``t + phi_g``, DESIGN.md §4) against the publishing step:
  ``layer_staleness(versions, step)``, the same metric the figA1/table
  benchmarks report. Gate: the max over groups must stay under
  ``max_staleness`` (in iterations).
* **drift** — the figA1 disagreement metric ``mean_i ||x_i - x_bar||``
  carried on the snapshot when the backend measures it. Gate: must stay
  under ``max_drift``.
* **swap cadence** — ``min_interval_steps`` rejects planes that arrive
  too soon after the last accepted swap (swapping costs an unpack and a
  jit-cache-warm decode step; don't thrash), while ``max_interval_steps``
  *force-accepts* once the serving params fall that many steps behind:
  past the bound, freshness beats the drift/staleness gates (the serve
  params' own staleness is then the larger divergence risk). A forced
  accept is recorded with its own reason so the trade stays visible.

``evaluate`` converts the snapshot's (possibly in-flight) version/drift
arrays to host values — it blocks the CALLING thread, which is the
serving side's poll loop, never the trainer.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

import numpy as np


@dataclass(frozen=True)
class SwapDecision:
    """Outcome of gating one snapshot. ``reason`` is one of
    ``fresh`` / ``forced-max-interval`` (accepted) or
    ``min-interval`` / ``staleness`` / ``drift`` /
    ``unhealthy-source`` (rejected)."""

    accepted: bool
    reason: str
    seq: int
    step: int
    staleness_max: float = 0.0
    drift: Optional[float] = None


@dataclass
class SwapPolicy:
    """Accept/reject a published plane for serving. All gates default to
    disabled (None / 0), i.e. accept-everything; configure what the
    deployment cares about."""

    max_staleness: Optional[float] = None   # max per-group staleness, iters
    max_drift: Optional[float] = None       # figA1 disagreement bound
    min_interval_steps: int = 0             # min training steps between swaps
    max_interval_steps: Optional[int] = None  # force-accept beyond this
    # membership view (a repro.chaos.PeerHealth): refuse snapshots whose
    # source worker is suspect/dead — a crashed peer's frozen replica
    # must never reach serving, DESIGN.md §15
    health: Optional[object] = None
    counts: Dict[str, int] = field(default_factory=dict)

    def _decide(self, snap, last_swap_step: Optional[int],
                worker: Optional[int]) -> SwapDecision:
        from repro.core.layerview import layer_staleness

        # host conversions: blocks this (serving) thread until the
        # producing step's gossip has materialized the clocks
        versions = np.asarray(snap.versions, np.float32)
        stale = np.asarray(layer_staleness(versions, snap.step), np.float32)
        stale_max = float(stale.max()) if stale.size else 0.0
        drift = None if snap.drift is None else float(np.asarray(snap.drift))
        age = (None if last_swap_step is None
               else snap.step - int(last_swap_step))

        def dec(accepted, reason):
            return SwapDecision(accepted=accepted, reason=reason,
                                seq=snap.seq, step=snap.step,
                                staleness_max=stale_max, drift=drift)

        # the health gate comes FIRST — it beats even the forced accept:
        # freshness never outranks serving a suspect/dead worker's replica
        if (self.health is not None and worker is not None
                and not self.health.serving_ok(worker)):
            return dec(False, "unhealthy-source")
        if age is not None and age < self.min_interval_steps:
            return dec(False, "min-interval")
        if (self.max_interval_steps is not None and age is not None
                and age >= self.max_interval_steps):
            return dec(True, "forced-max-interval")
        if self.max_staleness is not None and stale_max > self.max_staleness:
            return dec(False, "staleness")
        if (self.max_drift is not None and drift is not None
                and drift > self.max_drift):
            return dec(False, "drift")
        return dec(True, "fresh")

    def evaluate(self, snap, last_swap_step: Optional[int] = None,
                 worker: Optional[int] = None) -> SwapDecision:
        """Gate one snapshot against the last accepted swap's step.
        ``worker`` is the publishing worker's index for the health gate
        (ignored when no ``health`` view is configured)."""
        d = self._decide(snap, last_swap_step, worker)
        self.counts[d.reason] = self.counts.get(d.reason, 0) + 1
        return d

    @property
    def rejected(self) -> int:
        return sum(n for r, n in self.counts.items()
                   if r in ("min-interval", "staleness", "drift",
                            "unhealthy-source"))

    @property
    def gated_rejections(self) -> int:
        """Rejections from the divergence gates specifically (staleness or
        drift) — the bench's acceptance hook."""
        return (self.counts.get("staleness", 0)
                + self.counts.get("drift", 0))

    @property
    def accepted(self) -> int:
        return (self.counts.get("fresh", 0)
                + self.counts.get("forced-max-interval", 0))
