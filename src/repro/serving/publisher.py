"""PlanePublisher — the training→serving handoff of the flat read plane.

The decoupled lane's double-buffered parameters (DESIGN.md §9/§11) mean
there is, at every step boundary, a fully-materialized flat parameter
plane that training is *not* writing: the read buffer. The publisher turns
that property into a serving feed: once per gossip round the training side
calls :meth:`PlanePublisher.publish` with the current read-plane handles,
the per-group version clocks, the push-sum weights and (optionally) the
figA1 disagreement metric, and any number of serving consumers can pick up
the latest :class:`PlaneSnapshot` without ever touching a checkpoint.

**Zero-copy and donation safety.** A snapshot stores device-buffer
*handles*, not copies — publishing is O(1) on the host. But a handle into
a buffer that a later training step will DONATE dies with that step, so
what gets pinned depends on the producing lane:

* the pipeline engine (``overlap=True``) never donates the read plane
  (all R forward slices share it, so the engine keeps it un-donated by
  construction — DESIGN.md §10), so the plane handles are published as-is
  and stay valid for as long as the snapshot lives: true zero-copy;
* the monolithic decoupled step donates its whole input state, so a
  publisher fed from that lane is told ``stable=False`` and stabilizes
  the plane with one device-side ``jnp.copy`` per group — an async device
  op, never a host sync and never a checkpoint round-trip;
* the version clocks and push-sum weights are donated by the NEXT step on
  both lanes, so those (tiny) arrays are always defensively copied.

Publishing never blocks the host: the copies are async dispatches and the
snapshot swap is a lock-protected reference assignment. Consumers that
need host values (the :class:`~repro.serving.policy.SwapPolicy` gate)
block on *their* thread, which is the point — the training loop keeps its
run-ahead (the pipeline engine's dispatch schedule is unaffected).
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Dict, Optional


@dataclass(frozen=True)
class PlaneSnapshot:
    """One published read plane: handles + provenance, immutable.

    ``plane`` maps plane-buffer name → stacked ``(M, group_size)`` device
    buffer (the FlatPartition layout); ``versions`` is the ``(M, G)``
    per-group version clock and ``step`` the training step that produced
    the plane — together they define every group's staleness at serve
    time. ``drift`` is the figA1 disagreement metric when the producing
    backend measures it (``measure_drift=True``), else None. All array
    fields may still be in-flight futures; conversion blocks the caller,
    never the trainer."""

    seq: int                      # monotone publish counter
    step: int                     # training step index at publish
    plane: Dict[str, Any]         # {group: (M, size) device buffer}
    versions: Any                 # (M, G) float32 version clocks (copy)
    w: Any                        # (M,) push-sum weights (copy)
    drift: Optional[Any] = None   # figA1 disagreement, if measured
    published_at: float = 0.0     # host monotonic time of publish


@dataclass
class PublisherStats:
    published: int = 0
    skipped: int = 0              # publish calls below the `every` cadence
    copied_planes: int = 0        # stabilizing copies (monolithic lane)


class PlanePublisher:
    """Single-producer, multi-consumer atomic handoff of the read plane.

    ``every`` subsamples the publish cadence: the trainer calls
    :meth:`publish` once per gossip round and the publisher keeps every
    ``every``-th call (1 = every round). Consumers poll :meth:`latest`
    (non-blocking) or :meth:`wait_for` (blocking with timeout)."""

    def __init__(self, every: int = 1):
        if every < 1:
            raise ValueError(f"every must be >= 1, got {every}")
        self.every = int(every)
        self.stats = PublisherStats()
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._latest: Optional[PlaneSnapshot] = None
        self._seq = 0
        self._calls = 0

    def publish(self, plane: Dict[str, Any], versions, w, step: int, *,
                drift=None, stable: bool = True) -> Optional[PlaneSnapshot]:
        """Publish the current read plane; returns the snapshot, or None
        when skipped by the ``every`` cadence.

        ``stable=True`` promises the plane buffers are never donated by a
        later training step (the pipeline engine's read plane); with
        ``stable=False`` (monolithic lane — the step donates its state)
        each group buffer is stabilized with an async device copy first.
        ``versions``/``w`` are always copied (both lanes donate them on
        the next step). Never blocks on device work."""
        self._calls += 1
        if (self._calls - 1) % self.every != 0:
            self.stats.skipped += 1
            return None
        import jax.numpy as jnp
        if not stable:
            plane = {g: jnp.copy(b) for g, b in plane.items()}
            self.stats.copied_planes += 1
        snap_versions = jnp.copy(versions)
        snap_w = jnp.copy(w)
        with self._cond:
            self._seq += 1
            snap = PlaneSnapshot(seq=self._seq, step=int(step), plane=plane,
                                 versions=snap_versions, w=snap_w,
                                 drift=drift,
                                 published_at=time.monotonic())
            self._latest = snap
            self.stats.published += 1
            self._cond.notify_all()
        return snap

    def latest(self, after_seq: int = -1) -> Optional[PlaneSnapshot]:
        """The most recent snapshot, or None if none newer than
        ``after_seq`` has been published. Non-blocking."""
        with self._lock:
            s = self._latest
        if s is None or s.seq <= after_seq:
            return None
        return s

    def wait_for(self, after_seq: int = -1,
                 timeout: Optional[float] = None) -> Optional[PlaneSnapshot]:
        """Block until a snapshot newer than ``after_seq`` arrives (or
        timeout); returns it, or None on timeout."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cond:
            while (self._latest is None
                   or self._latest.seq <= after_seq):
                remaining = (None if deadline is None
                             else deadline - time.monotonic())
                if remaining is not None and remaining <= 0:
                    return None
                self._cond.wait(remaining)
            return self._latest
