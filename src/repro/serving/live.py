"""LiveServer — continuous deployment of a continuously-training model.

Composes the subsystem: a :class:`~repro.serving.publisher.PlanePublisher`
feeds read-plane snapshots from the trainer, a
:class:`~repro.serving.policy.SwapPolicy` gates them, and accepted planes
are unpacked through the training ``FlatPartition`` straight into the
:class:`~repro.launch.serve.ServeLoop`'s params — no checkpoint
save/load anywhere on the path. An optional
:class:`~repro.serving.queue.AdmissionQueue` fronts the loop's own slot
queue with overload control.

**Swap atomicity.** The unpack is one jitted call over the whole
snapshot (slice worker ``w`` out of every ``(M, size)`` group buffer,
then ``FlatPartition.unpack`` — static slice/reshape views, DESIGN.md
§11), so the produced parameter tree is derived from exactly one plane
version. The swap itself is a single reference assignment performed
between decode steps (``poll`` runs at step boundaries): a decode step
either sees the whole old tree or the whole new one — groups from two
plane versions can never mix, and the snapshot's version clocks advance
together with the params they describe.

**Zero-copy path.** Nothing on the swap path serializes or round-trips
through the filesystem: publish pins device handles, the gate reads two
tiny arrays, and the unpack is a device-side reshuffle dispatched once
per accepted swap. Rejected snapshots cost two small host transfers
(versions + drift) and nothing else — serving simply continues on the
previous params.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Dict, List, Optional

from repro.serving.policy import SwapDecision, SwapPolicy
from repro.serving.publisher import PlanePublisher
from repro.serving.queue import AdmissionQueue


@dataclass(frozen=True)
class SwapRecord:
    """Provenance of one accepted swap: which snapshot, when, and the
    host-side copy of its version clocks (all groups from one publish —
    the atomicity invariant tests assert on)."""

    seq: int
    step: int
    reason: str
    at_serve_step: int
    versions: Any  # (M, G) numpy copy at swap time


class LiveServer:
    """Drive a :class:`ServeLoop` on live, staleness-gated weights.

    ``worker`` selects which of the trainer's M per-worker replicas
    serves (the replicas converge through gossip; worker 0 by default).
    ``poll`` checks the publisher once and swaps if the policy accepts;
    ``step`` = admit → one decode step → poll, the serving inner loop.
    """

    def __init__(self, loop, part, publisher: PlanePublisher,
                 policy: Optional[SwapPolicy] = None,
                 admission: Optional[AdmissionQueue] = None,
                 worker: int = 0):
        import jax

        self.loop = loop
        self.part = part
        self.publisher = publisher
        self.policy = policy if policy is not None else SwapPolicy()
        self.admission = admission
        self.worker = int(worker)
        self.swaps: List[SwapRecord] = []
        self.decisions: List[SwapDecision] = []
        self._last_seq = -1
        self._last_swap_step: Optional[int] = None

        w = self.worker

        def unpack_worker(plane):
            return part.unpack({g: b[w] for g, b in plane.items()})

        self._unpack = jax.jit(unpack_worker)

    # -- swap path -----------------------------------------------------------
    def poll(self) -> Optional[SwapDecision]:
        """Evaluate the newest unseen snapshot; swap if accepted. Returns
        the decision, or None when nothing new was published. Called
        between decode steps only — the loop's params rebind atomically."""
        snap = self.publisher.latest(after_seq=self._last_seq)
        if snap is None:
            return None
        self._last_seq = snap.seq
        decision = self.policy.evaluate(snap,
                                        last_swap_step=self._last_swap_step,
                                        worker=self.worker)
        self.decisions.append(decision)
        if decision.accepted:
            import numpy as np

            params = self._unpack(snap.plane)
            self.loop.set_params(params, version=(snap.seq, snap.step))
            self._last_swap_step = snap.step
            self.swaps.append(SwapRecord(
                seq=snap.seq, step=snap.step, reason=decision.reason,
                at_serve_step=self.loop.steps_run,
                versions=np.asarray(snap.versions, np.float32)))
        return decision

    # -- serve loop ----------------------------------------------------------
    def _admit_from_queue(self) -> None:
        if self.admission is None:
            return
        free = sum(1 for s in self.loop.slots if s.req is None)
        room = free + max(0, 2 * self.loop.num_slots - len(self.loop.queue))
        for req in self.admission.take(room):
            self.loop.submit(req)

    def step(self) -> bool:
        """One serving iteration: drain admissions, run one decode step,
        then consider a swap at the step boundary. Returns False when
        there was nothing to decode (idle)."""
        self._admit_from_queue()
        progressed = self.loop.step_once()
        self.poll()
        return progressed

    def run_for(self, duration_s: float, *,
                idle_sleep_s: float = 0.002) -> None:
        """Serve for a wall-clock window (the benchmark's inner loop)."""
        t_end = time.monotonic() + duration_s
        while time.monotonic() < t_end:
            if not self.step():
                time.sleep(idle_sleep_s)

    def run_until_idle(self, max_steps: int = 10_000) -> None:
        """Serve until both queues drain (the example's inner loop)."""
        for _ in range(max_steps):
            if not self.step() and (self.admission is None
                                    or self.admission.depth == 0):
                break

    # -- accounting ----------------------------------------------------------
    @property
    def swap_count(self) -> int:
        return len(self.swaps)

    def stats(self) -> Dict[str, Any]:
        out = dict(self.loop.stats())
        out.update(swaps=self.swap_count,
                   publishes_seen=len(self.decisions),
                   swap_rejected=self.policy.rejected,
                   swap_rejected_gated=self.policy.gated_rejections,
                   swap_reasons=dict(self.policy.counts),
                   last_swap_step=self._last_swap_step)
        if self.admission is not None:
            out["admission"] = self.admission.stats()
        return out
