"""Admission-controlled request queue in front of the serve loop.

When training runs at full tilt on the same host devices, serve-step
latency degrades — every decode dispatch queues behind in-flight training
stages. An unbounded request queue would turn that into unbounded latency
for everyone; the admission controller instead degrades *gracefully*:

* **bounded depth** — past ``max_depth`` waiting requests, new arrivals
  are rejected immediately with a ``retry_after_s`` hint derived from the
  measured drain rate (reject-fast beats queue-forever for open-loop
  traffic);
* **per-request deadlines** — a request that has not been admitted into a
  decode slot by its deadline is dropped at dequeue time (its tokens
  would arrive too late to matter; serving them would only push everyone
  else past *their* deadlines).

The controller is thread-safe: the request generator submits from its own
thread while the serving loop drains via :meth:`take`.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass
from typing import Deque, List, Optional, Tuple


@dataclass(frozen=True)
class Ticket:
    """Admission outcome: ``accepted``, or rejected with a retry hint."""

    accepted: bool
    retry_after_s: float = 0.0
    reason: str = ""


class AdmissionQueue:
    """Bounded FIFO with deadline drop and a measured-drain retry hint."""

    def __init__(self, max_depth: int = 64):
        if max_depth < 1:
            raise ValueError(f"max_depth must be >= 1, got {max_depth}")
        self.max_depth = int(max_depth)
        self._lock = threading.Lock()
        self._q: Deque[Tuple[object, Optional[float]]] = deque()
        # drain-rate EMA (seconds per dequeued request) for retry_after
        self._drain_ema_s = 0.05
        self._last_take: Optional[float] = None
        self.submitted = 0
        self.admitted = 0
        self.rejected = 0
        self.deadline_dropped = 0

    def submit(self, request, *, deadline_s: Optional[float] = None,
               now: Optional[float] = None) -> Ticket:
        """Try to enqueue; on overload reject with a retry-after estimate
        (depth x measured drain time). ``deadline_s`` is an absolute
        ``time.monotonic()`` bound on *admission into a slot*."""
        now = time.monotonic() if now is None else now
        with self._lock:
            self.submitted += 1
            if len(self._q) >= self.max_depth:
                self.rejected += 1
                retry = max(0.001, len(self._q) * self._drain_ema_s)
                return Ticket(accepted=False, retry_after_s=retry,
                              reason="queue-full")
            self._q.append((request, deadline_s))
            return Ticket(accepted=True)

    def take(self, k: int, now: Optional[float] = None) -> List[object]:
        """Dequeue up to ``k`` admissible requests, dropping any whose
        deadline already passed (counted in ``deadline_dropped``)."""
        now = time.monotonic() if now is None else now
        out: List[object] = []
        with self._lock:
            while self._q and len(out) < k:
                req, deadline = self._q.popleft()
                if deadline is not None and now > deadline:
                    self.deadline_dropped += 1
                    continue
                out.append(req)
            if out:
                if self._last_take is not None:
                    dt = max(1e-4, (now - self._last_take) / len(out))
                    self._drain_ema_s += 0.2 * (dt - self._drain_ema_s)
                self._last_take = now
                self.admitted += len(out)
        return out

    @property
    def depth(self) -> int:
        with self._lock:
            return len(self._q)

    def stats(self) -> dict:
        with self._lock:
            return {"submitted": self.submitted, "admitted": self.admitted,
                    "rejected": self.rejected,
                    "deadline_dropped": self.deadline_dropped,
                    "depth": len(self._q),
                    "drain_ema_s": self._drain_ema_s}
