"""Production-backend step builders (pjit / shard_map on the real mesh).

Two distribution strategies, mirroring the paper's comparison:

* **DDP** (baseline): parameters replicated over the ('pod','data') axes,
  tensor-parallel over 'model'. Plain ``jax.jit``: GSPMD inserts the gradient
  all-reduce (2·P·(M−1)/M wire bytes — the synchronization the paper removes).

* **LayUp** (the paper): every data-parallel replica owns a distinct copy of
  the parameters (stacked leading worker axis, sharded over ('pod','data')).
  ``shard_map`` is *manual* over the worker axes and **auto (GSPMD) over
  'model'**, so tensor parallelism composes transparently ("orthogonal to
  model/tensor/pipeline parallelism", paper §1). Gossip is a
  ``collective_permute`` ring shift over the worker axes — the TPU-native
  realization of random-peer gossip (each hop is an ICI-neighbour hop; the
  shift is drawn per step from a static power-of-two set via ``lax.switch``,
  i.e. hypercube gossip — see DESIGN.md §2). Push-sum weights ride along as
  a per-worker scalar. Collectives are issued **per layer group by
  construction**: the parameter tree is partitioned through the same
  ``LayerPartition`` the sim backend's v2 hooks use (DESIGN.md §1), and each
  group's subtree ships as one logical gossip message — the HLO counterpart
  of the paper's layer-wise updates.

Serving: ``make_prefill_step`` / ``make_decode_step`` build the inference
paths (params replicated over data axes, TP over 'model'; decode donates the
KV cache).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
try:  # jax >= 0.5: top-level export with check_vma/axis_names kwargs
    from jax import shard_map as _shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, axis_names):
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_vma=False,
                          axis_names=set(axis_names))
except ImportError:  # jax 0.4.x: experimental API; partial-manual (auto=)
    # subgroup sharding trips an XLA CHECK in this generation, so fall back
    # to fully-manual shard_map — the body sees model-axis-replicated
    # shards (tensor parallelism folds into replication; numerics are
    # unchanged, memory is the 0.4.x price)
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, axis_names):
        return _shard_map(f, mesh, in_specs=in_specs, out_specs=out_specs,
                          check_rep=False)
from jax.sharding import NamedSharding, PartitionSpec as P

from jax.flatten_util import ravel_pytree

from repro.configs.base import ModelConfig, ShapeConfig, input_specs
from repro.core.layerview import LayerPartition
from repro.launch import sharding as SH
from repro.launch.mesh import data_axes, num_workers
from repro.models.model import Model
from repro.optim.optimizers import Optimizer, apply_updates


@dataclass
class ProdStep:
    """A lowered-able step: ``fn`` jitted with shardings, plus abstract args."""
    fn: Any
    abstract_args: Tuple[Any, ...]
    describe: str = ""

    def lower(self):
        return self.fn.lower(*self.abstract_args)


def _abstract_batch(cfg: ModelConfig, shape: ShapeConfig, dtype=None):
    return input_specs(cfg, shape, dtype)


# ---------------------------------------------------------------------------
# DDP train step (baseline)
# ---------------------------------------------------------------------------


def make_ddp_train_step(model: Model, mesh, optimizer: Optimizer,
                        schedule: Callable, shape: ShapeConfig,
                        overrides: Optional[Dict[str, Any]] = None,
                        preset: Optional[str] = None) -> ProdStep:
    cfg = model.cfg

    def step(params, opt_state, batch, step_idx):
        (loss, _), grads = jax.value_and_grad(model.loss_fn, has_aux=True)(
            params, batch)
        lr = schedule(step_idx)
        updates, opt_state = optimizer.update(grads, opt_state, params, lr)
        params = apply_updates(params, updates)
        return params, opt_state, loss

    p_sh = SH.param_shardings(model, mesh, overrides=overrides,
                              preset=preset)
    abstract_params = model.abstract_params()
    abstract_opt = jax.eval_shape(optimizer.init, abstract_params)
    opt_sh = _opt_shardings(optimizer, abstract_params, p_sh, mesh)
    batch_abs = _abstract_batch(cfg, shape)
    b_sh = SH.batch_shardings(batch_abs, mesh, overrides=overrides,
                              preset=preset)
    scalar = NamedSharding(mesh, P())
    fn = jax.jit(step,
                 in_shardings=(p_sh, opt_sh, b_sh, scalar),
                 out_shardings=(p_sh, opt_sh, scalar),
                 donate_argnums=(0, 1))
    abstract = (abstract_params, abstract_opt, batch_abs,
                jax.ShapeDtypeStruct((), jnp.int32))
    return ProdStep(fn, abstract, "ddp train")


def _opt_shardings(optimizer, abstract_params, p_sh, mesh):
    """Optimizer-state shardings: leaves that mirror a param shape get that
    param's sharding; scalars are replicated."""
    abstract_opt = jax.eval_shape(optimizer.init, abstract_params)
    flat_p = {l.shape: s for l, s in zip(jax.tree.leaves(abstract_params),
                                         jax.tree.leaves(p_sh))}

    def pick(leaf):
        if leaf.shape in flat_p:
            return flat_p[leaf.shape]
        return NamedSharding(mesh, P(*([None] * len(leaf.shape))))

    return jax.tree.map(pick, abstract_opt)


# ---------------------------------------------------------------------------
# LayUp train step (the paper, production form)
# ---------------------------------------------------------------------------


def make_layup_train_step(model: Model, mesh, optimizer: Optimizer,
                          schedule: Callable, shape: ShapeConfig,
                          shifts: Sequence[int] = (1, 2, 4, 8),
                          overrides: Optional[Dict[str, Any]] = None,
                          preset: Optional[str] = None,
                          accum_steps: int = 1,
                          constrain_grads: bool = False) -> ProdStep:
    cfg = model.cfg
    worker_axes = data_axes(mesh)
    # per-leaf model-axis specs (worker prefix stripped) — used to pin the
    # gradients to the parameter sharding so GSPMD reduce-scatters instead
    # of all-reduce+slice (§Perf iteration A3)
    rules_g = SH.rules_for(mesh, overrides, preset)
    from repro.models.layers import is_spec
    grad_specs = jax.tree.map(
        lambda sp: SH.spec_for_axes(tuple(sp.axes), rules_g, mesh,
                                    tuple(sp.shape)),
        model.specs, is_leaf=is_spec)
    ax = worker_axes if len(worker_axes) > 1 else worker_axes[0]
    M = num_workers(mesh)
    shifts = tuple(s % M for s in shifts if s % M != 0) or (1,)

    # layer-group partition shared with the sim backend's v2 hooks: gossip
    # messages are layer groups, not loose leaves (DESIGN.md §1/§2)
    part = LayerPartition(model.abstract_params())

    def gossip_mix(tree, w, shift_idx):
        """Push-sum ring-shift gossip: every worker sends to i+s and receives
        from i−s. Each layer group's leaves are packed into ONE flat f32
        buffer, so the wire carries exactly one collective per layer group
        (f32 is a lossless container for bf16; the mix runs in f32 anyway)."""
        groups = part.split(tree)
        packed, unravel = {}, {}
        for name, sub in groups.items():
            packed[name], unravel[name] = ravel_pytree(
                jax.tree.map(lambda v: v.astype(jnp.float32), sub))

        def branch(s):
            perm = [(i, (i + s) % M) for i in range(M)]

            def run(args):
                packed, w_half = args
                recv = {name: jax.lax.ppermute(v, ax, perm)
                        for name, v in packed.items()}
                rw = jax.lax.ppermute(w_half, ax, perm)
                return recv, rw

            return run

        w_half = w * 0.5
        recv, rw = jax.lax.switch(shift_idx, [branch(s) for s in shifts],
                                  (packed, w_half))
        new_w = w_half + rw
        mixed_groups = {}
        for name, mine in packed.items():
            mixed = (w_half * mine + rw * recv[name]) / new_w
            mixed_groups[name] = jax.tree.map(
                lambda x, ref: x.astype(ref.dtype),
                unravel[name](mixed), groups[name])
        return part.join(mixed_groups), new_w

    def worker_fn(params_st, opt_st, w_st, batch, step_idx, shift_idx):
        params = jax.tree.map(lambda x: x[0], params_st)
        opt_state = jax.tree.map(
            lambda x: x[0] if x.ndim >= 1 else x, opt_st)
        w = w_st[0]
        if accum_steps > 1:
            # microbatched gradient accumulation (§Perf memory lever):
            # activation footprint scales with the microbatch, not the
            # worker batch
            def micro(b):
                return jax.value_and_grad(model.loss_fn, has_aux=True)(
                    params, b)

            mb = jax.tree.map(
                lambda x: x.reshape((accum_steps, x.shape[0] // accum_steps)
                                    + x.shape[1:]), batch)

            def acc_body(carry, b):
                (l, _), g = micro(b)
                return jax.tree.map(lambda a, x: a + x, carry,
                                    {"l": l, "g": g}), ()

            zero = {"l": jnp.zeros((), jnp.float32),
                    "g": jax.tree.map(
                        lambda p: jnp.zeros(p.shape, jnp.float32), params)}
            tot, _ = jax.lax.scan(acc_body, zero, mb)
            loss = tot["l"] / accum_steps
            grads = jax.tree.map(lambda g, p: (g / accum_steps).astype(p.dtype),
                                 tot["g"], params)
        else:
            (loss, _), grads = jax.value_and_grad(model.loss_fn, has_aux=True)(
                params, batch)
        if constrain_grads:
            grads = jax.tree.map(
                lambda g, s: jax.lax.with_sharding_constraint(g, s),
                grads, grad_specs)
        lr = schedule(step_idx)
        updates, opt_state = optimizer.update(grads, opt_state, params, lr)
        params = apply_updates(params, updates)
        params, w = gossip_mix(params, w, shift_idx)
        loss = jax.lax.pmean(loss, worker_axes)
        restack = lambda t: jax.tree.map(lambda x: x[None], t)
        return (restack(params), restack(opt_state), w[None], loss)

    pw = P(worker_axes if len(worker_axes) > 1 else worker_axes[0])
    abstract_params = model.abstract_params()
    stacked_params = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((M,) + s.shape, s.dtype),
        abstract_params)
    abstract_opt_single = jax.eval_shape(optimizer.init, abstract_params)
    stacked_opt = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((M,) + s.shape, s.dtype),
        abstract_opt_single)
    opt_specs = jax.tree.map(lambda _: pw, abstract_opt_single)

    def batch_pspec(s):
        # M-RoPE positions are (3, B, S): worker axis is dim 1
        if len(s.shape) == 3 and s.shape[0] == 3 and s.dtype == jnp.int32:
            return P(None, worker_axes if len(worker_axes) > 1 else worker_axes[0])
        return pw

    batch_specs_sm = jax.tree.map(batch_pspec, _abstract_batch(cfg, shape))
    fn_sm = shard_map(
        worker_fn, mesh=mesh,
        in_specs=(jax.tree.map(lambda _: pw, abstract_params), opt_specs,
                  pw, batch_specs_sm, P(), P()),
        out_specs=(jax.tree.map(lambda _: pw, abstract_params), opt_specs,
                   pw, P()),
        axis_names=set(worker_axes))

    # model-axis sharding flows in through jit in_shardings (auto axis)
    p_sh = SH.param_shardings(model, mesh, stacked_workers=M,
                              overrides=overrides, preset=preset)
    opt_sh = _opt_shardings_stacked(abstract_opt_single, abstract_params,
                                    p_sh, mesh, M)
    batch_abs = _abstract_batch(cfg, shape)
    b_sh = SH.batch_shardings(batch_abs, mesh, overrides=overrides,
                              preset=preset)
    w_sh = NamedSharding(mesh, pw)
    scalar = NamedSharding(mesh, P())

    fn = jax.jit(fn_sm,
                 in_shardings=(p_sh, opt_sh, w_sh, b_sh, scalar, scalar),
                 out_shardings=(p_sh, opt_sh, w_sh, scalar),
                 donate_argnums=(0, 1, 2))
    abstract = (stacked_params, stacked_opt,
                jax.ShapeDtypeStruct((M,), jnp.float32), batch_abs,
                jax.ShapeDtypeStruct((), jnp.int32),
                jax.ShapeDtypeStruct((), jnp.int32))
    return ProdStep(fn, abstract, f"layup train (M={M}, shifts={shifts})")


def _opt_shardings_stacked(abstract_opt_single, abstract_params, p_sh, mesh, M):
    flat_p = {l.shape: s.spec for l, s in zip(jax.tree.leaves(abstract_params),
                                              jax.tree.leaves(p_sh))}
    worker_part = jax.tree.leaves(p_sh)[0].spec[0]  # ('pod','data') part

    def pick(leaf):
        if leaf.shape in flat_p:
            return NamedSharding(mesh, flat_p[leaf.shape])
        return NamedSharding(mesh, P(worker_part,
                                     *([None] * len(leaf.shape))))

    return jax.tree.map(pick, abstract_opt_single)


# ---------------------------------------------------------------------------
# serving steps
# ---------------------------------------------------------------------------


def make_prefill_step(model: Model, mesh, shape: ShapeConfig,
                      overrides: Optional[Dict[str, Any]] = None,
                      preset: Optional[str] = None) -> ProdStep:
    cfg = model.cfg

    def step(params, batch):
        cache, logits = model.prefill_fn(params, batch)
        return cache, logits

    p_sh = SH.param_shardings(model, mesh, overrides=overrides,
                              preset=preset)
    batch_abs = _abstract_batch(cfg, shape)
    b_sh = SH.batch_shardings(batch_abs, mesh, overrides=overrides,
                              preset=preset)
    fn = jax.jit(step, in_shardings=(p_sh, b_sh))
    return ProdStep(fn, (model.abstract_params(), batch_abs), "prefill")


def make_decode_step(model: Model, mesh, shape: ShapeConfig,
                     overrides: Optional[Dict[str, Any]] = None,
                     preset: Optional[str] = None) -> ProdStep:
    cfg = model.cfg
    B = shape.global_batch

    def step(params, cache, token, position):
        logits, new_cache = model.decode_fn(params, cache, token, position)
        return logits, new_cache

    p_sh = SH.param_shardings(model, mesh, overrides=overrides,
                              preset=preset)
    cache_abs = model.cache_specs(B, shape.seq_len)
    c_sh = SH.cache_shardings(cache_abs, mesh, cfg, overrides=overrides,
                              preset=preset)
    rules = SH.rules_for(mesh, overrides, preset)
    db = rules["batch"]
    if db is not None and B % SH._axis_size(mesh, db) != 0:
        db = None  # e.g. long_500k batch=1: replicate over the data axes
    tok_sh = NamedSharding(mesh, P(db, None))
    pos_sh = NamedSharding(mesh, P(db))
    fn = jax.jit(step, in_shardings=(p_sh, c_sh, tok_sh, pos_sh),
                 donate_argnums=(1,))
    abstract = (model.abstract_params(), cache_abs,
                jax.ShapeDtypeStruct((B, 1), jnp.int32),
                jax.ShapeDtypeStruct((B,), jnp.int32))
    return ProdStep(fn, abstract, "decode")


# ---------------------------------------------------------------------------
# dispatcher
# ---------------------------------------------------------------------------


def make_step(model: Model, mesh, shape: ShapeConfig, *, algo: str = "layup",
              optimizer: Optional[Optimizer] = None,
              schedule: Optional[Callable] = None,
              overrides: Optional[Dict[str, Any]] = None,
              shifts: Sequence[int] = (1, 2, 4, 8),
              preset: Optional[str] = None,
              accum_steps: int = 1,
              constrain_grads: bool = False) -> ProdStep:
    from repro.optim import momentum, constant
    optimizer = optimizer or momentum(0.9, state_dtype=model.cfg.dtype)
    schedule = schedule or constant(0.1)
    if shape.kind == "train":
        if algo == "ddp":
            return make_ddp_train_step(model, mesh, optimizer, schedule,
                                       shape, overrides, preset)
        return make_layup_train_step(model, mesh, optimizer, schedule, shape,
                                     shifts, overrides, preset, accum_steps,
                                     constrain_grads)
    if shape.kind == "prefill":
        return make_prefill_step(model, mesh, shape, overrides, preset)
    return make_decode_step(model, mesh, shape, overrides, preset)
