"""Production-backend step builders (pjit / shard_map on the real mesh).

Three distribution strategies, mirroring the paper's comparison:

* **DDP** (baseline): parameters replicated over the ('pod','data') axes,
  tensor-parallel over 'model'. Plain ``jax.jit``: GSPMD inserts the gradient
  all-reduce (2·P·(M−1)/M wire bytes — the synchronization the paper removes).

* **LayUp** (the paper): every data-parallel replica owns a distinct copy of
  the parameters (stacked leading worker axis, sharded over ('pod','data')).
  ``shard_map`` is *manual* over the worker axes and **auto (GSPMD) over
  'model'**, so tensor parallelism composes transparently ("orthogonal to
  model/tensor/pipeline parallelism", paper §1). Gossip is a
  ``collective_permute`` ring shift over the worker axes — the TPU-native
  realization of random-peer gossip (each hop is an ICI-neighbour hop; the
  shift is drawn per step from a static power-of-two set via ``lax.switch``,
  i.e. hypercube gossip — see DESIGN.md §2). Push-sum weights ride along as
  a per-worker scalar. Collectives are issued **per layer group by
  construction**: the parameter tree is partitioned through the same
  ``LayerPartition`` the sim backend's v2 hooks use (DESIGN.md §1), and each
  group's subtree ships as one logical gossip message — the HLO counterpart
  of the paper's layer-wise updates.

* **Decoupled LayUp** (the paper's PD-ASGD execution, production form):
  the per-worker step is assembled from three composable lanes —
  ``forward_lane`` (loss + grads on the *read* parameter buffer, with an
  R:1 forward:backward ratio), ``backward_update_lane`` (a D-deep gradient
  FIFO feeding the optimizer, mutating the *write* buffer), and
  ``gossip_lane`` (the per-layer-group push-sum ring mix). Parameters are
  **double-buffered**: the forward lane consumes the read copy while the
  update lane mutates the write copy; at the end of the step each layer
  group's read copy adopts the mixed write copy ("buffer swap") and its
  version clock is stamped with the group's generation time ``t + phi_g``
  (``send_fractions``). Forward passes at step ``t`` therefore use layer
  groups whose content reflects gradients through step ``t − 1 − D`` — the
  production analogue of the sim trainer's ``fb_ratio``/``update_delay``
  (DESIGN.md §3/§9). DDP and lockstep LayUp are assembled from the same
  lane pieces (R=1, D=0, with/without the gossip lane). By default the
  decoupled state carries the parameters as a **persistent flat plane**
  (one contiguous buffer per layer group, packed once at init through
  :class:`~repro.core.layerview.FlatPartition`): gossip ships the plane
  directly in the params' dtype — no per-step repack, no f32 wire bloat —
  and ``use_pallas`` fuses mix+apply into the ``gossip_mix`` kernel
  (DESIGN.md §11).

Serving: ``make_prefill_step`` / ``make_decode_step`` build the inference
paths (params replicated over data axes, TP over 'model'; decode donates the
KV cache).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
try:  # jax >= 0.5: top-level export with check_vma/axis_names kwargs
    from jax import shard_map as _shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, axis_names):
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_vma=False,
                          axis_names=set(axis_names))
except ImportError:  # jax 0.4.x: experimental API; partial-manual (auto=)
    # subgroup sharding trips an XLA CHECK in this generation, so fall back
    # to fully-manual shard_map — the body sees model-axis-replicated
    # shards (tensor parallelism folds into replication; numerics are
    # unchanged, memory is the 0.4.x price)
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, axis_names):
        return _shard_map(f, mesh, in_specs=in_specs, out_specs=out_specs,
                          check_rep=False)
from jax.sharding import NamedSharding, PartitionSpec as P

from jax.flatten_util import ravel_pytree

from repro.configs.base import ModelConfig, ShapeConfig, input_specs
from repro.core.layerview import (
    FlatPartition, LayerPartition, send_fractions, stamp_groups,
    version_metrics,
)
from repro.kernels.gossip_mix import gossip_mix as _gossip_mix_kernel
from repro.kernels.quantize import dequant_mix as _dequant_mix_kernel
from repro.kernels.quantize import quantize_plane as _quantize_plane_kernel
from repro.kernels.ref import dequant_mix_ref, quantize_plane_ref
from repro.launch import sharding as SH
from repro.launch.mesh import data_axes, num_workers
from repro.models.model import Model
from repro.optim.optimizers import Optimizer, apply_updates


@dataclass
class ProdStep:
    """A lowered-able step: ``fn`` jitted with shardings, plus abstract args.

    ``chaos`` (set by ``make_step(faults=)``) is the
    :class:`repro.chaos.ChaosController` driving the step's fault plan —
    callers apply ``chaos.before_step`` at each host step boundary."""
    fn: Any
    abstract_args: Tuple[Any, ...]
    describe: str = ""
    chaos: Any = None

    def lower(self):
        return self.fn.lower(*self.abstract_args)


def _abstract_batch(cfg: ModelConfig, shape: ShapeConfig, dtype=None):
    return input_specs(cfg, shape, dtype)


# ---------------------------------------------------------------------------
# composable lanes: forward / backward-update / gossip
#
# DDP, lockstep LayUp and decoupled LayUp are assembled from these three
# factories; each returns a pure per-worker function traced inside the
# step (shard_map body for the LayUp paths, plain jit for DDP).
# ---------------------------------------------------------------------------


def _batch_dim(leaf) -> int:
    """Per-leaf batch dimension: M-RoPE positions are (3, B, S) → dim 1,
    everything else leads with the batch dim."""
    if len(leaf.shape) == 3 and leaf.shape[0] == 3 and leaf.dtype == jnp.int32:
        return 1
    return 0


def _worker_batch_pspec(ax):
    """Per-leaf shard_map batch specs: the worker axes land on the leaf's
    batch dim (see :func:`_batch_dim`)."""
    def batch_pspec(s):
        if _batch_dim(s) == 1:
            return P(None, ax)
        return P(ax)
    return batch_pspec


def _split_fwd_slices(batch, R: int):
    """Split a per-worker batch into R equal forward slices along the batch
    dim (slice 0 feeds the backward lane — cf. api._split_fwd_lane)."""
    def slc(x, r):
        d = _batch_dim(x)
        n = x.shape[d]
        if n % R:
            raise ValueError(
                f"fb_ratio={R} needs per-worker batch divisible by {R}; "
                f"got leaf shape {x.shape}")
        return jax.lax.slice_in_dim(x, (n // R) * r, (n // R) * (r + 1),
                                    axis=d)

    return [jax.tree.map(lambda x: slc(x, r), batch) for r in range(R)]


def _apply_grad_specs(grads, grad_specs):
    """Pin gradients to the parameter sharding (reduce-scatter instead of
    all-reduce+slice, §Perf iteration A3). Shared by the monolithic forward
    lane and the per-slice pipeline stages so both compile identical HLO."""
    if grad_specs is None:
        return grads
    try:
        return jax.tree.map(
            lambda g, s: jax.lax.with_sharding_constraint(g, s),
            grads, grad_specs)
    except RuntimeError as e:
        # raw-PartitionSpec constraints need a mesh context; the jax 0.4.x
        # fully-manual shard_map body has none, and the constraint is a
        # no-op there anyway (model axes fold into replication —
        # DESIGN.md §2). Skip only that failure.
        if "non-empty mesh" not in str(e):
            raise
        return grads


def forward_lane(loss_fn: Callable, *, fb_ratio: int = 1,
                 accum_steps: int = 1, grad_specs=None) -> Callable:
    """Forward(+backward-AD) compute on the read buffer.

    Returns ``fwd(params, batch) -> (loss, grads)``. With ``fb_ratio=R > 1``
    the worker batch is split into R slices of which only slice 0 receives a
    backward — the paper's decoupled forward threads, serving data at R× the
    update rate; the reported loss averages all R slices. ``accum_steps``
    microbatches the backward (activation footprint scales with the
    microbatch); it does not compose with R > 1. ``grad_specs`` pins the
    gradients to the parameter sharding so GSPMD reduce-scatters instead of
    all-reduce+slice (§Perf iteration A3)."""
    R = int(fb_ratio)
    if R < 1:
        raise ValueError("fb_ratio must be >= 1")
    if R > 1 and accum_steps > 1:
        raise ValueError("fb_ratio > 1 does not compose with accum_steps")

    def fwd(params, batch):
        if accum_steps > 1:
            def micro(b):
                return jax.value_and_grad(loss_fn, has_aux=True)(params, b)

            mb = jax.tree.map(
                lambda x: x.reshape((accum_steps, x.shape[0] // accum_steps)
                                    + x.shape[1:]), batch)

            def acc_body(carry, b):
                (l, _), g = micro(b)
                return jax.tree.map(lambda a, x: a + x, carry,
                                    {"l": l, "g": g}), ()

            zero = {"l": jnp.zeros((), jnp.float32),
                    "g": jax.tree.map(
                        lambda p: jnp.zeros(p.shape, jnp.float32), params)}
            tot, _ = jax.lax.scan(acc_body, zero, mb)
            loss = tot["l"] / accum_steps
            grads = jax.tree.map(
                lambda g, p: (g / accum_steps).astype(p.dtype),
                tot["g"], params)
        elif R > 1:
            slices = _split_fwd_slices(batch, R)
            (bwd_loss, _), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, slices[0])
            fwd_losses = [loss_fn(params, s)[0] for s in slices[1:]]
            loss = (bwd_loss + sum(fwd_losses)) / R
        else:
            (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch)
        grads = _apply_grad_specs(grads, grad_specs)
        return loss, grads

    return fwd


def forward_slice_lane(loss_fn: Callable, *, fb_ratio: int = 1,
                       slice_idx: int = 0, grad_specs=None) -> Callable:
    """ONE forward slice of the decoupled forward lane, as a standalone
    stage — the unit the pipeline engine (repro.launch.pipeline) compiles
    into its own jitted executable.

    Slice 0 is the backward slice: returns ``(loss, grads)``. Slices
    ``1..R-1`` are forward-only: returns ``(loss, None)``. Slicing uses the
    same :func:`_split_fwd_slices` as the monolithic :func:`forward_lane`,
    so the per-slice math (and therefore the combined loss) is identical —
    the engine's parity with the monolithic step rests on it."""
    R, r = int(fb_ratio), int(slice_idx)
    if not 0 <= r < R:
        raise ValueError(f"slice_idx={r} out of range for fb_ratio={R}")

    def fwd(params, batch):
        s = _split_fwd_slices(batch, R)[r] if R > 1 else batch
        if r == 0:
            (loss, _), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, s)
            return loss, _apply_grad_specs(grads, grad_specs)
        return loss_fn(params, s)[0], None

    return fwd


def backward_update_lane(optimizer: Optimizer, schedule: Callable, *,
                         update_delay: int = 0, apply: bool = True,
                         compensate: float = 0.0) -> Callable:
    """Delayed update application on the write buffer.

    Returns ``upd(params, opt_state, grads, fifo, step_idx) ->
    (params, opt_state, fifo, update_staleness, nonfinite_skips)``.
    ``nonfinite_skips`` counts the layer groups whose delayed gradient
    arrived NaN/Inf this step: those groups' updates are skipped (params
    untouched, optimizer state fed zeros — DESIGN.md §15) instead of
    poisoning the plane. With ``update_delay=D > 0``
    gradients flow through a D-deep FIFO (``{"g": (D, ...) tree in the
    params' dtypes, "stamp": (D,) f32}``): the gradient applied at step
    ``t`` was generated
    at step ``t − D`` (warm-up: the FIFO holds zeros and stamp −1 for the
    first D steps, so early updates are no-ops). Mirrors the sim trainer's
    backward lane exactly (api.make_sim_trainer). ``active`` (scalar 0/1,
    per worker) masks the *application* of the update — the straggler
    emulation of the sim backend (the optimizer state still advances,
    matching api.make_sim_trainer's masked_apply semantics).

    ``apply=False`` returns the (masked) update DELTAS in place of the
    new params — the contract of the fused gossip lane
    (:func:`gossip_fused_lane`), which folds the apply into the mix's
    single memory pass. Params are still consumed read-only (weight
    decay, delayed-gradient dtype).

    ``compensate=λ > 0`` turns on Zheng-style delay compensation
    (DESIGN.md §14): the delayed gradient is corrected by the diagonal
    Hessian approximation ``g' = g + λ·g⊙g⊙(θ_now − θ_stale)`` before the
    optimizer sees it, with ``θ_now − θ_stale`` estimated from the
    version clocks as ``s·(θ_now − θ_prev)`` — ``s`` the measured update
    staleness and ``θ_prev`` ONE carried plane buffer (the previous
    step's pre-update params), not a D-deep tree copy. The lane then
    takes a ``theta`` kwarg and appends ``theta_new`` (this step's
    pre-update params) after ``nonfinite_skips``. At D == 0 the
    stamp-driven staleness is 0 and the correction self-gates to a
    no-op."""
    D = int(update_delay)
    if D < 0:
        raise ValueError("update_delay must be >= 0")
    lam = float(compensate)
    if lam < 0:
        raise ValueError("compensate (λ) must be >= 0")

    def upd(params, opt_state, grads, fifo, step_idx, active=None,
            theta=None):
        step_f = step_idx.astype(jnp.float32)
        if D > 0:
            g_apply = jax.tree.map(lambda b: b[0], fifo["g"])
            applied_stamp = fifo["stamp"][0]
            fifo = {
                "g": jax.tree.map(
                    lambda b, g: jnp.concatenate(
                        [b[1:], g[None].astype(b.dtype)], axis=0),
                    fifo["g"], grads),
                "stamp": jnp.concatenate([fifo["stamp"][1:], step_f[None]]),
            }
            grads = jax.tree.map(lambda g, p: g.astype(p.dtype),
                                 g_apply, params)
            update_staleness = jnp.where(applied_stamp >= 0.0,
                                         step_f - applied_stamp, 0.0)
        else:
            update_staleness = jnp.zeros((), jnp.float32)
        # nonfinite guard (DESIGN.md §15): a NaN/Inf gradient for a layer
        # group is skipped, not applied — sanitized to zero BEFORE the
        # optimizer (where(ok, g, 0), never g·0: Inf·0 is NaN — so the
        # optimizer state stays finite) and its update masked below so the
        # group's params are untouched. For finite gradients both steps
        # are bitwise identity (select-true, u·1.0). In flat mode leaves
        # ARE layer groups, so `skips` counts skipped (worker, group)
        # pairs.
        ok = jax.tree.map(lambda g: jnp.isfinite(g).all(), grads)
        skips = sum(1.0 - o.astype(jnp.float32)
                    for o in jax.tree.leaves(ok))
        skips = jnp.asarray(skips, jnp.float32)
        grads = jax.tree.map(lambda g, o: jnp.where(o, g, jnp.zeros_like(g)),
                             grads, ok)
        if lam > 0.0:
            drift = update_staleness  # θ_now − θ_stale ≈ s·(θ_now − θ_prev)

            def comp(g, p, tp):
                gf = g.astype(jnp.float32)
                delta = drift * (p.astype(jnp.float32)
                                 - tp.astype(jnp.float32))
                return (gf + lam * gf * gf * delta).astype(g.dtype)

            grads = jax.tree.map(comp, grads, params, theta)
        lr = schedule(step_idx)
        updates, opt_state = optimizer.update(grads, opt_state, params, lr)
        # mask the skipped groups' updates too: a sanitized-to-zero grad
        # can still move params through momentum — skip means UNCHANGED
        updates = jax.tree.map(lambda u, o: u * o.astype(u.dtype),
                               updates, ok)
        if active is not None:
            updates = jax.tree.map(lambda u: u * active.astype(u.dtype),
                                   updates)
        out = updates if not apply else apply_updates(params, updates)
        if lam > 0.0:
            return out, opt_state, fifo, update_staleness, skips, params
        return out, opt_state, fifo, update_staleness, skips

    return upd


def fifo_init(params_single, update_delay: int, M: int = 0):
    """Abstract/zero FIFO state: gradients in the params' dtypes plus f32
    generation stamps. Matching the parameter dtype (instead of a fixed
    f32) keeps the D param-sized FIFO slots at the parameter memory
    footprint — for bf16 params the FIFO is half the size, and the
    gradients it carries are quantized exactly like the updates the
    optimizer would apply anyway.

    With ``M > 0`` the gradient buffers are worker-stacked (M, D, ...) —
    the layout the decoupled step state carries."""
    D = int(update_delay)

    def zeros(p):
        shape = ((M, D) if M else (D,)) + tuple(p.shape)
        return jnp.zeros(shape, p.dtype)

    return {"g": jax.tree.map(zeros, params_single),
            "stamp": jnp.full((D,), -1.0, jnp.float32)}


def _resolve_interpret(interpret: Optional[bool]) -> bool:
    """Pallas interpret mode: on by default off-TPU (this container), so
    the same lanes run on CPU CI and real hardware."""
    if interpret is None:
        return jax.default_backend() != "tpu"
    return bool(interpret)


def _ring_exchange(plane, w, shift_idx, M: int, ax, shifts: Sequence[int],
                   alive=None):
    """One push-sum ring hop on the flat plane: ship every group buffer
    (in its own dtype — the wire cost is exactly ``plane_nbytes`` per
    peer) plus the halved push-sum weight.

    Returns ``(recv, w_keep, rw, use)``: the received buffers, the local
    share of the push-sum weight after the hop, the received weight
    share, and a 0/1 gate (``None`` without membership) that is 1 only
    when BOTH this worker and the hop's source are alive — callers must
    fall back to their own buffer when it is 0.

    ``alive`` (a per-worker 0/1 f32 scalar, DESIGN.md §15) gates the
    exchange for fault-tolerant membership: mass is sent only when both
    endpoints are alive (``w_sent = w/2 · a_self · a_tgt`` — a dead
    target would absorb it, leaking Σw out of the live set; a dead
    sender must not inject its stale plane), so Σw over the live peers
    is conserved exactly every round (``w_keep + w_sent`` re-adds the
    identical f32 terms). With every peer alive the gating multiplies by
    1.0 throughout — bitwise identical to the ungated hop."""
    def branch(s):
        perm = [(i, (i + s) % M) for i in range(M)]
        inv = [(i, (i - s) % M) for i in range(M)]

        def run(args):
            plane, w = args
            if alive is None:
                w_sent = w * 0.5
                w_keep = w * 0.5
            else:
                a_tgt = jax.lax.ppermute(alive, ax, inv)
                w_sent = w * 0.5 * (alive * a_tgt)
                w_keep = w - w_sent
            recv = {name: jax.lax.ppermute(v, ax, perm)
                    for name, v in plane.items()}
            rw = jax.lax.ppermute(w_sent, ax, perm)
            # the received w_sent already carries the sender's gating;
            # `use` re-derives it receiver-side (a_src · a_self) as the
            # fall-back-to-own-buffer predicate
            use = (None if alive is None
                   else jax.lax.ppermute(alive, ax, perm) * alive)
            return recv, w_keep, rw, use

        return run

    return jax.lax.switch(shift_idx, [branch(s) for s in shifts],
                          (plane, w))


def gossip_plane_lane(part: FlatPartition, M: int, ax,
                      shifts: Sequence[int], *, use_pallas: bool = False,
                      interpret: Optional[bool] = None,
                      wire: str = "param"):
    """Push-sum ring gossip directly on the persistent flat plane: no
    per-step ravel, no unravel, and the wire dtype IS the plane dtype
    (bf16 params ship half the bytes of the old blanket-f32 wire; the
    push-sum weight accounting stays f32). Returns
    ``mix(plane, w, shift_idx) -> (plane, w)``; the identity when M == 1.

    ``use_pallas`` routes the per-group mix through the fused
    ``gossip_mix`` kernel (pure-mix variant — the update was already
    applied by the backward lane); the default jnp path computes
    ``(w/2·mine + w'/2·recv) / (w/2 + w'/2)`` in f32, bitwise-identical
    per element to the legacy ravel_pytree lane.

    ``wire="int8"`` quantizes the OUTGOING plane (error-feedback
    residual carried in a second per-group plane buffer, DESIGN.md §14)
    and ships ``{q, scales}`` per group instead of the param-dtype
    buffer — ~0.52× the bf16 wire. The local mix operand stays exact;
    only the received side is dequantized. Signature becomes
    ``mix(plane, resid, w, shift_idx) -> (plane, resid, w)`` (identity
    at M == 1 — nothing crosses the wire, nothing is quantized)."""
    interpret = _resolve_interpret(interpret)
    if wire == "int8":
        if M == 1:
            return lambda plane, resid, w, shift_idx, alive=None: (
                plane, resid, w)
        if use_pallas:
            qfn = lambda x, r: _quantize_plane_kernel(
                x, r, interpret=interpret)
            dqfn = lambda x, q, s, a, b: _dequant_mix_kernel(
                x, q, s, None, a, b, interpret=interpret)
        else:
            qfn = quantize_plane_ref
            dqfn = lambda x, q, s, a, b: dequant_mix_ref(x, q, s, None, a, b)

        def mix_q(plane, resid, w, shift_idx, alive=None):
            payload, new_resid = {}, {}
            for name, mine in plane.items():
                q, s, r2 = qfn(mine, resid[name])
                payload[f"q:{name}"] = q
                payload[f"s:{name}"] = s
                new_resid[name] = r2
            recv, w_keep, rw, use = _ring_exchange(payload, w, shift_idx,
                                                   M, ax, shifts, alive)
            new_w = w_keep + rw
            # membership: a dead peer's weight is 0 on both sides of the
            # hop — guard the 0/0 (its buffers are never read again)
            denom = new_w if use is None else jnp.where(new_w > 0.0,
                                                        new_w, 1.0)
            alpha, beta = w_keep / denom, rw / denom
            mixed = {}
            for name, mine in plane.items():
                mx = dqfn(mine, recv[f"q:{name}"], recv[f"s:{name}"],
                          alpha, beta)
                mixed[name] = mx if use is None else jnp.where(
                    use > 0.0, mx, mine)
            return mixed, new_resid, new_w

        return mix_q
    if wire != "param":
        raise ValueError(f"unknown wire dtype {wire!r}")
    if M == 1:
        return lambda plane, w, shift_idx, alive=None: (plane, w)

    def mix(plane, w, shift_idx, alive=None):
        recv, w_keep, rw, use = _ring_exchange(plane, w, shift_idx, M, ax,
                                               shifts, alive)
        new_w = w_keep + rw
        denom = new_w if use is None else jnp.where(new_w > 0.0, new_w, 1.0)
        mixed = {}
        for name, mine in plane.items():
            if use_pallas:
                mx = _gossip_mix_kernel(
                    mine, recv[name], None, w_keep / denom, rw / denom,
                    interpret=interpret)
            else:
                mf = (w_keep * mine.astype(jnp.float32)
                      + rw * recv[name].astype(jnp.float32)) / denom
                mx = mf.astype(mine.dtype)
            mixed[name] = mx if use is None else jnp.where(use > 0.0, mx,
                                                           mine)
        return mixed, new_w

    return mix


def gossip_fused_lane(part: FlatPartition, M: int, ax,
                      shifts: Sequence[int], *, use_pallas: bool = True,
                      interpret: Optional[bool] = None,
                      wire: str = "param"):
    """The paper's Alg. 1 ordering, fused: ship the PRE-update plane, then
    one pass per group computes ``mixed = α·x + β·recv + upd`` (3 reads +
    1 write — the memory-bound op the ``gossip_mix`` Pallas kernel was
    written for; separate apply-then-mix costs 4 reads + 2 writes).
    Returns ``mix_apply(plane, updates, w, shift_idx) -> (plane, w)``.

    Note the semantic difference from the default lane: a worker's own
    update reaches its peers one ring hop later (it is not mixed into the
    outgoing message). Both orderings are valid push-sum ASGD; the fused
    lane is the kernel's contract and is selected by ``use_pallas`` on
    the decoupled paths. At M == 1 it degenerates to a fused
    ``x + upd`` apply (α=1, β=0), still through the kernel.

    ``wire="int8"`` quantizes the outgoing pre-update plane (EF residual
    carried forward, DESIGN.md §14) and fuses receive-side dequantize
    into the same single mix pass (``dequant_mix`` kernel). Signature
    becomes ``mix_apply(plane, resid, updates, w, shift_idx) ->
    (plane, resid, w)``; at M == 1 the residual passes through
    untouched."""
    interpret = _resolve_interpret(interpret)
    if use_pallas:
        op = lambda x, r, u, a, b: _gossip_mix_kernel(
            x, r, u, a, b, interpret=interpret)
    else:
        from repro.kernels.ref import gossip_mix_ref as op
    if wire == "int8":
        if use_pallas:
            qfn = lambda x, r: _quantize_plane_kernel(
                x, r, interpret=interpret)
            dqfn = lambda x, q, s, u, a, b: _dequant_mix_kernel(
                x, q, s, u, a, b, interpret=interpret)
        else:
            qfn = quantize_plane_ref
            dqfn = dequant_mix_ref

        def mix_apply_q(plane, resid, updates, w, shift_idx, alive=None):
            if M == 1:
                mixed = {name: op(x, x, updates[name], jnp.float32(1.0),
                                  jnp.float32(0.0))
                         for name, x in plane.items()}
                return mixed, resid, w
            payload, new_resid = {}, {}
            for name, mine in plane.items():
                q, s, r2 = qfn(mine, resid[name])
                payload[f"q:{name}"] = q
                payload[f"s:{name}"] = s
                new_resid[name] = r2
            recv, w_keep, rw, use = _ring_exchange(payload, w, shift_idx,
                                                   M, ax, shifts, alive)
            new_w = w_keep + rw
            denom = new_w if use is None else jnp.where(new_w > 0.0,
                                                        new_w, 1.0)
            alpha, beta = w_keep / denom, rw / denom
            mixed = {}
            for name, x in plane.items():
                mx = dqfn(x, recv[f"q:{name}"], recv[f"s:{name}"],
                          updates[name], alpha, beta)
                if use is not None:
                    # degraded hop: still apply the local update (α=1,
                    # β=0), just don't mix in the dead source's payload
                    own = op(x, x, updates[name], jnp.float32(1.0),
                             jnp.float32(0.0))
                    mx = jnp.where(use > 0.0, mx, own)
                mixed[name] = mx
            return mixed, new_resid, new_w

        return mix_apply_q
    if wire != "param":
        raise ValueError(f"unknown wire dtype {wire!r}")

    def mix_apply(plane, updates, w, shift_idx, alive=None):
        if M == 1:
            mixed = {name: op(x, x, updates[name], jnp.float32(1.0),
                              jnp.float32(0.0))
                     for name, x in plane.items()}
            return mixed, w
        recv, w_keep, rw, use = _ring_exchange(plane, w, shift_idx, M, ax,
                                               shifts, alive)
        new_w = w_keep + rw
        denom = new_w if use is None else jnp.where(new_w > 0.0, new_w, 1.0)
        alpha, beta = w_keep / denom, rw / denom
        mixed = {}
        for name, x in plane.items():
            mx = op(x, recv[name], updates[name], alpha, beta)
            if use is not None:
                own = op(x, x, updates[name], jnp.float32(1.0),
                         jnp.float32(0.0))
                mx = jnp.where(use > 0.0, mx, own)
            mixed[name] = mx
        return mixed, new_w

    return mix_apply


def gossip_lane(part: FlatPartition, M: int, ax, shifts: Sequence[int], *,
                use_pallas: bool = False,
                interpret: Optional[bool] = None):
    """Tree-level gossip for the lockstep LayUp step (whose state stays a
    parameter pytree): pack each layer group through the shared
    :class:`FlatPartition` layout, mix on the flat buffers, unpack. One
    collective per layer group, in the params' dtype — the decoupled
    lanes skip the per-call pack entirely by keeping the plane persistent
    (``gossip_plane_lane``). Returns ``mix(tree, w, shift_idx) ->
    (tree, w)``; the identity when M == 1."""
    if M == 1:
        return lambda tree, w, shift_idx, alive=None: (tree, w)
    plane_mix = gossip_plane_lane(part, M, ax, shifts,
                                  use_pallas=use_pallas,
                                  interpret=interpret)

    def mix(tree, w, shift_idx, alive=None):
        plane, w = plane_mix(part.pack(tree), w, shift_idx, alive=alive)
        return part.unpack(plane), w

    return mix


def gossip_lane_legacy(part: LayerPartition, M: int, ax,
                       shifts: Sequence[int]):
    """The pre-flat-plane gossip lane: re-packs every layer group with
    ``ravel_pytree`` on EVERY step and ships a blanket-f32 wire. Kept as
    the baseline side of ``benchmarks/gossip_path.py`` and behind the
    decoupled builders' ``flat=False`` escape hatch (which also retains
    per-leaf model-axis sharding of the parameters — the flat plane
    replicates them over 'model', see DESIGN.md §11). Returns
    ``mix(tree, w, shift_idx) -> (tree, w)``; the identity when M == 1."""
    if M == 1:
        return lambda tree, w, shift_idx, alive=None: (tree, w)

    def mix(tree, w, shift_idx, alive=None):
        if alive is not None:
            raise ValueError("membership needs the flat plane (flat=True)")
        groups = part.split(tree)
        packed, unravel = {}, {}
        for name, sub in groups.items():
            packed[name], unravel[name] = ravel_pytree(
                jax.tree.map(lambda v: v.astype(jnp.float32), sub))

        recv, w_keep, rw, _ = _ring_exchange(packed, w, shift_idx, M, ax,
                                             shifts)
        new_w = w_keep + rw
        mixed_groups = {}
        for name, mine in packed.items():
            mixed = (w_keep * mine + rw * recv[name]) / new_w
            mixed_groups[name] = jax.tree.map(
                lambda x, ref: x.astype(ref.dtype),
                unravel[name](mixed), groups[name])
        return part.join(mixed_groups), new_w

    return mix


# ---------------------------------------------------------------------------
# DDP train step (baseline)
# ---------------------------------------------------------------------------


def make_ddp_train_step(model: Model, mesh, optimizer: Optimizer,
                        schedule: Callable, shape: ShapeConfig,
                        overrides: Optional[Dict[str, Any]] = None,
                        preset: Optional[str] = None) -> ProdStep:
    cfg = model.cfg
    fwd = forward_lane(model.loss_fn)
    upd = backward_update_lane(optimizer, schedule)

    def step(params, opt_state, batch, step_idx):
        loss, grads = fwd(params, batch)
        params, opt_state, _, _, _ = upd(params, opt_state, grads, (),
                                         step_idx)
        return params, opt_state, loss

    p_sh = SH.param_shardings(model, mesh, overrides=overrides,
                              preset=preset)
    abstract_params = model.abstract_params()
    abstract_opt = jax.eval_shape(optimizer.init, abstract_params)
    opt_sh = _opt_shardings(optimizer, abstract_params, p_sh, mesh)
    batch_abs = _abstract_batch(cfg, shape)
    b_sh = SH.batch_shardings(batch_abs, mesh, overrides=overrides,
                              preset=preset)
    scalar = NamedSharding(mesh, P())
    fn = jax.jit(step,
                 in_shardings=(p_sh, opt_sh, b_sh, scalar),
                 out_shardings=(p_sh, opt_sh, scalar),
                 donate_argnums=(0, 1))
    abstract = (abstract_params, abstract_opt, batch_abs,
                jax.ShapeDtypeStruct((), jnp.int32))
    return ProdStep(fn, abstract, "ddp train")


def _param_path_index(abstract_params, per_param):
    """{param tree-path → (shape, per-param value)} for suffix matching."""
    flat_p, _ = jax.tree_util.tree_flatten_with_path(abstract_params)
    vals = jax.tree.leaves(per_param)
    return {jax.tree_util.keystr(path): (leaf.shape, v)
            for (path, leaf), v in zip(flat_p, vals)}


def _match_param(path, leaf, index):
    """Optimizer states nest the param tree under wrapper keys ("mu"/"nu"
    slots, etc.): match the longest tree-path *suffix* that names a param of
    the same shape. Keying by path (not leaf.shape) keeps two identically
    shaped params with different shardings from colliding (last-wins)."""
    for i in range(len(path)):
        hit = index.get(jax.tree_util.keystr(path[i:]))
        if hit is not None and hit[0] == leaf.shape:
            return hit[1]
    return None


def _opt_shardings(optimizer, abstract_params, p_sh, mesh):
    """Optimizer-state shardings: leaves whose tree path mirrors a param
    path (module-prefix-stripped) get that param's sharding; the rest are
    replicated."""
    abstract_opt = jax.eval_shape(optimizer.init, abstract_params)
    index = _param_path_index(abstract_params, p_sh)
    flat_o, treedef = jax.tree_util.tree_flatten_with_path(abstract_opt)
    out = []
    for path, leaf in flat_o:
        sh = _match_param(path, leaf, index)
        if sh is None:
            sh = NamedSharding(mesh, P(*([None] * len(leaf.shape))))
        out.append(sh)
    return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------
# LayUp train step (the paper, production form)
# ---------------------------------------------------------------------------


def make_layup_train_step(model: Model, mesh, optimizer: Optimizer,
                          schedule: Callable, shape: ShapeConfig,
                          shifts: Sequence[int] = (1, 2, 4, 8),
                          overrides: Optional[Dict[str, Any]] = None,
                          preset: Optional[str] = None,
                          accum_steps: int = 1,
                          constrain_grads: bool = False,
                          use_pallas: bool = False) -> ProdStep:
    cfg = model.cfg
    worker_axes = data_axes(mesh)
    # per-leaf model-axis specs (worker prefix stripped) — used to pin the
    # gradients to the parameter sharding so GSPMD reduce-scatters instead
    # of all-reduce+slice (§Perf iteration A3)
    rules_g = SH.rules_for(mesh, overrides, preset)
    from repro.models.layers import is_spec
    grad_specs = jax.tree.map(
        lambda sp: SH.spec_for_axes(tuple(sp.axes), rules_g, mesh,
                                    tuple(sp.shape)),
        model.specs, is_leaf=is_spec)
    ax = worker_axes if len(worker_axes) > 1 else worker_axes[0]
    M = num_workers(mesh)
    shifts = tuple(s % M for s in shifts if s % M != 0) or (1,)

    # layer-group partition shared with the sim backend's v2 hooks: gossip
    # messages are layer groups, not loose leaves (DESIGN.md §1/§2). The
    # FlatPartition layout makes each group ONE wire buffer in the params'
    # dtype (DESIGN.md §11).
    part = FlatPartition(model.abstract_params())
    fwd = forward_lane(model.loss_fn, accum_steps=accum_steps,
                       grad_specs=grad_specs if constrain_grads else None)
    upd = backward_update_lane(optimizer, schedule)
    mix = gossip_lane(part, M, ax, shifts, use_pallas=use_pallas)

    def worker_fn(params_st, opt_st, w_st, batch, step_idx, shift_idx):
        params = jax.tree.map(lambda x: x[0], params_st)
        opt_state = jax.tree.map(
            lambda x: x[0] if x.ndim >= 1 else x, opt_st)
        w = w_st[0]
        loss, grads = fwd(params, batch)
        params, opt_state, _, _, _ = upd(params, opt_state, grads, (),
                                         step_idx)
        params, w = mix(params, w, shift_idx)
        loss = jax.lax.pmean(loss, worker_axes)
        restack = lambda t: jax.tree.map(lambda x: x[None], t)
        return (restack(params), restack(opt_state), w[None], loss)

    pw = P(worker_axes if len(worker_axes) > 1 else worker_axes[0])
    abstract_params = model.abstract_params()
    stacked_params = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((M,) + s.shape, s.dtype),
        abstract_params)
    abstract_opt_single = jax.eval_shape(optimizer.init, abstract_params)
    stacked_opt = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((M,) + s.shape, s.dtype),
        abstract_opt_single)
    opt_specs = jax.tree.map(lambda _: pw, abstract_opt_single)

    batch_specs_sm = jax.tree.map(_worker_batch_pspec(ax),
                                  _abstract_batch(cfg, shape))
    fn_sm = shard_map(
        worker_fn, mesh=mesh,
        in_specs=(jax.tree.map(lambda _: pw, abstract_params), opt_specs,
                  pw, batch_specs_sm, P(), P()),
        out_specs=(jax.tree.map(lambda _: pw, abstract_params), opt_specs,
                   pw, P()),
        axis_names=set(worker_axes))

    # model-axis sharding flows in through jit in_shardings (auto axis)
    p_sh = SH.param_shardings(model, mesh, stacked_workers=M,
                              overrides=overrides, preset=preset)
    opt_sh = _opt_shardings_stacked(abstract_opt_single, abstract_params,
                                    p_sh, mesh, M)
    batch_abs = _abstract_batch(cfg, shape)
    b_sh = SH.batch_shardings(batch_abs, mesh, overrides=overrides,
                              preset=preset)
    w_sh = NamedSharding(mesh, pw)
    scalar = NamedSharding(mesh, P())

    fn = jax.jit(fn_sm,
                 in_shardings=(p_sh, opt_sh, w_sh, b_sh, scalar, scalar),
                 out_shardings=(p_sh, opt_sh, w_sh, scalar),
                 donate_argnums=(0, 1, 2))
    abstract = (stacked_params, stacked_opt,
                jax.ShapeDtypeStruct((M,), jnp.float32), batch_abs,
                jax.ShapeDtypeStruct((), jnp.int32),
                jax.ShapeDtypeStruct((), jnp.int32))
    return ProdStep(fn, abstract, f"layup train (M={M}, shifts={shifts})")


def _opt_shardings_stacked(abstract_opt_single, abstract_params, p_sh, mesh, M):
    index = _param_path_index(abstract_params,
                              [s.spec for s in jax.tree.leaves(p_sh)])
    worker_part = jax.tree.leaves(p_sh)[0].spec[0]  # ('pod','data') part
    flat_o, treedef = jax.tree_util.tree_flatten_with_path(
        abstract_opt_single)
    out = []
    for path, leaf in flat_o:
        spec = _match_param(path, leaf, index)
        if spec is None:
            spec = P(worker_part, *([None] * len(leaf.shape)))
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------
# Decoupled LayUp train step (PD-ASGD execution, production form)
# ---------------------------------------------------------------------------


def _decoupled_worker_fn(part: LayerPartition, fwd: Callable, upd: Callable,
                         mix: Callable, M: int, worker_axes, D: int,
                         squeeze_batch: bool = False,
                         active_fn: Optional[Callable] = None,
                         flat: bool = False,
                         fused_mix: Optional[Callable] = None,
                         wire: str = "param",
                         compensate: float = 0.0,
                         membership: bool = False):
    """Per-worker decoupled step body (traced inside shard_map).

    Arguments arrive worker-stacked with a leading axis of 1 (the shard):
    ``(read, write, opt, w, versions[, fifo_g, fifo_stamp][, resid]
    [, theta][, alive], batch, step_idx, shift_idx)`` — the fifo args are
    present iff ``D > 0``, the error-feedback residual plane iff
    ``wire="int8"``, the stale-θ reference plane iff ``compensate > 0``
    (DESIGN.md §14), and the per-worker 0/1 ``alive`` membership mask iff
    ``membership`` (DESIGN.md §15: a dead peer's updates are masked, its
    version clocks freeze, the gossip hop is alive-gated, and the loss is
    averaged over the live peers only). The three lanes compose: forward
    on the READ buffer, delayed
    update on the WRITE buffer, gossip on the updated write copy, then
    the per-layer-group buffer swap (read adopts each mixed group; its
    clock is stamped ``t + phi_g``).

    ``flat=True`` (the default route, DESIGN.md §11): read/write/opt/fifo
    are flat planes (``part`` is a :class:`FlatPartition`); the forward
    consumes the unpacked slice/reshape view of the read plane, gradients
    are packed ONCE right after AD, and everything downstream — FIFO,
    optimizer, gossip — runs on the plane. ``fused_mix`` (the
    ``use_pallas`` route) replaces apply-then-mix with the fused Alg. 1
    single pass; ``upd`` must then have been built with ``apply=False``."""
    phi = jnp.asarray(send_fractions(part.num_groups))
    unstack = lambda t: jax.tree.map(lambda x: x[0], t)
    unstack_opt = lambda t: jax.tree.map(
        lambda x: x[0] if x.ndim >= 1 else x, t)
    restack = lambda t: jax.tree.map(lambda x: x[None], t)
    int8 = wire == "int8"
    comp = float(compensate) > 0.0

    def worker_fn(*args):
        (read_st, write_st, opt_st, w_st, versions) = args[:5]
        i = 5
        if D > 0:
            fifo = {"g": unstack(args[5]), "stamp": args[6]}
            i = 7
        else:
            fifo = ()
        resid = None
        if int8:
            resid = unstack(args[i])
            i += 1
        theta = None
        if comp:
            theta = unstack(args[i])
            i += 1
        alive_st, a = None, None
        if membership:
            alive_st = args[i]
            a = alive_st[0]
            i += 1
        batch, step_idx, shift_idx = args[i:]
        read = unstack(read_st)
        write = unstack(write_st)
        opt_state = unstack_opt(opt_st)
        w = w_st[0]
        if squeeze_batch:  # sim-layout batches carry a leading worker axis
            batch = unstack(batch)

        # forward lane: consumes the read buffer (content = updates through
        # step t − 1 − D; never sees the write buffer mid-mutation). In
        # flat mode the read plane is unpacked into the tree view here
        # (static slices — XLA fuses them into the forward) and the
        # gradients are packed once, right out of AD.
        loss, grads = fwd(part.unpack(read) if flat else read, batch)
        if flat:
            grads = part.pack(grads)
        active = active_fn(step_idx) if active_fn is not None else None
        if fused_mix is not None:
            # fused route: the backward lane yields the update DELTAS and
            # the gossip lane folds apply+mix into one pass per group
            upd_out = upd(write, opt_state, grads, fifo, step_idx,
                          active=active, theta=theta) if comp else \
                upd(write, opt_state, grads, fifo, step_idx, active=active)
            updates, opt_state, fifo, upd_stale, skips = upd_out[:5]
            if comp:
                theta = upd_out[5]
            if membership:
                # a dead peer applies no updates (its replica is frozen
                # until donor re-sync). A SELECT, not `u·a`: an arithmetic
                # gate changes XLA's FMA contraction and breaks the
                # empty-plan bit-exactness; where(1.0, u, 0) is the
                # identity bit-for-bit
                updates = jax.tree.map(
                    lambda u: jnp.where(a > 0.0, u, jnp.zeros_like(u)),
                    updates)
            if int8:
                write, resid, w = fused_mix(write, resid, updates, w,
                                            shift_idx, alive=a)
            else:
                write, w = fused_mix(write, updates, w, shift_idx, alive=a)
        else:
            # backward/update lane: delayed gradient lands on the write
            # buffer, then the per-layer-group push-sum ring mix
            write_prev = write
            upd_out = upd(write, opt_state, grads, fifo, step_idx,
                          active=active, theta=theta) if comp else \
                upd(write, opt_state, grads, fifo, step_idx, active=active)
            write, opt_state, fifo, upd_stale, skips = upd_out[:5]
            if comp:
                theta = upd_out[5]
            if membership:
                # dead peer: params frozen until donor re-sync — a select
                # (bit-transparent when alive), never an arithmetic mask
                write = jax.tree.map(
                    lambda n, o: jnp.where(a > 0.0, n, o),
                    write, write_prev)
            if int8:
                write, resid, w = mix(write, resid, w, shift_idx, alive=a)
            else:
                write, w = mix(write, w, shift_idx, alive=a)
        # buffer swap: the read copy adopts the mixed write copy and each
        # group clock is stamped with its generation time t + phi_g. In the
        # real async system this is a per-group pointer flip as each
        # delayed gradient lands mid-backward; in the jitted step the swap
        # is the state carry (read == write at every step boundary — all
        # numeric staleness lives in the gradient FIFO, which is what keeps
        # R=1/D=0 exactly equal to the sim trainer). On the ring every
        # worker receives every step; with M == 1 nothing is received.
        read = write
        if M > 1:
            stamped = stamp_groups(versions,
                                   step_idx.astype(jnp.float32) + phi)
            # a dead peer's clocks freeze at its last live generation —
            # the serving health gate keys off this (DESIGN.md §15)
            versions = stamped if not membership else jnp.where(
                a > 0.0, stamped, versions)
        if membership:
            # loss over the live peers only (a dead peer's forward output
            # is meaningless); with every peer alive this is bitwise
            # pmean: psum(loss·1.0)/psum(1.0) == psum(loss)/M
            loss = (jax.lax.psum(loss * a, worker_axes)
                    / jax.lax.psum(a, worker_axes))
        else:
            loss = jax.lax.pmean(loss, worker_axes)
        # skips differ per worker (one peer's NaN is everyone's metric):
        # psum so the P() out spec is sound
        skips = jax.lax.psum(skips, worker_axes)
        outs = [restack(read), restack(write), restack(opt_state), w[None],
                versions]
        if D > 0:
            outs += [restack(fifo["g"]), fifo["stamp"]]
        if int8:
            outs += [restack(resid)]
        if comp:
            outs += [restack(theta)]
        if membership:
            outs += [alive_st]
        return tuple(outs) + (loss, upd_stale, skips)

    return worker_fn


def make_decoupled_state(params_stacked, optimizer, *, update_delay: int = 0,
                         part: Optional[LayerPartition] = None,
                         flat: bool = True, wire: str = "param",
                         compensate: float = 0.0,
                         membership: bool = False):
    """Initial step state for the decoupled lane.

    ``read`` and ``write`` start as identical copies. Both are fresh
    buffers (the step donates its state, so it must not alias the caller's
    ``params_stacked``, and read/write must not alias each other); the
    gradient FIFO holds zeros with stamp −1 (warm-up no-ops).

    With ``flat=True`` (the default — must match the step builder's flag)
    this is THE pack: params are packed into the persistent per-group
    plane here, once, and never repacked again — the step carries, mixes
    and donates the plane itself; the optimizer state and the gradient
    FIFO are allocated directly in plane layout (DESIGN.md §11).

    ``wire="int8"`` adds the zero-initialized error-feedback residual
    plane (``state["resid"]``, plane dtype); ``compensate > 0`` adds the
    stale-θ reference plane (``state["theta"]``, a copy of the initial
    params — the θ_prev of step 0); ``membership`` adds the per-worker
    0/1 ``alive`` mask (all ones — the chaos controller mutates it at
    fault events, DESIGN.md §15). All are flat-plane machinery and
    require ``flat=True``."""
    M = jax.tree_util.tree_leaves(params_stacked)[0].shape[0]
    single = jax.tree.map(lambda x: x[0], params_stacked)
    D = int(update_delay)
    if (wire == "int8" or float(compensate) > 0.0 or membership) \
            and not flat:
        raise ValueError("wire='int8' / compensate / membership need the "
                         "flat plane (flat=True)")
    if flat:
        if part is None:
            part = FlatPartition(single)
        elif not isinstance(part, FlatPartition):
            raise ValueError("flat=True needs a FlatPartition")
        # one pack, two copies: read and write must not alias each other
        # (the step donates both) nor the caller's buffers — jnp.copy
        # also guards the single-leaf-group case where pack's reshape can
        # be the identity
        plane = part.pack(params_stacked)
        read = jax.tree.map(jnp.copy, plane)
        state = {
            "read": read,
            "write": jax.tree.map(jnp.copy, plane),
            "opt": jax.vmap(optimizer.init)(read),
            "w": jnp.full((M,), 1.0 / M, jnp.float32),
            "versions": part.init_versions(M),
        }
        if D > 0:
            state["fifo"] = fifo_init(part.pack(single), D, M)
        if wire == "int8":
            state["resid"] = jax.tree.map(jnp.zeros_like, plane)
        if float(compensate) > 0.0:
            state["theta"] = jax.tree.map(jnp.copy, plane)
        if membership:
            state["alive"] = jnp.ones((M,), jnp.float32)
        return state
    part = part or LayerPartition(single)
    state = {
        "read": jax.tree.map(jnp.copy, params_stacked),
        "write": jax.tree.map(jnp.copy, params_stacked),
        "opt": jax.vmap(optimizer.init)(params_stacked),
        "w": jnp.full((M,), 1.0 / M, jnp.float32),
        "versions": part.init_versions(M),
    }
    if D > 0:
        state["fifo"] = fifo_init(single, D, M)
    return state


def _decoupled_metrics(w, versions, loss, upd_stale, step_idx, skips=None,
                       alive=None):
    out = {"loss": loss, "update_staleness": upd_stale,
           "weight_sum": jnp.sum(w)}
    if skips is not None:
        out["nonfinite_skips"] = skips
    if alive is not None:
        out["peers_live"] = jnp.sum(alive)
    out.update(version_metrics(versions, step_idx))
    return out


def _check_wire(wire: str, compensate: float, flat: bool,
                membership: bool = False) -> None:
    """Shared validation for the quantized-wire / delay-compensation /
    membership knobs (all flat-plane machinery — DESIGN.md §14/§15)."""
    if wire not in ("param", "int8"):
        raise ValueError(f"unknown wire dtype {wire!r} "
                         "(expected 'param' or 'int8')")
    if float(compensate) < 0.0:
        raise ValueError("compensate (λ) must be >= 0")
    if (wire == "int8" or float(compensate) > 0.0 or membership) \
            and not flat:
        raise ValueError("wire='int8' / compensate > 0 / faults need the "
                         "flat plane (flat=True)")


def _decoupled_state_specs(D: int, pw, wire: str = "param",
                           compensate: float = 0.0,
                           membership: bool = False):
    """shard_map specs for the flattened decoupled state
    (read, write, opt, w, versions[, fifo_g, fifo_stamp][, resid]
    [, theta][, alive])."""
    extra = (int(wire == "int8") + int(float(compensate) > 0.0)
             + int(membership))
    return [pw] * 5 + ([pw, P()] if D > 0 else []) + [pw] * extra


def _decoupled_step_caller(fn_sm, D: int, wire: str = "param",
                           compensate: float = 0.0,
                           membership: bool = False):
    """Adapt the flat shard_map'd worker fn to the dict state + metrics
    step signature shared by both decoupled entry points."""
    int8 = wire == "int8"
    comp = float(compensate) > 0.0

    def step(state, batch, step_idx, shift_idx):
        args = [state["read"], state["write"], state["opt"], state["w"],
                state["versions"]]
        if D > 0:
            args += [state["fifo"]["g"], state["fifo"]["stamp"]]
        if int8:
            args += [state["resid"]]
        if comp:
            args += [state["theta"]]
        if membership:
            args += [state["alive"]]
        outs = fn_sm(*args, batch, step_idx, shift_idx)
        read, write, opt, w, versions = outs[:5]
        loss, upd_stale, skips = outs[-3:]
        new_state = {"read": read, "write": write, "opt": opt, "w": w,
                     "versions": versions}
        i = 5
        if D > 0:
            new_state["fifo"] = {"g": outs[5], "stamp": outs[6]}
            i = 7
        if int8:
            new_state["resid"] = outs[i]
            i += 1
        if comp:
            new_state["theta"] = outs[i]
            i += 1
        alive = None
        if membership:
            new_state["alive"] = alive = outs[i]
            i += 1
        return new_state, _decoupled_metrics(w, versions, loss, upd_stale,
                                             step_idx, skips=skips,
                                             alive=alive)

    return step


def make_layup_decoupled_train_step(model: Model, mesh, optimizer: Optimizer,
                                    schedule: Callable, shape: ShapeConfig,
                                    shifts: Sequence[int] = (1, 2, 4, 8),
                                    overrides: Optional[Dict[str, Any]] = None,
                                    preset: Optional[str] = None,
                                    fb_ratio: int = 2,
                                    update_delay: int = 1,
                                    constrain_grads: bool = False,
                                    flat: bool = True,
                                    use_pallas: bool = False,
                                    wire: str = "param",
                                    compensate: float = 0.0,
                                    membership: bool = False) -> ProdStep:
    """The paper's decoupled execution on the real mesh.

    Step signature: ``fn(state, batch, step_idx, shift_idx) -> (state,
    metrics)`` where ``state`` is the dict built by
    :func:`make_decoupled_state` (double-buffered params + opt state +
    push-sum weights + per-group version clocks + D-deep gradient FIFO) and
    ``metrics`` carries loss / update_staleness / layer_staleness /
    staleness_mean / weight_sum — the same accounting the sim trainer
    reports, so sim-vs-prod parity is assertable key by key.

    ``flat=True`` (default): the state's parameter buffers are the
    persistent per-group flat plane (packed once in
    :func:`make_decoupled_state`) — gossip ships the plane directly in
    the params' dtype, no per-step ravel/unravel, and the plane is
    replicated over the 'model' axis (per-leaf tensor-parallel param
    sharding needs ``flat=False`` — DESIGN.md §11). ``use_pallas`` routes
    mix+apply through the fused ``gossip_mix`` kernel
    (:func:`gossip_fused_lane`; Alg. 1 ordering).

    ``wire="int8"`` quantizes the gossip wire with an error-feedback
    residual plane carried in the state; ``compensate=λ > 0`` turns on
    the staleness-aware delay compensation in the backward lane
    (DESIGN.md §14); ``membership`` compiles the fault-tolerant
    alive-gated lane (per-worker ``alive`` mask in the state, live-set
    push-sum renormalization, frozen dead-peer clocks — DESIGN.md §15).
    All require ``flat=True``."""
    cfg = model.cfg
    worker_axes = data_axes(mesh)
    ax = worker_axes if len(worker_axes) > 1 else worker_axes[0]
    M = num_workers(mesh)
    R, D = int(fb_ratio), int(update_delay)
    if shape.global_batch % (M * max(R, 1)):
        raise ValueError(
            f"global_batch={shape.global_batch} must divide by "
            f"M*R={M}*{R} for the decoupled forward lane")
    shifts = tuple(s % M for s in shifts if s % M != 0) or (1,)

    grad_specs = None
    if constrain_grads:
        rules_g = SH.rules_for(mesh, overrides, preset)
        from repro.models.layers import is_spec
        grad_specs = jax.tree.map(
            lambda sp: SH.spec_for_axes(tuple(sp.axes), rules_g, mesh,
                                        tuple(sp.shape)),
            model.specs, is_leaf=is_spec)

    if use_pallas and not flat:
        raise ValueError("use_pallas requires the flat plane (flat=True)")
    _check_wire(wire, compensate, flat, membership)
    part = FlatPartition(model.abstract_params())
    fwd = forward_lane(model.loss_fn, fb_ratio=R, grad_specs=grad_specs)
    upd = backward_update_lane(optimizer, schedule, update_delay=D,
                               apply=not use_pallas, compensate=compensate)
    if use_pallas:
        mix, fused = None, gossip_fused_lane(part, M, ax, shifts, wire=wire)
    elif flat:
        mix, fused = gossip_plane_lane(part, M, ax, shifts, wire=wire), None
    else:
        mix, fused = gossip_lane_legacy(part, M, ax, shifts), None
    worker_fn = _decoupled_worker_fn(part, fwd, upd, mix, M, worker_axes, D,
                                     flat=flat, fused_mix=fused, wire=wire,
                                     compensate=compensate,
                                     membership=membership)

    pw = P(ax)
    abstract_params = model.abstract_params()
    stack = lambda s: jax.ShapeDtypeStruct((M,) + tuple(s.shape), s.dtype)
    abstract_opt_base = part.abstract_plane() if flat else abstract_params
    if flat:
        stacked_params = part.abstract_plane((M,))
        fifo_g_abs = part.abstract_plane((M, D))
    else:
        stacked_params = jax.tree.map(stack, abstract_params)
        fifo_g_abs = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((M, D) + tuple(s.shape), s.dtype),
            abstract_params)
    abstract_opt_single = jax.eval_shape(optimizer.init, abstract_opt_base)
    stacked_opt = jax.tree.map(stack, abstract_opt_single)
    abstract_state = {
        "read": stacked_params,
        "write": stacked_params,
        "opt": stacked_opt,
        "w": jax.ShapeDtypeStruct((M,), jnp.float32),
        "versions": jax.ShapeDtypeStruct((M, part.num_groups), jnp.float32),
    }
    if D > 0:
        abstract_state["fifo"] = {
            "g": fifo_g_abs,
            "stamp": jax.ShapeDtypeStruct((D,), jnp.float32),
        }
    if wire == "int8":
        abstract_state["resid"] = stacked_params
    if float(compensate) > 0.0:
        abstract_state["theta"] = stacked_params
    if membership:
        abstract_state["alive"] = jax.ShapeDtypeStruct((M,), jnp.float32)

    batch_specs_sm = jax.tree.map(_worker_batch_pspec(ax),
                                  _abstract_batch(cfg, shape))
    state_specs = _decoupled_state_specs(D, pw, wire, compensate,
                                         membership)
    fn_sm = shard_map(
        worker_fn, mesh=mesh,
        in_specs=tuple(state_specs + [batch_specs_sm, P(), P()]),
        out_specs=tuple(state_specs + [P(), P(), P()]),
        axis_names=set(worker_axes))
    step = _decoupled_step_caller(fn_sm, D, wire, compensate, membership)

    w_sh = NamedSharding(mesh, pw)
    scalar = NamedSharding(mesh, P())
    if flat:
        # the flat plane carries only the worker axis: buffers are
        # replicated over 'model' (per-leaf TP sharding needs flat=False)
        worker_only = lambda tree: jax.tree.map(
            lambda _: w_sh, tree)
        p_sh = worker_only(stacked_params)
        opt_sh = worker_only(stacked_opt)
        fifo_g_sh = worker_only(fifo_g_abs) if D > 0 else None
    else:
        # model-axis sharding flows in through jit in_shardings (auto axis)
        p_sh = SH.param_shardings(model, mesh, stacked_workers=M,
                                  overrides=overrides, preset=preset)
        opt_sh = _opt_shardings_stacked(abstract_opt_single, abstract_params,
                                        p_sh, mesh, M)
        if D > 0:
            # FIFO leaves insert the depth axis after the worker axis
            fifo_g_sh = jax.tree.map(
                lambda s: NamedSharding(
                    mesh, P(s.spec[0], None, *tuple(s.spec)[1:])), p_sh)
    state_sh = {"read": p_sh, "write": p_sh, "opt": opt_sh, "w": w_sh,
                "versions": w_sh}
    if D > 0:
        state_sh["fifo"] = {"g": fifo_g_sh, "stamp": scalar}
    if wire == "int8":
        state_sh["resid"] = p_sh
    if float(compensate) > 0.0:
        state_sh["theta"] = p_sh
    if membership:
        state_sh["alive"] = w_sh
    metrics_sh = {"loss": scalar, "update_staleness": scalar,
                  "layer_staleness": scalar, "staleness_mean": scalar,
                  "weight_sum": scalar, "nonfinite_skips": scalar}
    if membership:
        metrics_sh["peers_live"] = scalar
    batch_abs = _abstract_batch(cfg, shape)
    b_sh = SH.batch_shardings(batch_abs, mesh, overrides=overrides,
                              preset=preset)
    fn = jax.jit(step,
                 in_shardings=(state_sh, b_sh, scalar, scalar),
                 out_shardings=(state_sh, metrics_sh),
                 donate_argnums=(0,))
    abstract = (abstract_state, batch_abs,
                jax.ShapeDtypeStruct((), jnp.int32),
                jax.ShapeDtypeStruct((), jnp.int32))
    return ProdStep(fn, abstract,
                    f"layup decoupled train (M={M}, R={R}, D={D}, "
                    f"shifts={shifts}, flat={flat}"
                    f"{', pallas' if use_pallas else ''}"
                    f"{', wire=int8' if wire == 'int8' else ''}"
                    f"{f', comp={compensate}' if compensate else ''}"
                    f"{', membership' if membership else ''})")


def straggler_active_fn(mesh, straggler_delays) -> Optional[Callable]:
    """Per-worker 0/1 activity mask from a straggler-delay vector:
    ``straggler_delays[i] = d`` makes worker ``i`` active every ``d + 1``
    steps. Traced inside the shard_map body (uses ``axis_index``); shared
    by the monolithic decoupled step and the pipeline engine's update
    stage. Returns ``None`` when no delays are given."""
    if straggler_delays is None:
        return None
    worker_axes = data_axes(mesh)
    delays_c = jnp.asarray(np.asarray(straggler_delays), jnp.int32)
    sizes = [mesh.shape[a] for a in worker_axes]

    def active_fn(step_idx):
        idx = jnp.zeros((), jnp.int32)
        for a, n in zip(worker_axes, sizes):
            idx = idx * n + jax.lax.axis_index(a)
        return (jnp.mod(step_idx, delays_c[idx] + 1) == 0).astype(
            jnp.float32)

    return active_fn


def make_decoupled_backend_trainer(loss_fn: Callable, optimizer: Optimizer,
                                   schedule: Callable, mesh, *,
                                   shifts: Sequence[int] = (1, 2, 4, 8),
                                   fb_ratio: int = 1, update_delay: int = 0,
                                   straggler_delays=None,
                                   measure_drift: bool = False,
                                   flat: bool = True,
                                   use_pallas: bool = False,
                                   publisher=None,
                                   wire: str = "param",
                                   compensate: float = 0.0,
                                   membership: bool = False):
    """Decoupled LayUp over a generic pytree + loss_fn (no Model/ShapeConfig)
    — the engine behind the ``"prod"`` TrainerBackend (core/backend.py).

    Batches use the sim layout: every leaf carries a leading ``(M,)`` worker
    axis, so the same data pipeline drives the sim and prod backends.
    ``straggler_delays[i] = d`` makes worker ``i`` apply its local update
    only every ``d + 1`` steps (it still gossips and receives, paper §5.4)
    — the numeric analogue of the sim backend's straggler mask.
    ``measure_drift`` adds the ``disagreement`` metric, computed inside the
    jitted step like the sim trainer does.

    ``publisher`` (a :class:`repro.serving.PlanePublisher`) receives the
    read plane + version clocks + drift once per gossip round (= per
    step), the training side of the train-and-serve path (DESIGN.md §12).
    This step is jitted with ``donate_argnums=(0,)`` — the state the
    publisher sees IS donated on the next call — so the publish is marked
    ``stable=False`` and the publisher stabilizes the plane with async
    device copies (still no checkpoint round-trip; the pipeline engine's
    publish path is the zero-copy one). Requires ``flat=True``.

    Returns ``(init_fn, step_fn, shifts, box)``: ``init_fn(rng,
    params_single) -> state``, ``step_fn(state, batch, step_idx,
    shift_idx) -> (state, metrics)``, the effective (mod-M-filtered)
    gossip shift set the caller draws ``shift_idx`` from, and the build
    box (``box["part"]`` holds the FlatPartition once ``init_fn`` has
    seen the params — the unpack key for exporting the flat state)."""
    worker_axes = data_axes(mesh)
    ax = worker_axes if len(worker_axes) > 1 else worker_axes[0]
    M = num_workers(mesh)
    R, D = int(fb_ratio), int(update_delay)
    shifts = tuple(s % M for s in shifts if s % M != 0) or (1,)
    active_fn = straggler_active_fn(mesh, straggler_delays)
    part_box = {}

    if use_pallas and not flat:
        raise ValueError("use_pallas requires the flat plane (flat=True)")
    if publisher is not None and not flat:
        raise ValueError("publisher needs the flat plane (flat=True): the "
                         "legacy tree state has no per-group plane to "
                         "publish")
    _check_wire(wire, compensate, flat, membership)

    def build(params_single):
        part = FlatPartition(params_single)
        fwd = forward_lane(loss_fn, fb_ratio=R)
        upd = backward_update_lane(optimizer, schedule, update_delay=D,
                                   apply=not use_pallas,
                                   compensate=compensate)
        if use_pallas:
            mix, fused = None, gossip_fused_lane(part, M, ax, shifts,
                                                 wire=wire)
        elif flat:
            mix, fused = gossip_plane_lane(part, M, ax, shifts,
                                           wire=wire), None
        else:
            mix, fused = gossip_lane_legacy(part, M, ax, shifts), None
        worker_fn = _decoupled_worker_fn(part, fwd, upd, mix, M, worker_axes,
                                         D, squeeze_batch=True,
                                         active_fn=active_fn, flat=flat,
                                         fused_mix=fused, wire=wire,
                                         compensate=compensate,
                                         membership=membership)
        pw = P(ax)
        state_specs = _decoupled_state_specs(D, pw, wire, compensate,
                                             membership)
        fn_sm = shard_map(worker_fn, mesh=mesh,
                          in_specs=tuple(state_specs + [pw, P(), P()]),
                          out_specs=tuple(state_specs + [P(), P(), P()]),
                          axis_names=set(worker_axes))
        base_step = _decoupled_step_caller(fn_sm, D, wire, compensate,
                                           membership)

        def step(state, batch, step_idx, shift_idx):
            new_state, metrics = base_step(state, batch, step_idx, shift_idx)
            if measure_drift:
                from repro.core.api import disagreement
                metrics["disagreement"] = disagreement(new_state["read"],
                                                       new_state["w"])
            return new_state, metrics

        return jax.jit(step, donate_argnums=(0,)), part

    def init_fn(rng, params_single):
        del rng
        stacked = jax.tree.map(
            lambda p: jnp.broadcast_to(p[None], (M,) + p.shape),
            params_single)
        if "step" not in part_box:
            part_box["step"], part_box["part"] = build(params_single)
        return make_decoupled_state(stacked, optimizer, update_delay=D,
                                    part=part_box["part"], flat=flat,
                                    wire=wire, compensate=compensate,
                                    membership=membership)

    def step_fn(state, batch, step_idx, shift_idx):
        if "step" not in part_box:
            raise RuntimeError("call init_fn before step_fn")
        new_state, metrics = part_box["step"](
            state, batch, jnp.asarray(step_idx, jnp.int32),
            jnp.asarray(shift_idx, jnp.int32))
        if publisher is not None:
            # stable=False: this jitted step donates its input state, so
            # the read plane the publisher pins here is consumed on the
            # NEXT step_fn call — the publisher copies it (async, on
            # device) before handing it to serving consumers
            publisher.publish(new_state["read"], new_state["versions"],
                              new_state["w"], int(step_idx),
                              drift=metrics.get("disagreement"),
                              stable=False)
        return new_state, metrics

    return init_fn, step_fn, shifts, part_box


def make_prefill_step(model: Model, mesh, shape: ShapeConfig,
                      overrides: Optional[Dict[str, Any]] = None,
                      preset: Optional[str] = None) -> ProdStep:
    cfg = model.cfg

    def step(params, batch):
        cache, logits = model.prefill_fn(params, batch)
        return cache, logits

    p_sh = SH.param_shardings(model, mesh, overrides=overrides,
                              preset=preset)
    batch_abs = _abstract_batch(cfg, shape)
    b_sh = SH.batch_shardings(batch_abs, mesh, overrides=overrides,
                              preset=preset)
    fn = jax.jit(step, in_shardings=(p_sh, b_sh))
    return ProdStep(fn, (model.abstract_params(), batch_abs), "prefill")


def make_decode_step(model: Model, mesh, shape: ShapeConfig,
                     overrides: Optional[Dict[str, Any]] = None,
                     preset: Optional[str] = None) -> ProdStep:
    cfg = model.cfg
    B = shape.global_batch

    def step(params, cache, token, position):
        logits, new_cache = model.decode_fn(params, cache, token, position)
        return logits, new_cache

    p_sh = SH.param_shardings(model, mesh, overrides=overrides,
                              preset=preset)
    cache_abs = model.cache_specs(B, shape.seq_len)
    c_sh = SH.cache_shardings(cache_abs, mesh, cfg, overrides=overrides,
                              preset=preset)
    rules = SH.rules_for(mesh, overrides, preset)
    db = rules["batch"]
    if db is not None and B % SH._axis_size(mesh, db) != 0:
        db = None  # e.g. long_500k batch=1: replicate over the data axes
    tok_sh = NamedSharding(mesh, P(db, None))
    pos_sh = NamedSharding(mesh, P(db))
    fn = jax.jit(step, in_shardings=(p_sh, c_sh, tok_sh, pos_sh),
                 donate_argnums=(1,))
    abstract = (model.abstract_params(), cache_abs,
                jax.ShapeDtypeStruct((B, 1), jnp.int32),
                jax.ShapeDtypeStruct((B,), jnp.int32))
    return ProdStep(fn, abstract, "decode")


# ---------------------------------------------------------------------------
# dispatcher
# ---------------------------------------------------------------------------


def make_step(model: Model, mesh, shape: ShapeConfig, *, algo: str = "layup",
              optimizer: Optional[Optimizer] = None,
              schedule: Optional[Callable] = None,
              overrides: Optional[Dict[str, Any]] = None,
              shifts: Sequence[int] = (1, 2, 4, 8),
              preset: Optional[str] = None,
              accum_steps: int = 1,
              constrain_grads: bool = False,
              fb_ratio: int = 1,
              update_delay: int = 0,
              overlap: bool = False,
              flat: bool = True,
              use_pallas: bool = False,
              streams: int = 1,
              wire: str = "param",
              compensate: float = 0.0,
              faults=None,
              max_inflight_steps: Optional[int] = None,
              tuning=None) -> ProdStep:
    """``overlap=True`` selects the stage-graph pipeline engine
    (repro.launch.pipeline): the decoupled lane compiled into separately
    jitted fwd-slice / bwd+update / gossip stages dispatched asynchronously
    from the host, instead of one monolithic jitted step. Numerics are
    identical (the monolithic path stays as the oracle — DESIGN.md §10);
    only the dispatch schedule and the per-stage timestamps differ.

    ``streams`` (with ``overlap=True``): > 1 runs those stages on
    per-stage execution streams with the gossip stage split per layer
    group behind one-sided signals (repro.launch.streams, DESIGN.md §13)
    — measured execution overlap in the timeline, same numerics.

    ``flat`` (decoupled lanes, default True) keeps the parameters as the
    persistent per-group flat plane — param-dtype gossip wire, zero
    per-step repack (DESIGN.md §11); ``flat=False`` restores the legacy
    tree state + per-step f32 ravel (and per-leaf TP param sharding).
    ``use_pallas`` routes the gossip mix through the fused Pallas
    ``gossip_mix`` kernel (interpret mode off-TPU).

    ``wire="int8"`` (decoupled lanes, flat only) quantizes the gossip
    wire to int8 with error-feedback residuals — ~0.52× the bf16 wire
    bytes; ``compensate=λ > 0`` adds the staleness-aware delay
    compensation ``g + λ·g⊙g⊙(θ_now − θ_stale)`` in the backward lane
    (λ = 0.5 is the documented default when turning it on —
    DESIGN.md §14).

    ``faults`` (a :class:`repro.chaos.FaultPlan` or spec string,
    decoupled lanes, flat only) compiles the fault-tolerant membership
    lane (per-worker ``alive`` mask, live-set push-sum renormalization —
    DESIGN.md §15) and attaches a ``ChaosController`` for the plan on
    the returned step (``.chaos``); an empty plan enables the machinery
    without injecting anything.

    ``tuning`` (a :class:`repro.launch.tuner.TuningRecord` or a path to
    its JSON) replaces the hand-picked schedule defaults with the
    autotuned ones (DESIGN.md §16): any of ``fb_ratio``/``update_delay``/
    ``flat``/``max_inflight_steps`` still at its documented default takes
    the record's best candidate (explicit kwargs always win), and a
    loaded record implies ``overlap=True`` — the record tunes the stage
    schedule. A missing/corrupt/stale/mismatched record warns and leaves
    every default untouched, never raises."""
    from repro.optim import momentum, constant
    optimizer = optimizer or momentum(0.9, state_dtype=model.cfg.dtype)
    schedule = schedule or constant(0.1)
    if tuning is not None:
        from repro.launch.tuner import apply_tuning, resolve_tuning
        record = resolve_tuning(tuning)
        if record is not None:
            tuned = apply_tuning(record, fb_ratio=fb_ratio,
                                 update_delay=update_delay, flat=flat,
                                 max_inflight_steps=max_inflight_steps)
            fb_ratio = tuned["fb_ratio"]
            update_delay = tuned["update_delay"]
            flat = tuned["flat"]
            max_inflight_steps = tuned["max_inflight_steps"]
            overlap = True
    decoupled = fb_ratio > 1 or update_delay > 0 or overlap
    membership = faults is not None
    if streams > 1 and not overlap:
        raise ValueError("streams > 1 is a property of the stage-graph "
                         "pipeline; it requires overlap=True")
    _check_wire(wire, compensate, flat, membership)
    if (wire != "param" or float(compensate) > 0.0 or membership) \
            and not decoupled:
        raise ValueError("wire='int8' / compensate > 0 / faults belong to "
                         "the decoupled LayUp lane (fb_ratio/update_delay/"
                         "overlap)")
    if decoupled and (shape.kind != "train" or algo == "ddp"):
        raise ValueError(
            "fb_ratio/update_delay/overlap define the decoupled LayUp lane; "
            f"they do not apply to algo={algo!r} kind={shape.kind!r}")
    if shape.kind == "train":
        if algo == "ddp":
            return make_ddp_train_step(model, mesh, optimizer, schedule,
                                       shape, overrides, preset)
        if decoupled:
            if accum_steps > 1:
                raise ValueError(
                    "the decoupled lane does not compose with accum_steps")
            if overlap:
                from repro.launch.pipeline import make_layup_decoupled_pipeline
                step = make_layup_decoupled_pipeline(
                    model, mesh, optimizer, schedule, shape, shifts=shifts,
                    overrides=overrides, preset=preset, fb_ratio=fb_ratio,
                    update_delay=update_delay,
                    constrain_grads=constrain_grads, flat=flat,
                    use_pallas=use_pallas, streams=streams, wire=wire,
                    compensate=compensate, membership=membership,
                    max_inflight_steps=max_inflight_steps)
            else:
                step = make_layup_decoupled_train_step(
                    model, mesh, optimizer, schedule, shape, shifts,
                    overrides, preset, fb_ratio, update_delay,
                    constrain_grads, flat, use_pallas, wire, compensate,
                    membership)
            if membership:
                from repro.chaos import ChaosController
                step.chaos = ChaosController(
                    faults, num_workers(mesh), update_delay=update_delay,
                    wire=wire, compensate=compensate)
            return step
        return make_layup_train_step(model, mesh, optimizer, schedule, shape,
                                     shifts, overrides, preset, accum_steps,
                                     constrain_grads, use_pallas)
    if shape.kind == "prefill":
        return make_prefill_step(model, mesh, shape, overrides, preset)
    return make_decode_step(model, mesh, shape, overrides, preset)
