"""Batched serving loop: slot-based continuous batching over the decode step.

A fixed decode batch of ``num_slots`` sequences; requests are admitted into
free slots, each slot decodes with its own position counter (the decode step
takes per-sequence positions), and finished sequences (EOS / max-tokens)
free their slot immediately for the next queued request — the standard
continuous-batching pattern. The inner step is exactly the serve_step the
decode_32k/long_500k dry-runs lower (one token × full cache), so the same
loop drives ``make_decode_step`` on the production mesh.

Prompts are consumed through the decode path one token at a time
("prefill-by-decode"), which works uniformly for every architecture family
(attention caches, SSM states, hybrids).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class Request:
    uid: int
    prompt: np.ndarray          # (prompt_len,) int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    output: List[int] = field(default_factory=list)
    done: bool = False


@dataclass
class _Slot:
    req: Optional[Request] = None
    pos: int = 0                # next cache position to write
    cursor: int = 0             # prompt tokens consumed
    last_tok: int = 0           # last generated token (decode phase)


class ServeLoop:
    def __init__(self, model, params, *, num_slots: int = 4,
                 max_len: int = 256):
        self.model = model
        self.params = params
        self.num_slots = num_slots
        self.max_len = max_len
        self.slots = [_Slot() for _ in range(num_slots)]
        self.queue: List[Request] = []
        self.steps_run = 0
        self.cache = jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype),
            model.cache_specs(num_slots, max_len))
        self._step = jax.jit(model.decode_fn, donate_argnums=(1,))

    def submit(self, req: Request):
        self.queue.append(req)

    # -- internals -------------------------------------------------------------
    def _reset_slot(self, i: int):
        """Zero a slot's cache entries (SSM states carry across sequences;
        attention slots are position-masked but cleared for hygiene)."""
        self.cache = jax.tree.map(
            lambda c: c.at[:, i].set(jnp.zeros_like(c[:, i])), self.cache)

    def _admit(self):
        for i, s in enumerate(self.slots):
            if s.req is None and self.queue:
                s.req = self.queue.pop(0)
                s.pos = s.cursor = 0
                s.last_tok = 0
                self._reset_slot(i)

    def _feed_tokens(self) -> np.ndarray:
        toks = np.zeros(self.num_slots, np.int32)
        for i, s in enumerate(self.slots):
            if s.req is None:
                continue
            if s.cursor < len(s.req.prompt):
                toks[i] = int(s.req.prompt[s.cursor])
            else:
                toks[i] = s.last_tok
        return toks

    def _advance(self, logits):
        greedy = np.asarray(jnp.argmax(logits[:, -1], axis=-1), np.int32)
        for i, s in enumerate(self.slots):
            r = s.req
            if r is None:
                continue
            in_prompt = s.cursor < len(r.prompt)
            if in_prompt:
                s.cursor += 1
            # once the LAST prompt token has been fed, every step emits a
            # generated token
            if not in_prompt or s.cursor == len(r.prompt):
                s.last_tok = int(greedy[i])
                r.output.append(s.last_tok)
            s.pos += 1
            if (len(r.output) >= r.max_new_tokens
                    or (r.eos_id is not None and r.output
                        and r.output[-1] == r.eos_id)
                    or s.pos >= self.max_len):
                r.done = True
                s.req = None  # free the slot (cache slots position-masked)

    # -- public API --------------------------------------------------------------
    def run(self, max_steps: int = 10_000):
        for _ in range(max_steps):
            self._admit()
            if all(s.req is None for s in self.slots) and not self.queue:
                break
            toks = self._feed_tokens()
            positions = jnp.asarray([s.pos for s in self.slots], jnp.int32)
            logits, self.cache = self._step(
                self.params, self.cache, jnp.asarray(toks)[:, None],
                positions)
            self._advance(logits)
            self.steps_run += 1

    def serve(self, requests: List[Request],
              max_steps: int = 10_000) -> Dict[int, List[int]]:
        """Submit all requests, run to completion, return uid → tokens."""
        for r in requests:
            self.submit(r)
        self.run(max_steps)
        return {r.uid: r.output for r in requests}
