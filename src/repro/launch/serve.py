"""Batched serving loop: slot-based continuous batching over the decode step.

A fixed decode batch of ``num_slots`` sequences; requests are admitted into
free slots, each slot decodes with its own position counter (the decode step
takes per-sequence positions), and finished sequences (EOS / max-tokens)
free their slot immediately for the next queued request — the standard
continuous-batching pattern. The inner step is exactly the serve_step the
decode_32k/long_500k dry-runs lower (one token × full cache), so the same
loop drives ``make_decode_step`` on the production mesh.

Prompts are consumed through the decode path one token at a time
("prefill-by-decode"), which works uniformly for every architecture family
(attention caches, SSM states, hybrids).

The loop is live-swappable: ``set_params`` atomically rebinds the whole
parameter tree between decode steps (``step_once`` is the step
granularity), which is how ``repro.serving.LiveServer`` hot-swaps weights
published from the training read plane (DESIGN.md §12). ``stats()``
summarizes throughput and occupancy for the serve benchmarks.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class Request:
    uid: int
    prompt: np.ndarray          # (prompt_len,) int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    output: List[int] = field(default_factory=list)
    done: bool = False


@dataclass
class _Slot:
    req: Optional[Request] = None
    pos: int = 0                # next cache position to write
    cursor: int = 0             # prompt tokens consumed
    last_tok: int = 0           # last generated token (decode phase)


class ServeLoop:
    def __init__(self, model, params, *, num_slots: int = 4,
                 max_len: int = 256):
        self.model = model
        self.params = params
        self.num_slots = num_slots
        self.max_len = max_len
        self.slots = [_Slot() for _ in range(num_slots)]
        self.queue: List[Request] = []
        self.steps_run = 0
        self.tokens_emitted = 0
        self.requests_completed = 0
        self.params_version = None   # provenance tag set by set_params
        self._busy_slot_steps = 0    # Σ over steps of occupied slots
        self.cache = jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype),
            model.cache_specs(num_slots, max_len))
        self._step = jax.jit(model.decode_fn, donate_argnums=(1,))

    def submit(self, req: Request):
        self.queue.append(req)

    def set_params(self, params, version=None):
        """Atomically rebind the serving parameters. Called between decode
        steps only (``step_once`` reads ``self.params`` exactly once per
        step), so a decode step sees either the whole old tree or the
        whole new one — never a mix. The decode executable is shape-stable
        across swaps, so no retrace. ``version`` is an opaque provenance
        tag (the live path passes ``(snapshot.seq, training_step)``)."""
        self.params = params
        self.params_version = version

    # -- internals -------------------------------------------------------------
    def _reset_slot(self, i: int):
        """Zero a slot's cache entries (SSM states carry across sequences;
        attention slots are position-masked but cleared for hygiene)."""
        self.cache = jax.tree.map(
            lambda c: c.at[:, i].set(jnp.zeros_like(c[:, i])), self.cache)

    def _admit(self):
        for i, s in enumerate(self.slots):
            if s.req is None and self.queue:
                s.req = self.queue.pop(0)
                s.pos = s.cursor = 0
                s.last_tok = 0
                self._reset_slot(i)

    def _feed_tokens(self) -> np.ndarray:
        toks = np.zeros(self.num_slots, np.int32)
        for i, s in enumerate(self.slots):
            if s.req is None:
                continue
            if s.cursor < len(s.req.prompt):
                toks[i] = int(s.req.prompt[s.cursor])
            else:
                toks[i] = s.last_tok
        return toks

    def _advance(self, logits):
        greedy = np.asarray(jnp.argmax(logits[:, -1], axis=-1), np.int32)
        for i, s in enumerate(self.slots):
            r = s.req
            if r is None:
                continue
            in_prompt = s.cursor < len(r.prompt)
            if in_prompt:
                s.cursor += 1
            # once the LAST prompt token has been fed, every step emits a
            # generated token
            if not in_prompt or s.cursor == len(r.prompt):
                s.last_tok = int(greedy[i])
                r.output.append(s.last_tok)
                self.tokens_emitted += 1
            s.pos += 1
            if (len(r.output) >= r.max_new_tokens
                    or (r.eos_id is not None and r.output
                        and r.output[-1] == r.eos_id)
                    or s.pos >= self.max_len):
                r.done = True
                self.requests_completed += 1
                s.req = None  # free the slot (cache slots position-masked)

    # -- public API --------------------------------------------------------------
    def step_once(self) -> bool:
        """Admit from the queue, run ONE decode step over the slots, and
        retire finished sequences. Returns False (and runs no device work)
        when every slot is empty after admission — the loop is idle.

        This is the swap granularity: callers that rebind ``params``
        (``set_params``) between ``step_once`` calls get atomic weight
        swaps for free, since the decode step reads ``self.params`` once."""
        self._admit()
        if all(s.req is None for s in self.slots):
            return False
        self._busy_slot_steps += sum(s.req is not None for s in self.slots)
        toks = self._feed_tokens()
        positions = jnp.asarray([s.pos for s in self.slots], jnp.int32)
        logits, self.cache = self._step(
            self.params, self.cache, jnp.asarray(toks)[:, None],
            positions)
        self._advance(logits)
        self.steps_run += 1
        return True

    def run(self, max_steps: int = 10_000):
        for _ in range(max_steps):
            if not self.step_once():
                break

    def stats(self) -> Dict[str, Any]:
        """Run summary: steps, throughput and occupancy — the accounting
        the serve benchmarks and the live example report."""
        steps = self.steps_run
        return {
            "steps_run": steps,
            "tokens_emitted": self.tokens_emitted,
            "requests_completed": self.requests_completed,
            "queue_depth": len(self.queue),
            "slots_busy": sum(s.req is not None for s in self.slots),
            "num_slots": self.num_slots,
            "slot_occupancy": (self._busy_slot_steps
                               / (steps * self.num_slots) if steps else 0.0),
            "params_version": self.params_version,
        }

    def serve(self, requests: List[Request],
              max_steps: int = 10_000) -> Dict[int, List[int]]:
        """Submit all requests, run to completion, return uid → tokens."""
        for r in requests:
            self.submit(r)
        self.run(max_steps)
        return {r.uid: r.output for r in requests}
