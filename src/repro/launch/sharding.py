"""Logical-axis → mesh-axis sharding rules.

Model code declares *logical* axes on every parameter (see
repro.models.layers.ParamSpec); this module maps them onto the production
mesh. The default rules implement:

  * tensor parallelism over 'model' (heads / ffn / experts / inner / vocab)
  * replica ("worker") stacking over ('pod','data') for LayUp's per-worker
    parameters; batch over the same axes
  * everything else replicated

Rules are a plain dict so per-architecture overrides (used by the §Perf
hillclimbs, e.g. shard kv-heads None for GQA archs where kv < model axis)
are one-line changes.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

DEFAULT_RULES: Dict[str, Any] = {
    "worker": ("pod", "data"),
    "batch": ("pod", "data"),
    "heads": "model",
    "kv": "model",
    "ffn": "model",
    "experts": "model",
    "inner": "model",
    "vocab": "model",
    "embed": None,
    "hd": None,
    "layers": None,
    "state": None,
}

# ('data','expert','tp') mesh: expert parallelism for MoE + kv-head sharding
# for GQA(kv=8) + 2-way TP — the §Perf mesh-factorization optimization.
EP_RULES: Dict[str, Any] = {
    "worker": ("pod", "data"),
    "batch": ("pod", "data"),
    "heads": ("expert", "tp"),
    "kv": "expert",
    "ffn": "tp",
    "experts": "expert",
    "inner": ("expert", "tp"),
    "vocab": ("expert", "tp"),
    "embed": None,
    "hd": None,
    "layers": None,
    "state": None,
}

# FSDP preset for the 2D mesh: parameters sharded along d_model, activations
# batch-sharded over 'model' too (set transformer.ACTIVATION_PSPEC) — weight
# all-gathers replace activation all-reduces (§Perf, dense train shapes).
FSDP_RULES: Dict[str, Any] = {
    "worker": ("pod", "data"),
    "batch": ("pod", "data"),
    "embed": "model",
    "heads": None,
    "kv": None,
    "ffn": None,
    "experts": None,
    "inner": None,
    "vocab": None,
    "hd": None,
    "layers": None,
    "state": None,
}

PRESETS = {"megatron": DEFAULT_RULES, "ep": EP_RULES, "fsdp": FSDP_RULES}


def rules_for(mesh, overrides: Optional[Dict[str, Any]] = None,
              preset: Optional[str] = None) -> Dict[str, Any]:
    if preset is None:
        preset = "ep" if "expert" in mesh.axis_names else "megatron"
    rules = dict(PRESETS[preset])
    names = set(mesh.axis_names)
    # restrict to axes that exist on this mesh (e.g. no 'pod' single-pod)
    for k, v in list(rules.items()):
        if isinstance(v, tuple):
            v = tuple(a for a in v if a in names)
            rules[k] = v if v else None
        elif v is not None and v not in names:
            rules[k] = None
    if overrides:
        rules.update(overrides)
    return rules


def _axis_size(mesh, m) -> int:
    axes = m if isinstance(m, tuple) else (m,)
    out = 1
    for a in axes:
        out *= mesh.shape[a]
    return out


def spec_for_axes(axes: Tuple[Optional[str], ...], rules: Dict[str, Any],
                  mesh, shape: Optional[Tuple[int, ...]] = None) -> P:
    """Logical axes → PartitionSpec with two safety rails:

    * jit argument shardings must divide evenly — non-divisible dims fall
      back to replication (recorded per-arch in the roofline notes, e.g.
      whisper's 20 heads on a 16-way model axis);
    * each mesh axis may appear once per spec — duplicates (MoE experts AND
      ffn both → 'model') keep the first occurrence.
    """
    parts = []
    used = set()
    for i, a in enumerate(axes):
        m = rules.get(a) if a is not None else None
        if m is not None:
            maxes = m if isinstance(m, tuple) else (m,)
            if any(x in used for x in maxes):
                m = None
            elif shape is not None and shape[i] % _axis_size(mesh, m) != 0:
                m = None
            else:
                used.update(maxes)
        parts.append(m)
    return P(*parts)


def param_shardings(model, mesh, *, stacked_workers: int = 0,
                    overrides: Optional[Dict[str, Any]] = None,
                    preset: Optional[str] = None):
    """NamedSharding tree for the model params (optionally worker-stacked)."""
    from repro.models.layers import is_spec
    rules = rules_for(mesh, overrides, preset)

    def to_sharding(spec):
        axes = tuple(spec.axes)
        shape = tuple(spec.shape)
        if stacked_workers:
            axes = ("worker",) + axes
            shape = (stacked_workers,) + shape
        return NamedSharding(mesh, spec_for_axes(axes, rules, mesh, shape))

    return jax.tree.map(to_sharding, model.specs, is_leaf=is_spec)


def batch_shardings(batch_specs, mesh, *, stacked_workers: bool = False,
                    overrides: Optional[Dict[str, Any]] = None,
                    preset: Optional[str] = None):
    """Shard data batches: leading batch dim over ('pod','data').

    With stacked_workers the leading axis is the worker axis instead (used
    by the shard_map path, where each worker sees its own sub-batch)."""
    rules = rules_for(mesh, overrides, preset)
    first = rules["batch"]

    def safe(dim):
        if first is None or dim % _axis_size(mesh, first) != 0:
            return None  # e.g. long_500k batch=1: replicate over data
        return first

    def to_sharding(s):
        ndim = len(s.shape)
        if s.shape and s.shape[0] == 3 and ndim == 3:  # mrope (3, B, S)
            return NamedSharding(mesh, P(None, safe(s.shape[1]),
                                         *(None,) * (ndim - 2)))
        return NamedSharding(mesh, P(safe(s.shape[0]), *(None,) * (ndim - 1)))

    return jax.tree.map(to_sharding, batch_specs)


def cache_shardings(cache_specs, mesh, cfg,
                    overrides: Optional[Dict[str, Any]] = None,
                    preset: Optional[str] = None):
    """KV caches: (layers, B, S, kv_heads, hd) → batch over data, kv heads
    over the model axes; SSM states likewise on the SSM-head dim."""
    rules = rules_for(mesh, overrides, preset)
    db = rules["batch"]
    tp = rules["kv"]
    hdr = rules.get("hd")
    ssm_tp = rules["inner"]

    def safe(axis, dim):
        if axis is None or dim % _axis_size(mesh, axis) != 0:
            return None
        return axis

    def to_sharding(path, s):
        key = jax.tree_util.keystr(path)
        nd = len(s.shape)
        if "state" in key:      # (L, B, H, N, P) ssm state: heads → model
            return NamedSharding(mesh, P(None, safe(db, s.shape[1]),
                                         safe(ssm_tp, s.shape[2]), None,
                                         None))
        if "conv_tail" in key:  # (L, B, K-1, conv_dim): channels → model
            return NamedSharding(mesh, P(None, safe(db, s.shape[1]), None,
                                         safe(ssm_tp, s.shape[3])))
        if nd == 5:             # (L, B, S, Hkv, hd) attention cache
            used_tp = safe(tp, s.shape[3])
            hd_spec = safe(hdr, s.shape[4])
            if hd_spec is not None and used_tp is not None:
                a1 = set(used_tp if isinstance(used_tp, tuple) else (used_tp,))
                a2 = set(hd_spec if isinstance(hd_spec, tuple) else (hd_spec,))
                if a1 & a2:
                    hd_spec = None
            return NamedSharding(mesh, P(None, safe(db, s.shape[1]), None,
                                         used_tp, hd_spec))
        if nd == 3:
            return NamedSharding(mesh, P(None, safe(db, s.shape[1]), None))
        return NamedSharding(mesh, P(*([None] * nd)))

    return jax.tree_util.tree_map_with_path(to_sharding, cache_specs)
