"""Roofline-driven stage autotuner with a deterministic cutout harness
(DESIGN.md §16).

Every schedule knob of the decoupled lane — the fwd:bwd ratio R, the
update delay D, the layer-grouping granularity, the engine's
``max_inflight_steps`` backpressure bound and the gossip/quantize tile
size — is hand-picked today ("R=2 because the paper did"). This module
closes the loop between the analytic roofline model
(``repro.launch.analysis``) and the measured :class:`~repro.launch.
pipeline.StageTimeline`, in the style of DaCe's cutout tuner + roofline
model: cut each jitted stage executable out of the engine, time it in
isolation, score a small config grid against the roofline terms plus the
measured overlap, and emit the winner as a reusable
:class:`TuningRecord` that ``make_step`` / ``ProdTrainerBackend`` load
in place of the hand-picked defaults.

Three layers, each independently testable with NO real timing:

* **Cutouts** (:class:`StageCutout`, :func:`extract_cutouts`) — the
  engines expose ``stage_cutouts()``: every separately jitted stage
  executable (fwd slice, bwd+update, gossip mix — per layer group on the
  stream engine) paired with its abstract argument signature. A cutout
  is independently runnable: :func:`synthesize_args` materializes fresh
  concrete buffers from the abstract signature per invocation, so the
  stages' donation contracts hold exactly as they do in-engine (a
  donated synthetic buffer is consumed and replaced, never reused).
* **Harness** (:class:`CutoutHarness`) — times a cutout over warmup +
  measured repetitions. Both the clock and the runner (the thing that
  actually executes the stage and blocks on its outputs) are injected,
  so unit tests drive the whole grid search with a scripted clock and a
  fake executable backend — fully deterministic, no wall time anywhere.
  The default runner executes the real jit and blocks via
  ``jax.block_until_ready``.
* **Scoring + record** (:func:`score_candidate`, :func:`build_record`,
  :class:`TuningRecord`) — a deterministic throughput model over the
  measured per-stage times: forward-slice work and the update+gossip
  tail overlap up to the efficiency the measured timeline actually
  demonstrated, roofline terms (:func:`repro.launch.analysis.
  stage_floors`) clamp any cutout time that claims to beat physics, and
  a staleness discount prices the quality cost of deep R/D. The best
  candidate lands in a versioned JSON record keyed by (model config,
  mesh descriptor, wire dtype); loads that fail — corrupted JSON, stale
  schema version, wrong key — warn and fall back to the hand-picked
  defaults, never crash.
"""
from __future__ import annotations

import json
import os
import time
import warnings
from dataclasses import asdict, dataclass, field, fields
from typing import Any, Callable, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "TUNING_SCHEMA_VERSION", "Candidate", "DEFAULT_CANDIDATE",
    "StageCutout", "CutoutHarness", "TuningRecord",
    "apply_tuning", "build_record", "enumerate_grid", "extract_cutouts",
    "load_tuning", "make_key", "mesh_descriptor", "overlap_efficiency",
    "problem_descriptor", "resolve_tuning", "score_candidate",
    "stage_times_from_cutouts", "synthesize_args",
]

# bump whenever the record layout or the scoring semantics change: a loader
# seeing another version treats the record as stale and falls back to the
# hand-picked defaults (never apply a schedule tuned under different rules)
TUNING_SCHEMA_VERSION = 1


# ---------------------------------------------------------------------------
# candidates
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Candidate:
    """One point of the schedule grid.

    ``grouping``: ``"layer"`` is the per-layer-group flat plane
    (DESIGN.md §11 — one contiguous buffer per layer group, per-group
    signals on the stream engine); ``"legacy"`` is the per-leaf tree
    state with the per-step f32 ravel wire. ``tile`` is the
    gossip/quantize lane-row tile (the Pallas kernels pin 128 rows
    today, so other values score a modeled launch/padding penalty and
    are recorded for the kernel lane rather than applied)."""

    R: int = 2
    D: int = 1
    grouping: str = "layer"
    max_inflight_steps: int = 3
    tile: int = 128

    def label(self) -> str:
        return (f"R{self.R}_D{self.D}_{self.grouping}"
                f"_q{self.max_inflight_steps}_t{self.tile}")


#: the hand-picked defaults every PR so far shipped (R=2/D=1 from the
#: paper, flat plane, max_inflight_steps=3, 128-lane kernel rows) — the
#: baseline a tuned schedule must never score below.
DEFAULT_CANDIDATE = Candidate()


def enumerate_grid(R_values: Sequence[int] = (1, 2, 4),
                   D_values: Sequence[int] = (0, 1, 2),
                   groupings: Sequence[str] = ("layer",),
                   max_inflight: Sequence[int] = (2, 3, 4),
                   tiles: Sequence[int] = (128,)) -> List[Candidate]:
    """The config grid, in a deterministic nested order (R outermost).

    Pure enumeration — no filtering, no timing, no randomness — so tests
    pin the exact candidate list."""
    out = []
    for r in R_values:
        for d in D_values:
            for g in groupings:
                for q in max_inflight:
                    for t in tiles:
                        out.append(Candidate(R=int(r), D=int(d),
                                             grouping=str(g),
                                             max_inflight_steps=int(q),
                                             tile=int(t)))
    return out


# ---------------------------------------------------------------------------
# cutouts
# ---------------------------------------------------------------------------


@dataclass
class StageCutout:
    """One stage executable cut out of an engine: the jitted callable
    plus the abstract argument signature to synthesize inputs from."""

    name: str
    fn: Callable
    abstract_args: tuple


def extract_cutouts(engine) -> Dict[str, StageCutout]:
    """Extract every jitted stage executable from a
    :class:`~repro.launch.pipeline.PipelineEngine` or
    :class:`~repro.launch.streams.StreamEngine` as an independently
    runnable cutout. Raises ``ValueError`` if the engine carries no
    abstract argument signatures (``engine.stage_cutouts()`` owns that
    check — backend-path engines fill the forward batch abstract at
    their first step)."""
    return {name: StageCutout(name, fn, args)
            for name, (fn, args) in engine.stage_cutouts().items()}


def synthesize_args(abstract_args) -> tuple:
    """Fresh concrete buffers for an abstract argument signature.

    Every ``ShapeDtypeStruct`` leaf becomes a numpy array of ones (ones,
    not zeros: push-sum weights and version clocks stay benign). A NEW
    tree is built per call — the stages donate inputs, so a cutout
    invocation must never hand the runner a buffer a previous invocation
    already consumed. The host→device transfer rides each timed call
    uniformly across candidates, which is what a relative schedule
    comparison needs."""
    import jax

    def mk(leaf):
        if hasattr(leaf, "shape") and hasattr(leaf, "dtype"):
            return np.ones(tuple(leaf.shape), np.dtype(leaf.dtype))
        return leaf

    return jax.tree.map(mk, abstract_args)


def _default_runner(fn, args):
    """Execute a stage executable and block until its outputs retired —
    the real-timing backend (the injectable seam for tests)."""
    import jax
    out = fn(*args)
    jax.block_until_ready(out)
    return out


class CutoutHarness:
    """Times stage cutouts in isolation with injectable clock + runner.

    ``clock`` is read immediately before and after each measured
    repetition ONLY (warmup repetitions never touch it), so a scripted
    clock maps one tick pair per rep and the arithmetic is exact in
    tests. ``runner(fn, args)`` performs the execution; the default runs
    the real jit and blocks on its outputs. Synthetic arguments are
    re-synthesized for every invocation (donation — see
    :func:`synthesize_args`)."""

    def __init__(self, *, clock: Callable[[], float] = time.perf_counter,
                 runner: Optional[Callable] = None, warmup: int = 1,
                 reps: int = 3):
        if reps < 1:
            raise ValueError(f"need at least one measured rep, got {reps}")
        self.clock = clock
        self.runner = runner if runner is not None else _default_runner
        self.warmup = int(warmup)
        self.reps = int(reps)

    def time_cutout(self, cutout: StageCutout) -> Dict[str, float]:
        for _ in range(self.warmup):
            self.runner(cutout.fn, synthesize_args(cutout.abstract_args))
        samples = []
        for _ in range(self.reps):
            args = synthesize_args(cutout.abstract_args)
            t0 = self.clock()
            self.runner(cutout.fn, args)
            samples.append(self.clock() - t0)
        return {"mean_s": sum(samples) / len(samples),
                "best_s": min(samples), "reps": float(self.reps)}

    def time_engine(self, engine) -> Dict[str, Dict[str, float]]:
        """Time every cutout of an engine: ``{cutout_name: timing}``."""
        return {name: self.time_cutout(c)
                for name, c in extract_cutouts(engine).items()}


def stage_times_from_cutouts(timings: Dict[str, Dict[str, float]],
                             reduce: str = "mean_s") -> Dict[str, float]:
    """Collapse per-cutout timings into the three canonical stage times
    the scorer consumes: ``fwd`` (mean per forward slice), ``update``,
    and ``gossip`` (the full-plane stage, or the sum of the per-group
    mixes + the clock on the stream engine)."""
    fwd = [v[reduce] for n, v in timings.items() if n.startswith("fwd")]
    out = {"fwd": (sum(fwd) / len(fwd)) if fwd else 0.0,
           "update": timings.get("update", {}).get(reduce, 0.0)}
    if "gossip" in timings:
        out["gossip"] = timings["gossip"][reduce]
    else:
        out["gossip"] = (sum(v[reduce] for n, v in timings.items()
                             if n.startswith("mix:"))
                         + timings.get("clock", {}).get(reduce, 0.0))
    return out


# ---------------------------------------------------------------------------
# scoring
# ---------------------------------------------------------------------------


def overlap_efficiency(timeline_summary: Optional[Dict[str, Any]]) -> float:
    """Fraction of the wall the measured timeline proved overlapped, in
    [0, 1]. ``None`` means "no measurement" and scores as ideal (1.0 —
    pure-model ranking); an EMPTY timeline (zero closed steps) scores
    0.0 without dividing by zero."""
    if timeline_summary is None:
        return 1.0
    wall = float(timeline_summary.get("wall_s") or 0.0)
    if wall <= 0.0:
        return 0.0
    ov = max(float(timeline_summary.get("exec_overlap_s", 0.0)),
             float(timeline_summary.get("fwd_gossip_overlap_s", 0.0)),
             float(timeline_summary.get("overlap_s", 0.0)))
    return min(1.0, max(0.0, ov / wall))


def score_candidate(cand: Candidate, stage_times: Dict[str, float], *,
                    floors: Optional[Dict[str, float]] = None,
                    timeline: Optional[Dict[str, Any]] = None,
                    staleness_penalty: float = 0.1,
                    legacy_gossip_factor: float = 2.0) -> Dict[str, float]:
    """Deterministic throughput score for one candidate. Higher is
    better.

    The model, term by term:

    * stage times come from the cutout harness (``fwd`` is PER SLICE);
      ``floors`` — per-stage roofline lower bounds from
      :func:`repro.launch.analysis.stage_floors` — clamp any measured
      time that claims to beat the hardware;
    * ``grouping="legacy"`` multiplies the gossip time by
      ``legacy_gossip_factor`` (the per-step f32 ravel repack + the f32
      wire, vs. the zero-repack param-dtype plane — the measured ratio
      in ``BENCH_gossip_path``); off-128 tiles pay a modeled launch
      (smaller) or padding (larger) penalty;
    * one step runs R forward slices against the update+gossip tail.
      Fully serial that costs ``R·t_fwd + t_upd + t_gossip``; fully
      overlapped, ``max(R·t_fwd, t_upd + t_gossip)``. The schedule
      recovers the gap in proportion to (a) the overlap efficiency the
      MEASURED timeline demonstrated and (b) the pipeline depth the
      candidate affords (``1 − 2^−(max_inflight_steps + D)`` — each
      extra in-flight step or FIFO slot halves the remaining stall);
    * the score is forward passes per second (R per step — the paper's
      throughput currency) discounted by the staleness the schedule
      induces: ``D`` full delay slots plus ``(R−1)/2`` of forward
      run-ahead.

    Pure arithmetic over its inputs — the unit tests drive it with
    hand-written times and pin exact values."""
    t_fwd = float(stage_times["fwd"])
    t_upd = float(stage_times["update"])
    t_gos = float(stage_times["gossip"])
    if cand.grouping == "legacy":
        t_gos *= float(legacy_gossip_factor)
    if cand.tile < 128:
        t_gos *= 1.0 + 0.05 * (128.0 / cand.tile - 1.0)
    elif cand.tile > 128:
        t_gos *= 1.0 + 0.02 * (cand.tile / 128.0 - 1.0)
    if floors:
        t_fwd = max(t_fwd, float(floors.get("fwd", 0.0)))
        t_upd = max(t_upd, float(floors.get("update", 0.0)))
        t_gos = max(t_gos, float(floors.get("gossip", 0.0)))

    R = max(int(cand.R), 1)
    serial = R * t_fwd + t_upd + t_gos
    critical = max(R * t_fwd, t_upd + t_gos)
    eff = overlap_efficiency(timeline)
    depth = 1.0 - 0.5 ** max(int(cand.max_inflight_steps) + int(cand.D), 1)
    step_time = serial - eff * depth * (serial - critical)

    staleness = float(cand.D) + 0.5 * (R - 1)
    discount = 1.0 / (1.0 + float(staleness_penalty) * staleness)
    score = (R * discount / step_time) if step_time > 0.0 else 0.0
    return {"score": score, "step_time_s": step_time, "serial_s": serial,
            "critical_s": critical, "staleness": staleness,
            "overlap_eff": eff}


# ---------------------------------------------------------------------------
# the tuning record
# ---------------------------------------------------------------------------


def mesh_descriptor(mesh) -> str:
    """``data4xmodel1``-style key component for a jax mesh."""
    return "x".join(f"{name}{size}" for name, size
                    in zip(mesh.axis_names, mesh.devices.shape))


def problem_descriptor(part) -> str:
    """Key component pinning the model's flat-plane layout (a
    :class:`~repro.core.layerview.FlatPartition`): group names + sizes —
    two models tune interchangeably iff their planes match."""
    items = sorted((str(n), int(s)) for n, s in part.group_sizes.items())
    return "plane[" + ",".join(f"{n}:{s}" for n, s in items) + "]"


def make_key(problem: str, mesh_desc: str, wire: str) -> str:
    """The record key: model config + mesh descriptor + wire dtype."""
    return f"{problem}|{mesh_desc}|wire={wire}"


@dataclass
class TuningRecord:
    """A versioned, keyed tuning result — what the autotuner emits and
    ``make_step`` / ``ProdTrainerBackend`` load."""

    version: int
    key: str
    best: Dict[str, Any]
    score: float
    table: List[Dict[str, Any]] = field(default_factory=list)
    stage_times: Dict[str, float] = field(default_factory=dict)
    meta: Dict[str, Any] = field(default_factory=dict)

    def best_candidate(self) -> Candidate:
        names = {f.name for f in fields(Candidate)}
        return Candidate(**{k: v for k, v in self.best.items()
                            if k in names})

    def to_dict(self) -> Dict[str, Any]:
        return asdict(self)

    @classmethod
    def from_dict(cls, doc: Dict[str, Any]) -> "TuningRecord":
        if not isinstance(doc, dict):
            raise ValueError(f"tuning record must be a dict, got "
                             f"{type(doc).__name__}")
        for req in ("version", "key", "best", "score"):
            if req not in doc:
                raise ValueError(f"tuning record missing field {req!r}")
        best = doc["best"]
        if not isinstance(best, dict):
            raise ValueError("tuning record 'best' must be a dict")
        for req in ("R", "D"):
            if req not in best:
                raise ValueError(f"tuning record best missing {req!r}")
        rec = cls(version=int(doc["version"]), key=str(doc["key"]),
                  best=dict(best), score=float(doc["score"]),
                  table=list(doc.get("table", [])),
                  stage_times=dict(doc.get("stage_times", {})),
                  meta=dict(doc.get("meta", {})))
        rec.best_candidate()  # validates the candidate fields coerce
        return rec

    def save(self, path: str) -> str:
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.to_dict(), f, indent=1, sort_keys=True)
        return path


def build_record(entries: Iterable[Tuple[Candidate, Dict[str, float],
                                         Optional[Dict[str, Any]]]], *,
                 key: str, floors: Optional[Dict[str, float]] = None,
                 staleness_penalty: float = 0.1,
                 meta: Optional[Dict[str, Any]] = None) -> TuningRecord:
    """Score measured candidates and emit the record.

    ``entries`` — ``(candidate, stage_times, timeline_summary)`` triples
    (timeline may be None). ``floors`` is a per-stage dict, or a callable
    ``cand -> dict`` when the floor depends on the candidate (the fwd
    roofline floor divides by R — ``analysis.stage_floors(report,
    R=cand.R)``). The best candidate is the max score; ties break toward
    the EARLIEST entry, so putting the hand-picked default first
    guarantees "tuned never scores worse than untuned" degrades to the
    default under exact ties. The table keeps every scored row, sorted
    best-first, for the nightly artifact."""
    rows = []
    for i, (cand, stage_times, timeline) in enumerate(entries):
        fl = floors(cand) if callable(floors) else floors
        s = score_candidate(cand, stage_times, floors=fl,
                            timeline=timeline,
                            staleness_penalty=staleness_penalty)
        rows.append((s["score"], -i, cand, stage_times, s))
    if not rows:
        raise ValueError("build_record needs at least one scored candidate")
    rows.sort(key=lambda r: (r[0], r[1]), reverse=True)
    best_score, _, best, best_times, best_s = rows[0]
    table = [{**asdict(c), **s, "label": c.label()}
             for _, _, c, _, s in rows]
    return TuningRecord(
        version=TUNING_SCHEMA_VERSION, key=key,
        best={**asdict(best), "label": best.label()}, score=best_score,
        table=table, stage_times=dict(best_times), meta=dict(meta or {}))


# ---------------------------------------------------------------------------
# loading + applying (the make_step / ProdTrainerBackend entry points)
# ---------------------------------------------------------------------------


def _warn(msg: str) -> None:
    warnings.warn(f"tuning record: {msg}; falling back to hand-picked "
                  f"defaults", UserWarning, stacklevel=3)


def load_tuning(path: str, *, key: Optional[str] = None,
                version: int = TUNING_SCHEMA_VERSION
                ) -> Optional[TuningRecord]:
    """Load a record from JSON; NEVER raises. A missing file, corrupted
    JSON, a stale/foreign schema version, a key mismatch or a malformed
    body each warn and return ``None`` — the caller keeps its
    hand-picked defaults."""
    try:
        with open(path) as f:
            doc = json.load(f)
    except Exception as e:
        _warn(f"{path!r} unreadable ({type(e).__name__}: {e})")
        return None
    if not isinstance(doc, dict) or doc.get("version") != version:
        got = doc.get("version") if isinstance(doc, dict) else None
        _warn(f"{path!r} has schema version {got!r}, expected {version} "
              f"(stale record)")
        return None
    if key is not None and doc.get("key") != key:
        _warn(f"{path!r} keyed for {doc.get('key')!r}, not {key!r}")
        return None
    try:
        return TuningRecord.from_dict(doc)
    except Exception as e:
        _warn(f"{path!r} malformed ({e})")
        return None


def resolve_tuning(tuning, *, key: Optional[str] = None
                   ) -> Optional[TuningRecord]:
    """Normalize the ``tuning=`` argument: ``None`` passes through, a
    :class:`TuningRecord` is key-checked, anything else is treated as a
    path and loaded via :func:`load_tuning` (same never-crash
    contract)."""
    if tuning is None:
        return None
    if isinstance(tuning, TuningRecord):
        if key is not None and tuning.key != key:
            _warn(f"record keyed for {tuning.key!r}, not {key!r}")
            return None
        return tuning
    return load_tuning(os.fspath(tuning), key=key)


def apply_tuning(record: Optional[TuningRecord], *, fb_ratio: int = 1,
                 update_delay: int = 0, flat: bool = True,
                 max_inflight_steps: Optional[int] = None
                 ) -> Dict[str, Any]:
    """Merge a record under the caller's kwargs: a knob the caller moved
    off its documented default (``fb_ratio=1``, ``update_delay=0``,
    ``flat=True``, ``max_inflight_steps=None``) always wins; the record
    only replaces untouched defaults. Returns the effective kwargs."""
    out = {"fb_ratio": int(fb_ratio), "update_delay": int(update_delay),
           "flat": bool(flat), "max_inflight_steps": max_inflight_steps}
    if record is None:
        return out
    best = record.best_candidate()
    if out["fb_ratio"] == 1:
        out["fb_ratio"] = int(best.R)
    if out["update_delay"] == 0:
        out["update_delay"] = int(best.D)
    if out["max_inflight_steps"] is None:
        out["max_inflight_steps"] = int(best.max_inflight_steps)
    if out["flat"] and best.grouping == "legacy":
        out["flat"] = False
    return out
