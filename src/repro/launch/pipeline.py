"""Stage-graph pipeline engine for the decoupled LayUp lane (DESIGN.md §10).

The monolithic ``make_layup_decoupled_train_step`` fuses the R forward
slices, the delayed backward/update and the gossip collectives into ONE
jitted program, so on real hardware they serialize and the paper's overlap
(forward threads hiding communication/update latency — the source of the
up-to-5.95× speedups) cannot manifest. This module compiles the SAME lane
factories (``forward_slice_lane`` / ``backward_update_lane`` /
``gossip_lane`` from ``repro.launch.train``) into **separately jitted,
buffer-donating stage executables**:

    fwd-slice r   (read, batch)                  -> loss_r [, grads]
    bwd+update    (write, opt[, fifo], grads, t) -> write', opt'[, fifo'], stale
    gossip-mix    (write', w, versions,
                   losses, stale, t, s)          -> mixed, w', versions', metrics

(the gossip stage also folds the metric reduction, so one step is exactly
R + 2 dispatches — the CPU PJRT client bounds the number of in-flight
executions, and every extra executable per step is one less step of
host run-ahead before dispatch throttles)

and drives them from a host-side dispatch loop that exploits JAX **async
dispatch**: every stage call returns a future immediately, so the host can
enqueue step ``t+1``'s forward slices while step ``t``'s gossip collectives
and delayed update are still executing on the device — data dependencies
are sequenced by the runtime, not by python. Numerics are IDENTICAL to the
monolithic step (the stage bodies are the very same lane closures, split at
the same boundaries; the monolithic path remains the numerics oracle and
``tests/test_pipeline.py`` asserts loss/staleness parity at
(R, D) ∈ {(1,0), (1,1), (2,1)}).

**Buffer ownership / donation rules.** The engine manages the
double-buffered parameters instead of carrying them as step-state pytrees:

* the *read* buffer (forward input) is never donated — all R forward
  slices of a step share it;
* the update stage donates the optimizer state, the gradient FIFO and the
  incoming gradients, but NOT its parameter input: after the gossip swap
  the read and write handles alias one engine-owned buffer, and donating a
  buffer that a still-in-flight forward reads would alias a live input;
* the gossip stage donates its parameter input (the update stage's fresh
  output — sole reference), the push-sum weights and the version clocks.
  Its mixed output becomes BOTH next-step handles (read == write at every
  step boundary, exactly like the monolithic step — all numeric staleness
  lives in the gradient FIFO).

**Timestamps.** Every dispatch is recorded in a :class:`StageTimeline`
with the host dispatch time, the set of stages still in flight at that
moment (probed via non-blocking ``jax.Array.is_ready`` on a per-stage
fence output — stage executables complete atomically, so any output
serves), and the first-observed-ready completion time. Overlap is
therefore *measured*, not simulated: ``fwd_gossip_overlap_s`` sums, over
forward dispatches that found the previous step's gossip in flight, the
window between the dispatch and the gossip's completion. Completion times
are first-*observed*-ready (an upper bound — polling happens at dispatch
points and at ``finalize()``), so reported overlap is what the host
provably ran ahead of, never an extrapolation.
"""
from __future__ import annotations

import json
import threading
import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.layerview import (
    FlatPartition, LayerPartition, send_fractions, stamp_groups,
)
from repro.launch.mesh import data_axes, num_workers
from repro.launch.train import (
    _abstract_batch, _check_wire, _decoupled_metrics, _opt_shardings_stacked,
    _ring_exchange, _worker_batch_pspec, backward_update_lane,
    forward_slice_lane, gossip_fused_lane, gossip_lane_legacy,
    gossip_plane_lane, make_decoupled_state, shard_map,
    straggler_active_fn,
)
from repro.launch import sharding as SH
from repro.optim.optimizers import Optimizer


# ---------------------------------------------------------------------------
# stage timeline: measured dispatch/complete timestamps + overlap accounting
# ---------------------------------------------------------------------------


def _is_ready(x) -> bool:
    """Non-blocking readiness probe; arrays already consumed by a donating
    stage count as retired."""
    try:
        return bool(x.is_ready())
    except Exception:
        return True


class StageTimeline:
    """Host-side record of every stage dispatch and stage execution.

    Two kinds of events share the list:

    * **dispatch events** (single-stream :class:`PipelineEngine`, via
      ``begin``/``commit``): ``{stage, step, slice, dispatch, complete,
      concurrent}``. ``dispatch`` is stamped when the host *initiates*
      the stage call, ``concurrent`` lists the ``(stage, step, slice)``
      triples whose fences were NOT ready at that moment — direct
      evidence the host ran ahead of the device — and ``complete`` is
      the first time the fence was observed ready (polled at subsequent
      dispatches and at ``finalize()``), i.e. an upper bound on the true
      completion.
    * **execution events** (:class:`~repro.launch.streams.StreamEngine`,
      via ``record_exec``, called from the stream threads): the same
      shape plus ``{stream, enqueue, exec_start, wait_s[, group]}``.
      ``[exec_start, complete]`` is a TRUE execution span — the owning
      stream thread launched the stage and blocked until its outputs
      were ready — so spans from different streams interleave exactly
      when the device executed two stages concurrently. ``dispatch`` is
      set to ``exec_start`` and ``concurrent`` to ``[]`` so the
      dispatch-level aggregations stay meaningful, and ``wait_s`` is the
      time the task spent blocked on its input signals/futures before
      launching (the signal-wait cost of the one-sided protocol)."""

    def __init__(self, clock: Callable[[], float] = time.perf_counter):
        self._clock = clock
        self._lock = threading.Lock()
        self.events: List[Dict[str, Any]] = []
        self._pending: List[Tuple[Dict[str, Any], Any]] = []

    def begin(self, stage: str, step: int, slice_idx=None) -> Dict[str, Any]:
        """Open an event at stage-call initiation: timestamp + snapshot of
        the stages still in flight. Pair with :meth:`commit`."""
        now = self._clock()
        self.poll(now)
        concurrent = [(e["stage"], e["step"], e["slice"])
                      for e, _ in self._pending]
        ev = {"stage": stage, "step": int(step), "slice": slice_idx,
              "dispatch": now, "complete": None, "concurrent": concurrent}
        self.events.append(ev)
        return ev

    def commit(self, ev: Dict[str, Any], fence) -> None:
        """Attach the dispatched stage's fence output to its event."""
        self._pending.append((ev, fence))
        self.poll()

    def record_exec(self, stage: str, step: int, *, stream: str,
                    enqueue: Optional[float], exec_start: float,
                    complete: float, wait_s: float = 0.0,
                    slice_idx=None, group: Optional[str] = None) -> None:
        """Record one finished stage execution from a stream thread.

        Called by :class:`~repro.launch.streams.Stream` AFTER it blocked
        on the stage's outputs, so ``[exec_start, complete]`` is a closed
        execution span (no pending fence to poll). Thread-safe — stream
        threads record concurrently with the host reading ``summary``."""
        ev = {"stage": stage, "step": int(step), "slice": slice_idx,
              "dispatch": exec_start, "complete": complete,
              "concurrent": [], "stream": stream, "enqueue": enqueue,
              "exec_start": exec_start, "wait_s": float(wait_s)}
        if group is not None:
            ev["group"] = group
        with self._lock:
            self.events.append(ev)

    def poll(self, now: Optional[float] = None) -> None:
        if not self._pending:
            return
        now = self._clock() if now is None else now
        still = []
        for ev, fence in self._pending:
            if _is_ready(fence):
                ev["complete"] = now
            else:
                still.append((ev, fence))
        self._pending = still

    def finalize(self) -> None:
        """Block on every outstanding fence and close its event."""
        for ev, fence in self._pending:
            try:
                jax.block_until_ready(fence)
            except Exception:
                pass
            ev["complete"] = self._clock()
        self._pending = []

    def reset(self) -> None:
        """Drop all recorded events (finalizing outstanding ones first) —
        for backends that re-init and measure a fresh run."""
        self.finalize()
        self.events = []

    def summary(self) -> Dict[str, Any]:
        """Aggregate the recorded events. Returned fields:

        * ``events`` — total events recorded (incl. still-pending ones);
          ``steps`` — ``max(step) + 1`` over closed events; ``wall_s`` —
          first dispatch to last completion.
        * ``stage_s`` — summed ``complete − dispatch`` per stage name
          (per-stage device occupancy upper bound; stages overlap, so
          the values can sum past ``wall_s``).
        * ``overlap_events`` / ``overlap_s`` — dispatch-level run-ahead:
          events whose initiation found ANY stage still in flight, and
          the summed window each provably overlapped (how far the host
          ran ahead — NOT proof of concurrent execution).
        * ``fwd_gossip_overlap_s`` — the paper's overlap: step ``t``
          forwards dispatched while step ``t−1`` gossip was in flight,
          counted once per adjacent step pair.
        * ``streams`` — distinct execution streams that recorded events
          (1 for the single-stream engine: everything shares the one
          dispatch lane).
        * ``exec_overlap_s`` — MEASURED execution concurrency: with each
          stream's ``[exec_start, complete]`` spans merged into busy
          intervals, the integral of ``(busy_streams − 1)`` over time.
          Zero unless two streams were executing at the same instant;
          same-stream pipelining never counts. This is the number the
          nightly M>1 gate asserts is positive (DESIGN.md §13).
        * ``stream_busy_s`` — per-stream merged busy time.
        * ``signal_wait_s`` — summed time stream tasks spent blocked on
          input signals/futures before launching (the wait side of the
          one-sided protocol; high values mean a starved stream)."""
        with self._lock:
            events = list(self.events)
        evs = [e for e in events if e["complete"] is not None]
        out: Dict[str, Any] = {
            "events": len(events), "steps": 0, "wall_s": 0.0,
            "overlap_events": 0, "overlap_s": 0.0,
            "fwd_gossip_overlap_s": 0.0, "stage_s": {},
            "streams": 1, "exec_overlap_s": 0.0, "stream_busy_s": {},
            "signal_wait_s": 0.0,
        }
        if not evs:
            return out
        t0 = min(e["dispatch"] for e in evs)
        out["steps"] = max(e["step"] for e in evs) + 1
        out["wall_s"] = max(e["complete"] for e in evs) - t0
        stage_s: Dict[str, float] = {}
        for e in evs:
            stage_s[e["stage"]] = (stage_s.get(e["stage"], 0.0)
                                   + e["complete"] - e["dispatch"])
        out["stage_s"] = stage_s
        index = {(e["stage"], e["step"], e["slice"]): e for e in evs}
        overlap = 0.0
        overlap_events = 0
        # the paper's overlap: step t's forward slices dispatched while
        # step t−1's gossip is still in flight. Count each gossip once,
        # from the EARLIEST forward that found it unretired, so neither
        # multiple slices nor deep run-ahead double-count the window.
        first_fwd: Dict[int, Dict[str, Any]] = {}
        for e in evs:
            window = 0.0
            for key in e["concurrent"]:
                g = index.get(tuple(key))
                if g is None or g["complete"] is None:
                    continue
                window = max(window, min(g["complete"], e["complete"])
                             - e["dispatch"])
                if (e["stage"] == "fwd" and key[0] == "gossip"
                        and key[1] == e["step"] - 1
                        and e["step"] not in first_fwd):
                    first_fwd[e["step"]] = e
            if e["concurrent"]:
                overlap_events += 1
                overlap += max(0.0, window)
        fwd_gossip = 0.0
        for t_step, e in first_fwd.items():
            g = index[("gossip", t_step - 1, None)]
            fwd_gossip += max(0.0, min(g["complete"], e["complete"])
                              - e["dispatch"])
        out["overlap_events"] = overlap_events
        out["overlap_s"] = overlap
        out["fwd_gossip_overlap_s"] = fwd_gossip

        # per-stream execution accounting (stream events only): merge each
        # stream's closed [exec_start, complete] spans into busy intervals,
        # then sweep the interval endpoints counting how many DISTINCT
        # streams are busy — exec_overlap_s integrates (busy − 1) over
        # time, so same-stream pipelining contributes nothing and the
        # value is > 0 iff two streams truly executed concurrently.
        sevs = [e for e in evs if e.get("stream")]
        if sevs:
            busy: Dict[str, List[List[float]]] = {}
            for e in sorted(sevs, key=lambda e: e["exec_start"]):
                iv = busy.setdefault(e["stream"], [])
                if iv and e["exec_start"] <= iv[-1][1]:
                    iv[-1][1] = max(iv[-1][1], e["complete"])
                else:
                    iv.append([e["exec_start"], e["complete"]])
            out["streams"] = len(busy)
            out["stream_busy_s"] = {
                n: sum(c - s for s, c in iv) for n, iv in busy.items()}
            out["signal_wait_s"] = sum(e.get("wait_s", 0.0) for e in sevs)
            edges = sorted((t, d) for iv in busy.values()
                           for s, c in iv for t, d in ((s, 1), (c, -1)))
            k, last, exec_overlap = 0, 0.0, 0.0
            for t, d in edges:
                if k > 1:
                    exec_overlap += (t - last) * (k - 1)
                k, last = k + d, t
            out["exec_overlap_s"] = exec_overlap
        return out

    def dump(self, path: str) -> str:
        """Write events (dispatch/complete relative to the first dispatch)
        plus the summary as JSON — the nightly per-stage timing artifact."""
        s = self.summary()
        with self._lock:
            snap = list(self.events)
        t0 = min((e["dispatch"] for e in snap), default=0.0)
        rel = lambda v: None if v is None else v - t0
        events = [{**e,
                   "dispatch": e["dispatch"] - t0,
                   "complete": rel(e["complete"]),
                   "concurrent": [list(c) for c in e["concurrent"]],
                   **({"enqueue": rel(e.get("enqueue")),
                       "exec_start": e["exec_start"] - t0}
                      if "stream" in e else {})}
                  for e in snap]
        with open(path, "w") as f:
            json.dump({"summary": s, "events": events}, f, indent=1)
        return path


# ---------------------------------------------------------------------------
# stage bodies (traced inside shard_map) — split at the lane boundaries
# ---------------------------------------------------------------------------


def _unstack(t):
    return jax.tree.map(lambda x: x[0], t)


def _unstack_opt(t):
    return jax.tree.map(lambda x: x[0] if x.ndim >= 1 else x, t)


def _restack(t):
    return jax.tree.map(lambda x: x[None], t)


def _stage_bodies(part: LayerPartition, R: int, D: int, M: int, worker_axes,
                  fwd_slices: Sequence[Callable], upd: Callable,
                  mix: Callable, *, squeeze_batch: bool = False,
                  active_fn: Optional[Callable] = None, flat: bool = False,
                  fused: bool = False, wire: str = "param",
                  compensate: float = 0.0, membership: bool = False):
    """Per-worker stage bodies. They compose the SAME lane closures as
    ``_decoupled_worker_fn``, split at the stage boundaries, so each
    stage's math is identical to the corresponding span of the monolithic
    body. The loss is NOT pmean'd per stage: each fwd stage returns its
    per-worker loss vector and the metrics stage combines slices first
    (monolithic order: ``(l0 + sum(rest)) / R``), then means over workers —
    bitwise-equal to ``lax.pmean`` of the per-worker combination for
    M ≤ 2, and within reduction-order noise beyond.

    ``flat``: read/write/opt/fifo are the persistent flat plane; the
    backward fwd slice packs its gradients before returning them, so the
    grads that cross the stage boundary are already plane buffers.
    ``fused`` (use_pallas): the update stage consumes the write plane
    READ-ONLY and returns the update deltas; the gossip stage takes
    (write, updates) and folds apply+mix into the fused kernel pass
    (``mix`` is then a :func:`gossip_fused_lane` closure).

    ``wire="int8"``: the gossip stage gains the error-feedback residual
    plane as an extra argument and returns its successor alongside the
    mixed plane; ``compensate > 0``: the update stage gains the stale-θ
    reference plane and returns this step's pre-update params as the
    next θ_prev (DESIGN.md §14).

    ``membership`` (DESIGN.md §15): both the update and the gossip stage
    gain the per-peer ``alive`` mask (a never-donated passthrough the
    engine threads from the chaos controller's state). Dead peers apply
    no updates, keep their version clocks frozen, and the alive-gated
    push-sum exchange conserves Σw over the live set. The update stage
    additionally returns the psum'd nonfinite-skip count (always — the
    guard is unconditional in :func:`backward_update_lane`)."""
    phi = jnp.asarray(send_fractions(part.num_groups))
    int8 = wire == "int8"
    comp = float(compensate) > 0.0

    def make_fwd_body(r):
        lane = fwd_slices[r]

        def fwd_body(read_st, batch):
            read = _unstack(read_st)
            if squeeze_batch:  # sim-layout batches carry a worker axis
                batch = _unstack(batch)
            loss, grads = lane(part.unpack(read) if flat else read, batch)
            if r == 0:
                if flat:
                    grads = part.pack(grads)
                return loss[None], _restack(grads)
            return loss[None]

        return fwd_body

    def update_body(*args):
        if D > 0:
            write_st, opt_st, fifo_g_st, fifo_stamp, grads_st = args[:5]
            rest = args[5:]
            fifo = {"g": _unstack(fifo_g_st), "stamp": fifo_stamp}
        else:
            write_st, opt_st, grads_st = args[:3]
            rest = args[3:]
            fifo = ()
        j = 0
        theta = None
        if comp:
            theta = _unstack(rest[j])
            j += 1
        alive_st = None
        if membership:
            alive_st = rest[j]
            j += 1
        step_idx = rest[-1]
        write = _unstack(write_st)
        opt_state = _unstack_opt(opt_st)
        grads = _unstack(grads_st)
        active = active_fn(step_idx) if active_fn is not None else None
        upd_out = upd(write, opt_state, grads, fifo, step_idx,
                      active=active, theta=theta) if comp else \
            upd(write, opt_state, grads, fifo, step_idx, active=active)
        out, opt_state, fifo, upd_stale, skips = upd_out[:5]
        if alive_st is not None:
            # a dead peer applies no updates (frozen until donor re-sync).
            # A SELECT, not an arithmetic `·a` folded into active: the
            # multiply changes XLA's FMA contraction and breaks empty-plan
            # bit-exactness; where(1.0, new, old) is the identity
            # bit-for-bit. Fused: ``out`` is the delta plane (gate to 0);
            # default: ``out`` is the updated write buffer (gate to prev).
            a = alive_st[0]
            out = (jax.tree.map(
                       lambda u: jnp.where(a > 0.0, u, jnp.zeros_like(u)),
                       out) if fused else
                   jax.tree.map(lambda n, o: jnp.where(a > 0.0, n, o),
                                out, write))
        # skips differs per worker (each sanitizes its own grads); the
        # monolithic body psums it, so the stage must too before the P()
        # out spec replicates it
        skips = jax.lax.psum(skips, worker_axes)
        # fused: ``out`` is the update-delta plane (write untouched);
        # default: ``out`` is the updated write buffer
        outs = [_restack(out), _restack(opt_state)]
        if D > 0:
            outs += [_restack(fifo["g"]), fifo["stamp"]]
        if comp:
            # θ_prev for the next step: this step's pre-update params.
            # The write input is NOT donated, so jit materializes this
            # output as a fresh copy — donatable next step without
            # aliasing the live read plane.
            outs += [_restack(upd_out[5])]
        return tuple(outs) + (upd_stale, skips)

    def gossip_body(*args):
        if fused:
            write_st, upd_st = args[:2]
            rest = args[2:]
        else:
            write_st = args[0]
            rest = args[1:]
        resid_st = rest[0] if int8 else None
        if int8:
            rest = rest[1:]
        if membership:
            w_st, versions, alive_st, step_idx, shift_idx = rest
            a = alive_st[0]
        else:
            w_st, versions, step_idx, shift_idx = rest
            a = None
        write = _unstack(write_st)
        w = w_st[0]
        resid = None
        if fused and int8:
            write, resid, w = mix(write, _unstack(resid_st),
                                  _unstack(upd_st), w, shift_idx, alive=a)
        elif fused:
            write, w = mix(write, _unstack(upd_st), w, shift_idx, alive=a)
        elif int8:
            write, resid, w = mix(write, _unstack(resid_st), w, shift_idx,
                                  alive=a)
        else:
            write, w = mix(write, w, shift_idx, alive=a)
        if M > 1:
            stamped = stamp_groups(versions,
                                   step_idx.astype(jnp.float32) + phi)
            # dead peers' clocks freeze: their replica stops advancing
            versions = stamped if a is None else \
                jnp.where(a > 0.0, stamped, versions)
        if int8:
            return _restack(write), _restack(resid), w[None], versions
        return _restack(write), w[None], versions

    def metrics_fn(losses, w, versions, upd_stale, step_idx, skips=None,
                   alive=None):
        per_worker = (losses[0] + sum(losses[1:])) / R
        if alive is None:
            loss = jnp.mean(per_worker)
        else:
            # live-weighted: a dead peer's (frozen) loss must not drag
            # the reported mean — same reduction as the monolithic
            # membership body's psum(loss*a)/psum(a)
            loss = jnp.sum(per_worker * alive) / jnp.sum(alive)
        return _decoupled_metrics(w, versions, loss, upd_stale, step_idx,
                                  skips=skips, alive=alive)

    return ([make_fwd_body(r) for r in range(R)], update_body, gossip_body,
            metrics_fn)


def _jit_stages(bodies, mesh, worker_axes, R: int, D: int, *, batch_specs,
                shardings: Optional[Dict[str, Any]] = None,
                fused: bool = False, wire: str = "param",
                compensate: float = 0.0, membership: bool = False):
    """shard_map + jit each stage body into its executable.

    ``shardings`` (Model path) pins jit-level in/out shardings so the model
    axis flows through GSPMD exactly like the monolithic step; the generic
    backend path omits it (plain jit, shardings inferred from shard_map).

    ``fused`` (use_pallas): the update stage's first output is the
    update-delta plane (its parameter input stays read-only — same
    donation set: opt/fifo/grads) and the gossip stage gains the deltas
    as a second argument. Gossip then donates the DELTAS instead of the
    plane: its plane input aliases the engine's read buffer, which the
    in-flight forward slices of the same step still read.

    ``wire="int8"``: gossip threads the residual plane (donated — its
    successor replaces it); ``compensate > 0``: update threads the θ_prev
    plane (donated — the stage returns a fresh copy of this step's
    pre-update params as the next θ_prev).

    ``membership``: the alive mask rides as an extra NEVER-donated input
    positioned after each stage's donated argument span, so every
    donation index formula below is unchanged. The update stage emits
    the nonfinite-skip count as a second trailing scalar (always)."""
    pw = P(worker_axes if len(worker_axes) > 1 else worker_axes[0])
    fwd_bodies, update_body, gossip_body, metrics_fn = bodies
    int8 = wire == "int8"
    comp = float(compensate) > 0.0

    def sm(f, in_specs, out_specs):
        return shard_map(f, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, axis_names=set(worker_axes))

    fwd_sm = [sm(fwd_bodies[0], (pw, batch_specs), (pw, pw))]
    fwd_sm += [sm(b, (pw, batch_specs), pw) for b in fwd_bodies[1:]]
    fifo_in = (pw, P()) if D > 0 else ()
    theta_in = (pw,) if comp else ()
    alive_in = (pw,) if membership else ()
    update_sm = sm(update_body,
                   (pw, pw) + fifo_in + (pw,) + theta_in + alive_in + (P(),),
                   (pw, pw) + fifo_in + theta_in + (P(), P()))
    resid_in = (pw,) if int8 else ()
    gossip_in = (((pw, pw) if fused else (pw,)) + resid_in
                 + (pw, pw) + alive_in + (P(), P()))
    gossip_sm = sm(gossip_body, gossip_in, (pw,) + resid_in + (pw, pw))

    def gossip_step(*args):
        # gossip + the metric reduction in ONE executable: per-slice
        # per-worker losses combine in the monolithic order
        # ((l0 + sum(rest)) / R, then mean over workers) and the staleness
        # metrics read the freshly stamped clocks — identical math to
        # _decoupled_step_caller, one less dispatch per step
        if membership:
            *plane_args, w_st, versions, alive, losses, upd_stale, skips, \
                step_idx, shift_idx = args
        else:
            *plane_args, w_st, versions, losses, upd_stale, skips, \
                step_idx, shift_idx = args
            alive = None
        sm_args = (*plane_args, w_st, versions)
        if membership:
            sm_args += (alive,)
        outs = gossip_sm(*sm_args, step_idx, shift_idx)
        versions = outs[-1]
        metrics = metrics_fn(losses, outs[-2], versions, upd_stale, step_idx,
                             skips=skips, alive=alive)
        return outs[:-1] + (versions, metrics)

    n_upd = (5 if D > 0 else 3) + (1 if comp else 0)  # donate all but write
    donate_upd = tuple(range(1, n_upd))
    n_plane = (2 if fused else 1) + (1 if int8 else 0)
    # fused: skip the live plane (arg 0); non-fused: donate it too.
    # Then the resid (int8), the weights and the clocks.
    donate_gossip = tuple(range(1 if fused else 0, n_plane + 2))
    if shardings is None:
        fwd = [jax.jit(f) for f in fwd_sm]
        update = jax.jit(update_sm, donate_argnums=donate_upd)
        gossip = jax.jit(gossip_step, donate_argnums=donate_gossip)
    else:
        s = shardings
        fwd = [jax.jit(fwd_sm[0], in_shardings=(s["p"], s["batch"]),
                       out_shardings=(s["lossvec"], s["grads"]))]
        fwd += [jax.jit(f, in_shardings=(s["p"], s["batch"]),
                        out_shardings=s["lossvec"]) for f in fwd_sm[1:]]
        fifo_sh = (s["fifo_g"], s["scalar"]) if D > 0 else ()
        theta_sh = (s["p"],) if comp else ()
        alive_sh = (s["w"],) if membership else ()
        update = jax.jit(
            update_sm,
            in_shardings=(s["p"], s["opt"]) + fifo_sh
            + (s["grads"],) + theta_sh + alive_sh + (s["scalar"],),
            out_shardings=(s["upd"], s["opt"]) + fifo_sh + theta_sh
            + (s["scalar"], s["scalar"]),
            donate_argnums=donate_upd)
        R_loss = tuple([s["lossvec"]] * len(fwd_sm))
        resid_sh = (s["p"],) if int8 else ()
        gossip_p = ((s["p"], s["upd"]) if fused else (s["p"],)) + resid_sh
        gossip = jax.jit(
            gossip_step,
            in_shardings=gossip_p + (s["w"], s["w"]) + alive_sh
            + (R_loss, s["scalar"], s["scalar"], s["scalar"], s["scalar"]),
            out_shardings=(s["p"],) + resid_sh
            + (s["w"], s["w"], s["metrics"]),
            donate_argnums=donate_gossip)
    return {"fwd": fwd, "update": update, "gossip": gossip}


def _jit_group_stages(part: FlatPartition, mesh, worker_axes, M: int,
                      mix: Callable, metrics_fn: Callable,
                      shifts: Sequence[int], *, fused: bool = False,
                      shardings: Optional[Dict[str, Any]] = None,
                      R: int = 1, wire: str = "param",
                      membership: bool = False):
    """The gossip stage split at the layer-group boundary, for the stream
    engine (``streams > 1``): one jitted mix executable PER PLANE BUFFER
    plus one clock/metrics executable.

    Each mix calls the very same gossip lane closure on a single-buffer
    sub-dict ``{name: buf}`` — the lanes iterate ``plane.items()``, so the
    per-element f32 math is bitwise-identical to the full-plane stage; the
    push-sum weight exchange is recomputed per group (a scalar ppermute —
    cheap and deterministic, so every group derives the identical
    ``w_half``/``rw``) and the mixed weight is discarded. The clock stage
    recomputes the exchange ONCE more to produce the canonical new weight,
    stamps the version clocks (M > 1), and folds the metric reduction —
    together the group stages compute exactly what ``_jit_stages``' fused
    gossip stage computes, split so each group's mix can launch as soon as
    its own signal lands (one-sided gossip, DESIGN.md §13).

    Donation: the non-fused mix donates its fresh-plane input (the update
    stage's per-group output — sole live reference); the fused mix
    donates the update DELTAS and leaves the live plane alone (the
    forward slices of the same step still read it). Neither donates the
    push-sum weights: the clock donates those (and the clocks), which is
    safe only because the stream engine runs every mix of a step before
    its clock on the same FIFO stream.

    ``wire="int8"``: each mix gains its group's residual buffer and
    returns ``(mixed, resid)`` — the residual is donated alongside the
    usual set (its successor replaces it).

    ``membership``: every mix and the clock gain the (never-donated)
    alive mask just before ``shift_idx``; the clock also threads the
    update stage's nonfinite-skip scalar into the metric fold."""
    pw = P(worker_axes if len(worker_axes) > 1 else worker_axes[0])
    ax = worker_axes if len(worker_axes) > 1 else worker_axes[0]
    phi = jnp.asarray(send_fractions(part.num_groups))
    int8 = wire == "int8"

    def sm(f, in_specs, out_specs):
        return shard_map(f, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, axis_names=set(worker_axes))

    def make_mix_body(name):
        # the alive mask (membership) rides just before shift_idx so the
        # donated-argument indices below stay put for every variant
        def mix_body(*args):
            if membership:
                *head, alive_st, shift_idx = args
                a = alive_st[0]
            else:
                *head, shift_idx = args
                a = None
            if fused and int8:
                buf_st, upd_st, resid_st, w_st = head
                mixed, resid, _ = mix({name: buf_st[0]}, {name: resid_st[0]},
                                      {name: upd_st[0]}, w_st[0], shift_idx,
                                      alive=a)
                return mixed[name][None], resid[name][None]
            if fused:
                buf_st, upd_st, w_st = head
                mixed, _ = mix({name: buf_st[0]}, {name: upd_st[0]},
                               w_st[0], shift_idx, alive=a)
                return mixed[name][None]
            if int8:
                buf_st, resid_st, w_st = head
                mixed, resid, _ = mix({name: buf_st[0]}, {name: resid_st[0]},
                                      w_st[0], shift_idx, alive=a)
                return mixed[name][None], resid[name][None]
            buf_st, w_st = head
            mixed, _ = mix({name: buf_st[0]}, w_st[0], shift_idx, alive=a)
            return mixed[name][None]
        return mix_body

    def clock_body(*args):
        if membership:
            w_st, versions, alive_st, step_idx, shift_idx = args
            al = alive_st[0]
        else:
            w_st, versions, step_idx, shift_idx = args
            al = None
        w = w_st[0]
        if M > 1:
            # the same scalar push-sum hop the full-plane gossip stage
            # performs, on an empty plane — only the weight ships
            _, w_keep, rw, _ = _ring_exchange({}, w, shift_idx, M, ax,
                                              shifts, alive=al)
            w = w_keep + rw
            stamped = stamp_groups(versions,
                                   step_idx.astype(jnp.float32) + phi)
            versions = stamped if al is None else \
                jnp.where(al > 0.0, stamped, versions)
        return w[None], versions

    resid_in = (pw,) if int8 else ()
    alive_in = (pw,) if membership else ()
    mix_in = (((pw, pw) if fused else (pw,)) + resid_in + (pw,)
              + alive_in + (P(),))
    mix_out = (pw, pw) if int8 else pw
    mix_sms = {name: sm(make_mix_body(name), mix_in, mix_out)
               for name in part.group_sizes}
    clock_sm = sm(clock_body, (pw, pw) + alive_in + (P(), P()), (pw, pw))

    def clock_step(*args):
        if membership:
            w_st, versions, alive, losses, upd_stale, skips, step_idx, \
                shift_idx = args
            clock_args = (w_st, versions, alive, step_idx, shift_idx)
        else:
            w_st, versions, losses, upd_stale, skips, step_idx, \
                shift_idx = args
            alive = None
            clock_args = (w_st, versions, step_idx, shift_idx)
        w, versions = clock_sm(*clock_args)
        metrics = metrics_fn(losses, w, versions, upd_stale, step_idx,
                             skips=skips, alive=alive)
        return w, versions, metrics

    if fused:
        donate_mix = (1, 2) if int8 else (1,)
    else:
        donate_mix = (0, 1) if int8 else (0,)
    if shardings is None:
        mixes = {name: jax.jit(f, donate_argnums=donate_mix)
                 for name, f in mix_sms.items()}
        clock = jax.jit(clock_step, donate_argnums=(0, 1))
    else:
        s = shardings
        buf = lambda name: s["p"][name]
        mixes = {}
        alive_sh = (s["w"],) if membership else ()
        for name, f in mix_sms.items():
            resid_sh = (buf(name),) if int8 else ()
            mix_sh = (((buf(name), s["upd"][name]) if fused
                       else (buf(name),)) + resid_sh + (s["w"],)
                      + alive_sh + (s["scalar"],))
            mix_out_sh = (buf(name), buf(name)) if int8 else buf(name)
            mixes[name] = jax.jit(f, in_shardings=mix_sh,
                                  out_shardings=mix_out_sh,
                                  donate_argnums=donate_mix)
        R_loss = tuple([s["lossvec"]] * R)
        clock = jax.jit(
            clock_step,
            in_shardings=(s["w"], s["w"]) + alive_sh
            + (R_loss, s["scalar"], s["scalar"], s["scalar"], s["scalar"]),
            out_shardings=(s["w"], s["w"], s["metrics"]),
            donate_argnums=(0, 1))
    return {"mix": mixes, "clock": clock}


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------


class PipelineEngine:
    """Owns the stage executables, the double buffers and the timeline.

    ``step(state, batch, step_idx, shift_idx) -> (state, metrics)`` keeps
    the monolithic step's signature and state layout (``read``/``write``/
    ``opt``/``w``/``versions``[/``fifo``] dict), but every return value is
    an un-awaited future: the caller can dispatch the next step before this
    one finished, and the runtime chains the data dependencies. Blocking
    happens only when the caller converts a metric (or calls
    ``timeline.finalize()``)."""

    def __init__(self, *, R: int, D: int, M: int, stages: Dict[str, Any],
                 timeline: Optional[StageTimeline] = None, describe: str = "",
                 abstract_args: Optional[Dict[str, tuple]] = None,
                 max_inflight_steps: int = 3, fused: bool = False,
                 wire: str = "param", compensate: float = 0.0):
        self.R, self.D, self.M = int(R), int(D), int(M)
        self.fused = bool(fused)
        self.wire = wire
        self.compensate = float(compensate)
        self._stages = stages
        self.timeline = timeline if timeline is not None else StageTimeline()
        self.describe = describe
        self.abstract_args = abstract_args or {}
        # deferred-release buffers: dropping the LAST python reference to a
        # buffer that an in-flight stage still reads makes the CPU PJRT
        # client block the host until the readers retire — rebinding the
        # state dict each step would silently serialize the pipeline. The
        # engine therefore keeps each step's consumed handles alive until
        # that step's final fence is ready, and releases them on a later
        # (non-blocking) prune. ``max_inflight_steps`` is the backpressure
        # bound: the host blocks on the oldest step's fence rather than
        # run further ahead, capping the extra memory at that many
        # retired-but-held step states.
        self.max_inflight_steps = int(max_inflight_steps)
        self._graveyard: List[Tuple[Any, Any]] = []

    def step(self, state, batch, step_idx, shift_idx):
        """Dispatch one decoupled update iteration; never blocks on math.

        ``state`` is the decoupled state dict (``read``/``write``/``opt``/
        ``w``/``versions``[/``fifo``] — from ``make_decoupled_state``, or
        a previous ``step``'s return, whose leaves may be un-awaited
        futures). ``batch`` is one step's input; ``step_idx``/``shift_idx``
        should be python ints or numpy scalars — a ``jnp`` scalar is an
        eager device-0 computation whose reshard queues behind every
        in-flight stage and serializes the pipeline.

        Dispatches the R forward slices, the backward/update and the
        gossip(+metrics) stage as separate async jit calls and returns
        ``(new_state, metrics)`` immediately: every value is a future, the
        runtime chains the data dependencies, and the host may call
        ``step`` again for ``t+1`` while ``t`` still executes (bounded by
        ``max_inflight_steps`` backpressure). Converting any metric (e.g.
        ``float(metrics["loss"])``) blocks on that value only."""
        tl = self.timeline
        t = int(step_idx)
        # release buffers whose step has fully retired (never blocks), then
        # apply backpressure: at most max_inflight_steps steps in flight
        self._graveyard = [(f, p) for f, p in self._graveyard
                           if not _is_ready(f)]
        while len(self._graveyard) >= self.max_inflight_steps:
            try:
                jax.block_until_ready(self._graveyard[0][0])
            except Exception:
                pass
            self._graveyard.pop(0)
            self._graveyard = [(f, p) for f, p in self._graveyard
                               if not _is_ready(f)]
        # numpy scalars, NOT jnp.asarray: an eager conversion is a tiny
        # computation committed to device 0 whose reshard-to-replicated
        # then queues behind every in-flight stage — one jnp scalar per
        # step silently serializes the whole pipeline (measured on the
        # CPU PJRT client). A numpy scalar rides the jit call's host→device
        # put, which never touches the execution queue.
        si = (step_idx if isinstance(step_idx, jax.Array)
              else np.int32(step_idx))
        sh = (shift_idx if isinstance(shift_idx, jax.Array)
              else np.int32(shift_idx))

        # forward lane: all R slices read the same (never-donated) buffer
        ev = tl.begin("fwd", t, slice_idx=0)
        loss0, grads = self._stages["fwd"][0](state["read"], batch)
        tl.commit(ev, loss0)
        losses = [loss0]
        for r in range(1, self.R):
            ev = tl.begin("fwd", t, slice_idx=r)
            lr = self._stages["fwd"][r](state["read"], batch)
            tl.commit(ev, lr)
            losses.append(lr)

        # backward/update lane: donates opt + fifo + grads (+ the stale-θ
        # plane when compensating), NOT the params (the write handle
        # aliases the read buffer the fwd slices consume). In fused
        # (use_pallas) mode the first output is the update-delta plane and
        # the write buffer is consumed read-only.
        comp = self.compensate > 0.0
        int8 = self.wire == "int8"
        # membership (chaos lane): the alive mask is a never-donated
        # passthrough — the chaos controller mutates it host-side at
        # fault events, every stage reads it
        alive = state.get("alive")
        ev = tl.begin("update", t)
        upd_args = (state["write"], state["opt"])
        if self.D > 0:
            upd_args += (state["fifo"]["g"], state["fifo"]["stamp"])
        upd_args += (grads,)
        if comp:
            upd_args += (state["theta"],)
        if alive is not None:
            upd_args += (alive,)
        upd_outs = self._stages["update"](*upd_args, si)
        write, opt = upd_outs[0], upd_outs[1]
        i = 2
        if self.D > 0:
            fifo_g, fifo_stamp = upd_outs[2], upd_outs[3]
            i = 4
        if comp:
            theta = upd_outs[i]
            i += 1
        upd_stale, skips = upd_outs[i], upd_outs[i + 1]
        tl.commit(ev, upd_stale)

        # gossip lane (+ fused metric reduction): the mixed result becomes
        # both next-step buffer handles. Default: donates the update's
        # fresh output — the flat plane itself — + w + versions. Fused:
        # the plane argument aliases the live read buffer, so the deltas
        # are donated instead of the plane. int8 wire: the EF residual
        # plane rides along (donated; its successor replaces it).
        ev = tl.begin("gossip", t)
        plane_args = (state["write"], write) if self.fused else (write,)
        if int8:
            plane_args += (state["resid"],)
        gossip_args = plane_args + (state["w"], state["versions"])
        if alive is not None:
            gossip_args += (alive,)
        gossip_outs = self._stages["gossip"](
            *gossip_args, tuple(losses), upd_stale, skips, si, sh)
        if int8:
            mixed, resid, w, versions, metrics = gossip_outs
        else:
            mixed, w, versions, metrics = gossip_outs
        tl.commit(ev, metrics["loss"])

        # hold EVERY handle this step touched until its last fence retires:
        # on the CPU PJRT client, dropping the final python reference to
        # any buffer an in-flight execution reads (the old read/write
        # params), was donated (opt/fifo/w/versions, grads), or has not
        # yet materialized (the previous metrics dict the caller rebinds)
        # blocks the host until that execution completes — any one of
        # those silently serializes the pipeline. Holding the handles is
        # free (no copies); they are released on a later non-blocking
        # prune once the fence is ready.
        self._graveyard.append(
            (metrics["loss"], (state, metrics, losses, upd_stale, skips,
                               grads, write)))

        new_state = {"read": mixed, "write": mixed, "opt": opt, "w": w,
                     "versions": versions}
        if self.D > 0:
            new_state["fifo"] = {"g": fifo_g, "stamp": fifo_stamp}
        if int8:
            new_state["resid"] = resid
        if comp:
            new_state["theta"] = theta
        if alive is not None:
            new_state["alive"] = alive
        return new_state, metrics

    def reset(self) -> None:
        """Prepare for a fresh measured run: finalize and drop the
        timeline's events, then release the held step handles (safe —
        finalize just retired every fence they wait on)."""
        self.timeline.reset()
        self._graveyard = []

    def stage_cutouts(self) -> Dict[str, Tuple[Any, tuple]]:
        """Every separately jitted stage executable paired with the
        abstract argument signature to synthesize inputs from — the
        autotuner's extraction point (``launch/tuner.py``, DESIGN.md
        §16). Keys match ``lower()``: ``fwd0..fwdR-1``, ``update``,
        ``gossip``. Raises when the engine carries no abstract args
        (legacy tree state) or the forward batch signature is still the
        backend path's placeholder (step once first)."""
        if not self.abstract_args:
            raise ValueError(
                "engine has no abstract args to cut stages out against "
                "(the flat-plane factories publish them at build; the "
                "legacy tree state has none)")
        if self.abstract_args["fwd"][-1] is None:
            raise ValueError(
                "forward batch abstract unknown: step the engine once so "
                "the backend path records the batch signature")
        out = {}
        for r, f in enumerate(self._stages["fwd"]):
            out[f"fwd{r}"] = (f, self.abstract_args["fwd"])
        for name in ("update", "gossip"):
            out[name] = (self._stages[name], self.abstract_args[name])
        return out

    def lower(self) -> Dict[str, Any]:
        """Lower every stage executable against its abstract args."""
        if not self.abstract_args:
            raise ValueError("engine has no abstract args to lower against")
        out = {}
        for r, f in enumerate(self._stages["fwd"]):
            out[f"fwd{r}"] = f.lower(*self.abstract_args["fwd"])
        for name in ("update", "gossip"):
            out[name] = self._stages[name].lower(*self.abstract_args[name])
        return out


@dataclass
class PipelineStep:
    """Drop-in analogue of :class:`~repro.launch.train.ProdStep` for the
    overlap engine: ``fn(state, batch, step_idx, shift_idx)`` like the
    monolithic decoupled step, ``init_state(params_stacked)`` builds the
    engine-managed state, ``lower()`` lowers every stage."""
    engine: PipelineEngine
    init_state: Callable
    describe: str = ""

    def fn(self, state, batch, step_idx, shift_idx):
        return self.engine.step(state, batch, step_idx, shift_idx)

    def lower(self):
        return self.engine.lower()

    @property
    def timeline(self) -> StageTimeline:
        return self.engine.timeline


# ---------------------------------------------------------------------------
# factories: Model/mesh path and generic-backend path
# ---------------------------------------------------------------------------


def flat_abstract_args(part, optimizer: Optimizer, M: int, R: int, D: int, *,
                       batch_abs=None, fused: bool = False,
                       wire: str = "param", compensate: float = 0.0,
                       membership: bool = False,
                       groups: bool = False) -> Dict[str, tuple]:
    """Abstract argument signatures for every stage executable of a
    FLAT-plane engine, keyed like ``PipelineEngine.abstract_args``
    (``"fwd"``/``"update"``/``"gossip"``, plus ``"mix:{group}"``/
    ``"clock"`` when ``groups=True`` for the stream engine).

    This is the cutout-extraction contract (``launch/tuner.py``,
    DESIGN.md §16): both factory paths publish these on the engine so
    each stage is independently lowerable and runnable in isolation.
    ``batch_abs=None`` leaves a placeholder the backend path fills from
    the first concrete batch it sees (``stage_cutouts()`` refuses to
    hand out the forward stage until then)."""
    stack = lambda s: jax.ShapeDtypeStruct((M,) + tuple(s.shape), s.dtype)
    stacked_params = part.abstract_plane((M,))
    stacked_opt = jax.tree.map(
        stack, jax.eval_shape(optimizer.init, part.abstract_plane()))
    i32 = jax.ShapeDtypeStruct((), jnp.int32)
    f32 = jax.ShapeDtypeStruct((), jnp.float32)
    w_abs = jax.ShapeDtypeStruct((M,), jnp.float32)
    v_abs = jax.ShapeDtypeStruct((M, part.num_groups), jnp.float32)
    lossvec_abs = jax.ShapeDtypeStruct((M,), jnp.float32)
    fifo_abs = ()
    if D > 0:
        fifo_abs = (part.abstract_plane((M, D)),
                    jax.ShapeDtypeStruct((D,), jnp.float32))
    upd_abs = (jax.eval_shape(
        lambda p: optimizer.update(p, optimizer.init(p), p, 0.1)[0],
        part.abstract_plane()) if fused else stacked_params)
    if fused:
        upd_abs = jax.tree.map(stack, upd_abs)
    int8 = wire == "int8"
    comp = float(compensate) > 0.0
    resid_abs = (stacked_params,) if int8 else ()
    theta_abs = (stacked_params,) if comp else ()
    alive_abs = (w_abs,) if membership else ()
    gossip_plane_abs = (((stacked_params, upd_abs) if fused
                         else (stacked_params,)) + resid_abs)
    out = {
        "fwd": (stacked_params, batch_abs),
        "update": (stacked_params, stacked_opt) + fifo_abs
                  + (stacked_params,) + theta_abs + alive_abs + (i32,),
        "gossip": gossip_plane_abs + (w_abs, v_abs) + alive_abs
                  + (tuple([lossvec_abs] * R), f32, f32, i32, i32),
    }
    if groups:
        for name in part.group_sizes:
            buf_abs = ((stacked_params[name], upd_abs[name]) if fused
                       else (stacked_params[name],))
            if int8:
                buf_abs = buf_abs + (stacked_params[name],)
            out[f"mix:{name}"] = buf_abs + (w_abs,) + alive_abs + (i32,)
        out["clock"] = ((w_abs, v_abs) + alive_abs
                        + (tuple([lossvec_abs] * R), f32, f32, i32, i32))
    return out


def make_layup_decoupled_pipeline(model, mesh, optimizer: Optimizer,
                                  schedule: Callable, shape,
                                  shifts: Sequence[int] = (1, 2, 4, 8),
                                  overrides: Optional[Dict[str, Any]] = None,
                                  preset: Optional[str] = None,
                                  fb_ratio: int = 2, update_delay: int = 1,
                                  constrain_grads: bool = False,
                                  timeline: Optional[StageTimeline] = None,
                                  flat: bool = True,
                                  use_pallas: bool = False,
                                  streams: int = 1, wire: str = "param",
                                  compensate: float = 0.0,
                                  membership: bool = False,
                                  max_inflight_steps: Optional[int] = None
                                  ) -> PipelineStep:
    """The decoupled LayUp lane as a stage-graph pipeline on the real mesh —
    same sharding/abstract setup as ``make_layup_decoupled_train_step``,
    split into separately jitted stages. ``flat=True`` (default): the
    engine's double buffers ARE the persistent flat plane and the gossip
    stage donates it; ``use_pallas`` swaps in the fused-kernel gossip
    stage (DESIGN.md §11). ``streams > 1`` runs the stages on per-stage
    execution streams with one-sided per-group signal gossip
    (:class:`repro.launch.streams.StreamEngine`, DESIGN.md §13) — same
    numerics, measured *execution* overlap; requires ``flat=True``.
    ``wire="int8"`` quantizes the gossip wire with error-feedback
    residuals; ``compensate > 0`` enables the staleness-aware delay
    correction in the update stage (DESIGN.md §14). ``membership`` adds
    the per-peer alive mask to the state and alive-gates every exchange
    (fault-tolerant lane, DESIGN.md §15)."""
    cfg = model.cfg
    worker_axes = data_axes(mesh)
    ax = worker_axes if len(worker_axes) > 1 else worker_axes[0]
    M = num_workers(mesh)
    R, D = int(fb_ratio), int(update_delay)
    if shape.global_batch % (M * max(R, 1)):
        raise ValueError(
            f"global_batch={shape.global_batch} must divide by "
            f"M*R={M}*{R} for the decoupled forward lane")
    shifts = tuple(s % M for s in shifts if s % M != 0) or (1,)

    grad_specs = None
    if constrain_grads:
        rules_g = SH.rules_for(mesh, overrides, preset)
        from repro.models.layers import is_spec
        grad_specs = jax.tree.map(
            lambda sp: SH.spec_for_axes(tuple(sp.axes), rules_g, mesh,
                                        tuple(sp.shape)),
            model.specs, is_leaf=is_spec)

    if use_pallas and not flat:
        raise ValueError("use_pallas requires the flat plane (flat=True)")
    if streams > 1 and not flat:
        raise ValueError("streams > 1 ships the flat group plane across "
                         "the stream boundary; it requires flat=True")
    _check_wire(wire, compensate, flat, membership)
    int8 = wire == "int8"
    comp = float(compensate) > 0.0
    part = FlatPartition(model.abstract_params())
    fwd_slices = [forward_slice_lane(model.loss_fn, fb_ratio=R, slice_idx=r,
                                     grad_specs=grad_specs)
                  for r in range(R)]
    upd = backward_update_lane(optimizer, schedule, update_delay=D,
                               apply=not use_pallas, compensate=compensate)
    if use_pallas:
        mix = gossip_fused_lane(part, M, ax, shifts, wire=wire)
    elif flat:
        mix = gossip_plane_lane(part, M, ax, shifts, wire=wire)
    else:
        mix = gossip_lane_legacy(part, M, ax, shifts)
    bodies = _stage_bodies(part, R, D, M, worker_axes, fwd_slices, upd, mix,
                           flat=flat, fused=use_pallas, wire=wire,
                           compensate=compensate, membership=membership)

    pw = P(ax)
    abstract_params = model.abstract_params()
    stack = lambda s: jax.ShapeDtypeStruct((M,) + tuple(s.shape), s.dtype)
    abstract_opt_base = part.abstract_plane() if flat else abstract_params
    if flat:
        stacked_params = part.abstract_plane((M,))
        fifo_g_abs = part.abstract_plane((M, D))
    else:
        stacked_params = jax.tree.map(stack, abstract_params)
        fifo_g_abs = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((M, D) + tuple(s.shape), s.dtype),
            abstract_params)
    abstract_opt_single = jax.eval_shape(optimizer.init, abstract_opt_base)
    stacked_opt = jax.tree.map(stack, abstract_opt_single)
    batch_abs = _abstract_batch(cfg, shape)

    w_sh = NamedSharding(mesh, pw)
    scalar = NamedSharding(mesh, P())
    if flat:
        p_sh = jax.tree.map(lambda _: w_sh, stacked_params)
        opt_sh = jax.tree.map(lambda _: w_sh, stacked_opt)
        fifo_g_sh = jax.tree.map(lambda _: w_sh, fifo_g_abs)
    else:
        p_sh = SH.param_shardings(model, mesh, stacked_workers=M,
                                  overrides=overrides, preset=preset)
        opt_sh = _opt_shardings_stacked(abstract_opt_single, abstract_params,
                                        p_sh, mesh, M)
        fifo_g_sh = jax.tree.map(
            lambda s: NamedSharding(
                mesh, P(s.spec[0], None, *tuple(s.spec)[1:])), p_sh)
    b_sh = SH.batch_shardings(batch_abs, mesh, overrides=overrides,
                              preset=preset)
    metrics_sh = {"loss": scalar, "update_staleness": scalar,
                  "layer_staleness": scalar, "staleness_mean": scalar,
                  "weight_sum": scalar, "nonfinite_skips": scalar}
    if membership:
        metrics_sh["peers_live"] = scalar
    shardings = {
        "p": p_sh, "opt": opt_sh, "w": w_sh, "scalar": scalar, "batch": b_sh,
        "lossvec": w_sh, "grads": p_sh, "upd": p_sh,
        "fifo_g": fifo_g_sh,
        "metrics": metrics_sh,
    }
    batch_specs_sm = jax.tree.map(_worker_batch_pspec(ax), batch_abs)
    stages = _jit_stages(bodies, mesh, worker_axes, R, D,
                         batch_specs=batch_specs_sm, shardings=shardings,
                         fused=use_pallas, wire=wire, compensate=compensate,
                         membership=membership)

    if flat:
        # the shared helper IS the published stage-signature contract
        # (cutout extraction, DESIGN.md §16) — the backend path builds
        # the identical dict, minus the batch it learns at step one
        abstract_args = flat_abstract_args(
            part, optimizer, M, R, D, batch_abs=batch_abs,
            fused=use_pallas, wire=wire, compensate=compensate,
            membership=membership, groups=streams > 1)
    else:
        i32 = jax.ShapeDtypeStruct((), jnp.int32)
        f32 = jax.ShapeDtypeStruct((), jnp.float32)
        w_abs = jax.ShapeDtypeStruct((M,), jnp.float32)
        v_abs = jax.ShapeDtypeStruct((M, part.num_groups), jnp.float32)
        lossvec_abs = jax.ShapeDtypeStruct((M,), jnp.float32)
        fifo_abs = ()
        if D > 0:
            fifo_abs = (fifo_g_abs, jax.ShapeDtypeStruct((D,), jnp.float32))
        upd_abs = stacked_params
        resid_abs = (stacked_params,) if int8 else ()
        theta_abs = (stacked_params,) if comp else ()
        alive_abs = (w_abs,) if membership else ()
        gossip_plane_abs = (stacked_params,) + resid_abs
        abstract_args = {
            "fwd": (stacked_params, batch_abs),
            "update": (stacked_params, stacked_opt) + fifo_abs
                      + (stacked_params,) + theta_abs + alive_abs + (i32,),
            "gossip": gossip_plane_abs + (w_abs, v_abs) + alive_abs
                      + (tuple([lossvec_abs] * R), f32, f32, i32, i32),
        }
    tags = (f"{', pallas' if use_pallas else ''}"
            f"{', wire=int8' if int8 else ''}"
            f"{f', comp={float(compensate):g}' if comp else ''}"
            f"{', membership' if membership else ''}")
    inflight_kw = ({} if max_inflight_steps is None
                   else {"max_inflight_steps": int(max_inflight_steps)})
    if streams > 1:
        from repro.launch.streams import StreamEngine
        group_stages = _jit_group_stages(part, mesh, worker_axes, M, mix,
                                         bodies[3], shifts,
                                         fused=use_pallas,
                                         shardings=shardings, R=R,
                                         wire=wire, membership=membership)
        engine = StreamEngine(
            R=R, D=D, M=M, group_names=list(part.group_sizes),
            stages=stages, group_stages=group_stages, timeline=timeline,
            n_streams=streams, fused=use_pallas, wire=wire,
            compensate=compensate,
            describe=(f"layup decoupled stream pipeline (M={M}, R={R}, "
                      f"D={D}, shifts={shifts}, streams={streams}, "
                      f"groups={len(part.group_sizes)}{tags})"),
            abstract_args=abstract_args, **inflight_kw)
    else:
        engine = PipelineEngine(
            R=R, D=D, M=M, stages=stages, timeline=timeline,
            fused=use_pallas, wire=wire, compensate=compensate,
            describe=(f"layup decoupled pipeline (M={M}, R={R}, D={D}, "
                      f"shifts={shifts}, stages={R + 2}, flat={flat}"
                      f"{tags})"),
            abstract_args=abstract_args, **inflight_kw)

    def init_state(params_stacked):
        state = make_decoupled_state(params_stacked, optimizer,
                                     update_delay=D, part=part, flat=flat,
                                     wire=wire, compensate=compensate,
                                     membership=membership)
        if membership:
            # the alive mask is a passthrough (never a stage OUTPUT), so
            # unlike w it would keep its eager default-device placement
            # forever — commit it to the mesh like the stage inputs expect
            state["alive"] = jax.device_put(state["alive"], w_sh)
        return state

    return PipelineStep(engine, init_state, engine.describe)


def make_pipeline_backend_trainer(loss_fn: Callable, optimizer: Optimizer,
                                  schedule: Callable, mesh, *,
                                  shifts: Sequence[int] = (1, 2, 4, 8),
                                  fb_ratio: int = 1, update_delay: int = 0,
                                  straggler_delays=None,
                                  measure_drift: bool = False,
                                  timeline: Optional[StageTimeline] = None,
                                  flat: bool = True,
                                  use_pallas: bool = False,
                                  publisher=None,
                                  streams: int = 1, wire: str = "param",
                                  compensate: float = 0.0,
                                  membership: bool = False,
                                  max_inflight_steps: Optional[int] = None):
    """Pipeline-engine counterpart of ``make_decoupled_backend_trainer``:
    same generic pytree + loss_fn contract, same sim-layout batches, but
    the step is the stage-graph engine instead of one jitted program.

    ``wire="int8"`` quantizes the gossip wire (error-feedback residuals
    ride the state as an extra plane); ``compensate > 0`` turns on the
    staleness-aware delay correction in the update stage (DESIGN.md §14).

    ``streams > 1`` swaps in the :class:`repro.launch.streams.
    StreamEngine`: the same fwd/update stage executables plus the gossip
    stage split per layer group, run on dedicated execution streams
    coordinated by one-sided signals (DESIGN.md §13). Numerics stay
    loss/staleness-exact vs ``streams=1``; the timeline gains measured
    ``exec_overlap_s``. Requires ``flat=True``; ``publisher`` is not
    supported with ``streams > 1`` yet (the publisher contract expects
    concrete read-plane handles at publish time, not stream futures).

    ``publisher`` (a :class:`repro.serving.PlanePublisher`) receives the
    engine's read plane + version clocks + drift once per gossip round.
    This is the ZERO-COPY publish path: the engine never donates the read
    plane (all R forward slices of a step share it — see the donation
    rules above), so the published handles stay valid for the snapshot's
    lifetime and the publish is ``stable=True``. The (tiny) version/weight
    arrays ARE donated by the next step's gossip stage, so the publisher
    copies those; nothing in the publish blocks the host or disturbs the
    engine's dispatch run-ahead (DESIGN.md §12). Requires ``flat=True``.

    Returns ``(init_fn, step_fn, shifts, box)`` — ``box["engine"]`` holds
    the :class:`PipelineEngine` once ``init_fn`` has seen the params."""
    worker_axes = data_axes(mesh)
    ax = worker_axes if len(worker_axes) > 1 else worker_axes[0]
    M = num_workers(mesh)
    R, D = int(fb_ratio), int(update_delay)
    shifts = tuple(s % M for s in shifts if s % M != 0) or (1,)
    active_fn = straggler_active_fn(mesh, straggler_delays)
    pw = P(ax)
    box: Dict[str, Any] = {}

    if use_pallas and not flat:
        raise ValueError("use_pallas requires the flat plane (flat=True)")
    if publisher is not None and not flat:
        raise ValueError("publisher needs the flat plane (flat=True): the "
                         "legacy tree state has no per-group plane to "
                         "publish")
    if streams > 1 and not flat:
        raise ValueError("streams > 1 ships the flat group plane across "
                         "the stream boundary; it requires flat=True")
    if streams > 1 and publisher is not None:
        raise ValueError("publisher is not supported with streams > 1: "
                         "the stream engine's read plane is a future, not "
                         "a stable handle to publish (serve from a "
                         "streams=1 engine, or materialize snapshots)")
    _check_wire(wire, compensate, flat, membership)

    def build(params_single):
        part = FlatPartition(params_single)
        fwd_slices = [forward_slice_lane(loss_fn, fb_ratio=R, slice_idx=r)
                      for r in range(R)]
        upd = backward_update_lane(optimizer, schedule, update_delay=D,
                                   apply=not use_pallas,
                                   compensate=compensate)
        if use_pallas:
            mix = gossip_fused_lane(part, M, ax, shifts, wire=wire)
        elif flat:
            mix = gossip_plane_lane(part, M, ax, shifts, wire=wire)
        else:
            mix = gossip_lane_legacy(part, M, ax, shifts)
        bodies = _stage_bodies(part, R, D, M, worker_axes, fwd_slices, upd,
                               mix, squeeze_batch=True, active_fn=active_fn,
                               flat=flat, fused=use_pallas, wire=wire,
                               compensate=compensate, membership=membership)
        stages = _jit_stages(bodies, mesh, worker_axes, R, D, batch_specs=pw,
                             fused=use_pallas, wire=wire,
                             compensate=compensate, membership=membership)
        tags = (f"{', pallas' if use_pallas else ''}"
                f"{', wire=int8' if wire == 'int8' else ''}"
                f"{f', comp={float(compensate):g}' if compensate else ''}"
                f"{', membership' if membership else ''}")
        # publish the stage signatures so the tuner can cut stages out of
        # a backend-path engine too; the forward BATCH abstract is a
        # placeholder until step_fn sees the first concrete batch
        absargs = None
        if flat:
            absargs = flat_abstract_args(
                part, optimizer, M, R, D, fused=use_pallas, wire=wire,
                compensate=compensate, membership=membership,
                groups=streams > 1)
        inflight_kw = ({} if max_inflight_steps is None
                       else {"max_inflight_steps": int(max_inflight_steps)})
        if streams > 1:
            from repro.launch.streams import StreamEngine
            group_stages = _jit_group_stages(part, mesh, worker_axes, M,
                                             mix, bodies[3], shifts,
                                             fused=use_pallas, R=R,
                                             wire=wire,
                                             membership=membership)
            engine = StreamEngine(
                R=R, D=D, M=M, group_names=list(part.group_sizes),
                stages=stages, group_stages=group_stages,
                timeline=timeline, n_streams=streams, fused=use_pallas,
                wire=wire, compensate=compensate,
                describe=(f"stream pipeline backend (M={M}, R={R}, D={D}, "
                          f"streams={streams}, "
                          f"groups={len(part.group_sizes)}{tags})"),
                abstract_args=absargs, **inflight_kw)
        else:
            engine = PipelineEngine(
                R=R, D=D, M=M, stages=stages, timeline=timeline,
                fused=use_pallas, wire=wire, compensate=compensate,
                describe=(f"pipeline backend (M={M}, R={R}, D={D}, "
                          f"flat={flat}{tags})"),
                abstract_args=absargs, **inflight_kw)
        return engine, part

    def init_fn(rng, params_single):
        del rng
        stacked = jax.tree.map(
            lambda p: jnp.broadcast_to(p[None], (M,) + p.shape),
            params_single)
        if "engine" not in box:
            box["engine"], box["part"] = build(params_single)
            if measure_drift:
                from repro.core.api import disagreement
                box["drift"] = jax.jit(disagreement)
        state = make_decoupled_state(stacked, optimizer, update_delay=D,
                                     part=box["part"], flat=flat,
                                     wire=wire, compensate=compensate,
                                     membership=membership)
        if membership:
            # passthrough leaf: commit to the mesh once (see the Model
            # path's init_state) — no stage output ever re-shards it
            state["alive"] = jax.device_put(
                state["alive"], NamedSharding(mesh, pw))
        return state

    def step_fn(state, batch, step_idx, shift_idx):
        if "engine" not in box:
            raise RuntimeError("call init_fn before step_fn")
        eng = box["engine"]
        if eng.abstract_args and eng.abstract_args["fwd"][-1] is None:
            # the backend path learns the forward batch signature from
            # the first concrete batch — from here on stage cutouts
            # (launch/tuner.py) and lower() work like the Model path
            eng.abstract_args["fwd"] = (
                eng.abstract_args["fwd"][0],
                jax.tree.map(
                    lambda x: jax.ShapeDtypeStruct(np.shape(x), x.dtype),
                    batch))
        state, metrics = eng.step(state, batch, step_idx, shift_idx)
        if measure_drift:
            if streams > 1:
                # state leaves are stream futures: run the drift jit on
                # the gossip stream after the step's clock (FIFO — the
                # inputs are concrete by then, and w is read before the
                # next clock donates it)
                metrics["disagreement"] = box["engine"].submit_aux(
                    "drift", box["drift"], (state["read"], state["w"]),
                    int(step_idx))
            else:
                metrics["disagreement"] = box["drift"](state["read"],
                                                       state["w"])
        if publisher is not None:
            # stable=True: the engine never donates the read plane, so the
            # snapshot pins the live handles — zero-copy. Everything here
            # is an async dispatch or a reference swap; the host keeps its
            # run-ahead over the in-flight stages.
            publisher.publish(state["read"], state["versions"], state["w"],
                              int(step_idx),
                              drift=metrics.get("disagreement"),
                              stable=True)
        return state, metrics

    return init_fn, step_fn, shifts, box
