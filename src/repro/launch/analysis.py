"""Roofline-term extraction for the CPU dry-run (TPU v5e is the target).

Three sources, used for what each is reliable at:

1. **Analytic cost model** (``analytic_costs``) — per-device FLOPs and
   minimum HBM traffic from the architecture/shape/sharding, including GSPMD
   padding for non-divisible dims, remat recompute, MoE capacity padding and
   causal/SWA attention factors. XLA's ``cost_analysis`` counts while-loop
   bodies once (verified) and CPU "bytes accessed" reflects CPU fusion, so
   the analytic model is the TPU-relevant number; the raw XLA values are
   recorded alongside for reference.
2. **Structured HLO parsing** (``parse_collectives``) — collective ops from
   ``compiled.as_text()`` with result-shape bytes and a ring-cost wire
   model; collectives inside while-loop bodies (the layer scan) are
   multiplied by the scan trip count.
3. **memory_analysis()** — per-device buffers from the full compile, with
   the measured XLA-CPU f32-residual artifact subtracted (see
   ``cpu_residual_artifact_bytes``).

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI.
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass, field, asdict
from typing import Dict, List, Optional, Set

PEAK_FLOPS = 197e12       # bf16 / chip
HBM_BW = 819e9            # bytes/s / chip
ICI_BW = 50e9             # bytes/s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_COLL_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_LINE_RE = re.compile(
    r"=\s*(?P<shapes>[^=]*?)\s*"
    r"(?P<kind>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?P<start>-start)?\(")
_SHAPE_RE = re.compile(r"(pred|bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64|u64)"
                       r"\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_COMP_START_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(")
_CALL_RE = re.compile(r"(?:body|calls|to_apply|branch_computations)="
                      r"\{?%?([\w\.\-]+(?:, ?%?[\w\.\-]+)*)\}?")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return max(len(m.group(1).split(",")), 1)
    return default


def _wire_factor(kind: str, g: int) -> float:
    if kind == "all-reduce":
        return 2.0 * (g - 1) / max(g, 1)
    if kind == "all-gather":
        return (g - 1) / max(g, 1)
    if kind == "reduce-scatter":
        return float(g - 1)  # result shape is the per-shard output
    if kind == "all-to-all":
        return (g - 1) / max(g, 1)
    return 1.0  # collective-permute


@dataclass
class CollectiveStats:
    count: float = 0
    result_bytes: float = 0.0
    wire_bytes: float = 0.0


# ---------------------------------------------------------------------------
# structured HLO parsing
# ---------------------------------------------------------------------------


def _segment_computations(hlo_text: str):
    """Split HLO text into {computation_name: [lines]} + call edges."""
    comps: Dict[str, List[str]] = {}
    edges: Dict[str, Set[str]] = {}
    while_bodies: Set[str] = set()
    cur = None
    for line in hlo_text.splitlines():
        stripped = line.rstrip()
        if cur is None:
            # computation definitions start at column 0 and end with "{"
            if stripped.endswith("{") and not line.startswith(" "):
                m = _COMP_START_RE.match(stripped)
                if m:
                    cur = m.group(1)
                    comps[cur] = []
                    edges[cur] = set()
            continue
        if stripped == "}" and not line.startswith(" "):
            cur = None
            continue
        comps[cur].append(line)
        for m in _CALL_RE.finditer(line):
            for name in m.group(1).split(","):
                edges[cur].add(name.strip().lstrip("%"))
        if " while(" in line:
            for m in re.finditer(r"body=%?([\w\.\-]+)", line):
                while_bodies.add(m.group(1))
    return comps, edges, while_bodies


def _reachable_from(roots: Set[str], edges: Dict[str, Set[str]]) -> Set[str]:
    seen = set(roots)
    stack = list(roots)
    while stack:
        c = stack.pop()
        for n in edges.get(c, ()):
            if n not in seen:
                seen.add(n)
                stack.append(n)
    return seen


def parse_collectives(hlo_text: str, *, loop_trip: int = 1,
                      default_group: int = 16) -> Dict[str, CollectiveStats]:
    """Sum collective bytes; ops inside while-loop bodies ×``loop_trip``
    (the layer-scan trip count — XLA text contains each body once)."""
    comps, edges, while_bodies = _segment_computations(hlo_text)
    in_loop = _reachable_from(while_bodies, edges)
    out: Dict[str, CollectiveStats] = {k: CollectiveStats()
                                       for k in _COLL_KINDS}
    for cname, lines in comps.items():
        mult = loop_trip if cname in in_loop else 1
        for line in lines:
            m = _LINE_RE.search(line)
            if not m:
                continue
            kind = m.group("kind")
            b = _shape_bytes(m.group("shapes"))
            g = _group_size(line, default_group)
            st = out[kind]
            st.count += mult
            st.result_bytes += b * mult
            st.wire_bytes += b * _wire_factor(kind, g) * mult
    return out


def cpu_residual_artifact_bytes(hlo_text: str, n_super: int,
                                min_bytes: float = 5e8) -> float:
    """Bytes of whole-stack f32 residual copies (XLA-CPU artifact).

    The jaxpr keeps remat residual streams in bf16; the CPU backend
    materializes an f32 copy of layer-stacked residuals (verified on
    stablelm-1.6b: f32[24,16,4096,2048] twin of the bf16 carry stack). We
    count f32 buffers whose leading dim equals the superblock count, ≥0.5 GB,
    once per distinct shape."""
    if n_super <= 1:
        return 0.0
    total = 0.0
    seen = set()
    for m in re.finditer(r"f32\[(%d,[0-9,]+)\]" % n_super, hlo_text):
        dims = m.group(1)
        if dims in seen:
            continue
        n = 1
        for d in dims.split(","):
            n *= int(d)
        if n * 4 >= min_bytes:
            seen.add(dims)
            total += n * 4
    return total


# ---------------------------------------------------------------------------
# analytic per-device cost model
# ---------------------------------------------------------------------------


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def _pad(x: int, n: int) -> int:
    """Per-shard size after GSPMD padding of dim x over n shards."""
    return _ceil_div(x, n)


def analytic_costs(cfg, shape, *, n_model: int, n_workers: int,
                   algo: str = "layup") -> Dict:
    """Per-device FLOPs and minimum HBM bytes for one step.

    Conventions: dense/attention matmul flops = 2·m·n·k; causal attention
    counts the block-skipped (≈half) cost the TPU kernel achieves; MoE
    includes the capacity padding factor; train = fwd + 2×bwd + 1×remat-fwd
    for in-scan layers (3× for embed/unembed, outside remat); bf16 = 2 bytes.
    """
    B, S = shape.global_batch, shape.seq_len
    kind = shape.kind
    dt = 2  # bf16

    B_loc = _pad(B, n_workers)
    d = cfg.d_model
    hd = cfg.head_dim
    hq_loc = _pad(cfg.num_heads, n_model) if cfg.num_heads else 0
    hkv_loc = _pad(cfg.num_kv_heads, n_model) if cfg.num_kv_heads else 0
    v_loc = _pad(cfg.vocab_size, n_model)
    ffn_loc = _pad(cfg.d_ff, n_model) if cfg.d_ff else 0

    if kind == "train":
        Sq = S
        ctx = (min(cfg.sliding_window, S) if cfg.sliding_window
               else S / 2)  # causal block-skip
        passes_f, layer_mult = 1, 4.0  # fwd + 2 bwd + remat fwd
        head_mult = 3.0                # embed/unembed outside remat
    elif kind == "prefill":
        Sq = S
        ctx = min(cfg.sliding_window, S) if cfg.sliding_window else S / 2
        layer_mult = head_mult = 1.0
    else:  # decode
        Sq = 1
        ctx = min(cfg.sliding_window, S) if cfg.sliding_window else S
        layer_mult = head_mult = 1.0

    T_loc = B_loc * Sq  # tokens per worker (model axis shards dims, not T)

    flops = {}
    byts = {}

    # ---- per-layer components ----------------------------------------------
    def attn_layer():
        proj = 2 * T_loc * d * (hq_loc + 2 * hkv_loc) * hd \
            + 2 * T_loc * hq_loc * hd * d
        score = 2 * T_loc * ctx * hq_loc * hd * 2  # qk + pv
        f = proj + score
        # bytes: read h, write q/k/v, stream scores in VMEM, write out
        b = dt * (2 * T_loc * d + T_loc * (hq_loc + 2 * hkv_loc) * hd
                  + T_loc * hq_loc * hd)
        if kind == "decode":
            # KV-cache read dominates: ctx slots × kv heads
            b += dt * 2 * B_loc * ctx * hkv_loc * hd
        elif kind == "prefill":
            b += dt * 2 * T_loc * hkv_loc * hd  # cache write
        return f, b

    def mlp_layer():
        f = 2 * T_loc * d * 3 * ffn_loc
        b = dt * (2 * T_loc * d + 3 * T_loc * ffn_loc)
        return f, b

    def moe_layer():
        E = cfg.num_experts
        k = cfg.experts_per_token
        dff = cfg.expert_d_ff()
        cap = cfg.capacity_factor
        # the sharding rules put either the expert axis (E % n_model == 0) or
        # the per-expert dff on the model axis — both divide expert compute
        if E % n_model == 0:
            shard = n_model
        elif dff % n_model == 0:
            shard = n_model
        else:
            shard = 1  # fully replicated fallback
        f = 2 * T_loc * d * E  # router
        f += 2 * (T_loc * k * cap) * d * 3 * dff / shard
        # bytes: tokens in/out of buffers + local expert weights + router
        b = dt * (4 * T_loc * d + 3 * E * d * dff / shard)
        return f, b

    def ssm_layer():
        di_loc = _pad(cfg.d_inner, n_model)
        n = cfg.ssm_state
        h_loc = _pad(cfg.ssm_heads, n_model)
        p = cfg.ssm_head_dim
        chunk = min(128, Sq)
        f = 2 * T_loc * d * (2 * di_loc + h_loc)  # z,x,dt proj (B,C replicated)
        f += 2 * T_loc * d * 2 * n
        f += 2 * T_loc * (di_loc + 2 * n) * cfg.ssm_conv
        if kind == "decode":
            f += 2 * B_loc * h_loc * n * p * 2  # recurrent update + output
        else:
            f += 2 * T_loc * chunk * n          # C·B
            f += 2 * T_loc * chunk * h_loc * p  # intra
            f += 2 * 2 * T_loc * n * h_loc * p  # states + inter
        f += 2 * T_loc * di_loc * d  # out proj
        b = dt * (2 * T_loc * d + 4 * T_loc * di_loc)
        if kind == "decode":
            b += dt * 2 * B_loc * h_loc * n * p  # state read+write
        return f, b

    # ---- assemble over layers ------------------------------------------------
    f_layers = b_layers = 0.0
    n_layers = cfg.num_layers
    for l in range(n_layers):
        if cfg.family in ("ssm", "hybrid") and not cfg.is_attn_layer(l):
            f, b = ssm_layer()
        else:
            f, b = attn_layer()
            if cfg.enc_dec:  # cross attention (ctx = enc_seq)
                f2 = (2 * T_loc * d * (hq_loc + 2 * hkv_loc) * hd
                      + 2 * T_loc * hq_loc * hd * d
                      + 2 * T_loc * cfg.enc_seq * hq_loc * hd * 2)
                f += f2
                b += dt * (2 * T_loc * d + T_loc * hq_loc * hd)
        f_layers += f
        b_layers += b
        if cfg.d_ff or cfg.num_experts:
            if cfg.is_moe_layer(l):
                f, b = moe_layer()
            else:
                f, b = mlp_layer()
            f_layers += f
            b_layers += b

    if cfg.enc_dec:  # encoder (train/prefill only; decode reads cross cache)
        if kind != "decode":
            Te = B_loc * cfg.enc_seq
            fe = (2 * Te * d * (hq_loc + 2 * hkv_loc) * hd
                  + 2 * Te * hq_loc * hd * d
                  + 2 * Te * cfg.enc_seq * hq_loc * hd * 2
                  + 2 * Te * d * 3 * ffn_loc)
            f_layers += fe * cfg.enc_layers
            b_layers += dt * 5 * Te * d * cfg.enc_layers

    flops["layers"] = f_layers * layer_mult
    byts["activations"] = b_layers * (3.0 if kind == "train" else 1.0)

    # ---- embed / unembed -----------------------------------------------------
    f_head = 2 * T_loc * d * v_loc
    flops["unembed"] = f_head * head_mult
    byts["logits"] = 4 * T_loc * v_loc * (2 if kind == "train" else 1)

    # ---- parameter traffic ---------------------------------------------------
    p_dev = cfg.param_counts()["total"] / (n_model * 1.0)
    if kind == "train":
        # read fwd + bwd + remat, write grads, opt read+write (p, m),
        # gossip/all-reduce read+write
        byts["params"] = p_dev * dt * 9
        flops["optimizer"] = p_dev * 8  # momentum + update + gossip mix
    else:
        byts["params"] = p_dev * dt
        flops["optimizer"] = 0.0

    total_f = sum(flops.values())
    total_b = sum(byts.values())
    return {
        "flops_per_device": total_f,
        "bytes_per_device": total_b,
        "flops_detail": flops,
        "bytes_detail": byts,
    }


# ---------------------------------------------------------------------------
# report
# ---------------------------------------------------------------------------


@dataclass
class RooflineReport:
    arch: str = ""
    shape: str = ""
    algo: str = ""
    mesh: str = ""
    flops_per_device: float = 0.0
    bytes_per_device: float = 0.0
    collective_wire_bytes: float = 0.0
    collectives: Dict[str, Dict] = field(default_factory=dict)
    t_compute: float = 0.0
    t_memory: float = 0.0
    t_collective: float = 0.0
    dominant: str = ""
    model_flops_total: float = 0.0
    model_flops_per_device: float = 0.0
    useful_ratio: float = 0.0
    memory: Dict[str, float] = field(default_factory=dict)
    xla_raw: Dict[str, float] = field(default_factory=dict)
    detail: Dict[str, Dict] = field(default_factory=dict)
    notes: str = ""

    def to_dict(self):
        return asdict(self)


def memory_report(compiled, n_super: int = 1) -> Dict[str, float]:
    ma = compiled.memory_analysis()
    txt = compiled.as_text()
    artifact = cpu_residual_artifact_bytes(txt, n_super)
    peak = float(ma.argument_size_in_bytes + ma.output_size_in_bytes
                 + ma.temp_size_in_bytes - ma.alias_size_in_bytes)
    return {
        "argument_bytes": float(ma.argument_size_in_bytes),
        "output_bytes": float(ma.output_size_in_bytes),
        "temp_bytes": float(ma.temp_size_in_bytes),
        "alias_bytes": float(ma.alias_size_in_bytes),
        "peak_hbm_est": peak,
        "cpu_f32_residual_artifact": artifact,
        "peak_hbm_corrected": peak - artifact,
    }


def analyze(compiled, cfg, shape, *, arch: str, algo: str, mesh_desc: str,
            n_model: int, n_workers: int, n_devices: int, loop_trip: int,
            notes: str = "") -> RooflineReport:
    ca = compiled.cost_analysis() or {}
    txt = compiled.as_text()
    colls = parse_collectives(txt, loop_trip=loop_trip)
    wire = sum(c.wire_bytes for c in colls.values())

    ac = analytic_costs(cfg, shape, n_model=n_model, n_workers=n_workers,
                        algo=algo)
    flops = ac["flops_per_device"]
    byts = ac["bytes_per_device"]

    t_comp = flops / PEAK_FLOPS
    t_mem = byts / HBM_BW
    t_coll = wire / ICI_BW
    dom = max((("compute", t_comp), ("memory", t_mem),
               ("collective", t_coll)), key=lambda kv: kv[1])[0]

    mf_total = model_flops(cfg, shape)
    mf_dev = mf_total / max(n_devices, 1)
    return RooflineReport(
        arch=arch, shape=shape.name, algo=algo, mesh=mesh_desc,
        flops_per_device=flops, bytes_per_device=byts,
        collective_wire_bytes=wire,
        collectives={k: asdict(v) for k, v in colls.items() if v.count},
        t_compute=t_comp, t_memory=t_mem, t_collective=t_coll, dominant=dom,
        model_flops_total=mf_total,
        model_flops_per_device=mf_dev,
        useful_ratio=(mf_dev / flops) if flops else 0.0,
        memory=memory_report(compiled, loop_trip),
        xla_raw={"flops_scanbody_once": float(ca.get("flops", 0.0)),
                 "bytes_scanbody_once": float(ca.get("bytes accessed", 0.0))},
        detail={"flops": ac["flops_detail"], "bytes": ac["bytes_detail"]},
        notes=notes,
    )


def stage_floors(report, *, R: int = 1) -> Dict[str, float]:
    """Per-stage roofline lower bounds for the decoupled stage schedule,
    consumed by the autotuner's scorer (``launch/tuner.py``).

    The train convention above prices a step at fwd + 2×bwd + the remat
    fwd (layer_mult=4), so one forward pass is ~1/4 and the
    backward+update tail ~3/4 of the device-side term; the device term
    itself is the binding roof of compute vs memory. With R slices the
    step's forward work is split R ways, so the PER-SLICE floor divides
    by R. The gossip floor is the collective term unchanged — its wire
    bytes don't depend on the schedule.

    Accepts a :class:`RooflineReport` or its ``to_dict()`` form (the
    benchmarks pass reloaded JSON)."""
    if hasattr(report, "t_compute"):
        t_comp = float(report.t_compute)
        t_mem = float(report.t_memory)
        t_coll = float(report.t_collective)
    else:
        t_comp = float(report.get("t_compute", 0.0))
        t_mem = float(report.get("t_memory", 0.0))
        t_coll = float(report.get("t_collective", 0.0))
    dev = max(t_comp, t_mem)
    R = max(int(R), 1)
    return {"fwd": 0.25 * dev / R, "update": 0.75 * dev, "gossip": t_coll}


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS: 6·N(_active)·tokens for train, 2·N·tokens for inference."""
    counts = cfg.param_counts()
    n = counts["active"]
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch
