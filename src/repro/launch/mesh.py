"""Production mesh construction.

Defined as FUNCTIONS (not module-level constants) so importing this module
never touches jax device state — the dry-run must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first init.

Target: TPU v5e, 256 chips/pod as a (16, 16) ('data', 'model') mesh;
multi-pod = 2 pods = 512 chips, ('pod', 'data', 'model') = (2, 16, 16).
The gossip-worker population for LayUp is the product of the ('pod','data')
axes: 16 single-pod, 32 multi-pod.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False, layout: str = "2d"):
    """layout='2d' → ('data','model')=(16,16) — the baseline Megatron-style
    mesh. layout='ep' → ('data','expert','tp')=(16,8,2) — same 256 chips/pod
    with the model axis factorized for expert parallelism + 2-way TP (§Perf
    optimization; GQA kv=8 heads and 8-expert MoEs shard exactly)."""
    if layout == "ep":
        shape = (2, 16, 8, 2) if multi_pod else (16, 8, 2)
        axes = (("pod",) if multi_pod else ()) + ("data", "expert", "tp")
        return jax.make_mesh(shape, axes)
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(shape: Tuple[int, ...] = (2, 2),
                   axes: Tuple[str, ...] = ("data", "model")):
    """Small mesh for CPU integration tests (requires the host-device flag)."""
    return jax.make_mesh(shape, axes)


def data_axes(mesh) -> Tuple[str, ...]:
    """The gossip/data axes (the rest are model-parallel)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def num_workers(mesh) -> int:
    n = 1
    for a in data_axes(mesh):
        n *= mesh.shape[a]
    return n
