"""Per-group execution streams with one-sided signal gossip (DESIGN.md §13).

The PR-3 pipeline engine (``repro.launch.pipeline``) overlaps *dispatch*:
one host thread initiates every stage call and the runtime chains the data
dependencies, so ``BENCH_overlap_stages.json`` shows the host running ahead
of the device — but a single dispatch lane gives the runtime no structural
guarantee that two stages ever *execute* concurrently, and the timeline
cannot even measure it (first-observed-ready completion times are an upper
bound polled from one thread). This module adds the missing layer:
**execution streams**.

A :class:`Stream` is one host thread that owns the execution of the stage
executables assigned to it: it resolves the stage's inputs (waiting on
signals), launches the jitted call, and **blocks until the result is
ready** before touching the next work item. Because the thread is
dedicated, the span between launch and readiness is a true *execution*
span on that stream, and spans recorded by different streams interleave
exactly when the device actually ran two stages concurrently —
``exec_overlap_s`` in :meth:`StageTimeline.summary
<repro.launch.pipeline.StageTimeline.summary>` is computed from those
spans, not from dispatch run-ahead. Off-TPU (this container, CI) the
streams are host threads over the multi-device CPU PJRT client — the
stand-in for real per-core TPU/GPU streams, with the same assignment of
stages to streams (see DESIGN.md §13 for the mapping onto real hardware).

**One-sided signal gossip.** Stages coordinate through a
:class:`SignalBoard` instead of rendezvous: the producer pushes a buffer
(the PR-4 flat *group plane* — one contiguous buffer per layer group, the
natural unit to ship across a stream boundary with zero repack) into a
named slot and flips the slot's **signal** to a new version; the consumer
spins on a ``signal_wait_until``-style predicate (``signal >= value``)
over exactly the slots it needs. The idiom is modeled on NVSHMEM's
``putmem_signal`` / ``signal_wait_until`` pair: payload delivery
happens-before the signal flip (release), and a successful wait
happens-after it (acquire) — here enforced by the board's condition
variable, on symmetric memory by the fenced signal word. The payoff is
per-*group* progress: each layer group's gossip mix launches as soon as
ITS plane signal lands, so a late group (or, across real peers, a slow
peer) delays only its own groups — the asynchrony DaSGD-style delayed
averaging assumes, instead of a full-plane barrier.

Stage-to-stream assignment (``streams=n``):

=========  =============================================================
n == 2     ``fwd`` (all R forward slices) | ``gossip`` (update + per-
           group mixes + clock/metrics)
n == 3     ``fwd`` | ``update`` | ``gossip``
n >= 4     ``fwd0..fwd{n-3}`` (slices round-robin) | ``update`` |
           ``gossip``
=========  =============================================================

Donation safety depends on per-stream FIFO order: the clock stage donates
the push-sum weights that the same step's per-group mixes read, which is
sound only because mixes and clock share the ``gossip`` stream and a
stream completes (blocks until ready) each task before starting the next.
Do not re-assign those stages to different streams without revisiting the
donation sets in ``repro.launch.pipeline``.

Numerics are EXACT vs the single-stream engine (and transitively vs the
monolithic oracle): the per-group mix applies the very same lane closure
to a single-group sub-dict — the same elementwise f32 expression on the
same inputs — and the clock stage recomputes the push-sum weight exchange
with the identical ``_ring_exchange`` ops. ``tests/test_streams.py``
asserts loss/staleness/param equality at (R, D) ∈ {(1, 1), (2, 1)}.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

__all__ = [
    "SignalBoard", "Stream", "StreamTask", "TaskOutput", "StreamEngine",
    "resolve_refs",
]

# generous guard against a lost signal turning a bug into a silent hang;
# every wait in this module times out with a diagnostic instead
_WAIT_TIMEOUT_S = 600.0


class SignalBoard:
    """One-sided signal slots: ``put_signal`` / ``wait_until``.

    Each slot holds a monotonically increasing integer **signal** (a
    version clock) and, per signalled version, an optional **payload**
    (the pushed buffer). ``put_signal(slot, signal, payload)`` stores the
    payload and then flips the signal — the memory-ordering contract is
    that a consumer which observes ``signal >= v`` also observes the
    payload pushed with ``v`` (release/acquire; here the condition
    variable's lock provides it, on symmetric memory the fenced signal
    word does). Signals never go backwards: a stale put raises instead of
    silently reordering.

    ``wait_until(slot, v)`` waits for ``signal >= v`` but returns the
    payload pushed **with v** — not the latest. A consumer of step ``t``
    that wakes up after a producer already signalled ``t+1`` must still
    read step ``t``'s buffer (e.g. a lagging forward slice of step ``t``
    racing the step's own gossip mix), so payloads are retained per
    version in a bounded window (``keep`` versions; the engine's
    bounded-queue backpressure keeps consumer lag far inside it)."""

    def __init__(self, keep: int = 64):
        self._cv = threading.Condition()
        self._keep = int(keep)
        self._signals: Dict[str, int] = {}
        self._payloads: Dict[str, Dict[int, Any]] = {}
        self._poison: Optional[BaseException] = None

    def put_signal(self, slot: str, signal: int, payload: Any = None) -> None:
        """Push ``payload`` into ``slot`` as version ``signal`` and flip
        the slot's signal (release). Evicts payload versions older than
        the retention window."""
        signal = int(signal)
        with self._cv:
            cur = self._signals.get(slot)
            if cur is not None and signal < cur:
                raise ValueError(
                    f"signal for slot {slot!r} must be monotone: "
                    f"have {cur}, got {signal}")
            d = self._payloads.setdefault(slot, {})
            d[signal] = payload
            for v in [v for v in d if v <= signal - self._keep]:
                del d[v]
            self._signals[slot] = signal
            self._cv.notify_all()

    def wait_until(self, slot: str, value: int,
                   timeout: float = _WAIT_TIMEOUT_S) -> Any:
        """Block until ``slot``'s signal is ``>= value``; return the
        payload pushed with version ``value`` (acquire). Raises
        ``TimeoutError`` after ``timeout`` seconds — a lost signal is a
        protocol bug, not a reason to hang — and ``KeyError`` if version
        ``value`` fell out of the retention window."""
        value = int(value)
        deadline = time.monotonic() + timeout
        with self._cv:
            while self._signals.get(slot, -(1 << 62)) < value:
                if self._poison is not None:
                    raise RuntimeError(
                        f"signal board poisoned while waiting on "
                        f"{slot!r} >= {value}") from self._poison
                remaining = deadline - time.monotonic()
                if remaining <= 0 or not self._cv.wait(remaining):
                    raise TimeoutError(
                        f"signal_wait_until({slot!r}, >= {value}) timed "
                        f"out at {self._signals.get(slot)!r}")
            if self._poison is not None:
                raise RuntimeError(
                    f"signal board poisoned while waiting on "
                    f"{slot!r} >= {value}") from self._poison
            d = self._payloads.get(slot, {})
            if value not in d:
                raise KeyError(
                    f"payload for {slot!r} version {value} evicted "
                    f"(retention window {self._keep}; have "
                    f"{sorted(d)[-4:]})")
            return d[value]

    def read(self, slot: str) -> Optional[int]:
        """Non-blocking probe of a slot's current signal (None if never
        signalled)."""
        with self._cv:
            return self._signals.get(slot)

    def poison(self, exc: BaseException) -> None:
        """Fail-fast kill switch: wake every waiter and make all current
        and future ``wait_until`` calls raise (chained to ``exc``). A
        task failure on one stream must not leave tasks on OTHER streams
        blocked on signals that will never arrive — without this, a
        poisoned pipeline strands daemon threads in 600 s timeouts."""
        with self._cv:
            if self._poison is None:
                self._poison = exc
            self._cv.notify_all()

    def reset(self) -> None:
        """Drop every slot and clear any poison (fresh run)."""
        with self._cv:
            self._signals.clear()
            self._payloads.clear()
            self._poison = None
            self._cv.notify_all()


class StreamTask:
    """One unit of stream work: resolve inputs, run a stage, signal.

    ``wait_fn()`` blocks on the task's input signals/futures and returns
    the resolved argument tuple (its duration is the task's recorded
    signal-wait time); ``run_fn(*args)`` launches the stage executable;
    ``signals_fn(out)`` (optional) performs the per-group push-and-signal
    protocol on the outputs. The owning :class:`Stream` blocks until the
    outputs are ready before completing the task, so ``result()`` always
    returns retired buffers. ``block_pick(out)`` (optional) selects WHICH
    outputs to block on — a producer whose signalled buffers are donated
    by a consumer on another stream must exclude them (``signals_fn``
    already blocked on each before flipping its signal, and stage
    executables complete atomically, so blocking on the remaining outputs
    still closes the execution span honestly)."""

    def __init__(self, stage: str, step: int, *, slice_idx=None, group=None,
                 wait_fn: Optional[Callable[[], tuple]] = None,
                 run_fn: Callable = None,
                 signals_fn: Optional[Callable[[Any], None]] = None,
                 block_pick: Optional[Callable[[Any], Any]] = None):
        self.stage, self.step = stage, int(step)
        self.slice_idx, self.group = slice_idx, group
        self.wait_fn, self.run_fn, self.signals_fn = wait_fn, run_fn, signals_fn
        self.block_pick = block_pick
        self.enqueue: Optional[float] = None
        self._done = threading.Event()
        self._result: Any = None
        self._exc: Optional[BaseException] = None

    @property
    def done(self) -> bool:
        return self._done.is_set()

    def result(self, timeout: float = _WAIT_TIMEOUT_S) -> Any:
        if not self._done.wait(timeout):
            raise TimeoutError(f"stream task {self.stage}@{self.step} "
                               f"did not complete within {timeout}s")
        if self._exc is not None:
            raise self._exc
        return self._result


class TaskOutput:
    """Lazy, picklable-by-reference view into a task's (future) result.

    Supports ``float()`` / ``np.asarray()`` so metric dicts built from
    stream futures drop into the ``TrainerBackend`` contract unchanged —
    converting one blocks only on its producing task."""

    __slots__ = ("_task", "_pick")

    def __init__(self, task: StreamTask, pick: Callable[[Any], Any] = None):
        self._task = task
        self._pick = pick if pick is not None else (lambda r: r)

    def result(self) -> Any:
        return self._pick(self._task.result())

    def __float__(self) -> float:
        return float(self.result())

    def __array__(self, dtype=None):
        return np.asarray(self.result(), dtype=dtype)


def resolve_refs(tree: Any) -> Any:
    """Recursively replace :class:`TaskOutput` leaves in a (dict / tuple /
    list) tree with their concrete results — blocking on the producing
    tasks. Everything else passes through untouched."""
    if isinstance(tree, TaskOutput):
        return tree.result()
    if isinstance(tree, dict):
        return {k: resolve_refs(v) for k, v in tree.items()}
    if isinstance(tree, (tuple, list)):
        return type(tree)(resolve_refs(v) for v in tree)
    return tree


class Stream:
    """One executable stream: a host thread that runs stage tasks FIFO.

    The thread resolves each task's inputs (signal waits), launches the
    stage, and blocks until the outputs are ready — so the recorded
    ``[exec_start, complete]`` window is a true execution span on this
    stream and interleaving spans across streams are measured execution
    concurrency. The bounded queue is the backpressure: ``submit`` blocks
    once the stream is ``maxsize`` tasks behind, capping host run-ahead
    exactly like the single-stream engine's ``max_inflight_steps``."""

    _SHUTDOWN = object()

    def __init__(self, name: str, timeline, *, maxsize: int = 0,
                 clock: Callable[[], float] = time.perf_counter,
                 on_error: Optional[Callable[[StreamTask,
                                              BaseException], None]] = None):
        self.name = name
        self.timeline = timeline
        self._clock = clock
        self.on_error = on_error
        self._q: "queue.Queue" = queue.Queue(maxsize)
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name=f"stream:{name}")
        self._thread.start()

    def submit(self, task: StreamTask) -> StreamTask:
        task.enqueue = self._clock()
        self._q.put(task)  # blocks when the stream is maxsize tasks behind
        return task

    def _loop(self) -> None:
        while True:
            task = self._q.get()
            if task is Stream._SHUTDOWN:
                return
            self._execute(task)

    def _execute(self, task: StreamTask) -> None:
        t0 = self._clock()
        t_exec = t0
        try:
            args = task.wait_fn() if task.wait_fn is not None else ()
            t_exec = self._clock()
            out = task.run_fn(*args)
            if task.signals_fn is not None:
                # per-group push-and-signal: blocks on each group buffer
                # then flips its slot — still inside this stream's span
                task.signals_fn(out)
            jax.block_until_ready(out if task.block_pick is None
                                  else task.block_pick(out))
            t_done = self._clock()
            task._result = out
        except BaseException as e:  # surfaced at result()/wait time
            task._exc = e
            t_done = self._clock()
            if self.on_error is not None:
                try:
                    self.on_error(task, e)
                except Exception:
                    pass  # the original failure must still surface
        if self.timeline is not None:
            self.timeline.record_exec(
                task.stage, task.step, stream=self.name,
                enqueue=task.enqueue, wait_s=t_exec - t0,
                exec_start=t_exec, complete=t_done,
                slice_idx=task.slice_idx, group=task.group)
        task._done.set()

    def close(self) -> None:
        self._q.put(Stream._SHUTDOWN)
        self._thread.join(timeout=5.0)


class StreamEngine:
    """The pipeline engine's stage graph on per-stage execution streams.

    Same external contract as :class:`~repro.launch.pipeline.
    PipelineEngine` — ``step(state, batch, step_idx, shift_idx) ->
    (state, metrics)`` with the decoupled state layout — but the stage
    executables run on dedicated :class:`Stream` threads coordinated
    through a :class:`SignalBoard`, and the gossip stage is split into
    one mix executable PER LAYER GROUP fed by push-and-signal:

    * ``fwd`` stream(s): each forward slice waits on the per-group plane
      signals for its step, then runs against the signalled buffers (the
      live read plane — never donated, so signal payloads stay valid);
    * ``update`` (own stream at ``streams >= 3``): waits on slice 0's
      gradient future, runs the backward/update executable, then pushes
      every group's post-update buffer (non-fused) or update-delta plane
      (fused) with signal value ``t``;
    * ``gossip`` stream: per-group mixes each wait on THEIR group's
      ``upd`` signal only, mix, and push the mixed group plane with
      signal ``t + 1`` (what the next step's forwards wait on); the
      clock stage then recomputes the push-sum weight exchange, stamps
      the version clocks and folds the metric reduction — identical math
      to the single-stream gossip stage, split at the group boundary.

    State leaves returned from ``step`` are :class:`TaskOutput` futures;
    pass them straight back into the next ``step`` (the streams resolve
    them), or call :meth:`materialize` for concrete arrays.
    """

    def __init__(self, *, R: int, D: int, M: int, group_names: Sequence[str],
                 stages: Dict[str, Any], group_stages: Dict[str, Any],
                 timeline=None, n_streams: int = 2, fused: bool = False,
                 describe: str = "", max_inflight_steps: int = 3,
                 abstract_args: Optional[Dict[str, tuple]] = None,
                 wire: str = "param", compensate: float = 0.0):
        if n_streams < 2:
            raise ValueError(f"StreamEngine needs >= 2 streams, got "
                             f"{n_streams} (streams=1 is the single-stream "
                             f"PipelineEngine)")
        self.R, self.D, self.M = int(R), int(D), int(M)
        self.fused = bool(fused)
        self.wire = wire
        self.compensate = float(compensate)
        self.group_names = list(group_names)
        self._stages = stages            # {"fwd": [R jits], "update": jit}
        self._group_stages = group_stages  # {"mix": {g: jit}, "clock": jit}
        if timeline is None:
            from repro.launch.pipeline import StageTimeline
            timeline = StageTimeline()
        self.timeline = timeline
        self.describe = describe
        self.abstract_args = abstract_args or {}
        self.max_inflight_steps = int(max_inflight_steps)
        self.board = SignalBoard()

        n = min(int(n_streams), self.R + 2)
        G = len(self.group_names)
        per_step_gossip = G + 2  # mixes + clock (+ the odd aux task)
        # any task failure poisons the board: tasks on OTHER streams
        # blocked in wait_until wake and fail instead of stranding their
        # daemon thread in a 600 s timeout (drained by finalize/close)
        mk = lambda name, per_step: Stream(
            name, timeline, maxsize=max(4, self.max_inflight_steps * per_step),
            on_error=lambda task, exc: self.board.poison(exc))
        self._gossip = mk("gossip", per_step_gossip)
        if n >= 3:
            self._update = mk("update", 2)
            n_fwd = n - 2
        else:
            self._update = self._gossip
            n_fwd = 1
        if n_fwd == 1:
            self._fwd = [mk("fwd", self.R + 1)]
        else:
            self._fwd = [mk(f"fwd{i}", self.R // n_fwd + 2)
                         for i in range(n_fwd)]
        self.n_streams = 1 + (self._update is not self._gossip) + len(self._fwd)
        self._tasks: List[StreamTask] = []

    # -- helpers -----------------------------------------------------------

    def _track(self, task: StreamTask) -> StreamTask:
        self._tasks.append(task)
        return task

    def _prune(self) -> None:
        self._tasks = [t for t in self._tasks if not t.done]

    @staticmethod
    def _plane_slot(g: str) -> str:
        return f"plane:{g}"

    @staticmethod
    def _upd_slot(g: str) -> str:
        return f"upd:{g}"

    def _seed_plane(self, read, t: int) -> None:
        """First step after (re-)init: the read plane is concrete — push
        every group buffer onto the board with signal ``t`` so the step's
        forwards/update find their inputs."""
        first = next(iter(read.values()))
        if isinstance(first, TaskOutput):
            return  # plane already lives on the board via mix signals
        for g in self.group_names:
            self.board.put_signal(self._plane_slot(g), t, read[g])

    # -- the step ----------------------------------------------------------

    def step(self, state, batch, step_idx, shift_idx):
        board = self.board
        t = int(step_idx)
        si = (step_idx if isinstance(step_idx, jax.Array)
              else np.int32(step_idx))
        sh = (shift_idx if isinstance(shift_idx, jax.Array)
              else np.int32(shift_idx))
        gnames = self.group_names
        int8 = self.wire == "int8"
        comp = self.compensate > 0.0
        self._prune()
        self._seed_plane(state["read"], t)

        def plane_wait():
            return {g: board.wait_until(self._plane_slot(g), t)
                    for g in gnames}

        # forward slices: wait on the per-group plane signals for step t,
        # run against the signalled buffers (round-robin over fwd streams)
        fwd_tasks = []
        for r in range(self.R):
            fn = self._stages["fwd"][r]
            task = StreamTask(
                "fwd", t, slice_idx=r,
                wait_fn=(lambda: (plane_wait(), batch)),
                run_fn=(lambda read, b, fn=fn: fn(read, b)))
            self._fwd[r % len(self._fwd)].submit(self._track(task))
            fwd_tasks.append(task)
        losses = [TaskOutput(fwd_tasks[0], lambda r: r[0])]
        losses += [TaskOutput(tk) for tk in fwd_tasks[1:]]
        grads_ref = TaskOutput(fwd_tasks[0], lambda r: r[1])

        # backward/update: waits on slice 0's gradients (cross-stream
        # future) + the plane signals; pushes each group's output buffer
        # (post-update plane, or the update-delta plane when fused) with
        # signal value t — the one-sided put the mixes wait on
        opt_ref, fifo_refs = state["opt"], state.get("fifo")
        theta_ref = state.get("theta")
        # membership (chaos lane): never-donated alive-mask passthrough,
        # mutated host-side by the chaos controller at fault events
        alive_ref = state.get("alive")
        upd_fn = self._stages["update"]

        def upd_wait():
            plane = plane_wait()
            args = [plane, resolve_refs(opt_ref)]
            if self.D > 0:
                fifo = resolve_refs(fifo_refs)
                args += [fifo["g"], fifo["stamp"]]
            args += [grads_ref.result()]
            if comp:
                # θ_prev plane: produced by the previous step's update on
                # THIS stream (FIFO) — safe to resolve and donate here
                args += [resolve_refs(theta_ref)]
            if alive_ref is not None:
                args += [resolve_refs(alive_ref)]
            return tuple(args) + (si,)

        def upd_signals(out):
            plane_out = out[0]
            for g in gnames:
                jax.block_until_ready(plane_out[g])
                board.put_signal(self._upd_slot(g), t, plane_out[g])

        # block_pick excludes the plane outputs: each was blocked on in
        # upd_signals before its signal, and the mixes (another stream)
        # donate them — blocking on a donated buffer raises
        upd_task = self._track(StreamTask(
            "update", t, wait_fn=upd_wait, run_fn=upd_fn,
            signals_fn=upd_signals, block_pick=lambda r: r[1:]))
        self._update.submit(upd_task)
        new_opt = TaskOutput(upd_task, lambda r: r[1])
        new_fifo = None
        if self.D > 0:
            new_fifo = {"g": TaskOutput(upd_task, lambda r: r[2]),
                        "stamp": TaskOutput(upd_task, lambda r: r[3])}
        new_theta = None
        if comp:
            theta_idx = 4 if self.D > 0 else 2
            new_theta = TaskOutput(upd_task,
                                   lambda r, i=theta_idx: r[i])
        upd_stale = TaskOutput(upd_task, lambda r: r[-2])
        skips = TaskOutput(upd_task, lambda r: r[-1])

        # per-group gossip mixes: each waits on ITS group's upd signal
        # only — a late group delays its own mix, nothing else — then
        # pushes the mixed plane with signal t+1 for the next forwards
        w_ref, versions_ref = state["w"], state["versions"]
        resid_refs = state.get("resid")
        mix_tasks: Dict[str, StreamTask] = {}
        for g in gnames:
            mix_fn = self._group_stages["mix"][g]
            resid_ref = resid_refs[g] if int8 else None

            def mix_tail():
                # never-donated alive mask rides just before shift_idx
                if alive_ref is not None:
                    return (resolve_refs(alive_ref), sh)
                return (sh,)

            if self.fused:
                def mix_wait(g=g, resid_ref=resid_ref):
                    # fused kernel contract: mix reads the LIVE plane
                    # (signal t) + the update deltas (upd signal t)
                    live = board.wait_until(self._plane_slot(g), t)
                    delta = board.wait_until(self._upd_slot(g), t)
                    if int8:
                        # EF residual: previous mix of THIS group on THIS
                        # stream produced it (FIFO) — resolve + donate
                        return (live, delta, resolve_refs(resid_ref),
                                resolve_refs(w_ref)) + mix_tail()
                    return (live, delta, resolve_refs(w_ref)) + mix_tail()
            else:
                def mix_wait(g=g, resid_ref=resid_ref):
                    fresh = board.wait_until(self._upd_slot(g), t)
                    if int8:
                        return (fresh, resolve_refs(resid_ref),
                                resolve_refs(w_ref)) + mix_tail()
                    return (fresh, resolve_refs(w_ref)) + mix_tail()

            def mix_signals(out, g=g):
                board.put_signal(self._plane_slot(g), t + 1,
                                 out[0] if int8 else out)

            task = self._track(StreamTask(
                "gossip", t, group=g, wait_fn=mix_wait, run_fn=mix_fn,
                signals_fn=mix_signals))
            self._gossip.submit(task)
            mix_tasks[g] = task
        if int8:
            mixed = {g: TaskOutput(tk, lambda r: r[0])
                     for g, tk in mix_tasks.items()}
            new_resid = {g: TaskOutput(tk, lambda r: r[1])
                         for g, tk in mix_tasks.items()}
        else:
            mixed = {g: TaskOutput(tk) for g, tk in mix_tasks.items()}

        # clock/metrics: recompute the push-sum weight exchange, stamp the
        # version clocks, fold the metric reduction (same math as the
        # single-stream gossip stage — split at the group boundary).
        # Donates w + versions: safe because the same step's mixes already
        # retired on this stream (FIFO).
        clock_fn = self._group_stages["clock"]

        def clock_wait():
            head = (resolve_refs(w_ref), resolve_refs(versions_ref))
            if alive_ref is not None:
                head += (resolve_refs(alive_ref),)
            return head + (tuple(l.result() for l in losses),
                           upd_stale.result(), skips.result(), si, sh)

        clock_task = self._track(StreamTask(
            "clock", t, wait_fn=clock_wait, run_fn=clock_fn))
        self._gossip.submit(clock_task)
        new_w = TaskOutput(clock_task, lambda r: r[0])
        new_versions = TaskOutput(clock_task, lambda r: r[1])
        metric_keys = ["loss", "update_staleness", "weight_sum",
                       "layer_staleness", "staleness_mean",
                       "nonfinite_skips"]
        if alive_ref is not None:
            metric_keys.append("peers_live")
        metrics = {k: TaskOutput(clock_task,
                                 (lambda r, k=k: r[2][k]))
                   for k in metric_keys}

        new_state = {"read": mixed, "write": mixed, "opt": new_opt,
                     "w": new_w, "versions": new_versions}
        if self.D > 0:
            new_state["fifo"] = new_fifo
        if int8:
            new_state["resid"] = new_resid
        if comp:
            new_state["theta"] = new_theta
        if alive_ref is not None:
            new_state["alive"] = alive_ref
        return new_state, metrics

    def submit_aux(self, stage: str, fn: Callable, arg_refs: tuple,
                   step: int) -> TaskOutput:
        """Run an auxiliary computation (e.g. the drift metric) on the
        gossip stream after the step's clock — its inputs may be
        :class:`TaskOutput` refs into the step just submitted."""
        task = self._track(StreamTask(
            stage, int(step),
            wait_fn=(lambda: tuple(resolve_refs(a) for a in arg_refs)),
            run_fn=fn))
        self._gossip.submit(task)
        return TaskOutput(task)

    # -- lifecycle ---------------------------------------------------------

    def materialize(self, tree):
        """Resolve every :class:`TaskOutput` leaf to a concrete array."""
        return resolve_refs(tree)

    def finalize(self) -> None:
        """Drain EVERY submitted task, then re-raise the first failure.

        Raising on the first failed task would leave later tasks (other
        streams) undrained and their threads potentially blocked on
        signals the failed task never produced; the board poison wakes
        them, and the full drain here guarantees every thread is idle
        before the exception surfaces."""
        first: Optional[BaseException] = None
        for task in self._tasks:
            try:
                task.result()
            except BaseException as e:
                if first is None:
                    first = e
        self._prune()
        if first is not None:
            raise first

    def reset(self) -> None:
        """Fresh measured run: drain the streams, clear the board and the
        timeline (mirrors ``PipelineEngine.reset``)."""
        self.finalize()
        self.board.reset()
        self.timeline.reset()

    def close(self) -> None:
        """Shut the stream threads down (tests; daemon threads otherwise
        die with the process). The streams are closed even when the drain
        raises — a poisoned pipeline must not leak its threads."""
        try:
            self.finalize()
        finally:
            seen = set()
            for s in [self._gossip, self._update, *self._fwd]:
                if id(s) not in seen:
                    seen.add(id(s))
                    s.close()

    def stage_cutouts(self) -> Dict[str, Tuple[Any, tuple]]:
        """Every separately jitted stage executable paired with its
        abstract argument signature — the autotuner's extraction point
        (``launch/tuner.py``, DESIGN.md §16; mirrors
        ``PipelineEngine.stage_cutouts``). Keys match ``lower()``:
        ``fwd0..fwdR-1``, ``update``, ``mix:{group}``, ``clock``."""
        if not self.abstract_args:
            raise ValueError(
                "engine has no abstract args to cut stages out against "
                "(the flat-plane factories publish them at build)")
        if self.abstract_args["fwd"][-1] is None:
            raise ValueError(
                "forward batch abstract unknown: step the engine once so "
                "the backend path records the batch signature")
        out = {}
        for r, f in enumerate(self._stages["fwd"]):
            out[f"fwd{r}"] = (f, self.abstract_args["fwd"])
        out["update"] = (self._stages["update"],
                         self.abstract_args["update"])
        for g in self.group_names:
            out[f"mix:{g}"] = (self._group_stages["mix"][g],
                               self.abstract_args[f"mix:{g}"])
        out["clock"] = (self._group_stages["clock"],
                        self.abstract_args["clock"])
        return out

    def lower(self) -> Dict[str, Any]:
        """Lower every stage executable against its abstract args (Model
        path only, mirrors ``PipelineEngine.lower``)."""
        if not self.abstract_args:
            raise ValueError("engine has no abstract args to lower against")
        out = {}
        for r, f in enumerate(self._stages["fwd"]):
            out[f"fwd{r}"] = f.lower(*self.abstract_args["fwd"])
        out["update"] = self._stages["update"].lower(
            *self.abstract_args["update"])
        for g in self.group_names:
            out[f"mix:{g}"] = self._group_stages["mix"][g].lower(
                *self.abstract_args[f"mix:{g}"])
        out["clock"] = self._group_stages["clock"].lower(
            *self.abstract_args["clock"])
        return out
