import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape) on the production
mesh, print memory/cost analysis, and extract roofline terms.

The two lines above MUST stay first: jax locks the device count on first
init, and only the dry-run should see 512 host devices (smoke tests and
benches see 1).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch granite-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--algo layup]
  PYTHONPATH=src python -m repro.launch.dryrun --all --shapes train_4k,prefill_32k

Results are cached as JSON under benchmarks/results/dryrun/ for
benchmarks/roofline.py to aggregate.
"""
import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import INPUT_SHAPES, get_config, list_configs
from repro.launch import analysis as AN
from repro.launch.mesh import make_production_mesh, num_workers
from repro.launch.train import make_step
from repro.models import build_model

ASSIGNED = [
    "jamba-v0.1-52b", "qwen2-vl-2b", "mamba2-780m", "mixtral-8x7b",
    "granite-8b", "qwen3-moe-30b-a3b", "yi-34b", "stablelm-1.6b",
    "moonshot-v1-16b-a3b", "whisper-large-v3",
]

RESULTS_DIR = os.path.join(os.path.dirname(__file__),
                           "../../../benchmarks/results/dryrun")


def effective_config(cfg, shape):
    """long_500k requires sub-quadratic attention: SSM is native; archs with
    a sliding window are native; everything else gets the SWA variant
    (window 4096) — recorded in the report notes (DESIGN.md §5)."""
    notes = ""
    if shape.name == "long_500k" and cfg.family != "ssm":
        if cfg.sliding_window == 0:
            cfg = cfg.with_(sliding_window=4096)
            notes = "SWA-variant(4096) for long_500k"
    return cfg, notes


def _compile_step(cfg, mesh, shape, algo, shifts, overrides, preset=None,
                  accum_steps=1, act_pspec=None, moe_groups=1,
                  constrain_grads=False, fb_ratio=1, update_delay=0):
    import repro.models.transformer as T
    import repro.models.moe as MOE
    from jax.sharding import NamedSharding, PartitionSpec as P
    model = build_model(cfg)
    if act_pspec is not None:
        if shape.kind == "train":  # traced inside shard_map: raw spec
            T.ACTIVATION_PSPEC = P(*act_pspec)
        else:  # pjit serve paths need an explicit NamedSharding
            T.ACTIVATION_PSPEC = NamedSharding(mesh, P(*act_pspec))
    if moe_groups > 1:
        eaxis = "expert" if "expert" in mesh.axis_names else "model"
        MOE.GROUPS = moe_groups
        if shape.kind == "train":  # traced inside shard_map: raw specs
            MOE.GROUP_PSPEC = P(eaxis, None, None)
            MOE.EXPERT_PSPEC = P(eaxis, None, None)
        else:  # pjit serve paths need explicit NamedShardings
            MOE.GROUP_PSPEC = NamedSharding(mesh, P(eaxis, None, None))
            MOE.EXPERT_PSPEC = NamedSharding(mesh, P(eaxis, None, None))
    try:
        step = make_step(model, mesh, shape, algo=algo, shifts=shifts,
                         overrides=overrides, preset=preset,
                         accum_steps=accum_steps,
                         constrain_grads=constrain_grads,
                         fb_ratio=fb_ratio, update_delay=update_delay)
        return step.lower().compile()
    finally:
        T.ACTIVATION_PSPEC = None
        MOE.GROUPS = 1
        MOE.GROUP_PSPEC = MOE.EXPERT_PSPEC = None


def run_one(arch: str, shape_name: str, *, algo: str = "layup",
            multi_pod: bool = False, shifts=(1,), overrides=None,
            save: bool = True, verbose: bool = True, tag_suffix: str = "",
            layout: str = "2d", preset=None, accum_steps: int = 1,
            act_pspec=None, moe_groups: int = 1, constrain_grads=False,
            fb_ratio: int = 1, update_delay: int = 0):
    shape = INPUT_SHAPES[shape_name]
    cfg0 = get_config(arch)
    cfg, notes = effective_config(cfg0, shape)
    mesh = make_production_mesh(multi_pod=multi_pod, layout=layout)
    mesh_desc = "x".join(str(mesh.shape[a]) for a in mesh.axis_names)
    if layout != "2d":
        notes = (notes + "; " if notes else "") + f"mesh layout={layout}"
    if preset:
        notes += f"; preset={preset}"
    if accum_steps > 1:
        notes += f"; accum={accum_steps}"
    if moe_groups > 1:
        notes += f"; moe_groups={moe_groups}"
    if fb_ratio > 1 or update_delay > 0:
        notes += f"; decoupled R={fb_ratio} D={update_delay}"

    # --- lower + compile: the dry-run proof ---------------------------------
    t0 = time.time()
    compiled = _compile_step(cfg, mesh, shape, algo, shifts, overrides,
                             preset, accum_steps, act_pspec, moe_groups,
                             constrain_grads, fb_ratio, update_delay)
    t_full = time.time() - t0

    from repro.models.transformer import _superblock_period
    n_super = cfg.num_layers // _superblock_period(cfg)
    from repro.launch.mesh import num_workers as _nw
    n_workers = _nw(mesh)
    n_model = mesh.size // n_workers

    report = AN.analyze(
        compiled, cfg, shape, arch=arch,
        algo=(algo if shape.kind == "train" else shape.kind),
        mesh_desc=mesh_desc, n_model=n_model, n_workers=n_workers,
        n_devices=mesh.size, loop_trip=n_super, notes=notes)
    d = report.to_dict()
    d["compile_s"] = round(t_full, 1)

    if verbose:
        print(f"[{arch} × {shape_name} × {mesh_desc} × {d['algo']}] "
              f"compile {t_full:.0f}s  {notes}")
        print(compiled.memory_analysis())
        print(f"  corrected flops/dev={report.flops_per_device:.3e} "
              f"bytes/dev={report.bytes_per_device:.3e} "
              f"coll_wire={report.collective_wire_bytes:.3e}")
        print(f"  t_comp={report.t_compute*1e3:.2f}ms "
              f"t_mem={report.t_memory*1e3:.2f}ms "
              f"t_coll={report.t_collective*1e3:.2f}ms "
              f"dominant={report.dominant} useful={report.useful_ratio:.2f} "
              f"hbm={report.memory.get('peak_hbm_corrected', 0)/1e9:.1f}GB")

    if save:
        os.makedirs(RESULTS_DIR, exist_ok=True)
        tag = f"{arch}_{shape_name}_{mesh_desc}_{d['algo']}" + tag_suffix
        with open(os.path.join(RESULTS_DIR, tag + ".json"), "w") as f:
            json.dump(d, f, indent=1)
    return d


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ASSIGNED + list_configs(), default=None)
    ap.add_argument("--shape", choices=list(INPUT_SHAPES), default=None)
    ap.add_argument("--shapes", default=None,
                    help="comma-separated subset for --all")
    ap.add_argument("--algo", default="layup", choices=["layup", "ddp"])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--archs", default=None)
    ap.add_argument("--shifts", default="1",
                    help="comma-separated gossip ring shifts (lax.switch set)")
    ap.add_argument("--layout", default="2d", choices=["2d", "ep"])
    ap.add_argument("--preset", default=None,
                    choices=[None, "megatron", "ep", "fsdp"])
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--fb-ratio", type=int, default=1,
                    help="decoupled lane: forward passes per backward")
    ap.add_argument("--update-delay", type=int, default=0,
                    help="decoupled lane: gradient FIFO depth D")
    ap.add_argument("--moe-groups", type=int, default=1)
    ap.add_argument("--constrain-grads", action="store_true")
    ap.add_argument("--act-pspec", default=None,
                    help="comma-separated activation PartitionSpec, "
                         "e.g. 'model,None,None' (FSDP batch sharding)")
    ap.add_argument("--tag", default="")
    ap.add_argument("--override", default=None,
                    help="comma-separated rule overrides, e.g. "
                         "'vocab=model,heads=None'")
    args = ap.parse_args()
    overrides = None
    if args.override:
        overrides = {}
        for kv in args.override.split(","):
            k, v = kv.split("=")
            if v == "None":
                overrides[k] = None
            elif "+" in v:
                overrides[k] = tuple(v.split("+"))
            else:
                overrides[k] = v
    act_pspec = None
    if args.act_pspec:
        act_pspec = tuple(None if a == "None" else a
                          for a in args.act_pspec.split(","))

    shifts = tuple(int(s) for s in args.shifts.split(","))
    failures = []
    if args.all:
        archs = args.archs.split(",") if args.archs else ASSIGNED
        shapes = (args.shapes.split(",") if args.shapes
                  else list(INPUT_SHAPES))
        for arch in archs:
            for shape in shapes:
                try:
                    run_one(arch, shape, algo=args.algo,
                            multi_pod=args.multi_pod, shifts=shifts,
                            layout=args.layout, preset=args.preset,
                            accum_steps=args.accum, act_pspec=act_pspec,
                            tag_suffix=args.tag, overrides=overrides,
                            moe_groups=args.moe_groups,
                            constrain_grads=args.constrain_grads,
                            fb_ratio=args.fb_ratio,
                            update_delay=args.update_delay)
                except Exception as e:
                    traceback.print_exc()
                    failures.append((arch, shape, repr(e)[:200]))
        if failures:
            print("FAILURES:")
            for f in failures:
                print(" ", f)
            sys.exit(1)
        print("ALL DRY-RUNS PASSED")
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        run_one(args.arch, args.shape, algo=args.algo,
                multi_pod=args.multi_pod, shifts=shifts,
                layout=args.layout, preset=args.preset,
                accum_steps=args.accum, act_pspec=act_pspec,
                tag_suffix=args.tag, overrides=overrides,
                moe_groups=args.moe_groups,
                constrain_grads=args.constrain_grads,
                fb_ratio=args.fb_ratio, update_delay=args.update_delay)


if __name__ == "__main__":
    main()
