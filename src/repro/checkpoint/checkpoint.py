"""Pytree checkpointing to .npz (orbax is not available offline).

Leaves are flattened with their tree paths as archive keys, so arbitrary
nested dict/tuple/list states (params, optimizer state, push-sum weights,
algorithm buffers) round-trip exactly. Atomic rename guards partial writes.
"""
from __future__ import annotations

import os
import re
import tempfile
from typing import Any, Optional

import jax
import numpy as np

_SEP = "|"


def _flatten(tree):
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in leaves:
        key = jax.tree_util.keystr(path)
        arr = np.asarray(leaf)
        if arr.dtype.kind == "V" or str(arr.dtype) == "bfloat16":
            # numpy can't serialize ml_dtypes (bf16 etc.); f32 is a lossless
            # container for bf16 and is cast back on restore
            arr = np.asarray(leaf, dtype=np.float32)
        out[key] = arr
    return out


def save_checkpoint(directory: str, step: int, tree: Any) -> str:
    os.makedirs(directory, exist_ok=True)
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    # np.savez appends ".npz" unless the name already ends with it
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".tmp.npz")
    os.close(fd)
    try:
        np.savez(tmp, **_flatten(tree))
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)
    return path


def restore_checkpoint(directory: str, step: Optional[int], like: Any,
                       fill_missing: bool = False) -> Any:
    """Restore into the structure of ``like`` (shapes/dtypes preserved).

    ``fill_missing=True`` keeps the ``like`` value for leaves absent from
    the archive instead of raising — lets newer TrainState layouts (e.g.
    the v2 ``versions``/``delay`` fields) resume from older checkpoints."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    with np.load(path) as data:
        flat = dict(data)
    paths_leaves, treedef = jax.tree_util.tree_flatten_with_path(like)
    new_leaves = []
    for path_, leaf in paths_leaves:
        key = jax.tree_util.keystr(path_)
        if key not in flat:
            if fill_missing:
                new_leaves.append(leaf)
                continue
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = flat[key]
        new_leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype)
                          if hasattr(leaf, "dtype") else arr)
    return jax.tree_util.tree_unflatten(treedef, new_leaves)


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for f in os.listdir(directory)
             if (m := re.match(r"ckpt_(\d+)\.npz$", f))]
    return max(steps) if steps else None
