from repro.optim.optimizers import Optimizer, sgd, momentum, adamw, get_optimizer
from repro.optim.schedules import constant, cosine, linear_warmup_cosine, linear_decay

__all__ = ["Optimizer", "sgd", "momentum", "adamw", "get_optimizer",
           "constant", "cosine", "linear_warmup_cosine", "linear_decay"]
