"""Learning-rate schedules used in the paper's experiments (App. A.5):
linear warmup + cosine (CIFAR/GPT) and linear-decay-to-zero (ImageNet)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine(lr: float, t_max: int, lr_min: float = 0.0):
    def fn(step):
        frac = jnp.clip(step / max(t_max, 1), 0.0, 1.0)
        return lr_min + 0.5 * (lr - lr_min) * (1 + jnp.cos(jnp.pi * frac))
    return fn


def linear_warmup_cosine(lr: float, warmup: int, t_max: int,
                         warmup_lr: float = 0.0, lr_min: float = 0.0):
    cos = cosine(lr, max(t_max - warmup, 1), lr_min)

    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        w = warmup_lr + (lr - warmup_lr) * step / max(warmup, 1)
        return jnp.where(step < warmup, w, cos(step - warmup))
    return fn


def linear_decay(lr: float, warmup: int, t_max: int, warmup_lr: float = 0.0):
    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        w = warmup_lr + (lr - warmup_lr) * step / max(warmup, 1)
        d = lr * jnp.clip((t_max - step) / max(t_max - warmup, 1), 0.0, 1.0)
        return jnp.where(step < warmup, w, d)
    return fn
