"""Minimal optimizer library (optax is not available offline).

An ``Optimizer`` is a pair of pure functions, optax-style:
  init(params) -> state
  update(grads, state, params, lr) -> (updates, state)
Updates are *descent directions already scaled by lr* — apply with
``params + updates`` via ``apply_updates``.

The paper uses plain SGD (vision) and AdamW (GPT); SlowMo/CO2 wrap an inner
optimizer with an outer momentum step (see repro.core.slowmo / co2).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[..., Any]  # (grads, state, params, lr) -> (updates, state)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u.astype(p.dtype)), params, updates)


def _wd_term(params, weight_decay):
    if weight_decay == 0.0:
        return lambda g, p: g
    return lambda g, p: g + weight_decay * p.astype(g.dtype)


def sgd(weight_decay: float = 0.0) -> Optimizer:
    wd = _wd_term(None, weight_decay)

    def init(params):
        return ()

    def update(grads, state, params, lr):
        upd = jax.tree.map(lambda g, p: -lr * wd(g, p), grads, params)
        return upd, state

    return Optimizer(init, update)


def momentum(beta: float = 0.9, weight_decay: float = 0.0,
             nesterov: bool = False, state_dtype=None) -> Optimizer:
    wd = _wd_term(None, weight_decay)

    def init(params):
        return jax.tree.map(
            lambda p: jnp.zeros(p.shape, state_dtype or p.dtype), params)

    def update(grads, state, params, lr):
        g = jax.tree.map(wd, grads, params)
        new_m = jax.tree.map(lambda m, gg: beta * m + gg.astype(m.dtype),
                             state, g)
        if nesterov:
            upd = jax.tree.map(lambda m, gg: -lr * (beta * m + gg.astype(m.dtype)),
                               new_m, g)
        else:
            upd = jax.tree.map(lambda m: -lr * m, new_m)
        return upd, new_m

    return Optimizer(init, update)


def adamw(b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
          weight_decay: float = 0.0, state_dtype=jnp.float32) -> Optimizer:

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, state_dtype)
        return {"mu": jax.tree.map(zeros, params),
                "nu": jax.tree.map(zeros, params),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        count = state["count"] + 1
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(m.dtype),
                          state["mu"], grads)
        nu = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(v.dtype)),
            state["nu"], grads)
        c1 = 1 - b1 ** count.astype(jnp.float32)
        c2 = 1 - b2 ** count.astype(jnp.float32)

        def upd(m, v, p):
            mhat = m / c1
            vhat = v / c2
            return -lr * (mhat / (jnp.sqrt(vhat) + eps)
                          + weight_decay * p.astype(m.dtype))

        updates = jax.tree.map(upd, mu, nu, params)
        return updates, {"mu": mu, "nu": nu, "count": count}

    return Optimizer(init, update)


def get_optimizer(name: str, **kw) -> Optimizer:
    return {"sgd": sgd, "momentum": momentum, "adamw": adamw}[name](**kw)


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    n = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(n, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), n
