"""Pallas TPU kernels for the int8 quantized gossip wire (DESIGN.md §14).

The PR-4 flat plane ships each layer group as ONE contiguous buffer in the
params' dtype; these kernels halve that wire again. ``quantize_plane``
compresses a plane buffer to int8 with one f32 scale per 128-lane row
(the (rows, 128) tiled view of the flattened buffer), carrying the
quantization error forward as an **error-feedback residual**:

    v      = x + residual                 (f32)
    scale  = absmax_row(v) / 127          (1.0 where a row is all zeros)
    q      = clip(round(v / scale), -127, 127)      int8
    resid' = v - q * scale                (stored in x's dtype)

Because ``absmax`` is computed on ``v`` the clip never truncates beyond
rounding, so ``|resid'| <= scale/2 = absmax_row(v)/254`` elementwise — the
residual is bounded and does NOT drift across rounds (the EF invariant
``x + resid == dequant(q, s) + resid'`` holds exactly in f32).

``dequant_mix`` is the receive side fused with the push-sum mix (and,
optionally, the local update — the Alg. 1 fused path):

    out = alpha * x_local + beta * (q_recv * s_recv) [+ upd]

one read pass per operand, one write — the same memory-bound shape as
``gossip_mix``, with the peer operand read at 1/2 (bf16) or 1/4 (f32) the
bytes. Wire cost per buffer: ``n`` int8 bytes + ``4 * quant_rows(n)`` scale
bytes ≈ 1.03 bytes/element (~0.52x the bf16 wire).

Layout: rows are padded to the int8 sublane multiple (32 — the int8 TPU
tile is (32, 128); f32/bf16 operands' (8, 128)/(16, 128) tiles divide it)
and then to a whole number of ``tile_rows`` grid tiles. Padding rows are
zeros → scale 1.0, q 0, dequant 0; the unpad slice discards them. The
per-row scale output is a narrow (tile, 1) block — same shape class as the
flash kernel's LSE output; interpret mode (CPU CI) is exact, on real TPU
the narrow write is padded into a lane by Mosaic.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANE = 128
SUBLANE_I8 = 32  # int8 min tile is (32, 128); 32 also covers f32/bf16 tiles


def quant_layout(n: int, tile_rows: int = 256):
    """(rows, tile, ntiles) of the padded (rows, 128) view of an
    ``n``-element buffer — ``rows`` is also the number of f32 scales on
    the wire (``plane_nbytes(wire="int8")`` accounting)."""
    rows_total = -(-n // LANE)
    rows_total = -(-rows_total // SUBLANE_I8) * SUBLANE_I8
    tile = min(int(tile_rows), rows_total)
    ntiles = -(-rows_total // tile)
    return ntiles * tile, tile, ntiles


def quant_wire_nbytes(n: int, tile_rows: int = 256) -> int:
    """Bytes on the wire for one quantized ``n``-element buffer:
    int8 payload + f32 per-row scales."""
    rows, _, _ = quant_layout(n, tile_rows)
    return n + 4 * rows


def _quant_kernel(x_ref, r_ref, q_ref, s_ref, res_ref):
    v = x_ref[...].astype(jnp.float32) + r_ref[...].astype(jnp.float32)
    absmax = jnp.max(jnp.abs(v), axis=1, keepdims=True)
    scale = jnp.where(absmax > 0.0, absmax / 127.0, 1.0)
    q = jnp.clip(jnp.round(v / scale), -127.0, 127.0)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale
    res_ref[...] = (v - q * scale).astype(res_ref.dtype)


def quantize_plane(x, residual=None, *, tile_rows: int = 256,
                   interpret: bool = False):
    """Quantize one plane buffer (any shape) with EF residual carry.

    Returns ``(q, scales, new_residual)``: ``q`` int8 in ``x``'s shape,
    ``scales`` a ``(quant_rows,)`` f32 vector (one per 128-lane row of the
    padded layout), ``new_residual`` in ``x``'s dtype/shape.
    ``residual=None`` starts from a zero residual."""
    shape, dtype = x.shape, x.dtype
    n = x.size
    rows, tile, ntiles = quant_layout(n, tile_rows)
    padded = rows * LANE

    def flat(a):
        a = a.reshape(-1)
        return jnp.pad(a, (0, padded - n)).reshape(rows, LANE)

    if residual is None:
        residual = jnp.zeros(shape, dtype)
    q, s, res = pl.pallas_call(
        _quant_kernel,
        grid=(ntiles,),
        in_specs=[pl.BlockSpec((tile, LANE), lambda i: (i, 0))] * 2,
        out_specs=[pl.BlockSpec((tile, LANE), lambda i: (i, 0)),
                   pl.BlockSpec((tile, 1), lambda i: (i, 0)),
                   pl.BlockSpec((tile, LANE), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((rows, LANE), jnp.int8),
                   jax.ShapeDtypeStruct((rows, 1), jnp.float32),
                   jax.ShapeDtypeStruct((rows, LANE), dtype)],
        interpret=interpret,
    )(flat(x), flat(residual))
    unpad = lambda a: a.reshape(-1)[:n].reshape(shape)
    return unpad(q), s.reshape(-1), unpad(res)


def _dequant_mix_kernel(ab_ref, x_ref, q_ref, s_ref, u_ref, o_ref):
    a = ab_ref[0]
    b = ab_ref[1]
    x = x_ref[...].astype(jnp.float32)
    r = q_ref[...].astype(jnp.float32) * s_ref[...]
    u = u_ref[...].astype(jnp.float32)
    o_ref[...] = (a * x + b * r + u).astype(o_ref.dtype)


def _dequant_mix_kernel_pure(ab_ref, x_ref, q_ref, s_ref, o_ref):
    a = ab_ref[0]
    b = ab_ref[1]
    x = x_ref[...].astype(jnp.float32)
    r = q_ref[...].astype(jnp.float32) * s_ref[...]
    o_ref[...] = (a * x + b * r).astype(o_ref.dtype)


def dequant_mix(x, q, scales, upd, alpha, beta, *, tile_rows: int = 256,
                interpret: bool = False):
    """Fused dequantize + push-sum mix (+ optional local update):
    ``alpha * x + beta * dequant(q, scales) [+ upd]`` in one pass.

    ``q``/``scales`` must come from :func:`quantize_plane` with the same
    ``tile_rows`` (the row layout is shared). ``upd=None`` drops the
    update operand (the non-fused gossip path)."""
    shape, dtype = x.shape, x.dtype
    n = x.size
    rows, tile, ntiles = quant_layout(n, tile_rows)
    if scales.shape != (rows,):
        raise ValueError(
            f"scales shape {scales.shape} does not match quant layout "
            f"({rows},) for n={n}, tile_rows={tile_rows}")
    padded = rows * LANE

    def flat(a):
        a = a.reshape(-1)
        return jnp.pad(a, (0, padded - n)).reshape(rows, LANE)

    ab = jnp.stack([jnp.asarray(alpha, jnp.float32),
                    jnp.asarray(beta, jnp.float32)])
    operands = [ab, flat(x), flat(q), scales.reshape(rows, 1)]
    if upd is not None:
        operands.append(flat(upd))
    data_specs = [pl.BlockSpec((tile, LANE), lambda i: (i, 0)),
                  pl.BlockSpec((tile, LANE), lambda i: (i, 0)),
                  pl.BlockSpec((tile, 1), lambda i: (i, 0))]
    if upd is not None:
        data_specs.append(pl.BlockSpec((tile, LANE), lambda i: (i, 0)))
    out = pl.pallas_call(
        _dequant_mix_kernel if upd is not None else _dequant_mix_kernel_pure,
        grid=(ntiles,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM)] + data_specs,
        out_specs=pl.BlockSpec((tile, LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANE), dtype),
        interpret=interpret,
    )(*operands)
    return out.reshape(-1)[:n].reshape(shape)
