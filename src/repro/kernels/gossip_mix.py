"""Pallas TPU kernel for LayUp's fused push-sum mix + local update.

The paper's inner-loop op (Alg. 1), applied per layer:

    x_new = α·x + β·x_recv + upd        α = w/(w+w'), β = w'/(w+w')

Fusing the three reads + one write into a single pass halves HBM traffic for
the update path versus separate mix and apply ops (the op is purely
memory-bound: 3 reads + 1 write per element). 1-D grid over (8·TILE,128)
tiles of the flattened parameter; α/β prefetched as scalars.

``upd=None`` selects the pure-mix variant (2 reads + 1 write: the lockstep
gossip path, which mixes already-updated parameters). The gossip lanes in
``repro.launch.train`` call this kernel per layer group on the persistent
flat plane (`FlatPartition` buffers) behind their ``use_pallas`` flag, with
``interpret=True`` on CPU and ``repro.kernels.ref.gossip_mix_ref`` as the
numerics oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANE = 128
SUBLANE = 8


def _mix_kernel(ab_ref, x_ref, r_ref, u_ref, o_ref):
    a = ab_ref[0]
    b = ab_ref[1]
    x = x_ref[...].astype(jnp.float32)
    r = r_ref[...].astype(jnp.float32)
    u = u_ref[...].astype(jnp.float32)
    o_ref[...] = (a * x + b * r + u).astype(o_ref.dtype)


def _mix_kernel_pure(ab_ref, x_ref, r_ref, o_ref):
    a = ab_ref[0]
    b = ab_ref[1]
    x = x_ref[...].astype(jnp.float32)
    r = r_ref[...].astype(jnp.float32)
    o_ref[...] = (a * x + b * r).astype(o_ref.dtype)


def gossip_mix(x, x_recv, upd, alpha, beta, *, tile_rows: int = 256,
               interpret: bool = False):
    """Flat fused mix+update on one parameter leaf (any shape).

    ``upd=None`` drops the update operand entirely (pure mix, 2 reads +
    1 write) rather than streaming a zeros buffer through the kernel."""
    shape, dtype = x.shape, x.dtype
    n = x.size
    cols = LANE
    rows_total = -(-n // cols)
    rows_total = -(-rows_total // SUBLANE) * SUBLANE
    tile = min(tile_rows, rows_total)
    # pad rows to a tile multiple
    ntiles = -(-rows_total // tile)
    rows = ntiles * tile
    padded = rows * cols

    def flat(a):
        a = a.reshape(-1)
        return jnp.pad(a, (0, padded - n)).reshape(rows, cols)

    ab = jnp.stack([jnp.asarray(alpha, jnp.float32),
                    jnp.asarray(beta, jnp.float32)])

    operands = [ab, flat(x), flat(x_recv)]
    if upd is not None:
        operands.append(flat(upd))
    out = pl.pallas_call(
        _mix_kernel if upd is not None else _mix_kernel_pure,
        grid=(ntiles,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM)]
        + [pl.BlockSpec((tile, cols), lambda i: (i, 0))
           for _ in operands[1:]],
        out_specs=pl.BlockSpec((tile, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), dtype),
        interpret=interpret,
    )(*operands)
    return out.reshape(-1)[:n].reshape(shape)


def gossip_mix_tree(params, recv, updates, alpha, beta, *,
                    interpret: bool = False):
    """Apply the fused op leaf-wise (per layer group — the paper's
    layer-wise granularity)."""
    return jax.tree.map(
        lambda x, r, u: gossip_mix(x, r, u, alpha, beta, interpret=interpret),
        params, recv, updates)
