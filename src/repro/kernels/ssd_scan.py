"""Pallas TPU kernel for the Mamba2 SSD chunked scan.

Grid (B·H, S/chunk) with the chunk dimension innermost ("arbitrary"): the
carried SSM state (N × P) lives in VMEM scratch and persists across chunk
steps. Each chunk step is matmul-heavy (the "dual form"): an intra-chunk
(chunk × chunk) masked attention-like product plus state ingest/emit
matmuls — all MXU work, which is exactly why SSD beats the sequential
Mamba1 scan on TPU.

B/C are shared across heads (ngroups=1) and indexed via the BlockSpec index
map, not broadcast. Validated in interpret mode against
``repro.kernels.ref.ssd_ref`` (sequential recurrence).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_ref, *,
                chunk: int, nc: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, 0].astype(jnp.float32)    # (chunk, P)
    dt = dt_ref[0, 0].astype(jnp.float32)  # (chunk,)
    A = a_ref[0, 0]                        # scalar (negative decay rate)
    Bm = b_ref[0].astype(jnp.float32)      # (chunk, N)
    Cm = c_ref[0].astype(jnp.float32)      # (chunk, N)

    dA = dt * A                            # (chunk,)
    cum = jnp.cumsum(dA)                   # inclusive

    # ---- intra-chunk dual form ---------------------------------------------
    seg = cum[:, None] - cum[None, :]      # decay j→i
    causal = (jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
              >= jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1))
    Lmat = jnp.where(causal, jnp.exp(seg), 0.0)
    CB = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    W = CB * Lmat * dt[None, :]
    y = jax.lax.dot_general(W, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # ---- contribution from the carried state -------------------------------
    decay_in = jnp.exp(cum)[:, None]       # (chunk, 1)
    y += decay_in * jax.lax.dot_general(
        Cm, state_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    # ---- state update --------------------------------------------------------
    decay_out = jnp.exp(cum[-1] - cum) * dt          # (chunk,)
    ingest = jax.lax.dot_general(Bm * decay_out[:, None], x,
                                 (((0,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # (N, P)
    state_ref[...] = state_ref[...] * jnp.exp(cum[-1]) + ingest

    y_ref[0, 0] = y.astype(y_ref.dtype)


def ssd_scan(x, dt, A, Bm, Cm, *, chunk: int = 128, interpret: bool = False):
    """x: (B, H, S, P); dt: (B, H, S); A: (H,); Bm/Cm: (B, S, N).

    Returns y: (B, H, S, P). (The model-side wrapper reshapes from/to the
    (B, S, H, P) layout and applies D-skip/gating outside the kernel.)
    """
    B, H, S, P = x.shape
    N = Bm.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk

    kernel = functools.partial(_ssd_kernel, chunk=chunk, nc=nc)
    grid = (B * H, nc)

    def xmap(bh, ci):
        return (bh // H, bh % H, ci, 0)

    def dtmap(bh, ci):
        return (bh // H, bh % H, ci)

    def amap(bh, ci):
        return (bh // H, bh % H)

    def bcmap(bh, ci):
        return (bh // H, ci, 0)

    try:
        compiler_params = pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary"))
    except Exception:  # pragma: no cover
        compiler_params = None

    a2 = jnp.broadcast_to(A.reshape(1, H), (B, H)).astype(jnp.float32)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, chunk, P), xmap),
            pl.BlockSpec((1, 1, chunk), dtmap),
            pl.BlockSpec((1, 1), amap),
            pl.BlockSpec((1, chunk, N), bcmap),
            pl.BlockSpec((1, chunk, N), bcmap),
        ],
        out_specs=pl.BlockSpec((1, 1, chunk, P), xmap),
        out_shape=jax.ShapeDtypeStruct((B, H, S, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
        **({"compiler_params": compiler_params} if compiler_params else {}),
    )(x, dt, a2, Bm, Cm)
