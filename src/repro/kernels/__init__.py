from repro.kernels.ops import (dequant_mix, flash_attention, gossip_mix,
                               quantize_plane, rmsnorm, ssd_scan)

__all__ = ["dequant_mix", "flash_attention", "gossip_mix", "quantize_plane",
           "rmsnorm", "ssd_scan"]
