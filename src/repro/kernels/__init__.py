from repro.kernels.ops import flash_attention, gossip_mix, rmsnorm, ssd_scan

__all__ = ["flash_attention", "gossip_mix", "rmsnorm", "ssd_scan"]
