"""Pallas TPU kernel: fused RMSNorm (single HBM pass, f32 statistics).

Grid over row tiles of the flattened (rows, d_model) view; each program
reads its tile once, computes the f32 mean-square per row on-chip and
writes the normalized tile — versus the unfused jnp path which materializes
an f32 upcast of the input. Validated in interpret mode vs
``repro.kernels.ref.rmsnorm_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rmsnorm_kernel(x_ref, g_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)           # (rows_tile, d)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps)
    o_ref[...] = (x * inv * g_ref[...].astype(jnp.float32)
                  ).astype(o_ref.dtype)


def rmsnorm(x, gamma, *, eps: float = 1e-5, tile_rows: int = 256,
            interpret: bool = False):
    """x: (..., d); gamma: (d,) → same shape/dtype as x."""
    shape, dtype = x.shape, x.dtype
    d = shape[-1]
    rows = 1
    for s in shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    tile = min(tile_rows, rows)
    ntiles = -(-rows // tile)
    pad = ntiles * tile - rows
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))

    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(ntiles,),
        in_specs=[
            pl.BlockSpec((tile, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tile, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((ntiles * tile, d), dtype),
        interpret=interpret,
    )(x2, gamma)
    return out[:rows].reshape(shape)
