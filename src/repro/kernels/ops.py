"""Jit'd dispatch wrappers for the Pallas kernels.

``interpret`` defaults to True on CPU (this container) and False on real
TPU, so the same call sites work in both environments. Models default to
the pure-jnp paths (XLA fuses those well and interpret-mode Pallas is slow
on CPU); pass ``use_pallas=True`` at the call sites that support it to run
the kernels.
"""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.gossip_mix import gossip_mix as _gossip, gossip_mix_tree
from repro.kernels.quantize import dequant_mix as _dequant_mix
from repro.kernels.quantize import quantize_plane as _quantize_plane
from repro.kernels.rmsnorm import rmsnorm as _rmsnorm
from repro.kernels.ssd_scan import ssd_scan as _ssd


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k",
                                   "interpret"))
def flash_attention(q, k, v, *, causal=True, window=0, block_q=128,
                    block_k=128, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _flash(q, k, v, causal=causal, window=window, block_q=block_q,
                  block_k=block_k, interpret=interpret)


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, Bm, Cm, *, chunk=128, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _ssd(x, dt, A, Bm, Cm, chunk=chunk, interpret=interpret)


@partial(jax.jit, static_argnames=("interpret",))
def gossip_mix(x, x_recv, upd, alpha, beta, *, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _gossip(x, x_recv, upd, alpha, beta, interpret=interpret)


@partial(jax.jit, static_argnames=("eps", "tile_rows", "interpret"))
def rmsnorm(x, gamma, *, eps=1e-5, tile_rows=256, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _rmsnorm(x, gamma, eps=eps, tile_rows=tile_rows,
                    interpret=interpret)


@partial(jax.jit, static_argnames=("tile_rows", "interpret"))
def quantize_plane(x, residual=None, *, tile_rows=256, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _quantize_plane(x, residual, tile_rows=tile_rows,
                           interpret=interpret)


@partial(jax.jit, static_argnames=("tile_rows", "interpret"))
def dequant_mix(x, q, scales, upd, alpha, beta, *, tile_rows=256,
                interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _dequant_mix(x, q, scales, upd, alpha, beta, tile_rows=tile_rows,
                        interpret=interpret)


__all__ = ["flash_attention", "ssd_scan", "gossip_mix", "gossip_mix_tree",
           "rmsnorm", "quantize_plane", "dequant_mix"]
