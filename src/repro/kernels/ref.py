"""Pure-jnp oracles for every Pallas kernel (shape-for-shape references)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal=True, window=0):
    """q: (B, Hq, Sq, d); k, v: (B, Hkv, Sk, d). Naive softmax attention."""
    B, Hq, Sq, d = q.shape
    _, Hkv, Sk, _ = k.shape
    G = Hq // Hkv
    qh = q.reshape(B, Hkv, G, Sq, d).astype(jnp.float32) * d ** -0.5
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qh, k.astype(jnp.float32))
    qp = jnp.arange(Sq)[:, None]
    kp = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kp <= qp
    if window > 0:
        mask &= (qp - kp) < window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return o.reshape(B, Hq, Sq, d).astype(q.dtype)


def ssd_ref(x, dt, A, Bm, Cm):
    """Sequential SSD recurrence. x: (B, H, S, P); dt: (B, H, S); A: (H,);
    Bm/Cm: (B, S, N) → y: (B, H, S, P)."""
    B, H, S, P = x.shape
    N = Bm.shape[-1]
    state = jnp.zeros((B, H, N, P), jnp.float32)
    ys = []
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf = Bm.astype(jnp.float32)
    Cf = Cm.astype(jnp.float32)
    for t in range(S):
        dA = jnp.exp(dtf[:, :, t] * A[None, :])  # (B, H)
        upd = jnp.einsum("bn,bhp->bhnp", Bf[:, t],
                         dtf[:, :, t][..., None] * xf[:, :, t])
        state = state * dA[:, :, None, None] + upd
        ys.append(jnp.einsum("bn,bhnp->bhp", Cf[:, t], state))
    return jnp.stack(ys, axis=2).astype(x.dtype)


def gossip_mix_ref(x, x_recv, upd, alpha, beta):
    return (alpha * x.astype(jnp.float32) + beta * x_recv.astype(jnp.float32)
            + upd.astype(jnp.float32)).astype(x.dtype)


def _quant_padded(a, rows):
    from repro.kernels.quantize import LANE
    a = a.reshape(-1).astype(jnp.float32)
    return jnp.pad(a, (0, rows * LANE - a.size)).reshape(rows, LANE)


def quantize_plane_ref(x, residual=None, *, tile_rows=256):
    """Same math as the quantize kernel, plain jnp (same padded layout)."""
    from repro.kernels.quantize import quant_layout
    shape, dtype = x.shape, x.dtype
    n = x.size
    rows, _, _ = quant_layout(n, tile_rows)
    v = _quant_padded(x, rows)
    if residual is not None:
        v = v + _quant_padded(residual, rows)
    absmax = jnp.max(jnp.abs(v), axis=1, keepdims=True)
    scale = jnp.where(absmax > 0.0, absmax / 127.0, 1.0)
    q = jnp.clip(jnp.round(v / scale), -127.0, 127.0)
    res = v - q * scale
    unpad = lambda a, dt: a.reshape(-1)[:n].reshape(shape).astype(dt)
    return unpad(q, jnp.int8), scale.reshape(-1), unpad(res, dtype)


def dequant_mix_ref(x, q, scales, upd, alpha, beta, *, tile_rows=256):
    """alpha * x + beta * dequant(q, scales) [+ upd], plain jnp."""
    from repro.kernels.quantize import quant_layout
    shape, dtype = x.shape, x.dtype
    n = x.size
    rows, _, _ = quant_layout(n, tile_rows)
    r = _quant_padded(q, rows) * scales.reshape(rows, 1)
    out = alpha * _quant_padded(x, rows) + beta * r
    if upd is not None:
        out = out + _quant_padded(upd, rows)
    return out.reshape(-1)[:n].reshape(shape).astype(dtype)


def rmsnorm_ref(x, gamma, eps=1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)
            * gamma.astype(jnp.float32)).astype(x.dtype)
