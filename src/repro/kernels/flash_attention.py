"""Pallas TPU flash attention (forward), GQA + causal + sliding window.

TPU-native design (DESIGN.md §8): grid (B·Hq, Sq/bq, Sk/bk) with the KV
dimension innermost ("arbitrary" semantics); online-softmax statistics and
the output accumulator live in VMEM scratch and persist across the KV grid
steps. Block shapes keep the working set in VMEM and the matmul operands
MXU-aligned (bq, bk, head_dim multiples of 128 on real hardware; tests sweep
smaller shapes in interpret mode).

Validated in interpret mode against ``repro.kernels.ref.attention_ref``;
the training path uses the pure-jnp flash (custom VJP) in
``repro.models.layers`` — this kernel is the TPU deployment artifact.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel_lse(q_ref, k_ref, v_ref, o_ref, lse_ref, acc_ref, m_ref,
                      l_ref, *, bq: int, bk: int, nk: int, causal: bool,
                      window: int, scale: float):
    """Forward kernel that also emits logsumexp (for the backward pass)."""
    _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
                  bq=bq, bk=bk, nk=nk, causal=causal, window=window,
                  scale=scale)

    @pl.when(pl.program_id(2) == nk - 1)
    def _write_lse():
        lse_ref[0, 0] = (m_ref[...]
                         + jnp.log(jnp.maximum(l_ref[...], 1e-30)))[:, 0]


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  bq: int, bk: int, nk: int, causal: bool, window: int,
                  scale: float):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale  # (bq, d)
    k = k_ref[0, 0].astype(jnp.float32)          # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)          # (bk, d)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (bq, bk)

    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1, keepdims=True)
    m_ref[...] = m_new
    acc_ref[...] = (acc_ref[...] * corr
                    + jax.lax.dot_general(p.astype(v.dtype), v,
                                          (((1,), (0,)), ((), ())),
                                          preferred_element_type=jnp.float32))

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False, return_lse: bool = False):
    """q: (B, Hq, Sq, d); k, v: (B, Hkv, Sk, d) → (B, Hq, Sq, d)
    [+ lse (B, Hq, Sq) when return_lse]."""
    B, Hq, Sq, d = q.shape
    _, Hkv, Sk, _ = k.shape
    G = Hq // Hkv
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, bq, Sk, bk)
    nq, nk = Sq // bq, Sk // bk
    scale = d ** -0.5

    kernel = functools.partial(
        _flash_kernel_lse if return_lse else _flash_kernel,
        bq=bq, bk=bk, nk=nk, causal=causal, window=window, scale=scale)

    grid = (B * Hq, nq, nk)

    def qmap(bh, qi, ki):
        return (bh // Hq, bh % Hq, qi, 0)

    def kvmap(bh, qi, ki):
        return (bh // Hq, (bh % Hq) // G, ki, 0)

    try:
        compiler_params = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))
    except Exception:  # pragma: no cover - older pallas API
        compiler_params = None

    out_specs = pl.BlockSpec((1, 1, bq, d), qmap)
    out_shape = jax.ShapeDtypeStruct((B, Hq, Sq, d), q.dtype)
    if return_lse:
        lse_spec = pl.BlockSpec((1, 1, bq), lambda bh, qi, ki:
                                (bh // Hq, bh % Hq, qi))
        out_specs = (out_specs, lse_spec)
        out_shape = (out_shape, jax.ShapeDtypeStruct((B, Hq, Sq),
                                                     jnp.float32))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), qmap),
            pl.BlockSpec((1, 1, bk, d), kvmap),
            pl.BlockSpec((1, 1, bk, d), kvmap),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
        **({"compiler_params": compiler_params} if compiler_params else {}),
    )(q, k, v)


# ---------------------------------------------------------------------------
# backward kernels (flash bwd: recompute scores per block; two passes)
# ---------------------------------------------------------------------------


def _flash_bwd_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                         dq_ref, acc_ref, *, bq, bk, nk, causal, window,
                         scale):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    do = do_ref[0, 0].astype(jnp.float32)          # (bq, d)
    lse = lse_ref[0, 0][:, None]                   # (bq, 1)
    delta = delta_ref[0, 0][:, None]               # (bq, 1)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, NEG_INF)
    p = jnp.exp(s - lse)
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp - delta)
    acc_ref[...] += jax.lax.dot_general(ds, k, (((1,), (0,)), ((), ())),
                                        preferred_element_type=jnp.float32)

    @pl.when(ki == nk - 1)
    def _finish():
        dq_ref[0, 0] = (acc_ref[...] * scale).astype(dq_ref.dtype)


def _flash_bwd_dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                          dk_ref, dv_ref, dk_acc, dv_acc, *, bq, bk, nq,
                          causal, window, scale):
    ki = pl.program_id(1)
    qi = pl.program_id(2)

    @pl.when(qi == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    q = q_ref[0, 0].astype(jnp.float32) * scale
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    do = do_ref[0, 0].astype(jnp.float32)
    lse = lse_ref[0, 0][:, None]
    delta = delta_ref[0, 0][:, None]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, NEG_INF)
    p = jnp.exp(s - lse)                           # (bq, bk)
    dv_acc[...] += jax.lax.dot_general(p, do, (((0,), (0,)), ((), ())),
                                       preferred_element_type=jnp.float32)
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp - delta)
    dk_acc[...] += jax.lax.dot_general(ds, q, (((0,), (0,)), ((), ())),
                                       preferred_element_type=jnp.float32)

    @pl.when(qi == nq - 1)
    def _finish():
        dk_ref[0, 0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_acc[...].astype(dv_ref.dtype)


def flash_attention_bwd(q, k, v, o, lse, do, *, causal=True, window=0,
                        block_q=128, block_k=128, interpret=False):
    """Backward kernels. q/o/do: (B, Hq, Sq, d); k, v: (B, Hkv, Sk, d);
    lse: (B, Hq, Sq). Returns (dq, dk, dv) with GQA group-summing done
    on the per-q-head partials."""
    B, Hq, Sq, d = q.shape
    _, Hkv, Sk, _ = k.shape
    G = Hq // Hkv
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    nq, nk = Sq // bq, Sk // bk
    scale = d ** -0.5
    delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), axis=-1)

    def q_of(order):
        # order: which grid dim indexes the q blocks
        def f(bh, x, y):
            qi = x if order == 1 else y
            return (bh // Hq, bh % Hq, qi, 0)
        return f

    def kv_of(order):
        def f(bh, x, y):
            ki = x if order == 1 else y
            return (bh // Hq, (bh % Hq) // G, ki, 0)
        return f

    def lse_of(order):
        def f(bh, x, y):
            qi = x if order == 1 else y
            return (bh // Hq, bh % Hq, qi)
        return f

    try:
        cp = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))
        cp_kw = {"compiler_params": cp}
    except Exception:  # pragma: no cover
        cp_kw = {}

    # ---- pass 1: dq, grid (B·Hq, nq, nk) -----------------------------------
    dq = pl.pallas_call(
        functools.partial(_flash_bwd_dq_kernel, bq=bq, bk=bk, nk=nk,
                          causal=causal, window=window, scale=scale),
        grid=(B * Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), q_of(1)),
            pl.BlockSpec((1, 1, bk, d), kv_of(2)),
            pl.BlockSpec((1, 1, bk, d), kv_of(2)),
            pl.BlockSpec((1, 1, bq, d), q_of(1)),
            pl.BlockSpec((1, 1, bq), lse_of(1)),
            pl.BlockSpec((1, 1, bq), lse_of(1)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), q_of(1)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq, d), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, d), jnp.float32)],
        interpret=interpret, **cp_kw,
    )(q, k, v, do, lse, delta)

    # ---- pass 2: dk/dv per q-head, grid (B·Hq, nk, nq) ---------------------
    dk_h, dv_h = pl.pallas_call(
        functools.partial(_flash_bwd_dkv_kernel, bq=bq, bk=bk, nq=nq,
                          causal=causal, window=window, scale=scale),
        grid=(B * Hq, nk, nq),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), q_of(2)),
            pl.BlockSpec((1, 1, bk, d), kv_of(1)),
            pl.BlockSpec((1, 1, bk, d), kv_of(1)),
            pl.BlockSpec((1, 1, bq, d), q_of(2)),
            pl.BlockSpec((1, 1, bq), lse_of(2)),
            pl.BlockSpec((1, 1, bq), lse_of(2)),
        ],
        out_specs=(
            pl.BlockSpec((1, 1, bk, d),
                         lambda bh, ki, qi: (bh // Hq, bh % Hq, ki, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bh, ki, qi: (bh // Hq, bh % Hq, ki, 0)),
        ),
        out_shape=(jax.ShapeDtypeStruct((B, Hq, Sk, d), jnp.float32),
                   jax.ShapeDtypeStruct((B, Hq, Sk, d), jnp.float32)),
        scratch_shapes=[pltpu.VMEM((bk, d), jnp.float32),
                        pltpu.VMEM((bk, d), jnp.float32)],
        interpret=interpret, **cp_kw,
    )(q, k, v, do, lse, delta)
    # GQA: sum the per-q-head partials within each kv group
    dk = dk_h.reshape(B, Hkv, G, Sk, d).sum(axis=2).astype(k.dtype)
    dv = dv_h.reshape(B, Hkv, G, Sk, d).sum(axis=2).astype(v.dtype)
    return dq, dk, dv


def flash_attention_trainable(q, k, v, *, causal=True, window=0,
                              block_q=128, block_k=128, interpret=False):
    """Differentiable flash attention: Pallas forward + Pallas backward
    (saves only out + lse; scores recomputed block-wise in the bwd)."""
    kw = dict(causal=causal, window=window, block_q=block_q,
              block_k=block_k, interpret=interpret)

    @jax.custom_vjp
    def run(q, k, v):
        return flash_attention(q, k, v, **kw)

    def fwd(q, k, v):
        o, lse = flash_attention(q, k, v, return_lse=True, **kw)
        return o, (q, k, v, o, lse)

    def bwd(res, do):
        return flash_attention_bwd(*res, do, **kw)

    run.defvjp(fwd, bwd)
    return run(q, k, v)
