"""Fault injection + self-healing membership for the async training lane.

``repro.chaos`` makes the PD-ASGD lane degrade gracefully instead of
deadlocking (DESIGN.md §15): a deterministic :class:`FaultPlan` replayed
by the :class:`ChaosController` at the host step boundary, a
:class:`PeerHealth` membership state machine fed by per-peer liveness
epochs, an alive-gated push-sum exchange that conserves Σw over the live
peer set, a :class:`WireGuard` checksum/resend protocol on the int8
gossip wire, and donor-based recovery (:func:`resync_peer`) that
re-admits a crashed peer with damped mixing weight.

Enable it end to end with ``ProdTrainerBackend(..., faults=...)`` or
``make_step(..., faults=...)`` — ``faults`` is a spec string (see
:mod:`repro.chaos.plan`) or a :class:`FaultPlan`; the empty plan turns
the membership machinery on without injecting anything (bit-exact with
the fault-free lane).
"""
from repro.chaos.controller import ChaosController
from repro.chaos.guard import WireGuard, buffer_checksum, plane_checksum
from repro.chaos.health import ALIVE, DEAD, SUSPECT, PeerHealth
from repro.chaos.plan import Fault, FaultPlan, as_plan
from repro.chaos.recovery import resync_peer

__all__ = [
    "ALIVE", "SUSPECT", "DEAD",
    "ChaosController", "Fault", "FaultPlan", "PeerHealth", "WireGuard",
    "as_plan", "buffer_checksum", "plane_checksum", "resync_peer",
]
