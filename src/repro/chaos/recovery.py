"""Peer recovery: donor re-sync of a re-admitted worker's replica.

A DEAD peer that comes back does NOT restart training from scratch: it
re-syncs its whole per-worker replica row — flat parameter planes (read +
write), optimizer state, version clocks, EF residual plane, the stale-θ
reference and its gradient-FIFO lane — from a live *donor*, then re-enters
mixing carrying an exact share of the donor's push-sum mass (DESIGN.md
§15). The mass split is exact by construction::

    w_peer  = damp * w_donor / 2
    w_donor = w_donor - w_peer          # Σw unchanged, bitwise

so the Σw-conservation invariant the membership lane maintains over the
live set survives re-admission. ``damp`` < 1 (wired from the delay
compensation strength λ when enabled) under-weights the re-admitted peer's
first mixing rounds — push-sum's native form of the paper's staleness
damping: its contributions fade in as its weight recovers toward 1/M
through subsequent mixing rounds.

All mutations are host-side (numpy round-trip, shardings restored with
``jax.device_put``): recovery is a rare event, never part of the jitted
step.
"""
from __future__ import annotations

from typing import Callable, Dict

import jax
import numpy as np


def mutate_leaf(leaf, fn: Callable[[np.ndarray], None]):
    """Round-trip one device array through host memory, apply ``fn`` in
    place, and restore the original sharding."""
    arr = np.array(leaf)
    fn(arr)
    return jax.device_put(arr, leaf.sharding)


def _row_copy(tree, peer: int, donor: int, M: int):
    """``leaf[peer] = leaf[donor]`` for every worker-stacked leaf."""
    def one(x):
        if getattr(x, "ndim", 0) >= 1 and x.shape[0] == M:
            return mutate_leaf(x, lambda a: a.__setitem__(peer, a[donor]))
        return x  # worker-shared leaf (e.g. FIFO stamps): nothing to sync
    return jax.tree.map(one, tree)


def resync_peer(state: Dict[str, object], peer: int, donor: int, M: int, *,
                damp: float = 1.0) -> Dict[str, object]:
    """Re-sync ``peer``'s replica from ``donor`` and split the donor's
    push-sum mass. Returns the updated state dict (``alive`` is set by
    the caller via the health tracker's mask)."""
    if peer == donor:
        raise ValueError("recovery donor must differ from the peer")
    if not 0.0 < damp <= 1.0:
        raise ValueError(f"recovery damp must be in (0, 1], got {damp}")
    state = dict(state)
    for key in ("read", "write", "opt", "versions", "resid", "theta"):
        if key in state:
            state[key] = _row_copy(state[key], peer, donor, M)
    if "fifo" in state:
        state["fifo"] = {"g": _row_copy(state["fifo"]["g"], peer, donor, M),
                         "stamp": state["fifo"]["stamp"]}

    def split(w):
        share = np.asarray(w[donor] * 0.5 * damp, w.dtype)
        w[donor] = w[donor] - share  # exact: Σw is the same two terms
        w[peer] = share
    state["w"] = mutate_leaf(state["w"], split)
    return state
