"""Per-round plane checksum guard for the quantized gossip wire.

The int8 wire (DESIGN.md §14) ships per-group ``{q, scales}`` payloads
every round. :class:`WireGuard` models the NIC-side integrity protocol at
the round boundary: the sender *seals* each outgoing group buffer with a
CRC32 over its raw bytes and keeps the pristine buffer as a resend cache;
the receiver verifies the checksum and, on mismatch (corrupt) or a
missing payload (drop), rejects the delivery and requests a resend —
substituting the sender's sealed copy. Because the repaired payload IS
the sealed original, a guarded round is bit-exact with an unguarded
fault-free round by construction; what the guard adds is *detection*
(``checksum_rejects`` / ``drops_detected`` / ``resends`` counters
surfaced in ``summary()``) and a bounded time-to-detect of one round.

This is a host-boundary emulation: the in-jit ``ppermute`` exchange has
no per-payload host hook, so the guard runs on the materialized plane at
the step boundary where the chaos controller injects wire faults
(DESIGN.md §15).
"""
from __future__ import annotations

import zlib
from typing import Dict, Optional, Tuple

import numpy as np


def buffer_checksum(buf) -> int:
    """CRC32 over a buffer's raw bytes (host transfer for device arrays)."""
    arr = np.asarray(buf)
    return zlib.crc32(np.ascontiguousarray(arr).tobytes())


def plane_checksum(plane: Dict[str, object]) -> Dict[str, int]:
    """Per-group CRC32 of a flat plane (the unit the wire ships)."""
    return {name: buffer_checksum(buf) for name, buf in plane.items()}


class WireGuard:
    """Seal / verify / resend protocol for one plane per round."""

    def __init__(self):
        self.rounds_sealed = 0
        self.checksum_rejects = 0
        self.drops_detected = 0
        self.resends = 0

    def seal(self, plane: Dict[str, object]) -> Dict[str, int]:
        """Checksum every outgoing group buffer (the resend cache is the
        plane itself — the caller keeps the handles alive)."""
        self.rounds_sealed += 1
        return plane_checksum(plane)

    def verify(self, seals: Dict[str, int], name: str,
               payload: Optional[object]) -> bool:
        """True iff ``payload`` arrived and matches its seal."""
        if payload is None:
            return False
        return buffer_checksum(payload) == seals[name]

    def round_trip(self, plane: Dict[str, object], *,
                   corrupt_group: Optional[str] = None,
                   drop_group: Optional[str] = None
                   ) -> Tuple[Dict[str, object], Dict[str, str]]:
        """One guarded wire round with optional injected faults.

        Seals ``plane``, damages the in-transit copy of the named groups
        (byte flip for ``corrupt_group``, absence for ``drop_group``),
        verifies on receive, and repairs every rejected payload from the
        sealed pristine buffer. Returns ``(delivered, events)`` where
        ``delivered`` is bit-exact with ``plane`` (repair == resend of
        the original) and ``events`` records what the guard saw per
        damaged group (``"ok"`` / ``"checksum-reject"`` / ``"drop"``)."""
        seals = self.seal(plane)
        delivered: Dict[str, object] = {}
        events: Dict[str, str] = {}
        for name, buf in plane.items():
            wire: Optional[object] = buf
            if name == drop_group:
                wire = None
            elif name == corrupt_group:
                damaged = np.array(np.asarray(buf))  # in-transit copy
                flat = damaged.view(np.uint8).reshape(-1)
                flat[0] ^= 0xFF
                wire = damaged
            if self.verify(seals, name, wire):
                events[name] = "ok"
                delivered[name] = buf  # verified: keep the device handle
                continue
            if wire is None:
                self.drops_detected += 1
                events[name] = "drop"
            else:
                self.checksum_rejects += 1
                events[name] = "checksum-reject"
            self.resends += 1
            delivered[name] = buf  # resend: the sealed pristine buffer
        return delivered, events

    def counters(self) -> Dict[str, int]:
        return {"rounds_sealed": self.rounds_sealed,
                "checksum_rejects": self.checksum_rejects,
                "drops_detected": self.drops_detected,
                "resends": self.resends}
