"""Peer membership tracking: liveness epochs, suspicion, death, re-entry.

:class:`PeerHealth` is the single membership view every component of the
fault-tolerant lane consults (DESIGN.md §15): the chaos controller feeds
it liveness *epochs* (one beat per peer per step, mirrored onto the
stream engine's SignalBoard as ``live:{peer}`` slots when one is
attached), the gossip mixes read its ``alive_mask`` to renormalize
push-sum weights over the live set, and the serving ``SwapPolicy``
refuses snapshots sourced from a peer it does not report healthy.

State machine (per peer)::

    ALIVE --(suspect_after missed epochs)--> SUSPECT
    SUSPECT --(dead_after missed epochs)---> DEAD
    DEAD --(readmit, after donor re-sync)--> ALIVE

A SUSPECT peer still participates in mixing (its last payloads may be in
flight and are still valid push-sum mass) but is no longer a trusted
serving source; only DEAD removes it from the mixing set. Deadline-guarded
waits (:meth:`wait_guarded`) escalate through the same ladder instead of
letting a ``TimeoutError`` crash the run: retry with exponential backoff,
then mark the peer suspect, then dead.
"""
from __future__ import annotations

import time
from typing import List, Optional, Tuple

ALIVE = "alive"
SUSPECT = "suspect"
DEAD = "dead"


class PeerHealth:
    """Membership state machine over ``M`` peers, driven by liveness
    epochs (monotone per-peer step counters)."""

    def __init__(self, M: int, *, suspect_after: int = 1,
                 dead_after: int = 2):
        if not 0 < suspect_after < dead_after:
            raise ValueError("need 0 < suspect_after < dead_after")
        self.M = int(M)
        self.suspect_after = int(suspect_after)
        self.dead_after = int(dead_after)
        self._status = [ALIVE] * self.M
        self._last_seen = [-1] * self.M
        # detect latency captured AT the DEAD transition — _last_seen is
        # rewritten on readmission, so it can't be recomputed later
        self._dead_latency: dict = {}
        #: transition timeline: (epoch, peer, old_status, new_status)
        self.events: List[Tuple[int, int, str, str]] = []

    # -- liveness feed ----------------------------------------------------
    def beat(self, peer: int, epoch: int) -> None:
        """Record a liveness epoch for ``peer`` (idempotent per step)."""
        if self._status[peer] == DEAD:
            return  # a dead peer must be readmitted, not just beat
        self._last_seen[peer] = max(self._last_seen[peer], int(epoch))

    def observe(self, epoch: int) -> List[Tuple[int, str]]:
        """Advance the state machine to ``epoch``; returns the peers that
        transitioned this call as ``(peer, new_status)``."""
        out: List[Tuple[int, str]] = []
        for p in range(self.M):
            if self._status[p] == DEAD:
                continue
            missed = int(epoch) - self._last_seen[p]
            if missed >= self.dead_after:
                self._transition(p, DEAD, epoch)
                out.append((p, DEAD))
            elif missed >= self.suspect_after:
                if self._status[p] == ALIVE:
                    self._transition(p, SUSPECT, epoch)
                    out.append((p, SUSPECT))
            elif self._status[p] == SUSPECT:
                self._transition(p, ALIVE, epoch)
                out.append((p, ALIVE))
        return out

    # -- explicit transitions ---------------------------------------------
    def mark_suspect(self, peer: int, epoch: int = -1) -> None:
        if self._status[peer] == ALIVE:
            self._transition(peer, SUSPECT, epoch)

    def mark_dead(self, peer: int, epoch: int = -1) -> None:
        if self._status[peer] != DEAD:
            self._transition(peer, DEAD, epoch)

    def readmit(self, peer: int, epoch: int) -> None:
        """Re-admit a peer after its donor re-sync (DESIGN.md §15)."""
        self._transition(peer, ALIVE, epoch)
        self._last_seen[peer] = int(epoch)

    def _transition(self, peer: int, new: str, epoch: int) -> None:
        old = self._status[peer]
        if old != new:
            self._status[peer] = new
            self.events.append((int(epoch), int(peer), old, new))
            if new == DEAD and epoch >= 0:
                self._dead_latency[peer] = int(epoch) - self._last_seen[peer]

    # -- views ------------------------------------------------------------
    def status(self, peer: int) -> str:
        return self._status[peer]

    def is_live(self, peer: int) -> bool:
        """Participates in mixing (ALIVE or SUSPECT)."""
        return self._status[peer] != DEAD

    def serving_ok(self, peer: int) -> bool:
        """Trusted as a serving snapshot source (strictly ALIVE)."""
        return self._status[peer] == ALIVE

    def alive_mask(self):
        """f32 0/1 mask over peers, 1 for every non-DEAD peer — the host
        value of the in-jit ``alive`` membership leaf."""
        import numpy as np
        return np.asarray([0.0 if s == DEAD else 1.0
                           for s in self._status], np.float32)

    @property
    def peers_dead(self) -> int:
        return sum(1 for s in self._status if s == DEAD)

    @property
    def peers_suspect(self) -> int:
        return sum(1 for s in self._status if s == SUSPECT)

    def detect_latency(self, peer: int) -> Optional[int]:
        """Epochs between the peer's last beat and its DEAD transition
        (captured at the transition — stable across readmission)."""
        return self._dead_latency.get(peer)

    # -- deadline-guarded waits -------------------------------------------
    def wait_guarded(self, board, slot: str, value, peer: int, *,
                     epoch: int = 0, deadline: float = 0.05,
                     retries: int = 3, backoff: float = 2.0):
        """``board.wait_until`` with escalation instead of an escaping
        ``TimeoutError``: retry with exponential backoff, then mark the
        peer SUSPECT and grant one final grace wait, then mark it DEAD
        and return ``None`` (the caller degrades — mixes fall back to
        the live set). A success while SUSPECT re-admits via the normal
        :meth:`observe` path on the next epoch."""
        t = float(deadline)
        for _ in range(max(1, int(retries))):
            try:
                return board.wait_until(slot, value, timeout=t)
            except TimeoutError:
                t *= float(backoff)
                time.sleep(0.0)  # yield
        self.mark_suspect(peer, epoch)
        try:
            return board.wait_until(slot, value, timeout=t)
        except TimeoutError:
            self.mark_dead(peer, epoch)
            return None
