"""Deterministic fault plans for chaos-testing the async training lane.

A :class:`FaultPlan` is a seeded, reproducible schedule of fault events —
crash a peer at step t, hang the host loop for s seconds, corrupt or drop
a gossip wire payload, inject a NaN into one layer group's delayed
gradient — that the :class:`~repro.chaos.controller.ChaosController`
replays against a running ``ProdTrainerBackend``. The plan is data, not
behaviour: the same spec string always produces the same event sequence,
so every chaos test and the nightly ``benchmarks/fault_tolerance.py`` run
is exactly reproducible (DESIGN.md §15).

Spec grammar (semicolon-separated events, ``key=value`` fields)::

    crash:peer=1,step=5            kill peer 1's liveness at step 5
    crash:peer=1,step=5,recover=9  ... and re-admit it at step 9
    hang:step=2,seconds=0.25       host loop sleeps 0.25s before step 2
    nan:step=3,peer=0,group=0      NaN into peer 0's queued grad, group 0
    corrupt:step=4,group=1         flip bytes in group 1's wire payload
    drop:step=6,group=0            group 0's wire payload never arrives
    recover:peer=1,step=9,donor=0  re-sync peer 1 from donor 0

An *empty* plan (``FaultPlan.parse("")``) is a valid no-op schedule: it
turns the membership machinery on without injecting anything, which is
exactly the configuration the bit-exactness tests pin against the
fault-free lane.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Sequence, Tuple

_KINDS = ("crash", "hang", "nan", "corrupt", "drop", "recover")
_MAX_HANG_S = 30.0


@dataclass(frozen=True)
class Fault:
    """One scheduled fault event."""
    kind: str
    step: int
    peer: int = 0
    group: int = 0
    seconds: float = 0.0
    donor: int = -1  # recover: -1 = first live peer

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} "
                             f"(expected one of {_KINDS})")
        if self.step < 0:
            raise ValueError(f"fault step must be >= 0, got {self.step}")
        if self.kind == "hang" and not 0.0 <= self.seconds <= _MAX_HANG_S:
            raise ValueError(f"hang seconds must be in [0, {_MAX_HANG_S}]")


@dataclass(frozen=True)
class FaultPlan:
    """An ordered, deterministic schedule of :class:`Fault` events."""
    faults: Tuple[Fault, ...] = ()
    seed: int = 0

    @classmethod
    def parse(cls, spec: str, seed: int = 0) -> "FaultPlan":
        """Parse the spec grammar above. ``""`` is the empty plan."""
        faults: List[Fault] = []
        for ev in (spec or "").split(";"):
            ev = ev.strip()
            if not ev:
                continue
            if ":" not in ev:
                raise ValueError(f"fault event {ev!r} needs 'kind:fields'")
            kind, _, body = ev.partition(":")
            kind = kind.strip()
            fields: Dict[str, str] = {}
            for kv in body.split(","):
                kv = kv.strip()
                if not kv:
                    continue
                if "=" not in kv:
                    raise ValueError(f"fault field {kv!r} needs key=value")
                k, _, v = kv.partition("=")
                fields[k.strip()] = v.strip()
            recover_at = fields.pop("recover", None)
            if recover_at is not None and kind != "crash":
                raise ValueError("recover= sugar only applies to crash")
            if "step" not in fields:
                raise ValueError(f"fault event {ev!r} needs step=")
            faults.append(Fault(
                kind=kind,
                step=int(fields.pop("step")),
                peer=int(fields.pop("peer", 0)),
                group=int(fields.pop("group", 0)),
                seconds=float(fields.pop("seconds", 0.0)),
                donor=int(fields.pop("donor", -1)),
            ))
            if fields:
                raise ValueError(f"unknown fault fields {sorted(fields)} "
                                 f"in {ev!r}")
            if recover_at is not None:
                faults.append(Fault(kind="recover", step=int(recover_at),
                                    peer=faults[-1].peer))
        return cls(faults=cls._ordered(faults), seed=int(seed))

    @staticmethod
    def _ordered(faults: Sequence[Fault]) -> Tuple[Fault, ...]:
        # stable order: by step, then by original position — replay is
        # deterministic regardless of how the plan was written
        return tuple(sorted(faults, key=lambda f: f.step))

    def at(self, step: int) -> Tuple[Fault, ...]:
        return tuple(f for f in self.faults if f.step == int(step))

    @property
    def empty(self) -> bool:
        return not self.faults

    @property
    def last_step(self) -> int:
        return max((f.step for f in self.faults), default=-1)

    def describe(self) -> str:
        if self.empty:
            return "empty plan (membership on, no faults)"
        return "; ".join(
            f"{f.kind}@{f.step}"
            + (f" peer={f.peer}" if f.kind in ("crash", "nan", "recover")
               else "")
            + (f" group={f.group}" if f.kind in ("nan", "corrupt", "drop")
               else "")
            + (f" {f.seconds:g}s" if f.kind == "hang" else "")
            for f in self.faults)


def as_plan(faults) -> FaultPlan:
    """Coerce ``faults`` (a FaultPlan, a spec string, or None) to a plan."""
    if faults is None:
        return FaultPlan()
    if isinstance(faults, FaultPlan):
        return faults
    if isinstance(faults, str):
        return FaultPlan.parse(faults)
    raise TypeError(f"faults must be a FaultPlan or spec string, "
                    f"got {type(faults).__name__}")
