"""ChaosController: replay a :class:`FaultPlan` against a live backend.

The controller runs at the host step boundary (``before_step``), which is
the only place the async lane is mutable without recompilation: it feeds
per-peer liveness epochs into :class:`~repro.chaos.health.PeerHealth`
(mirrored onto the stream engine's SignalBoard as ``live:{peer}`` slots),
advances the membership state machine, and applies the step's scheduled
faults to the training state:

* ``crash`` — the peer stops beating; after the health tracker escalates
  it to DEAD, its ``alive`` mask entry drops to 0 and its push-sum mass
  is redistributed proportionally over the survivors (one-time host
  renormalization — Σw over the live set is conserved; every subsequent
  round conserves it in-jit via the alive-gated exchange).
* ``hang`` — the host loop sleeps (wall-clock degradation only).
* ``nan`` — poisons the peer's queued delayed gradient for one layer
  group (D > 0) or its batch slice (D == 0); the update lane's nonfinite
  guard detects, skips and counts it.
* ``corrupt`` / ``drop`` — one guarded int8-wire round through
  :class:`~repro.chaos.guard.WireGuard` (reject-and-resend; bit-exact
  repair by construction).
* ``recover`` — donor re-sync via :func:`~repro.chaos.recovery
  .resync_peer`, then re-admission with its first rounds damped through
  the push-sum mass split.

With an *empty* plan the controller only beats/observes — it never
touches device state, so the membership lane stays bit-exact with the
fault-free lane (the pinned chaos-matrix test).
"""
from __future__ import annotations

import time
from typing import Dict, Optional

import numpy as np

from repro.chaos.guard import WireGuard
from repro.chaos.health import DEAD, PeerHealth
from repro.chaos.plan import Fault, FaultPlan, as_plan
from repro.chaos.recovery import mutate_leaf, resync_peer


class ChaosController:
    def __init__(self, faults, M: int, *, update_delay: int = 0,
                 wire: str = "param", compensate: float = 0.0,
                 suspect_after: int = 1, dead_after: int = 2):
        self.plan: FaultPlan = as_plan(faults)
        self.M = int(M)
        self.D = int(update_delay)
        self.wire = wire
        # λ doubles as the recovery damping: the re-admitted peer's first
        # mixing rounds are under-weighted exactly like a stale gradient
        self.damp = float(compensate) if float(compensate) > 0 else 1.0
        self.health = PeerHealth(M, suspect_after=suspect_after,
                                 dead_after=dead_after)
        self.guard = WireGuard()
        self._crashed = set()
        self._engine = None
        self._board = None
        self.faults_injected = 0
        self.rounds_degraded = 0
        self.resyncs = 0
        self.hangs = 0
        self.nan_injections = 0
        self._death_step: Dict[int, int] = {}
        self._resync_step: Dict[int, int] = {}

    # -- wiring ------------------------------------------------------------
    def attach(self, *, engine=None, board=None) -> None:
        """Hook up the stream/pipeline engine (for materializing futures
        before a host mutation) and its SignalBoard (liveness mirror)."""
        self._engine = engine
        self._board = board if board is not None else getattr(
            engine, "board", None)

    # -- the per-step hook ---------------------------------------------------
    def before_step(self, state, batch, step: int):
        """Apply this step's faults; returns the (possibly re-materialized
        and mutated) ``(state, batch)``."""
        step = int(step)
        events = self.plan.at(step)
        for f in events:
            self.faults_injected += 1
            if f.kind == "crash":
                self._crashed.add(f.peer)

        # liveness epochs: every non-crashed peer beats; the mirror slot on
        # the SignalBoard is what deadline-guarded waits key off
        for p in range(self.M):
            if p not in self._crashed:
                self.health.beat(p, step)
                if self._board is not None:
                    try:
                        self._board.put_signal(f"live:{p}", step)
                    except ValueError:
                        pass  # board reset mid-run: stale-put guard
        for peer, status in self.health.observe(step):
            if status == DEAD:
                self._death_step[peer] = step
                state = self._kill(state, peer)

        for f in events:
            if f.kind == "hang":
                self.hangs += 1
                time.sleep(f.seconds)
            elif f.kind == "nan":
                state, batch = self._poison_nan(state, batch, f)
            elif f.kind in ("corrupt", "drop"):
                state = self._wire_fault(state, f)
            elif f.kind == "recover":
                state = self._recover(state, f, step)

        if events or self.health.peers_dead or self.health.peers_suspect:
            self.rounds_degraded += 1
        return state, batch

    # -- fault applicators ---------------------------------------------------
    def _materialize(self, state):
        if self._engine is not None and hasattr(self._engine, "materialize"):
            return self._engine.materialize(state)
        return state

    def _kill(self, state, peer: int):
        """Zero the dead peer's alive mask and redistribute its push-sum
        mass proportionally over the survivors (the ONE host-side renorm;
        in-jit alive gating conserves Σ_live w every round after)."""
        state = dict(self._materialize(state))
        mask = self.health.alive_mask()

        def renorm(w):
            total = w.sum(dtype=np.float64)
            w[peer] = 0.0
            live = mask > 0
            s_live = w[live].sum(dtype=np.float64)
            if s_live > 0:
                w[live] = (w[live].astype(np.float64)
                           * (total / s_live)).astype(w.dtype)
        state["w"] = mutate_leaf(state["w"], renorm)
        state["alive"] = mutate_leaf(
            state["alive"], lambda a: a.__setitem__(slice(None), mask))
        return state

    def _poison_nan(self, state, batch, f: Fault):
        self.nan_injections += 1
        if self.D > 0 and "fifo" in state:
            state = dict(self._materialize(state))
            g = dict(state["fifo"]["g"])
            names = sorted(g)
            name = names[f.group % len(names)]
            g[name] = mutate_leaf(
                g[name], lambda a: a.__setitem__((f.peer, 0), np.nan))
            state["fifo"] = {"g": g, "stamp": state["fifo"]["stamp"]}
            return state, batch

        def poison(leaf):
            if np.issubdtype(np.asarray(leaf).dtype, np.floating):
                return mutate_leaf(
                    leaf, lambda a: a.__setitem__(f.peer, np.nan))
            return leaf
        import jax
        return state, jax.tree.map(poison, batch)

    def _wire_fault(self, state, f: Fault):
        """One guarded wire round over the read plane: the injected damage
        is detected and repaired from the sealed pristine buffer, so the
        state is bit-exact afterwards — the counters carry the evidence."""
        state = dict(self._materialize(state))
        plane = state["read"]
        names = sorted(plane)
        name = names[f.group % len(names)]
        delivered, _ = self.guard.round_trip(
            plane,
            corrupt_group=name if f.kind == "corrupt" else None,
            drop_group=name if f.kind == "drop" else None)
        state["read"] = delivered
        return state

    def _recover(self, state, f: Fault, step: int):
        if self.health.status(f.peer) != DEAD:
            return state  # nothing to recover
        state = dict(self._materialize(state))
        mask = self.health.alive_mask()
        donor = f.donor
        if donor < 0:
            donor = next(p for p in range(self.M)
                         if mask[p] > 0 and p != f.peer)
        state = resync_peer(state, f.peer, donor, self.M, damp=self.damp)
        self._crashed.discard(f.peer)
        self.health.readmit(f.peer, step)
        self.resyncs += 1
        self._resync_step[f.peer] = step
        state["alive"] = mutate_leaf(
            state["alive"],
            lambda a: a.__setitem__(slice(None), self.health.alive_mask()))
        return state

    # -- accounting ----------------------------------------------------------
    def time_to_detect(self) -> Optional[float]:
        """Mean steps from a peer's last beat to its DEAD transition."""
        lat = [self.health.detect_latency(p) for p in self._death_step]
        lat = [v for v in lat if v is not None]
        return float(np.mean(lat)) if lat else None

    def time_to_resync(self) -> Optional[float]:
        """Mean steps a recovered peer spent DEAD before re-admission."""
        spans = [self._resync_step[p] - self._death_step[p]
                 for p in self._resync_step if p in self._death_step]
        return float(np.mean(spans)) if spans else None

    def summary(self) -> Dict[str, object]:
        out = {
            "faults_injected": self.faults_injected,
            "rounds_degraded": self.rounds_degraded,
            "peers_dead": self.health.peers_dead,
            "peers_suspect": self.health.peers_suspect,
            "resyncs": self.resyncs,
            "hangs": self.hangs,
            "nan_injections": self.nan_injections,
        }
        out.update(self.guard.counters())
        ttd, ttr = self.time_to_detect(), self.time_to_resync()
        if ttd is not None:
            out["time_to_detect_steps"] = ttd
        if ttr is not None:
            out["time_to_resync_steps"] = ttr
        return out
