"""Whisper large-v3 — encoder-decoder audio transformer (conv frontend STUB).

[arXiv:2212.04356] 32L encoder + 32L decoder, d_model=1280, 20H (MHA),
d_ff=5120, vocab=51866. The mel-spectrogram + conv feature extractor is a
stub: input_specs supplies (B, 1500, 1280) frame embeddings.
"""
import jax.numpy as jnp

from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="whisper-large-v3",
    family="audio",
    num_layers=32,
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,
    d_ff=5120,
    vocab_size=51866,
    enc_dec=True,
    enc_layers=32,
    enc_seq=1500,
    frontend="audio",
    rope_theta=0.0,  # whisper uses learned/sinusoidal positions; we use
                     # sinusoidal for the encoder and RoPE-free learned-style
                     # additive positions for the decoder cache indexing
    tie_embeddings=True,
    dtype=jnp.bfloat16,
    source="arXiv:2212.04356",
))
