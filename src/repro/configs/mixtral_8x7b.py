"""Mixtral 8x7B — sparse MoE with sliding-window attention.

[arXiv:2401.04088] 32L, d_model=4096, 32H (GQA kv=8), d_ff=14336 per expert,
vocab=32000, 8 experts top-2, SWA window 4096.
"""
import jax.numpy as jnp

from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    num_experts=8,
    experts_per_token=2,
    moe_layer_period=1,
    sliding_window=4096,
    rope_theta=1e6,
    tie_embeddings=False,
    dtype=jnp.bfloat16,
    source="arXiv:2401.04088",
))
