"""Moonlight-16B-A3B (moonshot) — fine-grained MoE, 64 experts top-6.

[hf:moonshotai/Moonlight-16B-A3B] 48L, d_model=2048, 16H (GQA kv=16 -> MHA at
16 heads), per-expert d_ff=1408, vocab=163840.

NOTE: the assignment pool labels this entry "[dense]" yet specifies
"MoE 64e top-6"; Moonlight-16B-A3B is a DeepSeek-V3-style MoE, so we build it
as MoE per the explicit expert spec (discrepancy recorded in DESIGN.md §5).
"""
import jax.numpy as jnp

from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    vocab_size=163840,
    num_experts=64,
    experts_per_token=6,
    moe_d_ff=1408,
    moe_layer_period=1,
    rope_theta=5e4,
    tie_embeddings=True,
    dtype=jnp.bfloat16,
    source="hf:moonshotai/Moonlight-16B-A3B",
))
