"""Qwen3-30B-A3B — fine-grained MoE, 128 experts top-8, QK-norm.

[hf:Qwen/Qwen3-30B-A3B] 48L, d_model=2048, 32H (GQA kv=4, head_dim=128 so
q-proj is 4096 ≠ d_model), per-expert d_ff=768, vocab=151936.
"""
import jax.numpy as jnp

from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=768,
    vocab_size=151936,
    num_experts=128,
    experts_per_token=8,
    moe_d_ff=768,
    moe_layer_period=1,
    qk_norm=True,
    rope_theta=1e6,
    tie_embeddings=True,
    dtype=jnp.bfloat16,
    source="hf:Qwen/Qwen3-30B-A3B",
))
