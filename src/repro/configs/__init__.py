from repro.configs.base import (
    INPUT_SHAPES,
    ModelConfig,
    ShapeConfig,
    get_config,
    input_specs,
    list_configs,
    reduced,
    register,
)

__all__ = [
    "INPUT_SHAPES", "ModelConfig", "ShapeConfig", "get_config",
    "input_specs", "list_configs", "reduced", "register",
]
