"""Jamba v0.1 52B — hybrid Mamba+attention 1:7 interleave with MoE.

[arXiv:2403.19887] 32L, d_model=4096, 32H (GQA kv=8), d_ff=14336,
vocab=65536, MoE 16 experts top-2 every second layer; 1 attention layer per
8-layer block. Mamba layers use d_state=16, conv=4, expand=2 (Jamba uses
Mamba-1; we realize the SSM with our SSD block at the configured state size —
adaptation noted in DESIGN.md).
"""
import jax.numpy as jnp

from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    num_experts=16,
    experts_per_token=2,
    moe_layer_period=2,
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,
    ssm_head_dim=64,
    attn_layer_period=8,
    sliding_window=0,
    tie_embeddings=False,
    dtype=jnp.bfloat16,
    source="arXiv:2403.19887",
))
