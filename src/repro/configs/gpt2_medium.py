"""GPT-2 Medium (~400M) — the paper's own pre-training architecture
(LayUp Table 3: GPT-2 Medium on MiniPile). Realized as a llama-style
pre-norm decoder at GPT-2 Medium dimensions.
"""
import jax.numpy as jnp

from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="gpt2-medium",
    family="dense",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=50257,
    tie_embeddings=True,
    dtype=jnp.float32,
    source="paper (LayUp Table 3); arXiv:1909.... GPT-2",
))
