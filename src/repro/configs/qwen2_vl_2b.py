"""Qwen2-VL 2B — VLM backbone with M-RoPE and dynamic resolution.

[arXiv:2409.12191] 28L, d_model=1536, 12H (GQA kv=2), d_ff=8960,
vocab=151936. The vision encoder (ViT) is a STUB per the brief; input_specs
supplies mixed text+patch embeddings and 3-axis (t/h/w) M-RoPE positions.
"""
import jax.numpy as jnp

from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    d_ff=8960,
    vocab_size=151936,
    mrope=True,
    frontend="vision",
    rope_theta=1e6,
    tie_embeddings=True,
    dtype=jnp.bfloat16,
    source="arXiv:2409.12191",
))
