"""StableLM 2 1.6B — dense MHA decoder with partial rotary embedding.

[hf:stabilityai/stablelm-2-1_6b] 24L, d_model=2048, 32H (kv=32, i.e. MHA),
d_ff=5632, vocab=100352, rotary on 25% of head dims.
"""
import jax.numpy as jnp

from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="stablelm-1.6b",
    family="dense",
    num_layers=24,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=5632,
    vocab_size=100352,
    rope_fraction=0.25,
    tie_embeddings=False,
    dtype=jnp.bfloat16,
    source="hf:stabilityai/stablelm-2-1_6b",
))
