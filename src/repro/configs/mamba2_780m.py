"""Mamba2 780M — attention-free SSM with SSD (state-space duality).

[arXiv:2405.21060] 48L, d_model=1536, d_state=128, expand=2, head_dim=64,
vocab=50280. Sub-quadratic natively; long_500k decode uses the O(1)
recurrent state.
"""
import jax.numpy as jnp

from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="mamba2-780m",
    family="ssm",
    num_layers=48,
    d_model=1536,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    head_dim=64,
    ssm_state=128,
    ssm_conv=4,
    ssm_expand=2,
    ssm_head_dim=64,
    tie_embeddings=True,
    dtype=jnp.bfloat16,
    source="arXiv:2405.21060",
))
