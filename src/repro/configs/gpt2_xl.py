"""GPT-2 XL (~1.6B) — the paper's finetuning architecture (Table 3,
Wikitext-103)."""
import jax.numpy as jnp

from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="gpt2-xl",
    family="dense",
    num_layers=48,
    d_model=1600,
    num_heads=25,
    num_kv_heads=25,
    d_ff=6400,
    vocab_size=50257,
    tie_embeddings=True,
    dtype=jnp.float32,
    source="paper (LayUp Table 3); GPT-2",
))
