"""Model & input-shape configuration system.

Every assigned architecture gets a ``ModelConfig`` in its own module under
``repro.configs``; the registry here exposes them by id for ``--arch`` flags.
``input_specs`` builds ShapeDtypeStruct stand-ins for dry-runs (no device
allocation), and ``reduced`` derives the CPU smoke-test variant of a config.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    """Architecture hyper-parameters (decoder-only unless ``enc_dec``)."""

    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads

    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0  # per-expert hidden dim (0 -> d_ff)
    moe_layer_period: int = 1  # MoE every k-th layer (jamba: 2)
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01

    # SSM (mamba2-style SSD)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    attn_layer_period: int = 0  # hybrid: attention every k-th layer (jamba: 8)

    # attention details
    sliding_window: int = 0  # 0 -> full attention
    rope_theta: float = 10000.0
    rope_fraction: float = 1.0  # stablelm2: 0.25
    qk_norm: bool = False  # qwen3
    mrope: bool = False  # qwen2-vl (3-axis positions)

    # encoder-decoder (whisper)
    enc_dec: bool = False
    enc_layers: int = 0
    enc_seq: int = 1500  # whisper audio frames after conv stub

    # frontend stub: None | 'audio' | 'vision'
    frontend: Optional[str] = None

    tie_embeddings: bool = True
    norm_eps: float = 1e-5
    dtype: Any = jnp.float32  # compute/param dtype (bf16 for dry-runs)

    source: str = ""  # citation

    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads > 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    # ---- derived quantities -------------------------------------------------
    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def d_inner(self) -> int:
        """SSM inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def is_attn_layer(self, l: int) -> bool:
        """Hybrid interleave: layer ``l`` is attention iff period says so."""
        if self.family == "ssm":
            return False
        if self.attn_layer_period <= 0:
            return True
        # jamba: 1 attention layer per `period` block, at position period//2
        return (l % self.attn_layer_period) == self.attn_layer_period // 2

    def is_moe_layer(self, l: int) -> bool:
        if self.num_experts == 0:
            return False
        return (l % self.moe_layer_period) == self.moe_layer_period - 1

    def expert_d_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ---- parameter counting (for roofline MODEL_FLOPS) ----------------------
    def param_counts(self) -> Dict[str, float]:
        """Approximate total and active parameter counts."""
        d, V = self.d_model, self.vocab_size
        embed = V * d * (1 if self.tie_embeddings else 2)

        def attn_params():
            return d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d

        def dense_mlp():
            return 3 * d * self.d_ff  # swiglu

        def moe_mlp(active: bool):
            e = self.experts_per_token if active else self.num_experts
            # experts (swiglu) + router
            return 3 * d * self.expert_d_ff() * e + d * self.num_experts

        def ssm_params():
            di = self.d_inner
            # in_proj (z,x,B,C,dt) + conv + out_proj (mamba2-ish)
            return d * (2 * di + 2 * self.ssm_state + self.ssm_heads) + di * self.ssm_conv + di * d

        total = embed
        active = embed
        n_layers = self.num_layers + (self.enc_layers if self.enc_dec else 0)
        for l in range(self.num_layers):
            if self.family in ("ssm", "hybrid") and not self.is_attn_layer(l):
                total += ssm_params(); active += ssm_params()
            else:
                total += attn_params(); active += attn_params()
                if self.enc_dec:  # cross attention in decoder
                    total += attn_params(); active += attn_params()
            if self.is_moe_layer(l):
                total += moe_mlp(False); active += moe_mlp(True)
            else:
                total += dense_mlp(); active += dense_mlp()
        if self.enc_dec:
            for _ in range(self.enc_layers):
                total += attn_params() + dense_mlp()
                active += attn_params() + dense_mlp()
        return {"total": float(total), "active": float(active)}


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


INPUT_SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    _ensure_loaded()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs():
    _ensure_loaded()
    return sorted(_REGISTRY)


_ARCH_MODULES = [
    "jamba_v0_1_52b", "qwen2_vl_2b", "mamba2_780m", "mixtral_8x7b",
    "granite_8b", "qwen3_moe_30b_a3b", "yi_34b", "stablelm_1_6b",
    "moonshot_v1_16b_a3b", "whisper_large_v3", "gpt2_medium", "gpt2_xl",
]

_LOADED = False


def _ensure_loaded():
    global _LOADED
    if _LOADED:
        return
    import importlib
    for m in _ARCH_MODULES:
        importlib.import_module(f"repro.configs.{m}")
    _LOADED = True


# ---------------------------------------------------------------------------
# Reduced (smoke-test) variants
# ---------------------------------------------------------------------------


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Tiny same-family variant: ≤2 layers, d_model ≤ 512, ≤4 experts."""
    kw: Dict[str, Any] = dict(
        name=cfg.name + "-reduced",
        num_layers=2,
        d_model=256,
        d_ff=512,
        vocab_size=512,
        head_dim=32,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 2) if cfg.num_kv_heads < cfg.num_heads else 4,
        dtype=jnp.float32,
    )
    if cfg.num_experts:
        kw.update(num_experts=4,
                  experts_per_token=min(cfg.experts_per_token, 2),
                  moe_d_ff=128)
    if cfg.ssm_state:
        kw.update(ssm_state=16, ssm_head_dim=32)
    if cfg.attn_layer_period:
        # keep the hybrid interleave visible with 2 layers: attn at layer 1
        kw.update(attn_layer_period=2)
    if cfg.enc_dec:
        kw.update(enc_layers=2, enc_seq=16)
    if cfg.sliding_window:
        kw.update(sliding_window=16)
    return cfg.with_(**kw)


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins; no allocation)
# ---------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, shape: ShapeConfig,
                dtype=None) -> Dict[str, jax.ShapeDtypeStruct]:
    """Abstract inputs for one step of the given kind.

    For the decode kinds the KV-cache/SSM-state specs are built by the model
    (they depend on layer structure); this returns the *data* inputs only.
    """
    dtype = dtype or cfg.dtype
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct
    if shape.kind == "train":
        specs: Dict[str, jax.ShapeDtypeStruct] = {}
        if cfg.frontend == "vision":
            # stubbed frontend: mixed text+patch embeddings (see DESIGN.md §6)
            specs["embeds"] = sds((B, S, cfg.d_model), dtype)
            specs["positions"] = sds((3, B, S), i32)  # M-RoPE t/h/w
        elif cfg.frontend == "audio":
            specs["audio_embeds"] = sds((B, cfg.enc_seq, cfg.d_model), dtype)
            specs["tokens"] = sds((B, S), i32)
        else:
            specs["tokens"] = sds((B, S), i32)
        specs["labels"] = sds((B, S), i32)
        return specs
    if shape.kind == "prefill":
        specs = {}
        if cfg.frontend == "vision":
            specs["embeds"] = sds((B, S, cfg.d_model), dtype)
            specs["positions"] = sds((3, B, S), i32)
        elif cfg.frontend == "audio":
            specs["audio_embeds"] = sds((B, cfg.enc_seq, cfg.d_model), dtype)
            specs["tokens"] = sds((B, S), i32)
        else:
            specs["tokens"] = sds((B, S), i32)
        return specs
    if shape.kind == "decode":
        specs = {"token": sds((B, 1), i32), "position": sds((B,), i32)}
        if cfg.frontend == "audio":
            # cross-attention context (encoder output) is part of the cache
            pass
        return specs
    raise ValueError(shape.kind)
