"""Sharded, prefetching data iterator.

Host-side pipeline: a background thread produces per-worker numpy batches
(deterministic per (epoch, step, worker)), the main thread uploads them.
On a real multi-host TPU deployment each process would materialize only its
addressable shard (``jax.process_index()``-sliced); here that is a single
host, and the stacked (M, ...) leading axis is the gossip-worker axis.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Dict, Iterator, Optional

import jax
import numpy as np

from repro.data.synthetic import make_worker_batches


class ShardedIterator:
    def __init__(self, dataset, num_workers: int, batch_per_worker: int,
                 *, prefetch: int = 2, seed: int = 0, sharding=None):
        self.dataset = dataset
        self.num_workers = num_workers
        self.batch_per_worker = batch_per_worker
        self.seed = seed
        self.sharding = sharding
        self._q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._step = 0
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    def _producer(self):
        step = 0
        while not self._stop.is_set():
            batch = make_worker_batches(self.dataset, self.num_workers,
                                        self.batch_per_worker, step,
                                        epoch_seed=self.seed)
            try:
                self._q.put(batch, timeout=1.0)
                step += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, jax.Array]:
        batch = None
        while batch is None and not self._stop.is_set():
            try:
                batch = self._q.get(timeout=5.0)
            except queue.Empty:
                raise StopIteration
        if self.sharding is not None:
            return jax.tree.map(
                lambda x: jax.device_put(x, self.sharding), batch)
        return jax.tree.map(jax.numpy.asarray, batch)

    def close(self):
        self._stop.set()
