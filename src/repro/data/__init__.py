from repro.data.synthetic import (
    SyntheticLM, SyntheticVision, make_worker_batches, lm_batch_for,
)
from repro.data.pipeline import ShardedIterator

__all__ = ["SyntheticLM", "SyntheticVision", "make_worker_batches",
           "lm_batch_for", "ShardedIterator"]
