"""Deterministic synthetic datasets with *learnable structure*.

Convergence experiments need tasks where loss actually decreases so the
paper's algorithm comparisons (DDP vs LayUp vs …) are meaningful:

* ``SyntheticLM`` — a Markov-chain language: a fixed random transition matrix
  with temperature; the optimal cross-entropy is the chain's conditional
  entropy, so models must learn real structure (bigram stats + position
  effects) to approach it.
* ``SyntheticVision`` — a k-class Gaussian-prototype image task (CIFAR
  stand-in): class prototypes + noise; linearly separable at high SNR, made
  harder by low SNR and distractor dimensions.

Both shard deterministically per worker: the k-th sample of an epoch is used
by exactly one worker (paper Eq. 1: "the k-th sample is exclusively used on
device i within a given epoch").
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class SyntheticLM:
    vocab: int = 256
    seq_len: int = 64
    temperature: float = 1.5
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        logits = rng.normal(size=(self.vocab, self.vocab)) * self.temperature
        self.trans = np.exp(logits - logits.max(-1, keepdims=True))
        self.trans /= self.trans.sum(-1, keepdims=True)
        # conditional entropy = irreducible loss floor
        p_stat = np.full(self.vocab, 1.0 / self.vocab)
        for _ in range(50):
            p_stat = p_stat @ self.trans
        self.entropy = float(-(p_stat[:, None] * self.trans
                               * np.log(self.trans + 1e-12)).sum())

    def sample(self, rng: np.random.Generator, batch: int) -> Dict[str, np.ndarray]:
        toks = np.empty((batch, self.seq_len + 1), np.int32)
        toks[:, 0] = rng.integers(0, self.vocab, batch)
        # vectorized chain sampling via inverse-cdf
        cdf = np.cumsum(self.trans, axis=-1)
        for t in range(self.seq_len):
            u = rng.random(batch)
            toks[:, t + 1] = (u[:, None] < cdf[toks[:, t]]).argmax(-1)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


@dataclass
class SyntheticVision:
    num_classes: int = 10
    dim: int = 256
    snr: float = 0.35
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        self.prototypes = rng.normal(size=(self.num_classes, self.dim)).astype(np.float32)
        self.prototypes /= np.linalg.norm(self.prototypes, axis=-1, keepdims=True)

    def sample(self, rng: np.random.Generator, batch: int) -> Dict[str, np.ndarray]:
        y = rng.integers(0, self.num_classes, batch)
        x = (self.snr * self.prototypes[y]
             + rng.normal(size=(batch, self.dim)).astype(np.float32))
        return {"x": x.astype(np.float32), "labels": y.astype(np.int32)}


def make_worker_batches(dataset, num_workers: int, batch_per_worker: int,
                        step: int, epoch_seed: int = 0):
    """Deterministic per-(worker, step) batches, disjoint within an epoch."""
    out = []
    for w in range(num_workers):
        rng = np.random.default_rng(
            (epoch_seed * 1_000_003 + step) * 64 + w)
        out.append(dataset.sample(rng, batch_per_worker))
    # stack over workers → leading M axis
    return {k: np.stack([b[k] for b in out]) for k in out[0]}


def lm_batch_for(cfg, batch: int, seq: int, seed: int = 0) -> Dict[str, jnp.ndarray]:
    """Random-token batch matching ``input_specs`` (for smoke tests/examples)."""
    rng = jax.random.PRNGKey(seed)
    r1, r2, r3 = jax.random.split(rng, 3)
    out: Dict[str, jnp.ndarray] = {}
    if cfg.frontend == "vision":
        out["embeds"] = (jax.random.normal(r1, (batch, seq, cfg.d_model),
                                           jnp.float32) * 0.02).astype(cfg.dtype)
        pos = jnp.broadcast_to(jnp.arange(seq)[None, None], (3, batch, seq))
        out["positions"] = pos.astype(jnp.int32)
    elif cfg.frontend == "audio":
        out["audio_embeds"] = (jax.random.normal(
            r1, (batch, cfg.enc_seq, cfg.d_model), jnp.float32) * 0.02
        ).astype(cfg.dtype)
        out["tokens"] = jax.random.randint(r2, (batch, seq), 0, cfg.vocab_size)
    else:
        out["tokens"] = jax.random.randint(r2, (batch, seq), 0, cfg.vocab_size)
    out["labels"] = jax.random.randint(r3, (batch, seq), 0, cfg.vocab_size)
    return out
