"""LayUp — the paper's algorithm (Alg. 1).

Asynchronous decentralized SGD with push-sum randomized gossip and
layer-wise updates. Under the v2 layer-granular API the layer-wise
mechanism manifests as three things (see DESIGN.md §4):

1. **Zero-delay mixing** — because each layer's parameters are sent *during*
   the backward pass, a peer's next forward sees them immediately
   (``layerwise=True``). With ``layerwise=False`` ("block updates", ≡ GoSGD)
   the whole-model message lands only after the full backward, i.e. with one
   iteration of delay (buffered in ``extras``) — this is the paper's §3.2
   drift comparison.
2. **Mixed-version updates** — the local update computed at the
   forward-pass parameters x̂ is applied on top of freshly *mixed*
   parameters x̃ (receiver side), which is exactly the gradient bias the
   paper bounds in Lemma 6.1.
3. **Per-layer version stamps** — receivers stamp each layer group with the
   fractional generation time of the message (``send_fractions``): layer ℓ's
   message leaves when its gradient is ready during the backward, so
   layer-wise staleness is strictly below the block-mode staleness of 2
   iterations at every layer (asserted in tests/test_algorithms.py).

Collisions (two senders picking the same peer) skip the losing send with
weights untouched, conserving Σw exactly (paper §3.1: information is
delayed, never lost).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.core.api import (
    DistAlgorithm, choose_peers, pushsum_weight_update, register_algorithm,
)
from repro.core.layerview import LayerView, send_fractions, stamp_groups


class LayUp(DistAlgorithm):
    asynchronous = True

    def __init__(self, layerwise: bool = True, name: str = "layup",
                 peer_mode: str = "random"):
        """peer_mode: 'random' (paper-faithful randomized gossip) or
        'hypercube' (beyond-paper: deterministic XOR-partner schedule,
        i ↔ i⊕2^(t mod log₂M) — a perfect matching every step, collision-free
        by construction, consensus in log₂M rounds instead of the
        O(log M / log(1/λ₂)) expected rounds of uniform random gossip)."""
        self.layerwise = layerwise
        self.name = name
        self.peer_mode = peer_mode

    def _peers(self, rng, M, active, step):
        if self.peer_mode == "hypercube":
            import numpy as np
            bits = max(int(np.ceil(np.log2(M))), 1)
            stride = 1 << (step % bits)
            me = jnp.arange(M)
            peers = jnp.bitwise_xor(me, stride)
            valid = peers < M  # non-power-of-two M: unpaired workers idle
            send_ok = active & valid
            has_recv = send_ok[jnp.clip(peers, 0, M - 1)] & valid
            sender_idx = jnp.where(has_recv, jnp.clip(peers, 0, M - 1), 0)
            return send_ok, has_recv, sender_idx
        return choose_peers(rng, M, active)

    # -- pending-buffer helpers (block mode only) ------------------------------
    #
    # Block (≡ GoSGD) messages carry the WHOLE model and are sent only after
    # the full backward pass, so they land too late for the peer's next
    # forward — one extra iteration of staleness versus layer-wise sends
    # (paper §3.2). Modeled as a 2-slot message queue; each slot carries the
    # generation-time stamp receivers merge into their version clock.
    def _empty_slot(self, groups, M):
        return {"vals": jax.tree.map(jnp.zeros_like, groups),
                "w": jnp.zeros((M,), jnp.float32),
                "valid": jnp.zeros((M,), bool),
                "stamp": jnp.zeros((), jnp.float32)}

    def init_extras(self, view: LayerView, M: int):
        if self.layerwise:
            return ()
        return {"q0": self._empty_slot(view.groups, M),
                "q1": self._empty_slot(view.groups, M)}

    def pre(self, view: LayerView, weights, extras, step):
        if self.layerwise:
            return view, weights, extras
        # apply the oldest buffered block mix (sent two iterations ago)
        slot = extras["q0"]
        w_s = slot["w"]
        valid = slot["valid"]
        denom = jnp.maximum(weights + w_s, 1e-12)
        alpha = jnp.where(valid, weights / denom, 1.0)
        beta = jnp.where(valid, w_s / denom, 0.0)

        def mix(x, v):
            a = self._bcast(alpha, x)
            b = self._bcast(beta, x)
            return (a * x.astype(jnp.float32)
                    + b * v.astype(jnp.float32)).astype(x.dtype)

        groups = jax.tree.map(mix, view.groups, slot["vals"])
        weights = weights + jnp.where(valid, w_s, 0.0)
        versions = stamp_groups(view.versions, slot["stamp"],
                                worker_mask=valid)
        extras = {"q0": extras["q1"],
                  "q1": {**slot, "valid": jnp.zeros_like(valid),
                         "w": jnp.zeros_like(w_s)}}
        return (view.with_groups(groups).with_versions(versions), weights,
                extras)

    def post(self, view: LayerView, weights, extras, updates, active, rng,
             step):
        M = weights.shape[0]
        send_ok, has_recv, sender_idx = self._peers(rng, M, active, step)
        af = active.astype(jnp.float32)
        params = view.groups

        if self.layerwise:
            # sender transmits its *updated* layer; receiver mixes, then its
            # own update lands on the mixed value (x̃) → Lemma 6.1 bias.
            # NB: a worker that is simultaneously a winning sender mixes with
            # its POST-halving weight (it shipped half its mass away) — this
            # is what conserves Σ wᵢxᵢ exactly (property-tested).
            w_self = jnp.where(send_ok, weights * 0.5, weights)
            w_s = (weights * 0.5)[sender_idx]  # winners' halved mass
            denom = jnp.maximum(w_self + w_s, 1e-12)
            alpha = jnp.where(has_recv, w_self / denom, 1.0)
            beta = jnp.where(has_recv, w_s / denom, 0.0)

            def apply_leaf(x, u):
                uf = self._bcast(af, x) * u.astype(jnp.float32)
                upd_x = x.astype(jnp.float32) + uf  # sender-side value
                sent = upd_x[sender_idx]
                a = self._bcast(alpha, x)
                b = self._bcast(beta, x)
                mixed = a * x.astype(jnp.float32) + b * sent + uf
                out = jnp.where(self._bcast(has_recv.astype(jnp.float32), x) > 0,
                                mixed, upd_x)
                return out.astype(x.dtype)

            new_groups = jax.tree.map(apply_leaf, params, updates)
            new_weights = pushsum_weight_update(weights, send_ok, has_recv,
                                                sender_idx)
            # layer ℓ's message is generated mid-backward at send_fractions[ℓ]
            phi = jnp.asarray(send_fractions(view.num_groups))
            versions = stamp_groups(view.versions,
                                    jnp.asarray(step, jnp.float32) + phi,
                                    worker_mask=has_recv)
            metrics = {"gossip_sends": jnp.sum(send_ok.astype(jnp.float32))}
            return (view.with_groups(new_groups).with_versions(versions),
                    new_weights, extras, metrics)

        # ---- block mode (≡ GoSGD): update now, enqueue the mix --------------
        new_groups = self.masked_apply(params, updates, active)
        sent = jax.tree.map(lambda x: x[sender_idx], new_groups)
        w_half = weights * 0.5
        new_weights = jnp.where(send_ok, w_half, weights)
        extras = {
            "q0": extras["q0"],
            "q1": {
                "vals": sent,
                "w": jnp.where(has_recv, w_half[sender_idx], 0.0),
                "valid": has_recv,
                # whole-model message generated at the end of this iteration
                "stamp": jnp.asarray(step, jnp.float32) + 1.0,
            },
        }
        metrics = {"gossip_sends": jnp.sum(send_ok.astype(jnp.float32))}
        return (view.with_groups(new_groups), new_weights, extras, metrics)


@register_algorithm("layup")
def _layup(**kw):
    return LayUp(layerwise=True, name="layup", **kw)


@register_algorithm("layup-block")
def _layup_block():
    """Ablation: LayUp without layer-wise updates (end-of-iteration mix)."""
    return LayUp(layerwise=False, name="layup-block")


@register_algorithm("layup-hypercube")
def _layup_hypercube():
    """Beyond-paper: deterministic hypercube gossip schedule (§Perf)."""
    return LayUp(layerwise=True, name="layup-hypercube",
                 peer_mode="hypercube")
