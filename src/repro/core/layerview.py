"""Layer-granular parameter views — the v2 `DistAlgorithm` currency.

The v1 API handed algorithms a *monolithic* stacked pytree, so "layer-wise"
could only manifest indirectly (zero-delay mixing). The v2 API partitions
every parameter tree into **layer groups** and threads a per-group,
per-worker *version clock* through the hooks, making the paper's layer-wise
updates (and their staleness) a first-class, measurable concept
(DESIGN.md §1–§3).

* ``LayerPartition`` — a static partitioner derived from a tree's structure.
  Leaves are grouped by their tree path: the top-level key normally, or
  ``"<key>.<idx>"`` for per-layer containers (lists/tuples of blocks), so a
  transformer's ``params["blocks"][k]`` becomes its own group. Group names
  are zero-padded and sorted, so order is exact depth order *within* a
  per-layer container ("blocks.000" < "blocks.001") but alphabetical
  across top-level keys ("blocks" < "embed") — group index is therefore an
  approximation of model depth, not ground truth. The staleness guarantees
  that matter (layer-wise < block at every group) are ordering-independent:
  every layer-wise stamp lies within the backward pass, in (0, 1] of the
  iteration, strictly fresher than block mode's 2-iteration queue.

* ``FlatPartition`` — a :class:`LayerPartition` that additionally fixes a
  **persistent flat layout**: every layer group packs into ONE contiguous
  buffer per dtype (leaves flattened and concatenated in tree order, each
  leaf stored at its own dtype). ``pack`` runs once at state init
  (`make_decoupled_state`) — from then on the plane IS the parameter
  representation: gossip collectives ship the per-group buffers directly
  (no per-step ``ravel_pytree``, no f32 upcast of a bf16 wire) and
  ``unpack`` is a cheap static slice+reshape view materialized only for
  the forward pass and for checkpoint export (DESIGN.md §11).

* ``LayerView`` — the pytree handed to the hooks: ``groups`` (an ordered
  ``{name: {path: leaf}}`` mapping whose leaves keep the stacked ``(M, ...)``
  layout, so ``jax.tree.map`` works exactly as it did on the raw tree) plus
  ``versions``, an ``(M, G)`` float32 array holding, per worker and group,
  the *generation time* (in fractional iterations) of the freshest remote
  information mixed into that group. Versions only move forward
  (``stamp_groups`` max-merges).

* Version/staleness conventions: iteration ``t`` spans ``[t, t+1)``;
  a message whose content was produced at the end of iteration ``t`` carries
  stamp ``t + 1``. Layer-wise senders ship group ``g`` *during* the backward
  pass at the fractional time ``send_fractions`` computes (output-most group
  first), which is why layer-wise staleness is strictly below block
  staleness at every layer — the paper's §3.2 drift claim, at layer
  granularity.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.tree_util import DictKey, FlattenedIndexKey, GetAttrKey, SequenceKey


def _key_str(entry) -> str:
    if isinstance(entry, DictKey):
        return str(entry.key)
    if isinstance(entry, SequenceKey):
        return f"{entry.idx:03d}"
    if isinstance(entry, GetAttrKey):
        return str(entry.name)
    if isinstance(entry, FlattenedIndexKey):
        return f"{entry.key:03d}"
    return str(entry)


def _group_label(path) -> str:
    """Group = top-level key, or "<key>.<idx>" for per-layer containers."""
    if not path:
        return "root"
    if len(path) >= 2 and isinstance(path[1], (SequenceKey, FlattenedIndexKey)):
        return f"{_key_str(path[0])}.{_key_str(path[1])}"
    return _key_str(path[0])


class LayerPartition:
    """Static partitioner: split a tree into layer groups and join it back.

    Built from any tree with the target *structure* (abstract or concrete;
    stacked or single-worker — only the treedef matters). ``split`` produces
    the ``groups`` mapping for a :class:`LayerView`; ``join`` restores the
    original tree. Both are pure reshuffles — safe under ``jit``.
    """

    def __init__(self, example_tree):
        flat, treedef = jax.tree_util.tree_flatten_with_path(example_tree)
        self._treedef = treedef
        self._index = []  # (group_label, leaf_key) per leaf, in flatten order
        seen: Dict[str, None] = {}
        for path, _ in flat:
            label = _group_label(path)
            leaf_key = ".".join(_key_str(e) for e in path) or "leaf"
            self._index.append((label, leaf_key))
            seen.setdefault(label, None)
        self.names: Tuple[str, ...] = tuple(sorted(seen))
        self._gidx = {n: i for i, n in enumerate(self.names)}

    @property
    def num_groups(self) -> int:
        return len(self.names)

    def group_index(self, name: str) -> int:
        return self._gidx[name]

    def split(self, tree) -> Dict[str, Dict[str, Any]]:
        leaves = jax.tree_util.tree_leaves(tree)
        if len(leaves) != len(self._index):
            raise ValueError(
                f"tree has {len(leaves)} leaves; partition expects "
                f"{len(self._index)}")
        groups: Dict[str, Dict[str, Any]] = {n: {} for n in self.names}
        for (label, leaf_key), leaf in zip(self._index, leaves):
            groups[label][leaf_key] = leaf
        return groups

    def join(self, groups: Dict[str, Dict[str, Any]]):
        leaves = [groups[label][leaf_key] for label, leaf_key in self._index]
        return jax.tree_util.tree_unflatten(self._treedef, leaves)

    def init_versions(self, M: int) -> jnp.ndarray:
        return jnp.zeros((M, self.num_groups), jnp.float32)

    def view(self, tree, versions=None, M: int | None = None) -> "LayerView":
        if versions is None:
            if M is None:
                M = jax.tree_util.tree_leaves(tree)[0].shape[0]
            versions = self.init_versions(M)
        return LayerView(groups=self.split(tree), versions=versions,
                         names=self.names)


class _LeafSlot(NamedTuple):
    """Where one leaf lives inside its group's flat buffer."""
    group: str
    offset: int
    size: int
    shape: Tuple[int, ...]
    dtype: Any


class FlatPartition(LayerPartition):
    """A :class:`LayerPartition` with a fixed flat layout per group.

    Each group's leaves are flattened (C order) and concatenated, in tree
    order, into one contiguous buffer PER DTYPE: a uniform-dtype group
    (the usual case) is exactly one buffer named after the group, in the
    params' dtype — so a bf16 model gets a bf16 plane and a bf16 gossip
    wire; a group mixing dtypes (e.g. bf16 weights + f32 norm scales)
    gets one ``"<group>:<dtype>"`` buffer per dtype. Every leaf is stored
    at ITS OWN dtype — the flat plane never silently promotes bf16
    leaves to f32 master copies, so the persistent representation is
    numerically identical to the legacy per-leaf tree state.
    ``pack``/``unpack`` accept any number of leading batch axes
    (worker-stacked ``(M, ...)`` trees, ``(M, D, ...)`` FIFO stacks) —
    the leading axes are inferred from the first leaf and carried through
    to the buffers.

    Both directions are pure static reshuffles (reshape/concat on pack,
    slice/reshape on unpack), safe under ``jit`` and free to fuse into
    their consumers. The intended discipline is pack-once: the plane is
    the persistent state, ``unpack`` produces the tree view for the
    forward pass / checkpoint export, and per-step packing is only ever
    applied to gradients (DESIGN.md §11).

    ``group_sizes``/``group_dtypes`` are keyed by plane-buffer name
    (== group name for uniform groups); ``names`` (inherited) stays the
    per-group key of the version clocks.
    """

    def __init__(self, example_tree):
        super().__init__(example_tree)
        flat, _ = jax.tree_util.tree_flatten_with_path(example_tree)
        dtypes_by_group: Dict[str, list] = {n: [] for n in self.names}
        for (label, _), (_, leaf) in zip(self._index, flat):
            dt = jnp.dtype(leaf.dtype)
            if dt not in dtypes_by_group[label]:
                dtypes_by_group[label].append(dt)

        def bucket(label, dt):
            if len(dtypes_by_group[label]) == 1:
                return label
            return f"{label}:{jnp.dtype(dt).name}"

        self.group_dtypes: Dict[str, Any] = {}
        sizes: Dict[str, int] = {}
        self._slots: list = []  # per leaf, in tree-flatten order
        for (label, _), (_, leaf) in zip(self._index, flat):
            dt = jnp.dtype(leaf.dtype)
            key = bucket(label, dt)
            self.group_dtypes[key] = dt
            shape = tuple(int(d) for d in leaf.shape)
            size = int(np.prod(shape, dtype=np.int64)) if shape else 1
            self._slots.append(_LeafSlot(key, sizes.get(key, 0), size,
                                         shape, dt))
            sizes[key] = sizes.get(key, 0) + size
        self.group_sizes: Dict[str, int] = sizes

    def plane_nbytes(self, wire: str = "param") -> int:
        """Bytes of ONE flat plane (single worker) — the per-step gossip
        wire cost per peer, and the regression hook for the
        wire-dtype-follows-params guarantee (bf16 plane = half the f32
        plane).

        ``wire="param"`` prices each group buffer at its param dtype (the
        PR-4 wire); ``wire="int8"`` prices the quantized wire — one int8
        byte per element plus one f32 scale per 128-lane row of each
        group's padded quant layout (DESIGN.md §14)."""
        if wire == "param":
            return sum(size * jnp.dtype(self.group_dtypes[n]).itemsize
                       for n, size in self.group_sizes.items())
        if wire == "int8":
            from repro.kernels.quantize import quant_wire_nbytes
            return sum(quant_wire_nbytes(size)
                       for size in self.group_sizes.values())
        raise ValueError(f"unknown wire dtype {wire!r}")

    def abstract_plane(self, lead: Tuple[int, ...] = ()) -> Dict[str, Any]:
        """ShapeDtypeStructs of the plane with the given leading axes."""
        return {n: jax.ShapeDtypeStruct(tuple(lead) + (size,),
                                        self.group_dtypes[n])
                for n, size in self.group_sizes.items()}

    def pack(self, tree) -> Dict[str, Any]:
        """Tree → ``{group: (*lead, group_size) buffer}``. Leading axes are
        inferred (leaves must share them); leaves are cast to the group
        dtype."""
        leaves = jax.tree_util.tree_leaves(tree)
        if len(leaves) != len(self._slots):
            raise ValueError(f"tree has {len(leaves)} leaves; partition "
                             f"expects {len(self._slots)}")
        lead = leaves[0].ndim - len(self._slots[0].shape)
        if lead < 0:
            raise ValueError(
                f"leaf rank {leaves[0].ndim} below partition rank "
                f"{len(self._slots[0].shape)}")
        chunks: Dict[str, list] = {n: [] for n in self.group_sizes}
        for slot, leaf in zip(self._slots, leaves):
            if tuple(leaf.shape[lead:]) != slot.shape:
                raise ValueError(
                    f"leaf shape {tuple(leaf.shape)} does not end with "
                    f"partition shape {slot.shape} (lead={lead})")
            buf = jnp.asarray(leaf).astype(self.group_dtypes[slot.group])
            chunks[slot.group].append(
                buf.reshape(tuple(leaf.shape[:lead]) + (slot.size,)))
        return {n: (jnp.concatenate(c, axis=-1) if len(c) > 1 else c[0])
                for n, c in chunks.items()}

    def unpack(self, plane: Dict[str, Any]):
        """``{group: (*lead, group_size)}`` → tree (original shapes and
        dtypes, leading axes preserved). Static slices — a view, not a
        repack."""
        leaves = []
        for slot in self._slots:
            buf = plane[slot.group]
            lead = tuple(buf.shape[:-1])
            piece = jax.lax.slice_in_dim(buf, slot.offset,
                                         slot.offset + slot.size, axis=-1)
            leaves.append(piece.reshape(lead + slot.shape)
                          .astype(slot.dtype))
        return jax.tree_util.tree_unflatten(self._treedef, leaves)


@jax.tree_util.register_dataclass
@dataclass
class LayerView:
    """Layer-grouped stacked parameters + per-group version clocks."""

    groups: Dict[str, Any]   # {group: {path: (M, ...) leaf}}
    versions: jnp.ndarray    # (M, G) float32 generation-time stamps
    names: Tuple[str, ...] = field(metadata=dict(static=True), default=())

    @property
    def num_groups(self) -> int:
        return len(self.names)

    def with_groups(self, groups) -> "LayerView":
        return replace(self, groups=groups)

    def with_versions(self, versions) -> "LayerView":
        return replace(self, versions=versions)


# ---------------------------------------------------------------------------
# version-clock arithmetic
# ---------------------------------------------------------------------------


def send_fractions(G: int, bwd_ratio: float = 2.0) -> np.ndarray:
    """Fractional iteration time at which group ``g``'s update/message is
    generated during the backward pass.

    The backward visits groups output→input, so group ``g`` (partition
    order, treated as depth order — an approximation across top-level keys,
    see the module docstring; 0 = input-most) finishes at fraction
    ``(G - g)/G`` of the backward:
    ``phi_g = (1 + bwd_ratio * (G - g)/G) / (1 + bwd_ratio)`` ∈ (0, 1].
    Output-most groups are generated earliest (small ``phi``); the
    input-most group lands exactly at the iteration boundary (``phi = 1``).
    All values stay within the iteration, so the layer-wise < block-mode
    staleness ordering holds regardless of how groups are numbered.
    """
    g = np.arange(G, dtype=np.float32)
    return ((1.0 + bwd_ratio * (G - g) / G)
            / (1.0 + bwd_ratio)).astype(np.float32)


def stamp_groups(versions: jnp.ndarray, value, worker_mask=None) -> jnp.ndarray:
    """Max-merge new generation-time stamps into the ``(M, G)`` clock.

    ``value`` broadcasts against ``(M, G)`` — a scalar stamps every group,
    a ``(G,)`` vector stamps per group. ``worker_mask`` (M,) bool restricts
    the stamp to receiving workers. Monotone: versions never move backward,
    so "no news" simply lets staleness grow.
    """
    value = jnp.broadcast_to(jnp.asarray(value, jnp.float32), versions.shape)
    stamped = jnp.maximum(versions, value)
    if worker_mask is None:
        return stamped
    return jnp.where(worker_mask.reshape(-1, 1), stamped, versions)


def layer_staleness(versions: jnp.ndarray, step) -> jnp.ndarray:
    """Per-group staleness ``(G,)`` measured at the end of iteration ``step``:
    mean over workers of ``(step + 1) - versions``, clipped at 0."""
    now = (jnp.asarray(step, jnp.float32) + 1.0)
    return jnp.mean(jnp.maximum(now - versions, 0.0), axis=0)


def version_metrics(versions: jnp.ndarray, step) -> Dict[str, jnp.ndarray]:
    """The staleness metrics both the sim trainer and the production
    decoupled lane report, so sim-vs-prod parity is assertable key by key."""
    ls = layer_staleness(versions, step)
    return {"layer_staleness": ls, "staleness_mean": jnp.mean(ls)}
