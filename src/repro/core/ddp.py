"""Synchronous distributed data parallel (the paper's primary baseline).

Gradients are all-reduced (averaged) across workers before the optimizer
step, so replicas stay bit-identical. In the production backend this is a
``psum`` over the ('pod','data') axes; here (sim) a mean over the stacked
axis. Synchronous ⇒ ignores the straggler mask (it *waits*; the cost shows
up as wall-clock in repro.core.simulator, reproducing paper Fig. 3B).

Under the v2 layer-granular hooks every group's version clock is stamped to
the current step on every iteration — synchronous training has zero
staleness at every layer, the reference point the async algorithms are
measured against.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.api import DistAlgorithm, register_algorithm
from repro.core.layerview import LayerView, stamp_groups


class DDP(DistAlgorithm):
    name = "ddp"
    asynchronous = False

    def transform_grads(self, grads, extras):
        g = jax.tree.map(lambda x: jnp.broadcast_to(
            jnp.mean(x, axis=0, keepdims=True), x.shape), grads)
        return g, extras

    def post(self, view: LayerView, weights, extras, updates, active, rng,
             step):
        new_groups = jax.tree.map(
            lambda p, u: p + u.astype(p.dtype), view.groups, updates)
        versions = stamp_groups(view.versions,
                                jnp.asarray(step, jnp.float32) + 1.0)
        return (view.with_groups(new_groups).with_versions(versions),
                weights, extras, {})


@register_algorithm("ddp")
def _ddp():
    return DDP()
