"""Local SGD baseline (Stich, 2019): H local steps, then full averaging.

Version clocks: every group is stamped to ``step + 1`` on sync steps only —
between syncs no remote information flows, so per-layer staleness ramps
from 0 up to H−1 and resets, the sawtooth the paper's periodic-averaging
baselines all share.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.api import DistAlgorithm, register_algorithm
from repro.core.layerview import LayerView, stamp_groups


class LocalSGD(DistAlgorithm):
    asynchronous = False

    def __init__(self, sync_every: int = 8, name: str = "localsgd"):
        self.H = sync_every
        self.name = name

    def post(self, view: LayerView, weights, extras, updates, active, rng,
             step):
        new_groups = jax.tree.map(
            lambda p, u: p + u.astype(p.dtype), view.groups, updates)
        sync = (jnp.mod(step + 1, self.H) == 0)

        def maybe_avg(p):
            avg = jnp.broadcast_to(
                jnp.mean(p.astype(jnp.float32), axis=0, keepdims=True),
                p.shape).astype(p.dtype)
            return jnp.where(sync, avg, p)

        versions = stamp_groups(
            view.versions,
            jnp.where(sync, jnp.asarray(step, jnp.float32) + 1.0, 0.0))
        return (view.with_groups(jax.tree.map(maybe_avg, new_groups))
                .with_versions(versions), weights, extras,
                {"synced": sync.astype(jnp.float32)})


@register_algorithm("localsgd")
def _localsgd(sync_every: int = 8):
    return LocalSGD(sync_every)
