"""Local SGD baseline (Stich, 2019): H local steps, then full averaging."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.api import DistAlgorithm, register_algorithm


class LocalSGD(DistAlgorithm):
    asynchronous = False

    def __init__(self, sync_every: int = 8, name: str = "localsgd"):
        self.H = sync_every
        self.name = name

    def post(self, params, weights, extras, updates, active, rng, step):
        new_params = jax.tree.map(
            lambda p, u: p + u.astype(p.dtype), params, updates)
        sync = (jnp.mod(step + 1, self.H) == 0)

        def maybe_avg(p):
            avg = jnp.broadcast_to(
                jnp.mean(p.astype(jnp.float32), axis=0, keepdims=True),
                p.shape).astype(p.dtype)
            return jnp.where(sync, avg, p)

        return (jax.tree.map(maybe_avg, new_params), weights, extras,
                {"synced": sync.astype(jnp.float32)})


@register_algorithm("localsgd")
def _localsgd(sync_every: int = 8):
    return LocalSGD(sync_every)
