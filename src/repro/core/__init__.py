from repro.core.api import (
    DistAlgorithm, TrainState, get_algorithm, list_algorithms,
    make_sim_trainer, register_algorithm, consensus, disagreement,
)

__all__ = [
    "DistAlgorithm", "TrainState", "get_algorithm", "list_algorithms",
    "make_sim_trainer", "register_algorithm", "consensus", "disagreement",
]
