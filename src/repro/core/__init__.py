from repro.core.api import (
    DistAlgorithm, TrainState, get_algorithm, list_algorithms,
    make_sim_trainer, register_algorithm, consensus, disagreement,
)
from repro.core.backend import (
    EventSimBackend, ProdTrainerBackend, SimTrainerBackend, TrainerBackend,
    drive, make_backend,
)
from repro.core.layerview import (
    FlatPartition, LayerPartition, LayerView, layer_staleness, send_fractions,
    stamp_groups,
    version_metrics,
)

__all__ = [
    "DistAlgorithm", "TrainState", "get_algorithm", "list_algorithms",
    "make_sim_trainer", "register_algorithm", "consensus", "disagreement",
    "EventSimBackend", "ProdTrainerBackend", "SimTrainerBackend",
    "TrainerBackend", "drive", "make_backend",
    "FlatPartition", "LayerPartition", "LayerView", "layer_staleness",
    "send_fractions",
    "stamp_groups", "version_metrics",
]
