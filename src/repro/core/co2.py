"""CO2 baseline (Sun et al., 2024): Local SGD whose outer
averaging/momentum step overlaps communication by operating on a *stale*
(one-outer-round-old) average. Requires extra model-sized buffers (the paper
quotes up to 4× model memory with the penalty gap; like the paper's own
comparison we implement the overlap without the penalty-gap correction —
that correction affects final quality only, not convergence speed).

Version clocks: the outer step consumes the average from the *previous*
sync round, so sync steps stamp ``step + 1 − H`` — CO2's overlap trades a
full outer round of staleness for hidden communication, which the
``layer_staleness`` metric now makes visible.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.api import DistAlgorithm, register_algorithm
from repro.core.layerview import LayerView, stamp_groups
from repro.core.slowmo import SlowMo


class CO2(SlowMo):
    asynchronous = True  # overlapped outer step tolerates stragglers

    def __init__(self, sync_every: int = 8, outer_lr: float = 1.0,
                 outer_beta: float = 0.5):
        super().__init__(sync_every, outer_lr, outer_beta, name="co2")

    def init_extras(self, view: LayerView, M: int):
        base = super().init_extras(view, M)
        base["stale_avg"] = jax.tree.map(jnp.array, base["z"])
        return base

    def post(self, view: LayerView, weights, extras, updates, active, rng,
             step):
        new_groups = self.masked_apply(view.groups, updates, active)
        sync = (jnp.mod(step + 1, self.H) == 0)

        # outer step uses the STALE average (communication overlapped)
        u_new = jax.tree.map(
            lambda uu, z, xa: self.outer_beta * uu.astype(jnp.float32)
            + (z.astype(jnp.float32) - xa.astype(jnp.float32)) / self.outer_lr,
            extras["u"], extras["z"], extras["stale_avg"])
        z_new = jax.tree.map(
            lambda zz, uu: zz.astype(jnp.float32) - self.outer_lr * uu,
            extras["z"], u_new)
        # refresh the stale average with *this* round's mean (arrives "later")
        xavg = jax.tree.map(
            lambda p: jnp.mean(p.astype(jnp.float32), axis=0), new_groups)

        def sel(a, b):
            return jnp.where(sync, a.astype(jnp.float32),
                             b.astype(jnp.float32)).astype(b.dtype)

        z = jax.tree.map(sel, z_new, extras["z"])
        u = jax.tree.map(sel, u_new, extras["u"])
        stale = jax.tree.map(sel, xavg, extras["stale_avg"])
        out = jax.tree.map(
            lambda p, zz: jnp.where(
                sync, jnp.broadcast_to(zz[None].astype(jnp.float32), p.shape),
                p.astype(jnp.float32)).astype(p.dtype),
            new_groups, z)
        versions = stamp_groups(
            view.versions,
            jnp.where(sync,
                      jnp.asarray(step, jnp.float32) + 1.0 - self.H, 0.0))
        return (view.with_groups(out).with_versions(versions), weights,
                {"z": z, "u": u, "stale_avg": stale},
                {"synced": sync.astype(jnp.float32)})


@register_algorithm("co2")
def _co2(sync_every: int = 8, outer_lr: float = 1.0, outer_beta: float = 0.5):
    return CO2(sync_every, outer_lr, outer_beta)
