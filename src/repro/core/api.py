"""Distributed-algorithm API v2 + the simulation-backend trainer.

Every algorithm (LayUp and all baselines) is a ``DistAlgorithm`` with four
pure hooks operating on a :class:`~repro.core.layerview.LayerView` — a
layer-grouped partition of the stacked parameters (every leaf keeps its
leading ``M`` worker axis) carrying per-group/per-worker version clocks:

  init_extras(view, M)                   → algorithm-private state
  transform_grads(grads, extras)         → grads  (grouped like view.groups;
                                           DDP: mean over workers)
  pre(view, weights, extras, step)       → applied before the forward pass
                                           (e.g. delayed/buffered gossip)
  post(view, weights, extras, updates, active, rng, step)
                                         → applies local updates + mixing
                                           and stamps the version clocks

``make_sim_trainer`` wires a model loss, an optimizer, a schedule and an
algorithm into a jitted step. The same stacked representation runs on one
CPU device (vmap) or on a mesh (leading axis sharded over ('pod','data')).

Decoupled execution (the paper's PD-ASGD mechanism, DESIGN.md §3):
``make_sim_trainer(..., fb_ratio=R, update_delay=D)`` splits each worker's
batch into ``R`` forward passes of which one receives a backward (the
forward lane runs at ``R×`` the update rate), and delays gradient
application by ``D`` iterations through a FIFO — the gradient computed from
parameters at version ``v_f`` lands on parameters at version ``v_f + D``,
the mixed-version bias the paper bounds in Lemma 6.1, now measurable via
the ``update_staleness`` / ``layer_staleness`` metrics.

Straggler emulation: ``straggler_delays[i] = d`` makes worker ``i`` perform
its local update + gossip only every ``d+1`` iterations (it still *receives*
peer updates, matching the paper §5.4). Synchronous algorithms ignore the
mask — their straggler cost is wall-clock (see repro.core.simulator).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.layerview import (
    LayerPartition, LayerView, send_fractions, stamp_groups, version_metrics,
)
from repro.optim.optimizers import Optimizer, apply_updates

# ---------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclass
class TrainState:
    params: Any           # stacked (M, ...) pytree
    opt_state: Any        # stacked
    weights: jnp.ndarray  # (M,) push-sum weights (sum == 1)
    extras: Any           # algorithm-private
    step: jnp.ndarray     # scalar int32
    versions: jnp.ndarray = None  # (M, G) per-group version clocks
    delay: Any = ()       # decoupled-mode gradient FIFO ({} when D == 0)


class DistAlgorithm:
    """Base class; subclasses override the hooks they need.

    Hooks receive a :class:`LayerView`; ``view.groups`` maps like the raw
    parameter tree under ``jax.tree.map`` (against equally-grouped updates
    or gradients), and ``view.versions`` is the per-group staleness clock
    the algorithm stamps whenever remote information is incorporated.
    """

    name: str = "base"
    asynchronous: bool = False  # respects the straggler active-mask

    def init_extras(self, view: LayerView, M: int):
        return ()

    def transform_grads(self, grads, extras):
        return grads, extras

    def pre(self, view: LayerView, weights, extras, step):
        return view, weights, extras

    def post(self, view: LayerView, weights, extras, updates, active, rng,
             step):
        raise NotImplementedError

    # -- shared helpers -------------------------------------------------------
    @staticmethod
    def _bcast(v, leaf):
        """Reshape a per-worker (M,) vector for broadcasting against a leaf."""
        return v.reshape((-1,) + (1,) * (leaf.ndim - 1)).astype(jnp.float32)

    @classmethod
    def masked_apply(cls, params, updates, active):
        """params + updates where active (per-worker mask)."""
        def f(p, u):
            a = cls._bcast(active.astype(jnp.float32), p)
            return p + (a * u.astype(jnp.float32)).astype(p.dtype)
        return jax.tree.map(f, params, updates)


# ---------------------------------------------------------------------------
# gossip peer selection with collision-skip (paper §3.1)
# ---------------------------------------------------------------------------


def choose_peers(rng, M: int, active) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Random peer per active worker; colliding senders are skipped
    ("first" sender by index wins — deterministic stand-in for race winner).

    Returns (send_ok (M,) bool, has_recv (M,) bool, sender_idx (M,) int —
    valid where has_recv)."""
    peers = jax.random.randint(rng, (M,), 0, M - 1)
    me = jnp.arange(M)
    peers = peers + (peers >= me)  # j != i
    contestant = jnp.where(active, me, M)  # inactive never win
    winner = jnp.full((M,), M, jnp.int32).at[peers].min(contestant.astype(jnp.int32))
    send_ok = active & (winner[peers] == me)
    has_recv = winner < M
    sender_idx = jnp.where(has_recv, winner, 0)
    return send_ok, has_recv, sender_idx


def pushsum_weight_update(weights, send_ok, has_recv, sender_idx):
    """w_i ← w_i/2 on send; w_j ← w_j + w_s/2 on receive. Σw conserved."""
    w_old = weights
    w = jnp.where(send_ok, w_old * 0.5, w_old)
    gain = jnp.where(has_recv, w_old[sender_idx] * 0.5, 0.0)
    return w + gain


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_ALGOS: Dict[str, Callable[..., DistAlgorithm]] = {}


def register_algorithm(name: str):
    def deco(fn):
        _ALGOS[name] = fn
        return fn
    return deco


def get_algorithm(name: str, **kw) -> DistAlgorithm:
    _ensure_loaded()
    if name not in _ALGOS:
        raise KeyError(f"unknown algorithm {name!r}; known: {sorted(_ALGOS)}")
    return _ALGOS[name](**kw)


def list_algorithms():
    _ensure_loaded()
    return sorted(_ALGOS)


_LOADED = False


def _ensure_loaded():
    global _LOADED
    if _LOADED:
        return
    import importlib
    for m in ("ddp", "layup", "gosgd", "adpsgd", "localsgd", "slowmo", "co2"):
        importlib.import_module(f"repro.core.{m}")
    _LOADED = True


# ---------------------------------------------------------------------------
# sim trainer
# ---------------------------------------------------------------------------


def consensus(params, weights):
    """Push-sum consensus estimate x̄ = Σ_i w_i x_i / Σ_i w_i.

    The normalization matters when gossip mass is in flight (buffered
    messages carry part of Σw between iterations)."""
    wsum = jnp.maximum(jnp.sum(weights), 1e-12)

    def f(p):
        w = weights.reshape((-1,) + (1,) * (p.ndim - 1)).astype(jnp.float32)
        return jnp.sum(w * p.astype(jnp.float32), axis=0) / wsum
    return jax.tree.map(f, params)


def disagreement(params, weights):
    """Mean over workers of ‖x_i − x̄‖ (the paper's 'model disagreement')."""
    xbar = consensus(params, weights)

    def sq(p, b):
        d = p.astype(jnp.float32) - b[None]
        return jnp.sum(jnp.square(d), axis=tuple(range(1, p.ndim)))

    per_worker = sum(jax.tree.leaves(jax.tree.map(sq, params, xbar)))
    return jnp.mean(jnp.sqrt(per_worker))


def _split_fwd_lane(batch, R: int):
    """Split each worker's batch into R forward slices along the batch dim.

    Slice 0 feeds the backward lane (gradient); slices 1..R-1 are
    forward-only passes — the decoupled forward threads of the paper, which
    process data at R× the update rate."""
    def check(x):
        if x.ndim < 2 or x.shape[1] % R:
            raise ValueError(
                f"fb_ratio={R} needs per-worker batch divisible by {R}; "
                f"got leaf shape {x.shape}")
        return x

    jax.tree.map(check, batch)
    return [jax.tree.map(
        lambda x: x[:, (x.shape[1] // R) * r:(x.shape[1] // R) * (r + 1)],
        batch) for r in range(R)]


def make_sim_trainer(algo: DistAlgorithm, loss_fn: Callable, optimizer: Optimizer,
                     schedule: Callable, M: int,
                     straggler_delays: Optional[np.ndarray] = None,
                     measure_drift: bool = True,
                     fb_ratio: int = 1, update_delay: int = 0):
    """Returns (init_fn, step_fn).

    loss_fn(params, batch) -> (loss, metrics); batch leaves have a leading
    M axis matching params.

    ``fb_ratio=R`` runs R forward passes per backward (forward lane);
    ``update_delay=D`` applies each gradient D iterations after the forward
    that produced it (decoupled backward lane). Metrics gain
    ``layer_staleness`` (G,), ``staleness_mean`` and ``update_staleness``.
    """
    if fb_ratio < 1 or update_delay < 0:
        raise ValueError("fb_ratio must be >= 1 and update_delay >= 0")
    delays = (jnp.zeros((M,), jnp.int32) if straggler_delays is None
              else jnp.asarray(straggler_delays, jnp.int32))
    D, R = int(update_delay), int(fb_ratio)

    def init_fn(rng, params_single) -> TrainState:
        params = jax.tree.map(
            lambda p: jnp.broadcast_to(p[None], (M,) + p.shape), params_single)
        part = LayerPartition(params)
        opt_state = jax.vmap(optimizer.init)(params)
        delay = ()
        if D > 0:
            # FIFO buffers live in the params' dtypes (matching the prod
            # lane's fifo_init) so sim-vs-prod D>0 parity holds for any
            # parameter dtype, not just f32
            delay = {
                "g": jax.tree.map(
                    lambda p: jnp.zeros((D,) + p.shape, p.dtype), params),
                "stamp": jnp.full((D,), -1.0, jnp.float32),
            }
        return TrainState(
            params=params,
            opt_state=opt_state,
            weights=jnp.full((M,), 1.0 / M, jnp.float32),
            extras=algo.init_extras(part.view(params, M=M), M),
            step=jnp.zeros((), jnp.int32),
            versions=part.init_versions(M),
            delay=delay,
        )

    def grad_fn(p, b):
        (loss, aux), g = jax.value_and_grad(loss_fn, has_aux=True)(p, b)
        return g, loss

    @jax.jit
    def step_fn(state: TrainState, batch, rng):
        part = LayerPartition(state.params)
        view = LayerView(part.split(state.params), state.versions, part.names)
        view, weights, extras = algo.pre(view, state.weights, state.extras,
                                         state.step)
        params = part.join(view.groups)
        active = (jnp.mod(state.step, delays + 1) == 0) | (~jnp.bool_(algo.asynchronous))

        # -- forward lane (R slices; slice 0 feeds the backward lane) ---------
        if R > 1:
            slices = _split_fwd_lane(batch, R)
            grads, bwd_loss = jax.vmap(grad_fn)(params, slices[0])
            fwd_losses = [jax.vmap(lambda p, b: loss_fn(p, b)[0])(params, s)
                          for s in slices[1:]]
            losses = (bwd_loss + sum(fwd_losses)) / R
        else:
            grads, losses = jax.vmap(grad_fn)(params, batch)

        # -- backward lane: delay-D gradient FIFO -----------------------------
        delay = state.delay
        if D > 0:
            g_apply = jax.tree.map(lambda b: b[0], delay["g"])
            applied_stamp = delay["stamp"][0]
            delay = {
                "g": jax.tree.map(
                    lambda b, g: jnp.concatenate(
                        [b[1:], g[None].astype(b.dtype)], axis=0),
                    delay["g"], grads),
                "stamp": jnp.concatenate(
                    [delay["stamp"][1:],
                     state.step.astype(jnp.float32)[None]]),
            }
            # warm-up: the FIFO holds zeros for the first D steps
            grads = jax.tree.map(lambda g, p: g.astype(p.dtype),
                                 g_apply, params)
            update_staleness = jnp.where(
                applied_stamp >= 0.0,
                state.step.astype(jnp.float32) - applied_stamp, 0.0)
        else:
            update_staleness = jnp.zeros((), jnp.float32)

        ggrads, extras = algo.transform_grads(part.split(grads), extras)
        grads = part.join(ggrads)
        lr = schedule(state.step)
        updates, opt_state = jax.vmap(
            lambda g, s, p: optimizer.update(g, s, p, lr))(
                grads, state.opt_state, params)
        r1, _ = jax.random.split(rng)
        view = LayerView(part.split(params), view.versions, part.names)
        view, weights, extras, algo_metrics = algo.post(
            view, weights, extras, part.split(updates), active, r1,
            state.step)
        params = part.join(view.groups)
        metrics = {"loss": jnp.mean(losses), "lr": lr,
                   "weight_sum": jnp.sum(weights),
                   "update_staleness": update_staleness,
                   **version_metrics(view.versions, state.step),
                   **algo_metrics}
        if measure_drift:
            metrics["disagreement"] = disagreement(params, weights)
        new_state = TrainState(params=params, opt_state=opt_state,
                               weights=weights, extras=extras,
                               step=state.step + 1,
                               versions=view.versions, delay=delay)
        return new_state, metrics

    return init_fn, step_fn
