"""Distributed-algorithm API + the simulation-backend trainer.

Every algorithm (LayUp and all baselines) is a ``DistAlgorithm`` with four
pure hooks operating on *stacked* parameters — every pytree leaf carries a
leading ``M`` (worker) axis:

  init_extras(params, M)                 → algorithm-private state
  transform_grads(grads, extras)         → grads   (DDP: mean over workers)
  pre(params, weights, extras)           → applied before the forward pass
                                           (e.g. delayed/buffered gossip)
  post(params, weights, extras, updates, active, rng, step)
                                         → applies local updates + mixing

``make_sim_trainer`` wires a model loss, an optimizer, a schedule and an
algorithm into a jitted step. The same stacked representation runs on one
CPU device (vmap) or on a mesh (leading axis sharded over ('pod','data')).

Straggler emulation: ``straggler_delays[i] = d`` makes worker ``i`` perform
its local update + gossip only every ``d+1`` iterations (it still *receives*
peer updates, matching the paper §5.4). Synchronous algorithms ignore the
mask — their straggler cost is wall-clock (see repro.core.simulator).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim.optimizers import Optimizer, apply_updates

# ---------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclass
class TrainState:
    params: Any          # stacked (M, ...) pytree
    opt_state: Any       # stacked
    weights: jnp.ndarray  # (M,) push-sum weights (sum == 1)
    extras: Any          # algorithm-private
    step: jnp.ndarray    # scalar int32


class DistAlgorithm:
    """Base class; subclasses override the hooks they need."""

    name: str = "base"
    asynchronous: bool = False  # respects the straggler active-mask

    def init_extras(self, params, M: int):
        return ()

    def transform_grads(self, grads, extras):
        return grads, extras

    def pre(self, params, weights, extras):
        return params, weights, extras

    def post(self, params, weights, extras, updates, active, rng, step):
        raise NotImplementedError

    # -- shared helpers -------------------------------------------------------
    @staticmethod
    def _bcast(v, leaf):
        """Reshape a per-worker (M,) vector for broadcasting against a leaf."""
        return v.reshape((-1,) + (1,) * (leaf.ndim - 1)).astype(jnp.float32)

    @classmethod
    def masked_apply(cls, params, updates, active):
        """params + updates where active (per-worker mask)."""
        def f(p, u):
            a = cls._bcast(active.astype(jnp.float32), p)
            return p + (a * u.astype(jnp.float32)).astype(p.dtype)
        return jax.tree.map(f, params, updates)


# ---------------------------------------------------------------------------
# gossip peer selection with collision-skip (paper §3.1)
# ---------------------------------------------------------------------------


def choose_peers(rng, M: int, active) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Random peer per active worker; colliding senders are skipped
    ("first" sender by index wins — deterministic stand-in for race winner).

    Returns (send_ok (M,) bool, has_recv (M,) bool, sender_idx (M,) int —
    valid where has_recv)."""
    peers = jax.random.randint(rng, (M,), 0, M - 1)
    me = jnp.arange(M)
    peers = peers + (peers >= me)  # j != i
    contestant = jnp.where(active, me, M)  # inactive never win
    winner = jnp.full((M,), M, jnp.int32).at[peers].min(contestant.astype(jnp.int32))
    send_ok = active & (winner[peers] == me)
    has_recv = winner < M
    sender_idx = jnp.where(has_recv, winner, 0)
    return send_ok, has_recv, sender_idx


def pushsum_weight_update(weights, send_ok, has_recv, sender_idx):
    """w_i ← w_i/2 on send; w_j ← w_j + w_s/2 on receive. Σw conserved."""
    w_old = weights
    w = jnp.where(send_ok, w_old * 0.5, w_old)
    gain = jnp.where(has_recv, w_old[sender_idx] * 0.5, 0.0)
    return w + gain


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_ALGOS: Dict[str, Callable[..., DistAlgorithm]] = {}


def register_algorithm(name: str):
    def deco(fn):
        _ALGOS[name] = fn
        return fn
    return deco


def get_algorithm(name: str, **kw) -> DistAlgorithm:
    _ensure_loaded()
    if name not in _ALGOS:
        raise KeyError(f"unknown algorithm {name!r}; known: {sorted(_ALGOS)}")
    return _ALGOS[name](**kw)


def list_algorithms():
    _ensure_loaded()
    return sorted(_ALGOS)


_LOADED = False


def _ensure_loaded():
    global _LOADED
    if _LOADED:
        return
    import importlib
    for m in ("ddp", "layup", "gosgd", "adpsgd", "localsgd", "slowmo", "co2"):
        importlib.import_module(f"repro.core.{m}")
    _LOADED = True


# ---------------------------------------------------------------------------
# sim trainer
# ---------------------------------------------------------------------------


def consensus(params, weights):
    """Push-sum consensus estimate x̄ = Σ_i w_i x_i / Σ_i w_i.

    The normalization matters when gossip mass is in flight (buffered
    messages carry part of Σw between iterations)."""
    wsum = jnp.maximum(jnp.sum(weights), 1e-12)

    def f(p):
        w = weights.reshape((-1,) + (1,) * (p.ndim - 1)).astype(jnp.float32)
        return jnp.sum(w * p.astype(jnp.float32), axis=0) / wsum
    return jax.tree.map(f, params)


def disagreement(params, weights):
    """Mean over workers of ‖x_i − x̄‖ (the paper's 'model disagreement')."""
    xbar = consensus(params, weights)

    def sq(p, b):
        d = p.astype(jnp.float32) - b[None]
        return jnp.sum(jnp.square(d), axis=tuple(range(1, p.ndim)))

    per_worker = sum(jax.tree.leaves(jax.tree.map(sq, params, xbar)))
    return jnp.mean(jnp.sqrt(per_worker))


def make_sim_trainer(algo: DistAlgorithm, loss_fn: Callable, optimizer: Optimizer,
                     schedule: Callable, M: int,
                     straggler_delays: Optional[np.ndarray] = None,
                     measure_drift: bool = True):
    """Returns (init_fn, step_fn).

    loss_fn(params, batch) -> (loss, metrics); batch leaves have a leading
    M axis matching params.
    """
    delays = (jnp.zeros((M,), jnp.int32) if straggler_delays is None
              else jnp.asarray(straggler_delays, jnp.int32))

    def init_fn(rng, params_single) -> TrainState:
        params = jax.tree.map(
            lambda p: jnp.broadcast_to(p[None], (M,) + p.shape), params_single)
        opt_state = jax.vmap(optimizer.init)(params)
        return TrainState(
            params=params,
            opt_state=opt_state,
            weights=jnp.full((M,), 1.0 / M, jnp.float32),
            extras=algo.init_extras(params, M),
            step=jnp.zeros((), jnp.int32),
        )

    def grad_fn(p, b):
        (loss, aux), g = jax.value_and_grad(loss_fn, has_aux=True)(p, b)
        return g, loss

    @jax.jit
    def step_fn(state: TrainState, batch, rng):
        params, weights, extras = algo.pre(state.params, state.weights,
                                           state.extras)
        active = (jnp.mod(state.step, delays + 1) == 0) | (~jnp.bool_(algo.asynchronous))
        grads, losses = jax.vmap(grad_fn)(params, batch)
        grads, extras = algo.transform_grads(grads, extras)
        lr = schedule(state.step)
        updates, opt_state = jax.vmap(
            lambda g, s, p: optimizer.update(g, s, p, lr))(
                grads, state.opt_state, params)
        r1, _ = jax.random.split(rng)
        params, weights, extras, algo_metrics = algo.post(
            params, weights, extras, updates, active, r1, state.step)
        metrics = {"loss": jnp.mean(losses), "lr": lr,
                   "weight_sum": jnp.sum(weights), **algo_metrics}
        if measure_drift:
            metrics["disagreement"] = disagreement(params, weights)
        new_state = TrainState(params=params, opt_state=opt_state,
                               weights=weights, extras=extras,
                               step=state.step + 1)
        return new_state, metrics

    return init_fn, step_fn
