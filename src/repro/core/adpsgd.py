"""AD-PSGD baseline (Lian et al., 2018).

Asynchronous decentralized SGD with *symmetric* pairwise averaging: at each
iteration workers form a random matching and each matched pair averages
parameters atomically, then applies local gradients. Symmetric exchange
doubles communication volume vs push-sum gossip (paper §2) but needs no
push-sum weights (mass is conserved by construction).

Version clocks: the averaged partner state is the partner's
*start-of-iteration* parameters (its iteration-``step`` update is applied
locally after the average, not shipped), i.e. content generated at the end
of iteration ``step − 1`` → matched workers stamp every layer group with
``step`` (whole-model exchange — no layer granularity, unlike LayUp).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.api import DistAlgorithm, register_algorithm
from repro.core.layerview import LayerView, stamp_groups


def random_matching(rng, M: int) -> jnp.ndarray:
    """Partner index per worker (involution; odd one out maps to itself)."""
    perm = jax.random.permutation(rng, M)
    partner_of_perm = jnp.arange(M) + jnp.where(jnp.arange(M) % 2 == 0, 1, -1)
    partner_of_perm = jnp.where(partner_of_perm >= M, jnp.arange(M),
                                partner_of_perm)
    partner = jnp.zeros((M,), jnp.int32).at[perm].set(perm[partner_of_perm])
    return partner


class ADPSGD(DistAlgorithm):
    name = "adpsgd"
    asynchronous = True

    def post(self, view: LayerView, weights, extras, updates, active, rng,
             step):
        M = weights.shape[0]
        partner = random_matching(rng, M)
        # pairs average only if both endpoints are willing (active receiver is
        # fine; stragglers still participate in averaging — they're passive)
        def avg_then_update(p, u):
            pf = p.astype(jnp.float32)
            mixed = 0.5 * (pf + pf[partner])
            a = self._bcast(active.astype(jnp.float32), p)
            return (mixed + a * u.astype(jnp.float32)).astype(p.dtype)

        new_groups = jax.tree.map(avg_then_update, view.groups, updates)
        matched = partner != jnp.arange(M)
        versions = stamp_groups(view.versions,
                                jnp.asarray(step, jnp.float32),
                                worker_mask=matched)
        return (view.with_groups(new_groups).with_versions(versions),
                weights, extras, {
                    "pairs": jnp.sum(matched.astype(jnp.float32)) / 2})


@register_algorithm("adpsgd")
def _adpsgd():
    return ADPSGD()
