"""Event-driven wall-clock simulator for the distributed algorithms.

The container has one CPU device, so the paper's *timing* claims (TTC, MFU,
straggler robustness — Tables 1–4, Fig. 3) cannot be measured directly.
This simulator models the schedule each algorithm induces:

  worker i, iteration k:  fwd (F_i) → bwd (B_i) → algorithm-specific comm
  * DDP        — global barrier after bwd, then ring all-reduce
                 (2·(M−1)/M · P bytes at bus bandwidth).
  * LocalSGD / SlowMo — barrier + all-reduce every H iterations only.
  * CO2        — barrier every H iterations, all-reduce *overlapped* (hidden
                 unless it exceeds H·(F+B) of compute).
  * GoSGD      — no barrier; full-model push (P bytes) on the sender NIC
                 after bwd; stalls only if the previous send is in flight.
  * AD-PSGD    — no barrier, but symmetric pairwise averaging (2·P bytes)
                 requires rendezvous with a random partner → a straggler
                 delays whoever draws it.
  * LayUp      — no barrier; layer-wise sends start DURING bwd (layer ℓ's
                 message enters the NIC when its gradient is ready), so
                 communication hides behind the remaining backward compute.

The machinery is an incremental :class:`EventSimulator` — one ``step()``
per update iteration — exposing the same per-iteration cadence as the
numeric sim trainer so both run behind the ``TrainerBackend`` protocol
(repro.core.backend, DESIGN.md §7). ``simulate`` is the batch wrapper.

**Decoupled thread lanes** (the paper's PD-ASGD mechanism, DESIGN.md §3):
``fb_ratio=R`` / ``update_delay=D`` switch the async gossip algorithms to
two per-worker lanes — a forward lane running R forward passes per update
and a backward lane consuming the activations of the forward from D updates
ago. Compute never stalls on the NIC or on update locks (messages queue;
updates land late instead), so utilization pins at the kernel ceiling while
the forward lane serves samples at R× the update rate — this is what makes
the paper's R > 1 throughput and MFU claims simulable.

Stragglers: worker i's compute is scaled by (1 + delay_i) — the paper's
"idle for a multiple of one fwd+bwd" injection (§5.4).

Outputs per algorithm: wall-clock for N iterations, compute utilization
(busy/total), and MFU = utilization × kernel_mfu (the achievable MFU of the
pure compute kernels) — reproducing the structure of paper Table 4/Fig. 3B.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

import numpy as np

SYNC_ALGOS = ("ddp", "localsgd", "slowmo", "co2")
GOSSIP_ALGOS = ("gosgd", "layup", "layup-block", "layup-hypercube", "adpsgd")
LAYERWISE_ALGOS = ("layup", "layup-hypercube")


@dataclass
class HardwareModel:
    fwd_time: float = 1.0          # seconds per fwd pass (per worker)
    bwd_ratio: float = 2.0         # bwd = ratio * fwd (paper Table A4: ~2x)
    num_layers: int = 24
    model_bytes: float = 1.6e9     # fp32 GPT-2 medium ≈ 1.6 GB
    bandwidth: float = 25e9        # bytes/s per link (NVLink-ish)
    allreduce_bandwidth: float = 100e9  # bus bandwidth for ring all-reduce
    kernel_mfu: float = 0.75       # MFU of the pure compute kernels

    @property
    def bwd_time(self):
        return self.fwd_time * self.bwd_ratio

    @property
    def iter_compute(self):
        return self.fwd_time + self.bwd_time


@dataclass
class SimResult:
    total_time: float
    compute_time: float   # mean per-worker busy compute time
    utilization: float
    mfu: float
    iter_times: np.ndarray = field(repr=False, default=None)
    updates_per_s: float = 0.0
    fwd_passes_per_s: float = 0.0
    mean_grad_staleness: float = 0.0  # decoupled: activation age in seconds


def _mfu(hw: HardwareModel, compute: float, total: float) -> float:
    return hw.kernel_mfu * compute / max(total, 1e-12)


class EventSimulator:
    """Incremental per-iteration event simulator.

    ``step()`` advances every worker by one update iteration and returns the
    iteration's timing metrics; ``result()`` aggregates into a
    :class:`SimResult`. The batch helper :func:`simulate` preserves the
    original closed-form numbers for the synchronous algorithms and the
    NIC-serialized loop for the gossip family.
    """

    def __init__(self, algo: str, *, M: int, hw: HardwareModel,
                 straggler_delays: Optional[np.ndarray] = None,
                 sync_every: int = 8, seed: int = 0,
                 fb_ratio: int = 1, update_delay: int = 0):
        if algo not in SYNC_ALGOS + GOSSIP_ALGOS:
            raise ValueError(f"unknown algo {algo}")
        self.decoupled = fb_ratio > 1 or update_delay > 0
        if self.decoupled and algo not in GOSSIP_ALGOS:
            raise ValueError(
                "decoupled execution (fb_ratio > 1 / update_delay > 0) "
                f"requires an asynchronous gossip algorithm, not {algo!r}")
        if algo == "adpsgd" and self.decoupled:
            raise ValueError("adpsgd's rendezvous semantics do not admit "
                             "decoupled forward/backward lanes")
        self.algo = algo
        self.M = M
        self.hw = hw
        self.H = sync_every
        self.R = int(fb_ratio)
        self.D = int(update_delay)
        delays = (np.zeros(M) if straggler_delays is None
                  else np.asarray(straggler_delays, float))
        slow = 1.0 + delays
        self.F = hw.fwd_time * slow               # (M,)
        self.B = hw.bwd_time * slow
        self.rng = np.random.default_rng(seed)
        self.send_t = hw.model_bytes / hw.bandwidth
        self.ar = 2 * (M - 1) / M * hw.model_bytes / hw.allreduce_bandwidth

        self.k = 0
        self.clock = np.zeros(M)                  # worker-ready time
        self.nic_free = np.zeros(M)               # sender NIC availability
        self.busy = np.zeros(M)                   # per-worker busy compute
        self.fwd_busy = np.zeros(M)               # forward-lane busy time
        self.bwd_busy = np.zeros(M)               # backward-lane busy time
        self.sync_elapsed = 0.0                   # sync algos: scalar clock
        self.it_times: list = []
        # decoupled: forward-completion ring (per worker) for delay D
        self._fwd_done = np.zeros((max(self.D, 1), M))
        self._stale_sum = 0.0

    # -- per-family iteration bodies ----------------------------------------

    def _step_sync(self) -> float:
        F, B, M = self.F, self.B, self.M
        maxFB = (F + B).max()
        self.busy += F + B
        if self.algo == "ddp":
            dt = maxFB + self.ar
        elif self.algo in ("localsgd", "slowmo"):
            dt = maxFB + (self.ar if (self.k + 1) % self.H == 0 else 0.0)
        else:  # co2: all-reduce overlapped, pays only when comm-bound
            dt = maxFB
            if (self.k + 1) % self.H == 0:
                dt += max(0.0, self.ar - self.H * maxFB)
        self.sync_elapsed += dt
        self.clock[:] = self.sync_elapsed
        return dt

    def _step_adpsgd(self) -> float:
        start = self.clock.copy()
        end = start + self.F + self.B
        perm = self.rng.permutation(self.M)
        for a in range(0, self.M - 1, 2):
            i, j = perm[a], perm[a + 1]
            t = max(end[i], end[j]) + 2 * self.send_t
            end[i] = end[j] = t
        self.busy += self.F + self.B
        self.clock = end
        return self.clock.max() - start.max()

    def _step_gossip_coupled(self) -> float:
        start = self.clock.copy()
        comp_end = start + self.F + self.B
        if self.algo in LAYERWISE_ALGOS:
            # layer-wise: message enters the NIC as each layer's grad is
            # ready; the NIC drains P bytes starting after the first layer's
            # gradient (fwd + bwd/L into the iteration)
            first_grad = start + self.F + self.B / self.hw.num_layers
            nic_done = np.maximum(self.nic_free, first_grad) + self.send_t
        else:  # gosgd / layup-block: whole model sent after bwd
            nic_done = np.maximum(self.nic_free, comp_end) + self.send_t
        self.nic_free = nic_done
        # next iteration may start when compute is done AND the NIC backlog
        # is < one message (otherwise buffering would grow)
        self.clock = np.maximum(comp_end, nic_done - self.send_t)
        self.busy += self.F + self.B
        return self.clock.max() - start.max()

    def _step_gossip_decoupled(self) -> float:
        """Two lanes per worker on one compute engine: R forwards then one
        backward, back to back — compute never waits on the NIC (messages
        queue) or on update locks (updates land D iterations late)."""
        start = self.clock.copy()
        fwd_end = start + self.R * self.F
        self.fwd_busy += self.R * self.F
        # backward consumes the forward from D updates ago (already complete
        # by construction — the forward lane runs ahead)
        if self.D and self.k >= self.D:
            src = self._fwd_done[self.k % self.D]
        else:  # warm-up: the FIFO has not wrapped yet
            src = fwd_end
        self._stale_sum += float(np.mean(np.maximum(fwd_end - src, 0.0)))
        bwd_end = fwd_end + self.B
        self.bwd_busy += self.B
        self._fwd_done[self.k % max(self.D, 1)] = fwd_end
        if self.algo in LAYERWISE_ALGOS:
            first_grad = fwd_end + self.B / self.hw.num_layers
            self.nic_free = np.maximum(self.nic_free, first_grad) + self.send_t
        else:
            self.nic_free = np.maximum(self.nic_free, bwd_end) + self.send_t
        self.clock = bwd_end
        self.busy += self.R * self.F + self.B
        return self.clock.max() - start.max()

    # -- public API ----------------------------------------------------------

    def step(self) -> Dict[str, float]:
        if self.algo in SYNC_ALGOS:
            dt = self._step_sync()
        elif self.algo == "adpsgd":
            dt = self._step_adpsgd()
        elif self.decoupled:
            dt = self._step_gossip_decoupled()
        else:
            dt = self._step_gossip_coupled()
        self.k += 1
        self.it_times.append(dt)
        total, comp, util = self._totals()
        return {"iter_time": dt, "total_time": total,
                "utilization": util, "mfu": _mfu(self.hw, comp, total),
                "updates_per_s": self.k / total,
                "fwd_passes_per_s": self.R * self.k / total}

    def _totals(self):
        """(total, comp, util) — O(M) scalars, no history copies."""
        comp = self.busy.mean()
        if self.algo in SYNC_ALGOS:
            total = self.sync_elapsed
            util = comp / max(total, 1e-12)
        elif self.algo == "adpsgd":
            total = self.clock.max()
            util = comp / max(total, 1e-12)
        else:
            # async gossip finishes when the collective work target is met;
            # the slow worker contributes fewer iterations (others are never
            # blocked). Completion = median worker timeline.
            total = float(np.median(self.clock))
            util = comp / min(total if total > 0 else 1,
                              max(self.clock.max(), 1e-12))
        return max(total, 1e-12), comp, util

    def result(self) -> SimResult:
        iters = max(self.k, 1)
        total, comp, util = self._totals()
        return SimResult(
            total, comp, util, _mfu(self.hw, comp, total),
            np.asarray(self.it_times),
            updates_per_s=iters / total,
            fwd_passes_per_s=self.R * iters / total,
            mean_grad_staleness=self._stale_sum / iters if self.decoupled
            else 0.0)


def simulate(algo: str, *, M: int, iters: int, hw: HardwareModel,
             straggler_delays: Optional[np.ndarray] = None,
             sync_every: int = 8, seed: int = 0,
             fb_ratio: int = 1, update_delay: int = 0) -> SimResult:
    sim = EventSimulator(algo, M=M, hw=hw, straggler_delays=straggler_delays,
                         sync_every=sync_every, seed=seed, fb_ratio=fb_ratio,
                         update_delay=update_delay)
    for _ in range(iters):
        sim.step()
    return sim.result()


def straggler_sweep(algos, *, M: int, iters: int, hw: HardwareModel,
                    delays=(0, 1, 2, 4, 8), seed: int = 0) -> Dict[str, list]:
    """Paper Fig. 3B: training time as a function of straggler delay."""
    out: Dict[str, list] = {a: [] for a in algos}
    for d in delays:
        dl = np.zeros(M)
        dl[0] = d
        for a in algos:
            out[a].append(simulate(a, M=M, iters=iters, hw=hw,
                                   straggler_delays=dl, seed=seed).total_time)
    return out
