"""Event-driven wall-clock simulator for the distributed algorithms.

The container has one CPU device, so the paper's *timing* claims (TTC, MFU,
straggler robustness — Tables 1–4, Fig. 3) cannot be measured directly.
This simulator models the schedule each algorithm induces:

  worker i, iteration k:  fwd (F_i) → bwd (B_i) → algorithm-specific comm
  * DDP        — global barrier after bwd, then ring all-reduce
                 (2·(M−1)/M · P bytes at bus bandwidth).
  * LocalSGD / SlowMo — barrier + all-reduce every H iterations only.
  * CO2        — barrier every H iterations, all-reduce *overlapped* (hidden
                 unless it exceeds H·(F+B) of compute).
  * GoSGD      — no barrier; full-model push (P bytes) on the sender NIC
                 after bwd; stalls only if the previous send is in flight.
  * AD-PSGD    — no barrier, but symmetric pairwise averaging (2·P bytes)
                 requires rendezvous with a random partner → a straggler
                 delays whoever draws it.
  * LayUp      — no barrier; layer-wise sends start DURING bwd (layer ℓ's
                 message enters the NIC when its gradient is ready), so
                 communication hides behind the remaining backward compute.

Stragglers: worker i's compute is scaled by (1 + delay_i) — the paper's
"idle for a multiple of one fwd+bwd" injection (§5.4).

Outputs per algorithm: wall-clock for N iterations, compute utilization
(busy/total), and MFU = utilization × kernel_mfu (the achievable MFU of the
pure compute kernels) — reproducing the structure of paper Table 4/Fig. 3B.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

import numpy as np


@dataclass
class HardwareModel:
    fwd_time: float = 1.0          # seconds per fwd pass (per worker)
    bwd_ratio: float = 2.0         # bwd = ratio * fwd (paper Table A4: ~2x)
    num_layers: int = 24
    model_bytes: float = 1.6e9     # fp32 GPT-2 medium ≈ 1.6 GB
    bandwidth: float = 25e9        # bytes/s per link (NVLink-ish)
    allreduce_bandwidth: float = 100e9  # bus bandwidth for ring all-reduce
    kernel_mfu: float = 0.75       # MFU of the pure compute kernels

    @property
    def bwd_time(self):
        return self.fwd_time * self.bwd_ratio

    @property
    def iter_compute(self):
        return self.fwd_time + self.bwd_time


@dataclass
class SimResult:
    total_time: float
    compute_time: float   # mean per-worker busy compute time
    utilization: float
    mfu: float
    iter_times: np.ndarray = field(repr=False, default=None)


def _mfu(hw: HardwareModel, compute: float, total: float) -> float:
    return hw.kernel_mfu * compute / max(total, 1e-12)


def simulate(algo: str, *, M: int, iters: int, hw: HardwareModel,
             straggler_delays: Optional[np.ndarray] = None,
             sync_every: int = 8, seed: int = 0) -> SimResult:
    delays = np.zeros(M) if straggler_delays is None else np.asarray(
        straggler_delays, float)
    slow = 1.0 + delays                      # per-worker compute multiplier
    F = hw.fwd_time * slow                   # (M,)
    B = hw.bwd_time * slow
    rng = np.random.default_rng(seed)

    if algo == "ddp":
        ar = 2 * (M - 1) / M * hw.model_bytes / hw.allreduce_bandwidth
        iter_time = (F + B).max() + ar
        total = iters * iter_time
        comp = iters * (F + B).mean()
        return SimResult(total, comp, comp / total, _mfu(hw, comp, total),
                         np.full(iters, iter_time))

    if algo in ("localsgd", "slowmo"):
        ar = 2 * (M - 1) / M * hw.model_bytes / hw.allreduce_bandwidth
        n_sync = iters // sync_every
        # between syncs workers run freely; every sync waits for the slowest
        block = sync_every * (F + B).max() + ar
        total = n_sync * block + (iters - n_sync * sync_every) * (F + B).max()
        comp = iters * (F + B).mean()
        return SimResult(total, comp, comp / total, _mfu(hw, comp, total))

    if algo == "co2":
        # same barriers, but the all-reduce is overlapped with the next block
        block_comm = 2 * (M - 1) / M * hw.model_bytes / hw.allreduce_bandwidth
        n_sync = iters // sync_every
        block_compute = sync_every * (F + B).max()
        block = max(block_compute, block_comm)  # hidden unless comm-bound
        total = n_sync * block + (iters - n_sync * sync_every) * (F + B).max()
        comp = iters * (F + B).mean()
        return SimResult(total, comp, comp / total, _mfu(hw, comp, total))

    if algo in ("gosgd", "layup", "layup-block", "adpsgd"):
        send_t = hw.model_bytes / hw.bandwidth
        clock = np.zeros(M)          # worker-ready time
        nic_free = np.zeros(M)       # sender NIC availability
        busy = np.zeros(M)
        it_times = np.zeros(iters)
        for k in range(iters):
            start = clock.copy()
            if algo == "adpsgd":
                # rendezvous: random matching; pair advances together, 2x volume
                perm = rng.permutation(M)
                end = start + F + B
                for a in range(0, M - 1, 2):
                    i, j = perm[a], perm[a + 1]
                    t = max(end[i], end[j]) + 2 * send_t
                    end[i] = end[j] = t
                busy += F + B
                clock = end
            else:
                comp_end = start + F + B
                if algo == "layup":
                    # layer-wise: message enters the NIC as each layer's grad
                    # is ready; the NIC drains P bytes starting after the
                    # first layer's gradient (fwd + bwd/L into the iteration)
                    first_grad = start + F + B / hw.num_layers
                    nic_done = np.maximum(nic_free, first_grad) + send_t
                else:  # gosgd / layup-block: whole model sent after bwd
                    nic_done = np.maximum(nic_free, comp_end) + send_t
                nic_free = nic_done
                # next iteration may start when compute is done AND the NIC
                # backlog is < one message (otherwise buffering would grow)
                clock = np.maximum(comp_end, nic_done - send_t)
                busy += F + B
            it_times[k] = clock.max() - start.max()
        # async methods finish when the collective work target is met; the
        # slow worker contributes fewer iterations (others are never blocked,
        # except AD-PSGD rendezvous). Completion = median worker timeline.
        if algo == "adpsgd":
            total = clock.max()
        else:
            total = np.median(clock)
        comp = busy.mean()
        return SimResult(total, comp, comp / min(total if total > 0 else 1, clock.max()),
                         _mfu(hw, comp, total), it_times)

    raise ValueError(f"unknown algo {algo}")


def straggler_sweep(algos, *, M: int, iters: int, hw: HardwareModel,
                    delays=(0, 1, 2, 4, 8), seed: int = 0) -> Dict[str, list]:
    """Paper Fig. 3B: training time as a function of straggler delay."""
    out: Dict[str, list] = {a: [] for a in algos}
    for d in delays:
        dl = np.zeros(M)
        dl[0] = d
        for a in algos:
            out[a].append(simulate(a, M=M, iters=iters, hw=hw,
                                   straggler_delays=dl, seed=seed).total_time)
    return out
