"""TrainerBackend — one protocol over the repo's three execution backends.

The repo trains through three engines that historically had disjoint APIs:

* the **jitted sim trainer** (``repro.core.api.make_sim_trainer``) — real
  numerics, vmapped M workers on one device; produces losses, drift and
  staleness metrics;
* the **event-driven simulator** (``repro.core.simulator``) — no numerics,
  models the wall-clock schedule (barriers, NIC serialization, decoupled
  lanes); produces iteration times, utilization and MFU;
* the **production decoupled lane** (``repro.launch.train``) — real
  numerics through the shard_map path on an actual device mesh: one worker
  per ('pod','data') mesh cell, double-buffered parameters, D-deep gradient
  FIFO, per-layer-group ring gossip (DESIGN.md §9). The lane the paper
  actually ships.

All three sit behind the :class:`TrainerBackend` protocol (DESIGN.md §7):
``init(rng, params) → state`` then ``step(state, batch, rng) →
(state, metrics)`` once per update iteration, plus a ``summary()`` of
run-level aggregates. Benchmarks and examples drive any of them — or
several in lock-step, joining numeric metrics with modeled wall-clock,
which is how the paper's metric-vs-time plots are produced
(``benchmarks/algo_runner``).

``make_backend`` is the single entry point::

    be = make_backend("sim", "layup", M=8, loss_fn=..., optimizer=...,
                      schedule=..., fb_ratio=2, update_delay=1)
    ev = make_backend("event", "layup", M=8, hw=HardwareModel(),
                      fb_ratio=2, update_delay=1)
    pr = make_backend("prod", "layup", M=8, loss_fn=..., optimizer=...,
                      schedule=..., fb_ratio=2, update_delay=1)

The prod backend needs M local devices on the worker axis (set
``XLA_FLAGS=--xla_force_host_platform_device_count=M`` before jax init to
fake them on CPU); it consumes the same sim-layout batches (leading (M,)
worker axis) as the sim backend, so the two are drop-in interchangeable.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Protocol, Tuple, runtime_checkable

import numpy as np

from repro.core.api import DistAlgorithm, get_algorithm, make_sim_trainer
from repro.core.simulator import EventSimulator, HardwareModel, SimResult

# event-time model for algorithms whose numeric semantics differ from their
# schedule: block-mode LayUp times like GoSGD, hypercube like LayUp
_EVENT_ALIAS = {"layup-block": "gosgd", "layup-hypercube": "layup"}

# the metric keys every numeric backend (sim and prod) surfaces in summary()
_NUMERIC_SUMMARY_KEYS = ("loss", "disagreement", "staleness_mean",
                         "update_staleness", "weight_sum",
                         "nonfinite_skips", "peers_live")


def _numeric_summary(steps: int, last: Dict[str, Any]) -> Dict[str, float]:
    out = {"steps": float(steps)}
    for k in _NUMERIC_SUMMARY_KEYS:
        if k in last:
            out[k] = float(last[k])
    return out


@runtime_checkable
class TrainerBackend(Protocol):
    """One update iteration at a time, identically for both engines."""

    name: str
    kind: str  # "sim" (numeric) or "event" (wall-clock)

    def init(self, rng, params_single) -> Any: ...

    def step(self, state, batch, rng) -> Tuple[Any, Dict[str, Any]]: ...

    def summary(self) -> Dict[str, float]: ...


class SimTrainerBackend:
    """Numeric backend: wraps the jitted sim trainer."""

    kind = "sim"

    def __init__(self, algo, loss_fn: Callable, optimizer, schedule,
                 M: int, *, straggler_delays=None, measure_drift: bool = True,
                 fb_ratio: int = 1, update_delay: int = 0):
        if isinstance(algo, str):
            algo = get_algorithm(algo)
        self.algo: DistAlgorithm = algo
        self.name = f"sim:{algo.name}"
        self.M = M
        self._init_fn, self._step_fn = make_sim_trainer(
            algo, loss_fn, optimizer, schedule, M,
            straggler_delays=straggler_delays, measure_drift=measure_drift,
            fb_ratio=fb_ratio, update_delay=update_delay)
        self._steps = 0
        self._last: Dict[str, Any] = {}

    def init(self, rng, params_single):
        return self._init_fn(rng, params_single)

    def step(self, state, batch, rng):
        state, metrics = self._step_fn(state, batch, rng)
        self._steps += 1
        self._last = metrics
        return state, metrics

    def summary(self) -> Dict[str, float]:
        return _numeric_summary(self._steps, self._last)


class EventSimBackend:
    """Wall-clock backend: wraps the event-driven simulator.

    ``init`` ignores the params (no numerics) and returns the simulator as
    the state; ``step`` ignores the batch and advances the event clock by
    one update iteration."""

    kind = "event"

    def __init__(self, algo, M: int, *, hw: Optional[HardwareModel] = None,
                 straggler_delays=None, sync_every: int = 8, seed: int = 0,
                 fb_ratio: int = 1, update_delay: int = 0):
        algo_name = algo.name if isinstance(algo, DistAlgorithm) else str(algo)
        self.name = f"event:{algo_name}"
        self.M = M
        self._kw = dict(
            M=M, hw=hw or HardwareModel(), straggler_delays=straggler_delays,
            sync_every=sync_every, seed=seed, fb_ratio=fb_ratio,
            update_delay=update_delay)
        self._event_algo = _EVENT_ALIAS.get(algo_name, algo_name)
        self._sim: Optional[EventSimulator] = None
        # validate eagerly so misconfiguration fails at build, not step time
        EventSimulator(self._event_algo, **self._kw)

    def init(self, rng, params_single=None):
        self._sim = EventSimulator(self._event_algo, **self._kw)
        return self._sim

    def step(self, state: EventSimulator, batch=None, rng=None):
        return state, state.step()

    def result(self) -> SimResult:
        if self._sim is None:
            raise RuntimeError("call init() before result()")
        return self._sim.result()

    def summary(self) -> Dict[str, float]:
        r = self.result()
        return {"steps": float(r.iter_times.size),
                "total_time": r.total_time, "utilization": r.utilization,
                "mfu": r.mfu, "updates_per_s": r.updates_per_s,
                "fwd_passes_per_s": r.fwd_passes_per_s,
                "mean_grad_staleness": r.mean_grad_staleness}


class ProdTrainerBackend:
    """Production backend: the decoupled shard_map lane on a real mesh.

    Runs the same numerics as the mesh step builders in
    ``repro.launch.train`` — double-buffered parameters, D-deep gradient
    FIFO, per-layer-group push-sum ring gossip — behind the one-step-per-
    iteration protocol. Only the layup family is implementable here (the
    ring IS the layup gossip; barrier algorithms have no decoupled prod
    lane). Batches use the sim layout (leading (M,) worker axis).

    ``mesh`` defaults to an (M, 1) ('data', 'model') mesh over the local
    devices; pass an explicit mesh to add tensor parallelism. The per-step
    gossip shift is drawn from ``shifts`` by a HOST-side numpy generator
    seeded at init (deterministic per run, identical across the monolithic
    and overlap paths); the protocol's per-step ``rng`` argument is NOT
    used by this backend — a device-side draw would be a device-0
    computation whose reshard serializes the pipeline engine's dispatch.

    ``overlap=True`` swaps the monolithic jitted step for the stage-graph
    pipeline engine (``repro.launch.pipeline``): the same lanes compiled
    into separately jitted fwd-slice / bwd+update / gossip stages that the
    host dispatches asynchronously, recording per-stage dispatch/complete
    timestamps on ``self.timeline``. Numerics are identical (the monolithic
    path is the oracle); ``summary()`` gains the measured overlap fields.

    ``publisher`` (a :class:`repro.serving.PlanePublisher`) turns the
    backend into the training side of the train-and-serve subsystem
    (DESIGN.md §12): every step's read plane + version clocks + drift are
    published for live serving consumers — zero-copy on the overlap
    engine (its read plane is never donated), stabilized by async device
    copies on the monolithic step (which donates its state).

    ``wire="int8"`` ships the gossip plane as int8 + per-row f32 scales
    with error-feedback residuals (about half the bf16 wire bytes);
    ``compensate=λ > 0`` applies the staleness-aware delay correction
    ``g + λ·g⊙g⊙(θ_now − θ_stale)`` in the update lane (DESIGN.md §14).
    Both require ``flat=True``; ``summary()`` reports ``wire_dtype`` and
    ``wire_bytes_per_round``.

    ``faults`` (a :class:`repro.chaos.FaultPlan` or spec string, DESIGN.md
    §15) turns on the fault-tolerant membership lane: the state gains the
    per-peer ``alive`` mask, every push-sum exchange is alive-gated, and a
    :class:`repro.chaos.ChaosController` replays the plan at each host
    step boundary — crash/hang/nan/corrupt/drop/recover. The empty plan
    (``faults=""``) enables the machinery without injecting anything and
    is bit-exact with ``faults=None``; ``summary()`` merges the
    controller's fault accounting (``faults_injected``,
    ``rounds_degraded``, ``peers_dead``, ``resyncs``, ...)."""

    kind = "prod"

    def __init__(self, algo, loss_fn: Callable, optimizer, schedule,
                 M: int, *, mesh=None, shifts=(1, 2, 4, 8),
                 fb_ratio: int = 1, update_delay: int = 0,
                 straggler_delays=None, measure_drift: bool = True,
                 overlap: bool = False, flat: bool = True,
                 use_pallas: bool = False, publisher=None,
                 streams: int = 1, wire: str = "param",
                 compensate: float = 0.0, faults=None,
                 max_inflight_steps=None, tuning=None):
        import jax
        from repro.launch.mesh import num_workers
        from repro.launch.train import make_decoupled_backend_trainer

        # a tuning record (launch/tuner.py, DESIGN.md §16) replaces the
        # hand-picked schedule defaults; kwargs the caller moved off their
        # defaults always win, and a failed load warns and changes nothing
        self.tuning = None
        if tuning is not None:
            from repro.launch.tuner import apply_tuning, resolve_tuning
            record = resolve_tuning(tuning)
            if record is not None:
                tuned = apply_tuning(record, fb_ratio=fb_ratio,
                                     update_delay=update_delay, flat=flat,
                                     max_inflight_steps=max_inflight_steps)
                fb_ratio = tuned["fb_ratio"]
                update_delay = tuned["update_delay"]
                flat = tuned["flat"]
                max_inflight_steps = tuned["max_inflight_steps"]
                overlap = True
                self.tuning = record

        algo_name = algo.name if isinstance(algo, DistAlgorithm) else str(algo)
        if not algo_name.startswith("layup"):
            raise ValueError(
                f"prod backend implements the layup family only, not "
                f"{algo_name!r} (the gossip ring is the algorithm)")
        self.name = f"prod:{algo_name}"
        if mesh is None:
            devs = jax.devices()
            if len(devs) < M:
                raise ValueError(
                    f"prod backend needs {M} devices for {M} workers; "
                    f"found {len(devs)} (set XLA_FLAGS="
                    f"--xla_force_host_platform_device_count={M})")
            mesh = jax.make_mesh((M, 1), ("data", "model"),
                                 devices=devs[:M])
        if num_workers(mesh) != M:
            raise ValueError(
                f"mesh worker axes give {num_workers(mesh)} workers, "
                f"expected M={M}")
        self.M = M
        self.mesh = mesh
        self.overlap = bool(overlap)
        self.flat = bool(flat)
        self.streams = int(streams)
        self.publisher = publisher
        self.wire = str(wire)
        self.compensate = float(compensate)
        self.update_delay = int(update_delay)
        self.membership = faults is not None
        self._faults = faults
        self.chaos = None
        self._nonfinite_total = 0.0
        if self.membership:
            # build eagerly so a malformed plan fails here, not at step;
            # init() rebuilds a fresh controller per run
            from repro.chaos import ChaosController
            self.chaos = ChaosController(
                faults, M, update_delay=self.update_delay, wire=self.wire,
                compensate=self.compensate)
        membership = self.membership
        if streams > 1 and not overlap:
            raise ValueError("streams > 1 is a property of the stage-graph "
                             "pipeline; it requires overlap=True")
        if overlap:
            from repro.launch.pipeline import (StageTimeline,
                                               make_pipeline_backend_trainer)
            self.timeline = StageTimeline()
            self._init_fn, self._step_fn, self._shifts, self._engine_box = \
                make_pipeline_backend_trainer(
                    loss_fn, optimizer, schedule, mesh, shifts=shifts,
                    fb_ratio=fb_ratio, update_delay=update_delay,
                    straggler_delays=straggler_delays,
                    measure_drift=measure_drift, timeline=self.timeline,
                    flat=flat, use_pallas=use_pallas, publisher=publisher,
                    streams=streams, wire=wire, compensate=compensate,
                    membership=membership,
                    max_inflight_steps=max_inflight_steps)
        else:
            self.timeline = None
            self._init_fn, self._step_fn, self._shifts, self._engine_box = \
                make_decoupled_backend_trainer(
                    loss_fn, optimizer, schedule, mesh, shifts=shifts,
                    fb_ratio=fb_ratio, update_delay=update_delay,
                    straggler_delays=straggler_delays,
                    measure_drift=measure_drift, flat=flat,
                    use_pallas=use_pallas, publisher=publisher,
                    wire=wire, compensate=compensate,
                    membership=membership)
        self._steps = 0
        self._last: Dict[str, Any] = {}
        # host-side gossip-shift schedule: deterministic per backend, no
        # per-step device RNG (a jax.random draw is a device-0 computation
        # whose reshard would serialize the pipeline engine's dispatch)
        self._shift_rng = np.random.default_rng(0xC0FFEE)

    @property
    def engine(self):
        """The PipelineEngine (overlap=True, after init); else None."""
        return self._engine_box.get("engine")

    @property
    def part(self):
        """The FlatPartition fixing the state's plane layout (after init)
        — the unpack key serving consumers need (``repro.serving``)."""
        return self._engine_box.get("part")

    def export_params(self, state):
        """Stacked ``(M, ...)`` parameter TREE view of the state's read
        buffer — unpacks the persistent flat plane (DESIGN.md §11);
        identity on the legacy ``flat=False`` tree state. The handle for
        anything that consumes parameters structurally: eval/consensus
        snapshots (benchmarks/algo_runner) and checkpoint export."""
        if not self.flat:
            return state["read"]
        part = self._engine_box.get("part")
        if part is None:
            raise RuntimeError("call init() before export_params()")
        read = state["read"]
        if self.streams > 1:
            # stream-engine state leaves are TaskOutput futures
            read = self.engine.materialize(read)
        return part.unpack(read)

    def init(self, rng, params_single):
        self._steps = 0
        self._shift_rng = np.random.default_rng(0xC0FFEE)
        if self.engine is not None:
            # re-init measures a fresh run: stale events would collide in
            # the overlap accounting's event index
            self.engine.reset()
        elif self.timeline is not None:  # overlap=True, first init
            self.timeline.reset()
        state = self._init_fn(rng, params_single)
        if self.membership:
            # fresh controller per run (fault replay + health state are
            # per-run); hook it to the engine so host mutations can
            # materialize stream futures, and to the SignalBoard so the
            # liveness beats land where deadline-guarded waits look
            from repro.chaos import ChaosController
            self.chaos = ChaosController(
                self._faults, self.M, update_delay=self.update_delay,
                wire=self.wire, compensate=self.compensate)
            self._nonfinite_total = 0.0
            eng = self.engine
            self.chaos.attach(engine=eng, board=getattr(eng, "board", None))
        return state

    def step(self, state, batch, rng):
        # rng is part of the TrainerBackend protocol (the sim backend uses
        # it for peer selection); the prod ring's shift schedule is drawn
        # host-side so stepping never enqueues device work beyond the lanes
        if self.chaos is not None:
            state, batch = self.chaos.before_step(state, batch, self._steps)
        shift_idx = np.int32(self._shift_rng.integers(0, len(self._shifts)))
        state, metrics = self._step_fn(state, batch, self._steps, shift_idx)
        if self.chaos is not None and "nonfinite_skips" in metrics:
            # cumulative skip accounting for summary(): a transient NaN's
            # per-step metric is 0 again by the end of the run. Chaos mode
            # already does host work per step, so the forced resolve of
            # this one scalar (blocks on the stream engine's update task)
            # is acceptable here — and only here
            self._nonfinite_total += float(metrics["nonfinite_skips"])
        self._steps += 1
        self._last = metrics
        return state, metrics

    def summary(self) -> Dict[str, float]:
        out = _numeric_summary(self._steps, self._last)
        out["wire_dtype"] = self.wire
        part = self._engine_box.get("part")
        if part is not None:
            # one full plane crosses the ring per gossip round per worker
            out["wire_bytes_per_round"] = float(
                part.plane_nbytes(wire=self.wire))
        if self.timeline is not None:
            eng = self.engine
            if eng is not None and hasattr(eng, "finalize"):
                eng.finalize()  # stream engine: retire in-flight tasks
            self.timeline.finalize()
            t = self.timeline.summary()
            out.update(pipeline_wall_s=t["wall_s"],
                       overlap_events=float(t["overlap_events"]),
                       overlap_s=t["overlap_s"],
                       fwd_gossip_overlap_s=t["fwd_gossip_overlap_s"],
                       streams=float(t["streams"]),
                       exec_overlap_s=t["exec_overlap_s"],
                       signal_wait_s=t["signal_wait_s"])
        if self.chaos is not None:
            out.update(self.chaos.summary())
            # cumulative across the run, not the last step's transient
            out["nonfinite_skips"] = self._nonfinite_total
        return out


def make_backend(kind: str, algo, *, M: int, loss_fn: Callable = None,
                 optimizer=None, schedule=None,
                 hw: Optional[HardwareModel] = None, **kw) -> TrainerBackend:
    """Single entry point over the three backends.

    kind="sim":   requires loss_fn, optimizer, schedule.
    kind="event": requires hw (or uses the default HardwareModel).
    kind="prod":  requires loss_fn, optimizer, schedule and M local devices
                  (or an explicit mesh kwarg).
    Shared kwargs: straggler_delays, fb_ratio, update_delay; sim/prod also
    take measure_drift, event also takes sync_every and seed, prod also
    takes mesh, shifts, overlap (the stage-graph pipeline engine), streams
    (with overlap=True: >1 runs the stages on per-stage execution streams
    with one-sided per-group signal gossip — measured exec_overlap_s,
    identical numerics, DESIGN.md §13), flat
    (default True — the persistent flat parameter plane with param-dtype
    gossip wire; False restores the legacy tree state + per-step f32
    ravel), use_pallas (fused gossip_mix kernel), publisher (a
    repro.serving.PlanePublisher receiving the read plane each gossip
    round — the train-and-serve feed, DESIGN.md §12), wire ("param" —
    bit-exact plane exchange — or "int8": quantized gossip wire with
    error-feedback residuals, DESIGN.md §14), compensate (λ > 0 turns
    on the staleness-aware delay correction in the update lane) and
    faults (a repro.chaos FaultPlan/spec string enabling the
    fault-tolerant membership lane + chaos injection, DESIGN.md §15),
    max_inflight_steps (the pipeline engine's backpressure bound) and
    tuning (a repro.launch.tuner TuningRecord or path — autotuned
    schedule defaults, DESIGN.md §16).
    """
    if kind == "sim":
        if loss_fn is None or optimizer is None or schedule is None:
            raise ValueError("sim backend needs loss_fn, optimizer, schedule")
        return SimTrainerBackend(algo, loss_fn, optimizer, schedule, M, **kw)
    if kind == "event":
        return EventSimBackend(algo, M, hw=hw, **kw)
    if kind == "prod":
        if loss_fn is None or optimizer is None or schedule is None:
            raise ValueError("prod backend needs loss_fn, optimizer, schedule")
        return ProdTrainerBackend(algo, loss_fn, optimizer, schedule, M, **kw)
    raise ValueError(
        f"unknown backend kind {kind!r}; use 'sim', 'event' or 'prod'")


def drive(backend: TrainerBackend, batches, rng, params_single=None,
          history_keys: Tuple[str, ...] = ()) -> Dict[str, Any]:
    """Run a backend over an iterable of batches; collect metric history.

    Returns {"state": final_state, "history": {key: np.ndarray}, and the
    backend's summary() entries}. The event backend accepts batches of
    ``None``."""
    import jax
    state = backend.init(rng, params_single)
    hist: Dict[str, list] = {k: [] for k in history_keys}
    for t, batch in enumerate(batches):
        rng, r = jax.random.split(rng)
        state, metrics = backend.step(state, batch, r)
        for k in history_keys:
            if k in metrics:
                hist[k].append(np.asarray(metrics[k]))
    out: Dict[str, Any] = {"state": state,
                           "history": {k: np.asarray(v)
                                       for k, v in hist.items()}}
    out.update(backend.summary())
    return out
