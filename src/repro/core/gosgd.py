"""GoSGD baseline (Blot et al., 2019) — randomized push-sum gossip SGD.

Whole-model (block) gossip exchanged once per iteration, applied at the next
iteration boundary. The paper notes its GoSGD implementation was adapted
from the LayUp code — ours likewise shares the LayUp block-mode machinery
(LayUp minus layer-wise updates).
"""
from repro.core.api import register_algorithm
from repro.core.layup import LayUp


@register_algorithm("gosgd")
def _gosgd():
    return LayUp(layerwise=False, name="gosgd")
