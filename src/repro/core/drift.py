"""Diagnostics for the paper's theory: drift, gradient bias, elastic bound.

* ``disagreement`` (in repro.core.api): mean_i ‖x_i − x̄‖ — paper Fig. A1.
* ``gradient_bias``: ‖g(x̂) − g(x̃)‖² — the bias the paper bounds in
  Lemma 6.1: E‖b‖² ≤ 4 K_b² η² B².
* ``estimate_lipschitz``: empirical K_b via random perturbations.
* ``elastic_constant``: empirical B̂ from E‖x̄ − x_i‖² ≤ η²B² (Assumption 6).

Together these let the experiments check Lemma 6.1 numerically:
    bias² ≤ 4 · K̂² · η² · B̂²   (see benchmarks/figA1_drift.py).
"""
from __future__ import annotations

from typing import Callable, Tuple

import jax
import jax.numpy as jnp

from repro.core.api import consensus


def _tree_sqnorm(tree):
    return sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
               for x in jax.tree.leaves(tree))


def gradient_bias(loss_fn: Callable, params_hat, params_tilde, batch):
    """‖∇L(x̂) − ∇L(x̃)‖ for a single worker's params/batch."""
    g_hat = jax.grad(lambda p: loss_fn(p, batch)[0])(params_hat)
    g_tld = jax.grad(lambda p: loss_fn(p, batch)[0])(params_tilde)
    diff = jax.tree.map(lambda a, b: a - b, g_hat, g_tld)
    return jnp.sqrt(_tree_sqnorm(diff))


def estimate_lipschitz(loss_fn: Callable, params, batch, rng, *,
                       n_probes: int = 4, eps: float = 1e-3):
    """K̂_b = max over probes of ‖g(x+δ) − g(x)‖ / ‖δ‖."""
    g0 = jax.grad(lambda p: loss_fn(p, batch)[0])(params)
    ks = []
    for i in range(n_probes):
        r = jax.random.fold_in(rng, i)
        leaves, treedef = jax.tree.flatten(params)
        noise = [jax.random.normal(jax.random.fold_in(r, j), l.shape, jnp.float32)
                 for j, l in enumerate(leaves)]
        nn = jnp.sqrt(sum(jnp.sum(jnp.square(n)) for n in noise))
        noise = [eps * n / nn for n in noise]
        pert = jax.tree.unflatten(treedef, [
            (l.astype(jnp.float32) + n).astype(l.dtype)
            for l, n in zip(leaves, noise)])
        g1 = jax.grad(lambda p: loss_fn(p, batch)[0])(pert)
        dn = jnp.sqrt(_tree_sqnorm(jax.tree.map(lambda a, b: a - b, g1, g0)))
        ks.append(dn / eps)
    return jnp.max(jnp.stack(ks))


def elastic_constant(params_stacked, weights, lr) -> jnp.ndarray:
    """B̂ = max_i ‖x̄ − x_i‖ / η (empirical elastic-consistency constant)."""
    xbar = consensus(params_stacked, weights)

    def per_worker_sq(p, b):
        d = p.astype(jnp.float32) - b[None]
        return jnp.sum(jnp.square(d), axis=tuple(range(1, p.ndim)))

    sq = sum(jax.tree.leaves(jax.tree.map(per_worker_sq, params_stacked, xbar)))
    return jnp.sqrt(jnp.max(sq)) / jnp.maximum(lr, 1e-12)


def lemma61_bound(k_hat, lr, b_hat) -> jnp.ndarray:
    """RHS of Lemma 6.1: 4 K² η² B² (on the *squared* bias)."""
    return 4.0 * k_hat ** 2 * lr ** 2 * b_hat ** 2
