"""SlowMo baseline (Wang et al.): Local SGD + slow outer momentum.

Every ``sync_every`` steps: x̄ ← mean(x); u ← β·u + (z − x̄)/η_out;
z ← z − η_out·u; all replicas reset to z. Needs an extra model-sized buffer
(z and u) — one of the memory costs the paper contrasts LayUp against.

Version clocks follow Local SGD: stamped to ``step + 1`` on sync steps,
free-running (staleness ramps to H−1) in between.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.api import DistAlgorithm, register_algorithm
from repro.core.layerview import LayerView, stamp_groups


class SlowMo(DistAlgorithm):
    asynchronous = False

    def __init__(self, sync_every: int = 8, outer_lr: float = 1.0,
                 outer_beta: float = 0.5, name: str = "slowmo"):
        self.H = sync_every
        self.outer_lr = outer_lr
        self.outer_beta = outer_beta
        self.name = name

    def init_extras(self, view: LayerView, M: int):
        single = jax.tree.map(lambda p: p[0], view.groups)
        return {"z": single, "u": jax.tree.map(jnp.zeros_like, single)}

    def _outer(self, new_groups, extras):
        """One outer step from the current average. Returns (z, u) grouped."""
        xavg = jax.tree.map(
            lambda p: jnp.mean(p.astype(jnp.float32), axis=0), new_groups)
        u = jax.tree.map(
            lambda uu, z, xa: self.outer_beta * uu.astype(jnp.float32)
            + (z.astype(jnp.float32) - xa) / self.outer_lr,
            extras["u"], extras["z"], xavg)
        z = jax.tree.map(
            lambda zz, uu: zz.astype(jnp.float32) - self.outer_lr * uu,
            extras["z"], u)
        return z, u

    def post(self, view: LayerView, weights, extras, updates, active, rng,
             step):
        new_groups = jax.tree.map(
            lambda p, u: p + u.astype(p.dtype), view.groups, updates)
        sync = (jnp.mod(step + 1, self.H) == 0)
        z_new, u_new = self._outer(new_groups, extras)

        def sel(a, b):
            return jnp.where(sync, a.astype(jnp.float32),
                             b.astype(jnp.float32)).astype(b.dtype)

        z = jax.tree.map(sel, z_new, extras["z"])
        u = jax.tree.map(sel, u_new, extras["u"])
        out = jax.tree.map(
            lambda p, zz: jnp.where(
                sync, jnp.broadcast_to(zz[None].astype(jnp.float32), p.shape),
                p.astype(jnp.float32)).astype(p.dtype),
            new_groups, z)
        versions = stamp_groups(
            view.versions,
            jnp.where(sync, jnp.asarray(step, jnp.float32) + 1.0, 0.0))
        return (view.with_groups(out).with_versions(versions), weights,
                {"z": z, "u": u}, {"synced": sync.astype(jnp.float32)})


@register_algorithm("slowmo")
def _slowmo(sync_every: int = 8, outer_lr: float = 1.0, outer_beta: float = 0.5):
    return SlowMo(sync_every, outer_lr, outer_beta)
