"""Nightly chaos job — degraded-mode training under injected faults.

Runs the full fault-tolerant lane (M=4 host devices, per-group streams,
int8 wire, R=2/D=1 — the PR-9 acceptance configuration) once fault-free
and once per chaos flavor, with every fault scheduled through a
deterministic :class:`~repro.chaos.plan.FaultPlan` (DESIGN.md §15):

* ``crash``   — peer 1 dies mid-run and re-enters via donor re-sync;
* ``hang``    — the host loop stalls (wall-clock degradation only);
* ``corrupt`` — int8 wire payloads are damaged/dropped and must be
  rejected by checksum and repaired bit-exact (reject-and-resend).

Nightly artifact: ``BENCH_fault_tolerance.json`` — per-flavor final loss,
loss delta vs fault-free, time-to-detect and time-to-resync (in steps,
from the membership tracker), degraded-round and guard counters. Gates
(CI fails otherwise):

* every degraded run completes with finite loss, no ``TimeoutError``;
* degraded final loss <= 1.2x the fault-free final loss — a single
  crashed/recovered peer or a repaired wire round must not derail
  convergence;
* the crash flavor detects the death (time_to_detect recorded), re-syncs
  exactly once, and conserves push-sum mass (weight_sum == 1.0) on every
  round it reports;
* the corrupt flavor's guard counters show the damage was seen
  (checksum reject + drop detect) and repaired (resends == rejects).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import dump_json, emit, ensure_host_devices, section

FLAVORS = {
    "crash": "crash:peer=1,step=3,recover=9",
    "hang": "hang:step=4,seconds=0.05;hang:step=8,seconds=0.05",
    "corrupt": "corrupt:step=3,group=0;drop:step=6,group=1",
}
LOSS_BUDGET = 1.2  # degraded final loss <= 1.2x fault-free


def _problem():
    import jax
    import jax.numpy as jnp

    def loss_fn(p, b):
        h = jnp.tanh(b["x"] @ p["l1"])
        logits = h @ p["l2"]
        ce = -jnp.mean(jax.nn.log_softmax(logits)[
            jnp.arange(logits.shape[0]), b["labels"]])
        return ce, {}

    params = {"l1": jax.random.normal(jax.random.PRNGKey(1), (16, 64)) * 0.2,
              "l2": jax.random.normal(jax.random.PRNGKey(2), (64, 10)) * 0.2}
    return loss_fn, params


def _batch(t, M, b=16):
    import jax
    return {"x": jax.random.normal(jax.random.PRNGKey(10 + t), (M, b, 16)),
            "labels": jax.random.randint(jax.random.PRNGKey(90 + t),
                                         (M, b), 0, 10)}


def _run(flavor, faults, M, steps):
    """One measured run; returns final loss, wall time and the chaos
    accounting from the backend summary."""
    import jax

    from repro.core.backend import make_backend
    from repro.optim.optimizers import sgd

    loss_fn, params = _problem()
    be = make_backend("prod", "layup", M=M, loss_fn=loss_fn,
                      optimizer=sgd(0.1), schedule=lambda t: 0.1,
                      fb_ratio=2, update_delay=1, overlap=True, streams=3,
                      wire="int8", measure_drift=False, faults=faults)
    rng = jax.random.PRNGKey(0)
    state = be.init(rng, params)
    losses, wsums = [], []
    t0 = time.perf_counter()
    for t in range(steps):
        state, m = be.step(state, _batch(t, M), rng)
        losses.append(float(m["loss"]))
        wsums.append(float(m["weight_sum"]))
    wall = time.perf_counter() - t0
    be.engine.close()
    s = be.summary()
    assert all(np.isfinite(losses)), (flavor, losses)
    assert all(abs(w - 1.0) < 1e-3 for w in wsums), (
        f"{flavor}: push-sum mass not conserved: {wsums}")
    final = float(np.mean(losses[-3:]))
    return {"final_loss": final, "wall_s": wall, "losses": losses,
            "summary": s}


def main(steps=None, quick=False):
    import jax

    section("Fault-tolerant lane under chaos injection (DESIGN.md §15)")
    n_dev = len(jax.devices())
    M = 4 if n_dev >= 4 else n_dev
    steps = steps or (14 if quick else 28)

    base = _run("fault-free", "", M, steps)
    emit("fault.baseline.final_loss", base["wall_s"] / steps * 1e6,
         f"final_loss={base['final_loss']:.4f};M={M};steps={steps}")

    for flavor, spec in FLAVORS.items():
        r = _run(flavor, spec, M, steps)
        s = r["summary"]
        delta = r["final_loss"] - base["final_loss"]
        ratio = r["final_loss"] / base["final_loss"]
        ttd = s.get("time_to_detect_steps", float("nan"))
        ttr = s.get("time_to_resync_steps", float("nan"))
        emit(f"fault.{flavor}.final_loss", r["wall_s"] / steps * 1e6,
             f"final_loss={r['final_loss']:.4f};delta={delta:+.4f};"
             f"ratio={ratio:.3f};faults={s['faults_injected']};"
             f"degraded_rounds={s['rounds_degraded']};"
             f"time_to_detect={ttd};time_to_resync={ttr};"
             f"resyncs={s['resyncs']};nonfinite_skips="
             f"{s.get('nonfinite_skips', 0)}")

        # the acceptance gate: a fault-injected run must stay within the
        # loss budget of the fault-free run
        assert r["final_loss"] <= LOSS_BUDGET * base["final_loss"], (
            f"{flavor}: degraded final loss {r['final_loss']:.4f} blew the "
            f"{LOSS_BUDGET}x budget vs fault-free {base['final_loss']:.4f}")

        if flavor == "crash":
            assert s["resyncs"] == 1, s
            assert s["peers_dead"] == 0, s  # recovered before the end
            assert s.get("time_to_detect_steps", -1) > 0, s
            assert s.get("time_to_resync_steps", -1) > 0, s
            emit("fault.crash.time_to_detect",
                 s["time_to_detect_steps"] * 1e6,
                 f"steps={s['time_to_detect_steps']}")
            emit("fault.crash.time_to_resync",
                 s["time_to_resync_steps"] * 1e6,
                 f"steps={s['time_to_resync_steps']}")
        if flavor == "corrupt":
            assert s["checksum_rejects"] >= 1, s
            assert s["drops_detected"] >= 1, s
            assert s["resends"] == (s["checksum_rejects"]
                                    + s["drops_detected"]), s
        if flavor == "hang":
            assert s["hangs"] == 2, s
            # a hang degrades wall-clock only — numerics are untouched,
            # so the trajectory matches fault-free exactly
            assert r["losses"] == base["losses"], (
                "hang flavor changed numerics")

    dump_json("fault_tolerance", prefix="fault.")
    print("# fault-tolerance gates passed", flush=True)
    return base


if __name__ == "__main__":
    import argparse

    p = argparse.ArgumentParser()
    p.add_argument("--quick", action="store_true")
    p.add_argument("--steps", type=int, default=None)
    a = p.parse_args()
    ensure_host_devices(4)
    main(steps=a.steps, quick=a.quick)
