"""Roofline-driven stage autotuner over the real engine (DESIGN.md §16).

For every candidate schedule (R, D, max_inflight_steps, ...) this builds
the M=4 decoupled backend, runs a few measured steps (the StageTimeline
supplies the candidate's demonstrated overlap), cuts the jitted stage
executables out of the engine and times each in isolation
(``launch/tuner.py``), then scores the grid against the analytic roofline
floors and emits the winner as a versioned ``TuningRecord``.

Nightly artifacts: ``BENCH_autotune.json`` (the scored grid) and
``BENCH_autotune_record.json`` (the record itself — the thing
``make_step(tuning=...)`` / ``ProdTrainerBackend(tuning=...)`` load).

Gates (CI fails otherwise):

* the hand-picked default schedule (R=2, D=1, flat plane,
  max_inflight_steps=3) is IN the grid, and the tuned best never scores
  below it on the same measured timelines;
* the emitted record round-trips through ``load_tuning`` (version + key
  checked) and drives a fresh ``ProdTrainerBackend`` to exactly the
  tuned (R, D, max_inflight_steps) — and that backend trains (finite
  loss).
"""
from __future__ import annotations

import os

import numpy as np

from benchmarks.common import dump_json, emit, ensure_host_devices, section

W = 256          # hidden width of the probe MLP
BATCH = 8        # per-worker batch; divisible by every grid R (1, 2, 4)


def _problem():
    import jax
    import jax.numpy as jnp

    def loss_fn(p, b):
        h = jnp.tanh(b["x"] @ p["l1"])
        h = jnp.tanh(h @ p["l2"])
        logits = h @ p["l3"]
        ce = -jnp.mean(jax.nn.log_softmax(logits)[
            jnp.arange(logits.shape[0]), b["labels"]])
        return ce, {}

    k = jax.random.PRNGKey(0)
    params = {"l1": jax.random.normal(k, (64, W)) * 0.05,
              "l2": jax.random.normal(k, (W, W)) * 0.05,
              "l3": jax.random.normal(k, (W, 10)) * 0.05}
    return loss_fn, params


def _batches(M, mesh, n=4):
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import data_axes

    bsh = NamedSharding(mesh, P(data_axes(mesh)))
    rng = np.random.default_rng(7)
    batches = [jax.device_put(
        {"x": rng.standard_normal((M, BATCH, 64)).astype(np.float32),
         "labels": rng.integers(0, 10, (M, BATCH))}, bsh)
        for _ in range(n)]
    jax.block_until_ready(batches)
    return batches


def _mlp_roofline(part, M):
    """Honest analytic terms for the probe MLP, in the train convention of
    ``launch/analysis.py`` (fwd + 2×bwd + remat fwd → device term = 4×fwd
    matmul flops): on CPU the measured cutouts sit far above these TPU
    floors, so the clamp never binds here — but the scoring path is the
    SAME one a real-accelerator run exercises."""
    from repro.launch.analysis import HBM_BW, ICI_BW, PEAK_FLOPS

    fwd_flops = 2.0 * BATCH * (64 * W + W * W + W * 10)
    plane_bytes = float(part.plane_nbytes())
    return {"t_compute": 4.0 * fwd_flops / PEAK_FLOPS,
            "t_memory": 3.0 * plane_bytes / M / HBM_BW,
            "t_collective": plane_bytes / ICI_BW}


def _measure(cand, M, steps, reps):
    """One grid point: build the backend at the candidate's schedule, run
    the measured steps, then time its stage cutouts in isolation."""
    import jax

    from repro.core import make_backend
    from repro.launch.tuner import CutoutHarness, stage_times_from_cutouts
    from repro.optim import constant, momentum

    loss_fn, params = _problem()
    be = make_backend("prod", "layup", M=M, loss_fn=loss_fn,
                      optimizer=momentum(0.9), schedule=constant(0.05),
                      fb_ratio=cand.R, update_delay=cand.D, overlap=True,
                      max_inflight_steps=cand.max_inflight_steps,
                      measure_drift=False)
    st = be.init(jax.random.PRNGKey(0), params)
    batches = _batches(M, be.mesh)
    losses = []
    for t in range(steps):
        st, m = be.step(st, batches[t % len(batches)], None)
        losses.append(m["loss"])  # future — no block inside the loop
    be.summary()  # finalizes the timeline
    tl = be.timeline.summary()
    assert all(np.isfinite(float(v)) for v in losses), cand.label()

    harness = CutoutHarness(warmup=1, reps=reps)
    timings = harness.time_engine(be.engine)
    stage_times = stage_times_from_cutouts(timings)
    part = be.part
    if hasattr(be.engine, "close"):
        be.engine.close()
    return stage_times, tl, part, be.mesh


def run_autotune(quick=True, steps=6, reps=2, out_dir=None):
    """Grid-search the schedule on the real engine. Returns ``(record,
    default_score)`` — the emitted :class:`TuningRecord` and the
    hand-picked default's score on the same measurements. Writes the
    record JSON to ``out_dir`` when given."""
    import jax

    from repro.launch.analysis import stage_floors
    from repro.launch.tuner import (DEFAULT_CANDIDATE, build_record,
                                    enumerate_grid, make_key,
                                    mesh_descriptor, problem_descriptor)

    M = min(4, len(jax.devices()))
    if quick:
        grid = enumerate_grid(R_values=(1, 2), D_values=(0, 1),
                              max_inflight=(3,))
    else:
        grid = enumerate_grid()  # R {1,2,4} × D {0,1,2} × q {2,3,4}
    assert DEFAULT_CANDIDATE in grid, "the default must be a grid point"

    entries = []
    part = mesh = None
    for cand in grid:
        stage_times, tl, part, mesh = _measure(cand, M, steps, reps)
        entries.append((cand, stage_times, tl))
        print(f"# {cand.label()}: fwd={stage_times['fwd'] * 1e3:.2f}ms "
              f"upd={stage_times['update'] * 1e3:.2f}ms "
              f"gos={stage_times['gossip'] * 1e3:.2f}ms "
              f"exec_overlap={tl['exec_overlap_s']:.3f}s "
              f"overlap={tl['overlap_s']:.3f}s", flush=True)

    roof = _mlp_roofline(part, M)
    key = make_key(problem_descriptor(part), mesh_descriptor(mesh), "param")
    record = build_record(entries, key=key,
                          floors=lambda c: stage_floors(roof, R=c.R),
                          meta={"M": M, "steps": steps, "reps": reps,
                                "quick": bool(quick), "W": W,
                                "batch": BATCH})
    default_score = next(r["score"] for r in record.table
                         if r["label"] == DEFAULT_CANDIDATE.label())
    if out_dir is not None:
        os.makedirs(out_dir, exist_ok=True)
        path = record.save(os.path.join(out_dir,
                                        "BENCH_autotune_record.json"))
        print(f"# wrote {path}", flush=True)
    return record, default_score


def main(steps=None, quick=False):
    import jax

    from repro.core import make_backend
    from repro.launch.tuner import load_tuning
    from repro.optim import constant, momentum

    section("Stage autotuner — cutout-timed schedule grid (DESIGN.md §16)")
    out_dir = os.path.join(os.path.dirname(__file__), "results")
    steps = steps or (4 if quick else 8)
    record, default_score = run_autotune(quick=quick, steps=steps,
                                         out_dir=out_dir)

    for row in record.table:
        emit(f"autotune.cand.{row['label']}", row["step_time_s"] * 1e6,
             f"score={row['score']:.4f};staleness={row['staleness']:.2f};"
             f"overlap_eff={row['overlap_eff']:.3f}")
    emit("autotune.best", record.table[0]["step_time_s"] * 1e6,
         f"label={record.best['label']};score={record.score:.4f};"
         f"default_score={default_score:.4f};key_len={len(record.key)}")

    # gate: the tuned schedule never scores below the hand-picked default
    # on the same measured timelines (the default is a grid point, so
    # this can only fail if the ranking itself is broken)
    assert record.score >= default_score, (record.score, default_score)

    # gate: the artifact round-trips — version + key checked — and drives
    # a fresh backend to exactly the tuned schedule
    path = os.path.join(out_dir, "BENCH_autotune_record.json")
    loaded = load_tuning(path, key=record.key)
    assert loaded is not None, "emitted record failed to load back"
    best = loaded.best_candidate()
    loss_fn, params = _problem()
    be = make_backend("prod", "layup", M=loaded.meta["M"], loss_fn=loss_fn,
                      optimizer=momentum(0.9), schedule=constant(0.05),
                      tuning=loaded, measure_drift=False)
    st = be.init(jax.random.PRNGKey(0), params)
    assert be.overlap
    assert be.engine.R == best.R and be.engine.D == best.D
    assert be.engine.max_inflight_steps == best.max_inflight_steps
    batches = _batches(loaded.meta["M"], be.mesh, n=2)
    for t in range(2):
        st, m = be.step(st, batches[t % 2], None)
    assert np.isfinite(float(m["loss"]))
    if hasattr(be.engine, "close"):
        be.engine.close()
    emit("autotune.loadthrough", 1.0,
         f"R={best.R};D={best.D};q={best.max_inflight_steps};applied=1")

    dump_json("autotune", prefix="autotune.")
    return record


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    ensure_host_devices(4)
    main(steps=args.steps, quick=args.quick)
