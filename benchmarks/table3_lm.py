"""Paper Table 3 analogue (sequence modeling): perplexity + training time for
all algorithms pre-training a small transformer LM on the synthetic Markov
language (MiniPile stand-in), with GPT-2-Medium/8×A100 timing from the
hardware simulator.

``--backend prod`` runs the layup family through the production decoupled
shard_map lane (needs one host device per worker — the __main__ guard sets
the XLA flag before jax initializes, so jax-touching imports are deferred).
Every run emits perplexity-vs-wallclock curve rows and dumps them via
``benchmarks.common.dump_json``."""
from __future__ import annotations

ALGOS = ["ddp", "co2", "slowmo", "gosgd", "adpsgd", "layup"]

M_WORKERS = 4


def _hw():
    from repro.core.simulator import HardwareModel
    # GPT-2 Medium on 8×A100-40G (paper C2): ~400M params fp32
    return HardwareModel(fwd_time=0.11, bwd_ratio=2.0, num_layers=24,
                         model_bytes=0.4e9 * 4, bandwidth=100e9,
                         allreduce_bandwidth=150e9, kernel_mfu=0.70)


def _bench_cfg():
    from repro.configs.base import ModelConfig
    return ModelConfig(
        name="bench-lm", family="dense", num_layers=2, d_model=128,
        num_heads=4, num_kv_heads=4, d_ff=256, vocab_size=128,
        tie_embeddings=True)


def _problem(M, seq=64):
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.data.synthetic import SyntheticLM
    from repro.models import build_model

    cfg = _bench_cfg()
    ds = SyntheticLM(vocab=cfg.vocab_size, seq_len=seq,
                     temperature=1.2, seed=0)
    model = build_model(cfg)
    eval_rng = np.random.default_rng(77)
    eb = ds.sample(eval_rng, 128)
    eval_batch = {k: jnp.asarray(v) for k, v in eb.items()}

    def loss_fn(p, batch):
        return model.loss_fn(p, batch, block_k=32)

    @jax.jit
    def eval_ppl(p):
        return jnp.exp(model.loss_fn(p, eval_batch, block_k=32)[0])

    return ds, model, loss_fn, eval_ppl


def main(steps=300, M=M_WORKERS, quick=False, backend="sim",
         fb_ratio=1, update_delay=0):
    import numpy as np

    from benchmarks.algo_runner import run_algorithm
    from benchmarks.common import dump_json, emit, section
    from benchmarks.table1_vision import emit_curve

    section(f"Table 3 analogue — LM pre-training "
            f"(perplexity/time, backend={backend})")
    if quick:
        steps = 120
    ds, model, loss_fn, eval_ppl = _problem(M)
    floor = float(np.exp(ds.entropy))
    print(f"# irreducible ppl floor (Markov entropy): {floor:.2f}")
    algos = ALGOS if backend == "sim" else ["layup"]
    out = {}
    for algo in algos:
        r = run_algorithm(algo, ds=ds,
                          init_params_fn=lambda rng: model.init(rng),
                          loss_fn=loss_fn, eval_fn=eval_ppl, M=M,
                          steps=steps,
                          batch_per_worker=16 * max(fb_ratio, 1), lr=0.15,
                          hw=_hw(), eval_every=max(steps // 6, 1),
                          backend=backend, fb_ratio=fb_ratio,
                          update_delay=update_delay)
        out[algo] = r
        tag = f"table3.{algo}" if backend == "sim" else f"table3.prod.{algo}"
        emit(tag, r.iter_time * 1e6,
             f"ppl={r.eval_metric[-1]:.2f};time_s={r.total_time:.1f};"
             f"floor={floor:.2f}")
        emit_curve(tag, r)
    dump_json(f"table3_lm_{backend}" if backend != "sim" else "table3_lm",
              prefix="table3.")
    return out


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--backend", choices=["sim", "prod"], default="sim")
    ap.add_argument("--fb-ratio", type=int, default=1)
    ap.add_argument("--update-delay", type=int, default=0)
    args = ap.parse_args()
    if args.backend == "prod":
        # one host device per worker; must be set before jax initializes
        from benchmarks.common import ensure_host_devices
        ensure_host_devices(M_WORKERS)
    main(steps=args.steps, quick=args.quick, backend=args.backend,
         fb_ratio=args.fb_ratio, update_delay=args.update_delay)
