"""Paper Table 3 analogue (sequence modeling): perplexity + training time for
all algorithms pre-training a small transformer LM on the synthetic Markov
language (MiniPile stand-in), with GPT-2-Medium/8×A100 timing from the
hardware simulator."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.algo_runner import run_algorithm
from benchmarks.common import emit, section
from repro.configs.base import ModelConfig
from repro.data.synthetic import SyntheticLM
from repro.core.simulator import HardwareModel
from repro.models import build_model

ALGOS = ["ddp", "co2", "slowmo", "gosgd", "adpsgd", "layup"]

# GPT-2 Medium on 8×A100-40G (paper C2): ~400M params fp32
HW = HardwareModel(fwd_time=0.11, bwd_ratio=2.0, num_layers=24,
                   model_bytes=0.4e9 * 4, bandwidth=100e9,
                   allreduce_bandwidth=150e9, kernel_mfu=0.70)

BENCH_CFG = ModelConfig(
    name="bench-lm", family="dense", num_layers=2, d_model=128,
    num_heads=4, num_kv_heads=4, d_ff=256, vocab_size=128,
    tie_embeddings=True)


def _problem(M, seq=64):
    ds = SyntheticLM(vocab=BENCH_CFG.vocab_size, seq_len=seq,
                     temperature=1.2, seed=0)
    model = build_model(BENCH_CFG)
    eval_rng = np.random.default_rng(77)
    eb = ds.sample(eval_rng, 128)
    eval_batch = {k: jnp.asarray(v) for k, v in eb.items()}

    def loss_fn(p, batch):
        return model.loss_fn(p, batch, block_k=32)

    @jax.jit
    def eval_ppl(p):
        return jnp.exp(model.loss_fn(p, eval_batch, block_k=32)[0])

    return ds, model, loss_fn, eval_ppl


def main(steps=300, M=4, quick=False):
    section("Table 3 analogue — LM pre-training (perplexity/time)")
    if quick:
        steps = 120
    ds, model, loss_fn, eval_ppl = _problem(M)
    floor = float(np.exp(ds.entropy))
    print(f"# irreducible ppl floor (Markov entropy): {floor:.2f}")
    out = {}
    for algo in ALGOS:
        r = run_algorithm(algo, ds=ds,
                          init_params_fn=lambda rng: model.init(rng),
                          loss_fn=loss_fn, eval_fn=eval_ppl, M=M,
                          steps=steps, batch_per_worker=16, lr=0.15, hw=HW,
                          eval_every=max(steps // 6, 1))
        out[algo] = r
        emit(f"table3.{algo}", r.iter_time * 1e6,
             f"ppl={r.eval_metric[-1]:.2f};time_s={r.total_time:.1f};"
             f"floor={floor:.2f}")
    return out


if __name__ == "__main__":
    main()
