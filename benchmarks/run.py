"""Benchmark driver — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines. Usage:

  PYTHONPATH=src python -m benchmarks.run [--quick] [--only table3,fig3]

The roofline module aggregates dry-run artifacts if present (run
``PYTHONPATH=src python -m repro.launch.dryrun --all`` first for the full
§Roofline table).
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

MODULES = [
    ("table1", "benchmarks.table1_vision"),
    ("table3", "benchmarks.table3_lm"),
    ("table4", "benchmarks.table4_mfu"),
    ("fig3", "benchmarks.fig3_stragglers"),
    ("figA1", "benchmarks.figA1_drift"),
    ("kernels", "benchmarks.kernels_bench"),
    ("roofline", "benchmarks.roofline"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of: "
                         + ",".join(k for k, _ in MODULES))
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    failures = []
    t0 = time.time()
    for key, modname in MODULES:
        if only and key not in only:
            continue
        try:
            import importlib
            mod = importlib.import_module(modname)
            mod.main(quick=args.quick)
        except Exception:
            traceback.print_exc()
            failures.append(key)
    print(f"\n# total benchmark time: {time.time() - t0:.0f}s")
    if failures:
        print("# FAILED:", failures)
        sys.exit(1)
    print("# ALL BENCHMARKS COMPLETED")


if __name__ == "__main__":
    main()
