"""Shared benchmark helpers: CSV emission, sim-clock based TTC/TTA."""
from __future__ import annotations

import time
from typing import Dict, List, Optional

import numpy as np

RESULTS: List[str] = []


def emit(name: str, us_per_call: float, derived: str = ""):
    line = f"{name},{us_per_call:.1f},{derived}"
    RESULTS.append(line)
    print(line, flush=True)


def section(title: str):
    print(f"\n# === {title} ===", flush=True)


def time_to_target(values: np.ndarray, per_step_time: float, target: float,
                   mode: str = "below") -> Optional[float]:
    """First wall-clock time at which the metric crosses the target."""
    ok = values < target if mode == "below" else values > target
    idx = np.argmax(ok)
    if not ok.any():
        return None
    return float((idx + 1) * per_step_time)
