"""Shared benchmark helpers: CSV emission, sim-clock based TTC/TTA."""
from __future__ import annotations

import time
from typing import Dict, List, Optional

import numpy as np

RESULTS: List[str] = []


def emit(name: str, us_per_call: float, derived: str = ""):
    line = f"{name},{us_per_call:.1f},{derived}"
    RESULTS.append(line)
    print(line, flush=True)


def section(title: str):
    print(f"\n# === {title} ===", flush=True)


def dump_json(tag: str, prefix=None, out_dir: Optional[str] = None) -> str:
    """Write the emitted CSV lines as ``BENCH_<tag>.json`` — the artifact
    the nightly CI job uploads so the perf trajectory is tracked per run.

    ``prefix`` (a string or tuple of strings) restricts the dump to those
    metric-name prefixes (modules share the RESULTS buffer when driven by
    benchmarks.run)."""
    import json
    import os
    out_dir = out_dir or os.path.join(os.path.dirname(__file__), "results")
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"BENCH_{tag}.json")
    rows = {}
    for line in RESULTS:
        name, us, derived = line.split(",", 2)
        if prefix and not name.startswith(prefix):
            continue
        rows[name] = {"us_per_call": float(us), "derived": derived}
    with open(path, "w") as f:
        json.dump(rows, f, indent=1, sort_keys=True)
    print(f"# wrote {path} ({len(rows)} entries)", flush=True)
    return path


def ensure_host_devices(n: int) -> None:
    """Make sure jax will fake >= ``n`` host CPU devices. Must run BEFORE
    jax initializes (the prod-backend benchmarks call it from their
    __main__ guards). Appends the XLA flag if absent; if the environment
    already pins a SMALLER count, raises the count to ``n`` (and says so)
    rather than letting the backend fail with a device-count error."""
    import os
    import re
    flags = os.environ.get("XLA_FLAGS", "")
    m = re.search(r"--xla_force_host_platform_device_count=(\d+)", flags)
    if m is None:
        flags = (flags + f" --xla_force_host_platform_device_count={n}")
    elif int(m.group(1)) < n:
        print(f"# raising xla_force_host_platform_device_count "
              f"{m.group(1)} -> {n} (needed for M={n} workers)", flush=True)
        flags = re.sub(r"--xla_force_host_platform_device_count=\d+",
                       f"--xla_force_host_platform_device_count={n}", flags)
    os.environ["XLA_FLAGS"] = flags.strip()
    os.environ.setdefault("JAX_PLATFORMS", "cpu")


def time_to_target(values: np.ndarray, per_step_time: float, target: float,
                   mode: str = "below") -> Optional[float]:
    """First wall-clock time at which the metric crosses the target."""
    ok = values < target if mode == "below" else values > target
    idx = np.argmax(ok)
    if not ok.any():
        return None
    return float((idx + 1) * per_step_time)
