"""Paper Fig. 3 analogue: straggler robustness.

A: final task accuracy as a function of the injected delay (sim backend —
   the straggler computes/updates only every (delay+1) iterations for async
   methods; sync methods wait).
B: total training time as a function of delay (event-driven simulator).
"""
from __future__ import annotations

import numpy as np

from benchmarks.algo_runner import run_algorithm
from benchmarks.common import emit, section
from benchmarks.table1_vision import _hw, _problem
from repro.core.simulator import straggler_sweep

ALGOS = ["ddp", "co2", "slowmo", "gosgd", "adpsgd", "layup"]
DELAYS = (0, 1, 2, 4, 8)


def main(steps=250, M=8, quick=False):
    section("Fig 3A analogue — accuracy vs straggler delay")
    if quick:
        steps = 120
    ds, init, loss_fn, eval_fn = _problem(M)
    delays_list = (0, 4) if quick else (0, 2, 8)
    for d in delays_list:
        dl = np.zeros(M, int)
        dl[0] = d
        for algo in ALGOS:
            r = run_algorithm(algo, ds=ds, init_params_fn=init,
                              loss_fn=loss_fn, eval_fn=eval_fn, M=M,
                              steps=steps, batch_per_worker=64, lr=0.08,
                              hw=_hw(), straggler_delays=dl,
                              eval_every=steps)
            emit(f"fig3a.{algo}.delay{d}", 0.0,
                 f"acc={r.eval_metric[-1]:.4f}")

    section("Fig 3B analogue — training time vs straggler delay")
    sweep = straggler_sweep(ALGOS, M=M, iters=steps, hw=_hw(), delays=DELAYS)
    for algo, times in sweep.items():
        for d, t in zip(DELAYS, times):
            emit(f"fig3b.{algo}.delay{d}", t / steps * 1e6, f"total_s={t:.1f}")


if __name__ == "__main__":
    main()
