"""Paper Table 4 analogue: Model FLOPs Utilization per algorithm.

Wall-clock MFU cannot be measured on this CPU container; the event-driven
simulator models each algorithm's schedule (barriers, overlap, NIC
serialization) on the paper's two hardware configs. Reported MFU =
kernel_mfu × compute_utilization — the schedule-induced component the paper
attributes the LayUp gain to (§5.3).

The final section is MEASURED, not simulated: the stage-graph pipeline
engine (DESIGN.md §10) runs a real decoupled workload and reports the
per-stage dispatch/complete timestamps its timeline recorded — including
the forward-of-step-t+1 vs gossip-of-step-t overlap the paper's speedups
come from. With >1 host device (the nightly job sets
``--xla_force_host_platform_device_count=4``) the run asserts that overlap
is nonzero and dumps the full timeline as ``BENCH_overlap_stages.json``.

Dispatch overlap is the ceiling, not the achievement: this engine runs on
one executable stream, so its summary pins ``streams: 1`` and
``exec_overlap_s: 0.0``. Execution-level concurrency (per-group streams,
one-sided signal gossip, DESIGN.md §13) is measured and gated by
``benchmarks.stream_stages``."""
from __future__ import annotations

import os

import numpy as np

from benchmarks.common import dump_json, emit, section
from repro.core.simulator import HardwareModel, simulate

ALGOS = ["ddp", "co2", "slowmo", "gosgd", "adpsgd", "layup"]

CONFIGS = {
    # GPT-2 Medium pre-training, 8×A100-SXM4-40G (paper C2)
    "gpt2-medium-pretrain": dict(
        M=8, hw=HardwareModel(fwd_time=0.11, bwd_ratio=2.0, num_layers=24,
                              model_bytes=1.6e9, bandwidth=40e9,
                              allreduce_bandwidth=75e9, kernel_mfu=0.75)),
    # GPT-2 XL finetuning, 4×H100 (paper C3) — smaller batch, comm-bound
    "gpt2-xl-finetune": dict(
        M=4, hw=HardwareModel(fwd_time=0.095, bwd_ratio=2.0, num_layers=48,
                              model_bytes=6.4e9, bandwidth=45e9,
                              allreduce_bandwidth=55e9, kernel_mfu=0.65)),
}


def main(iters=None, quick=False):
    if iters is None:  # CI smoke (--quick): tiny config, same assertions
        iters = 40 if quick else 200
    section("Table 4 analogue — modeled MFU per algorithm")
    out = {}
    for cname, cfg in CONFIGS.items():
        for algo in ALGOS:
            r = simulate(algo, M=cfg["M"], iters=iters, hw=cfg["hw"],
                         sync_every=20)
            out[(cname, algo)] = r.mfu
            emit(f"table4.{cname}.{algo}", r.total_time / iters * 1e6,
                 f"mfu={100 * r.mfu:.2f}%;util={r.utilization:.3f}")
    # paper's qualitative claim: layup >= ddp on both configs
    for cname in CONFIGS:
        assert out[(cname, "layup")] >= out[(cname, "ddp")] - 1e-9, cname

    section("Decoupled execution — fwd/bwd thread lanes (PD-ASGD §3)")
    for cname, cfg in CONFIGS.items():
        base = simulate("layup", M=cfg["M"], iters=iters, hw=cfg["hw"])
        r1 = None
        for R, D in ((1, 1), (2, 1), (4, 1)):
            r = simulate("layup", M=cfg["M"], iters=iters, hw=cfg["hw"],
                         fb_ratio=R, update_delay=D)
            r1 = r if (R, D) == (1, 1) else r1
            emit(f"table4.{cname}.layup.R{R}D{D}",
                 r.total_time / iters * 1e6,
                 f"mfu={100 * r.mfu:.2f}%;fwd_per_s={r.fwd_passes_per_s:.2f};"
                 f"upd_per_s={r.updates_per_s:.2f};"
                 f"grad_stale_s={r.mean_grad_staleness:.3f}")
        # decoupled lanes never stall on the NIC → MFU pins at the kernel
        # ceiling and can't fall below the coupled schedule
        assert r1.mfu >= base.mfu - 1e-9, cname
    measured_overlap(quick=quick)
    dump_json("table4_mfu", prefix="table4.")
    return out


def measured_overlap(steps=None, quick=False):
    """Run the pipeline engine on a real workload; report MEASURED overlap.

    The model is sized so the gossip stage's execution comfortably exceeds
    the host's dispatch turnaround (gossip packs/mixes the whole parameter
    tree, so its cost scales with the ~4M params at the base width) —
    otherwise the device retires each stage before the host can run ahead
    and there is nothing to measure. That threshold is runner-dependent: a
    fast machine can retire the W=2048 gossip inside its dispatch
    turnaround and measure zero overlap even though the schedule is
    correct. So the probe auto-scales: if M > 1 and no overlap shows, the
    width is doubled (up to 8192) and the probe rerun before the overlap
    assert fires. Only the final probe's numbers are emitted. The
    workload is an MLP, not the event-sim's GPT configs: the claim under
    test is the ENGINE's dispatch schedule, which is model-agnostic."""
    import jax

    section("Measured stage overlap — pipeline engine (DESIGN.md §10)")
    n_dev = len(jax.devices())
    M = 4 if n_dev >= 4 else n_dev
    steps = steps or (10 if quick else 16)
    for W in (2048, 4096, 8192):
        s, be = _overlap_probe(W, M, steps)
        if M == 1 or s["fwd_gossip_overlap_s"] > 0:
            break
        print(f"# no overlap measured at W={W} (fast runner retires "
              f"gossip within dispatch turnaround); doubling probe width",
              flush=True)
    tl = be.timeline.summary()
    for stage, total in sorted(tl["stage_s"].items()):
        emit(f"table4.overlap.stage.{stage}", total / steps * 1e6,
             f"inflight_s={total:.3f}")
    emit("table4.overlap.fwd_gossip",
         s["fwd_gossip_overlap_s"] / steps * 1e6,
         f"overlap_s={s['fwd_gossip_overlap_s']:.3f};"
         f"events={int(s['overlap_events'])};"
         f"wall_s={s['pipeline_wall_s']:.3f};M={M};W={W}")
    # execution-level accounting (zero here by construction — one stream;
    # see benchmarks.stream_stages for the streams>1 numbers)
    emit("table4.overlap.exec", s["exec_overlap_s"] / steps * 1e6,
         f"exec_overlap_s={s['exec_overlap_s']:.3f};"
         f"streams={int(s['streams'])};see=stream_stages")
    out_dir = os.path.join(os.path.dirname(__file__), "results")
    os.makedirs(out_dir, exist_ok=True)
    path = be.timeline.dump(os.path.join(out_dir,
                                         "BENCH_overlap_stages.json"))
    print(f"# wrote {path} ({len(be.timeline.events)} stage events)",
          flush=True)
    # acceptance: with real gossip (M > 1) the engine must exhibit
    # measured forward/gossip overlap — the monolithic step cannot
    if M > 1:
        assert s["fwd_gossip_overlap_s"] > 0, (
            "pipeline engine showed no fwd/gossip overlap up to W=8192")
        assert s["overlap_events"] > 0
    return s


def _overlap_probe(W, M, steps):
    """One probe run at MLP width ``W``; returns (summary, backend)."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core import make_backend
    from repro.optim import constant, momentum

    def loss_fn(p, b):
        h = jnp.tanh(b["x"] @ p["l1"])
        h = jnp.tanh(h @ p["l2"])
        logits = h @ p["l3"]
        ce = -jnp.mean(jax.nn.log_softmax(logits)[
            jnp.arange(logits.shape[0]), b["labels"]])
        return ce, {}

    k = jax.random.PRNGKey(0)
    params = {"l1": jax.random.normal(k, (64, W)) * 0.05,
              "l2": jax.random.normal(k, (W, W)) * 0.05,
              "l3": jax.random.normal(k, (W, 10)) * 0.05}
    be = make_backend("prod", "layup", M=M, loss_fn=loss_fn,
                      optimizer=momentum(0.9), schedule=constant(0.05),
                      fb_ratio=2, update_delay=1, overlap=True,
                      measure_drift=False)
    st = be.init(jax.random.PRNGKey(0), params)
    from repro.launch.mesh import data_axes
    bsh = NamedSharding(be.mesh, P(data_axes(be.mesh)))
    rng = np.random.default_rng(7)
    batches = [jax.device_put(
        {"x": rng.standard_normal((M, 16, 64)).astype(np.float32),
         "labels": rng.integers(0, 10, (M, 16))}, bsh) for _ in range(4)]
    jax.block_until_ready(batches)
    # the measuring loop must NOT materialize metrics per step — blocking
    # on a loss each iteration would serialize exactly the overlap being
    # measured (metrics stay futures; summary() converts at the end)
    for t in range(steps):
        st, _ = be.step(st, batches[t % 4], None)
    return be.summary(), be


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=None)
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    main(iters=args.iters, quick=args.quick)
