"""Paper Table 4 analogue: Model FLOPs Utilization per algorithm.

Wall-clock MFU cannot be measured on this CPU container; the event-driven
simulator models each algorithm's schedule (barriers, overlap, NIC
serialization) on the paper's two hardware configs. Reported MFU =
kernel_mfu × compute_utilization — the schedule-induced component the paper
attributes the LayUp gain to (§5.3)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import dump_json, emit, section
from repro.core.simulator import HardwareModel, simulate

ALGOS = ["ddp", "co2", "slowmo", "gosgd", "adpsgd", "layup"]

CONFIGS = {
    # GPT-2 Medium pre-training, 8×A100-SXM4-40G (paper C2)
    "gpt2-medium-pretrain": dict(
        M=8, hw=HardwareModel(fwd_time=0.11, bwd_ratio=2.0, num_layers=24,
                              model_bytes=1.6e9, bandwidth=40e9,
                              allreduce_bandwidth=75e9, kernel_mfu=0.75)),
    # GPT-2 XL finetuning, 4×H100 (paper C3) — smaller batch, comm-bound
    "gpt2-xl-finetune": dict(
        M=4, hw=HardwareModel(fwd_time=0.095, bwd_ratio=2.0, num_layers=48,
                              model_bytes=6.4e9, bandwidth=45e9,
                              allreduce_bandwidth=55e9, kernel_mfu=0.65)),
}


def main(iters=None, quick=False):
    if iters is None:  # CI smoke (--quick): tiny config, same assertions
        iters = 40 if quick else 200
    section("Table 4 analogue — modeled MFU per algorithm")
    out = {}
    for cname, cfg in CONFIGS.items():
        for algo in ALGOS:
            r = simulate(algo, M=cfg["M"], iters=iters, hw=cfg["hw"],
                         sync_every=20)
            out[(cname, algo)] = r.mfu
            emit(f"table4.{cname}.{algo}", r.total_time / iters * 1e6,
                 f"mfu={100 * r.mfu:.2f}%;util={r.utilization:.3f}")
    # paper's qualitative claim: layup >= ddp on both configs
    for cname in CONFIGS:
        assert out[(cname, "layup")] >= out[(cname, "ddp")] - 1e-9, cname

    section("Decoupled execution — fwd/bwd thread lanes (PD-ASGD §3)")
    for cname, cfg in CONFIGS.items():
        base = simulate("layup", M=cfg["M"], iters=iters, hw=cfg["hw"])
        r1 = None
        for R, D in ((1, 1), (2, 1), (4, 1)):
            r = simulate("layup", M=cfg["M"], iters=iters, hw=cfg["hw"],
                         fb_ratio=R, update_delay=D)
            r1 = r if (R, D) == (1, 1) else r1
            emit(f"table4.{cname}.layup.R{R}D{D}",
                 r.total_time / iters * 1e6,
                 f"mfu={100 * r.mfu:.2f}%;fwd_per_s={r.fwd_passes_per_s:.2f};"
                 f"upd_per_s={r.updates_per_s:.2f};"
                 f"grad_stale_s={r.mean_grad_staleness:.3f}")
        # decoupled lanes never stall on the NIC → MFU pins at the kernel
        # ceiling and can't fall below the coupled schedule
        assert r1.mfu >= base.mfu - 1e-9, cname
    dump_json("table4_mfu", prefix="table4.")
    return out


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=None)
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    main(iters=args.iters, quick=args.quick)
