"""Measured EXECUTION overlap — per-group streams + one-sided signal gossip.

``table4_mfu.measured_overlap`` shows *dispatch* overlap: the single-stream
pipeline engine runs the host ahead of the device, but one CPU PJRT stream
still serializes execution, so ``BENCH_overlap_stages.json`` reports
``streams: 1`` and ``exec_overlap_s: 0.0``. This benchmark runs the same
decoupled workload on the stream engine (``streams > 1``, DESIGN.md §13):
each forward slice and the per-group gossip stage execute on their own
stream (host threads off-TPU), shipping the PR-4 flat group plane across
the boundary through one-sided signal slots. The timeline then records
true execution spans, and ``exec_overlap_s`` integrates the seconds during
which 2+ streams were simultaneously busy.

Nightly artifact: ``BENCH_stream_stages.json``. Gates (CI fails otherwise):

* M > 1 ⇒ ``streams >= 2`` and ``exec_overlap_s > 0`` — with the same
  width auto-scale guard as table4 (a fast runner can retire a W=2048
  gossip before the fwd stream's slice finishes; the probe doubles the
  width up to 8192 before the assert fires).
* ``streams > 1`` numerics are loss-EXACT vs the single-stream engine on
  every step — measured concurrency must not change a single bit.
"""
from __future__ import annotations

import os

import numpy as np

from benchmarks.common import dump_json, emit, ensure_host_devices, section

N_STREAMS = 3  # fwd | update | gossip (R+2-capped inside the engine)


def main(steps=None, quick=False):
    import jax

    section("Measured execution overlap — stream engine (DESIGN.md §13)")
    n_dev = len(jax.devices())
    M = 4 if n_dev >= 4 else n_dev
    steps = steps or (10 if quick else 16)
    for W in (2048, 4096, 8192):
        base, stream = _probe_pair(W, M, steps)
        if M == 1 or stream["exec_overlap_s"] > 0:
            break
        print(f"# no exec overlap at W={W} (stage executions retired "
              f"faster than the streams interleave); doubling probe width",
              flush=True)

    # exactness gate: same data, same schedule, different executor — the
    # per-step losses must match bit-for-bit
    assert base["losses"] == stream["losses"], (
        "streams>1 loss diverged from the single-stream engine: "
        f"{base['losses']} vs {stream['losses']}")

    emit("streams.baseline.wall", base["wall_s"] / steps * 1e6,
         f"wall_s={base['wall_s']:.3f};streams={int(base['streams'])};"
         f"M={M};W={W}")
    emit("streams.exec.wall", stream["wall_s"] / steps * 1e6,
         f"wall_s={stream['wall_s']:.3f};streams={int(stream['streams'])};"
         f"M={M};W={W}")
    emit("streams.exec.overlap", stream["exec_overlap_s"] / steps * 1e6,
         f"exec_overlap_s={stream['exec_overlap_s']:.3f};"
         f"signal_wait_s={stream['signal_wait_s']:.3f};exact=1")
    for name, busy in sorted(stream["stream_busy_s"].items()):
        emit(f"streams.exec.busy.{name}", busy / steps * 1e6,
             f"busy_s={busy:.3f}")

    # acceptance: real streams must show measured EXECUTION concurrency —
    # the single-stream engine structurally cannot (its summary pins
    # streams=1, exec_overlap_s=0.0)
    if M > 1:
        assert stream["streams"] >= 2
        assert stream["exec_overlap_s"] > 0, (
            "stream engine showed no execution overlap up to W=8192")
    assert base["streams"] == 1 and base["exec_overlap_s"] == 0.0

    dump_json("stream_stages", prefix="streams.")
    return stream


def _probe_pair(W, M, steps):
    """Run the single-stream baseline and the stream engine on identical
    data; return both summaries (+ per-step losses, materialized only
    AFTER each measuring loop so blocking never serializes the overlap
    under test)."""
    out = []
    for streams in (1, N_STREAMS):
        s = _probe(W, M, steps, streams)
        out.append(s)
    return tuple(out)


def _probe(W, M, steps, streams):
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core import make_backend
    from repro.launch.mesh import data_axes
    from repro.optim import constant, momentum

    def loss_fn(p, b):
        h = jnp.tanh(b["x"] @ p["l1"])
        h = jnp.tanh(h @ p["l2"])
        logits = h @ p["l3"]
        ce = -jnp.mean(jax.nn.log_softmax(logits)[
            jnp.arange(logits.shape[0]), b["labels"]])
        return ce, {}

    k = jax.random.PRNGKey(0)
    params = {"l1": jax.random.normal(k, (64, W)) * 0.05,
              "l2": jax.random.normal(k, (W, W)) * 0.05,
              "l3": jax.random.normal(k, (W, 10)) * 0.05}
    be = make_backend("prod", "layup", M=M, loss_fn=loss_fn,
                      optimizer=momentum(0.9), schedule=constant(0.05),
                      fb_ratio=2, update_delay=1, overlap=True,
                      streams=streams, measure_drift=False)
    st = be.init(jax.random.PRNGKey(0), params)
    bsh = NamedSharding(be.mesh, P(data_axes(be.mesh)))
    rng = np.random.default_rng(7)
    batches = [jax.device_put(
        {"x": rng.standard_normal((M, 16, 64)).astype(np.float32),
         "labels": rng.integers(0, 10, (M, 16))}, bsh) for _ in range(4)]
    jax.block_until_ready(batches)
    losses = []
    for t in range(steps):
        st, m = be.step(st, batches[t % 4], None)
        losses.append(m["loss"])  # future / TaskOutput — no block here
    s = be.summary()  # finalizes the engine, then the timeline
    tl = be.timeline.summary()
    s["losses"] = [float(v) for v in losses]
    s["stream_busy_s"] = tl["stream_busy_s"]
    s["wall_s"] = tl["wall_s"]
    if streams > 1:
        out_dir = os.path.join(os.path.dirname(__file__), "results")
        os.makedirs(out_dir, exist_ok=True)
        path = be.timeline.dump(os.path.join(out_dir,
                                             "BENCH_stream_timeline.json"))
        print(f"# wrote {path} ({len(be.timeline.events)} exec events)",
              flush=True)
        be.engine.close()
    return s


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    ensure_host_devices(4)
    main(steps=args.steps, quick=args.quick)
