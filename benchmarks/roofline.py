"""Aggregate the dry-run roofline JSONs into the §Roofline table.

Reads benchmarks/results/dryrun/*.json (produced by
``python -m repro.launch.dryrun``) and prints a markdown table plus CSV
lines: per (arch × shape × mesh): the three roofline terms, the dominant
bottleneck, MODEL_FLOPS/HLO ratio and the memory estimate.
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit, section

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results", "dryrun")

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
ARCH_ORDER = [
    "jamba-v0.1-52b", "qwen2-vl-2b", "mamba2-780m", "mixtral-8x7b",
    "granite-8b", "qwen3-moe-30b-a3b", "yi-34b", "stablelm-1.6b",
    "moonshot-v1-16b-a3b", "whisper-large-v3",
]


def load():
    out = {}
    for path in glob.glob(os.path.join(RESULTS_DIR, "*.json")):
        with open(path) as f:
            d = json.load(f)
        stem = os.path.basename(path)[:-5]
        base = f"{d['arch']}_{d['shape']}_{d['mesh']}_{d['algo']}"
        variant = stem[len(base):].lstrip("_") or "base"
        out[(d["arch"], d["shape"], d["mesh"], d["algo"], variant)] = d
    return out


def fmt_ms(s):
    return f"{s * 1e3:.2f}"


def tuned_schedule():
    """Surface the autotuner's pick next to the analytic table: when the
    nightly ``BENCH_autotune_record.json`` artifact exists, print the
    measured best schedule and how far it sits from the hand-picked
    default (see benchmarks/autotune.py and DESIGN.md §16)."""
    path = os.path.join(os.path.dirname(__file__), "results",
                        "BENCH_autotune_record.json")
    if not os.path.exists(path):
        return None
    from repro.launch.tuner import load_tuning
    rec = load_tuning(path)
    if rec is None:
        return None
    section("Tuned schedule (from autotune artifact)")
    print(f"# key={rec.key}")
    print(f"# best={rec.best['label']} score={rec.score:.4f} "
          f"over {len(rec.table)} candidates")
    emit("roofline.tuned_schedule", rec.table[0]["step_time_s"] * 1e6,
         f"label={rec.best['label']};score={rec.score:.4f};"
         f"candidates={len(rec.table)}")
    return rec


def main(quick=False):
    section("Roofline table (from dry-run artifacts)")
    tuned_schedule()
    data = load()
    if not data:
        print("# no dry-run results yet — run: "
              "PYTHONPATH=src python -m repro.launch.dryrun --all")
        return {}
    print("| arch | shape | mesh | algo | t_comp ms | t_mem ms | t_coll ms |"
          " dominant | useful | HBM GB | notes |")
    print("|---|---|---|---|---|---|---|---|---|---|---|")
    rows = sorted(data.items(), key=lambda kv: (
        ARCH_ORDER.index(kv[0][0]) if kv[0][0] in ARCH_ORDER else 99,
        SHAPE_ORDER.index(kv[0][1]) if kv[0][1] in SHAPE_ORDER else 99,
        kv[0][2]))
    for (arch, shape, mesh, algo, variant), d in rows:
        hbm = d["memory"].get("peak_hbm_corrected", 0) / 1e9
        label = algo if variant == "base" else f"{algo}+{variant}"
        print(f"| {arch} | {shape} | {mesh} | {label} | "
              f"{fmt_ms(d['t_compute'])} | {fmt_ms(d['t_memory'])} | "
              f"{fmt_ms(d['t_collective'])} | {d['dominant']} | "
              f"{d['useful_ratio']:.2f} | {hbm:.1f} | {d['notes']} |")
        emit(f"roofline.{arch}.{shape}.{mesh}.{algo}.{variant}",
             d["t_compute"] * 1e6,
             f"dom={d['dominant']};useful={d['useful_ratio']:.2f}")
    return data


if __name__ == "__main__":
    main()
