"""Serve-under-training benchmark: live inference against the trainer's
read plane, both at full tilt on the SAME host devices.

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python -m benchmarks.serve_under_training [--quick]

Three phases on one process:

1. **training baseline** — the decoupled pipeline trainer (M workers,
   ``overlap=True``) runs alone; measures the no-serving step time.
2. **concurrent** — the trainer runs again at full tilt in a background
   thread with a :class:`repro.serving.PlanePublisher` attached, while an
   open-loop synthetic request generator feeds an
   :class:`repro.serving.AdmissionQueue` and the main thread drives the
   :class:`repro.serving.LiveServer` (continuous batching + gated
   checkpoint-free weight swaps). Mid-window the drift gate is forced
   shut until it has rejected at least one plane, so the gated-rejection
   path is exercised on every run.
3. **report** — p50/p99 token and request latency, swap/rejection
   accounting, and the training step-time delta vs the baseline, dumped
   as ``BENCH_serve_latency.json`` for the nightly artifact trail.

Token latency is the inter-token measure (wall time of one busy decode
step); request latency is submit → final token. Training throughput in
the concurrent window is measured exactly like the baseline: a timed run
of K steps with metrics kept as futures (blocking per step would
serialize the pipeline being measured).
"""
from __future__ import annotations

import threading
import time

import numpy as np

from benchmarks.common import dump_json, emit, section


def _pct(samples, q):
    return float(np.percentile(np.asarray(samples, np.float64), q))


def _build(M, quick):
    import jax
    from repro.configs.base import ModelConfig
    from repro.core import make_backend
    from repro.models import build_model
    from repro.optim import constant, momentum
    from repro.serving import PlanePublisher

    cfg = ModelConfig(name="tiny-lm", family="dense", num_layers=2,
                      d_model=64 if quick else 128, num_heads=4,
                      num_kv_heads=2, d_ff=128 if quick else 256,
                      vocab_size=128)
    model = build_model(cfg)
    pub = PlanePublisher()
    be = make_backend("prod", "layup", M=M,
                      loss_fn=lambda p, b: model.loss_fn(p, b, block_k=32),
                      optimizer=momentum(0.9), schedule=constant(0.02),
                      fb_ratio=2, update_delay=1, overlap=True,
                      measure_drift=True, publisher=pub)
    params = model.init(jax.random.PRNGKey(0))
    state = be.init(jax.random.PRNGKey(1), params)
    return cfg, model, pub, be, params, state


def _batches(cfg, be, M, n=4, B=4, T=32):
    import jax
    from repro.data.synthetic import SyntheticLM, make_worker_batches

    ds = SyntheticLM(vocab=cfg.vocab_size, seq_len=T, temperature=1.2)
    out = [make_worker_batches(ds, M, B, t) for t in range(n)]
    if M > 1:
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.mesh import data_axes
        bsh = NamedSharding(be.mesh, P(data_axes(be.mesh)))
        out = [jax.device_put(b, bsh) for b in out]
    else:
        import jax.numpy as jnp
        out = [jax.tree.map(jnp.asarray, b) for b in out]
    jax.block_until_ready(out)
    return out


def _timed_steps(be, state, batches, steps):
    """Run ``steps`` trainer steps without materializing metrics, block at
    the end; returns (state, wall_seconds)."""
    import jax

    t0 = time.monotonic()
    for t in range(steps):
        state, _ = be.step(state, batches[t % len(batches)], None)
    jax.block_until_ready(jax.tree.leaves(state["read"]))
    return state, time.monotonic() - t0


def main(quick=False):
    import jax

    from repro.launch.serve import Request, ServeLoop
    from repro.serving import AdmissionQueue, LiveServer, SwapPolicy

    n_dev = len(jax.devices())
    M = 4 if n_dev >= 4 else n_dev
    warmup, base_steps = 2, (6 if quick else 12)
    conc_steps = 12 if quick else 30
    prompt_len, max_new = 4, 8
    gen_interval_s = 0.02 if quick else 0.05

    section(f"Serve-under-training — M={M} workers, pipeline trainer + "
            f"live serving on the same {n_dev} host devices")
    cfg, model, pub, be, params, state = _build(M, quick)
    batches = _batches(cfg, be, M)

    # ---- phase 1: training alone (the no-serving step-time baseline) ------
    state, _ = _timed_steps(be, state, batches, warmup)
    state, base_wall = _timed_steps(be, state, batches, base_steps)
    base_step_s = base_wall / base_steps
    emit("serve.train_step.baseline", base_step_s * 1e6,
         f"steps={base_steps};M={M}")
    pub_before = pub.stats.published

    # ---- phase 2: trainer at full tilt + live serving concurrently --------
    loop = ServeLoop(model, params, num_slots=4,
                     max_len=prompt_len + max_new)
    adm = AdmissionQueue(max_depth=16)
    # M=1 never stamps version clocks → leave the staleness gate off there
    policy = SwapPolicy(max_staleness=None if M == 1 else float(base_steps
                                                                + conc_steps))
    srv = LiveServer(loop, be.part, pub, policy=policy, admission=adm)

    trainer_done = threading.Event()
    conc_wall_box = {}

    def trainer():
        nonlocal state
        state, wall = _timed_steps(be, state, batches, conc_steps)
        conc_wall_box["wall"] = wall
        trainer_done.set()

    submit_t = {}
    gen_stats = {"submitted": 0, "rejected": 0}

    def generator():
        uid = 0
        rs = np.random.default_rng(3)
        while not trainer_done.is_set():
            req = Request(uid=uid,
                          prompt=rs.integers(0, cfg.vocab_size, prompt_len,
                                             dtype=np.int32),
                          max_new_tokens=max_new)
            now = time.monotonic()
            ticket = adm.submit(req, deadline_s=now + 2.0, now=now)
            gen_stats["submitted"] += 1
            if ticket.accepted:
                submit_t[uid] = (now, req)
            else:
                gen_stats["rejected"] += 1
            uid += 1
            time.sleep(gen_interval_s)  # open loop: fixed arrival rate

    threads = [threading.Thread(target=trainer),
               threading.Thread(target=generator)]
    for th in threads:
        th.start()

    step_lat, req_lat = [], []
    done_uids = set()
    gate_forced = False
    while (not trainer_done.is_set() or adm.depth
           or any(s.req is not None for s in loop.slots)):
        t0 = time.monotonic()
        busy = srv.step()
        if busy:
            step_lat.append(time.monotonic() - t0)
        else:
            time.sleep(0.002)
        for uid, (t_sub, req) in submit_t.items():
            if req.done and uid not in done_uids:
                done_uids.add(uid)
                req_lat.append(time.monotonic() - t_sub)
        # force the drift gate shut once swapping works, until it has
        # rejected a plane — exercises the gated-rejection path every run
        if srv.swap_count >= 1 and not gate_forced:
            policy.max_drift = -1.0
            gate_forced = True
        if gate_forced and policy.gated_rejections >= 1:
            policy.max_drift = None
    for th in threads:
        th.join()
    srv.poll()  # pick up the final publish

    # ---- phase 3: report ---------------------------------------------------
    s = srv.stats()
    conc_step_s = conc_wall_box["wall"] / conc_steps
    tokens = s["tokens_emitted"]
    trainer_pub = pub.stats.published - pub_before
    emit("serve.train_step.concurrent", conc_step_s * 1e6,
         f"steps={conc_steps};delta_pct="
         f"{100 * (conc_step_s - base_step_s) / base_step_s:.1f}")
    if step_lat:
        emit("serve.token_latency", _pct(step_lat, 50) * 1e6,
             f"p50_us={_pct(step_lat, 50) * 1e6:.0f};"
             f"p99_us={_pct(step_lat, 99) * 1e6:.0f};n={len(step_lat)}")
    if req_lat:
        emit("serve.request_latency", _pct(req_lat, 50) * 1e6,
             f"p50_us={_pct(req_lat, 50) * 1e6:.0f};"
             f"p99_us={_pct(req_lat, 99) * 1e6:.0f};n={len(req_lat)}")
    emit("serve.tokens", 0.0,
         f"tokens={tokens};requests_done={s['requests_completed']};"
         f"slot_occupancy={s['slot_occupancy']:.3f}")
    emit("serve.swaps", 0.0,
         f"swaps={s['swaps']};publishes={trainer_pub};"
         f"rejected_gated={s['swap_rejected_gated']};"
         f"reasons={s['swap_reasons']}")
    emit("serve.admission", 0.0,
         f"submitted={gen_stats['submitted']};"
         f"rejected={s['admission']['rejected']};"
         f"deadline_dropped={s['admission']['deadline_dropped']}")
    path = dump_json("serve_latency", prefix="serve.")

    # acceptance: tokens actually served while the trainer made progress
    # in the same window, via checkpoint-free gated swaps
    assert tokens > 0, "no tokens served during the training window"
    assert trainer_pub >= conc_steps, "trainer under-published"
    assert s["swaps"] >= 1, "no live swap happened"
    assert s["swap_rejected_gated"] >= 1, "drift gate never exercised"
    print(f"# OK: {tokens} tokens served across {s['swaps']} live swaps "
          f"while the trainer ran {conc_steps} steps "
          f"({100 * (conc_step_s - base_step_s) / base_step_s:+.1f}% "
          f"step time); {path}", flush=True)
    return s


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--devices", type=int, default=4)
    args = ap.parse_args()
    from benchmarks.common import ensure_host_devices
    ensure_host_devices(args.devices)
    main(quick=args.quick)
