"""Paper Tables 1–2 analogue (vision): convergence accuracy, TTC and TTA
for all algorithms on the synthetic-vision task (CIFAR stand-in — the
container has no GPUs or datasets; the task is a k-class Gaussian-prototype
problem with an MLP, trained by the same 6 algorithms; wall-clock comes from
the event-driven hardware simulator with ResNet-50-like timing).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.algo_runner import run_algorithm
from benchmarks.common import emit, section, time_to_target
from repro.core.simulator import HardwareModel
from repro.data.synthetic import SyntheticVision

ALGOS = ["ddp", "co2", "slowmo", "gosgd", "adpsgd", "layup"]

# ResNet-50 / CIFAR-ish timing on 3×A100 (paper C1): fwd 16.6 ms, bwd ~2×
HW = HardwareModel(fwd_time=0.0166, bwd_ratio=1.8, num_layers=50,
                   model_bytes=25.6e6 * 4, bandwidth=25e9,
                   allreduce_bandwidth=60e9, kernel_mfu=0.45)


def _problem(M):
    ds = SyntheticVision(num_classes=10, dim=128, snr=0.9, seed=0)
    eval_rng = np.random.default_rng(10_000)
    eval_batch = ds.sample(eval_rng, 2048)
    ex = jnp.asarray(eval_batch["x"])
    ey = jnp.asarray(eval_batch["labels"])

    def init(rng):
        k1, k2, k3 = jax.random.split(rng, 3)
        return {"l1": jax.random.normal(k1, (128, 256)) * 0.1,
                "l2": jax.random.normal(k2, (256, 256)) * 0.1,
                "l3": jax.random.normal(k3, (256, 10)) * 0.1}

    def forward(p, x):
        h = jnp.tanh(x @ p["l1"])
        h = jnp.tanh(h @ p["l2"])
        return h @ p["l3"]

    def loss_fn(p, batch):
        logits = forward(p, batch["x"])
        ce = -jnp.mean(jax.nn.log_softmax(logits)[
            jnp.arange(logits.shape[0]), batch["labels"]])
        return ce, {}

    @jax.jit
    def eval_fn(p):
        return jnp.mean((forward(p, ex).argmax(-1) == ey).astype(jnp.float32))

    return ds, init, loss_fn, eval_fn


def main(steps=400, M=8, quick=False):
    section("Table 1/2 analogue — vision convergence (accuracy/TTC/TTA)")
    if quick:
        steps = 150
    ds, init, loss_fn, eval_fn = _problem(M)
    results = {}
    for algo in ALGOS:
        r = run_algorithm(algo, ds=ds, init_params_fn=init, loss_fn=loss_fn,
                          eval_fn=eval_fn, M=M, steps=steps,
                          batch_per_worker=64, lr=0.08, hw=HW)
        results[algo] = r
        emit(f"table1.{algo}.accuracy", r.iter_time * 1e6,
             f"acc={r.eval_metric[-1]:.4f};ttc_s={r.total_time:.1f};"
             f"mfu={r.mfu:.3f}")
    # TTA: target = best accuracy of the worst algorithm (paper's method)
    target = min(r.eval_metric.max() for r in results.values())
    for algo, r in results.items():
        # find first eval step crossing target
        idx = np.argmax(r.eval_metric >= target)
        tta = (r.eval_steps[idx] * r.iter_time
               if (r.eval_metric >= target).any() else float("nan"))
        emit(f"table2.{algo}.tta", tta * 1e6, f"target={target:.4f}")
    return results


if __name__ == "__main__":
    main()
