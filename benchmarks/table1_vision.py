"""Paper Tables 1–2 analogue (vision): convergence accuracy, TTC and TTA
for all algorithms on the synthetic-vision task (CIFAR stand-in — the
container has no GPUs or datasets; the task is a k-class Gaussian-prototype
problem with an MLP, trained by the same 6 algorithms; wall-clock comes from
the event-driven hardware simulator with ResNet-50-like timing).

``--backend prod`` runs the layup family through the production decoupled
shard_map lane (prod numerics joined with the same event-driven wall-clock)
— it needs one host device per worker, so the flag must be set before jax
initializes; the __main__ guard handles that, which is why every jax-touching
import in this module is deferred into the functions.

Every run emits metric-vs-wallclock curve rows
(``table1.<backend>.<algo>.curve.NNN`` → accuracy at that wall-clock) and
dumps them via ``benchmarks.common.dump_json`` so the nightly BENCH
trajectory captures convergence curves, not just endpoints.
"""
from __future__ import annotations

ALGOS = ["ddp", "co2", "slowmo", "gosgd", "adpsgd", "layup"]

M_WORKERS = 8


def _hw():
    from repro.core.simulator import HardwareModel
    # ResNet-50 / CIFAR-ish timing on 3×A100 (paper C1): fwd 16.6ms, bwd ~2×
    return HardwareModel(fwd_time=0.0166, bwd_ratio=1.8, num_layers=50,
                         model_bytes=25.6e6 * 4, bandwidth=25e9,
                         allreduce_bandwidth=60e9, kernel_mfu=0.45)


def _problem(M):
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.data.synthetic import SyntheticVision

    ds = SyntheticVision(num_classes=10, dim=128, snr=0.9, seed=0)
    eval_rng = np.random.default_rng(10_000)
    eval_batch = ds.sample(eval_rng, 2048)
    ex = jnp.asarray(eval_batch["x"])
    ey = jnp.asarray(eval_batch["labels"])

    def init(rng):
        k1, k2, k3 = jax.random.split(rng, 3)
        return {"l1": jax.random.normal(k1, (128, 256)) * 0.1,
                "l2": jax.random.normal(k2, (256, 256)) * 0.1,
                "l3": jax.random.normal(k3, (256, 10)) * 0.1}

    def forward(p, x):
        h = jnp.tanh(x @ p["l1"])
        h = jnp.tanh(h @ p["l2"])
        return h @ p["l3"]

    def loss_fn(p, batch):
        logits = forward(p, batch["x"])
        ce = -jnp.mean(jax.nn.log_softmax(logits)[
            jnp.arange(logits.shape[0]), batch["labels"]])
        return ce, {}

    @jax.jit
    def eval_fn(p):
        return jnp.mean((forward(p, ex).argmax(-1) == ey).astype(jnp.float32))

    return ds, init, loss_fn, eval_fn


def emit_curve(tag: str, r) -> None:
    """Metric-vs-wallclock curve rows: one row per eval point, us_per_call
    column = modeled wall-clock (µs) at that step."""
    from benchmarks.common import emit
    for i, (step, metric) in enumerate(zip(r.eval_steps, r.eval_metric)):
        emit(f"{tag}.curve.{i:03d}", step * r.iter_time * 1e6,
             f"metric={metric:.4f};step={int(step)}")


def main(steps=400, M=M_WORKERS, quick=False, backend="sim",
         fb_ratio=1, update_delay=0):
    import numpy as np

    from benchmarks.algo_runner import run_algorithm
    from benchmarks.common import dump_json, emit, section

    section(f"Table 1/2 analogue — vision convergence "
            f"(accuracy/TTC/TTA, backend={backend})")
    if quick:
        steps = 150
    ds, init, loss_fn, eval_fn = _problem(M)
    # the prod lane IS the layup gossip ring — barrier algorithms have no
    # production decoupled form (repro.core.backend)
    algos = ALGOS if backend == "sim" else ["layup"]
    results = {}
    for algo in algos:
        r = run_algorithm(algo, ds=ds, init_params_fn=init, loss_fn=loss_fn,
                          eval_fn=eval_fn, M=M, steps=steps,
                          batch_per_worker=64 * max(fb_ratio, 1), lr=0.08,
                          hw=_hw(), backend=backend, fb_ratio=fb_ratio,
                          update_delay=update_delay)
        results[algo] = r
        tag = f"table1.{algo}" if backend == "sim" else f"table1.prod.{algo}"
        emit(f"{tag}.accuracy", r.iter_time * 1e6,
             f"acc={r.eval_metric[-1]:.4f};ttc_s={r.total_time:.1f};"
             f"mfu={r.mfu:.3f}")
        emit_curve(tag, r)
    # TTA: target = best accuracy of the worst algorithm (paper's method)
    target = min(r.eval_metric.max() for r in results.values())
    for algo, r in results.items():
        idx = np.argmax(r.eval_metric >= target)
        tta = (r.eval_steps[idx] * r.iter_time
               if (r.eval_metric >= target).any() else float("nan"))
        tag = f"table2.{algo}" if backend == "sim" else f"table2.prod.{algo}"
        emit(f"{tag}.tta", tta * 1e6, f"target={target:.4f}")
    dump_json(f"table1_vision_{backend}" if backend != "sim"
              else "table1_vision", prefix=("table1.", "table2."))
    return results


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--backend", choices=["sim", "prod"], default="sim")
    ap.add_argument("--fb-ratio", type=int, default=1)
    ap.add_argument("--update-delay", type=int, default=0)
    args = ap.parse_args()
    if args.backend == "prod":
        # one host device per worker; must be set before jax initializes
        from benchmarks.common import ensure_host_devices
        ensure_host_devices(M_WORKERS)
    main(steps=args.steps, quick=args.quick, backend=args.backend,
         fb_ratio=args.fb_ratio, update_delay=args.update_delay)
