"""Shared training-loop runner for the algorithm-comparison benchmarks.

Drives BOTH execution backends through the unified ``TrainerBackend``
protocol (``repro.core.backend``): the numeric sim backend (vmapped M
workers on CPU) for LOSS/ACCURACY curves and the event-driven simulator for
WALL-CLOCK per iteration, stepped in lock-step and joined — the paper's
plots are metric-vs-wallclock.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import consensus, make_backend
from repro.core.simulator import HardwareModel
from repro.optim import constant, linear_warmup_cosine, momentum


@dataclass
class RunResult:
    losses: np.ndarray
    disagreement: np.ndarray
    eval_metric: np.ndarray  # accuracy or perplexity at eval points
    eval_steps: np.ndarray
    iter_time: float
    total_time: float
    mfu: float
    staleness: np.ndarray = None  # per-step mean layer staleness
    overlap: Optional[Dict] = None  # measured stage overlap (overlap=True)


def run_algorithm(algo_name: str, *, ds, init_params_fn, loss_fn, eval_fn,
                  M: int, steps: int, batch_per_worker: int, lr: float,
                  hw: HardwareModel, eval_every: int = 25,
                  straggler_delays: Optional[np.ndarray] = None,
                  warmup: int = 20, seed: int = 0,
                  fb_ratio: int = 1, update_delay: int = 0,
                  backend: str = "sim", overlap: bool = False) -> RunResult:
    """``backend`` selects the numeric engine: "sim" (vmapped workers, any
    algorithm) or "prod" (the decoupled shard_map lane on a real device
    mesh, layup family only — needs M local devices). Both consume the same
    worker batches and report the same metric keys, so the wall-clock join
    with the event backend is identical. ``overlap=True`` (prod only) runs
    the stage-graph pipeline engine and attaches its measured per-stage
    timeline summary as ``RunResult.overlap``."""
    from repro.data.synthetic import make_worker_batches
    sched = linear_warmup_cosine(lr, warmup, steps,
                                 warmup_lr=lr * 0.3)
    decoupled = dict(fb_ratio=fb_ratio, update_delay=update_delay)
    if (fb_ratio > 1 or update_delay > 0) and not algo_name.startswith(
            ("layup", "gosgd")):
        # keep the loss and wall-clock lanes consistent: the event backend
        # has no decoupled model for barrier/rendezvous algorithms, so a
        # decoupled numeric run would be joined with coupled timing
        raise ValueError(
            f"decoupled execution is only benchmarkable for the gossip "
            f"family, not {algo_name!r}")
    if backend not in ("sim", "prod"):
        raise ValueError(f"numeric backend must be 'sim' or 'prod', "
                         f"not {backend!r}")
    if overlap and backend != "prod":
        raise ValueError("overlap=True is a prod-backend engine option")
    # overlap is a prod-engine option only — it must not reach the event
    # backend's kwargs
    num_kw = dict(decoupled, overlap=True) if overlap else decoupled
    num = make_backend(backend, algo_name, M=M, loss_fn=loss_fn,
                       optimizer=momentum(0.9), schedule=sched,
                       straggler_delays=straggler_delays, **num_kw)
    ev = make_backend("event", algo_name, M=M, hw=hw,
                      straggler_delays=straggler_delays, **decoupled)

    st = num.init(jax.random.PRNGKey(seed),
                  init_params_fn(jax.random.PRNGKey(seed + 1)))
    ev_st = ev.init(jax.random.PRNGKey(seed))
    rng = jax.random.PRNGKey(seed + 2)
    raw, evals, esteps = [], [], []
    for t in range(steps):
        batch = jax.tree.map(jnp.asarray,
                             make_worker_batches(ds, M, batch_per_worker, t))
        rng, r = jax.random.split(rng)
        st, metrics = num.step(st, batch, r)
        ev_st, _ = ev.step(ev_st, None, None)
        # keep metrics as futures — a float() here would synchronize every
        # step and serialize exactly the overlap the pipeline engine
        # (overlap=True) exists to measure; conversion happens after the
        # loop. Eval points still synchronize, which is inherent to
        # evaluating a consensus snapshot.
        raw.append(metrics)
        if (t + 1) % eval_every == 0 or t == steps - 1:
            # prod-lane state is a dict whose read buffer is the flat
            # parameter plane — export_params unpacks it back to the
            # stacked tree eval_fn expects (DESIGN.md §11); sim state is
            # a TrainState
            params, weights = ((num.export_params(st), st["w"])
                               if isinstance(st, dict)
                               else (st.params, st.weights))
            xbar = consensus(params, weights)
            evals.append(float(eval_fn(xbar)))
            esteps.append(t + 1)

    losses = [float(m["loss"]) for m in raw]
    dis = [float(m["disagreement"]) for m in raw]
    stale = [float(m["staleness_mean"]) for m in raw]
    sim = ev.result()
    overlap_summary = None
    if overlap:
        num.timeline.finalize()
        overlap_summary = num.timeline.summary()
    return RunResult(np.array(losses), np.array(dis), np.array(evals),
                     np.array(esteps), sim.total_time / steps,
                     sim.total_time, sim.mfu, np.array(stale),
                     overlap_summary)
