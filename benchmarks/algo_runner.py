"""Shared training-loop runner for the algorithm-comparison benchmarks.

Runs the sim backend (vmapped M workers on CPU) for LOSS/ACCURACY curves and
the event-driven simulator (repro.core.simulator) for WALL-CLOCK per
iteration, then joins them — the paper's plots are metric-vs-wallclock.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import consensus, get_algorithm, make_sim_trainer
from repro.core.simulator import HardwareModel, simulate
from repro.optim import constant, linear_warmup_cosine, momentum


@dataclass
class RunResult:
    losses: np.ndarray
    disagreement: np.ndarray
    eval_metric: np.ndarray  # accuracy or perplexity at eval points
    eval_steps: np.ndarray
    iter_time: float
    total_time: float
    mfu: float


def run_algorithm(algo_name: str, *, ds, init_params_fn, loss_fn, eval_fn,
                  M: int, steps: int, batch_per_worker: int, lr: float,
                  hw: HardwareModel, eval_every: int = 25,
                  straggler_delays: Optional[np.ndarray] = None,
                  warmup: int = 20, seed: int = 0) -> RunResult:
    from repro.data.synthetic import make_worker_batches
    algo = get_algorithm(algo_name)
    sched = linear_warmup_cosine(lr, warmup, steps,
                                 warmup_lr=lr * 0.3)
    init_fn, step_fn = make_sim_trainer(algo, loss_fn, momentum(0.9),
                                        sched, M,
                                        straggler_delays=straggler_delays)
    st = init_fn(jax.random.PRNGKey(seed),
                 init_params_fn(jax.random.PRNGKey(seed + 1)))
    rng = jax.random.PRNGKey(seed + 2)
    losses, dis, evals, esteps = [], [], [], []
    for t in range(steps):
        batch = jax.tree.map(jnp.asarray,
                             make_worker_batches(ds, M, batch_per_worker, t))
        rng, r = jax.random.split(rng)
        st, metrics = step_fn(st, batch, r)
        losses.append(float(metrics["loss"]))
        dis.append(float(metrics["disagreement"]))
        if (t + 1) % eval_every == 0 or t == steps - 1:
            xbar = consensus(st.params, st.weights)
            evals.append(float(eval_fn(xbar)))
            esteps.append(t + 1)

    sim = simulate(algo_name if algo_name != "layup-block" else "gosgd",
                   M=M, iters=steps, hw=hw,
                   straggler_delays=straggler_delays)
    return RunResult(np.array(losses), np.array(dis), np.array(evals),
                     np.array(esteps), sim.total_time / steps,
                     sim.total_time, sim.mfu)
