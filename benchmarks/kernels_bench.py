"""Kernel microbenchmarks: us_per_call for the pure-jnp reference paths
(XLA-compiled) and, on small shapes, the interpret-mode Pallas kernels
(correctness-path timing only — interpret mode is not representative of TPU
throughput; the kernels are TPU deployment artifacts)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit, section
from repro.kernels import ops, ref
from repro.models.layers import flash_attention_jnp


def _bench(fn, *args, iters=10):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def main(quick=False):
    section("kernel microbenchmarks (CPU; Pallas timings are interpret-mode)")
    rng = jax.random.PRNGKey(0)

    # flash attention — jnp path at realistic-ish shape
    B, Hq, Hkv, S, D = 1, 8, 2, 1024, 64
    q = jax.random.normal(rng, (B, S, Hq, D), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(rng, 1), (B, S, Hkv, D))
    v = jax.random.normal(jax.random.fold_in(rng, 2), (B, S, Hkv, D))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    f = jax.jit(lambda q, k, v: flash_attention_jnp(
        q, k, v, q_positions=pos, k_positions=pos, block_k=256))
    us = _bench(f, q, k, v)
    flops = 2 * B * Hq * S * S * D * 2 / 2  # causal
    emit("kernel.flash_jnp.b1h8s1024", us,
         f"gflops={flops / us / 1e3:.1f}")

    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    us = _bench(jax.jit(lambda a, b, c: ref.attention_ref(a, b, c)), qt, kt, vt)
    emit("kernel.attention_naive.b1h8s1024", us, "")

    # ssd — jnp chunked vs sequential
    from repro.models.ssm import ssd_chunked
    b, l, h, p, n = 2, 512, 8, 64, 32
    x = jax.random.normal(rng, (b, l, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(rng, 3), (b, l, h)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(rng, 4), (h,)) * 0.3)
    Bm = jax.random.normal(jax.random.fold_in(rng, 5), (b, l, n)) * 0.5
    Cm = jax.random.normal(jax.random.fold_in(rng, 6), (b, l, n)) * 0.5
    us = _bench(jax.jit(lambda *a: ssd_chunked(*a, chunk=128)[0]),
                x, dt, A, Bm, Cm)
    emit("kernel.ssd_chunked_jnp.l512", us, "")

    # gossip mix fused vs unfused (the LayUp hot op)
    nelem = 4_000_000
    xx = jax.random.normal(rng, (nelem,), jnp.float32)
    rr = jax.random.normal(jax.random.fold_in(rng, 7), (nelem,))
    uu = jax.random.normal(jax.random.fold_in(rng, 8), (nelem,)) * 0.01
    fused = jax.jit(lambda x, r, u: ref.gossip_mix_ref(x, r, u, 0.6, 0.4))
    us = _bench(fused, xx, rr, uu)
    emit("kernel.gossip_mix_fused_jnp.4M", us,
         f"GBps={(4 * nelem * 4) / us / 1e3:.1f}")

    # quantized gossip wire: EF int8 quantize + dequant-mix (DESIGN.md §14)
    from repro.kernels.quantize import quant_layout
    res0 = jnp.zeros_like(xx)
    qfn = jax.jit(lambda x, r: ref.quantize_plane_ref(x, r))
    us = _bench(qfn, xx, res0)
    emit("kernel.quantize_plane_jnp.4M", us,
         f"GBps={(nelem * 4) / us / 1e3:.1f}")
    qq, ss, _ = qfn(xx, res0)
    dq = jax.jit(lambda x, q, s, u: ref.dequant_mix_ref(x, q, s, u,
                                                        0.6, 0.4))
    us = _bench(dq, xx, qq, ss, uu)
    rows, _, _ = quant_layout(nelem)
    emit("kernel.dequant_mix_jnp.4M", us,
         f"GBps={(nelem * 4) / us / 1e3:.1f};wire_rows={rows}")

    if not quick:
        # interpret-mode pallas on tiny shapes (correctness path)
        q2 = jax.random.normal(rng, (1, 2, 128, 32))
        k2 = jax.random.normal(rng, (1, 1, 128, 32))
        us = _bench(lambda a, b: ops.flash_attention(
            a, b, b, block_q=64, block_k=64, interpret=True), q2, k2, iters=2)
        emit("kernel.flash_pallas_interpret.s128", us, "not-TPU-representative")

        # gossip_mix + quantize/dequant pallas kernels, interpret mode
        nsmall = 8 * 128
        xs = jax.random.normal(rng, (nsmall,), jnp.float32)
        rs = jax.random.normal(jax.random.fold_in(rng, 9), (nsmall,))
        us_small = jax.random.normal(jax.random.fold_in(rng, 10),
                                     (nsmall,)) * 0.01
        us = _bench(lambda a, b, c: ops.gossip_mix(a, b, c, 0.6, 0.4,
                                                   interpret=True),
                    xs, rs, us_small, iters=2)
        emit("kernel.gossip_mix_pallas_interpret.1k", us,
             "not-TPU-representative")
        res_s = jnp.zeros_like(xs)
        us = _bench(lambda a, b: ops.quantize_plane(a, b, interpret=True),
                    xs, res_s, iters=2)
        emit("kernel.quantize_pallas_interpret.1k", us,
             "not-TPU-representative")
        qs, sc, _ = ops.quantize_plane(xs, res_s, interpret=True)
        us = _bench(lambda a, q, s, u: ops.dequant_mix(a, q, s, u, 0.6, 0.4,
                                                       interpret=True),
                    xs, qs, sc, us_small, iters=2)
        emit("kernel.dequant_mix_pallas_interpret.1k", us,
             "not-TPU-representative")


if __name__ == "__main__":
    main()
