"""Gossip/update-path benchmark: legacy per-step repack vs flat plane,
plus the quantized (int8) gossip wire.

The legacy decoupled lane re-packed every layer group with ``ravel_pytree``
on EVERY step and shipped a blanket-f32 wire; the flat-plane lane
(DESIGN.md §11) packs once at init and gossips the persistent per-group
buffers directly, in the params' dtype. ``wire="int8"`` (DESIGN.md §14)
further compresses the wire to int8 values + per-128-lane-row f32 scales
with error-feedback residuals. This benchmark times full decoupled steps
of the SAME workload through the lanes at several parameter sizes (small
batch, parameter-heavy MLP — the step cost is dominated by the
gossip/update path being compared), records the bytes-on-wire of one
plane for f32 vs bf16 vs int8 (bf16 must be exactly half of f32; int8
must be ≤ 0.55× bf16 at the largest size), and checks the quantized
wire's loss stays within tolerance of the exact param wire on the same
workload.

Emits ``gossip_path.*`` rows and dumps ``BENCH_gossip_path.json`` via
``common.dump_json`` — the nightly job runs ``--quick`` and uploads the
artifact, seeding the gossip-path perf trajectory. Asserts flat is
strictly faster per step than the legacy repack at the largest benchmarked
size (acceptance for the flat-plane PR).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import dump_json, emit, section

# (width, depth) of the MLP stack; params ≈ depth · width² floats
SIZES = [(256, 4), (512, 6), (1024, 8)]
SIZES_QUICK = [(128, 2), (256, 4)]


def _problem(width: int, depth: int, dtype):
    import jax
    import jax.numpy as jnp

    def loss_fn(p, b):
        h = b["x"]
        for blk in p["blocks"]:
            h = jnp.tanh(h @ blk["w"] + blk["b"])
        logits = (h @ p["head"]).astype(jnp.float32)
        ce = -jnp.mean(jax.nn.log_softmax(logits)[
            jnp.arange(logits.shape[0]), b["labels"]])
        return ce, {}

    k = jax.random.PRNGKey(0)
    params = {
        "blocks": [
            {"w": (jax.random.normal(jax.random.fold_in(k, i), (width, width))
                   * (1.0 / np.sqrt(width))).astype(dtype),
             "b": jnp.zeros((width,), dtype)}
            for i in range(depth)],
        "head": (jax.random.normal(jax.random.fold_in(k, 99), (width, 16))
                 * 0.05).astype(dtype),
    }
    return loss_fn, params


def _batch(M: int, b: int, width: int, seed: int):
    rng = np.random.default_rng(seed)
    return {"x": rng.standard_normal((M, b, width)).astype(np.float32),
            "labels": rng.integers(0, 16, (M, b))}


def _time_steps(be, params, width: int, M: int, steps: int, warmup: int = 3):
    """(median, min) per-step wall time (s). Each step blocks on its loss
    — the monolithic lane is one jitted call, so per-step blocking
    measures the true step latency (compile excluded by the warmup
    steps). The median is the reported figure; the min (best case, the
    standard microbenchmark statistic — scheduler noise only ever ADDS
    time) is what the acceptance comparison uses."""
    import jax
    st = be.init(jax.random.PRNGKey(0), params)
    batches = [_batch(M, 4, width, s) for s in range(4)]
    times = []
    for t in range(warmup + steps):
        t0 = time.perf_counter()
        st, m = be.step(st, batches[t % 4], None)
        float(m["loss"])
        if t >= warmup:
            times.append(time.perf_counter() - t0)
    return float(np.median(times)), float(np.min(times))


def main(steps=None, quick=False):
    import jax
    import jax.numpy as jnp

    from repro.core import FlatPartition, make_backend
    from repro.optim import constant, momentum

    steps = steps or (8 if quick else 30)
    sizes = SIZES_QUICK if quick else SIZES
    M = 2

    section("Gossip path — legacy per-step repack vs persistent flat plane")

    def measure(width, depth, steps):
        loss_fn, params = _problem(width, depth, jnp.float32)
        res = {}
        for flavor, kw in (("legacy", dict(flat=False)),
                           ("flat", dict(flat=True)),
                           ("int8", dict(flat=True, wire="int8"))):
            be = make_backend("prod", "layup", M=M, loss_fn=loss_fn,
                              optimizer=momentum(0.9),
                              schedule=constant(0.05), fb_ratio=1,
                              update_delay=1, measure_drift=False, **kw)
            res[flavor] = _time_steps(be, params, width, M, steps)
        return res, params

    per_size = {}
    for width, depth in sizes:
        res, params = measure(width, depth, steps)
        nparams = sum(int(np.prod(l.shape))
                      for l in jax.tree.leaves(params))
        for flavor in ("legacy", "flat", "int8"):
            med, best = res[flavor]
            emit(f"gossip_path.W{width}xL{depth}.{flavor}", med * 1e6,
                 f"min_us={best * 1e6:.1f};params={nparams};M={M};"
                 f"steps={steps}")
        emit(f"gossip_path.W{width}xL{depth}.speedup",
             (res["legacy"][0] - res["flat"][0]) * 1e6,
             f"x{res['legacy'][0] / res['flat'][0]:.3f}")
        per_size[(width, depth)] = res

    section("Wire bytes — param-dtype wire (bf16 = half the f32 plane); "
            "int8 wire = values + per-row f32 scales")
    for width, depth in sizes:
        _, p32 = _problem(width, depth, jnp.float32)
        _, p16 = _problem(width, depth, jnp.bfloat16)
        b32 = FlatPartition(p32).plane_nbytes()
        b16 = FlatPartition(p16).plane_nbytes()
        b8 = FlatPartition(p16).plane_nbytes(wire="int8")
        emit(f"gossip_path.W{width}xL{depth}.wire_bytes_f32", b32, "")
        emit(f"gossip_path.W{width}xL{depth}.wire_bytes_bf16", b16,
             f"ratio={b16 / b32:.3f}")
        emit(f"gossip_path.W{width}xL{depth}.wire_bytes_int8", b8,
             f"ratio_vs_bf16={b8 / b16:.3f}")
        assert b16 * 2 == b32, (width, depth, b16, b32)
    # acceptance: the int8 wire is at most 0.55× the bf16 wire at the
    # largest size (the per-row scale overhead amortizes with size)
    width, depth = sizes[-1]
    _, p16 = _problem(width, depth, jnp.bfloat16)
    b16 = FlatPartition(p16).plane_nbytes()
    b8 = FlatPartition(p16).plane_nbytes(wire="int8")
    assert b8 <= 0.55 * b16, (
        f"int8 wire {b8}B > 0.55 x bf16 wire {b16}B at W{width}xL{depth}")

    section("Quantized-wire loss parity — wire=int8 vs wire=param")
    width, depth = sizes[0]
    loss_fn, params = _problem(width, depth, jnp.float32)
    parity_steps = max(steps, 12)
    finals = {}
    for flavor, kw in (("param", dict()), ("int8", dict(wire="int8"))):
        be = make_backend("prod", "layup", M=M, loss_fn=loss_fn,
                          optimizer=momentum(0.9), schedule=constant(0.05),
                          fb_ratio=1, update_delay=1, measure_drift=False,
                          flat=True, **kw)
        st = be.init(jax.random.PRNGKey(0), params)
        losses = []
        for t in range(parity_steps):
            st, m = be.step(st, _batch(M, 4, width, t % 4), None)
            losses.append(float(m["loss"]))
        finals[flavor] = float(np.mean(losses[-4:]))
    rel = abs(finals["int8"] - finals["param"]) / max(
        abs(finals["param"]), 1e-9)
    emit(f"gossip_path.W{width}xL{depth}.int8_loss_parity", 0.0,
         f"param={finals['param']:.5f};int8={finals['int8']:.5f};"
         f"rel={rel:.4f}")
    assert rel < 0.1, (
        f"quantized-wire loss diverged: param={finals['param']:.5f} "
        f"int8={finals['int8']:.5f} (rel {rel:.4f})")

    dump_json("gossip_path", prefix="gossip_path.")

    # acceptance: the flat plane is strictly faster than the legacy repack
    # at the LARGEST size of whichever size set ran (--quick included).
    # Wall-clock comparisons on a shared runner are noisy, so the
    # comparison uses the per-flavor MIN step time (noise only ever adds
    # time — the min is the intrinsic cost) and, if even that is inverted
    # by a noisy window, re-measures once before failing.
    big = per_size[sizes[-1]]
    if big["flat"][1] >= big["legacy"][1]:
        print("# largest-size comparison inverted (noisy run?) — "
              "re-measuring once", flush=True)
        big, _ = measure(*sizes[-1], steps)
    assert big["flat"][1] < big["legacy"][1], (
        f"flat plane not faster at {sizes[-1]} (min per-step): "
        f"flat={big['flat'][1] * 1e6:.1f}us "
        f"legacy={big['legacy'][1] * 1e6:.1f}us")
    print(f"# flat plane {big['legacy'][1] / big['flat'][1]:.3f}x faster "
          f"(min per-step) at W{sizes[-1][0]}xL{sizes[-1][1]}", flush=True)
    return per_size


if __name__ == "__main__":
    import argparse

    from benchmarks.common import ensure_host_devices

    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    ensure_host_devices(2)
    main(steps=args.steps, quick=args.quick)
