"""Paper Fig. A1 + Lemma 6.1 analogue: model disagreement over training and
the empirical gradient-bias bound check (E‖b‖² ≤ 4·K̂²·η²·B̂²), plus the
delay-compensation A/B (DESIGN.md §14): at (R, D) ∈ {(2, 1), (4, 2)} the
Zheng-style corrected stale gradient g + λ·g⊙g⊙(θ_now − θ_stale) must
track ∇L(θ_now) at least as well as the raw stale gradient."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import dump_json, emit, section
from benchmarks.table1_vision import _problem
from repro.core import consensus, get_algorithm, make_sim_trainer
from repro.core.drift import (elastic_constant, estimate_lipschitz,
                              gradient_bias, lemma61_bound)
from repro.data.synthetic import make_worker_batches
from repro.optim import cosine, momentum

M = 8
LR = 0.05
LAM = 0.5  # compensation strength for the A/B (DESIGN.md §14)


def _tree_norm(a, b):
    return float(jnp.sqrt(sum(
        jnp.sum((x - y).astype(jnp.float32) ** 2)
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))))


def _compensation_ab(ds, init, loss_fn, steps: int, tail: int = 10):
    """Analytic gradient-bias A/B on recorded θ trajectories.

    Trains the layup sim lane at each (R, D), records worker-0's params
    each step, then on a FIXED batch compares — over ``tail`` steps from
    the MIDDLE of the run, where the cosine schedule still moves θ enough
    for staleness to matter — the raw stale gradient ∇L(θ_{t−D}) against
    the compensated one ∇L(θ_{t−D}) + λ·g⊙g⊙(θ_t − θ_{t−D}), both
    measured by distance to the true current gradient ∇L(θ_t). Returns
    {(R, D): (bias_raw, bias_comp)} means."""
    algo = get_algorithm("layup")
    batch = jax.tree.map(jnp.asarray, make_worker_batches(ds, M, 64, 7))
    b0 = jax.tree.map(lambda x: x[0], batch)
    grad = jax.jit(jax.grad(lambda p: loss_fn(p, b0)[0]))
    out = {}
    for R, D in ((2, 1), (4, 2)):
        init_fn, step_fn = make_sim_trainer(algo, loss_fn, momentum(0.9),
                                            cosine(LR, steps), M,
                                            fb_ratio=R, update_delay=D)
        st = init_fn(jax.random.PRNGKey(0), init(jax.random.PRNGKey(1)))
        rng = jax.random.PRNGKey(2)
        hist = []
        for t in range(steps):
            bt = jax.tree.map(jnp.asarray, make_worker_batches(ds, M, 64, t))
            rng, r = jax.random.split(rng)
            st, _ = step_fn(st, bt, r)
            hist.append(jax.tree.map(lambda x: np.asarray(x[0]), st.params))
        raws, comps = [], []
        mid = max(steps // 2, D)
        for t in range(mid, min(mid + tail, steps)):
            now, stale = hist[t], hist[t - D]
            g_now, g_stale = grad(now), grad(stale)
            g_comp = jax.tree.map(
                lambda g, pn, ps: g + LAM * g * g
                * (pn - ps).astype(g.dtype), g_stale, now, stale)
            raws.append(_tree_norm(g_stale, g_now))
            comps.append(_tree_norm(g_comp, g_now))
        out[(R, D)] = (float(np.mean(raws)), float(np.mean(comps)))
    return out


def main(steps=300, quick=False):
    section("Fig A1 analogue — disagreement; Lemma 6.1 bias bound")
    if quick:
        steps = 120
    ds, init, loss_fn, eval_fn = _problem(M)
    for algo_name in ("layup", "layup-block", "layup-hypercube"):
        algo = get_algorithm(algo_name)
        # cosine to zero — paper's point: disagreement → 0 as lr → 0
        init_fn, step_fn = make_sim_trainer(algo, loss_fn, momentum(0.9),
                                            cosine(LR, steps), M)
        st = init_fn(jax.random.PRNGKey(0), init(jax.random.PRNGKey(1)))
        rng = jax.random.PRNGKey(2)
        dis = []
        for t in range(steps):
            batch = jax.tree.map(jnp.asarray,
                                 make_worker_batches(ds, M, 64, t))
            rng, r = jax.random.split(rng)
            st, m = step_fn(st, batch, r)
            dis.append(float(m["disagreement"]))
        peak, end = float(np.max(dis)), float(np.mean(dis[-10:]))
        emit(f"figA1.{algo_name}.disagreement", 0.0,
             f"peak={peak:.4f};end={end:.4f};bounded={end < peak}")

        if algo_name == "layup":
            batch = jax.tree.map(jnp.asarray,
                                 make_worker_batches(ds, M, 64, steps + 1))
            b0 = jax.tree.map(lambda x: x[0], batch)
            p0 = jax.tree.map(lambda x: x[0], st.params)
            p1 = jax.tree.map(lambda x: x[1], st.params)
            # x̃ = x̂ after one push-sum mix with a peer (the lemma's mixed
            # version: forward ran at x̂ = p0, update lands on x̃)
            w0, w1 = float(st.weights[0]), float(st.weights[1]) / 2
            a, b = w0 / (w0 + w1), w1 / (w0 + w1)
            p_tilde = jax.tree.map(lambda x, y: a * x + b * y, p0, p1)
            k_hat = float(estimate_lipschitz(loss_fn, p0, b0,
                                             jax.random.PRNGKey(5),
                                             n_probes=8))
            b_hat = float(elastic_constant(st.params, st.weights, LR))
            bias = float(gradient_bias(loss_fn, p0, p_tilde, b0))
            bound = float(lemma61_bound(k_hat, LR, b_hat))
            emit("lemma61.bias_sq", 0.0, f"bias2={bias**2:.3e}")
            emit("lemma61.bound", 0.0,
                 f"bound={bound:.3e};holds={bias**2 <= bound}")

    section("Delay compensation A/B — raw vs compensated stale gradient")
    ab = _compensation_ab(ds, init, loss_fn, steps)
    for (R, D), (raw, comp) in ab.items():
        emit(f"figA1.comp.R{R}D{D}", 0.0,
             f"bias_raw={raw:.4e};bias_comp={comp:.4e};"
             f"ratio={comp / max(raw, 1e-12):.4f};lam={LAM}")
    # acceptance: at the deeper-staleness point (4, 2) the compensated
    # stale gradient is no farther from the true gradient than the raw one
    raw42, comp42 = ab[(4, 2)]
    assert comp42 <= raw42, (
        f"compensation failed to reduce gradient bias at (R,D)=(4,2): "
        f"raw={raw42:.4e} comp={comp42:.4e}")
    dump_json("figA1_drift", prefix=("figA1.", "lemma61."))


if __name__ == "__main__":
    main()
