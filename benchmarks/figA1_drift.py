"""Paper Fig. A1 + Lemma 6.1 analogue: model disagreement over training and
the empirical gradient-bias bound check (E‖b‖² ≤ 4·K̂²·η²·B̂²)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, section
from benchmarks.table1_vision import _problem
from repro.core import consensus, get_algorithm, make_sim_trainer
from repro.core.drift import (elastic_constant, estimate_lipschitz,
                              gradient_bias, lemma61_bound)
from repro.data.synthetic import make_worker_batches
from repro.optim import cosine, momentum

M = 8
LR = 0.05


def main(steps=300, quick=False):
    section("Fig A1 analogue — disagreement; Lemma 6.1 bias bound")
    if quick:
        steps = 120
    ds, init, loss_fn, eval_fn = _problem(M)
    for algo_name in ("layup", "layup-block", "layup-hypercube"):
        algo = get_algorithm(algo_name)
        # cosine to zero — paper's point: disagreement → 0 as lr → 0
        init_fn, step_fn = make_sim_trainer(algo, loss_fn, momentum(0.9),
                                            cosine(LR, steps), M)
        st = init_fn(jax.random.PRNGKey(0), init(jax.random.PRNGKey(1)))
        rng = jax.random.PRNGKey(2)
        dis = []
        for t in range(steps):
            batch = jax.tree.map(jnp.asarray,
                                 make_worker_batches(ds, M, 64, t))
            rng, r = jax.random.split(rng)
            st, m = step_fn(st, batch, r)
            dis.append(float(m["disagreement"]))
        peak, end = float(np.max(dis)), float(np.mean(dis[-10:]))
        emit(f"figA1.{algo_name}.disagreement", 0.0,
             f"peak={peak:.4f};end={end:.4f};bounded={end < peak}")

        if algo_name == "layup":
            batch = jax.tree.map(jnp.asarray,
                                 make_worker_batches(ds, M, 64, steps + 1))
            b0 = jax.tree.map(lambda x: x[0], batch)
            p0 = jax.tree.map(lambda x: x[0], st.params)
            p1 = jax.tree.map(lambda x: x[1], st.params)
            # x̃ = x̂ after one push-sum mix with a peer (the lemma's mixed
            # version: forward ran at x̂ = p0, update lands on x̃)
            w0, w1 = float(st.weights[0]), float(st.weights[1]) / 2
            a, b = w0 / (w0 + w1), w1 / (w0 + w1)
            p_tilde = jax.tree.map(lambda x, y: a * x + b * y, p0, p1)
            k_hat = float(estimate_lipschitz(loss_fn, p0, b0,
                                             jax.random.PRNGKey(5),
                                             n_probes=8))
            b_hat = float(elastic_constant(st.params, st.weights, LR))
            bias = float(gradient_bias(loss_fn, p0, p_tilde, b0))
            bound = float(lemma61_bound(k_hat, LR, b_hat))
            emit("lemma61.bias_sq", 0.0, f"bias2={bias**2:.3e}")
            emit("lemma61.bound", 0.0,
                 f"bound={bound:.3e};holds={bias**2 <= bound}")


if __name__ == "__main__":
    main()
