"""Straggler robustness demo (paper Fig. 3): inject a slow worker and watch
LayUp keep converging at full speed while DDP's wall-clock blows up.

    PYTHONPATH=src python examples/straggler_demo.py [--delay 4]
    PYTHONPATH=src python examples/straggler_demo.py --backend prod \
        [--fb-ratio 2] [--update-delay 1] [--overlap [--streams 3]] \
        [--wire int8] [--compensate 0.5]

All execution engines run behind the same ``TrainerBackend`` protocol: the
numeric backend (``sim``: vmapped workers on one device; ``prod``: the
decoupled shard_map lane on an 8-device host mesh) produces the loss and
the measured per-layer staleness, while the event backend produces the
modeled wall-clock — stepped in lock-step per iteration. With ``--backend
prod`` the decoupled step *absorbs* the injected straggler delay: the slow
worker skips its local updates but keeps gossiping, the event simulator
predicts the wall-clock stays pinned to the fast workers, and the measured
per-layer staleness is printed next to the simulator's prediction.
"""
import argparse
import os

M = 8


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--delay", type=int, default=4)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--backend", choices=["sim", "prod"], default="sim")
    ap.add_argument("--fb-ratio", type=int, default=2,
                    help="prod backend: forward passes per backward")
    ap.add_argument("--update-delay", type=int, default=1,
                    help="prod backend: gradient FIFO depth D")
    ap.add_argument("--overlap", action="store_true",
                    help="prod backend: run the stage-graph pipeline engine "
                         "instead of the monolithic jitted step — separately "
                         "jitted fwd/update/gossip stages driven by an "
                         "async-dispatch host loop, with the measured "
                         "per-stage timeline (dispatch overlap, DESIGN.md "
                         "§10) printed after the run")
    ap.add_argument("--streams", type=int, default=1,
                    help="prod backend, needs --overlap: number of execution "
                         "streams (host threads standing in for device "
                         "streams). >1 runs forward slices, update and the "
                         "per-group one-sided signal gossip concurrently and "
                         "prints EXECUTION-level accounting (exec_overlap_s, "
                         "per-stream busy, signal-wait — DESIGN.md §13); "
                         "numerics stay bit-exact vs --streams 1")
    ap.add_argument("--wire", choices=["param", "int8"], default="param",
                    help="prod backend: gossip wire dtype. int8 ships "
                         "error-feedback quantized planes (values + "
                         "per-128-lane-row f32 scales — about half the "
                         "bf16 wire bytes, DESIGN.md §14); param is the "
                         "exact params-dtype wire")
    ap.add_argument("--compensate", type=float, default=0.0,
                    help="prod backend: strength λ of the staleness-aware "
                         "delay compensation g + λ·g⊙g⊙(θ_now − θ_stale) "
                         "applied to the popped stale gradient (0 = off, "
                         "DESIGN.md §14)")
    ap.add_argument("--faults", type=str, default=None,
                    help="prod backend: deterministic chaos plan, e.g. "
                         "'crash:peer=1,step=50,recover=120' or "
                         "'corrupt:step=30,group=0;hang:step=40,"
                         "seconds=0.1'. Turns the fault-tolerant "
                         "membership lane on (alive-gated push-sum, "
                         "deadline-guarded gossip, donor re-sync — "
                         "DESIGN.md §15) and prints the membership "
                         "timeline + degraded-round accounting after "
                         "the run. '' enables membership with no faults")
    args = ap.parse_args()
    if args.streams > 1 and not args.overlap:
        ap.error("--streams > 1 requires --overlap (DESIGN.md §13)")
    if (args.wire != "param" or args.compensate
            or args.faults is not None) and args.backend != "prod":
        ap.error("--wire / --compensate / --faults apply to the prod lane "
                 "only (use --backend prod)")

    if args.backend == "prod":
        # the prod lane needs one host device per worker; both env vars must
        # be set before jax initializes (append — don't clobber any flags
        # the user already exported)
        flag = f"--xla_force_host_platform_device_count={M}"
        existing = os.environ.get("XLA_FLAGS", "")
        if "xla_force_host_platform_device_count" not in existing:
            os.environ["XLA_FLAGS"] = (existing + " " + flag).strip()
        os.environ.setdefault("JAX_PLATFORMS", "cpu")

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import make_backend
    from repro.core.simulator import HardwareModel
    from repro.data.synthetic import SyntheticVision, make_worker_batches
    from repro.optim import constant, momentum

    ds = SyntheticVision(num_classes=10, dim=64, snr=1.2)

    def init(rng):
        k1, k2 = jax.random.split(rng)
        return {"l1": jax.random.normal(k1, (64, 128)) * 0.1,
                "l2": jax.random.normal(k2, (128, 10)) * 0.1}

    def loss_fn(p, b):
        h = jnp.tanh(b["x"] @ p["l1"])
        logits = h @ p["l2"]
        return -jnp.mean(jax.nn.log_softmax(logits)[
            jnp.arange(logits.shape[0]), b["labels"]]), {}

    delays = np.zeros(M, int)
    delays[0] = args.delay
    hw = HardwareModel(fwd_time=0.02, bwd_ratio=2.0, model_bytes=0.4e9,
                       allreduce_bandwidth=60e9)

    print(f"{M} workers, worker 0 is {args.delay}× slower\n")

    if args.backend == "prod":
        run_prod(args, hw, ds, init, loss_fn, delays)
        return

    print(f"{'algo':10s} {'final loss':>10s} {'wall-clock (s)':>15s} "
          f"{'vs no-straggler':>16s}")
    for algo_name in ("ddp", "slowmo", "gosgd", "layup"):
        num = make_backend("sim", algo_name, M=M, loss_fn=loss_fn,
                           optimizer=momentum(0.9), schedule=constant(0.05),
                           straggler_delays=delays)
        ev_slow = make_backend("event", algo_name, M=M, hw=hw,
                               straggler_delays=delays)
        ev_fast = make_backend("event", algo_name, M=M, hw=hw)
        st = num.init(jax.random.PRNGKey(0), init(jax.random.PRNGKey(1)))
        sl = ev_slow.init(jax.random.PRNGKey(0))
        fa = ev_fast.init(jax.random.PRNGKey(0))
        rng = jax.random.PRNGKey(2)
        loss = None
        for t in range(args.steps):
            batch = jax.tree.map(jnp.asarray,
                                 make_worker_batches(ds, M, 32, t))
            rng, r = jax.random.split(rng)
            st, m = num.step(st, batch, r)
            sl, _ = ev_slow.step(sl, None, None)
            fa, _ = ev_fast.step(fa, None, None)
            loss = float(m["loss"])
        t_slow = ev_slow.result().total_time
        t_fast = ev_fast.result().total_time
        print(f"{algo_name:10s} {loss:10.4f} {t_slow:15.1f} "
              f"{t_slow / t_fast:15.2f}×")


def run_prod(args, hw, ds, init, loss_fn, delays):
    """Decoupled prod lane vs the event simulator's prediction."""
    # jax is initialized by main() before this runs; imports are cached
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import make_backend
    from repro.data.synthetic import make_worker_batches
    from repro.optim import constant, momentum

    R, D = args.fb_ratio, args.update_delay
    if args.streams > 1:
        engine = f"stream engine, {args.streams} execution streams"
    elif args.overlap:
        engine = "stage-graph pipeline engine"
    else:
        engine = "monolithic jitted step"
    extras = ""
    if args.wire != "param":
        extras += f", {args.wire} wire"
    if args.compensate:
        extras += f", delay compensation λ={args.compensate:g}"
    if args.faults is not None:
        from repro.chaos import FaultPlan
        extras += (f", chaos: {FaultPlan.parse(args.faults).describe()}")
    print(f"prod decoupled lane: R={R}, D={D} "
          f"(double-buffered params, {D}-deep gradient FIFO, "
          f"{engine}{extras})\n")
    num = make_backend("prod", "layup", M=M, loss_fn=loss_fn,
                       optimizer=momentum(0.9), schedule=constant(0.05),
                       fb_ratio=R, update_delay=D,
                       straggler_delays=delays, shifts=(1, 2, 4),
                       overlap=args.overlap, streams=args.streams,
                       wire=args.wire, compensate=args.compensate,
                       faults=args.faults)
    ev_slow = make_backend("event", "layup", M=M, hw=hw,
                           straggler_delays=delays, fb_ratio=R,
                           update_delay=D)
    ev_fast = make_backend("event", "layup", M=M, hw=hw, fb_ratio=R,
                           update_delay=D)
    st = num.init(jax.random.PRNGKey(0), init(jax.random.PRNGKey(1)))
    sl = ev_slow.init(jax.random.PRNGKey(0))
    fa = ev_fast.init(jax.random.PRNGKey(0))
    rng = jax.random.PRNGKey(2)
    m = None
    # the prod lane splits each worker batch into R forward slices
    bpw = 32 * max(R, 1)
    for t in range(args.steps):
        batch = jax.tree.map(jnp.asarray,
                             make_worker_batches(ds, M, bpw, t))
        rng, r = jax.random.split(rng)
        st, m = num.step(st, batch, r)
        sl, _ = ev_slow.step(sl, None, None)
        fa, _ = ev_fast.step(fa, None, None)

    r_slow = ev_slow.result()
    r_fast = ev_fast.result()
    iters = args.steps
    iter_time = r_slow.total_time / iters
    predicted_iters = (r_slow.mean_grad_staleness / iter_time
                       if iter_time > 0 else 0.0)
    print(f"final loss                 {float(m['loss']):.4f}")
    print(f"wall-clock (straggler)     {r_slow.total_time:.1f}s "
          f"({r_slow.total_time / r_fast.total_time:.2f}× the no-straggler "
          f"run — the decoupled lane absorbs the delay)")
    print(f"utilization                {r_slow.utilization:.3f} "
          f"(event-sim: compute never stalls on the NIC)")
    ls = np.asarray(m["layer_staleness"])
    print("\nmeasured per-layer staleness (iterations, prod lane) "
          "vs event-sim prediction:")
    for g, s in enumerate(ls):
        print(f"  group {g}: {s:.3f}")
    print(f"  mean measured            {float(m['staleness_mean']):.3f}")
    print(f"  update staleness (FIFO)  {float(m['update_staleness']):.3f} "
          f"(== D after warm-up)")
    print(f"  staleness delta vs D     "
          f"{float(m['update_staleness']) - D:+.3f} "
          f"(measured − nominal FIFO depth)")
    print(f"  event-sim grad staleness {predicted_iters:.3f} iterations "
          f"({r_slow.mean_grad_staleness * 1e3:.1f} ms)")
    wire_b = num.part.plane_nbytes(wire=args.wire)
    print(f"\ngossip wire                {args.wire} "
          f"({wire_b / 1e3:.1f} KB/round per worker, one full plane "
          f"across all layer groups)")
    if args.compensate:
        print(f"delay compensation         λ={args.compensate:g} "
              f"(g + λ·g⊙g⊙(θ_now − θ_stale) on the popped gradient)")

    if args.overlap:
        s = num.summary()
        tl = num.timeline.summary()
        print("\nmeasured stage timeline (pipeline engine, host "
              "dispatch/complete timestamps):")
        for stage, total in sorted(tl["stage_s"].items()):
            print(f"  {stage:8s} in-flight {total:8.3f}s total "
                  f"({total / args.steps * 1e3:7.2f} ms/step)")
        print(f"  wall                     {s['pipeline_wall_s']:.3f}s")
        print(f"  dispatches that found a stage in flight: "
              f"{int(s['overlap_events'])}")
        print(f"  fwd(t+1) over gossip(t)  {s['fwd_gossip_overlap_s']:.3f}s "
              f"(measured — the overlap the monolithic step cannot exhibit)")
        if args.streams > 1:
            print("\nmeasured execution concurrency (stream engine, "
                  "closed per-stream spans):")
            for name, busy in sorted(tl["stream_busy_s"].items()):
                print(f"  stream {name:8s} busy {busy:8.3f}s")
            print(f"  exec_overlap_s           {s['exec_overlap_s']:.3f}s "
                  f"(2+ streams executing simultaneously)")
            print(f"  signal_wait_s            {s['signal_wait_s']:.3f}s "
                  f"(one-sided signal predicates, DESIGN.md §13)")

    if args.faults is not None:
        s = num.summary()
        print("\nmembership timeline (fault-tolerant lane, DESIGN.md §15):")
        events = num.chaos.health.events
        if events:
            for epoch, peer, old, new in events:
                print(f"  step {epoch:4d}  peer {peer}  "
                      f"{old:>7s} -> {new}")
        else:
            print("  (no membership transitions — all peers stayed ALIVE)")
        print("degraded-round accounting:")
        print(f"  faults injected          {int(s['faults_injected'])}")
        print(f"  rounds degraded          {int(s['rounds_degraded'])} "
              f"(gossip rounds with <{M} live peers or a wire event)")
        print(f"  peers dead at exit       {int(s['peers_dead'])}")
        print(f"  donor re-syncs           {int(s['resyncs'])}")
        print(f"  nonfinite grads skipped  {s['nonfinite_skips']:g}")
        if "time_to_detect_steps" in s:
            print(f"  time to detect (steps)   "
                  f"{s['time_to_detect_steps']:g}")
        if "time_to_resync_steps" in s:
            print(f"  time to re-sync (steps)  "
                  f"{s['time_to_resync_steps']:g}")
        print(f"  push-sum mass Σw         {float(s['weight_sum']):.6f} "
              f"(conserved = 1.0 through crash/renorm/recovery)")


if __name__ == "__main__":
    main()
