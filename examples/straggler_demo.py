"""Straggler robustness demo (paper Fig. 3): inject a slow worker and watch
LayUp keep converging at full speed while DDP's wall-clock blows up.

    PYTHONPATH=src python examples/straggler_demo.py [--delay 4]

Both execution engines run behind the same ``TrainerBackend`` protocol:
the numeric sim backend produces the loss, the event backend the modeled
wall-clock — stepped in lock-step per iteration.
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import make_backend
from repro.core.simulator import HardwareModel
from repro.data.synthetic import SyntheticVision, make_worker_batches
from repro.optim import constant, momentum

M = 8


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--delay", type=int, default=4)
    ap.add_argument("--steps", type=int, default=200)
    args = ap.parse_args()

    ds = SyntheticVision(num_classes=10, dim=64, snr=1.2)

    def init(rng):
        k1, k2 = jax.random.split(rng)
        return {"l1": jax.random.normal(k1, (64, 128)) * 0.1,
                "l2": jax.random.normal(k2, (128, 10)) * 0.1}

    def loss_fn(p, b):
        h = jnp.tanh(b["x"] @ p["l1"])
        logits = h @ p["l2"]
        return -jnp.mean(jax.nn.log_softmax(logits)[
            jnp.arange(logits.shape[0]), b["labels"]]), {}

    delays = np.zeros(M, int)
    delays[0] = args.delay
    hw = HardwareModel(fwd_time=0.02, bwd_ratio=2.0, model_bytes=0.4e9,
                       allreduce_bandwidth=60e9)

    print(f"{M} workers, worker 0 is {args.delay}× slower\n")
    print(f"{'algo':10s} {'final loss':>10s} {'wall-clock (s)':>15s} "
          f"{'vs no-straggler':>16s}")
    for algo_name in ("ddp", "slowmo", "gosgd", "layup"):
        num = make_backend("sim", algo_name, M=M, loss_fn=loss_fn,
                           optimizer=momentum(0.9), schedule=constant(0.05),
                           straggler_delays=delays)
        ev_slow = make_backend("event", algo_name, M=M, hw=hw,
                               straggler_delays=delays)
        ev_fast = make_backend("event", algo_name, M=M, hw=hw)
        st = num.init(jax.random.PRNGKey(0), init(jax.random.PRNGKey(1)))
        sl = ev_slow.init(jax.random.PRNGKey(0))
        fa = ev_fast.init(jax.random.PRNGKey(0))
        rng = jax.random.PRNGKey(2)
        loss = None
        for t in range(args.steps):
            batch = jax.tree.map(jnp.asarray, make_worker_batches(ds, M, 32, t))
            rng, r = jax.random.split(rng)
            st, m = num.step(st, batch, r)
            sl, _ = ev_slow.step(sl, None, None)
            fa, _ = ev_fast.step(fa, None, None)
            loss = float(m["loss"])
        t_slow = ev_slow.result().total_time
        t_fast = ev_fast.result().total_time
        print(f"{algo_name:10s} {loss:10.4f} {t_slow:15.1f} "
              f"{t_slow / t_fast:15.2f}×")


if __name__ == "__main__":
    main()
