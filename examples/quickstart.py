"""Quickstart: train a small LM with LayUp vs DDP on the sim backend.

    PYTHONPATH=src python examples/quickstart.py [--steps 200] [--algo layup]

Shows the public API end to end: config → model → algorithm → trainer →
metrics (loss, disagreement, push-sum mass).
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import get_algorithm, make_sim_trainer, consensus
from repro.data.synthetic import SyntheticLM, make_worker_batches
from repro.models import build_model
from repro.optim import linear_warmup_cosine, momentum


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--algo", default="layup",
                    choices=["layup", "ddp", "gosgd", "adpsgd", "localsgd",
                             "slowmo", "co2"])
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--workers", type=int, default=4)
    args = ap.parse_args()

    cfg = ModelConfig(name="quickstart-lm", family="dense", num_layers=2,
                      d_model=128, num_heads=4, num_kv_heads=2, d_ff=256,
                      vocab_size=128)
    model = build_model(cfg)
    ds = SyntheticLM(vocab=cfg.vocab_size, seq_len=64, temperature=1.2)
    print(f"model: {cfg.name}  params≈{cfg.param_counts()['total']/1e6:.2f}M  "
          f"irreducible ppl={float(jnp.exp(ds.entropy)):.2f}")

    algo = get_algorithm(args.algo)
    init_fn, step_fn = make_sim_trainer(
        algo, lambda p, b: model.loss_fn(p, b, block_k=32), momentum(0.9),
        linear_warmup_cosine(0.15, 20, args.steps), args.workers)
    state = init_fn(jax.random.PRNGKey(0), model.init(jax.random.PRNGKey(1)))

    rng = jax.random.PRNGKey(2)
    for t in range(args.steps):
        batch = jax.tree.map(jnp.asarray,
                             make_worker_batches(ds, args.workers, 16, t))
        rng, r = jax.random.split(rng)
        state, m = step_fn(state, batch, r)
        if (t + 1) % 25 == 0:
            print(f"step {t+1:4d}  loss={float(m['loss']):.4f}  "
                  f"ppl={float(jnp.exp(m['loss'])):.2f}  "
                  f"disagreement={float(m.get('disagreement', 0)):.4f}  "
                  f"Σw={float(m['weight_sum']):.4f}")

    xbar = consensus(state.params, state.weights)
    eval_batch = {k: jnp.asarray(v) for k, v in ds.sample(
        __import__("numpy").random.default_rng(9), 64).items()}
    loss, _ = model.loss_fn(xbar, eval_batch, block_k=32)
    print(f"\nfinal consensus eval ppl: {float(jnp.exp(loss)):.2f} "
          f"(floor {float(jnp.exp(ds.entropy)):.2f})")


if __name__ == "__main__":
    main()
