"""Quickstart: train a small LM with LayUp vs DDP on the sim backend.

    PYTHONPATH=src python examples/quickstart.py [--steps 200] [--algo layup]
    PYTHONPATH=src python examples/quickstart.py --fb-ratio 2 --update-delay 1

Shows the public API end to end: config → model → algorithm →
TrainerBackend → metrics (loss, disagreement, push-sum mass, per-layer
staleness). ``--fb-ratio``/``--update-delay`` switch on the paper's
decoupled forward/backward execution.
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import consensus, list_algorithms, make_backend
from repro.data.synthetic import SyntheticLM, make_worker_batches
from repro.models import build_model
from repro.optim import linear_warmup_cosine, momentum


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--algo", default="layup", choices=list_algorithms())
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--fb-ratio", type=int, default=1,
                    help="forward passes per backward (decoupled mode)")
    ap.add_argument("--update-delay", type=int, default=0,
                    help="iterations between a gradient's forward and its "
                         "application (decoupled mode)")
    args = ap.parse_args()

    cfg = ModelConfig(name="quickstart-lm", family="dense", num_layers=2,
                      d_model=128, num_heads=4, num_kv_heads=2, d_ff=256,
                      vocab_size=128)
    model = build_model(cfg)
    ds = SyntheticLM(vocab=cfg.vocab_size, seq_len=64, temperature=1.2)
    print(f"model: {cfg.name}  params≈{cfg.param_counts()['total']/1e6:.2f}M  "
          f"irreducible ppl={float(jnp.exp(ds.entropy)):.2f}")

    backend = make_backend(
        "sim", args.algo, M=args.workers,
        loss_fn=lambda p, b: model.loss_fn(p, b, block_k=32),
        optimizer=momentum(0.9),
        schedule=linear_warmup_cosine(0.15, 20, args.steps),
        fb_ratio=args.fb_ratio, update_delay=args.update_delay)
    state = backend.init(jax.random.PRNGKey(0),
                         model.init(jax.random.PRNGKey(1)))

    rng = jax.random.PRNGKey(2)
    for t in range(args.steps):
        batch = jax.tree.map(jnp.asarray,
                             make_worker_batches(ds, args.workers, 16, t))
        rng, r = jax.random.split(rng)
        state, m = backend.step(state, batch, r)
        if (t + 1) % 25 == 0:
            print(f"step {t+1:4d}  loss={float(m['loss']):.4f}  "
                  f"ppl={float(jnp.exp(m['loss'])):.2f}  "
                  f"disagreement={float(m.get('disagreement', 0)):.4f}  "
                  f"Σw={float(m['weight_sum']):.4f}  "
                  f"staleness={float(m['staleness_mean']):.2f}")

    xbar = consensus(state.params, state.weights)
    eval_batch = {k: jnp.asarray(v) for k, v in ds.sample(
        __import__("numpy").random.default_rng(9), 64).items()}
    loss, _ = model.loss_fn(xbar, eval_batch, block_k=32)
    print(f"\nfinal consensus eval ppl: {float(jnp.exp(loss)):.2f} "
          f"(floor {float(jnp.exp(ds.entropy)):.2f})")
    print("backend summary:", backend.summary())


if __name__ == "__main__":
    main()
