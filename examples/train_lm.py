"""End-to-end training driver: ~100M-parameter LM, LayUp with all substrates
(data pipeline w/ prefetch, cosine schedule, checkpointing, drift metrics).

    PYTHONPATH=src python examples/train_lm.py --steps 300 [--small]

The full run trains a ~100M model (GPT-2-small-ish dims) on the synthetic
Markov language for a few hundred steps on CPU; --small shrinks it for a
fast demo. Checkpoints land in /tmp/repro_ckpt; training resumes from the
latest checkpoint if present.
"""
import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.configs.base import ModelConfig
from repro.core import consensus, make_backend
from repro.data.pipeline import ShardedIterator
from repro.data.synthetic import SyntheticLM
from repro.models import build_model
from repro.optim import adamw, linear_warmup_cosine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--algo", default="layup")
    ap.add_argument("--small", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=100)
    args = ap.parse_args()

    if args.small:
        cfg = ModelConfig(name="lm-small", family="dense", num_layers=2,
                          d_model=128, num_heads=4, num_kv_heads=2,
                          d_ff=512, vocab_size=512)
        seq, bpw = 64, 8
    else:
        # ~100M params: 12L × 512 d_model, vocab 32k (GPT-2-small-ish)
        cfg = ModelConfig(name="lm-100m", family="dense", num_layers=12,
                          d_model=768, num_heads=12, num_kv_heads=12,
                          d_ff=3072, vocab_size=32000)
        seq, bpw = 128, 4
    model = build_model(cfg)
    print(f"{cfg.name}: {cfg.param_counts()['total']/1e6:.1f}M params, "
          f"{args.workers} workers × batch {bpw} × seq {seq}, {args.algo}")

    ds = SyntheticLM(vocab=cfg.vocab_size, seq_len=seq, temperature=1.2)
    opt = adamw(weight_decay=0.01)
    sched = linear_warmup_cosine(3e-4, 30, args.steps)
    backend = make_backend(
        "sim", args.algo, M=args.workers,
        loss_fn=lambda p, b: model.loss_fn(p, b, block_k=64),
        optimizer=opt, schedule=sched)
    state = backend.init(jax.random.PRNGKey(0),
                         model.init(jax.random.PRNGKey(1)))

    start = 0
    if latest_step(args.ckpt_dir) is not None:
        start = latest_step(args.ckpt_dir)
        state = restore_checkpoint(args.ckpt_dir, start, state,
                                   fill_missing=True)
        print(f"resumed from step {start}")

    it = ShardedIterator(ds, args.workers, bpw, prefetch=2)
    rng = jax.random.PRNGKey(2)
    t_start = time.time()
    try:
        for t in range(start, args.steps):
            batch = next(it)
            rng, r = jax.random.split(rng)
            state, m = backend.step(state, batch, r)
            if (t + 1) % 20 == 0:
                rate = (t + 1 - start) * args.workers * bpw * seq / (
                    time.time() - t_start)
                print(f"step {t+1:4d}  loss={float(m['loss']):.4f}  "
                      f"lr={float(m['lr']):.2e}  "
                      f"dis={float(m.get('disagreement', 0)):.4f}  "
                      f"tok/s={rate:,.0f}")
            if (t + 1) % args.ckpt_every == 0:
                path = save_checkpoint(args.ckpt_dir, t + 1, state)
                print(f"checkpoint → {path}")
    finally:
        it.close()

    xbar = consensus(state.params, state.weights)
    eval_batch = {k: jnp.asarray(v)
                  for k, v in ds.sample(np.random.default_rng(9), 32).items()}
    loss, _ = model.loss_fn(xbar, eval_batch, block_k=64)
    print(f"final eval ppl {float(jnp.exp(loss)):.2f} "
          f"(floor {float(np.exp(ds.entropy)):.2f})")


if __name__ == "__main__":
    main()
