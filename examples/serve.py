"""Serving demo: continuous batching over the decode step, static or live.

    PYTHONPATH=src python examples/serve.py [--arch granite-8b] [--tokens 32]
    PYTHONPATH=src python examples/serve.py --live [--train-steps 6]

Known --arch values (REDUCED variants on the CPU container; full configs
are exercised via the dry-runs):

    decoder LMs : gpt2-medium, gpt2-xl, granite-8b, stablelm-1.6b, yi-34b
    MoE         : mixtral-8x7b, moonshot-v1-16b-a3b, qwen3-moe-30b-a3b
    SSM / hybrid: mamba2-780m, jamba-v0.1-52b
    multimodal  : qwen2-vl-2b, whisper-large-v3  (need modality inputs —
                  not servable by this text-only demo loop)

The default path serves a static parameter set (what you would load from a
checkpoint) through :class:`repro.launch.serve.ServeLoop` — slot-based
continuous batching with prefill-by-decode — and prints the loop's
``stats()`` summary.

``--live`` instead runs the decoupled trainer (M=1, one CPU device) in a
background thread with a :class:`repro.serving.PlanePublisher` attached:
each gossip round publishes the flat read plane, a
:class:`repro.serving.SwapPolicy` gates it, and the
:class:`repro.serving.LiveServer` hot-swaps accepted planes into the
serving params between decode steps — no checkpoint save/load anywhere
(DESIGN.md §12).
"""
import argparse
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.launch.serve import Request, ServeLoop
from repro.models import build_model


def _requests(cfg, n, prompt_len, max_new):
    rs = np.random.default_rng(1)
    return [Request(uid=i,
                    prompt=rs.integers(0, cfg.vocab_size, prompt_len,
                                       dtype=np.int32),
                    max_new_tokens=max_new)
            for i in range(n)]


def _print_stats(stats):
    print("stats:")
    for k, v in stats.items():
        print(f"  {k:22s} {v}")


def serve_static(args):
    """Default path: static params (the checkpoint case), ServeLoop only."""
    cfg = reduced(get_config(args.arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    print(f"{cfg.name} ({cfg.family}): {args.batch} slots, "
          f"prompt={args.prompt_len}, decode={args.tokens}")

    loop = ServeLoop(model, params, num_slots=args.batch,
                     max_len=args.prompt_len + args.tokens)
    reqs = _requests(cfg, 2 * args.batch, args.prompt_len, args.tokens)
    t0 = time.time()
    out = loop.serve(reqs)
    dt = time.time() - t0
    print(f"served {len(out)} requests, {loop.tokens_emitted} tokens "
          f"in {dt:.2f}s ({loop.tokens_emitted / max(dt, 1e-9):.1f} tok/s)")
    print("generated token ids (uid 0):", out[0])
    _print_stats(loop.stats())


def serve_live(args):
    """--live: decoupled trainer publishes the read plane; the LiveServer
    swaps it into the serving params mid-decode, checkpoint-free."""
    from repro.core import make_backend
    from repro.data.synthetic import SyntheticLM, make_worker_batches
    from repro.optim import constant, momentum
    from repro.serving import (AdmissionQueue, LiveServer, PlanePublisher,
                               SwapPolicy)

    cfg = reduced(get_config(args.arch))
    model = build_model(cfg)
    pub = PlanePublisher()
    be = make_backend(
        "prod", "layup", M=1,
        loss_fn=lambda p, b: model.loss_fn(p, b, block_k=64),
        optimizer=momentum(0.9), schedule=constant(0.02),
        fb_ratio=2, update_delay=1, measure_drift=True, publisher=pub)
    params = model.init(jax.random.PRNGKey(0))
    state = be.init(jax.random.PRNGKey(1), params)
    ds = SyntheticLM(vocab=cfg.vocab_size, seq_len=32, temperature=1.2)
    print(f"{cfg.name} ({cfg.family}): live serving while training "
          f"{args.train_steps} steps on the same device")

    def train():
        st = state
        for t in range(args.train_steps):
            batch = jax.tree.map(jnp.asarray, make_worker_batches(ds, 1, 4, t))
            st, m = be.step(st, batch, None)
            print(f"  train step {t}: loss={float(m['loss']):.3f} "
                  f"(published {pub.stats.published})")

    loop = ServeLoop(model, params, num_slots=args.batch,
                     max_len=args.prompt_len + args.tokens)
    adm = AdmissionQueue(max_depth=4 * args.batch)
    # M=1 never stamps gossip version clocks, so gate on drift/cadence only
    srv = LiveServer(loop, be.part, pub,
                     policy=SwapPolicy(max_drift=args.max_drift),
                     admission=adm)
    for r in _requests(cfg, 2 * args.batch, args.prompt_len, args.tokens):
        ticket = adm.submit(r)
        if not ticket.accepted:
            print(f"  request {r.uid} rejected "
                  f"(retry in {ticket.retry_after_s:.2f}s)")

    trainer = threading.Thread(target=train)
    trainer.start()
    while trainer.is_alive() or adm.depth or any(
            s.req is not None for s in loop.slots):
        if not srv.step():
            time.sleep(0.002)
    trainer.join()
    srv.poll()  # pick up the final publish
    print(f"served on params_version={loop.params_version} "
          f"after {srv.swap_count} live swaps")
    _print_stats(srv.stats())


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--batch", type=int, default=4,
                    help="decode slots (continuous batching width)")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--live", action="store_true",
                    help="serve live weights from a concurrent trainer")
    ap.add_argument("--train-steps", type=int, default=6)
    ap.add_argument("--max-drift", type=float, default=None,
                    help="reject published planes above this figA1 "
                         "disagreement (live mode)")
    args = ap.parse_args()
    if args.live:
        serve_live(args)
    else:
        serve_static(args)


if __name__ == "__main__":
    main()
