"""Serving demo: prefill + batched incremental decode with KV cache.

    PYTHONPATH=src python examples/serve.py [--arch mixtral-8x7b] [--tokens 32]

Uses the REDUCED variant of the chosen architecture (CPU container); the
full configs are exercised via the dry-run. Demonstrates the serve path the
decode_32k / long_500k shapes lower: prefill a prompt batch, then decode
tokens one at a time (greedy).
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.data.synthetic import lm_batch_for
from repro.models import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B = args.batch
    total = args.prompt_len + args.tokens

    print(f"{cfg.name} ({cfg.family}): B={B}, prompt={args.prompt_len}, "
          f"decode={args.tokens}")

    # ---- prefill via incremental decode over the prompt --------------------
    # (the batch prefill_fn path is exercised by prefill_32k dry-runs; here
    # we show the pure decode loop, which works for every family)
    batch = lm_batch_for(cfg, B, args.prompt_len, seed=1)
    prompt = batch.get("tokens",
                       jnp.zeros((B, args.prompt_len), jnp.int32))
    cache = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                         model.cache_specs(B, total))
    decode = jax.jit(model.decode_fn, donate_argnums=(1,))

    t0 = time.time()
    logits = None
    for t in range(args.prompt_len):
        logits, cache = decode(params, cache, prompt[:, t:t + 1],
                               jnp.full((B,), t, jnp.int32))
    jax.block_until_ready(logits)
    print(f"prefill: {args.prompt_len} steps in {time.time() - t0:.2f}s")

    # ---- greedy decode -------------------------------------------------------
    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    out_tokens = [tok]
    t0 = time.time()
    for t in range(args.prompt_len, total - 1):
        logits, cache = decode(params, cache, tok,
                               jnp.full((B,), t, jnp.int32))
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    n = len(out_tokens) - 1
    print(f"decode: {n} steps × batch {B} in {dt:.2f}s "
          f"({B * n / max(dt, 1e-9):.1f} tok/s)")
    gen = jnp.concatenate(out_tokens, axis=1)
    print("generated token ids (seq 0):", gen[0].tolist())


if __name__ == "__main__":
    main()
